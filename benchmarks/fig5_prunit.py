"""Paper Fig 5a: PrunIT vertex reduction (superlevel filtration, degree
filtering function — every dominated vertex is removable, paper Remark 8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report
from repro.core.api import reduction_stats
from repro.data import graphs as gdata

DATASETS = ("DHFR", "ENZYMES", "NCI1", "PROTEINS", "SYNNEW", "OHSU",
            "TWITTER", "FACEBOOK", "FIRSTMM")


def run(report: Report, batch: int = 32) -> None:
    key = jax.random.PRNGKey(7)
    for name in DATASETS:
        g = gdata.load_dataset(name, key, batch=batch)
        st = reduction_stats(g, dim=0, method="prunit", sublevel=False)
        report.add("fig5a_prunit", f"{name}_vertex_reduction_pct",
                   float(jnp.mean(st.v_reduction_pct())))


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
