"""TopoWatch end-to-end smoke: live endpoints + a forced SLO breach.

The CI ``obs-watch`` step.  Exercises the whole active-observability
chain against a real TopoServe drain loop:

1. start a TopoServe ``serve_forever`` thread + the HTTP exporter;
2. assert ``/readyz`` flips to ready (plan-cache warmed), ``/healthz``
   reports a fresh heartbeat, ``/metrics`` is parseable Prometheus text,
   and ``/slo`` serves the installed engine's verdicts;
3. detune the drain (deterministic stall past a tightened p99 ceiling)
   so the latency SLO *must* trip: assert the breach is visible at
   ``/slo``, counted in ``slo.breaches_total``, and that the breach hook
   auto-dumped the flight ring to ``results/obs/FLIGHT_<rev>.json``;
4. load the dump back and sanity-check its schema.

Exit code 0 only if every step held.

  PYTHONPATH=src python -m benchmarks.obs_watch_smoke
"""
from __future__ import annotations

import json
import sys
import threading
import time
import urllib.request

from repro import obs
from repro.obs import flight, slo
from repro.serve import TopoServe, TopoServeConfig


def _get(url: str, expect: int = 200):
    try:
        with urllib.request.urlopen(url, timeout=10) as resp:
            return resp.status, resp.read()
    except urllib.error.HTTPError as e:
        body = e.read()
        if e.code != expect:
            raise
        return e.code, body


def _get_json(url: str, expect: int = 200) -> dict:
    code, body = _get(url, expect)
    assert code == expect, f"{url}: {code} != {expect}"
    return json.loads(body)


def main() -> int:
    cfg = TopoServeConfig(dim=1, method="prunit", sublevel=False,
                          max_batch=16, pad_batch_to=16)
    server = TopoServe(cfg)
    # tight ceiling + fast burn windows so the detuned drain trips within
    # a couple of seconds of traffic
    engine = slo.SLOEngine(slo.default_serve_slos(
        latency_p99_s=0.05, latency_p50_s=0.05,
        rules=(slo.BurnRule(long_s=2.0, short_s=0.5, factor=1.0),)))
    slo.install(engine)
    srv = obs.start_http_server(port=0)
    loop = threading.Thread(target=server.serve_forever,
                            name="smoke-drain", daemon=True)
    loop.start()

    try:
        # ---- 1. readiness: serve_forever warmed the plans
        deadline = time.time() + 60
        ready = None
        while time.time() < deadline:
            try:
                ready = _get_json(srv.url + "/readyz")
                break
            except Exception:
                time.sleep(0.2)
        assert ready and ready["status"] == "ready", ready
        print(f"[obs_watch_smoke] ready: {ready['ready']}")

        # ---- 2. liveness + scrape surface
        health = _get_json(srv.url + "/healthz")
        assert health["status"] == "ok", health
        code, prom = _get(srv.url + "/metrics")
        prom = prom.decode()
        assert "# TYPE serve_heartbeat_ts gauge" in prom, prom[:400]
        assert "serve_ready" in prom
        for line in prom.splitlines():  # parseable: every sample is "name v"
            if line and not line.startswith("#"):
                name_part, _, value_part = line.rpartition(" ")
                assert name_part, line
                float(value_part)
        doc = _get_json(srv.url + "/slo")
        assert len(doc["status"]) >= 10, doc
        print(f"[obs_watch_smoke] /healthz ok, /metrics "
              f"{len(prom.splitlines())} lines, "
              f"/slo {len(doc['status'])} objectives")

        # ---- 3. detuned drain: stall every drain past the p99 ceiling
        inner = server.drain

        def slow_drain():
            time.sleep(0.2)
            return inner()

        server.drain = slow_drain
        t_end = time.time() + 20
        breached: list[str] = []
        while time.time() < t_end and not breached:
            futs = [server.submit(edges=[(0, 1), (1, 2)], n_vertices=3)
                    for _ in range(8)]
            for f in futs:
                f.result(timeout=30)
            doc = _get_json(srv.url + "/slo")
            breached = [k for k, v in doc["status"].items()
                        if v["status"] == "breach"]
            time.sleep(0.3)
        assert breached, "detuned drain never tripped an SLO within 20s"
        assert sum(doc["breaches"].values()) >= 1, doc["breaches"]
        print(f"[obs_watch_smoke] SLO breach observed: {breached}")

        # ---- 4. the breach auto-dumped the flight ring
        dump_path = flight.last_dump_path()
        assert dump_path, "breach left no flight dump"
        with open(dump_path) as fh:
            dump = json.load(fh)
        assert dump["schema"] == 1 and dump["events"], dump_path
        assert dump["reason"].startswith("slo_breach"), dump["reason"]
        assert dump["slo"]["breaches_total"] >= 1
        fl = _get_json(srv.url + "/debug/flight")
        assert fl["last_dump"] == dump_path
        print(f"[obs_watch_smoke] flight dump OK: {dump_path} "
              f"({len(dump['events'])} events)")
        print("[obs_watch_smoke] PASS")
        return 0
    finally:
        server.stop()
        loop.join(timeout=10)
        srv.stop()
        slo.install(None)


if __name__ == "__main__":
    sys.exit(main())
