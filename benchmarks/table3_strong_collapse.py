"""Paper Table 3: PrunIT (prune once on the graph) vs Strong Collapse
(collapse every flag complex in the filtration) — Email-Enron surrogate,
degree filtering, two threshold step sizes.

Metrics match the paper: wall time of the elimination stage and the simplex
count fed to the PH reduction afterwards.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, timed
from repro.core.prunit import prunit
from repro.core.persistence_ref import simplex_count
from repro.core.strong_collapse import strong_collapse_filtration_masks
from repro.data import graphs as gdata


def run(report: Report, n_pad: int = 512) -> None:
    key = jax.random.PRNGKey(29)
    g = gdata.load_large_network("Email-Enron", key, n_pad=n_pad)
    fmax = float(jnp.max(jnp.where(g.mask, g.f, -jnp.inf)))

    for delta in (4, 12):
        n_steps = max(2, int(np.ceil(fmax / delta)))
        thresholds = jnp.arange(1, n_steps + 1, dtype=jnp.float32) * delta

        # --- PrunIT: one pruning pass on the graph ---
        gp, t_prunit = timed(lambda: prunit(g, sublevel=False))

        # --- Strong Collapse: collapse each filtration complex ---
        (sub, col), t_sc = timed(
            lambda: strong_collapse_filtration_masks(
                g, thresholds, n_steps, sublevel=False))

        # simplex totals across the filtration (what PH reduction consumes):
        # PrunIT feeds the pruned graph's superlevel complexes; SC feeds each
        # collapsed complex.
        def total(adj0, step_masks):
            tot = 0
            for i in range(step_masks.shape[0]):
                m = np.asarray(step_masks[i, 0])
                a = np.asarray(adj0) & m[None, :] & m[:, None]
                tot += simplex_count(a, m, max_dim=2)
            return tot

        sub_p = jax.vmap(
            lambda alpha: gp.mask & (gp.f >= alpha))(thresholds)
        s_prunit = total(gp.adj[0], sub_p)
        s_sc = total(g.adj[0], col)

        report.add("table3", f"delta{delta}_prunit_time_s", t_prunit)
        report.add("table3", f"delta{delta}_strongcollapse_time_s", t_sc)
        report.add("table3", f"delta{delta}_prunit_simplices", s_prunit)
        report.add("table3", f"delta{delta}_strongcollapse_simplices", s_sc)
        report.add("table3", f"delta{delta}_n_filtration_steps", n_steps)


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
