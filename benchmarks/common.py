"""Shared benchmark utilities: timing, CSV rows, dataset sampling."""
from __future__ import annotations

import time

import jax
import numpy as np


def timed(fn, *args, repeats: int = 3, **kwargs):
    """(result, best_seconds) with a warmup call (excludes compile)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


class Report:
    """Collects (benchmark, metric, value) rows; prints CSV at the end."""

    def __init__(self):
        self.rows: list[tuple[str, str, float]] = []

    def add(self, bench: str, metric: str, value) -> None:
        self.rows.append((bench, metric, float(value)))
        print(f"  {bench},{metric},{value:.4g}", flush=True)

    def csv(self) -> str:
        lines = ["benchmark,metric,value"]
        lines += [f"{b},{m},{v:.6g}" for b, m, v in self.rows]
        return "\n".join(lines)


def pct(before, after) -> float:
    before = float(np.maximum(before, 1))
    return 100.0 * (float(before) - float(after)) / before
