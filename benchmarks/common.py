"""Shared benchmark utilities: timing, CSV rows, JSON suite reports."""
from __future__ import annotations

import json
import os
import platform
import time

import jax
import numpy as np


def timed(fn, *args, repeats: int = 3, **kwargs):
    """(result, best_seconds) with a warmup call (excludes compile)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


class Report:
    """Collects (benchmark, metric, value) rows; prints CSV at the end.

    ``quick`` mirrors the runner's --quick flag so suites that were not
    updated to take a ``quick=`` kwarg can still read ``report.quick``.
    """

    def __init__(self, quick: bool = False):
        self.rows: list[tuple[str, str, float]] = []
        self.quick = quick

    def add(self, bench: str, metric: str, value) -> None:
        self.rows.append((bench, metric, float(value)))
        print(f"  {bench},{metric},{value:.4g}", flush=True)

    def csv(self) -> str:
        lines = ["benchmark,metric,value"]
        lines += [f"{b},{m},{v:.6g}" for b, m, v in self.rows]
        return "\n".join(lines)


def pct(before, after) -> float:
    before = float(np.maximum(before, 1))
    return 100.0 * (float(before) - float(after)) / before


def write_suite_json(out_dir: str, suite: str, description: str,
                     rows: list[tuple[str, str, float]], wall_s: float,
                     quick: bool, ok: bool = True) -> str:
    """Persist one suite's results as ``BENCH_<suite>.json``.

    The machine-readable companion of results/bench.csv: rows plus wall time
    and environment metadata, so the perf trajectory is trackable across PRs
    (compare the same suite's JSON from consecutive commits).
    """
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    payload = {
        "suite": suite,
        "description": description,
        "quick": bool(quick),
        "ok": bool(ok),
        "wall_s": round(float(wall_s), 4),
        "rows": [{"benchmark": b, "metric": m, "value": v}
                 for (b, m, v) in rows],
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
        },
    }
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
