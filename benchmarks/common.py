"""Shared benchmark utilities: timing, CSV rows, JSON suite reports."""
from __future__ import annotations

import json
import os
import platform
import subprocess
import time

import jax
import numpy as np


def timed(fn, *args, repeats: int = 3, **kwargs):
    """(result, best_seconds) with a warmup call (excludes compile)."""
    out = fn(*args, **kwargs)
    jax.block_until_ready(out)
    best = float("inf")
    for _ in range(repeats):
        t0 = time.perf_counter()
        out = fn(*args, **kwargs)
        jax.block_until_ready(out)
        best = min(best, time.perf_counter() - t0)
    return out, best


class Report:
    """Collects (benchmark, metric, value) rows; prints CSV at the end.

    ``quick`` mirrors the runner's --quick flag so suites that were not
    updated to take a ``quick=`` kwarg can still read ``report.quick``.
    """

    def __init__(self, quick: bool = False):
        self.rows: list[tuple[str, str, float]] = []
        self.quick = quick

    def add(self, bench: str, metric: str, value) -> None:
        self.rows.append((bench, metric, float(value)))
        print(f"  {bench},{metric},{value:.4g}", flush=True)

    def csv(self) -> str:
        lines = ["benchmark,metric,value"]
        lines += [f"{b},{m},{v:.6g}" for b, m, v in self.rows]
        return "\n".join(lines)


def pct(before, after) -> float:
    before = float(np.maximum(before, 1))
    return 100.0 * (float(before) - float(after)) / before


def git_rev(cwd: str | None = None) -> str | None:
    """Short git revision of the working tree, or None outside a checkout.

    A ``-dirty`` suffix marks uncommitted changes — a bench run from a
    dirty tree measured code that HEAD does not contain, and the JSON must
    not attribute the numbers to that commit.  ``cwd`` overrides the repo
    the revision is read from (tests point it at a scratch checkout).
    """
    if cwd is None:
        cwd = os.path.dirname(os.path.abspath(__file__))
    try:
        rev = subprocess.run(
            ["git", "rev-parse", "--short", "HEAD"],
            cwd=cwd, capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        if not rev:
            return None
        # exclude bench outputs from the dirty check: the run itself
        # rewrites results/*.json, which must not mark the CODE as dirty
        dirty = subprocess.run(
            ["git", "status", "--porcelain", "--", ":(exclude,top)results"],
            cwd=cwd, capture_output=True, text=True, timeout=10,
        ).stdout.strip()
        return f"{rev}-dirty" if dirty else rev
    except Exception:
        return None


def telemetry_snapshot() -> dict:
    """Current TopoScope/plan-cache counter values a suite run will mutate.

    Paired with :func:`telemetry_delta`: snapshot before a suite, diff
    after, and the flat delta dict becomes the ``telemetry`` block of that
    suite's ``BENCH_<suite>.json`` plus one ``telemetry.<metric>`` row per
    counter — so PerfGate baselines capture call-count regressions (e.g. a
    silently doubled Gram invocation) alongside the timings.
    """
    from repro import obs
    from repro.core.api import plan_cache_info

    kernels = obs.counter("kernels.calls").labeled("kernel")
    metric_calls = obs.counter("metrics.calls").labeled("backend")
    return {"plan_cache": plan_cache_info(),
            "kernel_calls": kernels, "metric_calls": metric_calls,
            "slo_breaches": obs.counter("slo.breaches_total").total()}


def telemetry_delta(before: dict) -> dict:
    """Flat ``{metric: count}`` of registry movement since ``before``.

    Kernel/metric counters only appear once non-zero (a suite that never
    touches the auction kernel gets no ``kernel_calls_auction_lap`` row);
    the plan-cache triple is always present.  ``slo_breaches_total`` is
    ALSO always present — even as 0 — so every committed baseline carries
    a reference row for it and PerfGate (which gates it ``abs_upper``)
    fails any gate run during which an SLO fired.
    """
    after = telemetry_snapshot()
    out = {}
    for k in ("hits", "misses", "evictions"):
        out[f"plan_cache_{k}"] = (after["plan_cache"][k]
                                  - before["plan_cache"].get(k, 0))
    for group, prefix in (("kernel_calls", "kernel_calls"),
                          ("metric_calls", "metric_calls")):
        for name, v in after[group].items():
            d = v - before[group].get(name, 0.0)
            if d:
                out[f"{prefix}_{name}"] = int(d)
    out["slo_breaches_total"] = int(after["slo_breaches"]
                                    - before.get("slo_breaches", 0))
    return out


def _previous_run(path: str) -> dict | None:
    """Load the JSON a previous run left at ``path`` (None if absent/bad)."""
    try:
        with open(path) as f:
            return json.load(f)
    except Exception:
        return None


def write_suite_json(out_dir: str, suite: str, description: str,
                     rows: list[tuple[str, str, float]], wall_s: float,
                     quick: bool, ok: bool = True,
                     telemetry: dict | None = None) -> str:
    """Persist one suite's results as ``BENCH_<suite>.json``.

    The machine-readable companion of results/bench.csv: rows plus wall time
    and environment metadata.  Each run is stamped with its ``git_rev``, and
    — since runs overwrite the previous file in place — the previous run's
    identity and per-metric deltas are folded into ``previous``/``deltas``
    before overwriting, so the perf trajectory is reconstructible from the
    repo alone (every committed JSON names the revision it measured and how
    much each metric moved since the run before it).  ``telemetry`` (the
    :func:`telemetry_delta` of the run) is stored verbatim as a structured
    block.
    """
    path = os.path.join(out_dir, f"BENCH_{suite}.json")
    prev = _previous_run(path)
    payload = {
        "suite": suite,
        "description": description,
        "quick": bool(quick),
        "ok": bool(ok),
        "git_rev": git_rev(),
        "wall_s": round(float(wall_s), 4),
        "rows": [{"benchmark": b, "metric": m, "value": v}
                 for (b, m, v) in rows],
        "meta": {
            "jax": jax.__version__,
            "backend": jax.default_backend(),
            "device_count": jax.device_count(),
            "python": platform.python_version(),
        },
    }
    if telemetry is not None:
        payload["telemetry"] = telemetry
    if prev is not None:
        payload["previous"] = {
            "git_rev": prev.get("git_rev"),
            "quick": prev.get("quick"),
            "ok": prev.get("ok"),
            "wall_s": prev.get("wall_s"),
        }
        prev_vals = {(r.get("benchmark"), r.get("metric")): r.get("value")
                     for r in prev.get("rows", [])}
        deltas = []
        for (b, m, v) in rows:
            pv = prev_vals.get((b, m))
            if pv is not None:
                deltas.append({"benchmark": b, "metric": m,
                               "value": v, "prev": pv,
                               "delta": round(float(v) - float(pv), 6)})
        payload["deltas"] = deltas
    os.makedirs(out_dir, exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    return path
