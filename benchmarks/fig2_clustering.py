"""Paper Fig 2 / Fig 10 + §D.2 conjecture: clustering coefficient vs the
number of higher (k>=1) topological features, plus the persistence-kernel
clustering repro.

Three probes:
  1. a controlled ER density sweep — the conjecture predicts nontrivial
     PD_1 only in a middle band of clustering coefficient (too sparse: no
     cycles; too dense: every cycle filled by a 2-simplex);
  2. a TWITTER-regime surrogate sample (the paper's Fig 2 datasets);
  3. **persistence-kernel clustering** — the paper's Fig 2 clustering-
     quality claim: diagrams of three structural graph families are
     embedded (``sw_embedding``), the Carrière-style SW kernel matrix
     ``exp(−γ·D)`` comes from the Pallas pairwise-L1 Gram
     (``TopoIndex.gram``), and two dependency-free kernel methods —
     kernel k-means and a kernel nearest-centroid classifier (the
     in-container stand-in for the paper's kernel SVM) — must recover the
     family structure (purity / held-out accuracy reported and asserted).

Clustering coefficients come from the Pallas common-neighbors kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.core.api import topological_signature
from repro.index import TopoIndex, TopoIndexConfig
from repro.kernels.ops import clustering_coefficients
from repro.data import graphs as gdata

FAMILIES = (
    # sparse rewired rings (PD1-rich) vs dense clique-ish vs tree-like
    ("ws", lambda k, b: gdata.watts_strogatz(k, b, 24, 20, 4, 0.1)),
    ("er_dense", lambda k, b: gdata.erdos_renyi(k, b, 24, 20, 0.45)),
    ("ba_tree", lambda k, b: gdata.barabasi_albert(k, b, 24, 20, 1)),
)


def _family_diagrams(key, per_family: int):
    """Diagrams + labels for ``per_family`` graphs of each family."""
    batches, labels = [], []
    for fam, (name, gen) in enumerate(FAMILIES):
        key, sub = jax.random.split(key)
        g = gdata.with_degree_filtration(gen(sub, per_family))
        batches.append(topological_signature(g, dim=1, method="both",
                                             edge_cap=160, tri_cap=384))
        labels += [fam] * per_family
    d = jax.tree.map(lambda *xs: jnp.concatenate(xs, axis=0), *batches)
    return d, np.asarray(labels)


def kernel_kmeans(kmat: np.ndarray, n_clusters: int, seed: int = 0,
                  n_iters: int = 30) -> np.ndarray:
    """Kernel k-means on a precomputed PSD kernel matrix (pure numpy).

    Feature-space distance to a cluster mean expands to
    ``K_xx − 2·mean_{y∈c} K_xy + mean_{y,y'∈c} K_yy'``; assignments are
    iterated from a seeded random init until fixpoint (empty clusters are
    reseeded with the farthest point).
    """
    n = kmat.shape[0]
    rng = np.random.default_rng(seed)
    assign = rng.integers(0, n_clusters, n)
    diag = np.diag(kmat)
    for _ in range(n_iters):
        dist = np.empty((n, n_clusters))
        for c in range(n_clusters):
            in_c = assign == c
            if not in_c.any():  # reseed an empty cluster
                far = int(np.argmax(dist[:, :c].min(axis=1))) if c else 0
                in_c = np.zeros(n, bool)
                in_c[far] = True
            kc = kmat[:, in_c]
            dist[:, c] = (diag - 2.0 * kc.mean(axis=1)
                          + kmat[np.ix_(in_c, in_c)].mean())
        new = dist.argmin(axis=1)
        if (new == assign).all():
            break
        assign = new
    return assign


def cluster_purity(assign: np.ndarray, labels: np.ndarray) -> float:
    """Majority-label purity of a clustering vs ground-truth families."""
    correct = 0
    for c in np.unique(assign):
        members = labels[assign == c]
        correct += np.bincount(members).max()
    return correct / len(labels)


def kernel_ncc_accuracy(kmat: np.ndarray, labels: np.ndarray,
                        train: np.ndarray) -> float:
    """Held-out accuracy of a kernel nearest-centroid classifier.

    Classifies each test point to the training class with the nearest
    feature-space mean under the same kernel expansion kernel k-means uses
    — the dependency-free stand-in for the paper's kernel SVM.
    """
    test = ~train
    classes = np.unique(labels[train])
    diag = np.diag(kmat)[test]
    dist = np.empty((test.sum(), len(classes)))
    for ci, c in enumerate(classes):
        in_c = train & (labels == c)
        dist[:, ci] = (diag - 2.0 * kmat[np.ix_(test, in_c)].mean(axis=1)
                       + kmat[np.ix_(in_c, in_c)].mean())
    pred = classes[dist.argmin(axis=1)]
    return float((pred == labels[test]).mean())


def _bench_persistence_kernel(report: Report, quick: bool) -> None:
    per_family = 8 if quick else 24
    d, labels = _family_diagrams(jax.random.PRNGKey(5), per_family)
    # "both": SW block + feature block — tree-like and dense families both
    # have near-empty PD_1, so PD_0 statistics must contribute to separate
    # them (same configuration the similarity example serves)
    index = TopoIndex(TopoIndexConfig(embedding="both", k=1, n_points=12,
                                      n_dirs=12, res=6))
    index.add(d)
    dist = index.gram()                    # Pallas pairwise-L1 Gram
    gamma = 1.0 / max(np.median(dist[dist > 0]), 1e-9)
    kmat = np.exp(-gamma * dist)           # Carrière-style SW kernel

    assign = kernel_kmeans(kmat, n_clusters=len(FAMILIES), seed=3)
    purity = cluster_purity(assign, labels)
    # deterministic interleaved split: 2 of every 3 per family train
    train = (np.arange(len(labels)) % 3) != 2
    acc = kernel_ncc_accuracy(kmat, labels, train)
    report.add("fig2_kernel", "graphs", len(labels))
    report.add("fig2_kernel", "kmeans_purity", purity)
    report.add("fig2_kernel", "ncc_holdout_accuracy", acc)
    if purity < 0.66 or acc < 0.66:
        raise AssertionError(
            f"persistence-kernel clustering degraded: purity={purity:.2f}, "
            f"ncc accuracy={acc:.2f} (want >= 0.66)")


def _mean_cc(g) -> jax.Array:
    cc = clustering_coefficients(g.adj, g.mask)
    return jnp.sum(cc, -1) / jnp.maximum(jnp.sum(g.mask, -1), 1)


def run(report: Report, quick: bool = False) -> None:
    key = jax.random.PRNGKey(31)
    # --- probe 1: ER density sweep (N=40, B=8 per density) ---
    densities = (0.05, 0.12, 0.25, 0.45, 0.7, 0.9)
    band = {}
    for p in densities:
        # N=14 keeps the full clique complex inside the caps at every
        # density, so feature counts are exact (no truncation artifacts)
        g = gdata.erdos_renyi(jax.random.fold_in(key, int(p * 100)),
                              8, 14, 14, p)
        g = gdata.with_degree_filtration(g)
        d = topological_signature(g, dim=1, method="both",
                                  edge_cap=128, tri_cap=512)
        cc = float(jnp.mean(_mean_cc(g)))
        n1 = float(jnp.mean(d.count(1)))
        band[p] = (cc, n1)
        report.add("fig2_cc", f"er_p{p}_mean_clustering", cc)
        report.add("fig2_cc", f"er_p{p}_mean_pd1_features", n1)
    # conjecture: middle densities carry more PD1 features than the extremes
    mids = [band[p][1] for p in (0.12, 0.25, 0.45)]
    exts = [band[p][1] for p in (0.05, 0.9)]
    report.add("fig2_cc", "mid_band_mean_pd1", float(np.mean(mids)))
    report.add("fig2_cc", "extreme_band_mean_pd1", float(np.mean(exts)))

    # --- probe 2: TWITTER surrogate ---
    g = gdata.load_dataset("TWITTER", key, batch=8)
    d = topological_signature(g, dim=1, method="both",
                              edge_cap=192, tri_cap=192)
    report.add("fig2_cc", "TWITTER_mean_clustering", float(jnp.mean(_mean_cc(g))))
    report.add("fig2_cc", "TWITTER_mean_pd1_features", float(jnp.mean(d.count(1))))

    # --- probe 3: persistence-kernel kmeans / nearest-centroid (Fig 2) ---
    _bench_persistence_kernel(report, quick)


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
