"""Paper Fig 2 / Fig 10 + §D.2 conjecture: clustering coefficient vs the
number of higher (k>=1) topological features.

Two probes:
  1. a controlled ER density sweep — the conjecture predicts nontrivial
     PD_1 only in a middle band of clustering coefficient (too sparse: no
     cycles; too dense: every cycle filled by a 2-simplex);
  2. a TWITTER-regime surrogate sample (the paper's Fig 2 datasets).

Clustering coefficients come from the Pallas common-neighbors kernel.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.core.api import topological_signature
from repro.kernels.ops import clustering_coefficients
from repro.data import graphs as gdata


def _mean_cc(g) -> jax.Array:
    cc = clustering_coefficients(g.adj, g.mask)
    return jnp.sum(cc, -1) / jnp.maximum(jnp.sum(g.mask, -1), 1)


def run(report: Report) -> None:
    key = jax.random.PRNGKey(31)
    # --- probe 1: ER density sweep (N=40, B=8 per density) ---
    densities = (0.05, 0.12, 0.25, 0.45, 0.7, 0.9)
    band = {}
    for p in densities:
        # N=14 keeps the full clique complex inside the caps at every
        # density, so feature counts are exact (no truncation artifacts)
        g = gdata.erdos_renyi(jax.random.fold_in(key, int(p * 100)),
                              8, 14, 14, p)
        g = gdata.with_degree_filtration(g)
        d = topological_signature(g, dim=1, method="both",
                                  edge_cap=128, tri_cap=512)
        cc = float(jnp.mean(_mean_cc(g)))
        n1 = float(jnp.mean(d.count(1)))
        band[p] = (cc, n1)
        report.add("fig2_cc", f"er_p{p}_mean_clustering", cc)
        report.add("fig2_cc", f"er_p{p}_mean_pd1_features", n1)
    # conjecture: middle densities carry more PD1 features than the extremes
    mids = [band[p][1] for p in (0.12, 0.25, 0.45)]
    exts = [band[p][1] for p in (0.05, 0.9)]
    report.add("fig2_cc", "mid_band_mean_pd1", float(np.mean(mids)))
    report.add("fig2_cc", "extreme_band_mean_pd1", float(np.mean(exts)))

    # --- probe 2: TWITTER surrogate ---
    g = gdata.load_dataset("TWITTER", key, batch=8)
    d = topological_signature(g, dim=1, method="both",
                              edge_cap=192, tri_cap=192)
    report.add("fig2_cc", "TWITTER_mean_clustering", float(jnp.mean(_mean_cc(g))))
    report.add("fig2_cc", "TWITTER_mean_pd1_features", float(jnp.mean(d.count(1))))


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
