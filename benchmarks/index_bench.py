"""ShardedIndex benchmarks: scaling, kernel speedup, retrieval quality.

Panels
------
* **corpus ladder** — add + query wall times through the ShardedIndex
  surface across corpus sizes (whatever mesh the host offers; CI runs the
  quick ladder on a simulated 4-device mesh via
  ``XLA_FLAGS=--xla_force_host_platform_device_count=4``).
* **device-count scaling** — coarse-scan throughput under the
  *critical-path* model: each shard scans ``ceil(N/P)`` rows
  independently, so the distributed scan's span is one shard's scan and
  ``scan_throughput = Q·N / t_shard``.  Timing the per-shard workload
  directly (instead of the whole mesh wall clock) keeps the number
  meaningful on CI hosts where P simulated devices share one core and
  wall clock would *grow* with P.  The ≥3x scaling acceptance
  (4 shards vs 1) is asserted when a ≥4-device mesh is actually up,
  logged + skipped otherwise.  The host-side merge of per-shard top-m
  survivors — the only serial stage — is timed as ``merge_seconds``.
* **Hamming kernel** — Pallas XOR+popcount scan vs the host numpy
  popcount-table scan on identical packed codes (parity asserted, ratio
  recorded; interpret-mode Pallas on CPU is expected to lose, the row
  tracks the TPU win condition).
* **retrieval quality** — sharded two-stage retrieval (sharded Hamming
  coarse -> Gram -> serve ``exact_w`` re-rank through the shard-owner
  cloud gather) vs the exhaustive exact re-rank ground truth:
  ``recall_at_10 >= 0.98`` asserted, plus sharded-vs-single-host distance
  parity within 1e-5.

  PYTHONPATH=src python -m benchmarks.index_bench [--quick]
  PYTHONPATH=src python -m benchmarks.run --only index [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import Report, timed, write_suite_json
from repro.index import ShardedIndex, TopoIndex, TopoIndexConfig
from repro.index.topo_index import _POPCOUNT
from repro.kernels import ops
from repro.launch.mesh import make_index_mesh
from repro.metrics import pairwise
from repro.metrics.testing import noisy_copies, seed_diagram_arrays
from repro.serve import SimilarityServe

_CFG = dict(embedding="sw", n_points=8, n_dirs=8, coarse="lsh",
            lsh_bits=128, lsh_overfetch=8)


def _make_corpus(n: int, rng, n_seeds: int = 32):
    seeds = seed_diagram_arrays(rng, n_seeds=n_seeds, s=16)
    return seeds, noisy_copies(seeds, rng, n, 0.02, 0.4)


def _bench_corpus_ladder(report: Report, quick: bool) -> None:
    """Add + query wall time through the ShardedIndex surface."""
    rng = np.random.default_rng(40)
    sizes = (256, 512) if quick else (512, 2048, 8192)
    q_n, k = 8, 10
    for n in sizes:
        seeds, corpus = _make_corpus(n, rng)
        queries = noisy_copies(seeds, rng, q_n, 0.01, 0.02)
        index = ShardedIndex(TopoIndexConfig(**_CFG))
        t0 = time.perf_counter()
        for s0 in range(0, n, 1024):
            index.add(jax.tree.map(lambda x: x[s0:s0 + 1024], corpus))
        report.add("index_ladder", f"N{n}_add_s", time.perf_counter() - t0)
        res, t_q = timed(index.query, queries, k=k, repeats=2)
        assert res.stats["shards"] == index.n_shards
        report.add("index_ladder", f"N{n}_query_s", t_q)
        report.add("index_ladder", f"N{n}_queries_per_s",
                   q_n / max(t_q, 1e-9))


def _host_scan(codes_q: np.ndarray, codes_db: np.ndarray) -> np.ndarray:
    """The host popcount-table scan ShardedIndex replaces (oracle+timing)."""
    return _POPCOUNT[codes_q[:, None, :] ^ codes_db[None]].sum(
        axis=-1, dtype=np.int32)


def _bench_hamming_kernel(report: Report, quick: bool) -> None:
    """Pallas XOR+popcount scan vs the host numpy scan, identical codes."""
    rng = np.random.default_rng(41)
    q_n = 16
    sizes = (4096,) if quick else (4096, 32768)
    for n in sizes:
        codes_db = rng.integers(0, 256, (n, 16), dtype=np.uint8)
        codes_q = rng.integers(0, 256, (q_n, 16), dtype=np.uint8)
        want, t_host = timed(_host_scan, codes_q, codes_db)
        got, t_pal = timed(ops.hamming_scan, codes_q, codes_db)
        np.testing.assert_array_equal(np.asarray(got), want)
        report.add("index_hamming", f"Q{q_n}_N{n}_host_s", t_host)
        report.add("index_hamming", f"Q{q_n}_N{n}_pallas_s", t_pal)
        report.add("index_hamming", f"Q{q_n}_N{n}_kernel_speedup",
                   t_host / max(t_pal, 1e-9))


def _bench_scaling(report: Report, quick: bool) -> None:
    """Coarse-scan scaling under the critical-path model + merge cost.

    ``P{p}_scan_throughput`` = scanned (query, row) Hamming counts per
    second with the corpus split over ``p`` shards, where the distributed
    scan's span is the slowest single shard — timed as one shard's
    ``ceil(N/p)``-row workload.  ``P{p}_merge_seconds`` times the host
    merge of the ``p`` per-shard top-m survivor sets (composite
    (dist, row) key, same code shape as ``ShardedIndex._coarse_candidates``).
    """
    rng = np.random.default_rng(42)
    # floor of 16384: below that the interpret-mode per-call overhead is a
    # large fraction of a quarter-shard scan and the scaling ratio reads low
    n = 16384 if quick else 32768
    q_n, m = 16, 80
    codes_db = rng.integers(0, 256, (n, 16), dtype=np.uint8)
    codes_q = rng.integers(0, 256, (q_n, 16), dtype=np.uint8)
    throughput = {}
    for p in (1, 2, 4):
        per = -(-n // p)
        _, t_shard = timed(ops.hamming_scan, codes_q, codes_db[:per])
        throughput[p] = q_n * n / max(t_shard, 1e-9)
        report.add("index_sharded", f"P{p}_shard_scan_s", t_shard)
        report.add("index_sharded", f"P{p}_scan_throughput", throughput[p])

        # host merge of per-shard survivors: (p, Q, m) dists+rows -> (Q, m)
        m_loc = min(m, per)
        dd = rng.integers(0, 128, (p, q_n, m_loc)).astype(np.int32)
        rr = rng.integers(0, n, (p, q_n, m_loc)).astype(np.int64)

        def merge(dd, rr):
            d2 = dd.transpose(1, 0, 2).reshape(q_n, -1)
            r2 = rr.transpose(1, 0, 2).reshape(q_n, -1)
            key = d2.astype(np.int64) * n + r2
            key = np.take_along_axis(
                key, np.argpartition(key, m - 1, axis=-1)[:, :m], -1)
            key.sort(axis=-1)
            return key % n

        _, t_merge = timed(merge, dd, rr)
        report.add("index_sharded", f"P{p}_merge_seconds", t_merge)

    speedup = throughput[4] / max(throughput[1], 1e-9)
    report.add("index_sharded", "P4_vs_P1_scan_speedup", speedup)
    if jax.device_count() >= 4:
        assert speedup >= 3.0, (
            f"4-shard critical-path scan speedup {speedup:.2f}x < 3x")
        print(f"[index_bench] 4-shard scan speedup {speedup:.2f}x (>= 3x)")
    else:
        print(f"[index_bench] {jax.device_count()} device(s): logged "
              f"4-shard speedup {speedup:.2f}x, >=3x assertion skipped "
              "(needs a >=4-device mesh)")


def _bench_sharded_recall(report: Report, quick: bool) -> float:
    """Sharded two-stage retrieval vs exhaustive exact re-rank.

    The sharded index runs the full production path — on-device coarse
    Hamming scan, host merge, candidate Gram, serve ``exact_w`` re-rank
    gathering clouds through the shard owners — and must reach
    recall@10 >= 0.98 against the exhaustive exact ground truth.  The
    single-host index answers the same queries for the distance-parity
    check (within 1e-5).
    """
    corpus_n = 2048 if quick else 6144
    q_n = 8 if quick else 16
    k = 10
    rng = np.random.default_rng(43)
    seeds, corpus = _make_corpus(corpus_n, rng)
    queries = noisy_copies(seeds, rng, q_n, 0.01, 0.02)

    cfg = TopoIndexConfig(**_CFG)
    base = TopoIndex(cfg)
    for s0 in range(0, corpus_n, 1024):
        base.add(jax.tree.map(lambda x: x[s0:s0 + 1024], corpus))
    sharded = ShardedIndex.from_index(base)
    report.add("index_recall", "corpus", corpus_n)
    report.add("index_recall", "shards", sharded.n_shards)

    # sharded vs single-host parity on the embedding metric
    want = base.query(queries, k=k)
    got = sharded.query(queries, k=k)
    parity = float(np.max(np.abs(got.distances - want.distances)))
    assert got.ids == want.ids, "sharded vs single-host id mismatch"
    assert parity <= 1e-5, f"sharded distance parity {parity:.2e} > 1e-5"
    report.add("index_recall", "single_host_dist_maxdiff", parity)

    # full two-stage path with the serve-level exact re-rank
    srv = SimilarityServe(index=sharded, rerank="exact_w", overfetch=4)
    t0 = time.perf_counter()
    res = sharded.query(queries, k=k * srv.overfetch)
    ids2, _, backends2 = srv._rerank_exact(queries, res)
    t_two_stage = time.perf_counter() - t0
    assert all(b == "exact_w" for row in backends2 for b in row)

    all_clouds = sharded.clouds(np.arange(len(sharded)))
    t0 = time.perf_counter()
    hits = 0
    for i in range(q_n):
        qi = jax.tree.map(lambda x: x[i][None], queries)
        d = np.asarray(pairwise(all_clouds, qi, metric="exact_w",
                                k=cfg.k, cap=cfg.cap, n_points=cfg.n_points,
                                block_rows=2048))[:, 0]
        gt = {sharded.ids[j] for j in np.argsort(d, kind="stable")[:k]}
        hits += len(gt & set(ids2[i][:k]))
    t_exhaustive = time.perf_counter() - t0
    recall = hits / (k * q_n)
    report.add("index_recall", "recall_at_10", recall)
    report.add("index_recall", "two_stage_s", t_two_stage)
    report.add("index_recall", "exhaustive_s", t_exhaustive)
    report.add("index_recall", "speedup_vs_exhaustive",
               t_exhaustive / max(t_two_stage, 1e-9))
    return recall


def run(report: Report, quick: bool = False) -> None:
    report.add("index_env", "device_count", jax.device_count())
    mesh = make_index_mesh()
    report.add("index_env", "mesh_rows", mesh.shape["row"])
    report.add("index_env", "mesh_cols", mesh.shape["col"])
    _bench_corpus_ladder(report, quick)
    _bench_hamming_kernel(report, quick)
    _bench_scaling(report, quick)            # asserts >=3x when mesh >= 4
    recall = _bench_sharded_recall(report, quick)
    if recall < 0.98:
        raise AssertionError(
            f"sharded retrieval recall@10 {recall:.3f} < 0.98 vs "
            "exhaustive exact re-rank")
    print(f"[index_bench] sharded recall@10: {recall:.3f} (>= 0.98) on "
          f"{jax.device_count()} device(s)")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI / CPU smoke)")
    ap.add_argument("--out-dir", default="results",
                    help="directory for BENCH_index.json")
    args = ap.parse_args()
    report = Report(quick=args.quick)
    t0 = time.time()
    ok = True
    try:
        run(report, quick=args.quick)
    except Exception:
        ok = False
        raise
    finally:
        path = write_suite_json(
            args.out_dir, "index",
            "ShardedIndex scaling + Hamming kernel + retrieval recall",
            report.rows, wall_s=time.time() - t0, quick=args.quick, ok=ok)
        print(f"wrote {path}")
    print(report.csv())


if __name__ == "__main__":
    main()
