"""Paper Fig 6: combined PrunIT + CoralTDA vertex reduction on the large
networks, for cores k = 2..5 (PD_{k-1})."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report
from repro.core.api import reduction_stats
from repro.data import graphs as gdata


def run(report: Report, n_pad: int = 1024, cores=(2, 3, 4, 5)) -> None:
    key = jax.random.PRNGKey(13)
    for name in gdata.TABLE1:
        g = gdata.load_large_network(name, jax.random.fold_in(key, 2), n_pad=n_pad)
        for k in cores:
            st = reduction_stats(g, dim=k - 1, method="both", sublevel=False)
            report.add("fig6_combined", f"{name}_core{k}_V_reduction_pct",
                       float(jnp.mean(st.v_reduction_pct())))


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
