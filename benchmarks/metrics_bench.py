"""TopoMetric benchmark: distance throughput, Gram kernel, parity, drift.

Four panels (docs/ARCHITECTURE.md §TopoMetric):

* **pairs/s** — batched sliced-Wasserstein and Sinkhorn-W2 throughput on
  diagram pairs produced by the real reduce->persist pipeline;
* **Gram** — Pallas pairwise-L1 kernel vs the jnp reference on SW
  embeddings (speedup + max abs diff);
* **parity** — the acceptance sweep: random small diagram pairs checked
  against the host references (SW within rtol 1e-5 of ``sw_dense``;
  Sinkhorn within 5% of exact W2) — failures are counted and raised;
* **drift** — the change-detection demo: a ``community_churn_stream`` whose
  churn schedule is quiet except for injected rewiring bursts, replayed
  through a drift-scoring ``TopoStream``; the bench asserts every burst is
  flagged and no quiet step is (zero false positives).

  PYTHONPATH=src python -m benchmarks.metrics_bench [--quick]
  PYTHONPATH=src python -m benchmarks.run --only metrics [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Report, timed, write_suite_json
from repro.core.api import topological_signature
from repro.core.delta import delta_step
from repro.core.persistence_jax import Diagrams
from repro.data import graphs as gdata
from repro.data.temporal import community_churn_stream
from repro.metrics import reference as mref
from repro.metrics import sinkhorn_w2, sliced_wasserstein, sw_embedding
from repro.metrics.testing import diagram_points, random_diagram
from repro.stream import TopoStream, TopoStreamConfig

CAP = 64.0


def _pipeline_diagrams(key, batch: int, n: int) -> Diagrams:
    g = gdata.erdos_renyi(key, batch, n, n, 0.18)
    g = gdata.with_degree_filtration(g)
    return topological_signature(g, dim=1, method="both",
                                 edge_cap=128, tri_cap=256)


def _bench_throughput(report: Report, quick: bool) -> None:
    batch = 64 if quick else 256
    key = jax.random.PRNGKey(31)
    d = _pipeline_diagrams(key, 2 * batch, 24)
    d1 = jax.tree.map(lambda x: x[0::2], d)
    d2 = jax.tree.map(lambda x: x[1::2], d)

    _, t_sw = timed(lambda a, b: sliced_wasserstein(a, b, k=1, cap=CAP), d1, d2)
    report.add("metrics_sw", f"B{batch}_pairs_per_s", batch / max(t_sw, 1e-9))
    _, t_sk = timed(lambda a, b: sinkhorn_w2(a, b, k=1, cap=CAP), d1, d2)
    report.add("metrics_sinkhorn", f"B{batch}_pairs_per_s",
               batch / max(t_sk, 1e-9))
    _, t_emb = timed(lambda a: sw_embedding(a, k=1, cap=CAP), d)
    report.add("metrics_sw_embedding", f"B{2*batch}_diagrams_per_s",
               2 * batch / max(t_emb, 1e-9))


def _bench_gram(report: Report, quick: bool) -> None:
    from benchmarks.kernel_bench import bench_pairwise_gram

    sizes = ((64, 256),) if quick else ((64, 256), (256, 512))
    worst = bench_pairwise_gram(report, "metrics_gram", sizes)
    if not worst < 1e-3:
        raise AssertionError(
            f"Pallas Gram diverges from jnp reference by {worst}")


def _bench_parity(report: Report, quick: bool) -> tuple[int, int]:
    """Random-pair sweep vs the host references; returns (checked, failed)."""
    n_pairs = 60 if quick else 200
    rng = np.random.default_rng(33)

    pairs = [(random_diagram(rng, essential=int(rng.integers(0, 3))),
              random_diagram(rng))
             for _ in range(n_pairs)]
    d1 = jax.tree.map(lambda *xs: jnp.stack(xs), *[a for a, _ in pairs])
    d2 = jax.tree.map(lambda *xs: jnp.stack(xs), *[b for _, b in pairs])
    sw = np.asarray(sliced_wasserstein(d1, d2, k=1, n_dirs=32, cap=CAP))
    sk = np.asarray(sinkhorn_w2(d1, d2, k=1, cap=CAP))

    checked = failed = 0
    for i, (a, b) in enumerate(pairs):
        pa, pb = diagram_points(a, k=1, cap=CAP), diagram_points(b, k=1, cap=CAP)
        sw_ref = mref.sw_dense(pa, pb, n_dirs=32)
        w2_ref = mref.wasserstein_exact(pa, pb, q=2.0)
        checked += 2
        tol = max(1e-5 * max(sw_ref, 1.0), 1e-5)
        if abs(sw[i] - sw_ref) > tol:
            failed += 1
        if (abs(sk[i]) > 1e-4 if w2_ref == 0
                else abs(sk[i] - w2_ref) / w2_ref > 0.05):
            failed += 1
    report.add("metrics_parity", "checked", checked)
    report.add("metrics_parity", "failed", failed)
    return checked, failed


def _bench_drift(report: Report, quick: bool) -> tuple[int, int, int]:
    """Burst detection on community churn; returns (bursts, flagged, false_pos).

    Quiet segments carry no structural updates (the monitoring regime: the
    stream is sampled faster than the network changes), so a false positive
    would mean the drift scorer invented a diagram change; bursts rewire
    ``churn`` edges at once and must all be flagged.
    """
    steps = 16 if quick else 32
    churn = 8
    burst_at = set(range(4, steps, 7))
    sched = np.zeros(steps, np.int32)
    for t in burst_at:
        sched[t] = churn
    g0, deltas = community_churn_stream(
        jax.random.PRNGKey(34), batch=4, n_pad=24, n_vertices=20, n_comm=4,
        p_in=0.45, p_out=0.05, steps=steps, churn=churn, churn_schedule=sched)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=160, tri_cap=384,
                           drift_metric="sw", drift_threshold=1.0)
    stream = TopoStream(g0, cfg)
    t0 = time.perf_counter()
    flagged = []
    for t in range(steps):
        stream.apply(delta_step(deltas, t))
        if stream.last_anomaly.any():
            flagged.append(t)
    wall = time.perf_counter() - t0
    hits = len(set(flagged) & burst_at)
    false_pos = len(set(flagged) - burst_at)
    report.add("metrics_drift", "steps", steps)
    report.add("metrics_drift", "steps_per_s", steps / max(wall, 1e-9))
    report.add("metrics_drift", "bursts", len(burst_at))
    report.add("metrics_drift", "bursts_flagged", hits)
    report.add("metrics_drift", "false_positives", false_pos)
    report.add("metrics_drift", "skip_rate", stream.skip_rate())
    return len(burst_at), hits, false_pos


def run(report: Report, quick: bool = False) -> None:
    _bench_throughput(report, quick)
    _bench_gram(report, quick)
    checked, failed = _bench_parity(report, quick)
    bursts, hits, false_pos = _bench_drift(report, quick)
    if failed:
        raise AssertionError(
            f"{failed}/{checked} distance checks diverged from the host "
            "references")
    if hits != bursts or false_pos:
        raise AssertionError(
            f"drift demo: {hits}/{bursts} bursts flagged, "
            f"{false_pos} false positives")
    print(f"[metrics_bench] parity OK: {checked} checks; drift OK: "
          f"{hits}/{bursts} bursts flagged, 0 false positives")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI / CPU smoke)")
    ap.add_argument("--out-dir", default="results",
                    help="directory for BENCH_metrics.json")
    args = ap.parse_args()
    report = Report(quick=args.quick)
    t0 = time.time()
    ok = True
    try:
        run(report, quick=args.quick)
    except Exception:
        ok = False
        raise
    finally:
        path = write_suite_json(
            args.out_dir, "metrics",
            "diagram distances + Gram kernel + parity + drift",
            report.rows, wall_s=time.time() - t0, quick=args.quick, ok=ok)
        print(f"wrote {path}")
    print(report.csv())


if __name__ == "__main__":
    main()
