"""MetricEngine benchmark: throughput, kernels, parity, drift, retrieval.

Seven panels (docs/ARCHITECTURE.md §MetricEngine):

* **pairs/s** — batched sliced-Wasserstein and Sinkhorn-W2 throughput on
  diagram pairs produced by the real reduce->persist pipeline;
* **Gram** — Pallas pairwise-L1 kernel vs the jnp reference on SW
  embeddings (speedup + max abs diff);
* **parity** — the acceptance sweep: random small diagram pairs checked
  against the host references (SW within rtol 1e-5 of ``sw_dense``;
  Sinkhorn within 5% of exact W2) — failures are counted and raised;
* **auction parity** — the exact-Wasserstein acceptance sweep: the batched
  auction-LAP ``exact_w`` backend (collapsed forward/reverse formulation)
  vs the Hungarian/scipy oracle within atol 1e-5 on randomized masked
  pairs (0 mismatches required), the collapsed-vs-expanded rounds
  reduction (≥ 5× asserted), plus the bisection ``bottleneck_approx`` vs
  ``bottleneck_exact``;
* **stage1 exact** — ``stage1_backend="exact_w"`` serving: exhaustive
  exact stage-1 (recall 1.0 by construction, asserted against an
  independent ``pairwise`` ground truth) with price-cache warm starts
  across drains, vs the LSH+Gram+re-rank funnel on the same corpus;
* **blocked Sinkhorn** — ``impl="blocked"`` vs ``impl="dense"`` agreement
  at tile-fitting sizes (f32-roundoff consistency), and the memory-ceiling
  demo: blocked runs full-tensor clouds whose dense cost matrices dwarf
  the previous ``n_points²`` working set;
* **rerank recall** — two-stage retrieval (LSH coarse → Gram → exact_w
  re-rank, the SimilarityServe stage-2 code path) vs an exhaustive exact
  re-rank over a ≥10k-diagram synthetic corpus: recall@10 ≥ 0.95 required,
  with per-stage candidate counts and wall times;
* **recall vs probes** — the multi-probe LSH trade-off at a deliberately
  tight overfetch (2): coarse recall@10 against the exhaustive
  embedding-metric ground truth as the ``probes`` budget sweeps 1/4/16 on
  *one* index (the per-query ``probes=`` override — same stored codes,
  wider masked scan);
* **drift** — the change-detection demo: a ``community_churn_stream`` whose
  churn schedule is quiet except for injected rewiring bursts, replayed
  through a drift-scoring ``TopoStream``; the bench asserts every burst is
  flagged and no quiet step is (zero false positives).

  PYTHONPATH=src python -m benchmarks.metrics_bench [--quick]
  PYTHONPATH=src python -m benchmarks.run --only metrics [--quick]
"""
from __future__ import annotations

import argparse
import time

import numpy as np

import jax
import jax.numpy as jnp

from benchmarks.common import Report, timed, write_suite_json
from repro.core.api import topological_signature
from repro.core.delta import delta_step
from repro.core.persistence_jax import Diagrams
from repro.data import graphs as gdata
from repro.data.temporal import community_churn_stream
from repro.index import TopoIndex, TopoIndexConfig
from repro.kernels import ops
from repro.metrics import (
    bottleneck_approx,
    compare,
    exact_w_info,
    pairwise,
    sinkhorn_w2,
    sw_embedding,
)
from repro.metrics import reference as mref
from repro.metrics.testing import (
    diagram_points,
    noisy_copies,
    random_diagram,
    seed_diagram_arrays,
)
from repro.serve import SimilarityServe
from repro.stream import TopoStream, TopoStreamConfig

CAP = 64.0


def _pipeline_diagrams(key, batch: int, n: int) -> Diagrams:
    g = gdata.erdos_renyi(key, batch, n, n, 0.18)
    g = gdata.with_degree_filtration(g)
    return topological_signature(g, dim=1, method="both",
                                 edge_cap=128, tri_cap=256)


def _bench_throughput(report: Report, quick: bool) -> None:
    batch = 64 if quick else 256
    key = jax.random.PRNGKey(31)
    d = _pipeline_diagrams(key, 2 * batch, 24)
    d1 = jax.tree.map(lambda x: x[0::2], d)
    d2 = jax.tree.map(lambda x: x[1::2], d)

    _, t_sw = timed(lambda a, b: compare(a, b, metric="sw", k=1, cap=CAP),
                    d1, d2)
    report.add("metrics_sw", f"B{batch}_pairs_per_s", batch / max(t_sw, 1e-9))
    _, t_sk = timed(
        lambda a, b: compare(a, b, metric="sinkhorn", k=1, cap=CAP), d1, d2)
    report.add("metrics_sinkhorn", f"B{batch}_pairs_per_s",
               batch / max(t_sk, 1e-9))
    _, t_ew = timed(
        lambda a, b: compare(a, b, metric="exact_w", k=1, cap=CAP), d1, d2)
    report.add("metrics_exact_w", f"B{batch}_pairs_per_s",
               batch / max(t_ew, 1e-9))
    _, t_emb = timed(lambda a: sw_embedding(a, k=1, cap=CAP), d)
    report.add("metrics_sw_embedding", f"B{2*batch}_diagrams_per_s",
               2 * batch / max(t_emb, 1e-9))


def _bench_gram(report: Report, quick: bool) -> None:
    from benchmarks.kernel_bench import bench_pairwise_gram

    sizes = ((64, 256),) if quick else ((64, 256), (256, 512))
    worst = bench_pairwise_gram(report, "metrics_gram", sizes)
    if not worst < 1e-3:
        raise AssertionError(
            f"Pallas Gram diverges from jnp reference by {worst}")


def _bench_parity(report: Report, quick: bool) -> tuple[int, int]:
    """Random-pair sweep vs the host references; returns (checked, failed)."""
    n_pairs = 60 if quick else 200
    rng = np.random.default_rng(33)

    pairs = [(random_diagram(rng, essential=int(rng.integers(0, 3))),
              random_diagram(rng))
             for _ in range(n_pairs)]
    d1 = jax.tree.map(lambda *xs: jnp.stack(xs), *[a for a, _ in pairs])
    d2 = jax.tree.map(lambda *xs: jnp.stack(xs), *[b for _, b in pairs])
    sw = np.asarray(compare(d1, d2, metric="sw", k=1, n_dirs=32, cap=CAP))
    sk = np.asarray(compare(d1, d2, metric="sinkhorn", k=1, cap=CAP))

    checked = failed = 0
    for i, (a, b) in enumerate(pairs):
        pa, pb = diagram_points(a, k=1, cap=CAP), diagram_points(b, k=1, cap=CAP)
        sw_ref = mref.sw_dense(pa, pb, n_dirs=32)
        w2_ref = mref.wasserstein_exact(pa, pb, q=2.0)
        checked += 2
        tol = max(1e-5 * max(sw_ref, 1.0), 1e-5)
        if abs(sw[i] - sw_ref) > tol:
            failed += 1
        if (abs(sk[i]) > 1e-4 if w2_ref == 0
                else abs(sk[i] - w2_ref) / w2_ref > 0.05):
            failed += 1
    report.add("metrics_parity", "checked", checked)
    report.add("metrics_parity", "failed", failed)
    return checked, failed


def _bench_auction_parity(report: Report, quick: bool) -> tuple[int, int]:
    """exact_w (auction-LAP) vs the Hungarian oracle; returns (checked, failed).

    The acceptance sweep for the exact backend: randomized masked diagram
    pairs, atol 1e-5 on W2, 0 mismatches required — run on the collapsed
    forward/reverse formulation (the production default).  The legacy
    expanded formulation solves the same pairs as the rounds denominator:
    the collapse speedup (``rounds_reduction``) must be ≥ 5× and the two
    formulations must agree.  The bisection bottleneck backend (also on
    collapsed 0/1 feasibility solves) rides along against
    ``bottleneck_exact``.
    """
    n_pairs = 60 if quick else 200
    rng = np.random.default_rng(35)
    pairs = [(random_diagram(rng, essential=int(rng.integers(0, 3))),
              random_diagram(rng))
             for _ in range(n_pairs)]
    d1 = jax.tree.map(lambda *xs: jnp.stack(xs), *[a for a, _ in pairs])
    d2 = jax.tree.map(lambda *xs: jnp.stack(xs), *[b for _, b in pairs])
    (w, conv, rounds), t_w = timed(
        lambda a, b: exact_w_info(a, b, k=1, q=2.0, n_points=16,
                                  collapse="on"), d1, d2, repeats=1)
    w, conv, rounds = np.asarray(w), np.asarray(conv), np.asarray(rounds)
    w_off, conv_off, rounds_off = exact_w_info(d1, d2, k=1, q=2.0,
                                               n_points=16, collapse="off")
    w_off = np.asarray(w_off)
    rounds_off = np.asarray(rounds_off)
    formulation_diff = float(np.max(np.abs(w - w_off)))
    bn = np.asarray(bottleneck_approx(d1, d2, k=1, n_points=16))

    checked = failed = bn_failed = 0
    for i, (a, b) in enumerate(pairs):
        pa, pb = diagram_points(a, k=1, cap=CAP), diagram_points(b, k=1,
                                                                 cap=CAP)
        checked += 2
        if abs(w[i] - mref.wasserstein_exact(pa, pb, q=2.0)) > 1e-5:
            failed += 1
        bref = mref.bottleneck_exact(pa, pb)
        if abs(bn[i] - bref) > max(1e-4, 1e-4 * bref):
            bn_failed += 1
    report.add("metrics_auction_parity", "checked", checked)
    report.add("metrics_auction_parity", "failed", failed)
    report.add("metrics_auction_parity", "bottleneck_failed", bn_failed)
    report.add("metrics_auction_parity", "converged_frac", conv.mean())
    report.add("metrics_auction_parity", "rounds_mean", rounds.mean())
    report.add("metrics_auction_parity", "rounds_mean_expanded",
               rounds_off.mean())
    reduction = float(rounds_off.mean() / max(rounds.mean(), 1e-9))
    report.add("metrics_auction_parity", "rounds_reduction", reduction)
    report.add("metrics_auction_parity", "collapse_vs_expanded_max_diff",
               formulation_diff)
    report.add("metrics_auction_parity", f"B{n_pairs}_pairs_per_s",
               n_pairs / max(t_w, 1e-9))
    if not (np.asarray(conv_off).all() and conv.all()):
        raise AssertionError("auction parity sweep did not converge "
                             "(collapsed and expanded must both certify)")
    if formulation_diff > 1e-4:
        raise AssertionError(
            f"collapsed and expanded exact_w disagree by {formulation_diff}")
    if reduction < 5.0:
        raise AssertionError(
            f"collapsed auction rounds reduction {reduction:.2f}x < 5x "
            f"(collapsed {rounds.mean():.1f} vs expanded "
            f"{rounds_off.mean():.1f} mean rounds)")
    return checked, failed + bn_failed


def _bench_blocked_sinkhorn(report: Report, quick: bool) -> None:
    """Blocked (Pallas tiled) vs dense Sinkhorn: consistency + memory demo.

    At tile-fitting sizes the two paths run identical accumulation algebra
    and must agree to f32 roundoff; at full-tensor sizes the blocked path
    runs where the dense per-pair cost matrices would dwarf the previous
    ``n_points²`` working-set ceiling.
    """
    rng = np.random.default_rng(37)

    def stacked(n, s):
        rows = [random_diagram(rng, s=s, n=int(rng.integers(2, 9)))
                for _ in range(n)]
        return jax.tree.map(lambda *x: jnp.stack(x), *rows)

    d1, d2 = stacked(16, 12), stacked(16, 12)
    dense = np.asarray(sinkhorn_w2(d1, d2, k=1, impl="dense"))
    blocked = np.asarray(sinkhorn_w2(d1, d2, k=1, impl="blocked"))
    rel = float(np.max(np.abs(dense - blocked) / np.maximum(dense, 1e-9)))
    report.add("metrics_blocked_sinkhorn", "tilefit_max_rel_diff", rel)
    if rel >= 1e-4:
        raise AssertionError(
            f"blocked Sinkhorn diverged from the dense path by {rel} "
            "relative at tile-fitting size (want f32 roundoff, < 1e-4)")

    # memory-ceiling demo: full-tensor clouds, cost never materialized
    s_full = 256 if quick else 512
    b1, b2 = stacked(2, s_full), stacked(2, s_full)
    kw = dict(k=1, n_points=None, n_iters=15, n_scales=3)
    got_d, t_dense = timed(
        lambda a, b: sinkhorn_w2(a, b, impl="dense", **kw), b1, b2,
        repeats=1)
    got_b, t_blocked = timed(
        lambda a, b: sinkhorn_w2(a, b, impl="blocked", **kw), b1, b2,
        repeats=1)
    rel_full = float(np.max(np.abs(np.asarray(got_d) - np.asarray(got_b))
                            / np.maximum(np.asarray(got_d), 1e-9)))
    dense_bytes = 3 * (2 * s_full) ** 2 * 4     # per pair: c_xy, c_xx, c_yy
    tile_bytes = 128 * 128 * 4
    report.add("metrics_blocked_sinkhorn", f"S{s_full}_full_rel_diff",
               rel_full)
    report.add("metrics_blocked_sinkhorn", f"S{s_full}_dense_s", t_dense)
    report.add("metrics_blocked_sinkhorn", f"S{s_full}_blocked_s", t_blocked)
    report.add("metrics_blocked_sinkhorn", "dense_cost_bytes_per_pair",
               dense_bytes)
    report.add("metrics_blocked_sinkhorn", "blocked_tile_bytes", tile_bytes)
    if rel_full >= 1e-3:
        raise AssertionError(
            f"blocked Sinkhorn diverged at full-tensor size: {rel_full}")


def _bench_rerank_recall(report: Report, quick: bool) -> float:
    """Two-stage retrieval vs exhaustive exact re-rank; returns recall@10.

    Stage 1 is the LSH-prefiltered Gram retrieval of ``TopoIndex``; stage 2
    is the very ``SimilarityServe._rerank_exact`` code path production
    drains run (batched auction exact_w over the stored clouds).  Ground
    truth is the exhaustive exact_w over the whole corpus.
    """
    corpus_n = 2048 if quick else 10240
    q_n = 8 if quick else 16
    k = 10
    rng = np.random.default_rng(36)
    seeds = seed_diagram_arrays(rng, n_seeds=32, s=16)
    corpus = noisy_copies(seeds, rng, corpus_n, 0.02, 0.4)
    queries = noisy_copies(seeds, rng, q_n, 0.01, 0.02)

    cfg = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8,
                          coarse="lsh", lsh_bits=128, lsh_overfetch=8)
    index = TopoIndex(cfg)
    t0 = time.perf_counter()
    for s0 in range(0, corpus_n, 1024):
        index.add(jax.tree.map(lambda x: x[s0:s0 + 1024], corpus))
    t_add = time.perf_counter() - t0

    srv = SimilarityServe(index=index, rerank="exact_w", overfetch=4)
    t0 = time.perf_counter()
    res = index.query(queries, k=k * srv.overfetch)
    ids2, _, backends2 = srv._rerank_exact(queries, res)
    t_two_stage = time.perf_counter() - t0
    assert all(b == "exact_w" for row in backends2 for b in row)

    # exhaustive ground truth: exact_w of every (corpus row, query) pair
    all_clouds = index.clouds(np.arange(len(index)))
    t0 = time.perf_counter()
    hits = 0
    for i in range(q_n):
        qi = jax.tree.map(lambda x: x[i][None], queries)
        d = np.asarray(pairwise(all_clouds, qi, metric="exact_w",
                                k=cfg.k, cap=cfg.cap, n_points=cfg.n_points,
                                block_rows=2048))[:, 0]
        gt = {index.ids[j] for j in np.argsort(d, kind="stable")[:k]}
        hits += len(gt & set(ids2[i][:k]))
    t_exhaustive = time.perf_counter() - t0
    recall = hits / (k * q_n)

    report.add("metrics_rerank", "corpus", corpus_n)
    report.add("metrics_rerank", "queries", q_n)
    report.add("metrics_rerank", "recall_at_10", recall)
    report.add("metrics_rerank", "coarse_candidates",
               res.stats["coarse_candidates"])
    report.add("metrics_rerank", "stage2_pairs", srv.stats["stage2_pairs"])
    report.add("metrics_rerank", "index_add_s", t_add)
    report.add("metrics_rerank", "two_stage_s", t_two_stage)
    report.add("metrics_rerank", "exhaustive_s", t_exhaustive)
    report.add("metrics_rerank", "speedup_vs_exhaustive",
               t_exhaustive / max(t_two_stage, 1e-9))
    return recall


def _bench_probes_recall(report: Report, quick: bool) -> None:
    """Coarse recall@10 vs the multi-probe budget at tight overfetch.

    One LSH index with ``lsh_overfetch=2`` (too tight for single-probe to
    saturate recall) answers the same query batch at ``probes`` 1/4/16 via
    the per-query override — no re-index between points, and each probe
    budget still costs one (masked) scan over the codes.  Ground truth is
    the exhaustive embedding-L1 top-10, i.e. what ``coarse="none"`` would
    return, so the panel isolates what the coarse stage loses and what
    probing buys back.
    """
    corpus_n = 1024 if quick else 4096
    q_n = 8 if quick else 16
    k = 10
    rng = np.random.default_rng(38)
    seeds = seed_diagram_arrays(rng, n_seeds=32, s=16)
    corpus = noisy_copies(seeds, rng, corpus_n, 0.02, 0.4)
    queries = noisy_copies(seeds, rng, q_n, 0.03, 0.08)

    index = TopoIndex(TopoIndexConfig(
        embedding="sw", n_points=8, n_dirs=8,
        coarse="lsh", lsh_bits=128, lsh_overfetch=2))
    for s0 in range(0, corpus_n, 1024):
        index.add(jax.tree.map(lambda x: x[s0:s0 + 1024], corpus))

    emb_q = np.asarray(index.embed(queries))
    g = np.asarray(ops.pairwise_l1(jnp.asarray(emb_q),
                                   jnp.asarray(index._emb)))
    gt = np.argsort(g, axis=-1, kind="stable")[:, :k]

    first = last = None
    for probes in (1, 4, 16):
        res, t_q = timed(index.query, queries, k=k, probes=probes,
                         repeats=2)
        assert res.stats["probes"] == probes
        hits = sum(len(set(gt[i]) & {int(r) for r in res.rows[i]})
                   for i in range(q_n))
        recall = hits / (k * q_n)
        report.add("metrics_probes", f"p{probes}_recall_at_10", recall)
        report.add("metrics_probes", f"p{probes}_candidates",
                   res.stats["coarse_candidates"])
        report.add("metrics_probes", f"p{probes}_query_s", t_q)
        assert last is None or recall >= last - 0.05, (
            f"recall fell from {last:.3f} to {recall:.3f} as probes "
            f"rose to {probes}")
        first = recall if first is None else first
        last = recall
    assert last > first, (
        f"probing bought no recall: p1 {first:.3f} vs p16 {last:.3f} — "
        "the overfetch=2 funnel should be visibly unsaturated")


def _bench_stage1_exact(report: Report, quick: bool) -> None:
    """``stage1_backend="exact_w"`` vs LSH+Gram+re-rank on one corpus.

    The exact stage-1 scores every query against every stored cloud
    (recall 1.0 by construction — the panel asserts its top-k distances
    match an independently computed exhaustive ``pairwise`` ground truth),
    then repeats the batch to measure the price-cache warm-start effect
    (hit rate + rounds drop across drains).  The two-stage LSH funnel runs
    the same queries for the cost/recall comparison.
    """
    corpus_n = 256 if quick else 1024
    q_n = 8 if quick else 16
    k = 10
    rng = np.random.default_rng(39)
    seeds = seed_diagram_arrays(rng, n_seeds=32, s=16)
    corpus = noisy_copies(seeds, rng, corpus_n, 0.02, 0.4)
    queries = noisy_copies(seeds, rng, q_n, 0.01, 0.02)

    cfg = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8)
    index = TopoIndex(cfg)
    index.add(corpus)
    srv = SimilarityServe(index=index, stage1_backend="exact_w")

    t0 = time.perf_counter()
    ids1, dists1, backends1 = srv._stage1_exact(queries, k)
    t_cold = time.perf_counter() - t0
    assert all(b == "exact_w" for row in backends1 for b in row)
    rounds_cold = srv.stats["auction_rounds"]

    t0 = time.perf_counter()
    ids_w, dists_w, _ = srv._stage1_exact(queries, k)
    t_warm = time.perf_counter() - t0
    st = srv.stats
    rounds_warm = st["auction_rounds"] - rounds_cold
    hit_rate = st["warm_start_hits"] / max(
        st["warm_start_hits"] + st["warm_start_misses"], 1)

    # recall 1.0 by construction: the exhaustive pairwise ground truth must
    # produce the same top-k distances (ids may permute under exact ties)
    gt = np.asarray(pairwise(queries, index.clouds(np.arange(len(index))),
                             metric="exact_w", k=cfg.k, cap=cfg.cap,
                             n_points=cfg.n_points, block_rows=2048))
    gt_topk = np.sort(gt, axis=-1)[:, :k]
    dist_err = float(np.max(np.abs(np.asarray(dists1) - gt_topk)))
    if dist_err > 1e-5:
        raise AssertionError(
            f"stage1 exact_w top-{k} distances diverge from the "
            f"exhaustive ground truth by {dist_err}")
    if ids_w != ids1:
        raise AssertionError(
            "warm-started stage1 exact_w returned different neighbors")

    # the two-stage funnel on the same corpus/queries, for comparison
    cfg2 = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8,
                           coarse="lsh", lsh_bits=128, lsh_overfetch=8)
    index2 = TopoIndex(cfg2)
    index2.add(corpus)
    srv2 = SimilarityServe(index=index2, rerank="exact_w", overfetch=4)
    t0 = time.perf_counter()
    res = index2.query(queries, k=k * srv2.overfetch)
    ids2, _, _ = srv2._rerank_exact(queries, res)
    t_two_stage = time.perf_counter() - t0
    hits = sum(len(set(ids1[i][:k]) & set(ids2[i][:k])) for i in range(q_n))
    lsh_recall = hits / (k * q_n)

    report.add("metrics_stage1_exact", "corpus", corpus_n)
    report.add("metrics_stage1_exact", "queries", q_n)
    report.add("metrics_stage1_exact", "pairs", q_n * corpus_n)
    report.add("metrics_stage1_exact", "cold_s", t_cold)
    report.add("metrics_stage1_exact", "warm_s", t_warm)
    report.add("metrics_stage1_exact", "rounds_cold", rounds_cold)
    report.add("metrics_stage1_exact", "rounds_warm", rounds_warm)
    report.add("metrics_stage1_exact", "warm_hit_rate", hit_rate)
    report.add("metrics_stage1_exact", "gt_max_abs_diff", dist_err)
    report.add("metrics_stage1_exact", "two_stage_s", t_two_stage)
    report.add("metrics_stage1_exact", "lsh_recall_vs_exact", lsh_recall)


def _bench_two_stage_serve(report: Report, quick: bool) -> None:
    """Per-stage stats through the real SimilarityServe two-phase drain."""
    from benchmarks.fig2_clustering import FAMILIES

    srv = SimilarityServe(
        index_config=TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8),
        default_k=3, rerank="exact_w", overfetch=3)
    per = 4 if quick else 8
    key = jax.random.PRNGKey(38)
    futs = []
    for name, gen in FAMILIES:
        key, sub = jax.random.split(key)
        g = gdata.with_degree_filtration(gen(sub, per + 1))
        for i in range(per + 1):
            adj = np.asarray(g.adj[i])
            n = int(np.asarray(g.mask[i]).sum())
            u, v = np.nonzero(np.triu(adj))
            edges = list(zip(u.tolist(), v.tolist()))
            if i < per:
                srv.add(edges=edges, n_vertices=n, gid=f"{name}/{i}")
            else:
                futs.append(srv.submit(edges=edges, n_vertices=n))
    t0 = time.perf_counter()
    srv.drain()
    wall = time.perf_counter() - t0
    for f in futs:
        r = f.result(timeout=30)
        assert r.backends == ("exact_w",) * len(r.ids), r.backends
    if not (srv.stats["stage1_candidates"] and srv.stats["stage2_pairs"]):
        raise AssertionError(f"two-stage drain stats missing: {srv.stats}")
    report.add("metrics_serve_two_stage", "indexed", srv.stats["indexed"])
    report.add("metrics_serve_two_stage", "queries", srv.stats["queries"])
    report.add("metrics_serve_two_stage", "stage1_candidates",
               srv.stats["stage1_candidates"])
    report.add("metrics_serve_two_stage", "stage2_pairs",
               srv.stats["stage2_pairs"])
    report.add("metrics_serve_two_stage", "stage1_s", srv.stats["stage1_s"])
    report.add("metrics_serve_two_stage", "stage2_s", srv.stats["stage2_s"])
    report.add("metrics_serve_two_stage", "drain_s", wall)


def _bench_drift(report: Report, quick: bool) -> tuple[int, int, int]:
    """Burst detection on community churn; returns (bursts, flagged, false_pos).

    Quiet segments carry no structural updates (the monitoring regime: the
    stream is sampled faster than the network changes), so a false positive
    would mean the drift scorer invented a diagram change; bursts rewire
    ``churn`` edges at once and must all be flagged.
    """
    steps = 16 if quick else 32
    churn = 8
    burst_at = set(range(4, steps, 7))
    sched = np.zeros(steps, np.int32)
    for t in burst_at:
        sched[t] = churn
    g0, deltas = community_churn_stream(
        jax.random.PRNGKey(34), batch=4, n_pad=24, n_vertices=20, n_comm=4,
        p_in=0.45, p_out=0.05, steps=steps, churn=churn, churn_schedule=sched)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=160, tri_cap=384,
                           drift_metric="sw", drift_threshold=1.0)
    stream = TopoStream(g0, cfg)
    t0 = time.perf_counter()
    flagged = []
    for t in range(steps):
        stream.apply(delta_step(deltas, t))
        if stream.last_anomaly.any():
            flagged.append(t)
    wall = time.perf_counter() - t0
    hits = len(set(flagged) & burst_at)
    false_pos = len(set(flagged) - burst_at)
    report.add("metrics_drift", "steps", steps)
    report.add("metrics_drift", "steps_per_s", steps / max(wall, 1e-9))
    report.add("metrics_drift", "bursts", len(burst_at))
    report.add("metrics_drift", "bursts_flagged", hits)
    report.add("metrics_drift", "false_positives", false_pos)
    report.add("metrics_drift", "skip_rate", stream.skip_rate())
    return len(burst_at), hits, false_pos


def run(report: Report, quick: bool = False) -> None:
    _bench_throughput(report, quick)
    _bench_gram(report, quick)
    checked, failed = _bench_parity(report, quick)
    a_checked, a_failed = _bench_auction_parity(report, quick)
    _bench_blocked_sinkhorn(report, quick)   # asserts internally
    recall = _bench_rerank_recall(report, quick)
    _bench_probes_recall(report, quick)      # asserts internally
    _bench_stage1_exact(report, quick)       # asserts internally
    _bench_two_stage_serve(report, quick)    # asserts internally
    bursts, hits, false_pos = _bench_drift(report, quick)
    if failed:
        raise AssertionError(
            f"{failed}/{checked} distance checks diverged from the host "
            "references")
    if a_failed:
        raise AssertionError(
            f"{a_failed}/{a_checked} auction/bottleneck checks diverged "
            "from the exact host oracles")
    if recall < 0.95:
        raise AssertionError(
            f"two-stage retrieval recall@10 {recall:.3f} < 0.95 vs "
            "exhaustive exact re-rank")
    if hits != bursts or false_pos:
        raise AssertionError(
            f"drift demo: {hits}/{bursts} bursts flagged, "
            f"{false_pos} false positives")
    print(f"[metrics_bench] parity OK: {checked} checks; auction parity "
          f"OK: {a_checked} checks; rerank recall@10: {recall:.3f}; drift "
          f"OK: {hits}/{bursts} bursts flagged, 0 false positives")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small sweep (CI / CPU smoke)")
    ap.add_argument("--out-dir", default="results",
                    help="directory for BENCH_metrics.json")
    args = ap.parse_args()
    report = Report(quick=args.quick)
    t0 = time.time()
    ok = True
    try:
        run(report, quick=args.quick)
    except Exception:
        ok = False
        raise
    finally:
        path = write_suite_json(
            args.out_dir, "metrics",
            "diagram distances + Gram kernel + parity + drift",
            report.rows, wall_s=time.time() - t0, quick=args.quick, ok=ok)
        print(f"wrote {path}")
    print(report.csv())


if __name__ == "__main__":
    main()
