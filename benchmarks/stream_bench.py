"""TopoStream benchmark: updates/s, skip-rate, and per-update parity.

Replays the temporal workloads (repro/data/temporal.py) through a
``TopoStream`` session and measures

* **updates/s** — graph updates absorbed per second by the incremental path
  (reduction-aware invalidation + restricted recompute);
* **skip-rate** — fraction of updates answered from cache with *zero*
  persistence recompute (the paper's Theorems 2/7 doing serve-time work);
* **scratch updates/s** — the from-scratch baseline (full plan execution on
  the whole batch per step), and the resulting speedup;

and, mirroring serve_bench's parity contract, asserts after **every** update
that the streamed diagram's persistence pairs in every guaranteed dimension
are bit-identical to a direct ``topological_signature`` call on the current
graph state (invalidation must be a scheduling decision, never a numerics
change).

  PYTHONPATH=src python -m benchmarks.stream_bench [--quick]
  PYTHONPATH=src python -m benchmarks.run --only stream [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax

from benchmarks.common import Report, write_suite_json
from repro.core.api import topological_signature
from repro.core.delta import delta_step
from repro.data.temporal import (
    community_churn_stream,
    ego_decay_stream,
    pa_growth_stream,
)
from repro.stream import TopoStream, TopoStreamConfig, dim_pairs


def _replay(g0, deltas, steps: int, cfg: TopoStreamConfig) -> tuple:
    """One full incremental replay; returns (stream, wall_seconds)."""
    stream = TopoStream(g0, cfg)
    jax.block_until_ready(stream.diagrams.birth)
    t0 = time.perf_counter()
    for t in range(steps):
        stream.apply(delta_step(deltas, t))
    jax.block_until_ready(stream.diagrams.birth)
    return stream, time.perf_counter() - t0


def _bench_workload(report: Report, tag: str, g0, deltas, steps: int,
                    cfg: TopoStreamConfig) -> tuple[int, int, float]:
    """Benchmark + verify one workload; returns (checked, mismatches, skip)."""
    check_dims = (tuple(range(cfg.dim + 1)) if cfg.exact_dims == "all"
                  else (cfg.dim,))
    batch = g0.batch

    # warmup replay: compile every jit signature (apply/verdict/plan shapes)
    # out of the timed region — jit caches are process-wide, so the timed
    # replay below sees them hot
    _replay(g0, deltas, steps, cfg)

    stream, wall = _replay(g0, deltas, steps, cfg)
    updates = stream.stats["graph_updates"]
    report.add(tag, "steps", steps)
    report.add(tag, "graph_updates", updates)
    report.add(tag, "updates_per_s", updates / max(wall, 1e-9))
    report.add(tag, "skip_rate", stream.skip_rate())
    report.add(tag, "coral_hits", stream.stats["coral_hits"])
    report.add(tag, "prunit_hits", stream.stats["prunit_hits"])
    report.add(tag, "recomputes", stream.stats["recomputes"])
    report.add(tag, "recomputed_rows", stream.stats["recomputed_rows"])

    # parity pass: replay again, checking every update against a from-scratch
    # computation on the same graph state (shares the stream's compiled plan
    # through the process-wide plan cache), and timing the from-scratch
    # executes as the recompute-everything baseline
    def scratch(g):
        return topological_signature(
            g, dim=cfg.dim, method=cfg.method, sublevel=cfg.sublevel,
            edge_cap=cfg.edge_cap, tri_cap=cfg.tri_cap,
            quad_cap=cfg.quad_cap, reducer=cfg.reducer)

    jax.block_until_ready(scratch(g0).birth)  # compile the (B, N) shape
    verifier = TopoStream(g0, cfg)
    checked = mismatches = 0
    scratch_wall = 0.0
    for t in range(steps):
        d = verifier.apply(delta_step(deltas, t))
        t0 = time.perf_counter()
        ref = scratch(verifier.graph)
        jax.block_until_ready(ref.birth)
        scratch_wall += time.perf_counter() - t0
        for b in range(batch):
            for k in check_dims:
                checked += 1
                if dim_pairs(d, b, k) != dim_pairs(ref, b, k):
                    mismatches += 1
    scratch_rate = (steps * batch) / max(scratch_wall, 1e-9)
    report.add(tag, "scratch_updates_per_s", scratch_rate)
    report.add(tag, "speedup_vs_scratch",
               (updates / max(wall, 1e-9)) / max(scratch_rate, 1e-9))
    report.add(tag, "parity_checked", checked)
    report.add(tag, "parity_mismatches", mismatches)
    return checked, mismatches, stream.skip_rate()


def run(report: Report, quick: bool = False) -> None:
    key = jax.random.PRNGKey(20)
    k_ego, k_gro, k_chu = jax.random.split(key, 3)

    # temporal ego-net decay — the acceptance workload: >= 500 graph updates
    # even in --quick, with a provably-skippable majority (satellite toggles)
    # and a recompute tail (core edges)
    ego_b, ego_t = (8, 64) if quick else (16, 128)
    g0, deltas = ego_decay_stream(k_ego, batch=ego_b, n_pad=32, n_core=10,
                                  n_double=6, n_pendant=6, steps=ego_t,
                                  toggles=1, p_core_edge=0.15)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=192, tri_cap=512)
    checked, mism, ego_skip = _bench_workload(
        report, "stream_ego", g0, deltas, ego_t, cfg)
    totals = {"checked": checked, "mismatches": mism}

    if not quick:
        # growing network, m=1: every arrival is dominated by its attachment
        # target -> PrunIT skips every recompute, in every dimension
        g0, deltas = pa_growth_stream(k_gro, batch=8, n_pad=64, n0=4, m=1,
                                      steps=48)
        cfg = TopoStreamConfig(dim=1, method="prunit", exact_dims="all",
                               edge_cap=128, tri_cap=192)
        c, m, _ = _bench_workload(report, "stream_growth", g0, deltas, 48, cfg)
        totals["checked"] += c
        totals["mismatches"] += m

        # community churn: most updates land inside the 2-core — the
        # recompute-bound regime (restricted recompute still pays)
        g0, deltas = community_churn_stream(k_chu, batch=8, n_pad=24,
                                            n_vertices=20, n_comm=4,
                                            p_in=0.45, p_out=0.05,
                                            steps=32, churn=2)
        cfg = TopoStreamConfig(dim=1, method="both", edge_cap=160,
                               tri_cap=384)
        c, m, _ = _bench_workload(report, "stream_churn", g0, deltas, 32, cfg)
        totals["checked"] += c
        totals["mismatches"] += m

    report.add("stream", "parity_checked", totals["checked"])
    report.add("stream", "parity_mismatches", totals["mismatches"])
    report.add("stream", "ego_skip_rate", ego_skip)
    if totals["mismatches"]:
        raise AssertionError(
            f"{totals['mismatches']}/{totals['checked']} streamed diagrams "
            "differ from direct topological_signature output")
    if not ego_skip > 0:
        raise AssertionError(
            "invalidation check never short-circuited a recompute on the "
            "temporal ego-net workload (skip-rate 0)")
    print(f"[stream_bench] parity OK: {totals['checked']} diagram "
          f"comparisons bit-identical; ego skip-rate {ego_skip:.3f}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small stream (CI / CPU smoke)")
    ap.add_argument("--out-dir", default="results",
                    help="directory for BENCH_stream.json")
    args = ap.parse_args()
    report = Report(quick=args.quick)
    t0 = time.time()
    ok = True
    try:
        run(report, quick=args.quick)
    except Exception:
        ok = False
        raise
    finally:
        path = write_suite_json(args.out_dir, "stream",
                                "TopoStream updates/s + skip-rate + parity",
                                report.rows, wall_s=time.time() - t0,
                                quick=args.quick, ok=ok)
        print(f"wrote {path}")
    print(report.csv())


if __name__ == "__main__":
    main()
