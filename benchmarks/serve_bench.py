"""TopoServe throughput/latency benchmark + served-vs-direct parity check.

Per padding bucket: graphs/s, p50/p99 request latency, executed batches —
and a bit-identical comparison of every served diagram against a direct
``topological_signature`` call on the same packed batches (the serve path
must be a pure scheduling layer, never a numerics layer).

  PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
  PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import argparse
import gc
import time

import numpy as np

from benchmarks.common import Report
from repro import obs
from repro.core.api import plan_cache_info, topological_signature
from repro.core.persistence_jax import diagrams_bitwise_equal
from repro.serve import TopoServe, TopoServeConfig
from repro.serve.topo_serve import pack_requests


def _query_stream(n_queries: int, seed: int = 0):
    """Synthetic ego-net-regime queries spanning the bucket ladder."""
    import networkx as nx

    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_queries):
        n = int(rng.integers(6, 56))
        kind = rng.integers(0, 3)
        if kind == 0:
            g = nx.gnp_random_graph(n, float(rng.uniform(0.1, 0.3)),
                                    seed=int(rng.integers(2**31)))
        elif kind == 1:
            g = nx.barabasi_albert_graph(n, min(3, n - 1),
                                         seed=int(rng.integers(2**31)))
        else:
            g = nx.powerlaw_cluster_graph(n, min(2, n - 1), 0.4,
                                          seed=int(rng.integers(2**31)))
        out.append((list(g.edges()), n))
    return out


def run(report: Report, quick: bool = False) -> None:
    n_queries = 60 if quick else 400
    max_batch = 32 if quick else 128
    # pad_batch_to == max_batch -> every executed batch has ONE shape per
    # bucket, so after warmup the timed region never recompiles
    cfg = TopoServeConfig(dim=1, method="prunit", sublevel=False,
                          max_batch=max_batch, pad_batch_to=max_batch,
                          record_batches=True)
    server = TopoServe(cfg)
    queries = _query_stream(n_queries, seed=11)

    # warmup round: compile every touched bucket out of the timed region
    warm = [server.submit(edges=e, n_vertices=n) for (e, n) in queries]
    server.drain()
    for f in warm:
        f.result()
    server.executed_batches.clear()
    # report deltas over the timed region only: server.stats accumulates the
    # warmup drain, and the plan cache is process-cumulative (other suites
    # compile through it under `-m benchmarks.run`)
    batches_before = server.stats["batches"]
    cache_before = plan_cache_info()

    # Exclude the cyclic collector from the timed region (timeit-style):
    # when full collections land is a function of process-wide allocation
    # counts, so merely importing another package can shift multi-ms GC
    # pauses into the submit loop and double the per-bucket p50s.  The
    # bench measures the serving layer, not collector scheduling.
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        t0 = time.perf_counter()
        futs = [server.submit(edges=e, n_vertices=n) for (e, n) in queries]
        server.drain()
        results = [f.result() for f in futs]
        wall = time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()

    report.add("serve", "graphs_per_s", len(futs) / wall)
    by_bucket: dict = {}
    for f in futs:
        by_bucket.setdefault(f.bucket, []).append(f)
    for bucket, bfuts in sorted(by_bucket.items()):
        lat = np.array([f.latency_s() for f in bfuts]) * 1e3
        tag = f"serve_n{bucket.n_pad}"
        report.add(tag, "graphs", len(bfuts))
        report.add(tag, "latency_p50_ms", np.percentile(lat, 50))
        report.add(tag, "latency_p99_ms", np.percentile(lat, 99))
    report.add("serve", "batches", server.stats["batches"] - batches_before)
    info = plan_cache_info()
    report.add("serve", "plan_cache_hits",
               info["hits"] - cache_before["hits"])
    report.add("serve", "plan_cache_misses",
               info["misses"] - cache_before["misses"])

    # ---- parity: replay the exact executed batches through the direct API
    import jax

    checked = 0
    mismatches = 0
    for bucket, reqs, bfuts in server.executed_batches:
        g = pack_requests(reqs, bucket)
        direct = topological_signature(
            g, dim=cfg.dim, method=cfg.method, sublevel=cfg.sublevel,
            edge_cap=bucket.edge_cap, tri_cap=bucket.tri_cap,
            quad_cap=cfg.quad_cap, reducer=cfg.reducer,
        )
        for i, fut in enumerate(bfuts):
            row = jax.tree.map(lambda x: x[i], direct)
            if not diagrams_bitwise_equal(fut.result(), row):
                mismatches += 1
            checked += 1
    assert checked == len(results), (checked, len(results))
    report.add("serve", "parity_mismatches", mismatches)
    if mismatches:
        raise AssertionError(
            f"{mismatches}/{len(results)} served diagrams differ from direct "
            "topological_signature output")
    print(f"[serve_bench] parity OK: {len(results)} served diagrams "
          "bit-identical to direct computation")

    # with REPRO_OBS=1 the timed drains above produced spans — export the
    # Chrome trace + a Prometheus snapshot next to the bench JSONs so a CI
    # smoke (or a human with Perfetto) can inspect the run
    if obs.enabled():
        trace_path = obs.export_chrome_trace("results/trace_serve_bench.json")
        prom_path = obs.export_prometheus("results/metrics_serve_bench.prom")
        print(f"[serve_bench] obs: wrote {trace_path} "
              f"({len(obs.trace_events())} spans) and {prom_path}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small stream (CI / CPU smoke)")
    args = ap.parse_args()
    report = Report()
    run(report, quick=args.quick)
    print(report.csv())


if __name__ == "__main__":
    main()
