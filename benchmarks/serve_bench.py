"""TopoServe throughput/latency benchmark + served-vs-direct parity check.

Per padding bucket: graphs/s, p50/p99 request latency, executed batches —
and a bit-identical comparison of every served diagram against a direct
``topological_signature`` call on the same packed batches (the serve path
must be a pure scheduling layer, never a numerics layer).

A TopoWatch panel follows the parity check: a second, live round behind a
running HTTP exporter + installed SLO engine measures the fully-watched
request path against the bare one (``watch_overhead_pct``: exporter
scraping, SLO ticking, request-context minting, flight recording — budget
≤2%), and ``--inject-slow-drain`` detunes the drain deterministically so
the latency SLO trips, flips ``/slo`` to breach, and leaves a flight dump
under ``results/obs/`` — the CI smoke asserts that whole chain.

  PYTHONPATH=src python -m benchmarks.serve_bench [--quick]
                                                  [--inject-slow-drain]
  PYTHONPATH=src python -m benchmarks.run --only serve
"""
from __future__ import annotations

import argparse
import gc
import json
import time
import urllib.request

import numpy as np

from benchmarks.common import Report
from repro import obs
from repro.core.api import plan_cache_info, topological_signature
from repro.core.persistence_jax import diagrams_bitwise_equal
from repro.serve import TopoServe, TopoServeConfig
from repro.serve.topo_serve import pack_requests


def _query_stream(n_queries: int, seed: int = 0):
    """Synthetic ego-net-regime queries spanning the bucket ladder."""
    import networkx as nx

    rng = np.random.default_rng(seed)
    out = []
    for _ in range(n_queries):
        n = int(rng.integers(6, 56))
        kind = rng.integers(0, 3)
        if kind == 0:
            g = nx.gnp_random_graph(n, float(rng.uniform(0.1, 0.3)),
                                    seed=int(rng.integers(2**31)))
        elif kind == 1:
            g = nx.barabasi_albert_graph(n, min(3, n - 1),
                                         seed=int(rng.integers(2**31)))
        else:
            g = nx.powerlaw_cluster_graph(n, min(2, n - 1), 0.4,
                                          seed=int(rng.integers(2**31)))
        out.append((list(g.edges()), n))
    return out


def run(report: Report, quick: bool = False,
        inject_slow_drain: bool = False) -> None:
    n_queries = 60 if quick else 400
    max_batch = 32 if quick else 128
    # pad_batch_to == max_batch -> every executed batch has ONE shape per
    # bucket, so after warmup the timed region never recompiles
    cfg = TopoServeConfig(dim=1, method="prunit", sublevel=False,
                          max_batch=max_batch, pad_batch_to=max_batch,
                          record_batches=True)
    server = TopoServe(cfg)
    queries = _query_stream(n_queries, seed=11)

    # warmup round: compile every touched bucket out of the timed region
    warm = [server.submit(edges=e, n_vertices=n) for (e, n) in queries]
    server.drain()
    for f in warm:
        f.result()
    server.executed_batches.clear()
    # report deltas over the timed region only: server.stats accumulates the
    # warmup drain, and the plan cache is process-cumulative (other suites
    # compile through it under `-m benchmarks.run`)
    batches_before = server.stats["batches"]
    cache_before = plan_cache_info()

    # Exclude the cyclic collector from the timed region (timeit-style):
    # when full collections land is a function of process-wide allocation
    # counts, so merely importing another package can shift multi-ms GC
    # pauses into the submit loop and double the per-bucket p50s.  The
    # bench measures the serving layer, not collector scheduling.
    gc.collect()
    gc_was_enabled = gc.isenabled()
    gc.disable()
    try:
        t0 = time.perf_counter()
        futs = [server.submit(edges=e, n_vertices=n) for (e, n) in queries]
        server.drain()
        results = [f.result() for f in futs]
        wall = time.perf_counter() - t0
    finally:
        if gc_was_enabled:
            gc.enable()

    report.add("serve", "graphs_per_s", len(futs) / wall)
    by_bucket: dict = {}
    for f in futs:
        by_bucket.setdefault(f.bucket, []).append(f)
    for bucket, bfuts in sorted(by_bucket.items()):
        lat = np.array([f.latency_s() for f in bfuts]) * 1e3
        tag = f"serve_n{bucket.n_pad}"
        report.add(tag, "graphs", len(bfuts))
        report.add(tag, "latency_p50_ms", np.percentile(lat, 50))
        report.add(tag, "latency_p99_ms", np.percentile(lat, 99))
    report.add("serve", "batches", server.stats["batches"] - batches_before)
    info = plan_cache_info()
    report.add("serve", "plan_cache_hits",
               info["hits"] - cache_before["hits"])
    report.add("serve", "plan_cache_misses",
               info["misses"] - cache_before["misses"])

    # ---- parity: replay the exact executed batches through the direct API
    import jax

    checked = 0
    mismatches = 0
    for bucket, reqs, bfuts in server.executed_batches:
        g = pack_requests(reqs, bucket)
        direct = topological_signature(
            g, dim=cfg.dim, method=cfg.method, sublevel=cfg.sublevel,
            edge_cap=bucket.edge_cap, tri_cap=bucket.tri_cap,
            quad_cap=cfg.quad_cap, reducer=cfg.reducer,
        )
        for i, fut in enumerate(bfuts):
            row = jax.tree.map(lambda x: x[i], direct)
            if not diagrams_bitwise_equal(fut.result(), row):
                mismatches += 1
            checked += 1
    assert checked == len(results), (checked, len(results))
    report.add("serve", "parity_mismatches", mismatches)
    if mismatches:
        raise AssertionError(
            f"{mismatches}/{len(results)} served diagrams differ from direct "
            "topological_signature output")
    print(f"[serve_bench] parity OK: {len(results)} served diagrams "
          "bit-identical to direct computation")

    # with REPRO_OBS=1 the timed drains above produced spans — export the
    # Chrome trace + a Prometheus snapshot under results/obs/ (TopoWatch
    # scratch, gitignored; CI uploads them as artifacts) so a smoke job or
    # a human with Perfetto can inspect the run
    if obs.enabled():
        trace_path = obs.export_chrome_trace(
            "results/obs/trace_serve_bench.json")
        prom_path = obs.export_prometheus(
            "results/obs/metrics_serve_bench.prom")
        print(f"[serve_bench] obs: wrote {trace_path} "
              f"({len(obs.trace_events())} spans) and {prom_path}")

    _watch_panel(report, queries, cfg,
                 inject_slow_drain=inject_slow_drain, quick=quick)


def _serve_round(server: TopoServe, queries) -> float:
    """Wall seconds to submit + drain + collect one full query stream."""
    gc.collect()
    t0 = time.perf_counter()
    futs = [server.submit(edges=e, n_vertices=n) for (e, n) in queries]
    server.drain()
    for f in futs:
        f.result()
    return time.perf_counter() - t0


def _watch_panel(report: Report, queries, cfg: TopoServeConfig,
                 inject_slow_drain: bool, quick: bool) -> None:
    """Live TopoWatch round: exporter + SLO engine around a serve loop.

    Measures the watched-vs-bare request path (same compiled plans — the
    bare round re-runs first so both sides are warm), scrapes /metrics and
    /healthz from the running exporter mid-traffic, and (opt-in) injects a
    deterministic drain-side stall that trips the p99 latency SLO: verdict
    visible at /slo, counted in slo.breaches_total (which PerfGate gates
    abs_upper), flight ring dumped to results/obs/FLIGHT_<rev>.json.
    """
    import threading

    from repro.obs import flight, slo
    from repro.obs.http import start_http_server

    # --- bare round (TopoWatch passive: no exporter, no SLO engine)
    bare = TopoServe(cfg)
    _serve_round(bare, queries)            # warm the per-size executables
    bare_s = min(_serve_round(bare, queries) for _ in range(3))

    # --- watched round: exporter scraping + SLO engine ticking in the
    # background while the same stream is served.  Un-injected ceilings
    # are deliberately unreachable (30s): the panel proves the machinery
    # runs at zero marginal cost, not that this machine is fast — and a
    # real breach here would poison telemetry.slo_breaches_total, which
    # PerfGate gates abs_upper against a zero baseline.
    tight = 0.050 if inject_slow_drain else 30.0
    engine = slo.SLOEngine(slo.default_serve_slos(
        latency_p99_s=tight, latency_p50_s=tight,
        rules=(slo.BurnRule(long_s=2.0, short_s=0.5, factor=1.0),)))
    slo.install(engine)
    srv = start_http_server(port=0)
    stop_scrape = threading.Event()

    def scraper():
        # realistic scrape cadence: Prometheus defaults to whole seconds;
        # 0.25s is already 4-40x tighter than production pulls
        while not stop_scrape.is_set():
            urllib.request.urlopen(srv.url + "/metrics").read()
            urllib.request.urlopen(srv.url + "/slo").read()
            stop_scrape.wait(0.25)

    scr = threading.Thread(target=scraper, daemon=True)
    scr.start()
    watched = TopoServe(cfg)
    if inject_slow_drain:
        # deterministic detune: every drain stalls past the (tightened)
        # p99 ceiling, so the burn-rate rules must fire
        inner = watched.drain
        stall = 4.0 * tight

        def slow_drain():
            time.sleep(stall)
            return inner()

        watched.drain = slow_drain
    _serve_round(watched, queries)
    n_rounds = 3 if quick else 5
    watched_s = []
    for _ in range(n_rounds):
        watched_s.append(_serve_round(watched, queries))
        engine.tick()
        time.sleep(0.1)  # burn windows need >1 distinct snapshot times
    engine.tick()
    stop_scrape.set()
    scr.join(timeout=2)

    health = json.load(urllib.request.urlopen(srv.url + "/healthz"))
    slo_doc = json.load(urllib.request.urlopen(srv.url + "/slo"))
    srv.stop()
    slo.install(None)

    if not inject_slow_drain:
        overhead = 100.0 * (min(watched_s) - bare_s) / bare_s
        report.add("serve_watch", "watch_overhead_pct", overhead)
    report.add("serve_watch", "slo_objectives", len(slo_doc["status"]))
    breached = [k for k, v in slo_doc["status"].items()
                if v["status"] == "breach"]
    report.add("serve_watch", "slo_breached", len(breached))
    print(f"[serve_bench] topowatch: health={health['status']} "
          f"breached={breached or 'none'}")
    if inject_slow_drain:
        dump = flight.last_dump_path()
        assert breached, "slow-drain injection did not trip any SLO"
        assert dump is not None, "SLO breach left no flight dump"
        print(f"[serve_bench] slow-drain injection tripped {breached}; "
              f"flight dump: {dump}")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small stream (CI / CPU smoke)")
    ap.add_argument("--inject-slow-drain", action="store_true",
                    help="detune the watched drain to force one SLO "
                         "breach + flight dump (CI smoke)")
    args = ap.parse_args()
    report = Report()
    run(report, quick=args.quick, inject_slow_drain=args.inject_slow_drain)
    print(report.csv())


if __name__ == "__main__":
    main()
