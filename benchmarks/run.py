"""Benchmark suite entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1] [--quick]

Writes results/bench.csv and prints per-row CSV as it goes.
"""
from __future__ import annotations

import argparse
import os
import time
import traceback

from benchmarks.common import Report

SUITES = {
    "fig4": ("benchmarks.fig4_coral_reduction", "CoralTDA vertex reduction (Fig 4)"),
    "fig5a": ("benchmarks.fig5_prunit", "PrunIT vertex reduction (Fig 5a)"),
    "fig5b": ("benchmarks.fig5b_ego_time", "PrunIT ego-net PD0 time (Fig 5b)"),
    "table1": ("benchmarks.table1_large_networks", "PrunIT on large networks (Table 1)"),
    "fig6": ("benchmarks.fig6_combined", "PrunIT+CoralTDA combined (Fig 6)"),
    "fig7_9": ("benchmarks.fig7_9_secondary", "clique/time/edge reduction (Figs 7-9)"),
    "table3": ("benchmarks.table3_strong_collapse", "PrunIT vs Strong Collapse (Table 3)"),
    "fig2": ("benchmarks.fig2_clustering", "clustering coeff vs higher PDs (Fig 2/10)"),
    "kernels": ("benchmarks.kernel_bench", "Pallas kernel microbenchmarks"),
    "serve": ("benchmarks.serve_bench", "TopoServe throughput/latency + parity"),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (default: all)")
    ap.add_argument("--out", default="results/bench.csv")
    args = ap.parse_args()

    keys = args.only.split(",") if args.only else list(SUITES)
    report = Report()
    failures = []
    for k in keys:
        mod_name, desc = SUITES[k]
        print(f"[bench] {k}: {desc}", flush=True)
        t0 = time.time()
        try:
            mod = __import__(mod_name, fromlist=["run"])
            mod.run(report)
            print(f"[bench] {k} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(k)
            traceback.print_exc()
    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        f.write(report.csv() + "\n")
    print(f"\nwrote {args.out} ({len(report.rows)} rows)")
    if failures:
        raise SystemExit(f"failed suites: {failures}")


if __name__ == "__main__":
    main()
