"""Benchmark suite entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1] [--quick]

Writes results/bench.csv plus a machine-readable ``BENCH_<suite>.json`` per
executed suite (rows + wall time + environment metadata — the cross-PR perf
trajectory), and prints per-row CSV as it goes.  ``--quick`` shrinks each
suite to a CI/CPU smoke size: suites whose ``run`` accepts a ``quick=``
kwarg get it directly; the rest can read ``report.quick``.

Each :class:`Suite` also carries the perf-reference policy PerfGate
(``python -m repro.perfgate check``) applies when diffing a fresh run
against the committed baseline: a tuple of
:class:`repro.perfgate.references.RefSpec` declarations (first ``fnmatch``
over ``"<benchmark>.<metric>"`` wins), with the metric-name classifier in
``repro/perfgate/references.py`` supplying defaults for everything not
declared.  ``quick_invariant=True`` marks suites whose workload sizes do
not change under ``--quick`` — their relative bands gate even when the
fresh run's quick flag differs from the baseline's.
"""
from __future__ import annotations

import argparse
import dataclasses
import inspect
import os
import time
import traceback

from benchmarks.common import (
    Report,
    telemetry_delta,
    telemetry_snapshot,
    write_suite_json,
)
from repro.perfgate.references import RefSpec


@dataclasses.dataclass(frozen=True)
class Suite:
    """One registered benchmark suite + its perf-reference policy."""

    module: str
    description: str
    references: tuple[RefSpec, ...] = ()
    quick_invariant: bool = False


SUITES = {
    "fig4": Suite("benchmarks.fig4_coral_reduction",
                  "CoralTDA vertex reduction (Fig 4)"),
    "fig5a": Suite("benchmarks.fig5_prunit",
                   "PrunIT vertex reduction (Fig 5a)"),
    "fig5b": Suite("benchmarks.fig5b_ego_time",
                   "PrunIT ego-net PD0 time (Fig 5b)"),
    "table1": Suite("benchmarks.table1_large_networks",
                    "PrunIT on large networks (Table 1)"),
    "fig6": Suite("benchmarks.fig6_combined",
                  "PrunIT+CoralTDA combined (Fig 6)"),
    "fig7_9": Suite("benchmarks.fig7_9_secondary",
                    "clique/time/edge reduction (Figs 7-9)"),
    "table3": Suite("benchmarks.table3_strong_collapse",
                    "PrunIT vs Strong Collapse (Table 3)"),
    "fig2": Suite(
        "benchmarks.fig2_clustering",
        "clustering coeff vs higher PDs (Fig 2/10)",
        references=(
            RefSpec("*.kmeans_purity", "higher", rel_band=0.08,
                    note="Fig 10 clustering separation must hold"),
            RefSpec("*.ncc_holdout_accuracy", "higher", rel_band=0.08,
                    note="nearest-class-centroid holdout accuracy"),
        ),
    ),
    "kernels": Suite(
        "benchmarks.kernel_bench",
        "Pallas kernel microbenchmarks",
        quick_invariant=True,  # fixed sizes: quick runs gate too
        references=(
            RefSpec("*_converged_frac", "higher", rel_band=0.02,
                    note="auction must converge on (near) every pair"),
            RefSpec("*_pallas_speedup", "higher", rel_band=0.60,
                    note="speedup ratios compound two timings' jitter"),
        ),
    ),
    "serve": Suite(
        "benchmarks.serve_bench",
        "TopoServe throughput/latency + parity",
        references=(
            RefSpec("*.plan_cache_misses", "info",
                    note="depends on request mix, not perf"),
        ),
    ),
    "stream": Suite(
        "benchmarks.stream_bench",
        "TopoStream updates/s + skip-rate + parity",
        references=(
            RefSpec("*.skip_rate", "higher", rel_band=0.10,
                    note="reduction-certificate hit rate is the win"),
        ),
    ),
    "metrics": Suite(
        "benchmarks.metrics_bench",
        "diagram distances + Gram kernel + parity + drift",
        references=(
            RefSpec("*.recall_at_10", "higher", rel_band=0.03,
                    note="two-stage retrieval quality (CI asserts >= 0.95)"),
            RefSpec("*.rounds_mean", "lower", rel_band=0.30,
                    note="collapsed auction bidding rounds per pair — the "
                         "perf_opt target; 'rounds' is an info token so "
                         "this gate must be explicit"),
            RefSpec("*.rounds_reduction", "higher", rel_band=0.30,
                    note="expanded/collapsed rounds ratio (>= 5x asserted "
                         "in-bench)"),
            RefSpec("*.warm_hit_rate", "higher", rel_band=0.10,
                    note="price-cache warm-start hit rate on repeated "
                         "stage1 exact drains"),
            RefSpec("*_bytes*", "lower", rel_band=0.0,
                    note="analytic working-set sizes; any growth is an "
                         "algorithmic change, not jitter"),
            RefSpec("*.speedup_vs_exhaustive", "higher", rel_band=0.60,
                    note="two-stage vs exhaustive ratio"),
        ),
    ),
    "index": Suite(
        "benchmarks.index_bench",
        "ShardedIndex scaling + Hamming kernel + retrieval recall",
        references=(
            RefSpec("*.scan_throughput", "higher", rel_band=0.60,
                    note="critical-path coarse-scan rate; host timing "
                         "jitter compounds with interpret-mode overhead"),
            RefSpec("*.recall_at_10", "higher", rel_band=0.02,
                    note="sharded two-stage retrieval quality "
                         "(in-bench assert >= 0.98)"),
            RefSpec("*.merge_seconds", "lower", rel_band=0.60,
                    note="host merge of per-shard top-m survivors — the "
                         "only serial stage of the sharded scan"),
            RefSpec("*.kernel_speedup", "higher", rel_band=0.60,
                    note="Pallas-vs-host ratio compounds two timings"),
            RefSpec("*_scan_speedup", "higher", rel_band=0.30,
                    note="4-shard critical-path scaling (>= 3x asserted "
                         "in-bench on a >= 4-device mesh)"),
        ),
    ),
    "reduction": Suite(
        "benchmarks.reduction_bench",
        "ReductionEngine two-phase repack win + reduction ratio + parity",
        references=(
            RefSpec("*_reduction_pct", "higher", rel_band=0.05,
                    note="paper's reduction ratios are structural, "
                         "not timing-jittery"),
            RefSpec("*.persist_speedup", "higher", rel_band=0.60),
            RefSpec("*.total_speedup", "higher", rel_band=0.60),
        ),
    ),
}


def _call_suite(mod, report: Report, quick: bool) -> None:
    """Invoke ``mod.run`` threading --quick through to suites that take it."""
    if "quick" in inspect.signature(mod.run).parameters:
        mod.run(report, quick=quick)
    else:
        mod.run(report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="small suite sizes (CI / CPU smoke)")
    ap.add_argument("--out", default="results/bench.csv")
    args = ap.parse_args()

    keys = args.only.split(",") if args.only else list(SUITES)
    unknown = [k for k in keys if k not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; known: {list(SUITES)}")
    out_dir = os.path.dirname(args.out) or "."
    report = Report(quick=args.quick)
    failures = []
    for k in keys:
        suite = SUITES[k]
        print(f"[bench] {k}: {suite.description}", flush=True)
        row_start = len(report.rows)
        t0 = time.time()
        tele0 = telemetry_snapshot()
        ok = True
        try:
            mod = __import__(suite.module, fromlist=["run"])
            _call_suite(mod, report, args.quick)
            print(f"[bench] {k} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(k)
            ok = False
            traceback.print_exc()
        # TopoScope telemetry block: registry movement attributable to this
        # suite (plan-cache traffic, kernel/metric call counts) — stamped as
        # rows too, so PerfGate baselines track call-count regressions
        telemetry = telemetry_delta(tele0)
        for metric, value in sorted(telemetry.items()):
            report.add("telemetry", metric, value)
        write_suite_json(out_dir, k, suite.description,
                         report.rows[row_start:],
                         wall_s=time.time() - t0, quick=args.quick, ok=ok,
                         telemetry=telemetry)
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        f.write(report.csv() + "\n")
    print(f"\nwrote {args.out} ({len(report.rows)} rows) "
          f"+ BENCH_<suite>.json per suite")
    if failures:
        raise SystemExit(f"failed suites: {failures}")


if __name__ == "__main__":
    main()
