"""Benchmark suite entry point: one module per paper table/figure.

  PYTHONPATH=src python -m benchmarks.run [--only fig4,table1] [--quick]

Writes results/bench.csv plus a machine-readable ``BENCH_<suite>.json`` per
executed suite (rows + wall time + environment metadata — the cross-PR perf
trajectory), and prints per-row CSV as it goes.  ``--quick`` shrinks each
suite to a CI/CPU smoke size: suites whose ``run`` accepts a ``quick=``
kwarg get it directly; the rest can read ``report.quick``.
"""
from __future__ import annotations

import argparse
import inspect
import os
import time
import traceback

from benchmarks.common import Report, write_suite_json

SUITES = {
    "fig4": ("benchmarks.fig4_coral_reduction", "CoralTDA vertex reduction (Fig 4)"),
    "fig5a": ("benchmarks.fig5_prunit", "PrunIT vertex reduction (Fig 5a)"),
    "fig5b": ("benchmarks.fig5b_ego_time", "PrunIT ego-net PD0 time (Fig 5b)"),
    "table1": ("benchmarks.table1_large_networks", "PrunIT on large networks (Table 1)"),
    "fig6": ("benchmarks.fig6_combined", "PrunIT+CoralTDA combined (Fig 6)"),
    "fig7_9": ("benchmarks.fig7_9_secondary", "clique/time/edge reduction (Figs 7-9)"),
    "table3": ("benchmarks.table3_strong_collapse", "PrunIT vs Strong Collapse (Table 3)"),
    "fig2": ("benchmarks.fig2_clustering", "clustering coeff vs higher PDs (Fig 2/10)"),
    "kernels": ("benchmarks.kernel_bench", "Pallas kernel microbenchmarks"),
    "serve": ("benchmarks.serve_bench", "TopoServe throughput/latency + parity"),
    "stream": ("benchmarks.stream_bench", "TopoStream updates/s + skip-rate + parity"),
    "metrics": ("benchmarks.metrics_bench", "diagram distances + Gram kernel + parity + drift"),
    "reduction": ("benchmarks.reduction_bench",
                  "ReductionEngine two-phase repack win + reduction ratio + parity"),
}


def _call_suite(mod, report: Report, quick: bool) -> None:
    """Invoke ``mod.run`` threading --quick through to suites that take it."""
    if "quick" in inspect.signature(mod.run).parameters:
        mod.run(report, quick=quick)
    else:
        mod.run(report)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated suite keys (default: all)")
    ap.add_argument("--quick", action="store_true",
                    help="small suite sizes (CI / CPU smoke)")
    ap.add_argument("--out", default="results/bench.csv")
    args = ap.parse_args()

    keys = args.only.split(",") if args.only else list(SUITES)
    unknown = [k for k in keys if k not in SUITES]
    if unknown:
        raise SystemExit(f"unknown suites {unknown}; known: {list(SUITES)}")
    out_dir = os.path.dirname(args.out) or "."
    report = Report(quick=args.quick)
    failures = []
    for k in keys:
        mod_name, desc = SUITES[k]
        print(f"[bench] {k}: {desc}", flush=True)
        row_start = len(report.rows)
        t0 = time.time()
        ok = True
        try:
            mod = __import__(mod_name, fromlist=["run"])
            _call_suite(mod, report, args.quick)
            print(f"[bench] {k} done in {time.time()-t0:.1f}s", flush=True)
        except Exception:
            failures.append(k)
            ok = False
            traceback.print_exc()
        write_suite_json(out_dir, k, desc, report.rows[row_start:],
                         wall_s=time.time() - t0, quick=args.quick, ok=ok)
    os.makedirs(out_dir, exist_ok=True)
    with open(args.out, "w") as f:
        f.write(report.csv() + "\n")
    print(f"\nwrote {args.out} ({len(report.rows)} rows) "
          f"+ BENCH_<suite>.json per suite")
    if failures:
        raise SystemExit(f"failed suites: {failures}")


if __name__ == "__main__":
    main()
