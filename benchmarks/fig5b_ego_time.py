"""Paper Fig 5b: PrunIT time reduction for 0-dim PDs of OGB-style ego
networks.  Host graph is a BA surrogate (citation-graph regime); all ego nets
are extracted and their PD0 computed with and without PrunIT, timing the full
pipeline (find+remove dominated vertices, induced graph, PD) per the paper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report, timed
from repro.core.api import topological_signature
from repro.data import graphs as gdata
from repro.data.ego import ego_batch


def run(report: Report, n_host: int = 192, n_pad: int = 64) -> None:
    key = jax.random.PRNGKey(3)
    host = gdata.barabasi_albert(key, 1, n_host, n_host, 3)
    f = host.degrees()[0].astype(jnp.float32)
    egos = ego_batch(host.adj[0], f, n_pad=n_pad)

    def pd0(method):
        return topological_signature(
            egos, dim=0, method=method, sublevel=False,
            edge_cap=192, tri_cap=8)

    _, t_none = timed(pd0, "none")
    d, t_prun = timed(pd0, "prunit")
    report.add("fig5b_ego", "pd0_time_none_s", t_none)
    report.add("fig5b_ego", "pd0_time_prunit_s", t_prun)
    report.add("fig5b_ego", "time_reduction_pct",
               100.0 * (t_none - t_prun) / t_none)
    report.add("fig5b_ego", "n_egos", egos.batch)


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
