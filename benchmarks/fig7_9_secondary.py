"""Paper Figs 7-9: CoralTDA clique-count, time, and edge reduction."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report, pct, timed
from repro.core.api import reduction_stats, topological_signature
from repro.core.kcore import coral_reduce
from repro.core.persistence_ref import simplex_count
from repro.data import graphs as gdata

DATASETS = ("DHFR", "ENZYMES", "PROTEINS", "SYNNEW")


def run(report: Report, batch: int = 16, ks=(1, 2, 3)) -> None:
    key = jax.random.PRNGKey(17)
    for name in DATASETS:
        g = gdata.load_dataset(name, key, batch=batch)
        for k in ks:
            # edge reduction (Fig 9)
            st = reduction_stats(g, dim=k, method="coral")
            report.add("fig9_edges", f"{name}_k{k}_E_reduction_pct",
                       float(jnp.mean(st.e_reduction_pct())))
            # clique/simplex count reduction (Fig 7) — host-side oracle count
            gr = coral_reduce(g, k)
            s_before = sum(
                simplex_count(np.asarray(g.adj[i]), np.asarray(g.mask[i]),
                              max_dim=min(k + 1, 2))
                for i in range(min(4, g.batch)))
            s_after = sum(
                simplex_count(np.asarray(gr.adj[i]), np.asarray(gr.mask[i]),
                              max_dim=min(k + 1, 2))
                for i in range(min(4, g.batch)))
            report.add("fig7_simplices", f"{name}_k{k}_simplex_reduction_pct",
                       pct(s_before, s_after))
        # time reduction (Fig 8) at k=1
        def pd(gg):
            return topological_signature(gg, dim=1, method="none",
                                         edge_cap=128, tri_cap=128)

        g1 = coral_reduce(g, 1)
        _, t_full = timed(pd, g)
        _, t_red = timed(pd, g1)
        report.add("fig8_time", f"{name}_k1_time_reduction_pct",
                   100.0 * (t_full - t_red) / max(t_full, 1e-9))


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
