"""Paper Table 1: PrunIT vertex/edge reduction on SNAP large networks
(scaled surrogates matched on family + average degree; see DESIGN.md §8)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report
from repro.core.api import reduction_stats
from repro.data import graphs as gdata


def run(report: Report, n_pad: int = 1024) -> None:
    key = jax.random.PRNGKey(11)
    for name in gdata.TABLE1:
        g = gdata.load_large_network(name, jax.random.fold_in(key, 1), n_pad=n_pad)
        st = reduction_stats(g, dim=0, method="prunit", sublevel=False)
        report.add("table1_large", f"{name}_V_reduction_pct",
                   float(jnp.mean(st.v_reduction_pct())))
        report.add("table1_large", f"{name}_E_reduction_pct",
                   float(jnp.mean(st.e_reduction_pct())))


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
