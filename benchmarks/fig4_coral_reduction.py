"""Paper Fig 4: CoralTDA vertex reduction on graph/node classification
datasets, for PD_k with k = 1..5 (reduction = 100·(|V|-|V^{k+1}|)/|V|)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import Report
from repro.core.api import reduction_stats
from repro.data import graphs as gdata

DATASETS = ("DHFR", "ENZYMES", "NCI1", "PROTEINS", "SYNNEW", "OHSU",
            "TWITTER", "FACEBOOK", "CORA", "CITESEER")


def run(report: Report, batch: int = 32, ks=(1, 2, 3, 4, 5)) -> None:
    key = jax.random.PRNGKey(42)
    for name in DATASETS:
        g = gdata.load_dataset(name, key, batch=batch)
        for k in ks:
            st = reduction_stats(g, dim=k, method="coral")
            v = float(jnp.mean(st.v_reduction_pct()))
            report.add("fig4_coral", f"{name}_k{k}_vertex_reduction_pct", v)


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
