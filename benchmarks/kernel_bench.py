"""Kernel-level microbenchmarks: Pallas (interpret on CPU) vs pure-jnp
reference, plus the jnp path that production uses on CPU.  On TPU the same
harness times the compiled kernels.  Shapes swept over the regimes the TDA
pipeline uses (B small-N graphs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report, timed
from repro.core.prunit import domination_matrix
from repro.core.kcore import kcore_mask
from repro.data import graphs as gdata
from repro.kernels import ops, ref


def run(report: Report) -> None:
    key = jax.random.PRNGKey(5)
    for (b, n) in ((32, 128), (8, 256)):
        g = gdata.erdos_renyi(key, b, n, n, 0.08)
        _, t_jnp = timed(jax.jit(domination_matrix), g.adj, g.mask)
        report.add("kernel_domination", f"B{b}_N{n}_jnp_s", t_jnp)
        _, t_pal = timed(lambda a, m: ops.domination(a, m), g.adj, g.mask)
        report.add("kernel_domination", f"B{b}_N{n}_pallas_interp_s", t_pal)

        _, t_kc = timed(jax.jit(lambda a, m: kcore_mask(a, m, 3)), g.adj, g.mask)
        report.add("kernel_kcore", f"B{b}_N{n}_jnp_s", t_kc)

        _, t_cn = timed(lambda a: ops.common_neighbors(a), g.adj)
        report.add("kernel_common_neighbors", f"B{b}_N{n}_pallas_interp_s", t_cn)

    # pairwise-L1 Gram over SW embeddings (TopoIndex's distance matrix);
    # interpret-mode fallback keeps this runnable on CPU CI
    bench_pairwise_gram(report, "kernel_pairwise_gram",
                        ((64, 256), (128, 512)))


def bench_pairwise_gram(report: Report, bench: str, sizes) -> float:
    """Time jnp vs Pallas pairwise-L1 Gram on random embeddings.

    Shared with the metrics suite (benchmarks/metrics_bench.py) so the
    microbench has one definition; returns the worst abs deviation seen
    (callers may assert fp32 parity on it).
    """
    kg = jax.random.PRNGKey(7)
    worst = 0.0
    for (m, d) in sizes:
        x = jax.random.normal(kg, (m, d), jnp.float32)
        gram, t_jnp = timed(jax.jit(ref.pairwise_l1_ref), x, x)
        gram_p, t_pal = timed(lambda a: ops.pairwise_l1(a, a), x)
        diff = float(jnp.max(jnp.abs(gram - gram_p)))
        worst = max(worst, diff)
        report.add(bench, f"G{m}_D{d}_jnp_s", t_jnp)
        report.add(bench, f"G{m}_D{d}_pallas_s", t_pal)
        report.add(bench, f"G{m}_D{d}_pallas_speedup", t_jnp / max(t_pal, 1e-9))
        report.add(bench, f"G{m}_D{d}_max_abs_diff", diff)
    return worst


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
