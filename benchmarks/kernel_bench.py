"""Kernel-level microbenchmarks: Pallas (interpret on CPU) vs pure-jnp
reference, plus the jnp path that production uses on CPU.  On TPU the same
harness times the compiled kernels.  Shapes swept over the regimes the TDA
pipeline uses (B small-N graphs)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import Report, timed
from repro.core.prunit import domination_matrix
from repro.core.kcore import kcore_mask
from repro.data import graphs as gdata
from repro.kernels import ops, ref


def run(report: Report) -> None:
    key = jax.random.PRNGKey(5)
    for (b, n) in ((32, 128), (8, 256)):
        g = gdata.erdos_renyi(key, b, n, n, 0.08)
        _, t_jnp = timed(jax.jit(domination_matrix), g.adj, g.mask)
        report.add("kernel_domination", f"B{b}_N{n}_jnp_s", t_jnp)
        _, t_pal = timed(lambda a, m: ops.domination(a, m), g.adj, g.mask)
        report.add("kernel_domination", f"B{b}_N{n}_pallas_interp_s", t_pal)

        _, t_kc = timed(jax.jit(lambda a, m: kcore_mask(a, m, 3)), g.adj, g.mask)
        report.add("kernel_kcore", f"B{b}_N{n}_jnp_s", t_kc)

        _, t_cn = timed(lambda a: ops.common_neighbors(a), g.adj)
        report.add("kernel_common_neighbors", f"B{b}_N{n}_pallas_interp_s", t_cn)

    # pairwise-L1 Gram over SW embeddings (TopoIndex's distance matrix);
    # interpret-mode fallback keeps this runnable on CPU CI
    bench_pairwise_gram(report, "kernel_pairwise_gram",
                        ((64, 256), (128, 512)))

    bench_auction_lap(report)
    bench_auction_collapsed(report)
    bench_sinkhorn_lse(report)


def bench_auction_lap(report: Report) -> None:
    """Batched auction-LAP kernel vs its jnp oracle on random costs."""
    kg = jax.random.PRNGKey(9)
    for (b, m) in ((64, 32), (256, 32)):
        c = jax.random.uniform(kg, (b, m, m), jnp.float32, 0.0, 5.0)
        (_, tot, conv, _), t = timed(ops.auction_lap, c, repeats=1)
        _, tot_ref, _, _ = jax.vmap(ref.auction_lap_ref)(c)
        diff = float(jnp.max(jnp.abs(tot - tot_ref)))
        report.add("kernel_auction_lap", f"B{b}_M{m}_pallas_s", t)
        report.add("kernel_auction_lap", f"B{b}_M{m}_solves_per_s",
                   b / max(t, 1e-9))
        report.add("kernel_auction_lap", f"B{b}_M{m}_converged_frac",
                   float(jnp.mean(conv)))
        report.add("kernel_auction_lap", f"B{b}_M{m}_ref_max_abs_diff", diff)


def bench_auction_collapsed(report: Report) -> None:
    """Collapsed forward/reverse auction kernel vs its jnp oracle.

    Random reduced-cost problems (cbar = pp − diag1 − diag2 over partially
    masked slots) — the K×K formulation the exact_w backend solves with
    ``collapse="on"``.  Kernel-vs-ref parity is semantic (same solver);
    optimality vs Hungarian is asserted in metrics_bench / tests.
    """
    kg = jax.random.PRNGKey(11)
    for (b, k) in ((64, 16), (256, 16)):
        ks = jax.random.split(kg, 5)
        kg = ks[0]
        pp = jax.random.uniform(ks[1], (b, k, k), jnp.float32, 0.0, 4.0)
        d1 = jax.random.uniform(ks[2], (b, k), jnp.float32, 0.0, 2.0)
        d2 = jax.random.uniform(ks[3], (b, k), jnp.float32, 0.0, 2.0)
        nreal = jax.random.randint(ks[4], (b, 2), k // 2, k + 1)
        idx = jnp.arange(k)
        keep1 = idx[None, :] < nreal[:, :1]
        keep2 = idx[None, :] < nreal[:, 1:]
        valid = keep1[:, :, None] & keep2[:, None, :]
        cbar = jnp.where(valid, pp - d1[:, :, None] - d2[:, None, :], 0.0)
        (_, tot, conv, rounds, _), t = timed(
            ops.auction_lap_collapsed, cbar, keep1, keep2, repeats=1)
        _, tot_ref, _, _, _ = jax.vmap(ref.auction_lap_collapsed_ref)(
            cbar, keep1, keep2)
        diff = float(jnp.max(jnp.abs(tot - tot_ref)))
        report.add("kernel_auction_collapsed", f"B{b}_K{k}_pallas_s", t)
        report.add("kernel_auction_collapsed", f"B{b}_K{k}_solves_per_s",
                   b / max(t, 1e-9))
        report.add("kernel_auction_collapsed", f"B{b}_K{k}_converged_frac",
                   float(jnp.mean(conv)))
        report.add("kernel_auction_collapsed", f"B{b}_K{k}_rounds_mean",
                   float(jnp.mean(rounds)))
        report.add("kernel_auction_collapsed",
                   f"B{b}_K{k}_ref_max_abs_diff", diff)


def bench_sinkhorn_lse(report: Report) -> None:
    """Blocked LSE kernel vs its dense jnp oracle (one half-update)."""
    from repro.metrics.distances import _cloud_planes

    kg = jax.random.PRNGKey(11)
    for (b, m) in ((8, 256), (4, 1024)):
        ks = jax.random.split(kg, 4)
        x = jax.random.normal(ks[0], (b, m, 2), jnp.float32)
        y = jax.random.normal(ks[1], (b, m, 2), jnp.float32)
        flags = jnp.arange(m) >= m // 2
        xp, yp = _cloud_planes(x, flags), _cloud_planes(y, flags)
        dual = jax.random.normal(ks[2], (b, m), jnp.float32)
        logw = jnp.where(jax.random.uniform(ks[3], (b, m)) > 0.1,
                         0.0, -jnp.inf)
        e_t = jnp.full((b, 1), 0.5, jnp.float32)
        got, t = timed(ops.sinkhorn_lse, xp, yp, dual, logw, e_t, repeats=1)
        want, t_ref = timed(jax.jit(ref.sinkhorn_lse_ref),
                            xp, yp, dual, logw, e_t, repeats=1)
        diff = float(jnp.max(jnp.abs(got - want)))
        report.add("kernel_sinkhorn_lse", f"B{b}_M{m}_pallas_s", t)
        report.add("kernel_sinkhorn_lse", f"B{b}_M{m}_jnp_s", t_ref)
        report.add("kernel_sinkhorn_lse", f"B{b}_M{m}_max_abs_diff", diff)


def bench_pairwise_gram(report: Report, bench: str, sizes) -> float:
    """Time jnp vs Pallas pairwise-L1 Gram on random embeddings.

    Shared with the metrics suite (benchmarks/metrics_bench.py) so the
    microbench has one definition; returns the worst abs deviation seen
    (callers may assert fp32 parity on it).
    """
    kg = jax.random.PRNGKey(7)
    worst = 0.0
    for (m, d) in sizes:
        x = jax.random.normal(kg, (m, d), jnp.float32)
        gram, t_jnp = timed(jax.jit(ref.pairwise_l1_ref), x, x)
        gram_p, t_pal = timed(lambda a: ops.pairwise_l1(a, a), x)
        diff = float(jnp.max(jnp.abs(gram - gram_p)))
        worst = max(worst, diff)
        report.add(bench, f"G{m}_D{d}_jnp_s", t_jnp)
        report.add(bench, f"G{m}_D{d}_pallas_s", t_pal)
        report.add(bench, f"G{m}_D{d}_pallas_speedup", t_jnp / max(t_pal, 1e-9))
        report.add(bench, f"G{m}_D{d}_max_abs_diff", diff)
    return worst


if __name__ == "__main__":
    r = Report()
    run(r)
    print(r.csv())
