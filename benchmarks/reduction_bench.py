"""ReductionEngine benchmark: two-phase repack win, reduction ratio, parity.

The tentpole claim of the two-phase refactor (repro/core/api.py,
``repack="on"``): the paper's reductions shrink graphs by up to ~95%, and
re-bucketing every reduced graph into a small :class:`ShapeClass` lets the
expensive GF(2) persist stage compile and run at *reduced* size instead of
input size.  This suite measures exactly that, on the two workloads where
the reductions bite hardest:

* **ego_decay** — the hub-dominated ego-net regime (paper §6.2): PrunIT
  collapses satellites, coral trims the periphery;
* **coral_heavy** — sparse ER cores with a heavy satellite tail (the
  Table 1 degree-distribution regime): most vertices fall out of the 2-core.

Reported per workload: vertex/edge reduction ratios, the post-reduction
rung histogram, single-phase wall time vs two-phase reduce/persist split,
``persist_speedup`` (single-phase pipeline time over the two-phase persist
phase — the acceptance metric, asserted >= 1.5x) and ``total_speedup``
(including the reduce phase + host repack), plus a pair-level parity sweep:
every graph's persistence pairs in every guaranteed dimension must be
bit-identical between ``repack="off"`` (the oracle) and ``"on"``.

A serve-level section drains star/ego queries spanning three input buckets
through a ``repack="on"`` TopoServe and reports how many persist rungs are
shared by >1 input bucket (asserted >= 1) together with the plan-cache
delta — reduced-size persist plans are process-wide shared artifacts.

  PYTHONPATH=src python -m benchmarks.reduction_bench [--quick]
  PYTHONPATH=src python -m benchmarks.run --only reduction [--quick]
"""
from __future__ import annotations

import argparse
import time

import jax
import numpy as np

from benchmarks.common import Report, timed, write_suite_json
from repro.core.api import make_topo_plan, plan_cache_info
from repro.core.persistence_jax import diagrams_to_numpy
from repro.data.graphs import attach_satellites, erdos_renyi
from repro.data.temporal import ego_decay_stream
from repro.serve import TopoServe, TopoServeConfig

CAPS = dict(edge_cap=320, tri_cap=512)  # the serve ladder's n64 rung


def _pairs(d, b, k):
    return diagrams_to_numpy(d, b, max_dim=k)[k]


def _workloads(quick: bool):
    b = 16 if quick else 64
    key = jax.random.PRNGKey(7)
    k_ego, k_er, k_sat = jax.random.split(key, 3)
    g_ego, _ = ego_decay_stream(k_ego, batch=b, n_pad=64, n_core=12,
                                n_double=12, n_pendant=12, steps=1)
    core = erdos_renyi(k_er, batch=b, n_pad=64, n_vertices=56, p=0.05)
    g_coral = attach_satellites(k_sat, core, frac=0.5)
    return [("ego_decay", g_ego), ("coral_heavy", g_coral)]


def _bench_workload(report: Report, tag: str, g, dims: tuple[int, ...]):
    single = make_topo_plan(dim=1, method="both", **CAPS)
    two = make_topo_plan(dim=1, method="both", repack="on", **CAPS)

    # warmup (compiles every touched rung) + repack accounting
    d_on, info = two.execute_info(g)
    jax.block_until_ready(d_on.birth)
    hist = info.rung_histogram()
    v0 = float(np.maximum(g.n_vertices().sum(), 1))
    e0 = float(np.maximum(g.n_edges().sum(), 1))
    report.add(tag, "v_reduction_pct",
               100.0 * (v0 - float(info.n_vertices.sum())) / v0)
    report.add(tag, "e_reduction_pct",
               100.0 * (e0 - float(info.n_edges.sum())) / e0)
    for n_pad, count in sorted(hist.items()):
        report.add(tag, f"rung_n{n_pad}_graphs", count)

    d_off, t_single = timed(single.execute, g)
    _, t_reduce = timed(two.reduce_executor, g)
    _, t_total = timed(two.execute, g)
    t_persist = max(t_total - t_reduce, 1e-9)
    report.add(tag, "single_phase_ms", t_single * 1e3)
    report.add(tag, "two_phase_reduce_ms", t_reduce * 1e3)
    report.add(tag, "two_phase_persist_ms", t_persist * 1e3)
    report.add(tag, "two_phase_total_ms", t_total * 1e3)
    persist_speedup = t_single / t_persist
    report.add(tag, "persist_speedup", persist_speedup)
    report.add(tag, "total_speedup", t_single / max(t_total, 1e-9))

    checked = mismatches = 0
    for bi in range(g.batch):
        for k in dims:
            checked += 1
            if _pairs(d_off, bi, k) != _pairs(d_on, bi, k):
                mismatches += 1
    report.add(tag, "parity_checked", checked)
    report.add(tag, "parity_mismatches", mismatches)
    return checked, mismatches, persist_speedup


def _serve_sharing(report: Report, quick: bool):
    """Drain queries spanning buckets through repack='on' TopoServe; count
    persist rungs shared across input buckets + the plan-cache delta."""
    import networkx as nx

    cache_before = plan_cache_info()
    srv = TopoServe(TopoServeConfig(dim=1, method="both", repack="on"))
    rng = np.random.default_rng(3)
    n_q = 24 if quick else 96
    futs = []
    for i in range(n_q):
        kind = i % 3
        if kind == 0:       # n16 bucket, collapses to a point
            g = nx.star_graph(int(rng.integers(6, 14)))
        elif kind == 1:     # n32 bucket
            g = nx.star_graph(int(rng.integers(17, 30)))
        else:               # n64 bucket, ego-like: hub + sparse core
            g = nx.gnp_random_graph(40, 0.06, seed=int(rng.integers(2**31)))
            hub = 0
            g.add_edges_from((hub, v) for v in range(1, 20))
        nodes = sorted(g.nodes())
        idx = {u: j for j, u in enumerate(nodes)}
        futs.append(srv.submit(
            edges=[(idx[u], idx[v]) for (u, v) in g.edges()],
            n_vertices=len(nodes)))
    srv.drain()
    for f in futs:
        f.result()
    rungs_by_bucket: dict[int, set] = {}
    for (bucket_n, rung_n), cnt in srv.stats["repack_rungs"].items():
        rungs_by_bucket.setdefault(rung_n, set()).add(bucket_n)
    shared = sum(1 for buckets in rungs_by_bucket.values() if len(buckets) > 1)
    info = plan_cache_info()
    report.add("reduction_serve", "queries", n_q)
    report.add("reduction_serve", "input_buckets",
               len({f.bucket for f in futs}))
    report.add("reduction_serve", "persist_rungs_used", len(rungs_by_bucket))
    report.add("reduction_serve", "shared_persist_rungs", shared)
    report.add("reduction_serve", "plan_cache_hits",
               info["hits"] - cache_before["hits"])
    report.add("reduction_serve", "plan_cache_misses",
               info["misses"] - cache_before["misses"])
    return shared


def run(report: Report, quick: bool = False) -> None:
    totals = {"checked": 0, "mismatches": 0}
    speedups = {}
    for tag, g in _workloads(quick):
        # method="both" includes coral: only the target dimension is
        # guaranteed (dims < 1 may legitimately differ after (dim+1)-core)
        c, m, s = _bench_workload(report, tag, g, dims=(1,))
        totals["checked"] += c
        totals["mismatches"] += m
        speedups[tag] = s

    shared = _serve_sharing(report, quick)

    report.add("reduction", "parity_checked", totals["checked"])
    report.add("reduction", "parity_mismatches", totals["mismatches"])
    if totals["mismatches"]:
        raise AssertionError(
            f"{totals['mismatches']}/{totals['checked']} two-phase diagrams "
            "differ from single-phase (repack='off') output")
    slow = {t: s for t, s in speedups.items() if s < 1.5}
    if slow:
        raise AssertionError(
            f"persist-phase speedup below the 1.5x acceptance bar: {slow}")
    if shared < 1:
        raise AssertionError(
            "no persist rung was shared across serve buckets — reduced-size "
            "persist plans are not being deduplicated")
    print(f"[reduction_bench] parity OK: {totals['checked']} diagram "
          f"comparisons bit-identical; persist speedups "
          + ", ".join(f"{t}={s:.1f}x" for t, s in speedups.items())
          + f"; {shared} persist rung(s) shared across serve buckets")


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="small workloads (CI / CPU smoke)")
    ap.add_argument("--out-dir", default="results",
                    help="directory for BENCH_reduction.json")
    args = ap.parse_args()
    report = Report(quick=args.quick)
    t0 = time.time()
    ok = True
    try:
        run(report, quick=args.quick)
    except Exception:
        ok = False
        raise
    finally:
        path = write_suite_json(
            args.out_dir, "reduction",
            "ReductionEngine two-phase repack win + reduction ratio + parity",
            report.rows, wall_s=time.time() - t0, quick=args.quick, ok=ok)
        print(f"wrote {path}")
    print(report.csv())


if __name__ == "__main__":
    main()
