"""StreamServe: session lifecycle, FIFO drains, counter surface, failures."""
import threading

import jax
import pytest

from repro.core.delta import EDGE_INSERT, delta_from_lists, delta_step
from repro.core.graph import from_edge_lists
from repro.data.temporal import ego_decay_stream
from repro.serve import StreamServe
from repro.stream import TopoStreamConfig, dim_pairs

CFG = TopoStreamConfig(dim=1, method="both", edge_cap=192, tri_cap=512)


def _square_batch(b=1):
    return from_edge_lists([[(0, 1), (1, 2), (2, 3), (3, 0)]] * b,
                           [4] * b, n_pad=8)


def test_session_flow_fresh_and_cached():
    srv = StreamServe(TopoStreamConfig(dim=1, method="both", edge_cap=48,
                                       tri_cap=96))
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0), (0, 3)]], [4], n_pad=8)
    sid = srv.create_session(g)
    assert dim_pairs(srv.diagrams(sid), 0, 1) == []
    # pendant delete -> cache hit; diagonal insert -> recompute
    f1 = srv.submit(sid, delta_from_lists([[(0, 3, "delete")]]))
    f2 = srv.submit(sid, delta_from_lists([[(1, 3, EDGE_INSERT),
                                            (2, 3, EDGE_INSERT)]]))
    assert srv.pending() == 2
    assert srv.drain() == 2
    assert f1.info == {"graph_updates": 1, "hits": 1, "coral_hits": 1,
                       "prunit_hits": 0, "recomputes": 0, "anomalies": 0}
    assert f2.info["recomputes"] == 1
    st = srv.session_stats(sid)
    assert st["hits"] == 1 and st["recomputes"] == 1
    assert 0.0 < st["skip_rate"] < 1.0


def test_sessions_are_independent():
    srv = StreamServe(TopoStreamConfig(dim=1, method="both", edge_cap=48,
                                       tri_cap=96))
    s1 = srv.create_session(_square_batch())
    s2 = srv.create_session(_square_batch())
    srv.submit(s1, delta_from_lists([[(0, 2, EDGE_INSERT)]]))
    srv.drain()
    assert dim_pairs(srv.diagrams(s1), 0, 1) != dim_pairs(srv.diagrams(s2), 0, 1)
    agg = srv.stats()
    assert agg["sessions"] == 2 and agg["graph_updates"] == 1
    srv.close_session(s1)
    assert srv.stats()["sessions"] == 1
    assert srv.stats()["sessions_closed"] == 1
    assert srv.stats()["graph_updates"] == 1  # closed stats folded in
    with pytest.raises(KeyError):
        srv.diagrams(s1)


def test_submit_validation():
    srv = StreamServe(CFG)
    g0, deltas = ego_decay_stream(jax.random.PRNGKey(0), batch=2, n_pad=32,
                                  n_core=10, n_double=6, n_pendant=6, steps=3)
    sid = srv.create_session(g0)
    with pytest.raises(ValueError, match="one update step"):
        srv.submit(sid, deltas)  # stacked stream, not a step
    bad = delta_from_lists([[(0, 1, EDGE_INSERT)]] * 5)  # wrong batch
    with pytest.raises(ValueError, match="batch"):
        srv.submit(sid, bad)
    with pytest.raises(KeyError):
        srv.submit("s999", delta_step(deltas, 0))


def test_drain_applies_temporal_stream_in_order():
    srv = StreamServe(CFG)
    g0, deltas = ego_decay_stream(jax.random.PRNGKey(1), batch=2, n_pad=32,
                                  n_core=10, n_double=6, n_pendant=6,
                                  steps=6, toggles=1)
    sid = srv.create_session(g0)
    futs = [srv.submit(sid, delta_step(deltas, t)) for t in range(6)]
    assert srv.drain() == 6
    assert all(f.done() for f in futs)
    agg = srv.stats()
    assert agg["graph_updates"] == sum(f.info["graph_updates"] for f in futs)
    assert agg["hits"] > 0


def test_background_serve_forever_thread():
    srv = StreamServe(CFG)
    g0, deltas = ego_decay_stream(jax.random.PRNGKey(2), batch=2, n_pad=32,
                                  n_core=10, n_double=6, n_pendant=6,
                                  steps=4, toggles=1)
    sid = srv.create_session(g0)
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        futs = [srv.submit(sid, delta_step(deltas, i)) for i in range(4)]
        for f in futs:
            f.result(timeout=120)
    finally:
        srv.stop()
        t.join(timeout=10)
    assert not t.is_alive()
    assert srv.session_stats(sid)["applied"] == 4


def test_failed_step_fails_dependent_futures():
    # an update that overflows the session caps must fail its future AND the
    # later queued futures of that session (their base state is undefined)
    srv = StreamServe(TopoStreamConfig(dim=1, method="none", edge_cap=4,
                                       tri_cap=8))
    sid = srv.create_session(_square_batch())
    big = delta_from_lists([[(0, 2, EDGE_INSERT), (1, 3, EDGE_INSERT)]])
    ok_before = srv.submit(sid, delta_from_lists([[(0, 1, "delete")]]))
    bad = srv.submit(sid, big)
    after = srv.submit(sid, delta_from_lists([[(0, 1, EDGE_INSERT)]]))
    srv.drain()
    ok_before.result(timeout=1)  # applied before the failure
    with pytest.raises(ValueError, match="simplex caps"):
        bad.result(timeout=1)
    with pytest.raises(ValueError, match="simplex caps"):
        after.result(timeout=1)


def test_drift_surface_in_step_info():
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=48, tri_cap=96,
                           drift_metric="sw", drift_threshold=0.5)
    srv = StreamServe(cfg)
    g = from_edge_lists([[(0, 1), (1, 2), (2, 3)]], [4], n_pad=8)
    sid = srv.create_session(g)
    f1 = srv.submit(sid, delta_from_lists([[(0, 3, EDGE_INSERT)]]))  # cycle
    srv.drain()
    info = f1.info
    assert info["recomputes"] == 1 and info["anomalies"] == 1
    assert info["drift"].shape == (1,) and info["drift"][0] > 0.5
    assert info["anomaly"].tolist() == [True]
    assert srv.session_stats(sid)["anomalies"] == 1
    assert srv.stats()["anomalies"] == 1
    # quiet step: no structural change -> zero drift, no anomaly
    f2 = srv.submit(sid, delta_from_lists([[(0, 3, EDGE_INSERT)]]))  # no-op
    srv.drain()
    assert f2.info["drift"].tolist() == [0.0]
    assert f2.info["anomalies"] == 0
