"""TopoStream: delta semantics, invalidation predicates, incremental parity.

The parity contract mirrors serve_bench's: incremental maintenance is a
scheduling decision, never a numerics change — after every update the
streamed diagram's pairs in every guaranteed dimension must equal a
from-scratch ``topological_signature`` on the current graph state.
"""
import numpy as np
import pytest

from conftest import hypothesis_or_stub

import jax
import jax.numpy as jnp

from repro.core import topological_signature
from repro.core.delta import (
    EDGE_DELETE,
    EDGE_INSERT,
    EDGE_NOP,
    DeltaBatch,
    apply_delta,
    canonicalize_delta,
    delta_from_lists,
    empty_delta,
)
from repro.core.graph import from_edge_lists
from repro.stream import TopoStream, TopoStreamConfig, dim_pairs

given, settings, st = hypothesis_or_stub()

CFG = dict(edge_cap=48, tri_cap=96)


def _scratch(g, cfg: TopoStreamConfig):
    return topological_signature(
        g, dim=cfg.dim, method=cfg.method, sublevel=cfg.sublevel,
        edge_cap=cfg.edge_cap, tri_cap=cfg.tri_cap, quad_cap=cfg.quad_cap)


def _assert_parity(stream, diagrams, dims):
    ref = _scratch(stream.graph, stream.config)
    for b in range(stream.graph.batch):
        for k in dims:
            assert dim_pairs(diagrams, b, k) == dim_pairs(ref, b, k), (b, k)


# ------------------------------------------------------------------- delta

def test_canonicalize_delta_invariants():
    d = DeltaBatch(
        edge_u=jnp.asarray([[3, 2, 9, 1]]),
        edge_v=jnp.asarray([[1, 2, 0, 2]]),
        edge_op=jnp.asarray([[EDGE_INSERT, EDGE_INSERT, EDGE_DELETE, EDGE_NOP]]),
        f_vertex=jnp.asarray([[7, 2]]),
        f_value=jnp.asarray([[1.0, 2.0]]),
        drop_vertex=jnp.asarray([[5, 3]]),
    )
    c = canonicalize_delta(d, n=6)
    # (3,1) ordered to u<v; self loop (2,2) -> NOP; out-of-range 9 -> NOP;
    # already-NOP slot endpoints cleared to -1
    assert c.edge_u.tolist() == [[1, -1, -1, -1]]
    assert c.edge_v.tolist() == [[3, -1, -1, -1]]
    assert c.edge_op.tolist() == [[EDGE_INSERT, EDGE_NOP, EDGE_NOP, EDGE_NOP]]
    assert c.f_vertex.tolist() == [[-1, 2]]     # 7 out of range
    assert c.drop_vertex.tolist() == [[5, 3]]


def test_apply_delta_insert_delete_and_invariants():
    g = from_edge_lists([[(0, 1), (1, 2)]], [4], n_pad=6)
    d = delta_from_lists([[(2, 3, EDGE_INSERT), (0, 1, EDGE_DELETE)]])
    g2 = apply_delta(g, d)
    a = np.asarray(g2.adj[0])
    assert not a[0, 1] and not a[1, 0]
    assert a[2, 3] and a[3, 2]
    assert np.array_equal(a, a.T) and not a.diagonal().any()
    # mask sentinels intact: no edges to padding, f=+inf outside mask
    m = np.asarray(g2.mask[0])
    assert not a[~m].any() and not a[:, ~m].any()
    assert np.isinf(np.asarray(g2.f[0])[~m]).all()


def test_apply_delta_delete_beats_insert():
    g = from_edge_lists([[(0, 1)]], [3], n_pad=4)
    d = DeltaBatch(
        edge_u=jnp.asarray([[0, 0]]), edge_v=jnp.asarray([[2, 2]]),
        edge_op=jnp.asarray([[EDGE_INSERT, EDGE_DELETE]]),
        f_vertex=jnp.full((1, 0), -1, jnp.int32),
        f_value=jnp.zeros((1, 0), jnp.float32),
        drop_vertex=jnp.full((1, 0), -1, jnp.int32),
    )
    assert not bool(apply_delta(g, d).adj[0, 0, 2])


def test_apply_delta_activates_endpoints_with_default_f():
    g = from_edge_lists([[(0, 1)]], [2], n_pad=5)
    d = delta_from_lists([[(1, 4, EDGE_INSERT)]])
    g2 = apply_delta(g, d)
    assert bool(g2.mask[0, 4]) and bool(g2.adj[0, 1, 4])
    assert float(g2.f[0, 4]) == 0.0  # activated without an f op


def test_apply_delta_drop_clears_incident_edges():
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0)]], [3], n_pad=4)
    g2 = apply_delta(g, delta_from_lists([[]], drops=[[1]], drop_slots=1))
    assert not bool(g2.mask[0, 1])
    assert not np.asarray(g2.adj[0])[1].any()
    assert np.isinf(float(g2.f[0, 1]))


def test_apply_delta_invalid_edge_ops_are_fully_dropped():
    # a malformed edge op must neither touch adjacency NOR activate an
    # endpoint: a raw self-loop insert (4, 4) on a padding vertex once
    # activated it as an isolated live vertex (silently changing PD_0)
    g = from_edge_lists([[(0, 1)]], [2], n_pad=6)
    d = DeltaBatch(
        edge_u=jnp.asarray([[4, 1, -3]]),
        edge_v=jnp.asarray([[4, 9, 2]]),
        edge_op=jnp.asarray([[EDGE_INSERT, EDGE_INSERT, EDGE_INSERT]]),
        f_vertex=jnp.full((1, 0), -1, jnp.int32),
        f_value=jnp.zeros((1, 0), jnp.float32),
        drop_vertex=jnp.full((1, 0), -1, jnp.int32),
    )
    g2 = apply_delta(g, d)
    assert np.array_equal(np.asarray(g2.mask), np.asarray(g.mask))
    assert np.array_equal(np.asarray(g2.adj), np.asarray(g.adj))
    assert np.array_equal(np.asarray(g2.f), np.asarray(g.f))


def test_apply_delta_duplicate_f_ops_last_slot_wins():
    # device-built deltas may carry duplicate f ops for one vertex; the
    # highest slot index must win deterministically (a raw scatter would be
    # backend-defined), matching delta_from_lists' host-side last-wins dedupe
    g = from_edge_lists([[(0, 1)]], [2], n_pad=4)
    d = DeltaBatch(
        edge_u=jnp.full((1, 0), -1, jnp.int32),
        edge_v=jnp.full((1, 0), -1, jnp.int32),
        edge_op=jnp.full((1, 0), EDGE_NOP, jnp.int32),
        f_vertex=jnp.asarray([[1, 1, 0]]),
        f_value=jnp.asarray([[5.0, 9.0, 2.0]]),
        drop_vertex=jnp.full((1, 0), -1, jnp.int32),
    )
    g2 = apply_delta(g, d)
    assert float(g2.f[0, 1]) == 9.0
    assert float(g2.f[0, 0]) == 2.0


def test_empty_delta_is_noop():
    g = from_edge_lists([[(0, 1), (1, 2)]], [4], n_pad=6)
    g2 = apply_delta(g, empty_delta(1, 2, 1, 1))
    assert np.array_equal(np.asarray(g.adj), np.asarray(g2.adj))
    assert np.array_equal(np.asarray(g.f), np.asarray(g2.f))


# ----------------------------------------------------------- invalidation

def test_outside_core_update_is_cache_hit():
    # triangle 0-1-2 (the 2-core) with pendant 3; deleting the pendant edge
    # cannot change PD_1 (Thm 2) -> answered from cache, zero recompute
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0), (0, 3)]], [4], n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both", **CFG))
    d = s.apply(delta_from_lists([[(0, 3, EDGE_DELETE)]]))
    assert s.stats["hits"] == 1 and s.stats["recomputes"] == 0
    assert s.stats["coral_hits"] == 1
    _assert_parity(s, d, dims=(1,))


def test_core_touching_update_recomputes():
    # inserting the square's diagonal touches two 2-core vertices -> the
    # induced core changes -> a real recompute (and PD_1 actually moves)
    g = from_edge_lists([[(0, 1), (1, 2), (2, 3), (3, 0)]], [4], n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both", **CFG))
    before = dim_pairs(s.diagrams, 0, 1)
    d = s.apply(delta_from_lists([[(0, 2, EDGE_INSERT)]]))
    assert s.stats["recomputes"] == 1 and s.stats["hits"] == 0
    assert dim_pairs(d, 0, 1) != before  # one cycle became two
    _assert_parity(s, d, dims=(1,))


def test_outside_core_insert_creating_core_recomputes():
    # path 0-1-2-3: no 2-core at all; closing it into a cycle creates one —
    # endpoints were outside the (empty) core, so a diff-only predicate
    # would wrongly hit; the fresh core-mask comparison must catch it
    g = from_edge_lists([[(0, 1), (1, 2), (2, 3)]], [4], n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both", **CFG))
    d = s.apply(delta_from_lists([[(0, 3, EDGE_INSERT)]]))
    assert s.stats["recomputes"] == 1
    assert dim_pairs(d, 0, 1) != []  # the new cycle is a real PD_1 class
    _assert_parity(s, d, dims=(1,))


def test_dominated_toggle_is_prunit_hit_all_dims():
    # hub 0 adjacent to everything; satellite 4 attached to hubs 0 and 1;
    # toggling (1, 4) keeps 4 (and 1) dominated by the untouched hub 0 ->
    # exact in EVERY dimension (Thm 7), even though 4 sits in the 2-core
    edges = [(0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (1, 3), (2, 3), (1, 4)]
    f = [[0.0, 0.0, 1.0, 1.0, 2.0]]
    g = from_edge_lists([edges], [5], n_pad=8, f_values=f)
    cfg = TopoStreamConfig(dim=1, method="prunit", exact_dims="all", **CFG)
    s = TopoStream(g, cfg)
    for op in (EDGE_DELETE, EDGE_INSERT):
        d = s.apply(delta_from_lists([[(1, 4, op)]]))
        _assert_parity(s, d, dims=(0, 1))
    assert s.stats["prunit_hits"] == 2 and s.stats["recomputes"] == 0
    assert s.all_dims_exact.all()


def test_dropping_dominated_vertex_is_prunit_hit():
    edges = [(0, 1), (0, 2), (0, 3), (0, 4), (1, 2), (1, 3), (2, 3), (1, 4)]
    f = [[0.0, 0.0, 1.0, 1.0, 2.0]]
    g = from_edge_lists([edges], [5], n_pad=8, f_values=f)
    cfg = TopoStreamConfig(dim=1, method="prunit", exact_dims="all", **CFG)
    s = TopoStream(g, cfg)
    d = s.apply(delta_from_lists([[]], drops=[[4]], drop_slots=1))
    assert s.stats["prunit_hits"] == 1 and s.stats["recomputes"] == 0
    _assert_parity(s, d, dims=(0, 1))


def test_f_update_outside_core_hits_inside_core_recomputes():
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0), (0, 3)]], [4], n_pad=8,
                        f_values=[[1.0, 2.0, 3.0, 4.0]])
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both", **CFG))
    d = s.apply(delta_from_lists([[]], f_ops=[[(3, 9.0)]], f_slots=1))
    assert s.stats["hits"] == 1 and s.stats["recomputes"] == 0
    _assert_parity(s, d, dims=(1,))
    # vertex 0 is in the 2-core AND not dominated (it owns the pendant), so
    # neither predicate can certify the f move
    d = s.apply(delta_from_lists([[]], f_ops=[[(0, 7.0)]], f_slots=1))
    assert s.stats["recomputes"] == 1
    _assert_parity(s, d, dims=(1,))


def test_ineffective_update_never_invalidates():
    # inserting an existing edge / rewriting f with the same value is not an
    # update at all (the verdict diffs states, not ops)
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0)]], [3], n_pad=4,
                        f_values=[[1.0, 2.0, 3.0]])
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both", **CFG))
    s.apply(delta_from_lists([[(0, 1, EDGE_INSERT)]],
                             f_ops=[[(2, 3.0)]], f_slots=1))
    assert s.stats["graph_updates"] == 0
    assert s.stats["hits"] == 0 and s.stats["recomputes"] == 0


def test_only_affected_graphs_recompute():
    graphs = [[(0, 1), (1, 2), (2, 3), (3, 0)]] * 4
    g = from_edge_lists(graphs, [4] * 4, n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both", **CFG))
    # touch only graph 2 (core edge -> recompute); others untouched
    ops = [[], [], [(0, 2, EDGE_INSERT)], []]
    d = s.apply(delta_from_lists(ops, edge_slots=1))
    assert s.stats["recomputes"] == 1
    assert s.stats["recomputed_rows"] == 1  # pow2 sub-batch of size 1
    _assert_parity(s, d, dims=(1,))
    # graph 0 (plain square) must recompute on deleting (0,1); graph 2 now
    # carries the diagonal, which makes 0 and 1 dominated by the untouched
    # vertex 2 — a PrunIT hit, so only ONE graph re-executes
    ops = [[(0, 1, EDGE_DELETE)], [], [(0, 1, EDGE_DELETE)], []]
    d = s.apply(delta_from_lists(ops, edge_slots=1))
    assert s.stats["recomputes"] == 2
    assert s.stats["recomputed_rows"] == 2
    assert s.stats["prunit_hits"] == 1
    _assert_parity(s, d, dims=(1,))


def test_caps_overflow_raises():
    g = from_edge_lists([[(0, 1), (1, 2)]], [4], n_pad=6)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="none",
                                       edge_cap=3, tri_cap=4))
    with pytest.raises(ValueError, match="simplex caps"):
        s.apply(delta_from_lists([[(0, 2, EDGE_INSERT), (0, 3, EDGE_INSERT),
                                   (1, 3, EDGE_INSERT)]]))


def test_config_validation():
    with pytest.raises(ValueError, match="exact_dims"):
        TopoStreamConfig(exact_dims="bogus")
    with pytest.raises(ValueError, match="every"):
        TopoStreamConfig(method="both", exact_dims="all")
    with pytest.raises(ValueError, match="unknown reduction"):
        TopoStreamConfig(method="nonsense")


def test_coral_hit_marks_lower_dims_stale():
    # pendant deletion: PD_1 provably unchanged, PD_0 legitimately moves
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0), (0, 3)]], [4], n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="prunit", **CFG))
    assert s.all_dims_exact.all()
    s.apply(delta_from_lists([[(0, 3, EDGE_DELETE)]]))
    assert s.stats["coral_hits"] == 1
    assert not s.all_dims_exact[0]


# ------------------------------------------------------- property testing

def _random_delta(rng, n_live):
    ops, f_ops = [], []
    for _ in range(rng.integers(1, 3)):
        u, v = rng.choice(n_live, size=2, replace=False)
        op = EDGE_INSERT if rng.random() < 0.5 else EDGE_DELETE
        ops.append((int(u), int(v), op))
    if rng.random() < 0.5:
        f_ops.append((int(rng.integers(0, n_live)),
                      float(rng.integers(0, 7))))
    return delta_from_lists([ops], [f_ops], edge_slots=2, f_slots=1)


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=10, deadline=None)
def test_incremental_equals_scratch_random_sequences(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 11))
    edges = [(int(u), int(v)) for u in range(n) for v in range(u + 1, n)
             if rng.random() < 0.3]
    f = [[float(rng.integers(0, 7)) for _ in range(n)]]
    g = from_edge_lists([edges], [n], n_pad=12, f_values=f)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=66, tri_cap=220)
    s = TopoStream(g, cfg)
    for _ in range(4):
        d = s.apply(_random_delta(rng, n))
        _assert_parity(s, d, dims=(1,))


@given(st.integers(min_value=0, max_value=10**6))
@settings(max_examples=6, deadline=None)
def test_incremental_all_dims_mode_random_sequences(seed):
    rng = np.random.default_rng(seed)
    n = int(rng.integers(6, 10))
    edges = [(int(u), int(v)) for u in range(n) for v in range(u + 1, n)
             if rng.random() < 0.35]
    g = from_edge_lists([edges], [n], n_pad=12)
    cfg = TopoStreamConfig(dim=1, method="prunit", exact_dims="all",
                           edge_cap=66, tri_cap=220)
    s = TopoStream(g, cfg)
    for _ in range(3):
        d = s.apply(_random_delta(rng, n))
        _assert_parity(s, d, dims=(0, 1))


# ----------------------------------------------------------------- drift

def test_drift_zero_on_cache_hit():
    # pendant toggle is a coral hit: the diagram provably did not move, so
    # the drift score must be exactly 0 and no anomaly may fire
    g = from_edge_lists([[(0, 1), (1, 2), (2, 0), (0, 3)]], [4], n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both",
                                       drift_metric="sw",
                                       drift_threshold=0.0, **CFG))
    s.apply(delta_from_lists([[(0, 3, EDGE_DELETE)]]))
    assert s.stats["hits"] == 1
    assert s.last_drift.tolist() == [0.0]
    assert not s.last_anomaly.any() and s.stats["anomalies"] == 0


def test_drift_matches_direct_distance_on_recompute():
    from repro.metrics import sliced_wasserstein

    g = from_edge_lists([[(0, 1), (1, 2), (2, 3)]], [4], n_pad=8)
    cfg = TopoStreamConfig(dim=1, method="both", drift_metric="sw",
                           drift_threshold=0.5, **CFG)
    s = TopoStream(g, cfg)
    before = s.diagrams
    s.apply(delta_from_lists([[(0, 3, EDGE_INSERT)]]))  # path -> cycle
    assert s.stats["recomputes"] == 1
    want = float(sliced_wasserstein(
        jax.tree.map(lambda x: x[0], before),
        jax.tree.map(lambda x: x[0], s.diagrams),
        k=cfg.dim, n_dirs=cfg.drift_n_dirs, cap=cfg.drift_cap))
    assert want > 0
    assert s.last_drift[0] == pytest.approx(want, rel=1e-5)
    assert bool(s.last_anomaly[0]) and s.stats["anomalies"] == 1


def test_drift_scores_only_recomputed_graphs():
    # graph 0 gets a real structural change, graph 1 an ineffective op
    g = from_edge_lists([[(0, 1), (1, 2), (2, 3)]] * 2, [4, 4], n_pad=8)
    s = TopoStream(g, TopoStreamConfig(dim=1, method="both",
                                       drift_metric="sw",
                                       drift_threshold=0.5, **CFG))
    d = DeltaBatch(
        edge_u=jnp.asarray([[0], [0]]),
        edge_v=jnp.asarray([[3], [1]]),
        edge_op=jnp.asarray([[EDGE_INSERT], [EDGE_INSERT]]),  # (0,1) exists
        f_vertex=jnp.asarray([[-1], [-1]]),
        f_value=jnp.asarray([[0.0], [0.0]]),
        drop_vertex=jnp.asarray([[-1], [-1]]),
    )
    s.apply(d)
    assert s.last_drift[0] > 0 and s.last_drift[1] == 0.0
    assert s.last_anomaly.tolist() == [True, False]


def test_drift_config_validation():
    with pytest.raises(ValueError, match="drift_metric"):
        TopoStreamConfig(drift_metric="bogus")
    with pytest.raises(ValueError, match="drift_dim"):
        TopoStreamConfig(dim=1, drift_dim=2, drift_metric="sw")
    # sub-target drift dims go stale on coral hits under exact_dims="target"
    with pytest.raises(ValueError, match="exact_dims"):
        TopoStreamConfig(dim=1, drift_dim=0, drift_metric="sw")
    TopoStreamConfig(dim=1, drift_dim=0, drift_metric="sw",
                     method="prunit", exact_dims="all")  # valid combination
    with pytest.raises(ValueError, match="auto:q"):
        TopoStreamConfig(drift_threshold="q0.99")
    with pytest.raises(ValueError, match="quantile"):
        TopoStreamConfig(drift_threshold="auto:q1.5")
    with pytest.raises(ValueError, match="drift_warmup"):
        TopoStreamConfig(drift_threshold="auto:q0.9", drift_warmup=2)
    TopoStreamConfig(drift_threshold="auto:q0.99")  # valid spec


# ------------------------------------------------- drift auto-calibration

def test_p2_quantile_estimator_converges():
    from repro.stream.calibration import P2Quantile

    rng = np.random.default_rng(0)
    xs = rng.exponential(2.0, size=4000)
    est = P2Quantile(0.9)
    assert est.value() is None  # < 5 observations
    for x in xs:
        est.update(float(x))
    want = float(np.quantile(xs, 0.9))
    assert est.value() == pytest.approx(want, rel=0.08)


def test_drift_calibrator_warmup_and_thresholds():
    from repro.stream.calibration import DriftCalibrator

    cal = DriftCalibrator(batch=2, q=0.5, warmup=5)
    assert np.isinf(cal.thresholds()).all()  # no flags before warmup
    cal.observe([0] * 5, [1.0, 2.0, 3.0, 4.0, 5.0])
    thr = cal.thresholds()
    assert np.isfinite(thr[0]) and thr[0] == pytest.approx(3.0)
    assert np.isinf(thr[1])  # graph 1 still uncalibrated


def test_drift_auto_no_flags_during_warmup():
    # the same path→cycle recompute that flags under a tiny constant
    # threshold must NOT flag in auto mode while history is short
    g = from_edge_lists([[(0, 1), (1, 2), (2, 3)]], [4], n_pad=8)
    cfg = TopoStreamConfig(dim=1, method="both", drift_metric="sw",
                           drift_threshold="auto:q0.9", drift_warmup=5,
                           **CFG)
    s = TopoStream(g, cfg)
    s.apply(delta_from_lists([[(0, 3, EDGE_INSERT)]]))
    assert s.stats["recomputes"] == 1 and s.last_drift[0] > 0
    assert np.isinf(s.drift_thresholds()).all()
    assert not s.last_anomaly.any() and s.stats["anomalies"] == 0


def test_drift_auto_calibrates_on_burst_workload():
    # community churn with injected rewiring bursts: quiet recomputes build
    # each graph's drift history; the burst must exceed the learned quantile
    import jax as _jax

    from repro.core.delta import delta_step
    from repro.data.temporal import community_churn_stream

    steps, churn = 26, 8
    burst_at = {20, 24}
    schedule = jnp.asarray([churn if t in burst_at else 1
                            for t in range(steps)])
    g0, deltas = community_churn_stream(
        _jax.random.PRNGKey(5), batch=4, n_pad=16, n_vertices=14, n_comm=3,
        p_in=0.5, p_out=0.06, steps=steps, churn=churn,
        churn_schedule=schedule)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=96, tri_cap=192,
                           drift_metric="sw", drift_threshold="auto:q0.9",
                           drift_warmup=5)
    s = TopoStream(g0, cfg)
    burst_flags = quiet_flags = 0
    quiet_steps_after_warmup = 0
    for t in range(steps):
        s.apply(delta_step(deltas, t))
        calibrated = np.isfinite(s.drift_thresholds()).any()
        if t in burst_at:
            burst_flags += int(s.last_anomaly.sum())
        elif calibrated:
            quiet_steps_after_warmup += 1
            quiet_flags += int(s.last_anomaly.sum())
    assert burst_flags >= 1  # the rewiring bursts are flagged...
    assert quiet_steps_after_warmup > 0
    # ...and flags are concentrated there, not sprayed over quiet churn
    # (q=0.9 admits ~10% steady-state exceedances by construction)
    assert quiet_flags <= quiet_steps_after_warmup
    assert np.isfinite(s.drift_thresholds()).any()  # thresholds calibrated
