"""Hypothesis property tests on the system's structural invariants
(fast graph-level properties; the theorem-level PD properties live in
test_coral_theorem.py / test_prunit_theorem.py)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional [dev] extra; skip module without
from hypothesis import given, settings, strategies as st

from repro.core.graph import GraphBatch, canonicalize
from repro.core.kcore import coreness, kcore_mask
from repro.core.prunit import domination_matrix, prunit
from repro.topo.features import persistence_stats


def _random_batch(seed: int, b: int, n: int, p: float) -> GraphBatch:
    key = jax.random.PRNGKey(seed)
    ka, km, kf = jax.random.split(key, 3)
    adj = jax.random.bernoulli(ka, p, (b, n, n))
    nv = jax.random.randint(km, (b,), 2, n + 1)
    mask = jnp.arange(n)[None, :] < nv[:, None]
    f = jax.random.randint(kf, (b, n), 0, 8).astype(jnp.float32)
    return canonicalize(adj, mask, f)


graph_params = st.tuples(
    st.integers(0, 2**30), st.integers(1, 4), st.integers(3, 14),
    st.floats(0.05, 0.7),
)


@settings(max_examples=20, deadline=None)
@given(graph_params)
def test_canonicalize_idempotent(args):
    g = _random_batch(*args)
    g2 = canonicalize(g.adj, g.mask, g.f)
    np.testing.assert_array_equal(np.asarray(g.adj), np.asarray(g2.adj))
    np.testing.assert_array_equal(np.asarray(g.mask), np.asarray(g2.mask))


@settings(max_examples=20, deadline=None)
@given(graph_params)
def test_kcore_monotone_in_k(args):
    g = _random_batch(*args)
    prev = g.mask
    for k in range(1, 5):
        cur = kcore_mask(g.adj, g.mask, k)
        assert not np.any(np.asarray(cur & ~prev)), "k-core must shrink with k"
        prev = cur


@settings(max_examples=20, deadline=None)
@given(graph_params)
def test_kcore_mask_is_fixed_point(args):
    """Every vertex of the k-core has degree >= k inside the core."""
    g = _random_batch(*args)
    for k in (2, 3):
        m = np.asarray(kcore_mask(g.adj, g.mask, k))
        a = np.asarray(g.adj) & m[:, None, :] & m[:, :, None]
        deg = a.sum(-1)
        assert np.all(deg[m] >= k)


@settings(max_examples=20, deadline=None)
@given(graph_params)
def test_coreness_consistent_with_kcore(args):
    g = _random_batch(*args)
    c = np.asarray(coreness(g.adj, g.mask))
    for k in (1, 2, 3):
        m = np.asarray(kcore_mask(g.adj, g.mask, k))
        np.testing.assert_array_equal(m, (c >= k) & np.asarray(g.mask))


@settings(max_examples=20, deadline=None)
@given(graph_params)
def test_domination_definition(args):
    """dom[u,v] == (closed nbhd of u) subset of (closed nbhd of v)."""
    g = _random_batch(*args)
    dom = np.asarray(domination_matrix(g.adj, g.mask))
    adj = np.asarray(g.adj)
    mask = np.asarray(g.mask)
    b, n = mask.shape
    eye = np.eye(n, dtype=bool)
    for i in range(b):
        nc = (adj[i] | eye) & mask[i][None, :] & mask[i][:, None]
        for u in range(n):
            for v in range(n):
                want = (mask[i, u] and mask[i, v] and u != v
                        and not np.any(nc[u] & ~nc[v]))
                assert dom[i, u, v] == want, (i, u, v)


@settings(max_examples=15, deadline=None)
@given(graph_params)
def test_prunit_only_removes_and_is_idempotent_with_floor(args):
    g = _random_batch(*args)
    gp = prunit(g, sublevel=True)
    # never adds vertices or edges
    assert not np.any(np.asarray(gp.mask & ~g.mask))
    assert not np.any(np.asarray(gp.adj & ~g.adj))
    # surviving vertices keep their original f (paper Remark 1)
    keep = np.asarray(gp.mask)
    np.testing.assert_array_equal(np.asarray(gp.f)[keep],
                                  np.asarray(g.f)[keep])
    # idempotent: no dominated-with-f-condition vertex remains removable
    gpp = prunit(gp, sublevel=True)
    np.testing.assert_array_equal(np.asarray(gp.mask), np.asarray(gpp.mask))


@settings(max_examples=10, deadline=None)
@given(st.integers(0, 2**30))
def test_feature_vector_permutation_invariant_stats(seed):
    """Persistence statistics are invariant to vertex relabelling."""
    from repro.core.api import topological_signature

    g = _random_batch(seed, 1, 10, 0.35)
    perm = np.asarray(jax.random.permutation(jax.random.PRNGKey(seed ^ 7), 10))
    adj_p = np.asarray(g.adj)[:, perm][:, :, perm]
    g2 = canonicalize(jnp.asarray(adj_p), g.mask[:, perm], g.f[:, perm])
    d1 = topological_signature(g, dim=1, method="both", edge_cap=64, tri_cap=128)
    d2 = topological_signature(g2, dim=1, method="both", edge_cap=64, tri_cap=128)
    s1 = np.asarray(persistence_stats(d1, 1))
    s2 = np.asarray(persistence_stats(d2, 1))
    np.testing.assert_allclose(s1, s2, rtol=1e-5, atol=1e-5)
