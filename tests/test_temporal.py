"""Temporal-graph generators: stream validity, regimes, replay invariants."""
import jax
import numpy as np
import pytest

from repro.core.delta import EDGE_NOP, apply_delta, delta_step
from repro.data.temporal import (
    community_churn_stream,
    ego_decay_stream,
    pa_growth_stream,
)
from repro.stream import TopoStream, TopoStreamConfig


def _replay_graph_invariants(g0, deltas, steps):
    """Apply every step; check GraphBatch invariants hold throughout."""
    g = g0
    for t in range(steps):
        g = apply_delta(g, delta_step(deltas, t))
        a = np.asarray(g.adj)
        m = np.asarray(g.mask)
        f = np.asarray(g.f)
        assert np.array_equal(a, np.swapaxes(a, -1, -2))
        assert not a[:, np.arange(g.n), np.arange(g.n)].any()
        assert not (a & ~(m[:, None, :] & m[:, :, None])).any()
        assert np.isinf(f[~m]).all() and np.isfinite(f[m]).all()
    return g


def test_pa_growth_activates_one_vertex_per_step():
    g0, deltas = pa_growth_stream(jax.random.PRNGKey(0), batch=3, n_pad=16,
                                  n0=3, m=2, steps=8)
    assert deltas.steps == 8 and deltas.batch == 3
    assert int(g0.n_vertices()[0]) == 3
    g = _replay_graph_invariants(g0, deltas, 8)
    nv = np.asarray(g.n_vertices())
    assert (nv == 3 + 8).all()
    # arrival-time filtration: f(v) = v for live vertices
    f = np.asarray(g.f)
    m = np.asarray(g.mask)
    assert (f[m] == np.tile(np.arange(16), (3, 1))[m]).all()


def test_pa_growth_m1_all_updates_skip():
    g0, deltas = pa_growth_stream(jax.random.PRNGKey(1), batch=2, n_pad=12,
                                  n0=3, m=1, steps=6)
    s = TopoStream(g0, TopoStreamConfig(dim=1, method="prunit",
                                        exact_dims="all", edge_cap=40,
                                        tri_cap=64))
    for t in range(6):
        s.apply(delta_step(deltas, t))
    # a pendant arrival is dominated by its attachment target: Thm 7 says no
    # diagram can move, so the whole growth stream is recompute-free
    assert s.skip_rate() == 1.0
    assert s.stats["recomputes"] == 0


def test_pa_growth_rejects_overflow():
    with pytest.raises(ValueError, match="n_pad"):
        pa_growth_stream(jax.random.PRNGKey(0), batch=1, n_pad=8, n0=4,
                         m=1, steps=8)


def test_community_churn_preserves_vertex_set_and_f():
    g0, deltas = community_churn_stream(
        jax.random.PRNGKey(2), batch=3, n_pad=12, n_vertices=10, n_comm=3,
        p_in=0.5, p_out=0.1, steps=6, churn=2)
    g = _replay_graph_invariants(g0, deltas, 6)
    assert np.array_equal(np.asarray(g.mask), np.asarray(g0.mask))
    assert np.array_equal(np.asarray(g.f), np.asarray(g0.f))
    # churn ops are real ops (sampled from existing edges / non-edges)
    ops = np.asarray(deltas.edge_op)
    assert (ops != EDGE_NOP).any()


def test_ego_decay_mixes_hits_and_recomputes():
    g0, deltas = ego_decay_stream(jax.random.PRNGKey(3), batch=4, n_pad=32,
                                  n_core=10, n_double=6, n_pendant=6,
                                  steps=12, toggles=1, p_core_edge=0.3)
    _replay_graph_invariants(g0, deltas, 12)
    s = TopoStream(g0, TopoStreamConfig(dim=1, method="both", edge_cap=192,
                                        tri_cap=512))
    for t in range(12):
        s.apply(delta_step(deltas, t))
    assert s.stats["hits"] > 0            # satellite toggles skip
    assert s.stats["coral_hits"] > 0      # pendant satellites
    assert s.stats["prunit_hits"] > 0     # hub-dominated satellites
    assert 0.0 < s.skip_rate() <= 1.0


def test_ego_decay_layout_validation():
    with pytest.raises(ValueError, match="n_pad"):
        ego_decay_stream(jax.random.PRNGKey(0), batch=1, n_pad=8, n_core=6,
                         n_double=4, n_pendant=4, steps=2)
    with pytest.raises(ValueError, match="n_core"):
        ego_decay_stream(jax.random.PRNGKey(0), batch=1, n_pad=16, n_core=3,
                         n_double=2, n_pendant=2, steps=2)
