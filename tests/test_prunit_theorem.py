"""Property tests of the paper's Theorem 7 / Remark 8 / Theorem 10 (PrunIT).

PrunIT must preserve EVERY persistence diagram (all dims) for sublevel and
superlevel filtrations, for arbitrary filtering functions — and the combined
PrunIT-then-Coral pipeline must stay exact at the target dimension.
"""
import networkx as nx
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional [dev] extra; skip module without
from hypothesis import given, settings, strategies as st

from repro.core import GraphBatch, prunit, prunit_then_coral
from repro.core.persistence_ref import (
    diagrams_equal,
    persistence_diagrams,
    power_filtration_diagrams,
)
from tests.conftest import graphs_to_batch


@st.composite
def graph_and_f(draw, n_min=4, n_max=14):
    n = draw(st.integers(n_min, n_max))
    p = draw(st.floats(0.2, 0.75))
    seed = draw(st.integers(0, 2**31 - 1))
    g = nx.gnp_random_graph(n, p, seed=seed)
    f = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    return g, np.asarray(f, dtype=np.float32)


def _with_f(batch, f):
    import jax.numpy as jnp

    fv = jnp.where(batch.mask, jnp.asarray(f)[None, : batch.n], jnp.inf)
    return GraphBatch(adj=batch.adj, mask=batch.mask, f=fv)


@settings(max_examples=25, deadline=None)
@given(graph_and_f(), st.booleans())
def test_prunit_preserves_all_diagrams(gf, sublevel):
    G, f = gf
    g = _with_f(graphs_to_batch([G]), f)
    gp = prunit(g, sublevel=sublevel)
    ref = persistence_diagrams(
        np.asarray(g.adj[0]), np.asarray(g.f[0]), np.asarray(g.mask[0]),
        max_dim=1, sublevel=sublevel,
    )
    red = persistence_diagrams(
        np.asarray(gp.adj[0]), np.asarray(gp.f[0]), np.asarray(gp.mask[0]),
        max_dim=1, sublevel=sublevel,
    )
    assert diagrams_equal(ref, red), (ref, red)


@settings(max_examples=15, deadline=None)
@given(graph_and_f(n_min=5, n_max=12), st.integers(1, 2))
def test_combined_prunit_coral_exact(gf, k):
    G, f = gf
    g = _with_f(graphs_to_batch([G]), f)
    gc = prunit_then_coral(g, k)
    ref = persistence_diagrams(
        np.asarray(g.adj[0]), np.asarray(g.f[0]), np.asarray(g.mask[0]), max_dim=k
    )
    red = persistence_diagrams(
        np.asarray(gc.adj[0]), np.asarray(gc.f[0]), np.asarray(gc.mask[0]), max_dim=k
    )
    assert diagrams_equal({k: ref.get(k, [])}, {k: red.get(k, [])})


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 10), st.floats(0.3, 0.8), st.integers(0, 2**31 - 1))
def test_prunit_power_filtration(n, p, seed):
    # Theorem 10: dominated-vertex removal preserves power-filtration PDs
    # (k >= 1) on connected graphs — no f-condition needed.
    G = nx.gnp_random_graph(n, p, seed=seed)
    if not nx.is_connected(G):
        G = nx.compose(G, nx.path_graph(n))
    g = graphs_to_batch([G])
    # prune with no f restriction: superlevel + degree satisfies Remark 8
    gp = prunit(g, sublevel=False)
    ref = power_filtration_diagrams(np.asarray(g.adj[0]), np.asarray(g.mask[0]), max_dim=1)
    red = power_filtration_diagrams(np.asarray(gp.adj[0]), np.asarray(gp.mask[0]), max_dim=1)
    assert diagrams_equal({1: ref.get(1, [])}, {1: red.get(1, [])}), (ref, red)
