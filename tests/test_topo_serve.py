"""TopoServe: bucket routing, plan-cache behaviour, served-vs-direct parity."""
import threading

import jax
import networkx as nx
import pytest

from repro.core import topological_signature
from repro.core.api import clear_plan_cache, make_topo_plan, plan_cache_info
from repro.core.persistence_jax import diagrams_bitwise_equal as _rows_equal
from repro.serve import Bucket, TopoServe, TopoServeConfig
from repro.serve.topo_serve import pack_requests


def _graph_query(g: nx.Graph):
    nodes = sorted(g.nodes())
    idx = {u: i for i, u in enumerate(nodes)}
    return [(idx[u], idx[v]) for (u, v) in g.edges()], len(nodes)


# ------------------------------------------------------------------ buckets

def test_bucket_assignment_deterministic():
    srv1 = TopoServe()
    srv2 = TopoServe()
    cases = [(3, 3), (16, 64), (17, 10), (16, 65), (40, 200), (100, 700)]
    for nv, ne in cases:
        b1 = srv1.bucket_for(nv, ne)
        b2 = srv2.bucket_for(nv, ne)
        assert b1 == b2
        assert nv <= b1.n_pad and ne <= b1.edge_cap
        # first-fit: no smaller configured bucket also fits
        for smaller in srv1.config.buckets:
            if smaller < b1:
                assert nv > smaller.n_pad or ne > smaller.edge_cap


def test_bucket_boundaries():
    srv = TopoServe()
    assert srv.bucket_for(16, 64).n_pad == 16   # exactly fits the first rung
    assert srv.bucket_for(17, 10).n_pad == 32   # vertex overflow -> next rung
    assert srv.bucket_for(10, 65).n_pad == 32   # edge overflow -> next rung
    with pytest.raises(ValueError):
        srv.bucket_for(10_000, 5)               # beyond the ladder


def test_custom_bucket_ladder():
    cfg = TopoServeConfig(buckets=(Bucket(8, 16, 16), Bucket(24, 96, 128)))
    srv = TopoServe(cfg)
    assert srv.bucket_for(8, 16).n_pad == 8
    assert srv.bucket_for(9, 4).n_pad == 24


# --------------------------------------------------------------- plan cache

def test_plan_cache_hit_miss():
    clear_plan_cache()
    p1 = make_topo_plan(dim=1, method="prunit", edge_cap=64, tri_cap=96)
    info = plan_cache_info()
    assert (info["hits"], info["misses"]) == (0, 1)
    p2 = make_topo_plan(dim=1, method="prunit", edge_cap=64, tri_cap=96)
    assert p2 is p1  # identical key -> same compiled plan object
    assert plan_cache_info()["hits"] == 1
    p3 = make_topo_plan(dim=1, method="prunit", edge_cap=128, tri_cap=96)
    assert p3 is not p1
    assert plan_cache_info()["misses"] == 2


def test_serve_reuses_plans_across_drains():
    clear_plan_cache()
    srv = TopoServe(TopoServeConfig(method="prunit"))
    q = _graph_query(nx.cycle_graph(6))
    srv.submit(edges=q[0], n_vertices=q[1])
    srv.drain()
    misses_after_first = plan_cache_info()["misses"]
    srv.submit(edges=q[0], n_vertices=q[1])
    srv.drain()
    info = plan_cache_info()
    assert info["misses"] == misses_after_first  # second drain: cache hit
    assert info["hits"] >= 1


# -------------------------------------------------------------------- serve

def test_served_equals_direct_single_bucket():
    srv = TopoServe(TopoServeConfig(method="prunit", record_batches=True))
    graphs = [nx.cycle_graph(6), nx.petersen_graph(),
              nx.barabasi_albert_graph(12, 2, seed=3)]
    futs = [srv.submit(*_graph_query(g)) for g in graphs]
    assert srv.drain() == len(graphs)
    (bucket, reqs, bfuts), = srv.executed_batches
    direct = topological_signature(
        pack_requests(reqs, bucket), dim=srv.config.dim,
        method=srv.config.method, sublevel=srv.config.sublevel,
        edge_cap=bucket.edge_cap, tri_cap=bucket.tri_cap,
    )
    for i, fut in enumerate(bfuts):
        assert _rows_equal(fut.result(), jax.tree.map(lambda x: x[i], direct))


def test_served_equals_direct_across_buckets_and_padding():
    # odd request count + pad_batch_to forces padded rows; mixed sizes force
    # multiple buckets; served rows must still match the direct computation
    srv = TopoServe(TopoServeConfig(method="prunit", pad_batch_to=4,
                                    record_batches=True))
    graphs = [nx.cycle_graph(5), nx.complete_graph(7),
              nx.gnp_random_graph(20, 0.2, seed=1),
              nx.gnp_random_graph(40, 0.1, seed=2),
              nx.path_graph(3)]
    futs = [srv.submit(*_graph_query(g)) for g in graphs]
    assert srv.drain() == len(graphs)
    assert len({f.bucket for f in futs}) >= 2
    for bucket, reqs, bfuts in srv.executed_batches:
        direct = topological_signature(
            pack_requests(reqs, bucket), dim=srv.config.dim,
            method=srv.config.method, sublevel=srv.config.sublevel,
            edge_cap=bucket.edge_cap, tri_cap=bucket.tri_cap,
        )
        for i, fut in enumerate(bfuts):
            assert _rows_equal(fut.result(), jax.tree.map(lambda x: x[i], direct))


def test_served_diagram_values():
    srv = TopoServe(TopoServeConfig(method="none"))
    fut_c6 = srv.submit(*_graph_query(nx.cycle_graph(6)))
    fut_k5 = srv.submit(*_graph_query(nx.complete_graph(5)))
    srv.drain()
    assert int(fut_c6.result().betti(0)) == 1
    assert int(fut_c6.result().betti(1)) == 1
    assert int(fut_k5.result().betti(1)) == 0


def test_background_serve_forever_thread():
    srv = TopoServe(TopoServeConfig(method="prunit"))
    t = threading.Thread(target=srv.serve_forever, daemon=True)
    t.start()
    try:
        futs = [srv.submit(*_graph_query(nx.cycle_graph(4 + i)))
                for i in range(5)]
        results = [f.result(timeout=120) for f in futs]
        assert all(int(d.betti(1)) == 1 for d in results)
    finally:
        srv.stop()
        t.join(timeout=10)
    assert not t.is_alive()
    assert srv.stats["served"] >= 5


def test_oversize_request_rejected_at_submit():
    srv = TopoServe()
    with pytest.raises(ValueError):
        srv.submit(edges=[(i, i + 1) for i in range(200)], n_vertices=201)


def test_malformed_requests_rejected_at_submit():
    # rejected at ingress so they can never fail co-batched futures at drain
    srv = TopoServe()
    with pytest.raises(ValueError, match="out of range"):
        srv.submit(edges=[(0, 500)], n_vertices=5)
    with pytest.raises(ValueError, match="out of range"):
        srv.submit(edges=[(-1, 0)], n_vertices=5)
    with pytest.raises(ValueError, match="f has"):
        srv.submit(edges=[(0, 1)], n_vertices=3, f=[1.0])
    with pytest.raises(ValueError, match="n_vertices"):
        srv.submit(edges=[], n_vertices=0)


def test_duplicate_edges_degree_invariant_under_cobatching():
    # a request with duplicate/bidirectional edge entries and f=None must get
    # the same diagram whether co-batched with f-carrying requests (per-
    # request _degree_f path) or not (from_edge_lists vectorized path)
    dup_edges = [(0, 1), (1, 0), (1, 2), (1, 2), (2, 0)]

    srv_alone = TopoServe(TopoServeConfig(method="none"))
    fut_alone = srv_alone.submit(edges=dup_edges, n_vertices=3)
    srv_alone.drain()

    srv_mixed = TopoServe(TopoServeConfig(method="none"))
    fut_mixed = srv_mixed.submit(edges=dup_edges, n_vertices=3)
    srv_mixed.submit(edges=[(0, 1)], n_vertices=2, f=[5.0, 7.0])
    srv_mixed.drain()

    assert _rows_equal(fut_alone.result(), fut_mixed.result())


def test_mesh_pad_rounds_up_to_mesh_multiple():
    class _FakeDevices:
        size = 4

    class _FakeMesh:
        devices = _FakeDevices()

    srv = TopoServe(TopoServeConfig(pad_batch_to=6), mesh=_FakeMesh())
    assert srv._pad_batch_to == 8  # next multiple of the 4-device mesh
    srv2 = TopoServe(TopoServeConfig(pad_batch_to=1), mesh=_FakeMesh())
    assert srv2._pad_batch_to == 4


def test_signature_features_matches_feature_vector():
    from repro.topo.features import feature_vector, signature_features
    import numpy as np

    plan = make_topo_plan(dim=1, method="prunit", edge_cap=64, tri_cap=96)
    g = pack_requests(
        [srv_req for srv_req in _requests([nx.cycle_graph(6),
                                           nx.petersen_graph()])],
        Bucket(16, 64, 96))
    direct = feature_vector(plan.execute(g), max_dim=plan.dim, res=4)
    shared = signature_features(g, plan, res=4)
    assert np.array_equal(np.asarray(direct), np.asarray(shared))


def _requests(graphs):
    from repro.serve.topo_serve import TopoRequest

    out = []
    for g in graphs:
        edges, n = _graph_query(g)
        out.append(TopoRequest(edges=tuple(edges), n_vertices=n))
    return out


def test_triangle_dense_graph_promoted_past_tri_cap():
    # K13: 78 edges fit the n32 rung (edge_cap 160) but its 286 triangles
    # exceed tri_cap 256 -> must promote to n64 so the diagrams stay exact
    srv = TopoServe(TopoServeConfig(method="none"))
    fut = srv.submit(*_graph_query(nx.complete_graph(13)))
    assert fut.bucket.n_pad == 64 and fut.bucket.tri_cap >= 286
    srv.drain()
    d = fut.result()
    assert int(d.betti(0)) == 1 and int(d.betti(1)) == 0  # K13 contractible


def test_failed_batch_resolves_futures_with_error():
    # an unexecutable bucket config must fail the future, not hang result()
    srv = TopoServe(TopoServeConfig(method="nonsense"))  # invalid reduction
    fut = srv.submit(*_graph_query(nx.cycle_graph(4)))
    assert srv.drain() == 0
    assert fut.done()
    with pytest.raises(ValueError):
        fut.result(timeout=1)
