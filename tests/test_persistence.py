"""JAX bit-packed persistence vs the exact NumPy oracle."""
import networkx as nx
import numpy as np
import pytest

from tests.conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.core import GraphBatch, persistence_diagrams_batched
from repro.core.persistence_jax import diagrams_to_numpy
from repro.core.persistence_ref import (
    betti_numbers,
    diagrams_equal,
    persistence_diagrams,
)
from tests.conftest import graphs_to_batch, random_graphs


def _check_batch(gs, g, max_dim=1, **caps):
    d = persistence_diagrams_batched(g, max_dim=max_dim, **caps)
    for i in range(len(gs)):
        ref = persistence_diagrams(
            np.asarray(g.adj[i]), np.asarray(g.f[i]), np.asarray(g.mask[i]),
            max_dim=max_dim,
        )
        ours = diagrams_to_numpy(d, i, max_dim)
        assert diagrams_equal(ref, ours), (i, ref, ours)


@pytest.mark.parametrize("kind", ["er", "ba", "plc", "complete"])
def test_jax_pd_matches_oracle(kind):
    gs = random_graphs(kind, 5, seed=hash(kind) % 1000)
    g = graphs_to_batch(gs)
    _check_batch(gs, g, max_dim=1, edge_cap=128, tri_cap=512)


def test_known_diagrams_cycle():
    # C_6, constant f=0: one essential H0 class, one essential H1 class.
    g = graphs_to_batch([nx.cycle_graph(6)])
    g = GraphBatch(adj=g.adj, mask=g.mask, f=g.f * 0.0)
    d = persistence_diagrams_batched(g, max_dim=1, edge_cap=16, tri_cap=16)
    assert int(d.betti(0)[0]) == 1
    assert int(d.betti(1)[0]) == 1


def test_known_diagrams_complete():
    # K_5 is contractible as a clique complex: Betti = (1, 0).
    g = graphs_to_batch([nx.complete_graph(5)])
    d = persistence_diagrams_batched(g, max_dim=1, edge_cap=16, tri_cap=16)
    assert int(d.betti(0)[0]) == 1
    assert int(d.betti(1)[0]) == 0


def test_two_components():
    G = nx.disjoint_union(nx.cycle_graph(4), nx.path_graph(3))
    g = graphs_to_batch([G])
    d = persistence_diagrams_batched(g, max_dim=1, edge_cap=16, tri_cap=16)
    assert int(d.betti(0)[0]) == 2
    assert int(d.betti(1)[0]) == 1


def test_pd2_with_quads():
    # The octahedron's clique complex is S^2: Betti = (1, 0, 1).  Its PD_2
    # needs tetrahedra columns (quad_cap > 0).
    G = nx.octahedral_graph()
    g = graphs_to_batch([G])
    d = persistence_diagrams_batched(
        g, max_dim=2, edge_cap=16, tri_cap=16, quad_cap=8
    )
    assert int(d.betti(0)[0]) == 1
    assert int(d.betti(1)[0]) == 0
    assert int(d.betti(2)[0]) == 1
    ref = betti_numbers(np.asarray(g.adj[0]), max_dim=2)
    assert ref == {0: 1, 1: 0, 2: 1}


@settings(max_examples=15, deadline=None)
@given(st.integers(4, 12), st.floats(0.2, 0.7), st.integers(0, 2**31 - 1),
       st.booleans())
def test_jax_pd_random_f(n, p, seed, sublevel):
    G = nx.gnp_random_graph(n, p, seed=seed)
    rng = np.random.default_rng(seed)
    f = rng.integers(0, 6, size=n).astype(np.float32)
    g = graphs_to_batch([G])
    import jax.numpy as jnp

    g = GraphBatch(adj=g.adj, mask=g.mask,
                   f=jnp.where(g.mask, jnp.asarray(f)[None, :], jnp.inf))
    d = persistence_diagrams_batched(
        g, max_dim=1, edge_cap=128, tri_cap=512, sublevel=sublevel
    )
    ref = persistence_diagrams(
        np.asarray(g.adj[0]), f, np.asarray(g.mask[0]), max_dim=1,
        sublevel=sublevel,
    )
    ours = diagrams_to_numpy(d, 0, 1)
    assert diagrams_equal(ref, ours), (ref, ours)


def test_pallas_reducer_path():
    gs = random_graphs("er", 3, seed=5)
    g = graphs_to_batch(gs)
    d1 = persistence_diagrams_batched(g, max_dim=1, edge_cap=96, tri_cap=256,
                                      reducer="jnp")
    d2 = persistence_diagrams_batched(g, max_dim=1, edge_cap=96, tri_cap=256,
                                      reducer="pallas")
    for i in range(len(gs)):
        assert diagrams_equal(diagrams_to_numpy(d1, i, 1), diagrams_to_numpy(d2, i, 1))
