"""MetricEngine: registry contracts, auction-LAP exact Wasserstein vs the
Hungarian oracle, blocked Sinkhorn consistency, and drift-through-registry.

The acceptance contract (ISSUE 5): auction-LAP ``exact_w`` within atol 1e-5
of the Hungarian reference on randomized masked pairs (0 mismatches),
ε-scaling converges, degenerate all-diagonal diagrams are handled; blocked
Sinkhorn agrees with the dense path to f32 roundoff at tile-fitting sizes;
every consumer routes through ``compare``/``pairwise``.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.kernels import ops, ref as kref
from repro.metrics import (
    METRIC_REGISTRY,
    MetricBackend,
    bottleneck_approx,
    compact_top_k,
    compare,
    exact_w,
    exact_w_info,
    get_metric,
    metric_params,
    pairwise,
    register_metric,
    sinkhorn_w2,
)
from repro.metrics import reference as mref
from repro.metrics.testing import diagram_points, random_diagram

CAP = 64.0


def stack(diagrams):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *diagrams)


@pytest.fixture(scope="module")
def random_pairs():
    rng = np.random.default_rng(21)
    return [(random_diagram(rng, essential=int(rng.integers(0, 3))),
             random_diagram(rng)) for _ in range(50)]


# ---------------------------------------------------------------- registry

def test_builtin_backends_and_contracts():
    for name, exact in (("sw", False), ("sinkhorn", False),
                        ("exact_w", True), ("bottleneck_approx", False)):
        be = get_metric(name)
        assert be.exact is exact
        assert be.error_bound and be.cost_class  # contract record present
        assert be.params  # tunables harvested from the fn signature
    assert "n_dirs" in metric_params("sw")
    assert "n_points" in metric_params("exact_w")


def test_register_validation():
    with pytest.raises(ValueError, match="unknown metric"):
        get_metric("bogus")
    with pytest.raises(ValueError, match="already registered"):
        register_metric(METRIC_REGISTRY["sw"])
    with pytest.raises(ValueError, match="not accepted"):
        register_metric(MetricBackend(
            name="tmp", fn=lambda d1, d2, k, cap: 0.0, exact=False,
            error_bound="", cost_class="", defaults={"nope": 1}))
    assert "tmp" not in METRIC_REGISTRY


def test_compare_rejects_unknown_params(random_pairs):
    a, b = random_pairs[0]
    with pytest.raises(ValueError, match="does not accept"):
        compare(a, b, metric="exact_w", n_dirs=8)  # n_dirs is sw-only


def test_compare_routes_all_backends(random_pairs):
    d1 = stack([a for a, _ in random_pairs[:8]])
    d2 = stack([b for _, b in random_pairs[:8]])
    for name in METRIC_REGISTRY:
        out = np.asarray(compare(d1, d2, metric=name, k=1, cap=CAP))
        assert out.shape == (8,) and np.isfinite(out).all() and (out >= 0).all()


def test_pairwise_matrix_and_blocking(random_pairs):
    d = stack([a for a, _ in random_pairs[:6]])
    full = np.asarray(pairwise(d, metric="sw", k=1, cap=CAP))
    assert full.shape == (6, 6)
    np.testing.assert_allclose(np.diag(full), 0.0, atol=1e-5)
    np.testing.assert_allclose(full, full.T, rtol=1e-6, atol=1e-5)
    blocked = np.asarray(pairwise(d, metric="sw", k=1, cap=CAP,
                                  block_rows=4))
    np.testing.assert_allclose(full, blocked, rtol=1e-6, atol=1e-6)


# ------------------------------------------------------ auction-LAP exact_w

def test_exact_w_matches_hungarian(random_pairs):
    d1 = stack([a for a, _ in random_pairs])
    d2 = stack([b for _, b in random_pairs])
    w = np.asarray(compare(d1, d2, metric="exact_w", k=1, cap=CAP,
                           n_points=16))
    for i, (a, b) in enumerate(random_pairs):
        want = mref.wasserstein_exact(diagram_points(a, 1, CAP),
                                      diagram_points(b, 1, CAP), q=2.0)
        assert abs(w[i] - want) <= 1e-5, (i, w[i], want)


def test_auction_eps_scaling_converges(random_pairs):
    d1 = stack([a for a, _ in random_pairs[:16]])
    d2 = stack([b for _, b in random_pairs[:16]])
    w, conv, rounds = exact_w_info(d1, d2, k=1, n_points=16)
    assert bool(np.asarray(conv).all())
    # collapsed pairs with no real bidders finish in 0 rounds, so only the
    # batch as a whole must show bidding work
    assert (np.asarray(rounds) >= 0).all() and np.asarray(rounds).sum() > 0
    # a coarse ladder still yields a valid (if looser) matching: the total
    # can only be >= the optimum, within the documented M·ε bound
    w2 = np.asarray(exact_w(d1, d2, k=1, n_points=16, n_scales=3))
    assert (w2 >= np.asarray(w) - 1e-4).all()


def test_auction_kernel_matches_jnp_oracle():
    rng = np.random.default_rng(13)
    c = jnp.asarray(rng.uniform(0, 5, (16, 24, 24)).astype(np.float32))
    a_k, tot_k, conv_k, _ = ops.auction_lap(c)
    a_r, tot_r, conv_r, _ = jax.vmap(kref.auction_lap_ref)(c)
    np.testing.assert_array_equal(np.asarray(a_k), np.asarray(a_r))
    np.testing.assert_array_equal(np.asarray(tot_k), np.asarray(tot_r))
    # every solve returns a permutation
    for row in np.asarray(a_k):
        assert sorted(row) == list(range(24))


def test_exact_w_degenerate_diagrams():
    rng = np.random.default_rng(14)
    empty = random_diagram(rng, n=0)
    one = random_diagram(rng, n=1)
    many = random_diagram(rng, n=6, essential=1)
    # empty vs empty: all reservoir slots, zero cost
    assert float(exact_w(empty, empty, k=1, cap=CAP)) == 0.0
    # self-distance: every point matches itself for free
    assert float(exact_w(many, many, k=1, cap=CAP)) <= 1e-5
    # empty vs non-empty: every point pays its diagonal distance
    got = float(exact_w(empty, one, k=1, cap=CAP))
    want = mref.wasserstein_exact([], diagram_points(one, 1, CAP), q=2.0)
    assert abs(got - want) <= 1e-5
    # symmetry
    ab = float(exact_w(many, one, k=1, cap=CAP))
    ba = float(exact_w(one, many, k=1, cap=CAP))
    assert ab == pytest.approx(ba, abs=1e-5)


def test_bottleneck_approx_matches_exact(random_pairs):
    d1 = stack([a for a, _ in random_pairs[:24]])
    d2 = stack([b for _, b in random_pairs[:24]])
    bn = np.asarray(bottleneck_approx(d1, d2, k=1, cap=CAP, n_points=16))
    for i, (a, b) in enumerate(random_pairs[:24]):
        want = mref.bottleneck_exact(diagram_points(a, 1, CAP),
                                     diagram_points(b, 1, CAP))
        assert abs(bn[i] - want) <= max(1e-4, 1e-4 * want), (i, bn[i], want)


def test_compact_top_k_shared_helper():
    rng = np.random.default_rng(15)
    d = random_diagram(rng, s=20, n=7)
    # wider than content: exact, fixed width
    b, e, keep = compact_top_k(d, 1, 12, CAP)
    assert b.shape == (12,) and int(keep.sum()) == 7
    # narrower: top-persistence truncation
    b2, e2, keep2 = compact_top_k(d, 1, 4, CAP)
    assert b2.shape == (4,) and int(keep2.sum()) == 4
    pers_all = sorted(np.asarray(e - b)[np.asarray(keep)], reverse=True)
    pers_top = sorted(np.asarray(e2 - b2)[np.asarray(keep2)], reverse=True)
    np.testing.assert_allclose(pers_top, pers_all[:4], rtol=1e-6)
    # tiny tensors pad up to the requested width
    tiny = random_diagram(rng, s=3, n=2, scatter=False)
    b3, _, keep3 = compact_top_k(tiny, 1, 8, CAP)
    assert b3.shape == (8,) and int(keep3.sum()) == 2


# --------------------------------------------------------- blocked Sinkhorn

def test_blocked_sinkhorn_consistent_at_tile_fitting_sizes(random_pairs):
    d1 = stack([a for a, _ in random_pairs[:12]])
    d2 = stack([b for _, b in random_pairs[:12]])
    dense = np.asarray(sinkhorn_w2(d1, d2, k=1, impl="dense"))
    blocked = np.asarray(sinkhorn_w2(d1, d2, k=1, impl="blocked"))
    np.testing.assert_allclose(blocked, dense, rtol=1e-4, atol=1e-5)


def test_blocked_sinkhorn_multi_tile_and_full_tensor():
    rng = np.random.default_rng(16)
    d1 = stack([random_diagram(rng, s=40, n=8) for _ in range(4)])
    d2 = stack([random_diagram(rng, s=40, n=8) for _ in range(4)])
    # full tensor (n_points=None): cloud 80 > tile 32 forces the online
    # multi-tile LSE merge; dense is the oracle
    dense = np.asarray(sinkhorn_w2(d1, d2, k=1, n_points=None,
                                   impl="dense"))
    blocked = np.asarray(sinkhorn_w2(d1, d2, k=1, n_points=None,
                                     impl="blocked", tile=32))
    np.testing.assert_allclose(blocked, dense, rtol=1e-3, atol=1e-4)
    with pytest.raises(ValueError, match="unknown sinkhorn impl"):
        sinkhorn_w2(d1, d2, k=1, impl="bogus")


# ------------------------------------------------------ drift via registry

def test_stream_drift_routes_through_registry():
    from repro.core.delta import delta_from_lists
    from repro.core.graph import from_edge_lists
    from repro.data.graphs import with_degree_filtration
    from repro.stream import TopoStream, TopoStreamConfig

    g = with_degree_filtration(from_edge_lists(
        [[(0, 1), (1, 2), (2, 3), (3, 0)]], [5], n_pad=8))
    scores = {}
    for metric in ("sw", "exact_w"):
        stream = TopoStream(g, TopoStreamConfig(
            dim=1, method="both", edge_cap=24, tri_cap=24,
            drift_metric=metric, drift_threshold=1e9))
        # close the 4-cycle's chord: creates a second 1-cycle, diagram moves
        stream.apply(delta_from_lists([[(0, 2, "insert")]]))
        assert stream.last_drift.shape == (1,)
        scores[metric] = float(stream.last_drift[0])
        assert np.isfinite(scores[metric]) and scores[metric] >= 0
    # both backends must register movement for a genuine topology change
    assert scores["exact_w"] > 0
