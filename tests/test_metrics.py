"""TopoMetric: batched diagram distances vs the host-side exact references.

The acceptance contract (ISSUE 3): batched sliced-Wasserstein within rtol
1e-5 of its dense reference and Sinkhorn-2-Wasserstein within 5% of exact
W2 on >= 200 random small diagram pairs; the Pallas pairwise Gram matches
its jnp reference at fp32 tolerance; self-distance 0 and symmetry hold
under masking/padding.
"""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import from_edge_lists, topological_signature
from repro.core.persistence_jax import Diagrams, diagrams_to_numpy
from repro.kernels import ops, ref as kref
from repro.metrics import (
    sinkhorn_w2,
    sliced_wasserstein,
    sw_embedding,
)
from repro.metrics import reference as ref
from repro.metrics.testing import diagram_points, random_diagram

CAP = 64.0
N_PAIRS = 200

rand_diagram = random_diagram  # shared generator (repro.metrics.testing)


def points(dg, k=1):
    return diagram_points(dg, k=k, cap=CAP)


def stack(diagrams):
    return jax.tree.map(lambda *xs: jnp.stack(xs), *diagrams)


@pytest.fixture(scope="module")
def random_pairs():
    rng = np.random.default_rng(11)
    pairs = []
    for i in range(N_PAIRS):
        e1 = int(rng.integers(0, 3))
        pairs.append((rand_diagram(rng, essential=e1), rand_diagram(rng)))
    return pairs


# ---------------------------------------------------------------- parity

def test_sliced_wasserstein_matches_dense_reference(random_pairs):
    d1 = stack([a for a, _ in random_pairs])
    d2 = stack([b for _, b in random_pairs])
    got = np.asarray(sliced_wasserstein(d1, d2, k=1, n_dirs=32, cap=CAP))
    assert got.shape == (N_PAIRS,)
    for i, (a, b) in enumerate(random_pairs):
        want = ref.sw_dense(points(a), points(b), n_dirs=32)
        np.testing.assert_allclose(got[i], want, rtol=1e-5, atol=1e-5)


def test_sinkhorn_within_5pct_of_exact_w2(random_pairs):
    d1 = stack([a for a, _ in random_pairs])
    d2 = stack([b for _, b in random_pairs])
    got = np.asarray(sinkhorn_w2(d1, d2, k=1, cap=CAP))
    for i, (a, b) in enumerate(random_pairs):
        want = ref.wasserstein_exact(points(a), points(b), q=2.0)
        if want == 0.0:
            assert abs(got[i]) < 1e-4, i
        else:
            assert abs(got[i] - want) / want < 0.05, (i, got[i], want)


def test_hungarian_matches_scipy():
    scipy_opt = pytest.importorskip("scipy.optimize")
    rng = np.random.default_rng(5)
    for _ in range(30):
        m = int(rng.integers(1, 10))
        c = rng.uniform(0, 5, (m, m))
        r, cc = scipy_opt.linear_sum_assignment(c)
        np.testing.assert_allclose(
            ref.hungarian_cost(c), float(c[r, cc].sum()), rtol=1e-12)


def test_reference_known_values():
    # single point vs empty: everything pays its distance to the diagonal
    assert ref.bottleneck_exact([(0.0, 4.0)], []) == pytest.approx(2.0)
    assert ref.wasserstein_exact([(0.0, 2.0)], [], q=2.0) == pytest.approx(
        np.sqrt(2.0))
    # matching beats the diagonal when points are close
    assert ref.bottleneck_exact([(0.0, 4.0)], [(1.0, 4.0)]) == pytest.approx(1.0)
    assert ref.wasserstein_exact([(0.0, 4.0)], [(1.0, 4.0)], q=2.0) == (
        pytest.approx(1.0))
    assert ref.bottleneck_exact([], []) == 0.0
    assert ref.sw_dense([(0.0, 2.0)], [(0.0, 2.0)]) == 0.0


# ------------------------------------------------- masking / metric axioms

def test_self_distance_zero_and_symmetry_under_padding():
    rng = np.random.default_rng(3)
    a = rand_diagram(rng, n=5, essential=1)
    b = rand_diagram(rng, n=3)
    for fn in (lambda x, y: sliced_wasserstein(x, y, k=1, cap=CAP),
               lambda x, y: sinkhorn_w2(x, y, k=1, cap=CAP)):
        assert float(fn(a, a)) == pytest.approx(0.0, abs=1e-5)
        assert float(fn(a, b)) == pytest.approx(float(fn(b, a)), rel=1e-6)
        assert float(fn(a, b)) > 0


def test_row_scatter_and_tensor_size_invariance():
    # same multiset of points in different rows and different tensor sizes S
    rng = np.random.default_rng(9)
    bs = np.array([1.0, 2.5], np.float32)
    ds = np.array([4.0, np.inf], np.float32)

    def build(s, order):
        b = np.full(s, np.nan, np.float32)
        d = np.full(s, np.nan, np.float32)
        dim = np.full(s, -1, np.int32)
        val = np.zeros(s, bool)
        b[order], d[order] = bs, ds
        dim[order], val[order] = 1, True
        return Diagrams(birth=jnp.asarray(b), death=jnp.asarray(d),
                        dim=jnp.asarray(dim), valid=jnp.asarray(val))

    a = build(8, np.array([0, 1]))
    b = build(8, np.array([6, 2]))
    c = build(20, np.array([17, 3]))
    assert float(sliced_wasserstein(a, b, k=1, cap=CAP)) == 0.0
    assert float(sliced_wasserstein(a, c, k=1, cap=CAP)) == 0.0  # S differs
    assert float(sinkhorn_w2(a, b, k=1, cap=CAP)) == pytest.approx(0.0, abs=1e-5)
    np.testing.assert_allclose(
        np.asarray(sw_embedding(a, k=1, cap=CAP)),
        np.asarray(sw_embedding(b, k=1, cap=CAP)), atol=1e-6)
    np.testing.assert_allclose(
        np.asarray(sw_embedding(a, k=1, cap=CAP)),
        np.asarray(sw_embedding(c, k=1, cap=CAP)), atol=1e-6)


def test_wrong_dimension_rows_are_inert():
    rng = np.random.default_rng(4)
    a = rand_diagram(rng, n=4, k=1, scatter=False)  # occupies rows 0..3
    noisy = Diagrams(  # add a dim-0 row; k=1 distances must not see it
        birth=a.birth.at[5].set(0.5), death=a.death.at[5].set(3.5),
        dim=a.dim.at[5].set(0), valid=a.valid.at[5].set(True))
    assert float(sliced_wasserstein(a, noisy, k=1, cap=CAP)) == 0.0
    assert float(sinkhorn_w2(a, noisy, k=1, cap=CAP)) == pytest.approx(
        0.0, abs=1e-5)


def test_empty_vs_empty_and_empty_vs_nonempty():
    rng = np.random.default_rng(6)
    empty = rand_diagram(rng, n=0)
    one = rand_diagram(rng, n=1)
    assert float(sliced_wasserstein(empty, empty, k=1, cap=CAP)) == 0.0
    assert float(sinkhorn_w2(empty, empty, k=1, cap=CAP)) == 0.0
    d = float(sliced_wasserstein(empty, one, k=1, cap=CAP))
    want = ref.sw_dense([], points(one))
    np.testing.assert_allclose(d, want, rtol=1e-5, atol=1e-6)


# ------------------------------------------------------------ Pallas Gram

def test_pairwise_gram_matches_jnp_reference():
    rng = np.random.default_rng(12)
    for (m, n, d) in ((5, 7, 33), (64, 64, 256), (130, 40, 257)):
        x = jnp.asarray(rng.normal(size=(m, d)).astype(np.float32))
        y = jnp.asarray(rng.normal(size=(n, d)).astype(np.float32))
        got = np.asarray(ops.pairwise_l1(x, y))
        want = np.asarray(kref.pairwise_l1_ref(x, y))
        np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-4)


def test_gram_over_embeddings_is_a_metric_surface():
    rng = np.random.default_rng(13)
    diags = stack([rand_diagram(rng) for _ in range(12)])
    emb = sw_embedding(diags, k=1, n_points=8, n_dirs=8, cap=CAP)
    gram = np.asarray(ops.pairwise_l1(emb, emb))
    np.testing.assert_allclose(np.diag(gram), 0.0, atol=1e-5)
    np.testing.assert_allclose(gram, gram.T, rtol=1e-6, atol=1e-5)
    assert (gram >= -1e-5).all()


# ---------------------------------------------------- end-to-end pipeline

def test_distances_on_pipeline_diagrams_match_reference():
    """Diagrams from the real reduce->persist pipeline, not synthetic rows."""
    g = from_edge_lists(
        [[(0, 1), (1, 2), (2, 3), (3, 0)],                    # 4-cycle
         [(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)],    # two triangles
         [(0, 1), (1, 2), (2, 3), (3, 4)]],                   # path
        [5, 5, 5], n_pad=8)
    d = topological_signature(g, dim=1, method="both", edge_cap=24, tri_cap=24)
    for k in (0, 1):
        for i in range(3):
            for j in range(3):
                di = jax.tree.map(lambda x: x[i], d)
                dj = jax.tree.map(lambda x: x[j], d)
                got = float(sliced_wasserstein(di, dj, k=k, cap=CAP))
                pi = ref.cap_points(diagrams_to_numpy(d, i, 1)[k], CAP)
                pj = ref.cap_points(diagrams_to_numpy(d, j, 1)[k], CAP)
                want = ref.sw_dense(pi, pj)
                np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
