"""Per-architecture smoke tests (deliverable f).

Every assigned architecture is instantiated at a REDUCED same-family config
(small depth/width/experts/embeddings, per registry.reduced_config) and runs
one forward/train step on CPU, asserting output shapes and no NaNs.  Decoder
archs additionally run a prefill+decode serve step against a KV cache.
The FULL configs are exercised by the dry-run (launch/dryrun.py).
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.registry import ARCHS, get_config, reduced_config
from repro.data.tokens import TokenStream
from repro.models import transformer as tf
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainState, make_train_step

LM_ARCHS = [a for a in ARCHS if a != "tda_ego"]


def _extras(cfg, batch, seq, decode=False):
    out = {}
    if cfg.family == "encdec":
        out["frames"] = jnp.zeros((batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    if cfg.mrope_sections:
        out["vision"] = jnp.zeros((batch, cfg.vision_tokens, cfg.d_model),
                                  jnp.bfloat16)
        s_pos = 1 if decode else seq
        out["mrope_positions"] = jnp.zeros((batch, s_pos, 3), jnp.int32)
    return out


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_full_config_matches_assignment(arch):
    """The FULL config carries the exact assigned hyperparameters."""
    cfg = get_config(arch)
    assigned = {
        "zamba2-7b": (81, 3584, 32, 32, 14336, 32000),
        "codeqwen1.5-7b": (32, 4096, 32, 32, 13440, 92416),
        "qwen3-1.7b": (28, 2048, 16, 8, 6144, 151936),
        "qwen1.5-4b": (40, 2560, 20, 20, 6912, 151936),
        "gemma3-27b": (62, 5376, 32, 16, 21504, 262144),
        "olmoe-1b-7b": (16, 2048, 16, 16, 1024, 50304),
        "phi3.5-moe-42b-a6.6b": (32, 4096, 32, 8, 6400, 32064),
        "rwkv6-1.6b": (24, 2048, 0, 0, 7168, 65536),
        "whisper-base": (6, 512, 8, 8, 2048, 51872),  # vocab padded 51865->51872
        "qwen2-vl-2b": (28, 1536, 12, 2, 8960, 151936),
    }[arch]
    got = (cfg.n_layers, cfg.d_model, cfg.n_heads or 0,
           cfg.n_kv_heads or 0, cfg.d_ff, cfg.vocab_size)
    if arch == "rwkv6-1.6b":  # attn-free: head fields unused
        got = (cfg.n_layers, cfg.d_model, 0, 0, cfg.d_ff, cfg.vocab_size)
    assert got == assigned


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_train_step_smoke(arch):
    cfg = reduced_config(arch)
    batch, seq = 2, 32
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    state = TrainState(params=params, opt=adamw_init(params))
    stream = TokenStream(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq)
    data = {**stream.batch_at(jnp.int32(0)), **_extras(cfg, batch, seq)}
    extra_keys = tuple(k for k in data if k != "tokens")
    step = make_train_step(cfg, grad_accum=1, extra_keys=extra_keys)
    new_state, metrics = jax.jit(step)(state, data)
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params changed and are finite
    for a, b in zip(jax.tree.leaves(state.params),
                    jax.tree.leaves(new_state.params)):
        assert np.isfinite(np.asarray(b, np.float32)).all()


@pytest.mark.parametrize("arch", LM_ARCHS)
def test_prefill_decode_smoke(arch):
    cfg = reduced_config(arch)
    batch, s0, s_kv = 2, 8, 16
    params = tf.init_params(cfg, jax.random.PRNGKey(1))
    caches = tf.init_caches(cfg, batch, s_kv)
    tokens = jnp.arange(batch * s0, dtype=jnp.int32).reshape(batch, s0) % cfg.vocab_size
    ex = _extras(cfg, batch, s0)
    logits, caches = tf.forward(params, cfg, tokens, mode="prefill",
                                caches=caches, **ex)
    assert logits.shape == (batch, s0, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits, np.float32)).all()
    exd = _extras(cfg, batch, 1, decode=True)
    nxt = jnp.argmax(logits[:, -1:, :], -1).astype(jnp.int32)
    logits2, _ = tf.forward(params, cfg, nxt, mode="decode", caches=caches,
                            pos=jnp.int32(s0), **exd)
    assert logits2.shape == (batch, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2, np.float32)).all()


def test_moe_group_equivalence_when_group_is_total():
    """moe_group == total tokens must reproduce the global-group baseline."""
    import dataclasses
    from repro.models.layers import moe_apply, moe_init

    cfg = reduced_config("olmoe-1b-7b")
    key = jax.random.PRNGKey(0)
    p = moe_init(key, cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    t = 2 * 16
    y_global = moe_apply(p, x, dataclasses.replace(cfg, moe_group=0))
    y_same = moe_apply(p, x, dataclasses.replace(cfg, moe_group=t))
    np.testing.assert_allclose(np.asarray(y_global), np.asarray(y_same),
                               rtol=1e-5, atol=1e-5)


def test_moe_blocked_routes_all_tokens_under_capacity():
    """With ample capacity, blocked routing loses no tokens (combine mass)."""
    import dataclasses
    from repro.models.layers import moe_apply, moe_init

    cfg = dataclasses.replace(reduced_config("olmoe-1b-7b"),
                              capacity_factor=8.0, moe_group=16)
    p = moe_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(2), (2, 32, cfg.d_model))
    y = moe_apply(p, x, cfg)
    assert np.isfinite(np.asarray(y)).all()
    assert float(jnp.abs(y).sum()) > 0


def test_banded_local_attention_matches_full():
    """Banded sliding-window path == full-scores-then-mask path."""
    import dataclasses
    from repro.models.layers import attn_init, attn_train
    from repro.models.layers import rope_tables

    cfg = dataclasses.replace(reduced_config("gemma3-27b"),
                              attn_chunk=64, sliding_window=64)
    p = attn_init(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 256, cfg.d_model),
                          jnp.float32).astype(jnp.bfloat16)
    cos, sin = rope_tables(jnp.arange(256), cfg.head_dim, cfg.rope_theta)
    cos, sin = cos[None], sin[None]
    y_band = attn_train(p, x, cfg, cos, sin, window=64, causal=True)
    # force the generic chunked path by making window > chunk ineligible
    cfg_full = dataclasses.replace(cfg, attn_chunk=128)
    y_full = attn_train(p, x, cfg_full, cos, sin, window=64, causal=True)
    np.testing.assert_allclose(np.asarray(y_band, np.float32),
                               np.asarray(y_full, np.float32),
                               rtol=2e-2, atol=2e-2)
