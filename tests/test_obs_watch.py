"""TopoWatch: request context, deadlines, cancellation, SLO engine,
flight recorder, and the HTTP exporter under concurrent scrapes."""
import json
import math
import threading
import time
import urllib.error
import urllib.request

import numpy as np
import pytest

from repro import obs
from repro.obs import flight, slo
from repro.obs.context import (
    DeadlineExceeded,
    current,
    current_request_id,
    new_request_id,
    request_context,
    resolve_submit,
)
from repro.obs.http import loop_health, readiness, start_http_server
from repro.obs.metrics import (
    Histogram,
    MetricsRegistry,
    bucket_count_over,
    bucket_quantile,
)
from repro.serve import TopoServe, TopoServeConfig
from repro.serve.futures import FutureCancelled, ServeFuture

CFG = TopoServeConfig(dim=1, method="prunit", sublevel=False,
                      max_batch=8, pad_batch_to=8)


def _get(url: str, timeout: float = 10.0):
    try:
        with urllib.request.urlopen(url, timeout=timeout) as resp:
            return resp.status, resp.read()
    except urllib.error.HTTPError as e:
        return e.code, e.read()


# ------------------------------------------------------ histogram quantiles

def test_bucket_quantile_uniform_exact():
    # 100 observations spread uniformly over (0, 4] in 4 unit buckets:
    # interpolation recovers the exact uniform quantiles
    bounds = (1.0, 2.0, 3.0, 4.0)
    counts = [25, 25, 25, 25, 0]
    assert bucket_quantile(bounds, counts, 0.5) == pytest.approx(2.0)
    assert bucket_quantile(bounds, counts, 0.25) == pytest.approx(1.0)
    assert bucket_quantile(bounds, counts, 0.875) == pytest.approx(3.5)


def test_bucket_quantile_empty_and_overflow():
    bounds = (1.0, 2.0)
    assert math.isnan(bucket_quantile(bounds, [0, 0, 0], 0.5))
    # everything in +Inf overflow -> clamped to the largest finite bound
    assert bucket_quantile(bounds, [0, 0, 10], 0.99) == pytest.approx(2.0)


def test_bucket_count_over_interpolates():
    bounds = (1.0, 2.0, 3.0)
    counts = [10, 10, 10, 0]
    # threshold mid-bucket: half of the containing bucket + all above
    assert bucket_count_over(bounds, counts, 1.5) == pytest.approx(15.0)
    assert bucket_count_over(bounds, counts, 3.0) == pytest.approx(0.0)


def test_histogram_quantile_matches_numpy():
    # uniform samples + uniform-in-bucket interpolation: the estimate must
    # track np.quantile to within one bucket width
    rng = np.random.default_rng(7)
    samples = rng.uniform(0.0, 1.0, size=5000)
    edges = tuple(np.linspace(0.02, 1.0, 50))  # width 0.02
    reg = MetricsRegistry()
    h = reg.histogram("t.lat", buckets=edges)
    for s in samples:
        h.observe(float(s))
    for q in (0.1, 0.5, 0.9, 0.99):
        est = h.quantile(q)
        ref = float(np.quantile(samples, q))
        assert abs(est - ref) < 0.02, (q, est, ref)
    # monotone in q
    qs = [h.quantile(q) for q in (0.1, 0.5, 0.9, 0.99)]
    assert qs == sorted(qs)


def test_histogram_quantile_label_superset():
    reg = MetricsRegistry()
    h = reg.histogram("t.lat2", buckets=(1.0, 2.0, 4.0))
    for _ in range(10):
        h.observe(0.5, bucket="a")
    for _ in range(10):
        h.observe(3.0, bucket="b")
    assert h.quantile(0.5, bucket="a") <= 1.0
    assert h.quantile(0.5, bucket="b") > 2.0
    # no labels -> merged over both series
    assert 1.0 <= h.quantile(0.5) <= 4.0
    assert math.isnan(h.quantile(0.5, bucket="zzz"))


# --------------------------------------------------------- request context

def test_request_context_ambient_and_nesting():
    assert current() is None
    with request_context(deadline_s=10.0) as outer:
        assert current_request_id() == outer.request_id
        assert outer.deadline is not None
        # inner without deadline inherits the outer budget
        with request_context() as inner:
            assert inner.request_id != outer.request_id
            assert inner.deadline == outer.deadline
        # explicit inner deadline is clamped to the outer one
        with request_context(deadline_s=10_000.0) as inner2:
            assert inner2.deadline == outer.deadline
        with request_context(deadline_s=0.001) as inner3:
            assert inner3.deadline < outer.deadline
        assert current() is outer
    assert current() is None


def test_resolve_submit_precedence():
    # explicit args win
    rid, dl = resolve_submit("my-rid", None)
    assert rid == "my-rid" and dl is None
    # ambient context supplies both
    with request_context(request_id="ctx-rid", deadline_s=5.0) as ctx:
        rid, dl = resolve_submit(None, None)
        assert rid == "ctx-rid" and dl == ctx.deadline
        # explicit relative deadline still clamped to the ambient one
        rid, dl = resolve_submit(None, 10_000.0)
        assert dl == ctx.deadline
    # no context: fresh mint, no deadline
    rid, dl = resolve_submit(None, None)
    assert rid and dl is None
    assert new_request_id() != new_request_id()


def test_request_context_thread_isolation():
    seen = {}

    def worker():
        seen["rid"] = current_request_id()

    with request_context(request_id="outer-only"):
        t = threading.Thread(target=worker)
        t.start()
        t.join()
    # a fresh thread never inherits the submitter's context
    assert seen["rid"] is None


# ------------------------------------------------- future state transitions

def test_future_cancel_wins_race():
    f = ServeFuture(request_id="r-x")
    assert f.cancel() is True
    assert f.cancelled() and f.done()
    assert f._resolve("late") is False      # drain racing the cancel loses
    assert f._fail(RuntimeError("x")) is False
    assert f.cancel() is False              # second cancel is a no-op
    with pytest.raises(FutureCancelled):
        f.result(timeout=1)


def test_future_resolve_blocks_cancel():
    f = ServeFuture()
    assert f._resolve(42) is True
    assert f.cancel() is False
    assert not f.cancelled()
    assert f.result(timeout=1) == 42


def test_future_expired():
    now = time.monotonic()
    assert not ServeFuture(deadline=None).expired()
    assert ServeFuture(deadline=now - 1).expired()
    assert not ServeFuture(deadline=now + 60).expired()
    assert ServeFuture(deadline=now + 60).expired(now=now + 61)


# --------------------------------------- cancel-leak regression (satellite)

def test_cancelled_request_never_executes():
    """The queued-forever leak: a caller abandons a request (cancel after a
    result timeout) — the drain must skip it, not burn a kernel slot."""
    server = TopoServe(TopoServeConfig(dim=1, method="prunit",
                                       sublevel=False, max_batch=8,
                                       pad_batch_to=8, record_batches=True))
    cancelled_before = server.stats["cancelled"]
    fut = server.submit(edges=[(0, 1), (1, 2)], n_vertices=3)
    with pytest.raises(TimeoutError):
        fut.result(timeout=0.01)            # no drain loop running
    assert fut.cancel()
    with pytest.raises(FutureCancelled):
        fut.result(timeout=1)
    n_batches = server.stats["batches"]
    server.drain()
    # the drain swept the cancelled request without executing anything
    assert server.stats["batches"] == n_batches
    assert server.executed_batches == []
    assert server.pending() == 0
    assert server.stats["cancelled"] == cancelled_before + 1


def test_cancel_mixed_with_live_requests():
    server = TopoServe(CFG)
    live = [server.submit(edges=[(0, 1), (1, 2)], n_vertices=3)
            for _ in range(3)]
    dead = server.submit(edges=[(0, 1), (1, 2)], n_vertices=3)
    dead.cancel()
    server.drain()
    for f in live:
        assert f.result(timeout=30) is not None
    with pytest.raises(FutureCancelled):
        dead.result(timeout=1)


# ------------------------------------------------------------ deadline sweep

def test_deadline_sweep_fails_expired_requests(tmp_path):
    flight.configure(dump_dir=str(tmp_path))
    try:
        server = TopoServe(CFG)
        missed_before = server.stats["deadline_exceeded"]
        expired = server.submit(edges=[(0, 1), (1, 2)], n_vertices=3,
                                deadline_s=0.0)
        ok = server.submit(edges=[(0, 1), (1, 2)], n_vertices=3,
                           deadline_s=60.0)
        time.sleep(0.01)
        server.drain()
        with pytest.raises(DeadlineExceeded):
            expired.result(timeout=1)
        assert ok.result(timeout=30) is not None
        assert server.stats["deadline_exceeded"] == missed_before + 1
        # per-bucket attribution on the shared serve counter
        by_bucket = obs.counter("serve.deadline_exceeded").labeled("bucket")
        assert sum(by_bucket.values()) >= 1
    finally:
        flight.configure(dump_dir="results/obs")


def test_submit_stamps_ambient_request_id():
    server = TopoServe(CFG)
    with request_context(request_id="req-77"):
        fut = server.submit(edges=[(0, 1)], n_vertices=2)
    assert fut.request_id == "req-77"
    explicit = server.submit(edges=[(0, 1)], n_vertices=2,
                             request_id="req-88", deadline_s=60.0)
    assert explicit.request_id == "req-88"
    assert explicit.deadline is not None
    server.drain()
    assert fut.result(timeout=30) is not None


# ------------------------------------------------- SLO engine (synthetic t)

def _err_spec(rules):
    return slo.SLOSpec(name="t-err", kind="error_rate",
                       bad="t.bad", total="t.total",
                       budget=0.01, rules=rules)


def test_slo_engine_breach_and_recovery_synthetic_clock():
    reg = MetricsRegistry()
    bad, total = reg.counter("t.bad"), reg.counter("t.total")
    breaches = []
    engine = slo.SLOEngine(
        [_err_spec((slo.BurnRule(long_s=10.0, short_s=5.0, factor=1.0),))],
        registry=reg, on_breach=lambda name, v: breaches.append(name))
    breach_counter = obs.counter("slo.breaches_total")
    n0 = breach_counter.total(slo="t-err")

    # no traffic yet -> no_data, nothing fires
    st = engine.tick(now=0.0)
    assert st["t-err"]["status"] == "no_data"

    # 50% bad over a 1% budget -> burn 50x on both windows -> breach
    total.inc(100)
    bad.inc(50)
    st = engine.tick(now=1.0)
    assert st["t-err"]["status"] == "breach"
    assert breaches == ["t-err"]
    assert breach_counter.total(slo="t-err") == n0 + 1

    # still breaching: the counter counts TRANSITIONS, not ticks
    st = engine.tick(now=2.0)
    assert st["t-err"]["status"] == "breach"
    assert breach_counter.total(slo="t-err") == n0 + 1
    assert breaches == ["t-err"]

    # clean traffic + windows past the bad burst -> recovery
    total.inc(1000)
    st = engine.tick(now=20.0)
    assert st["t-err"]["status"] == "ok"
    assert breach_counter.total(slo="t-err") == n0 + 1
    assert engine.breached() == []

    # a second distinct breach increments again
    bad.inc(600)
    total.inc(600)
    st = engine.tick(now=21.0)
    assert st["t-err"]["status"] == "breach"
    assert breach_counter.total(slo="t-err") == n0 + 2


def test_slo_multi_window_short_blip_does_not_fire():
    # a burst confined to the long window with a clean short window must
    # NOT fire (the short window proves the problem is still happening)
    reg = MetricsRegistry()
    bad, total = reg.counter("t.bad"), reg.counter("t.total")
    engine = slo.SLOEngine(
        [_err_spec((slo.BurnRule(long_s=100.0, short_s=5.0, factor=1.0),))],
        registry=reg, on_breach=lambda name, v: None)
    engine.tick(now=0.0)
    bad.inc(50)
    total.inc(100)
    engine.tick(now=1.0)                    # burst lands here (breach)
    total.inc(10_000)                       # then a long clean stretch
    engine.tick(now=90.0)
    total.inc(500)                          # clean traffic in short window
    st = engine.tick(now=96.0)              # short window: clean only
    v = st["t-err"]
    assert v["status"] == "ok", v
    r = v["rules"][0]
    assert r["burn_long"] is not None and r["burn_long"] > 0
    assert r["burn_short"] == pytest.approx(0.0)


def test_slo_latency_spec_observed_quantile():
    reg = MetricsRegistry()
    h = reg.histogram("t.lat3", buckets=(0.01, 0.1, 1.0))
    spec = slo.SLOSpec(name="t-lat", kind="latency", histogram="t.lat3",
                       quantile=0.5, ceiling_s=0.1, budget=0.5,
                       rules=(slo.BurnRule(10.0, 5.0, 1.0),))
    engine = slo.SLOEngine([spec], registry=reg,
                           on_breach=lambda name, v: None)
    engine.tick(now=0.0)
    for _ in range(100):
        h.observe(0.5)                      # all observations over ceiling
    st = engine.tick(now=1.0)
    v = st["t-lat"]
    assert v["status"] == "breach"
    assert v["observed_q_s"] > 0.1
    assert v["ceiling_s"] == 0.1


def test_slo_spec_validation():
    with pytest.raises(ValueError):
        slo.SLOSpec(name="x", kind="nope")
    with pytest.raises(ValueError):
        slo.SLOSpec(name="x", kind="latency")  # no histogram/ceiling
    with pytest.raises(ValueError):
        slo.BurnRule(long_s=1.0, short_s=5.0)  # long < short
    with pytest.raises(ValueError):
        slo.SLOEngine([_err_spec(slo.DEFAULT_RULES),
                       _err_spec(slo.DEFAULT_RULES)])  # duplicate names


def test_default_serve_slos_shape():
    specs = slo.default_serve_slos()
    names = [s.name for s in specs]
    assert len(names) == len(set(names))
    assert len(specs) == 2 * 4 + 4  # p50+p99 per bucket + 4 global
    assert "serve-deadline-miss" in names
    assert "stream-skip-rate" in names


def test_install_uninstall_roundtrip():
    reg = MetricsRegistry()
    engine = slo.SLOEngine([_err_spec(slo.DEFAULT_RULES)], registry=reg,
                           on_breach=lambda name, v: None)
    prev = slo.install(engine)
    try:
        assert slo.installed() is engine
        assert "t-err" in slo.slo_status(tick=True)
    finally:
        assert slo.install(prev) is engine
    if prev is None:
        assert slo.slo_status() == {}


# ------------------------------------------------------------ flight recorder

def test_flight_ring_bounded_and_ordered():
    flight.configure(capacity=8)
    try:
        def worker():
            for i in range(30):
                flight.record("test", f"ev-{i}", i=i)

        t = threading.Thread(target=worker, name="flight-capacity-probe")
        t.start()
        t.join()
        mine = [e for e in flight.events()
                if e["thread"] == "flight-capacity-probe"]
        assert len(mine) == 8               # bounded by the configured cap
        assert [e["name"] for e in mine] == [f"ev-{i}" for i in
                                             range(22, 30)]  # newest kept
        seqs = [e["seq"] for e in flight.events()]
        assert seqs == sorted(seqs)         # global total order
    finally:
        flight.configure(capacity=512)


def test_flight_dump_roundtrip(tmp_path):
    flight.record("test", "dump-probe", answer=42)
    path = flight.dump("unit-test", path=str(tmp_path / "FLIGHT_t.json"))
    assert flight.last_dump_path() == path
    with open(path) as fh:
        doc = json.load(fh)
    assert doc["schema"] == 1
    assert doc["reason"] == "unit-test"
    assert any(e["name"] == "dump-probe" and e["attrs"]["answer"] == 42
               for e in doc["events"])
    assert "metrics" in doc and "slo" in doc


def test_flight_auto_dump_rate_limited(tmp_path):
    flight.clear()
    flight.configure(dump_dir=str(tmp_path), min_dump_interval_s=3600.0)
    try:
        flight.record("test", "incident")
        p1 = flight.auto_dump("first")
        assert p1 is not None
        assert flight.auto_dump("second") is None  # within the interval
        assert flight.last_dump_path() == p1
    finally:
        flight.configure(dump_dir="results/obs", min_dump_interval_s=30.0)
        flight.clear()


# ------------------------------------------------------------- HTTP exporter

def test_loop_health_and_readiness_logic():
    reg = MetricsRegistry()
    assert loop_health(reg)["status"] == "no_loops"
    assert readiness(reg)["status"] == "not_ready"
    hb = reg.gauge("serve.heartbeat_ts")
    hb.set(time.time(), frontend="topo", instance="t-0")
    h = loop_health(reg, max_age_s=5.0)
    assert h["status"] == "ok" and "topo/t-0" in h["loops"]
    hb.set(time.time() - 100, frontend="topo", instance="t-0")
    h = loop_health(reg, max_age_s=5.0)
    assert h["status"] == "stale" and h["stale"] == ["topo/t-0"]
    rdy = reg.gauge("serve.ready")
    rdy.set(1, frontend="topo", instance="t-0")
    assert readiness(reg)["status"] == "ready"
    rdy.set(0, frontend="topo", instance="t-0")
    assert readiness(reg)["status"] == "not_ready"


def test_http_endpoints_fresh_registry():
    reg = MetricsRegistry()
    reg.counter("unit.c").inc(3, kind="x")
    hb = reg.gauge("serve.heartbeat_ts")
    srv = start_http_server(port=0, registry=reg, health_max_age_s=1.0)
    try:
        code, body = _get(srv.url + "/metrics")
        assert code == 200
        assert "# TYPE unit_c_total counter" in body.decode()
        code, _ = _get(srv.url + "/readyz")
        assert code == 503                  # nothing warmed on this registry
        code, body = _get(srv.url + "/healthz")
        assert code == 200                  # no loops -> alive
        assert json.loads(body)["status"] == "no_loops"
        hb.set(time.time() - 100, frontend="topo", instance="t-0")
        code, body = _get(srv.url + "/healthz")
        assert code == 503
        assert json.loads(body)["status"] == "stale"
        code, body = _get(srv.url + "/varz")
        assert code == 200
        assert "unit.c" in json.loads(body)["metrics"]
        code, body = _get(srv.url + "/slo")
        assert code == 200 and "status" in json.loads(body)
        code, body = _get(srv.url + "/nope")
        assert code == 404
        code, body = _get(srv.url + "/")
        assert "/metrics" in json.loads(body)["endpoints"]
    finally:
        srv.stop()


def _assert_prom_parseable(text: str) -> int:
    n = 0
    for line in text.splitlines():
        if not line or line.startswith("#"):
            continue
        name_part, _, value_part = line.rpartition(" ")
        assert name_part, line
        float(value_part)                   # must parse as a sample value
        n += 1
    return n


def test_metrics_scrape_concurrent_with_drains():
    """8 scrapers hammering /metrics while drains mutate the registry:
    every response must be complete, parseable Prometheus text."""
    server = TopoServe(CFG)
    srv = start_http_server(port=0)
    stop = threading.Event()
    errors: list[BaseException] = []
    n_scrapes = [0] * 8

    def scraper(i: int):
        while not stop.is_set():
            try:
                code, body = _get(srv.url + "/metrics", timeout=10)
                assert code == 200
                assert _assert_prom_parseable(body.decode()) > 0
                n_scrapes[i] += 1
            except BaseException as e:  # noqa: BLE001 - collected for report
                errors.append(e)
                return

    threads = [threading.Thread(target=scraper, args=(i,), daemon=True)
               for i in range(8)]
    try:
        for t in threads:
            t.start()
        for _ in range(4):                  # drains mutate counters mid-scrape
            futs = [server.submit(edges=[(0, 1), (1, 2), (2, 0)],
                                  n_vertices=3) for _ in range(6)]
            server.drain()
            for f in futs:
                f.result(timeout=60)
    finally:
        stop.set()
        for t in threads:
            t.join(timeout=10)
        srv.stop()
    assert not errors, errors[0]
    assert all(n > 0 for n in n_scrapes), n_scrapes
