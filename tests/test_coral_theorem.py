"""Property test of the paper's Theorem 2 (CoralTDA exactness).

For random graphs and random integer filtering functions:
    PD_j(G, f) == PD_j(G^{k+1}, f)   for all j >= k >= 1
computed with the exact NumPy oracle.
"""
import networkx as nx
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # optional [dev] extra; skip module without
from hypothesis import given, settings, strategies as st

from repro.core import GraphBatch, coral_reduce
from repro.core.persistence_ref import diagrams_equal, persistence_diagrams
from tests.conftest import graphs_to_batch


@st.composite
def graph_and_f(draw, n_min=4, n_max=14):
    n = draw(st.integers(n_min, n_max))
    p = draw(st.floats(0.2, 0.7))
    seed = draw(st.integers(0, 2**31 - 1))
    g = nx.gnp_random_graph(n, p, seed=seed)
    f = draw(st.lists(st.integers(0, 5), min_size=n, max_size=n))
    return g, np.asarray(f, dtype=np.float32)


@settings(max_examples=25, deadline=None)
@given(graph_and_f(), st.integers(1, 2))
def test_coral_exact_for_pd_k(gf, k):
    G, f = gf
    batch = graphs_to_batch([G])
    import jax.numpy as jnp

    fv = jnp.where(batch.mask, jnp.asarray(f)[None, : batch.n], jnp.inf)
    g = GraphBatch(adj=batch.adj, mask=batch.mask, f=fv)
    gr = coral_reduce(g, k)

    ref = persistence_diagrams(
        np.asarray(g.adj[0]), np.asarray(g.f[0]), np.asarray(g.mask[0]), max_dim=k
    )
    red = persistence_diagrams(
        np.asarray(gr.adj[0]), np.asarray(gr.f[0]), np.asarray(gr.mask[0]), max_dim=k
    )
    # Theorem 2: equality at dimension j = k (and above).
    assert diagrams_equal({k: ref.get(k, [])}, {k: red.get(k, [])}), (ref, red)


@settings(max_examples=10, deadline=None)
@given(graph_and_f(n_min=5, n_max=11))
def test_coral_degree_filtration(gf):
    # The paper's own experimental setting: degree function, sublevel.
    G, _ = gf
    g = graphs_to_batch([G])  # degree filtration by default
    gr = coral_reduce(g, 1)
    ref = persistence_diagrams(
        np.asarray(g.adj[0]), np.asarray(g.f[0]), np.asarray(g.mask[0]), max_dim=1
    )
    red = persistence_diagrams(
        np.asarray(gr.adj[0]), np.asarray(gr.f[0]), np.asarray(gr.mask[0]), max_dim=1
    )
    assert diagrams_equal({1: ref.get(1, [])}, {1: red.get(1, [])})


def test_coral_higher_dims_trivial_on_sparse():
    # Fig 4's "100% reduction at k>=4" phenomenon: sparse graphs have empty
    # 5-cores, so PD_4 is trivial — and coral detects it structurally.
    G = nx.barabasi_albert_graph(40, 2, seed=0)
    g = graphs_to_batch([G])
    gr = coral_reduce(g, 4)
    assert int(np.asarray(gr.n_vertices())[0]) == 0
