"""Collapsed forward/reverse auction solver + price cache (ISSUE 9).

The tentpole contracts:

* the reservoir-collapsed K×K formulation reaches the same optimum as the
  expanded (2K)² matrix — **bit-for-bit** on the named degenerate inputs
  (empty diagrams, all-on-diagonal, single point vs large diagram), where
  the optimum is unique, and within f32 tolerance on random inputs (both
  matchings are ε-optimal; tie-breaks may differ by an ulp);
* ``expand_collapsed_assignment`` always produces a valid permutation
  whose expanded-matrix cost equals the collapsed total;
* warm-starting from *any* nonnegative price vector preserves optimality
  (the reverse phase re-grounds stale prices);
* the f32 price-resolution stall detector terminates the per-scale loop
  (regression pin for the PR 5 livelock);
* the serve-level price cache LRU round-trips converged vectors and the
  ``stage1_backend="exact_w"`` drain resolves with exact backends.

Rides the conftest ``hypothesis_or_stub`` shim: without hypothesis the
property test skips cleanly and the plain tests still run.
"""
from __future__ import annotations

import functools

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from tests.conftest import hypothesis_or_stub

from repro.kernels import ops, ref as kref, tuning
from repro.kernels.auction_lap import (
    auction_solve,
    auction_solve_collapsed,
    expand_collapsed_assignment,
)
from repro.metrics import reference as mref
from repro.metrics.engine import compare, compare_info
from repro.metrics.exact import (
    augmented_cost,
    collapsed_cost,
    exact_w,
    exact_w_info,
)
from repro.metrics.price_cache import PriceCache
from repro.metrics.testing import diagram_points, random_diagram

given, settings, st = hypothesis_or_stub()

CAP = 64.0


def _solve_both(b1, e1, k1, b2, e2, k2, ground="l2"):
    """Solve one cloud pair both ways; return totals + the expanded
    evaluation of the collapsed assignment (all plain floats)."""
    b1, e1, b2, e2 = (jnp.asarray(x, jnp.float32) for x in (b1, e1, b2, e2))
    k1 = jnp.asarray(k1, bool)
    k2 = jnp.asarray(k2, bool)
    cbar, base = collapsed_cost(b1, e1, k1, b2, e2, k2, ground=ground)
    p2o, red, conv, _, _ = auction_solve_collapsed(cbar, k1, k2)
    assert bool(conv)
    cost = augmented_cost(b1, e1, k1, b2, e2, k2, ground=ground)
    _, tot_exp, conv_e, _ = auction_solve(cost)
    assert bool(conv_e)
    perm = np.asarray(expand_collapsed_assignment(p2o, k1, k2))
    assert sorted(perm.tolist()) == list(range(perm.shape[0]))
    evaluated = float(jnp.sum(cost[jnp.arange(perm.shape[0]), perm]))
    return float(base + red), float(tot_exp), evaluated


def test_degenerate_bitforbit_empty():
    k = 8
    z = np.zeros(k, np.float32)
    none = np.zeros(k, bool)
    tot_c, tot_e, ev = _solve_both(z, z, none, z, z, none)
    assert tot_c == tot_e == ev == 0.0


def test_degenerate_bitforbit_all_on_diagonal():
    # every point has zero persistence: diag costs 0, everything drops to
    # the reservoir at exactly 0 in both formulations
    k = 8
    b1 = np.linspace(0.0, 2.0, k).astype(np.float32)
    b2 = np.linspace(0.5, 3.0, k).astype(np.float32)
    all_k = np.ones(k, bool)
    tot_c, tot_e, ev = _solve_both(b1, b1, all_k, b2, b2, all_k)
    assert tot_c == tot_e == ev == 0.0


def test_degenerate_bitforbit_single_vs_large():
    # one real point vs a full diagram, dyadic coordinates under the linf
    # ground metric (diag = pers/2, no √2): every cost entry and every
    # partial sum is exact in f32 and the optimum is unique, so the
    # collapsed total, the expanded optimum, and the expanded evaluation
    # of the reconstructed assignment must agree bit-for-bit
    k = 8
    b1 = np.zeros(k, np.float32)
    e1 = np.zeros(k, np.float32)
    b1[0], e1[0] = 1.0, 3.0
    k1 = np.zeros(k, bool)
    k1[0] = True
    b2 = np.asarray([1.0, 4.0, 0.5, 2.0, 8.0, 1.5, 0.25, 6.0], np.float32)
    e2 = b2 + np.asarray([2.5, 1.0, 0.5, 4.0, 2.0, 0.75, 0.25, 1.0],
                         np.float32)
    k2 = np.ones(k, bool)
    tot_c, tot_e, ev = _solve_both(b1, e1, k1, b2, e2, k2, ground="linf")
    assert tot_c == ev, (tot_c, ev)
    assert tot_c == tot_e, (tot_c, tot_e)
    # and it is the true optimum
    want = mref.wasserstein_exact([(1.0, 3.0)], list(zip(b2, e2)), q=2.0,
                                  ground="linf")
    assert abs(tot_c ** 0.5 - want) <= 1e-5


def test_collapsed_matches_expanded_random():
    rng = np.random.default_rng(23)
    for _ in range(20):
        k = 10
        n1, n2 = int(rng.integers(0, k + 1)), int(rng.integers(0, k + 1))
        b1 = rng.uniform(0, 3, k).astype(np.float32)
        e1 = (b1 + rng.uniform(0.01, 3, k)).astype(np.float32)
        b2 = rng.uniform(0, 3, k).astype(np.float32)
        e2 = (b2 + rng.uniform(0.01, 3, k)).astype(np.float32)
        k1 = np.arange(k) < n1
        k2 = np.arange(k) < n2
        tot_c, tot_e, ev = _solve_both(b1, e1, k1, b2, e2, k2)
        # the reconstructed assignment must evaluate to the collapsed
        # total exactly; the two independent solves agree to f32 roundoff
        assert tot_c == pytest.approx(ev, abs=1e-5)
        assert tot_c == pytest.approx(tot_e, abs=1e-4)


def test_warm_start_any_nonneg_prices_stays_optimal():
    rng = np.random.default_rng(29)
    k = 12
    cbar = jnp.asarray(rng.uniform(-2, 2, (k, k)).astype(np.float32))
    k1 = jnp.asarray(np.arange(k) < 9)
    k2 = jnp.asarray(np.arange(k) < 7)
    _, red0, conv0, _, price = auction_solve_collapsed(cbar, k1, k2)
    assert bool(conv0)
    for price0 in (price,                                     # converged
                   jnp.asarray(rng.uniform(0, 5, k), jnp.float32),  # junk
                   jnp.full((k,), 100.0, jnp.float32)):       # stale-high
        _, red, conv, _, _ = auction_solve_collapsed(cbar, k1, k2, price0)
        assert bool(conv)
        assert float(red) == pytest.approx(float(red0), abs=1e-5)


def test_stall_detector_terminates_f32_livelock():
    # regression pin for the PR 5 f32 price-resolution livelock: an ε far
    # below the f32 resolution of the prices means bids can stop moving
    # the price vector entirely; the stall detector must still terminate
    # the scale loop and return a feasible matching
    rng = np.random.default_rng(31)
    k = 8
    cbar = jnp.asarray((rng.uniform(-1, 1, (k, k)) * 1e6).astype(np.float32))
    k1 = jnp.asarray(np.ones(k, bool))
    k2 = jnp.asarray(np.ones(k, bool))
    p2o, red, conv, rounds, _ = auction_solve_collapsed(
        cbar, k1, k2, eps0=1e-12, eps_factor=1.0, n_scales=1)
    assert int(rounds) > 0  # it ran…
    p2o = np.asarray(p2o)
    owned = p2o[p2o >= 0]
    assert len(set(owned.tolist())) == len(owned)  # …to a feasible matching
    assert np.isfinite(float(red))


def test_collapsed_kernel_matches_jnp_oracle():
    rng = np.random.default_rng(17)
    b, k = 12, 16
    cbar = jnp.asarray(rng.uniform(-3, 3, (b, k, k)).astype(np.float32))
    k1 = jnp.asarray(np.arange(k)[None, :] < rng.integers(0, k + 1, (b, 1)))
    k2 = jnp.asarray(np.arange(k)[None, :] < rng.integers(0, k + 1, (b, 1)))
    p_k, tot_k, conv_k, _, price_k = ops.auction_lap_collapsed(cbar, k1, k2)
    # the ops wrapper resolves rev_every through the tuning registry (an
    # autotune sweep axis) — the oracle must solve the same phase schedule
    # for bit-equality to be meaningful
    rev = int(tuning.resolve_tiles("auction_collapsed")["rev_every"])
    p_r, tot_r, conv_r, _, price_r = jax.vmap(
        functools.partial(kref.auction_lap_collapsed_ref,
                          rev_every=rev))(cbar, k1, k2)
    np.testing.assert_array_equal(np.asarray(p_k), np.asarray(p_r))
    np.testing.assert_array_equal(np.asarray(tot_k), np.asarray(tot_r))
    np.testing.assert_array_equal(np.asarray(conv_k), np.asarray(conv_r))
    np.testing.assert_array_equal(np.asarray(price_k), np.asarray(price_r))


def test_exact_w_collapse_modes_agree_and_validate():
    rng = np.random.default_rng(19)
    pairs = [(random_diagram(rng, essential=int(rng.integers(0, 3))),
              random_diagram(rng)) for _ in range(12)]
    d1 = jax.tree.map(lambda *xs: jnp.stack(xs), *[a for a, _ in pairs])
    d2 = jax.tree.map(lambda *xs: jnp.stack(xs), *[b for _, b in pairs])
    w_on, conv_on, r_on = exact_w_info(d1, d2, k=1, n_points=16,
                                       collapse="on")
    w_off, conv_off, r_off = exact_w_info(d1, d2, k=1, n_points=16,
                                          collapse="off")
    assert bool(np.asarray(conv_on).all() and np.asarray(conv_off).all())
    np.testing.assert_allclose(np.asarray(w_on), np.asarray(w_off),
                               atol=1e-5)
    # the perf_opt point: far fewer bidding rounds on the collapsed path
    assert np.asarray(r_on).mean() * 5 < np.asarray(r_off).mean()
    for i, (a, b) in enumerate(pairs):
        want = mref.wasserstein_exact(diagram_points(a, 1, CAP),
                                      diagram_points(b, 1, CAP), q=2.0)
        assert abs(float(np.asarray(w_on)[i]) - want) <= 1e-5
    with pytest.raises(ValueError, match="unknown collapse"):
        exact_w(d1, d2, k=1, collapse="bogus")


def test_compare_info_entry_and_warm_start_roundtrip():
    rng = np.random.default_rng(41)
    pairs = [(random_diagram(rng), random_diagram(rng)) for _ in range(4)]
    d1 = jax.tree.map(lambda *xs: jnp.stack(xs), *[a for a, _ in pairs])
    d2 = jax.tree.map(lambda *xs: jnp.stack(xs), *[b for _, b in pairs])
    w, conv, rounds, prices = compare_info(d1, d2, metric="exact_w", k=1,
                                           cap=CAP, n_points=16)
    assert prices.shape == (4, 16) and bool(np.asarray(conv).all())
    w2, conv2, _, _ = compare_info(d1, d2, metric="exact_w", k=1, cap=CAP,
                                   n_points=16, prices=prices)
    np.testing.assert_allclose(np.asarray(w2), np.asarray(w), atol=1e-5)
    np.testing.assert_allclose(
        np.asarray(w),
        np.asarray(compare(d1, d2, metric="exact_w", k=1, cap=CAP,
                           n_points=16)), atol=1e-6)
    with pytest.raises(ValueError, match="no diagnostics"):
        compare_info(d1, d2, metric="sw")
    with pytest.raises(ValueError, match="does not accept"):
        compare_info(d1, d2, metric="exact_w", n_dirs=4)


def test_price_cache_lru_roundtrip():
    cache = PriceCache(capacity=3, instance="test-pc")
    codes = np.asarray([[1, 2], [3, 4]], np.uint8)       # 2 queries
    rows = np.asarray([[0, 1], [0, 2]])                  # 2 candidates each
    p0, hits, misses = cache.lookup(codes, rows, 4)
    assert p0.shape == (2, 2, 4) and hits == 0 and misses == 4
    prices = np.arange(16, dtype=np.float32).reshape(2, 2, 4)
    conv = np.asarray([[True, True], [True, False]])
    assert cache.store(codes, rows, prices, conv) == 3   # unconverged skipped
    p1, hits, misses = cache.lookup(codes, rows, 4)
    assert hits == 3 and misses == 1
    np.testing.assert_array_equal(p1[0], prices[0])
    np.testing.assert_array_equal(p1[1, 0], prices[1, 0])
    np.testing.assert_array_equal(p1[1, 1], 0.0)         # never stored
    # capacity eviction: a fourth distinct key evicts the LRU entry
    cache.store(np.asarray([[9, 9]], np.uint8), np.asarray([[7]]),
                np.ones((1, 1, 4), np.float32), np.asarray([[True]]))
    assert len(cache) == 3
    with pytest.raises(ValueError, match="capacity"):
        PriceCache(capacity=0)


def test_stage1_backend_exact_w_serve():
    from repro.serve.similarity import SimilarityServe

    rng = np.random.default_rng(43)

    def graph(seed):
        r = np.random.default_rng(seed)
        n = 10
        edges = [(i, j) for i in range(n) for j in range(i + 1, n)
                 if r.uniform() < 0.35]
        return dict(edges=edges, n_vertices=n, f=r.uniform(0, 1, n).tolist())

    with pytest.raises(ValueError, match="unknown stage1_backend"):
        SimilarityServe(stage1_backend="bogus")
    srv = SimilarityServe(stage1_backend="exact_w", rerank="off")
    gs = [graph(s) for s in range(5)]
    for i, g in enumerate(gs):
        srv.add(gid=f"g{i}", **g)
    srv.drain()
    fut = srv.submit(k=3, **gs[2])
    srv.drain()
    res = fut.result(timeout=30)
    assert res.ids[0] == "g2" and abs(res.distances[0]) < 1e-6
    assert res.backends == ("exact_w",) * 3
    st1 = srv.stats
    assert st1["stage1_candidates"] == 5 and st1["auction_rounds"] > 0
    # same bucket second time around: the price cache warm-starts
    fut2 = srv.submit(k=3, **gs[2])
    srv.drain()
    assert fut2.result(timeout=30).ids == res.ids
    assert srv.stats["warm_start_hits"] >= 5


def _cloud_strategy(st):
    f32 = st.floats(0.0, 4.0, width=32, allow_nan=False)
    return st.lists(st.tuples(f32, st.floats(0.01, 4.0, width=32)),
                    min_size=0, max_size=8)


@given(hypothesis_or_stub()[2].data())
@settings(max_examples=25, deadline=None)
def test_property_collapsed_equals_expanded(data):
    """Property: collapsed and expanded optima agree on arbitrary clouds."""
    st_ = hypothesis_or_stub()[2]
    pts1 = data.draw(_cloud_strategy(st_), label="pts1")
    pts2 = data.draw(_cloud_strategy(st_), label="pts2")
    k = 8

    def pack(pts):
        b = np.zeros(k, np.float32)
        e = np.zeros(k, np.float32)
        m = np.zeros(k, bool)
        for i, (birth, pers) in enumerate(pts[:k]):
            b[i], e[i], m[i] = birth, birth + pers, True
        return b, e, m
    b1, e1, k1 = pack(pts1)
    b2, e2, k2 = pack(pts2)
    tot_c, tot_e, ev = _solve_both(b1, e1, k1, b2, e2, k2)
    scale = max(abs(tot_e), 1.0)
    assert tot_c == pytest.approx(ev, abs=1e-4 * scale)
    assert tot_c == pytest.approx(tot_e, abs=1e-4 * scale)
    want = mref.wasserstein_exact(list(zip(b1[k1], e1[k1])),
                                  list(zip(b2[k2], e2[k2])), q=2.0) ** 2.0
    assert tot_c == pytest.approx(want, abs=1e-3 * scale)
