"""PrunIT domination detection + batch-removal structure."""
import jax
import networkx as nx
import numpy as np

from repro.core import domination_matrix, prunit
from tests.conftest import graphs_to_batch, random_graphs


def naive_domination(adj, mask):
    n = adj.shape[0]
    dom = np.zeros((n, n), bool)
    for u in range(n):
        if not mask[u]:
            continue
        nu = set(np.nonzero(adj[u] & mask)[0]) | {u}
        for v in range(n):
            if v == u or not mask[v]:
                continue
            nv = set(np.nonzero(adj[v] & mask)[0]) | {v}
            dom[u, v] = nu <= nv
    return dom


def test_domination_vs_naive():
    gs = random_graphs("er", 5, seed=3) + random_graphs("ba", 3, seed=4)
    g = graphs_to_batch(gs)
    dom = np.asarray(domination_matrix(g.adj, g.mask))
    for i in range(len(gs)):
        adj = np.asarray(g.adj[i])
        mask = np.asarray(g.mask[i])
        assert (dom[i] == naive_domination(adj, mask)).all()


def test_figure3_example():
    # Paper Fig 3: vertex 3 (0-indexed: 2) dominates vertices 1 and 2 (0, 1).
    G = nx.Graph([(0, 2), (1, 2), (0, 1), (2, 3), (1, 3)])
    # construct: N[0]={0,1,2}, N[1]={0,1,2,3}, N[2]={0,1,2,3}, N[3]={1,2,3}
    g = graphs_to_batch([G])
    dom = np.asarray(domination_matrix(g.adj, g.mask))[0]
    assert dom[0, 1] and dom[0, 2]  # 0 dominated by 1 and 2
    assert dom[3, 1] and dom[3, 2]
    assert not dom[1, 0] and not dom[2, 3]


def test_prunit_star_collapses_to_core():
    # A star: every leaf is dominated by the hub; superlevel degree filtration
    # lets all leaves go (Remark 8), leaving hub + one leaf at most.
    g = graphs_to_batch([nx.star_graph(9)])
    gp = prunit(g, sublevel=False)
    assert int(np.asarray(gp.n_vertices())[0]) <= 2


def test_prunit_never_removes_below_floor():
    # Pruning a cycle: no vertex dominates another on C_n (n>=4); nothing
    # should be removed.
    g = graphs_to_batch([nx.cycle_graph(8)])
    gp = prunit(g, sublevel=False)
    assert int(np.asarray(gp.n_vertices())[0]) == 8


def test_prunit_idempotent():
    gs = random_graphs("ba", 4, seed=9)
    g = graphs_to_batch(gs)
    g1 = prunit(g, sublevel=False)
    g2 = prunit(g1, sublevel=False)
    assert (np.asarray(g1.mask) == np.asarray(g2.mask)).all()


def test_prunit_jit_vmap_composable():
    gs = random_graphs("er", 3, seed=11)
    g = graphs_to_batch(gs)
    out = jax.jit(lambda gb: prunit(gb, sublevel=False).mask)(g)
    assert out.shape == g.mask.shape
