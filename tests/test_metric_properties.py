"""Property tests for the MetricEngine registry contracts (ISSUE 6 sat a).

Hypothesis-driven metric-space properties over random Diagrams tensors —
``compare(d, d) == 0``, symmetry, and exact-flagged backends agreeing with
the host Hungarian oracle — plus plain contract tests that always run.
Rides the conftest ``hypothesis_or_stub`` shim: without hypothesis the
property tests skip cleanly and the plain tests still collect.
"""
from __future__ import annotations

import dataclasses

import numpy as np
import pytest

from tests.conftest import hypothesis_or_stub

from repro.metrics import engine
from repro.metrics.engine import (
    METRIC_REGISTRY,
    MetricBackend,
    compare,
    get_metric,
    metric_params,
    register_metric,
)
from repro.metrics.reference import wasserstein_exact
from repro.metrics.testing import diagram_points, random_diagram

given, settings, st = hypothesis_or_stub()

CAP = 64.0
# keep diagrams within every backend's default working width (n_points=16)
# so "exact up to top-n_points compaction" means exact, full stop
MAX_PTS = 8
SLOTS = 12

# slack per backend for the self-distance / symmetry properties: sw and
# exact_w are deterministic reductions (f32 roundoff only); sinkhorn is
# debiased (self-distance exactly 0 by construction) but symmetric only up
# to its convergence tolerance; bottleneck bisection resolves ~1e-7·cap
_SELF_ATOL = {"sw": 1e-4, "sinkhorn": 1e-3, "exact_w": 1e-4,
              "bottleneck_approx": 1e-3}
_SYM_ATOL = dict(_SELF_ATOL, sinkhorn=5e-3)


def _diagram(seed: int, n=None):
    return random_diagram(np.random.default_rng(seed), s=SLOTS, n=n)


@given(seed=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_self_distance_is_zero(seed):
    d = _diagram(seed)
    for name in sorted(METRIC_REGISTRY):
        v = float(compare(d, d, metric=name, cap=CAP))
        assert abs(v) <= _SELF_ATOL.get(name, 1e-3), name


@given(s1=st.integers(0, 2**31 - 1), s2=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_symmetry(s1, s2):
    d1, d2 = _diagram(s1), _diagram(s2)
    for name in sorted(METRIC_REGISTRY):
        a = float(compare(d1, d2, metric=name, cap=CAP))
        b = float(compare(d2, d1, metric=name, cap=CAP))
        tol = _SYM_ATOL.get(name, 1e-3)
        assert a == pytest.approx(b, abs=tol, rel=1e-3), name


@given(s1=st.integers(0, 2**31 - 1), s2=st.integers(0, 2**31 - 1))
@settings(max_examples=10, deadline=None)
def test_exact_backends_match_host_oracle(s1, s2):
    """Every ``exact=True`` backend must reproduce the Hungarian oracle
    on diagrams small enough that its compaction is lossless."""
    rng = np.random.default_rng(s1 ^ (s2 << 1))
    d1 = _diagram(s1, n=int(rng.integers(0, MAX_PTS + 1)))
    d2 = _diagram(s2, n=int(rng.integers(0, MAX_PTS + 1)))
    p1, p2 = diagram_points(d1, cap=CAP), diagram_points(d2, cap=CAP)
    want = wasserstein_exact(p1, p2, q=2.0, ground="l2")
    for name, be in sorted(METRIC_REGISTRY.items()):
        if not be.exact:
            continue
        got = float(compare(d1, d2, metric=name, cap=CAP, q=2.0))
        assert got == pytest.approx(want, abs=1e-3, rel=1e-3), name


# ------------------------------------------------------- plain contract tests

def test_every_backend_declares_its_contract():
    for name, be in METRIC_REGISTRY.items():
        assert be.name == name
        assert be.error_bound.strip(), name
        assert be.cost_class.strip(), name
        assert be.params, name
        assert metric_params(name) == be.params


def test_unknown_backend_and_param_rejected():
    with pytest.raises(ValueError, match="unknown metric backend"):
        get_metric("nope")
    with pytest.raises(ValueError, match="does not accept"):
        d = _diagram(0)
        compare(d, d, metric="sw", definitely_not_a_param=3)


def test_duplicate_registration_rejected():
    be = METRIC_REGISTRY["sw"]
    with pytest.raises(ValueError, match="already registered"):
        register_metric(be)
    # overwrite=True is the sanctioned escape hatch; restore the original
    register_metric(dataclasses.replace(be, description="tmp"),
                    overwrite=True)
    register_metric(be, overwrite=True)
    assert METRIC_REGISTRY["sw"] is be


def test_defaults_validated_against_params():
    bad = MetricBackend(
        name="_tmp_bad", fn=engine.sliced_wasserstein, exact=False,
        error_bound="x", cost_class="x", defaults={"no_such_param": 1})
    with pytest.raises(ValueError, match="not accepted by backend"):
        register_metric(bad)
    assert "_tmp_bad" not in METRIC_REGISTRY
