"""Unit tests for ``benchmarks/common.py::write_suite_json`` (ISSUE 6 sat b).

The BENCH_*.json files are both the CI-asserted contract (the tier-1 job
greps specific fields) and PerfGate's reference store — so the schema,
the previous-run delta computation, and the ``git_rev`` dirty stamping
get locked down here.
"""
from __future__ import annotations

import json
import subprocess

import pytest

from benchmarks.common import Report, git_rev, write_suite_json


def _load(out_dir, suite):
    with open(f"{out_dir}/BENCH_{suite}.json") as f:
        return json.load(f)


def test_first_write_schema(tmp_path):
    rows = [("b1", "t_s", 1.25), ("b1", "failed", 0.0)]
    path = write_suite_json(str(tmp_path), "x", "desc", rows,
                            wall_s=3.14159, quick=True, ok=True)
    payload = json.loads(open(path).read())
    # the fields CI and the PerfGate reference store key off
    assert payload["suite"] == "x"
    assert payload["description"] == "desc"
    assert payload["quick"] is True and payload["ok"] is True
    assert payload["wall_s"] == pytest.approx(3.1416)
    assert "git_rev" in payload  # may be None outside a checkout
    assert payload["rows"] == [
        {"benchmark": "b1", "metric": "t_s", "value": 1.25},
        {"benchmark": "b1", "metric": "failed", "value": 0.0},
    ]
    assert set(payload["meta"]) >= {"jax", "backend", "python"}
    # no previous run -> no previous/deltas blocks
    assert "previous" not in payload and "deltas" not in payload


def test_second_write_folds_previous_and_deltas(tmp_path):
    out = str(tmp_path)
    write_suite_json(out, "x", "d", [("b", "t_s", 2.0), ("b", "n", 5.0)],
                     wall_s=1.0, quick=False, ok=True)
    write_suite_json(out, "x", "d",
                     [("b", "t_s", 3.0), ("b", "fresh_metric", 1.0)],
                     wall_s=2.0, quick=True, ok=False)
    payload = _load(out, "x")
    assert payload["previous"] == {"git_rev": git_rev(), "quick": False,
                                   "ok": True, "wall_s": 1.0}
    # deltas only for metrics present in both runs
    assert payload["deltas"] == [
        {"benchmark": "b", "metric": "t_s", "value": 3.0, "prev": 2.0,
         "delta": 1.0}]
    assert payload["quick"] is True and payload["ok"] is False


def test_corrupt_previous_file_tolerated(tmp_path):
    path = tmp_path / "BENCH_x.json"
    path.write_text("{definitely not json")
    write_suite_json(str(tmp_path), "x", "d", [("b", "t_s", 1.0)],
                     wall_s=0.1, quick=False)
    payload = _load(str(tmp_path), "x")
    assert payload["rows"] and "previous" not in payload


def _git(cwd, *args):
    subprocess.run(["git", "-c", "user.email=t@t", "-c", "user.name=t",
                    *args], cwd=cwd, check=True, capture_output=True)


def test_git_rev_dirty_stamping_excludes_results(tmp_path):
    repo = tmp_path / "scratch"
    repo.mkdir()
    (repo / "code.py").write_text("x = 1\n")
    (repo / "results").mkdir()
    (repo / "results" / "BENCH_x.json").write_text("{}\n")
    _git(repo, "init", "-q")
    _git(repo, "add", "-A")
    _git(repo, "commit", "-qm", "seed")

    clean = git_rev(cwd=str(repo))
    assert clean and "-dirty" not in clean

    # a bench run rewriting results/ must NOT mark the code as dirty
    (repo / "results" / "BENCH_x.json").write_text('{"rows": []}\n')
    assert git_rev(cwd=str(repo)) == clean

    # ... but touching actual code must
    (repo / "code.py").write_text("x = 2\n")
    assert git_rev(cwd=str(repo)) == f"{clean}-dirty"


def test_git_rev_outside_checkout_is_none(tmp_path):
    assert git_rev(cwd=str(tmp_path)) is None


def test_report_rows_and_csv(capsys):
    r = Report(quick=True)
    r.add("b", "m", 1.5)
    r.add("b", "n", 2)
    assert r.rows == [("b", "m", 1.5), ("b", "n", 2.0)]
    assert r.csv().splitlines() == ["benchmark,metric,value",
                                    "b,m,1.5", "b,n,2"]
    assert r.quick is True
