"""Per-kernel shape/dtype sweeps: Pallas (interpret) vs pure-jnp oracle."""
import jax
import numpy as np
import networkx as nx
import pytest

from tests.conftest import hypothesis_or_stub

given, settings, st = hypothesis_or_stub()

from repro.core import from_networkx
from repro.core.filtration import build_filtered_complex
from repro.core.persistence_jax import pack_boundary, reduce_packed
from repro.kernels import ops, ref
from tests.conftest import graphs_to_batch, random_graphs


@pytest.mark.parametrize("n,tile", [(8, 8), (20, 8), (33, 16), (64, 32)])
def test_domination_shapes(n, tile):
    rng = np.random.default_rng(n * tile)
    adj = rng.random((2, n, n)) < 0.3
    adj = adj | adj.transpose(0, 2, 1)
    adj[:, np.arange(n), np.arange(n)] = False
    mask = rng.random((2, n)) < 0.9
    import jax.numpy as jnp

    adj_j = jnp.asarray(adj) & jnp.asarray(mask)[:, None, :] & jnp.asarray(mask)[:, :, None]
    out_k = ops.domination(adj_j, jnp.asarray(mask), tile=tile)
    out_r = jax.vmap(ref.domination_ref)(adj_j, jnp.asarray(mask))
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


@pytest.mark.parametrize("n,tile,k", [(24, 8, 1), (24, 8, 2), (40, 16, 3)])
def test_kcore_peel_shapes(n, tile, k):
    gs = random_graphs("er", 3, seed=n + k)
    g = graphs_to_batch(gs, n_pad=n)
    out_k = ops.kcore_peel(g.adj, g.mask, k, tile=tile)
    out_r = jax.vmap(lambda a, al: ref.kcore_peel_ref(a, al, k))(g.adj, g.mask)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


@pytest.mark.parametrize("n,tile", [(24, 8), (30, 16)])
def test_common_neighbors_shapes(n, tile):
    gs = random_graphs("plc", 3, seed=n)
    g = graphs_to_batch(gs, n_pad=n)
    out_k = ops.common_neighbors(g.adj, tile=tile)
    out_r = jax.vmap(ref.common_neighbors_ref)(g.adj)
    assert (np.asarray(out_k) == np.asarray(out_r)).all()


@settings(max_examples=10, deadline=None)
@given(st.integers(5, 16), st.floats(0.2, 0.7), st.integers(0, 2**31 - 1))
def test_gf2_reduce_property(n, p, seed):
    G = nx.gnp_random_graph(n, p, seed=seed)
    g = graphs_to_batch([G])
    fc = build_filtered_complex(g.adj[0], g.mask[0], g.f[0], 1, 64, 128)
    b = pack_boundary(fc)
    ow_k, pos_k = ops.gf2_reduce(b)
    ow_r, pos_r = reduce_packed(b)
    assert (np.asarray(ow_k) == np.asarray(ow_r)).all()
    assert (np.asarray(pos_k) == np.asarray(pos_r)).all()


def test_clustering_coefficients_vs_networkx():
    gs = random_graphs("plc", 4, seed=21)
    g = graphs_to_batch(gs, n_pad=24)
    cc = np.asarray(ops.clustering_coefficients(g.adj, g.mask, tile=8))
    for i, G in enumerate(gs):
        nxcc = nx.clustering(G)
        for v in G.nodes():
            assert abs(cc[i, v] - nxcc[v]) < 1e-6


def test_domination_kernel_drives_prunit():
    """End-to-end: prune using the Pallas domination kernel as dom_fn."""
    from repro.core.prunit import prune_round_mask

    gs = random_graphs("ba", 3, seed=17)
    g = graphs_to_batch(gs, n_pad=24)
    m1 = prune_round_mask(g.adj, g.mask, g.f, sublevel=False)
    m2 = prune_round_mask(
        g.adj, g.mask, g.f, sublevel=False,
        dom_fn=lambda a, m: ops.domination(a, m, tile=8),
    )
    assert (np.asarray(m1) == np.asarray(m2)).all()


_POP8 = np.array([bin(i).count("1") for i in range(256)], np.uint8)


@pytest.mark.parametrize("q,n,nbytes,tq,tn", [
    (5, 37, 16, 8, 128),   # 128-bit codes, ragged rows
    (16, 300, 8, 4, 64),   # 64-bit codes, multiple query tiles
    (3, 9, 5, 8, 32),      # odd byte count: word padding path
])
def test_hamming_scan_matches_popcount_oracle(q, n, nbytes, tq, tn):
    from repro.kernels.hamming import hamming_scan_pallas, pack_codes_u32
    import jax.numpy as jnp

    rng = np.random.default_rng(q * n)
    q8 = rng.integers(0, 256, (q, nbytes), dtype=np.uint8)
    c8 = rng.integers(0, 256, (n, nbytes), dtype=np.uint8)
    m8 = rng.integers(0, 256, (q, nbytes), dtype=np.uint8)

    want_plain = _POP8[q8[:, None, :] ^ c8[None, :, :]].sum(-1)
    want_mask = _POP8[(q8[:, None, :] ^ c8[None, :, :])
                      & m8[:, None, :]].sum(-1)

    # ops wrapper accepts the u8 packed-byte storage layout directly
    assert (np.asarray(ops.hamming_scan(q8, c8)) == want_plain).all()
    assert (np.asarray(ops.hamming_scan(q8, c8, mask_q=m8))
            == want_mask).all()
    # raw kernel + jnp oracle on the u32 word layout, explicit tiles
    qu, cu, mu = (jnp.asarray(pack_codes_u32(a)) for a in (q8, c8, m8))
    got = hamming_scan_pallas(qu, mu, cu, tile_q=tq, tile_n=tn,
                              interpret=True)
    assert (np.asarray(got) == want_mask).all()
    assert (np.asarray(ref.hamming_scan_ref(qu, mu, cu)) == want_mask).all()
