"""TopoIndex + SimilarityServe: embedding contract, kNN, save/load, serving."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import from_edge_lists, topological_signature
from repro.index import TopoIndex, TopoIndexConfig
from repro.serve import SimilarityServe

CYCLE4 = [(0, 1), (1, 2), (2, 3), (3, 0)]
TWO_TRI = [(0, 1), (1, 2), (2, 0), (2, 3), (3, 4), (4, 2)]
PATH = [(0, 1), (1, 2), (2, 3), (3, 4)]
STAR = [(0, 1), (0, 2), (0, 3), (0, 4)]


def corpus_diagrams(edge_cap=24, tri_cap=24, n_pad=8):
    g = from_edge_lists([CYCLE4, TWO_TRI, PATH, STAR], [5, 5, 5, 5],
                        n_pad=n_pad)
    return topological_signature(g, dim=1, method="prunit",
                                 edge_cap=edge_cap, tri_cap=tri_cap)


def test_add_query_roundtrip(tmp_path):
    index = TopoIndex(TopoIndexConfig(embedding="sw", k=1, n_points=8,
                                      n_dirs=8))
    d = corpus_diagrams()
    ids = index.add(d, ids=["cycle4", "twotri", "path", "star"])
    assert ids == ["cycle4", "twotri", "path", "star"] and len(index) == 4
    got_ids, dists = index.query(d, k=2)
    assert dists.shape == (4, 2)
    full_ids, full_dists = index.query(d, k=4)
    for i, gid in enumerate(["cycle4", "twotri", "path", "star"]):
        assert dists[i][0] == pytest.approx(0.0, abs=1e-5)
        # self is among the zero-distance ties (acyclic graphs all have an
        # empty PD_1, so their sw embeddings legitimately coincide)
        ties = [g for g, dist in zip(full_ids[i], full_dists[i])
                if dist < 1e-5]
        assert gid in ties
    # the 4-cycle (one essential 1-class) is far from the acyclic graphs
    cyc = index.query(jax.tree.map(lambda x: x[0], d), k=4)
    assert cyc[0][0][0] == "cycle4"
    assert cyc[1][0][-1] > 1.0

    # save / load preserves config, ids and answers — also for a path
    # without the .npz suffix (save must write to the path verbatim)
    for name in ("index.npz", "index.topo"):
        path = str(tmp_path / name)
        index.save(path)
        loaded = TopoIndex.load(path)
        assert loaded.config == index.config and loaded.ids == index.ids
        ids2, dists2 = loaded.query(d, k=2)
        assert ids2 == got_ids
        np.testing.assert_allclose(dists2, dists, atol=1e-6)


def test_embedding_width_independent_of_tensor_size():
    """Diagrams from different caps/buckets land in one embedding space."""
    cfg = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8)
    index = TopoIndex(cfg)
    small = corpus_diagrams(edge_cap=16, tri_cap=16)
    big = corpus_diagrams(edge_cap=48, tri_cap=96, n_pad=12)
    assert small.birth.shape[-1] != big.birth.shape[-1]
    index.add(small, ids=["a", "b", "c", "d"])
    ids, dists = index.query(big, k=1)  # same graphs, other tensor size
    np.testing.assert_allclose(dists[:, 0], 0.0, atol=1e-5)
    # the 4-cycle's PD_1 is unique in the corpus, so its id is unambiguous
    assert ids[0][0] == "a"


def test_features_and_both_embeddings():
    d = corpus_diagrams()
    for emb in ("features", "both"):
        index = TopoIndex(TopoIndexConfig(embedding=emb, n_points=8,
                                          n_dirs=8, res=4))
        index.add(d)
        assert index.config.width == index._emb.shape[1]
        ids, dists = index.query(d, k=1)
        assert [row[0] for row in ids] == ["g0", "g1", "g2", "g3"]
        np.testing.assert_allclose(dists[:, 0], 0.0, atol=1e-4)


def test_validation():
    index = TopoIndex(TopoIndexConfig(n_points=4, n_dirs=4))
    d = corpus_diagrams()
    with pytest.raises(ValueError, match="empty"):
        index.query(d)
    index.add(d, ids=["a", "b", "c", "d"])
    with pytest.raises(ValueError, match="duplicate"):
        index.add(d, ids=["a", "x", "y", "z"])
    with pytest.raises(ValueError, match="ids for"):
        index.add(d, ids=["only-one"])
    with pytest.raises(ValueError, match="unknown embedding"):
        TopoIndexConfig(embedding="bogus")
    # k larger than the index clips
    ids, dists = index.query(d, k=99)
    assert dists.shape == (4, 4)


def test_similarity_serve_end_to_end():
    srv = SimilarityServe(
        index_config=TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8),
        default_k=2)
    srv.add(edges=CYCLE4, n_vertices=4, gid="cycle4")
    srv.add(edges=TWO_TRI, n_vertices=5, gid="twotri")
    srv.add(edges=PATH, n_vertices=5, gid="path")
    fut = srv.submit(edges=CYCLE4, n_vertices=4)      # exact corpus member
    fut_k1 = srv.submit(edges=STAR, n_vertices=5, k=1)
    assert srv.pending() == 5
    assert srv.drain() == 2
    r = fut.result()
    assert r.ids[0] == "cycle4" and r.distances[0] == pytest.approx(0.0)
    assert len(r.ids) == 2 and r.distances[1] >= r.distances[0]
    assert len(fut_k1.result().ids) == 1
    assert srv.stats["indexed"] == 3 and srv.stats["queries"] == 2
    assert np.asarray(r.diagrams.birth).ndim == 1  # per-graph slice


def test_similarity_serve_empty_index_fails_queries():
    srv = SimilarityServe()
    fut = srv.submit(edges=PATH, n_vertices=5)
    srv.drain()
    with pytest.raises(ValueError, match="empty index"):
        fut.result()


def test_similarity_serve_duplicate_gid_does_not_wedge_queries():
    # an index failure (duplicate gid) must drop the add batch and still
    # resolve queued queries, never leave futures blocked forever
    srv = SimilarityServe(
        index_config=TopoIndexConfig(n_points=4, n_dirs=4))
    srv.add(edges=CYCLE4, n_vertices=4, gid="dup")
    srv.drain()
    srv.add(edges=PATH, n_vertices=5, gid="dup")       # collides at drain
    fut = srv.submit(edges=CYCLE4, n_vertices=4, k=1)
    assert srv.drain() == 1
    assert fut.result(timeout=5).ids == ("dup",)
    assert srv.stats["add_failures"] == 1 and len(srv.index) == 1


def test_query_result_backends_and_legacy_unpack():
    index = TopoIndex(TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8))
    d = corpus_diagrams()
    index.add(d, ids=["a", "b", "c", "d"])
    res = index.query(d, k=2)
    ids, dists = res                      # legacy tuple unpack still works
    assert ids == res[0] and (dists == res[1]).all()
    assert res.backends == [["gram", "gram"]] * 4  # provenance per distance
    assert res.stats["stage"] == "gram"
    assert res.stats["coarse_candidates"] == 4


def test_lsh_coarse_stage_recall():
    from repro.metrics.testing import noisy_copies, seed_diagram_arrays

    rng = np.random.default_rng(23)
    corpus = noisy_copies(seed_diagram_arrays(rng, n_seeds=8, s=16),
                          rng, 256, 0.02, 0.32)
    cfg_lsh = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8,
                              coarse="lsh", lsh_bits=128, lsh_overfetch=8)
    lsh = TopoIndex(cfg_lsh)
    full = TopoIndex(TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8))
    lsh.add(corpus)
    full.add(corpus)
    q = jax.tree.map(lambda x: x[:6], corpus)
    res_l = lsh.query(q, k=5)
    res_f = full.query(q, k=5)
    assert res_l.stats["stage"] == "lsh+gram"
    assert res_l.stats["coarse_candidates"] == 40  # k·overfetch of 256
    assert res_f.stats["stage"] == "gram"
    # self is indexed: distance 0 must survive the coarse stage
    np.testing.assert_allclose(res_l.distances[:, 0], 0.0, atol=1e-5)
    recall = np.mean([len(set(a) & set(b)) / 5
                      for a, b in zip(res_l.ids, res_f.ids)])
    assert recall >= 0.9, recall
    # tiny fetches fall back to the dense Gram (candidates == index)
    small = TopoIndex(cfg_lsh)
    small.add(jax.tree.map(lambda x: x[:8], corpus))
    assert small.query(q, k=5).stats["stage"] == "gram"


def test_index_clouds_roundtrip_for_rerank(tmp_path):
    from repro.metrics import compare

    cfg = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8,
                          coarse="lsh")
    index = TopoIndex(cfg)
    d = corpus_diagrams()
    index.add(d, ids=["cycle4", "twotri", "path", "star"])
    # the stored cloud of each entry is exactly its compacted diagram:
    # exact_w between the original and the rebuilt cloud is 0
    rebuilt = index.clouds(np.arange(4))
    dist = np.asarray(compare(d, rebuilt, metric="exact_w", k=cfg.k,
                              cap=cfg.cap, n_points=cfg.n_points))
    np.testing.assert_allclose(dist, 0.0, atol=1e-5)
    # clouds + lsh config survive save/load (codes rebuilt deterministically)
    path = str(tmp_path / "index.npz")
    index.save(path)
    loaded = TopoIndex.load(path)
    assert loaded.config == index.config
    np.testing.assert_array_equal(loaded._clouds, index._clouds)
    np.testing.assert_array_equal(loaded._codes, index._codes)


def test_similarity_serve_exact_rerank():
    srv = SimilarityServe(
        index_config=TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8),
        default_k=2, rerank="exact_w", overfetch=2)
    srv.add(edges=CYCLE4, n_vertices=4, gid="cycle4")
    srv.add(edges=TWO_TRI, n_vertices=5, gid="twotri")
    srv.add(edges=PATH, n_vertices=5, gid="path")
    fut = srv.submit(edges=CYCLE4, n_vertices=4)
    assert srv.drain() == 1
    r = fut.result(timeout=10)
    # exact self-match, exact backend labels, per-stage stats populated
    assert r.ids[0] == "cycle4" and r.distances[0] == pytest.approx(0.0)
    assert r.backends == ("exact_w",) * len(r.ids)
    assert srv.stats["stage1_candidates"] >= 2
    assert srv.stats["stage2_pairs"] >= 2
    assert srv.stats["stage2_s"] > 0
    with pytest.raises(ValueError, match="unknown rerank"):
        SimilarityServe(rerank="bogus")


def test_similarity_serve_rerank_off_labels_gram():
    srv = SimilarityServe(
        index_config=TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8),
        default_k=1)
    srv.add(edges=CYCLE4, n_vertices=4, gid="cycle4")
    fut = srv.submit(edges=CYCLE4, n_vertices=4)
    srv.drain()
    r = fut.result(timeout=10)
    assert r.backends == ("gram",)
    assert srv.stats["stage2_pairs"] == 0


def test_similarity_serve_mixed_buckets_in_one_drain():
    # a small and a large graph route to different padding buckets, so their
    # Diagrams rows have different tensor sizes S; one drain must index and
    # answer both (embeddings are S-independent; stacking is per shape class)
    srv = SimilarityServe(
        index_config=TopoIndexConfig(n_points=8, n_dirs=8), default_k=2)
    big_cycle = [(i, (i + 1) % 20) for i in range(20)]
    f_big_vals = [9.0] * 20  # shift the big cycle's birth away from degree 2
    srv.add(edges=CYCLE4, n_vertices=4, gid="small")
    srv.add(edges=big_cycle, n_vertices=20, f=f_big_vals, gid="big")
    f_small = srv.submit(edges=CYCLE4, n_vertices=4, k=1)
    f_big = srv.submit(edges=big_cycle, n_vertices=20, f=f_big_vals, k=1)
    assert srv.drain() == 2
    assert srv.stats["indexed"] == 2 and srv.stats["add_failures"] == 0
    assert f_small.result(timeout=5).ids == ("small",)
    assert f_big.result(timeout=5).ids == ("big",)
    assert f_small.result().diagrams.birth.shape != \
        f_big.result().diagrams.birth.shape


def _noisy_lsh_index(n=256, seed=23, **cfg_kw):
    from repro.metrics.testing import noisy_copies, seed_diagram_arrays

    rng = np.random.default_rng(seed)
    corpus = noisy_copies(seed_diagram_arrays(rng, n_seeds=8, s=16),
                          rng, n, 0.02, 0.32)
    cfg = TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8,
                          coarse="lsh", lsh_bits=64, lsh_overfetch=8,
                          **cfg_kw)
    index = TopoIndex(cfg)
    index.add(corpus)
    return index, corpus


def test_coarse_candidates_chunked_merge_is_chunk_invariant():
    # the running top-m merge must return the same candidates (same order)
    # whatever the streaming chunk size — boundary ties resolve by row
    index, _ = _noisy_lsh_index()
    emb_q = index._emb[:6]
    want = index._coarse_candidates(emb_q, 20)
    for chunk in (1, 7, 20, 100, 256, 1000):
        got = index._coarse_candidates(emb_q, 20, chunk=chunk)
        np.testing.assert_array_equal(got, want, err_msg=f"chunk={chunk}")


def test_multi_probe_mask_equals_min_over_flip_codes():
    # masking the t lowest-margin query bits == min Hamming over all 2^t
    # flip-probe codes: check the identity exhaustively against the corpus
    index, _ = _noisy_lsh_index()
    emb_q = index._emb[:4]
    margins = index._lsh_margins(emb_q)
    probes, t = 4, 2
    mask = index._query_bit_masks(margins, probes)
    bits = index.config.lsh_bits
    assert mask.shape == (4, bits // 8)
    pop = np.array([bin(i).count("1") for i in range(256)], np.uint8)
    assert (pop[mask].sum(-1) == bits - t).all()  # exactly t bits cleared

    codes_db = index._codes
    masked = pop[(np.packbits(margins > 0, axis=-1)[:, None, :]
                  ^ codes_db[None]) & mask[:, None, :]].sum(-1)
    flip_pos = np.argpartition(np.abs(margins), t - 1, axis=-1)[:, :t]
    best = None
    for assign in range(1 << t):
        b = margins > 0
        for j in range(t):
            b[np.arange(4), flip_pos[:, j]] = bool((assign >> j) & 1)
        d = pop[np.packbits(b, axis=-1)[:, None, :] ^ codes_db[None]].sum(-1)
        best = d if best is None else np.minimum(best, d)
    np.testing.assert_array_equal(masked, best)


def test_probes_config_validation_and_flip_bits():
    for probes, t in [(1, 0), (2, 1), (3, 2), (4, 2), (5, 3), (8, 3),
                      (9, 4)]:
        assert TopoIndexConfig(probes=probes).flip_bits == t
    with pytest.raises(ValueError, match="probes"):
        TopoIndexConfig(probes=0)
    with pytest.raises(ValueError, match="discriminating"):
        TopoIndexConfig(lsh_bits=8, probes=1 << 9)
    index, corpus = _noisy_lsh_index(probes=4)
    q = jax.tree.map(lambda x: x[:4], corpus)
    res = index.query(q, k=5)
    assert res.stats["probes"] == 4
    assert index.query(q, k=5, probes=1).stats["probes"] == 1  # override
    np.testing.assert_allclose(res.distances[:, 0], 0.0, atol=1e-5)


def test_save_load_persists_packed_codes(tmp_path):
    index, corpus = _noisy_lsh_index(n=32)
    path = str(tmp_path / "index.npz")
    index.save(path)
    with np.load(path, allow_pickle=False) as z:
        assert "codes" in z.files  # persisted since 1.7, not rebuilt
        payload = {k: z[k] for k in z.files}
    # loads must trust the stored codes: plant a distinctive byte pattern
    # and check it comes back verbatim instead of a recompute
    payload["codes"] = payload["codes"] ^ np.uint8(0xAA)
    with open(path, "wb") as fh:
        np.savez(fh, **payload)
    loaded = TopoIndex.load(path)
    np.testing.assert_array_equal(loaded._codes, index._codes ^ 0xAA)
    # a pre-1.7 save (no codes key) rebuilds them from the embeddings
    del payload["codes"]
    with open(path, "wb") as fh:
        np.savez(fh, **payload)
    rebuilt = TopoIndex.load(path)
    np.testing.assert_array_equal(rebuilt._codes, index._codes)


def test_legacy_load_keeps_rerank_disabled_across_resave(tmp_path):
    index, corpus = _noisy_lsh_index(n=16)
    path = str(tmp_path / "legacy.npz")
    index.save(path)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files}
    del payload["clouds"]  # pre-1.4 format: no stored clouds
    del payload["codes"]
    with open(path, "wb") as fh:
        np.savez(fh, **payload)
    loaded = TopoIndex.load(path)
    assert not loaded._has_clouds
    with pytest.raises(ValueError, match="pre-1.4"):
        loaded.clouds(np.arange(3))
    ids, dists = loaded.query(jax.tree.map(lambda x: x[:2], corpus), k=3)
    assert len(ids) == 2  # queries still work without the re-rank stage
    # a re-save of the legacy load must NOT resurrect the clouds array —
    # the placeholder is all-zero garbage, not the real diagrams
    path2 = str(tmp_path / "resaved.npz")
    loaded.save(path2)
    with np.load(path2, allow_pickle=False) as z:
        assert "clouds" not in z.files
        assert "codes" in z.files  # codes ARE pure config·emb: safe to save
    again = TopoIndex.load(path2)
    assert not again._has_clouds
    with pytest.raises(ValueError, match="pre-1.4"):
        again.clouds(np.arange(3))
