"""k-core / CoralTDA structural correctness vs networkx."""
import networkx as nx
import numpy as np
import pytest

from repro.core import coreness, degeneracy, kcore_mask
from tests.conftest import graphs_to_batch, random_graphs


@pytest.mark.parametrize("kind", ["er", "ba", "plc"])
@pytest.mark.parametrize("k", [1, 2, 3, 4])
def test_kcore_matches_networkx(kind, k):
    gs = random_graphs(kind, 5, seed=k * 13 + hash(kind) % 97)
    g = graphs_to_batch(gs)
    m = np.asarray(kcore_mask(g.adj, g.mask, k))
    for i, G in enumerate(gs):
        ours = set(np.nonzero(m[i])[0].tolist())
        theirs = set(nx.k_core(G, k).nodes())
        assert ours == theirs


def test_coreness_matches_networkx():
    gs = random_graphs("er", 4, seed=7)
    g = graphs_to_batch(gs)
    c = np.asarray(coreness(g.adj, g.mask))
    for i, G in enumerate(gs):
        G2 = G.copy()
        G2.remove_edges_from(nx.selfloop_edges(G2))
        cn = nx.core_number(G2)
        for v in G2.nodes():
            assert c[i, v] == cn[v]


def test_degeneracy():
    gs = [nx.complete_graph(5), nx.cycle_graph(6), nx.star_graph(5)]
    g = graphs_to_batch(gs)
    d = np.asarray(degeneracy(g.adj, g.mask))
    assert d.tolist() == [4, 2, 1]


def test_kcore_empty_and_isolated():
    gs = [nx.empty_graph(5)]
    g = graphs_to_batch(gs)
    assert np.asarray(kcore_mask(g.adj, g.mask, 1)).sum() == 0
    assert np.asarray(kcore_mask(g.adj, g.mask, 0)).sum() == 5
