"""Direct unit tests for repro.topo.features against hand-computed tiny
diagrams (previously only covered indirectly via test_topo_serve.py)."""
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.persistence_jax import Diagrams
from repro.topo.features import (
    betti_curve,
    feature_vector,
    persistence_image,
    persistence_landscape,
    persistence_stats,
)


def make_diagram(rows, s=8):
    """rows: [(birth, death, dim)] placed in the leading tensor slots."""
    b = np.full(s, np.nan, np.float32)
    d = np.full(s, np.nan, np.float32)
    dim = np.full(s, -1, np.int32)
    val = np.zeros(s, bool)
    for i, (bb, dd, kk) in enumerate(rows):
        b[i], d[i], dim[i], val[i] = bb, dd, kk, True
    return Diagrams(birth=jnp.asarray(b), death=jnp.asarray(d),
                    dim=jnp.asarray(dim), valid=jnp.asarray(val))


# two dim-0 classes: (1, 3) finite, (2, inf) essential
D0 = make_diagram([(1.0, 3.0, 0), (2.0, np.inf, 0)])


def test_betti_curve_hand_computed():
    grid = jnp.asarray([0.0, 1.0, 2.0, 3.0, 4.0])
    got = np.asarray(betti_curve(D0, 0, grid))
    # (1,3) alive on [1,3); (2,inf) alive on [2,inf)
    np.testing.assert_array_equal(got, [0.0, 1.0, 2.0, 1.0, 1.0])
    # no dim-1 classes anywhere
    np.testing.assert_array_equal(np.asarray(betti_curve(D0, 1, grid)), 0.0)


def test_persistence_stats_hand_computed():
    got = np.asarray(persistence_stats(D0, 0, cap=10.0))
    # [count, betti, total-pers, max-pers, mean-birth, mean-death]
    # pers: (3-1) + (10-2) = 10 with the essential death capped at 10
    np.testing.assert_allclose(
        got, [2.0, 1.0, 10.0, 8.0, 1.5, 6.5], rtol=1e-6)


def test_persistence_stats_empty_dimension_is_zero():
    np.testing.assert_array_equal(
        np.asarray(persistence_stats(D0, 1, cap=10.0)), 0.0)


def test_persistence_image_mass_location_and_weighting():
    # single point, birth 4, persistence 4 -> peak at grid cell (4, 4)
    d = make_diagram([(4.0, 8.0, 0)])
    res, hi = 9, 32.0  # grid step 4 -> (4, 4) is exactly cell (1, 1)
    img = np.asarray(persistence_image(d, 0, res=res, lo=0.0, hi=hi,
                                       sigma=1.0, cap=64.0))
    assert img.shape == (res, res)
    assert np.unravel_index(img.argmax(), img.shape) == (1, 1)
    # persistence weighting: doubling persistence more than doubles the mass
    d2 = make_diagram([(4.0, 12.0, 0)])
    img2 = np.asarray(persistence_image(d2, 0, res=res, lo=0.0, hi=hi,
                                        sigma=1.0, cap=64.0))
    assert img2.sum() > 1.5 * img.sum()
    # empty diagram -> identically zero image
    empty = make_diagram([])
    np.testing.assert_array_equal(
        np.asarray(persistence_image(empty, 0, res=res)), 0.0)


def test_persistence_landscape_hand_computed():
    grid = jnp.arange(7.0)
    d = make_diagram([(0.0, 4.0, 0), (2.0, 6.0, 0)])
    got = np.asarray(persistence_landscape(d, 0, grid, n_levels=2, cap=64.0))
    lam1 = [0.0, 1.0, 2.0, 1.0, 2.0, 1.0, 0.0]   # max of the two tents
    lam2 = [0.0, 0.0, 0.0, 1.0, 0.0, 0.0, 0.0]   # their overlap at x=3
    np.testing.assert_allclose(got, [lam1, lam2], rtol=1e-6)


def test_invalid_and_wrong_dim_rows_are_inert():
    grid = jnp.arange(7.0)
    noisy = make_diagram([(1.0, 3.0, 0), (2.0, np.inf, 0),
                          (0.5, 5.0, 1)])       # extra dim-1 row
    for fn in (lambda d: betti_curve(d, 0, grid),
               lambda d: persistence_stats(d, 0, cap=10.0),
               lambda d: persistence_image(d, 0),
               lambda d: persistence_landscape(d, 0, grid)):
        np.testing.assert_allclose(
            np.asarray(fn(noisy)), np.asarray(fn(D0)), rtol=1e-6)


def test_feature_vector_shape_and_batching():
    batch = jax.tree.map(lambda *xs: jnp.stack(xs), D0, D0, D0)
    fv = feature_vector(batch, max_dim=1, res=4)
    assert fv.shape == (3, (6 + 16) * 2)
    np.testing.assert_allclose(np.asarray(fv[0]), np.asarray(fv[2]))
    single = feature_vector(D0, max_dim=1, res=4)
    np.testing.assert_allclose(np.asarray(fv[0]), np.asarray(single))
