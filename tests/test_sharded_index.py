"""ShardedIndex: single-host parity, shard-owner gathers, save/load, serving.

Everything here runs on however many devices exist (CI: one, so the mesh
degenerates to (1, 1) and the tests pin the *logic* — padding, host merge,
owner gather, re-shard-on-add).  The slow subprocess test at the bottom
re-runs the parity checks on a simulated 4-device mesh
(``XLA_FLAGS=--xla_force_host_platform_device_count=4``), which cannot be
done in-process because device count is fixed at first jax use.
"""
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

import jax

from repro.index import ShardedIndex, TopoIndex, TopoIndexConfig
from repro.launch.mesh import make_index_mesh
from repro.metrics.testing import noisy_copies, seed_diagram_arrays
from repro.serve import SimilarityServe

CFG_LSH = dict(embedding="sw", n_points=8, n_dirs=8, coarse="lsh",
               lsh_bits=64, lsh_overfetch=4)
CFG_DENSE = dict(embedding="sw", n_points=8, n_dirs=8, coarse="none")


def _corpus(n=96, seed=11):
    rng = np.random.default_rng(seed)
    return noisy_copies(seed_diagram_arrays(rng, 6, 16), rng, n, 0.05, 0.6)


def _pair(n=96, **cfg_kw):
    """(single-host index, sharded wrap of the SAME store, corpus)."""
    corpus = _corpus(n)
    base = TopoIndex(TopoIndexConfig(**cfg_kw))
    base.add(corpus)
    return base, ShardedIndex.from_index(base), corpus


def _slice(d, sl):
    return jax.tree.map(lambda x: x[sl], d)


def test_sharded_lsh_query_matches_single_host():
    base, sharded, corpus = _pair(**CFG_LSH)
    q = _slice(corpus, slice(0, 7))
    want = base.query(q, k=5)
    got = sharded.query(q, k=5)
    assert got.ids == want.ids
    # identical candidate sets feed the same _rank_candidates, so the
    # distances agree to float32 exactness, not just loosely
    np.testing.assert_allclose(got.distances, want.distances, atol=1e-6)
    assert got.stats["stage"] == "sharded_lsh+gram"
    assert got.stats["shards"] == sharded.n_shards
    assert set(got.stats["mesh"]) == {"row", "col"}


def test_sharded_dense_query_and_gram_match_single_host():
    base, sharded, corpus = _pair(**CFG_DENSE)
    q = _slice(corpus, slice(0, 5))
    want = base.query(q, k=4)
    got = sharded.query(q, k=4)
    assert got.ids == want.ids
    np.testing.assert_allclose(got.distances, want.distances,
                               rtol=1e-5, atol=1e-5)
    assert got.stats["stage"] == "sharded_gram"
    np.testing.assert_allclose(sharded.gram(), base.gram(),
                               rtol=1e-5, atol=1e-5)


def test_sharded_probes_override_threads_through():
    _, sharded, corpus = _pair(**CFG_LSH)
    q = _slice(corpus, slice(0, 3))
    assert sharded.query(q, k=3).stats["probes"] == 1
    res = sharded.query(q, k=3, probes=4)
    assert res.stats["probes"] == 4
    np.testing.assert_allclose(res.distances[:, 0], 0.0, atol=1e-5)


def test_sharded_clouds_owner_gather_matches_base():
    base, sharded, _ = _pair(**CFG_LSH)
    rows = np.array([[0, 17, 5], [95, 3, 42]])
    want, got = base.clouds(rows), sharded.clouds(rows)
    for a, b in zip(jax.tree.leaves(want), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_sharded_save_load_roundtrip_and_reshard_on_add(tmp_path):
    base, sharded, corpus = _pair(n=64, **CFG_LSH)
    path = str(tmp_path / "index.npz")
    sharded.save(path)
    loaded = ShardedIndex.load(path)
    q = _slice(corpus, slice(0, 4))
    want = sharded.query(q, k=3)
    got = loaded.query(q, k=3)
    assert got.ids == want.ids
    np.testing.assert_allclose(got.distances, want.distances, atol=1e-6)
    # append after load: device state re-shards lazily and the new rows
    # are queryable (self-match at distance ~0)
    extra = _corpus(n=8, seed=99)
    new_ids = loaded.add(extra, ids=[f"new{i}" for i in range(8)])
    assert len(loaded) == 72
    res = loaded.query(_slice(extra, slice(0, 2)), k=1)
    assert [r[0] for r in res.ids] == new_ids[:2]
    np.testing.assert_allclose(res.distances[:, 0], 0.0, atol=1e-5)


def test_sharded_legacy_load_keeps_rerank_disabled(tmp_path):
    _, sharded, corpus = _pair(n=16, **CFG_LSH)
    path = str(tmp_path / "legacy.npz")
    sharded.save(path)
    with np.load(path, allow_pickle=False) as z:
        payload = {k: z[k] for k in z.files if k not in ("clouds", "codes")}
    with open(path, "wb") as fh:
        np.savez(fh, **payload)
    loaded = ShardedIndex.load(path)
    with pytest.raises(ValueError, match="pre-1.4"):
        loaded.clouds(np.arange(3))  # delegates to base: same contract
    ids, dists = loaded.query(_slice(corpus, slice(0, 2)), k=3)
    assert len(ids) == 2  # coarse+gram stages still work without clouds


def test_similarity_serve_sharded_end_to_end():
    srv = SimilarityServe(
        index_config=TopoIndexConfig(embedding="sw", n_points=8, n_dirs=8),
        default_k=2, rerank="exact_w", overfetch=2, sharded=True)
    assert isinstance(srv.index, ShardedIndex)
    srv.add(edges=[(0, 1), (1, 2), (2, 0)], n_vertices=3, gid="tri")
    srv.add(edges=[(0, 1), (1, 2), (2, 3), (3, 0)], n_vertices=4, gid="sq")
    srv.add(edges=[(0, 1), (1, 2)], n_vertices=3, gid="path")
    fut = srv.submit(edges=[(0, 1), (1, 2), (2, 0)], n_vertices=3)
    assert srv.drain() == 1
    r = fut.result(timeout=10)
    # the serve re-rank gathers clouds through the shard-owner path
    assert r.ids[0] == "tri" and r.distances[0] == pytest.approx(0.0)
    assert r.backends == ("exact_w",) * len(r.ids)
    assert srv.stats["stage2_pairs"] >= 2


def test_sharded_wrap_of_existing_serve_index():
    index = TopoIndex(TopoIndexConfig(**CFG_LSH))
    index.add(_corpus(n=32))
    srv = SimilarityServe(index=index, sharded=True, default_k=1)
    assert isinstance(srv.index, ShardedIndex)
    assert srv.index.base is index  # store of record is the passed index


_MESH_SMOKE = textwrap.dedent("""
    import numpy as np, jax
    from repro.index import ShardedIndex, TopoIndex, TopoIndexConfig
    from repro.launch.mesh import make_index_mesh
    from repro.metrics.testing import noisy_copies, seed_diagram_arrays

    assert jax.device_count() == 4, jax.device_count()
    rng = np.random.default_rng(7)
    corpus = noisy_copies(seed_diagram_arrays(rng, 6, 16), rng, 96,
                          0.05, 0.6)
    q = jax.tree.map(lambda x: x[:5], corpus)
    for cfg in (dict(embedding="sw", n_points=8, n_dirs=8, coarse="lsh",
                     lsh_bits=64, lsh_overfetch=4),
                dict(embedding="sw", n_points=8, n_dirs=8, coarse="none")):
        base = TopoIndex(TopoIndexConfig(**cfg))
        base.add(corpus)
        sharded = ShardedIndex.from_index(base)
        assert sharded.n_shards == 4
        assert dict(zip(sharded.mesh.axis_names,
                        sharded.mesh.devices.shape)) == \\
            {"row": 2, "col": 2}
        want, got = base.query(q, k=5), sharded.query(q, k=5)
        assert got.ids == want.ids, (cfg["coarse"], got.ids, want.ids)
        np.testing.assert_allclose(got.distances, want.distances,
                                   rtol=1e-5, atol=1e-5)
        rows = np.array([0, 17, 95, 48])
        for a, b in zip(jax.tree.leaves(base.clouds(rows)),
                        jax.tree.leaves(sharded.clouds(rows))):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # non-square submesh: 2 of the 4 devices on one row
    p2 = ShardedIndex.from_index(base, mesh=make_index_mesh(2))
    assert p2.n_shards == 2
    got2 = p2.query(q, k=5)
    assert got2.ids == want.ids
    print("MESH_SMOKE_OK")
""")


@pytest.mark.slow
def test_four_device_mesh_parity_subprocess():
    """End-to-end parity on a simulated 4-device mesh (fresh process —
    XLA's host device count is fixed at first jax use)."""
    env = dict(os.environ,
               XLA_FLAGS="--xla_force_host_platform_device_count=4",
               PYTHONPATH="src", JAX_PLATFORMS="cpu")
    proc = subprocess.run([sys.executable, "-c", _MESH_SMOKE],
                          capture_output=True, text=True, timeout=600,
                          cwd=os.path.dirname(os.path.dirname(
                              os.path.abspath(__file__))), env=env)
    assert proc.returncode == 0, proc.stderr[-4000:]
    assert "MESH_SMOKE_OK" in proc.stdout
