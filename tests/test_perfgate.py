"""PerfGate unit + integration tests (ISSUE 6 tentpole).

Covers the reference store (classifier defaults, suite RefSpec overrides,
jitter band widening), the gate's row diffing (directions, quick-flag
semantics, abs_upper never loosening), an end-to-end ``check`` with an
injected regression (deterministic — synthetic rows through the
injectable runner, no timing), cost-cell attribution, and the tile
autotuner (fake-kernel argmin, TUNED_tiles.json round-trip, fallback on
absent/foreign-device files).  A real timed sweep lives behind the
``bench`` marker (perf-gate CI job).
"""
from __future__ import annotations

import json
import os

import pytest

from repro.kernels import tuning
from repro.perfgate import autotune, cost_cells, gate
from repro.perfgate.references import (
    DEFAULT_REL_BAND,
    JITTER_MULT,
    MAX_REL_BAND,
    PerfReference,
    RefSpec,
    classify_metric,
    load_suite_references,
    resolve_spec,
)


def _ref(metric="t_s", value=1.0, direction="lower", band=0.5,
         quick=False, **kw):
    return PerfReference(
        suite="s", benchmark="b", metric=metric, value=value,
        direction=direction, rel_band=band, abs_tol=1e-6, jitter=0.0,
        quick=quick, source="test", **kw)


# ------------------------------------------------------------ reference store

def test_classifier_directions():
    assert classify_metric("kernel_x", "B32_N128_pallas_s").direction == "lower"
    assert classify_metric("serve_n16", "latency_p99_ms").direction == "lower"
    assert classify_metric("m", "dense_cost_bytes_per_pair").direction == "lower"
    assert classify_metric("m", "B256_pairs_per_s").direction == "higher"
    assert classify_metric("m", "persist_speedup").direction == "higher"
    assert classify_metric("m", "recall_at_10").direction == "higher"
    assert classify_metric("m", "v_reduction_pct").direction == "higher"
    assert classify_metric("m", "G64_max_abs_diff").direction == "abs_upper"
    assert classify_metric("m", "parity_mismatches").direction == "abs_upper"
    assert classify_metric("m", "failed").direction == "abs_upper"
    assert classify_metric("m", "plan_cache_hits").direction == "info"
    assert classify_metric("m", "er_p0.12_mean_clustering").direction == "info"
    # unrecognized names must never gate
    assert classify_metric("m", "zorblax").direction == "info"


def test_resolve_spec_first_match_wins():
    specs = (RefSpec("b.skip*", "higher", rel_band=0.1),
             RefSpec("b.*", "info"))
    spec, src = resolve_spec(specs, "b", "skip_rate")
    assert (spec.direction, spec.rel_band, src) == ("higher", 0.1,
                                                    "spec:b.skip*")
    spec, src = resolve_spec(specs, "b", "anything_else_s")
    assert (spec.direction, src) == ("info", "spec:b.*")
    spec, src = resolve_spec((), "b", "anything_else_s")
    assert (spec.direction, src) == ("lower", "default")


def test_refspec_rejects_unknown_direction():
    with pytest.raises(ValueError, match="unknown direction"):
        RefSpec("*", "sideways")


def test_load_suite_references_jitter_widens_band(tmp_path):
    path = tmp_path / "BENCH_x.json"
    path.write_text(json.dumps({
        "quick": True,
        "rows": [
            {"benchmark": "b", "metric": "steady_s", "value": 1.0},
            {"benchmark": "b", "metric": "jittery_s", "value": 2.0},
            {"benchmark": "b", "metric": "wild_s", "value": 3.0},
        ],
        "deltas": [
            {"benchmark": "b", "metric": "steady_s", "value": 1.0,
             "prev": 1.01, "delta": -0.01},
            {"benchmark": "b", "metric": "jittery_s", "value": 2.0,
             "prev": 4.0, "delta": -2.0},   # 50% run-to-run movement
            {"benchmark": "b", "metric": "wild_s", "value": 3.0,
             "prev": 0.3, "delta": 2.7},    # 900% movement -> capped
        ],
    }))
    refs = {r.metric: r for r in load_suite_references("x", str(path))}
    base = DEFAULT_REL_BAND["lower"]
    assert refs["steady_s"].rel_band == pytest.approx(base)
    assert refs["jittery_s"].rel_band == pytest.approx(
        max(base, JITTER_MULT * 0.5))
    assert refs["wild_s"].rel_band == MAX_REL_BAND
    assert all(r.quick for r in refs.values())


def test_load_suite_references_tolerates_missing_file(tmp_path):
    assert load_suite_references("x", str(tmp_path / "nope.json")) == []
    bad = tmp_path / "BENCH_bad.json"
    bad.write_text("{not json")
    assert load_suite_references("bad", str(bad)) == []


# ------------------------------------------------------------------ row diffs

def test_evaluate_lower_direction():
    ref = _ref(value=1.0, band=0.5)
    assert gate.evaluate_row(ref, 1.4)["status"] == "ok"
    rec = gate.evaluate_row(ref, 1.6)
    assert rec["status"] == "regression"
    assert rec["rel_change"] == pytest.approx(0.6)
    assert gate.evaluate_row(ref, 0.3)["status"] == "improvement"
    # band_scale widens the band
    assert gate.evaluate_row(ref, 1.6, band_scale=2.0)["status"] == "ok"


def test_evaluate_higher_direction():
    ref = _ref(metric="per_s", value=100.0, direction="higher", band=0.4)
    assert gate.evaluate_row(ref, 61.0)["status"] == "ok"
    assert gate.evaluate_row(ref, 59.0)["status"] == "regression"
    assert gate.evaluate_row(ref, 150.0)["status"] == "improvement"
    # a scaled "higher" band saturates at 0.95 — the allowed floor can
    # shrink toward zero but never goes negative
    rec = gate.evaluate_row(ref, 4.0, band_scale=10.0)
    assert rec["status"] == "regression"
    assert rec["allowed"] == pytest.approx(100.0 * 0.05)
    assert gate.evaluate_row(ref, 6.0, band_scale=10.0)["status"] == "ok"


def test_evaluate_abs_upper_never_loosens():
    ref = _ref(metric="parity_mismatches", value=0.0, direction="abs_upper")
    assert gate.evaluate_row(ref, 0.0)["status"] == "ok"
    rec = gate.evaluate_row(ref, 3.0, band_scale=100.0)
    assert rec["status"] == "regression"
    # nonzero float baselines allow 2x drift, still band_scale-immune
    ref2 = _ref(metric="max_abs_diff", value=1e-4, direction="abs_upper")
    assert gate.evaluate_row(ref2, 1.9e-4)["status"] == "ok"
    assert gate.evaluate_row(ref2, 3e-4, band_scale=100.0)[
        "status"] == "regression"


def test_quick_mismatch_demotes_to_info():
    ref = _ref(value=1.0, band=0.5, quick=False)
    rec = gate.evaluate_row(ref, 100.0, quick_mismatch=True)
    assert rec["status"] == "info_quick_mismatch"
    # ... but abs_upper correctness rows gate regardless of workload size
    ref2 = _ref(metric="failed", value=0.0, direction="abs_upper")
    assert gate.evaluate_row(ref2, 5.0, quick_mismatch=True)[
        "status"] == "regression"


def test_diff_rows_quick_invariant_gates_across_mismatch():
    refs = {("b", "t_s"): _ref(value=1.0, band=0.5, quick=False)}
    rows = [("b", "t_s", 5.0)]
    block = gate.diff_rows("s", rows, refs, fresh_quick=True)
    assert block["quick_mismatched"] == 1 and not block["regressions"]
    block = gate.diff_rows("s", rows, refs, fresh_quick=True,
                           quick_invariant=True)
    assert [r["metric"] for r in block["regressions"]] == ["t_s"]
    assert block["regressions"][0]["cost_cell"]["cell"]


def test_diff_rows_unreferenced_and_stale():
    refs = {("b", "gone_s"): _ref(metric="gone_s")}
    block = gate.diff_rows("s", [("b", "new_s", 1.0)], refs)
    assert block["unreferenced"] == ["b.new_s"]
    assert block["stale_refs"] == ["b.gone_s"]


# ----------------------------------------------------------------- cost cells

def test_parse_shape_tokens():
    assert cost_cells.parse_shape("B32_N128_pallas_s") == {"B": 32, "N": 128}
    assert cost_cells.parse_shape("G128_D512_max_abs_diff") == {"G": 128,
                                                                "D": 512}
    assert cost_cells.parse_shape("latency_p50_ms") == {}


def test_attribute_modeled_kernel():
    cell = cost_cells.attribute("kernels", "kernel_pairwise_gram",
                                "G128_D512_pallas_s")
    assert cell["modeled"] and cell["bound"] in ("compute", "memory")
    assert cell["flops"] == pytest.approx(3.0 * 128 * 128 * 512)
    assert cell["shape"] == {"G": 128, "D": 512}


def test_attribute_subsystem_fallback():
    cell = cost_cells.attribute("metrics", "metrics_rerank", "recall_at_10")
    assert not cell["modeled"] and "retrieval" in cell["cell"]
    cell = cost_cells.attribute("serve", "serve_n16", "latency_p50_ms")
    assert "TopoServe" in cell["cell"]
    cell = cost_cells.attribute("z", "no_such_bench", "x")
    assert cell["cell"] == "z/no_such_bench"


# ----------------------------------------------------- end-to-end gate checks

def test_check_clean_echo_passes(tmp_path):
    """Echoing every reference value back verbatim must pass the gate."""
    from benchmarks import run as brun

    refs = load_suite_references(
        "kernels", "results/BENCH_kernels.json",
        brun.SUITES["kernels"].references)
    assert refs, "committed kernels baseline must exist"

    def echo_runner(key, quick):
        return {"rows": [(r.benchmark, r.metric, r.value) for r in refs],
                "wall_s": 0.0, "ok": True, "error": None}

    out = str(tmp_path / "GATE_report.json")
    report = gate.check(only=["kernels"], quick=False, out=out,
                        runner=echo_runner)
    assert report["ok"] and report["total_regressions"] == 0
    on_disk = json.loads(open(out).read())
    assert on_disk["schema"] == 1
    assert on_disk["suites"]["kernels"]["gated_ok"] > 0
    assert not on_disk["suites"]["kernels"]["stale_refs"]


def test_check_injected_regression_fails_with_cost_cell(tmp_path):
    """A 100x-detuned Gram timing must fail the gate and be attributed."""
    from benchmarks import run as brun

    refs = load_suite_references(
        "kernels", "results/BENCH_kernels.json",
        brun.SUITES["kernels"].references)

    def detuned_runner(key, quick):
        rows = []
        for r in refs:
            v = r.value
            if (r.benchmark, r.metric) == ("kernel_pairwise_gram",
                                           "G128_D512_pallas_s"):
                v *= 100.0
            rows.append((r.benchmark, r.metric, v))
        return {"rows": rows, "wall_s": 0.0, "ok": True, "error": None}

    out = str(tmp_path / "GATE_report.json")
    report = gate.check(only=["kernels"], quick=False, out=out,
                        runner=detuned_runner)
    assert not report["ok"] and report["total_regressions"] == 1
    reg = json.loads(open(out).read())[
        "suites"]["kernels"]["regressions"][0]
    assert reg["metric"] == "G128_D512_pallas_s"
    assert reg["cost_cell"]["modeled"]
    assert "pairwise_gram" in reg["cost_cell"]["cell"]


def test_check_crashed_suite_fails(tmp_path):
    def crash_runner(key, quick):
        return {"rows": [], "wall_s": 0.0, "ok": False, "error": "boom"}

    report = gate.check(only=["kernels"], quick=False,
                        out=str(tmp_path / "g.json"), runner=crash_runner)
    assert not report["ok"] and report["failed_suites"] == ["kernels"]


def test_check_unknown_suite_rejected(tmp_path):
    with pytest.raises(SystemExit, match="unknown suites"):
        gate.check(only=["nope"], out=str(tmp_path / "g.json"))


# ------------------------------------------------------------------ autotuner

def _fake_tunable():
    # deterministic "timing": config (tile_m=16, tile_n=256) is the argmin
    def fake_time(workload, config, repeats):
        return (abs(config["tile_m"] - 16) + abs(config["tile_n"] - 256)
                + 1.0) * 1e-3

    return autotune.KernelTunable(
        name="pairwise_gram",
        space={"tile_m": (8, 16, 32), "tile_n": (128, 256)},
        make_workload=lambda quick: None,
        time_config=fake_time,
        workload_desc=lambda quick: "fake")


def test_sweep_picks_argmin():
    win = autotune.sweep(_fake_tunable(), quick=True, repeats=1)
    assert win["tiles"] == {"tile_m": 16, "tile_n": 256}
    assert win["candidates"] == 6
    assert len(win["sweep"]) == 6
    assert win["seconds"] == pytest.approx(1e-3)


def test_register_tunable_rejects_undeclared_params():
    with pytest.raises(ValueError, match="DEFAULT_TILES does not declare"):
        autotune.register_tunable(autotune.KernelTunable(
            name="pairwise_gram", space={"tile_q": (1, 2)},
            make_workload=lambda q: None,
            time_config=lambda w, c, r: 0.0,
            workload_desc=lambda q: ""), overwrite=True)


def test_tuned_tiles_round_trip(tmp_path, monkeypatch):
    path = str(tmp_path / "TUNED_tiles.json")
    monkeypatch.setitem(autotune.TUNABLES, "pairwise_gram",
                        _fake_tunable())
    report = autotune.tune(only=["pairwise_gram"], quick=True, repeats=1,
                           path=path)
    assert report["path"] == path
    payload = json.loads(open(path).read())
    assert payload["version"] == tuning.TILES_SCHEMA
    assert payload["device"] == tuning.device_string()
    assert payload["kernels"]["pairwise_gram"]["tiles"] == {
        "tile_m": 16, "tile_n": 256}

    # the ops layer resolves the pinned winner for this device ...
    monkeypatch.setenv(tuning.TILES_ENV, path)
    tuning.reload_tuned()
    t = tuning.resolve_tiles("pairwise_gram")
    assert (t["tile_m"], t["tile_n"]) == (16, 256)
    assert t["tile_d"] == tuning.DEFAULT_TILES["pairwise_gram"]["tile_d"]
    # ... explicit kwargs still win over pinned values
    assert tuning.resolve_tiles("pairwise_gram", tile_m=8)["tile_m"] == 8
    tuning.reload_tuned()


def test_tuned_tiles_foreign_device_ignored(tmp_path, monkeypatch):
    path = str(tmp_path / "TUNED_tiles.json")
    with open(path, "w") as f:
        json.dump({"version": tuning.TILES_SCHEMA,
                   "device": "tpu:TPU v5e",
                   "kernels": {"pairwise_gram":
                               {"tiles": {"tile_m": 32}}}}, f)
    monkeypatch.setenv(tuning.TILES_ENV, path)
    tuning.reload_tuned()
    assert tuning.tuned_tiles("pairwise_gram") == {}
    assert (tuning.resolve_tiles("pairwise_gram")
            == tuning.DEFAULT_TILES["pairwise_gram"])
    tuning.reload_tuned()


def test_tuned_tiles_absent_or_stale_schema(tmp_path, monkeypatch):
    monkeypatch.setenv(tuning.TILES_ENV, str(tmp_path / "absent.json"))
    tuning.reload_tuned()
    assert tuning.tuned_tiles("pairwise_gram") == {}
    stale = tmp_path / "stale.json"
    stale.write_text(json.dumps({"version": 0,
                                 "device": tuning.device_string(),
                                 "kernels": {}}))
    monkeypatch.setenv(tuning.TILES_ENV, str(stale))
    tuning.reload_tuned()
    assert tuning.load_tuned() is None
    tuning.reload_tuned()


def test_tuned_tiles_unknown_keys_dropped(tmp_path, monkeypatch):
    path = str(tmp_path / "TUNED_tiles.json")
    with open(path, "w") as f:
        json.dump({"version": tuning.TILES_SCHEMA,
                   "device": tuning.device_string(),
                   "kernels": {"pairwise_gram":
                               {"tiles": {"tile_m": 32,
                                          "evil_kwarg": 7}}}}, f)
    monkeypatch.setenv(tuning.TILES_ENV, path)
    tuning.reload_tuned()
    assert tuning.tuned_tiles("pairwise_gram") == {"tile_m": 32}
    tuning.reload_tuned()


def test_cli_check_exit_codes(tmp_path, monkeypatch):
    from repro.perfgate import __main__ as cli

    calls = {}

    def fake_check(**kw):
        calls.update(kw)
        return {"ok": kw["only"] == ["good"]}

    monkeypatch.setattr("repro.perfgate.gate.check", fake_check)
    assert cli.main(["check", "--only", "good", "--quick"]) == 0
    assert calls["quick"] is True
    assert cli.main(["check", "--only", "bad"]) == 1


@pytest.mark.bench
def test_real_gram_sweep_times_all_candidates():
    """Actually time the Pallas Gram kernel over its tile space (CI
    perf-gate job; minutes on CPU interpret mode)."""
    win = autotune.sweep(autotune.TUNABLES["pairwise_gram"], quick=True,
                         repeats=1)
    assert win["tiles"].keys() == {"tile_m", "tile_n", "tile_d"}
    assert win["seconds"] > 0
    assert win["candidates"] == 12
