"""End-to-end behaviour tests: train loop, fault tolerance (checkpoint /
restart / elastic reshard), data pipeline determinism, topo features."""
import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro.core.api import topological_signature
from repro.data import graphs as gdata
from repro.data.ego import ego_batch
from repro.data.tokens import TokenStream
from repro.topo.features import feature_vector, betti_curve

pytest.importorskip("msgpack")  # checkpoint serialization; [models] extra
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainState


def test_train_loss_decreases(tmp_path):
    from repro.launch.train import train

    out = train("qwen3-1.7b", steps=30, batch=4, seq=64,
                ckpt_dir=str(tmp_path), ckpt_every=10, lr=1e-3)
    assert out["steps_run"] == 30
    assert np.isfinite(out["final_loss"])
    assert out["final_loss"] < out["first_loss"]


def test_checkpoint_restart_bitwise(tmp_path):
    """Interrupted-and-resumed run == uninterrupted run (same stream state)."""
    from repro.launch.train import train

    d1 = tmp_path / "a"
    d2 = tmp_path / "b"
    # uninterrupted 14 steps
    r_full = train("qwen3-1.7b", steps=14, batch=2, seq=32,
                   ckpt_dir=str(d1), ckpt_every=7, seed=3)
    # interrupted at 7, resumed to 14
    train("qwen3-1.7b", steps=7, batch=2, seq=32,
          ckpt_dir=str(d2), ckpt_every=7, seed=3)
    r_resumed = train("qwen3-1.7b", steps=14, batch=2, seq=32,
                      ckpt_dir=str(d2), ckpt_every=7, seed=3)
    assert np.isclose(r_full["final_loss"], r_resumed["final_loss"],
                      rtol=1e-5, atol=1e-6)


def test_checkpoint_elastic_reshard(tmp_path):
    """Save under one sharding, restore under another shape's sharding."""
    params = {"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
              "b": jnp.ones((8,), jnp.bfloat16)}
    state = TrainState(params=params, opt=adamw_init(params))
    ckpt.save(str(tmp_path), 5, state, stream_state={"seed": 0, "step": 5},
              save_shards=3)
    # restore with no shardings (replicated on a different "mesh")
    like = jax.tree.map(lambda x: jnp.zeros_like(x), state)
    restored, step, sstate = ckpt.restore(str(tmp_path), like)
    assert step == 5 and sstate["step"] == 5
    for a, b in zip(jax.tree.leaves(state), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_prune_and_atomic(tmp_path):
    params = {"w": jnp.zeros((4,))}
    state = TrainState(params=params, opt=adamw_init(params))
    for s in (1, 2, 3, 4, 5):
        ckpt.save(str(tmp_path), s, state, keep=2)
    assert ckpt.latest_steps(str(tmp_path)) == [4, 5]
    # a stale .tmp dir must not be picked up
    (tmp_path / "step_00000099.tmp").mkdir()
    assert ckpt.latest_steps(str(tmp_path)) == [4, 5]


def test_token_stream_deterministic():
    s = TokenStream(vocab_size=512, batch=4, seq_len=32, seed=7)
    b1 = s.batch_at(jnp.int32(11))
    b2 = s.batch_at(jnp.int32(11))
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    b3 = s.batch_at(jnp.int32(12))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))
    assert int(b1["tokens"].max()) < 512 and int(b1["tokens"].min()) >= 0


@pytest.mark.parametrize("name", ["ENZYMES", "TWITTER", "SYNNEW"])
def test_dataset_surrogates_regime(name):
    """Surrogate generators land in the published order/size regime."""
    g = gdata.load_dataset(name, jax.random.PRNGKey(0), batch=16)
    spec = gdata.TABLE2[name]
    nv = np.asarray(g.n_vertices(), float)
    assert 0.3 * min(spec.avg_nodes, spec.n_pad) < nv.mean() < 1.5 * spec.n_pad
    # symmetric, no self loops, masked
    a = np.asarray(g.adj)
    assert (a == a.transpose(0, 2, 1)).all()
    assert not a[:, np.arange(a.shape[1]), np.arange(a.shape[1])].any()
    m = np.asarray(g.mask)
    assert not (a & ~m[:, None, :]).any()


def test_ego_extraction_matches_manual():
    key = jax.random.PRNGKey(1)
    host = gdata.erdos_renyi(key, 1, 24, 24, 0.2)
    adj = np.asarray(host.adj[0])
    f = np.arange(24, dtype=np.float32)
    eb = ego_batch(jnp.asarray(adj), jnp.asarray(f), n_pad=24)
    for c in range(24):
        members = np.where(adj[c] | (np.arange(24) == c))[0]
        got = int(np.asarray(eb.mask[c]).sum())
        assert got == len(members)
        # induced edge count matches
        want_e = adj[np.ix_(members, members)].sum() // 2
        ae = np.asarray(eb.adj[c]).sum() // 2
        assert ae == want_e


def test_topo_feature_vector_shapes_and_sanity():
    # a 5-cycle has betti_1 = 1 under its clique complex
    import networkx as nx
    from repro.core.graph import from_networkx

    g = from_networkx([nx.cycle_graph(5), nx.complete_graph(5)], n_pad=8)
    d = topological_signature(g, dim=1, method="both", edge_cap=32, tri_cap=32)
    b1 = np.asarray(d.betti(1))
    assert b1[0] == 1  # C5 has one 1-dim hole
    assert b1[1] == 0  # K5's clique complex fills everything
    fv = feature_vector(d, max_dim=1, res=4)
    assert fv.shape == (2, (6 + 16) * 2)
    assert np.isfinite(np.asarray(fv)).all()
    curve = betti_curve(d, 0, jnp.linspace(0, 8, 9))
    assert curve.shape == (2, 9)


def test_serve_generate_roundtrip():
    from repro.configs.registry import reduced_config
    from repro.models import transformer as tf
    from repro.serve.serve_step import generate

    cfg = reduced_config("qwen3-1.7b")
    params = tf.init_params(cfg, jax.random.PRNGKey(0))
    prompt = jnp.array([[5, 7, 11, 13]], jnp.int32)
    toks = generate(params, cfg, prompt, max_new=6, s_kv=32)
    assert toks.shape == (1, 10)
    assert ((np.asarray(toks) >= 0) & (np.asarray(toks) < cfg.vocab_size)).all()
