"""TopoScope (repro.obs): registry, tracing, exporters, trace report.

Locks down the observability contract the serving stack now depends on:

* metrics — thread-safe counters/gauges/histograms with label sets,
  Prometheus ``le`` bucket semantics, name/type conflicts rejected;
* tracing — off by default with a bounded disabled-path cost, nestable
  spans producing Perfetto-loadable Chrome-trace JSON that round-trips
  through ``export_chrome_trace`` → ``repro.obs.report``;
* the end-to-end drain: with tracing on, a repack="on" TopoServe drain
  emits the full serve.*/plan.* span tree and feeds ``obs.span_seconds``;
* PerfGate integration — ``telemetry.*`` rows classify as info.
"""
from __future__ import annotations

import json
import threading
import time

import networkx as nx
import pytest

from repro import obs
from repro.obs.metrics import Counter, MetricsRegistry
from repro.obs.report import aggregate, format_report, load_trace, self_times


@pytest.fixture
def traced():
    """Enable tracing for one test; restore the disabled default after."""
    obs.configure(enabled=True)
    obs.clear_trace()
    try:
        yield
    finally:
        obs.configure(enabled=False)
        obs.clear_trace()


# ----------------------------------------------------------------- registry

def test_counter_labels_and_totals():
    reg = MetricsRegistry()
    c = reg.counter("req.count", help="requests")
    c.inc(bucket="n16", frontend="topo")
    c.inc(3, bucket="n32", frontend="topo")
    c.inc(bucket="n16", frontend="sim")
    assert c.value(bucket="n16", frontend="topo") == 1
    assert c.value(bucket="n32", frontend="topo") == 3
    assert c.total(frontend="topo") == 4      # superset sum
    assert c.total() == 5
    assert c.labeled("bucket") == {"n16": 2.0, "n32": 3.0}
    with pytest.raises(ValueError):
        c.inc(-1)


def test_counter_thread_safety():
    c = Counter("c")
    n_threads, n_incs = 8, 2000

    def worker(i):
        for _ in range(n_incs):
            c.inc(thread=i % 2)  # two contended series

    ts = [threading.Thread(target=worker, args=(i,))
          for i in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.total() == n_threads * n_incs


def test_gauge_updown():
    reg = MetricsRegistry()
    g = reg.gauge("sessions.live")
    g.inc()
    g.inc()
    g.dec()
    assert g.value() == 1
    g.set(7, instance="s-0")
    assert g.value(instance="s-0") == 7


def test_histogram_bucket_math():
    reg = MetricsRegistry()
    h = reg.histogram("lat", buckets=(1.0, 2.0, 4.0))
    for v in (1.0, 1.5, 4.0, 5.0):  # le semantics: 1.0 lands in le=1.0
        h.observe(v)
    (series,) = h.snapshot_series().values()
    assert series["buckets"] == [(1.0, 1), (2.0, 2), (4.0, 3), ("+Inf", 4)]
    assert series["count"] == 4
    assert series["sum"] == pytest.approx(11.5)


def test_histogram_rejects_bad_buckets():
    reg = MetricsRegistry()
    with pytest.raises(ValueError):
        reg.histogram("bad", buckets=(2.0, 1.0))
    with pytest.raises(ValueError):
        reg.histogram("bad2", buckets=())


def test_registry_type_conflict_and_reset():
    reg = MetricsRegistry()
    c = reg.counter("x")
    assert reg.counter("x") is c  # get-or-create
    with pytest.raises(TypeError):
        reg.gauge("x")
    c.inc(5)
    reg.reset()
    assert c.total() == 0          # series cleared ...
    assert reg.get("x") is c       # ... instrument still registered


def test_snapshot_is_json_ready():
    reg = MetricsRegistry()
    reg.counter("a").inc(2, k="v")
    reg.histogram("b", buckets=(1.0,)).observe(0.5)
    snap = reg.snapshot()
    json.dumps(snap)  # must not raise
    assert snap["a"]["type"] == "counter"
    assert snap["a"]["series"] == [{"labels": {"k": "v"}, "value": 2.0}]
    assert snap["b"]["series"][0]["count"] == 1


# ---------------------------------------------------------------- exporters

def test_prometheus_text_format():
    reg = MetricsRegistry()
    reg.counter("serve.req", help="req count").inc(2, bucket="n16")
    reg.histogram("serve.lat", buckets=(0.1, 1.0)).observe(0.05)
    text = obs.prometheus_text(reg)
    assert '# TYPE serve_req_total counter' in text
    assert '# HELP serve_req_total req count' in text
    assert 'serve_req_total{bucket="n16"} 2' in text
    assert '# TYPE serve_lat histogram' in text
    assert 'serve_lat_bucket{le="0.1"} 1' in text
    assert 'serve_lat_bucket{le="+Inf"} 1' in text
    assert 'serve_lat_count 1' in text
    assert text.endswith("\n")


def test_append_jsonl_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("c").inc()
    path = str(tmp_path / "metrics.jsonl")
    obs.append_jsonl(path, reg)
    reg.counter("c").inc()
    obs.append_jsonl(path, reg)
    lines = [json.loads(ln) for ln in open(path)]
    assert len(lines) == 2
    assert lines[1]["metrics"]["c"]["series"][0]["value"] == 2.0
    assert lines[0]["ts"] <= lines[1]["ts"]


# ------------------------------------------------------------------ tracing

def test_span_disabled_is_noop():
    assert not obs.enabled()
    with obs.span("x", foo=1) as sp:
        assert sp is obs.span("y")  # shared singleton
        sp.set(bar=2)               # must be accepted and dropped
    assert obs.trace_events() == []


def test_span_disabled_overhead():
    # acceptance bound: the disabled path must stay under 1 us/span so
    # always-on call sites cannot move serving numbers
    best = float("inf")
    for _ in range(5):
        t0 = time.perf_counter()
        for _ in range(10_000):
            with obs.span("overhead.probe"):
                pass
        best = min(best, (time.perf_counter() - t0) / 10_000)
    assert best < 1e-6, f"disabled span cost {best * 1e9:.0f} ns"


def test_span_nesting_and_attrs(traced):
    with obs.span("t.outer", frontend="topo") as outer:
        assert obs.current_span() is outer
        with obs.span("t.inner") as inner:
            inner.set(graphs=3)
        outer.set(served=1)
    assert obs.current_span() is None
    by_name = {e["name"]: e for e in obs.trace_events()}
    assert set(by_name) == {"t.outer", "t.inner"}
    inner, outer = by_name["t.inner"], by_name["t.outer"]
    assert inner["args"]["parent"] == "t.outer"
    assert "parent" not in outer["args"]
    assert inner["args"]["graphs"] == 3
    assert outer["args"]["served"] == 1
    assert outer["cat"] == "t" and outer["ph"] == "X"
    # interval containment (all in microseconds)
    assert outer["ts"] <= inner["ts"]
    assert inner["ts"] + inner["dur"] <= outer["ts"] + outer["dur"] + 1.0


def test_span_records_error_and_unwinds(traced):
    with pytest.raises(RuntimeError):
        with obs.span("t.fail"):
            raise RuntimeError("boom")
    (ev,) = obs.trace_events()
    assert ev["args"]["error"] == "RuntimeError"
    assert obs.current_span() is None  # stack unwound despite the raise


def test_span_feeds_duration_histogram(traced):
    h = obs.get_instrument("obs.span_seconds")
    before = {k: v.count for k, v in h.series().items()}
    with obs.span("t.feed"):
        pass
    key = (("span", "t.feed"),)
    assert h.series()[key].count == before.get(key, 0) + 1


def test_trace_capacity_drops_not_grows(traced):
    obs.configure(capacity=5)
    try:
        for i in range(8):
            with obs.span("t.cap"):
                pass
        assert len(obs.trace_events()) == 5
        assert obs.dropped_events() == 3
    finally:
        obs.configure(capacity=200_000)


def test_chrome_trace_export_round_trip(tmp_path, traced):
    with obs.span("t.a", shape="G64_D128"):
        with obs.span("t.b"):
            pass
    path = str(tmp_path / "trace.json")
    assert obs.export_chrome_trace(path) == path
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    assert doc["otherData"]["dropped"] == 0
    events = doc["traceEvents"]
    assert {e["name"] for e in events} == {"t.a", "t.b"}
    for e in events:
        assert {"name", "cat", "ph", "ts", "dur", "pid", "tid",
                "args"} <= set(e)
    # report loader accepts both the object form and a bare array
    assert len(load_trace(path)) == 2
    json.dump(events, open(str(tmp_path / "bare.json"), "w"))
    assert len(load_trace(str(tmp_path / "bare.json"))) == 2


def test_cross_thread_spans_get_own_tid(traced):
    def other():
        with obs.span("t.worker"):
            pass

    t = threading.Thread(target=other)
    with obs.span("t.main"):
        t.start()
        t.join()
    by_name = {e["name"]: e for e in obs.trace_events()}
    assert by_name["t.worker"]["tid"] != by_name["t.main"]["tid"]
    # the worker thread has its own (empty) span stack: no false parent
    assert "parent" not in by_name["t.worker"]["args"]


# ------------------------------------------------------------- trace report

def _ev(name, ts, dur, tid=1, **args):
    return {"name": name, "cat": name.split(".")[0], "ph": "X", "ts": ts,
            "dur": dur, "pid": 1, "tid": tid, "args": args}


def test_self_times_subtract_children():
    events = [
        _ev("serve.drain", 0.0, 100.0),
        _ev("kernels.pairwise_l1", 10.0, 40.0, shape="G64_D128"),
        _ev("serve.drain", 0.0, 50.0, tid=2),  # other thread: independent
    ]
    st = {(e["name"], e["tid"]): s for e, s in self_times(events)}
    assert st[("serve.drain", 1)] == pytest.approx(60.0)
    assert st[("kernels.pairwise_l1", 1)] == pytest.approx(40.0)
    assert st[("serve.drain", 2)] == pytest.approx(50.0)


def test_aggregate_attaches_cost_cells():
    events = [
        _ev("serve.drain", 0.0, 100.0),
        _ev("kernels.pairwise_l1", 10.0, 40.0, shape="G64_D128"),
        _ev("kernels.pairwise_l1", 55.0, 40.0, shape="G64_D128"),
    ]
    rows = aggregate(events)
    assert [r["span"] for r in rows] == ["kernels.pairwise_l1",
                                        "serve.drain"]  # by -self_us
    krow = rows[0]
    assert krow["calls"] == 2 and krow["shape"] == "G64_D128"
    assert krow["cost_cell"] is not None
    assert "cell" in krow["cost_cell"] and "bound" in krow["cost_cell"]
    assert rows[1]["cost_cell"] is None  # non-kernel span

    text = format_report(events, top=1)
    assert "kernels.pairwise_l1" in text
    assert "1 more rows" in text
    assert format_report([]) == "(empty trace)"


# -------------------------------------------------- end-to-end serve tracing

def test_topo_serve_drain_emits_span_tree(traced):
    from repro.serve import TopoServe, TopoServeConfig

    srv = TopoServe(TopoServeConfig(method="prunit", repack="on"))
    graphs = [nx.cycle_graph(6), nx.petersen_graph(), nx.path_graph(5)]
    futs = []
    for g in graphs:
        nodes = sorted(g.nodes())
        idx = {u: i for i, u in enumerate(nodes)}
        futs.append(srv.submit(
            edges=[(idx[u], idx[v]) for (u, v) in g.edges()],
            n_vertices=len(nodes)))
    assert srv.drain() == len(graphs)
    for f in futs:
        f.result()

    names = {e["name"] for e in obs.trace_events()}
    assert {"serve.drain", "serve.batch", "serve.gather", "serve.sync",
            "serve.resolve", "plan.reduce", "plan.measure", "plan.repack",
            "plan.persist"} <= names
    by_name = {e["name"]: e for e in obs.trace_events()}
    assert by_name["serve.batch"]["args"]["parent"] == "serve.drain"
    assert by_name["serve.drain"]["args"]["served"] == len(graphs)
    # the drain span must cover its children (the >=95% wall-clock
    # acceptance is checked on the bench-scale run; here: containment)
    drain = by_name["serve.drain"]
    for e in obs.trace_events():
        if e is drain or e["tid"] != drain["tid"]:
            continue
        assert e["ts"] >= drain["ts"] - 1.0
        assert e["ts"] + e["dur"] <= drain["ts"] + drain["dur"] + 1.0
    # idle drain: early return, no extra span
    n_before = len(obs.trace_events())
    assert srv.drain() == 0
    assert len(obs.trace_events()) == n_before


def test_serve_stats_view_backed_by_registry():
    from repro.serve import TopoServe, TopoServeConfig

    srv = TopoServe(TopoServeConfig(method="none"))
    srv.submit(edges=[(0, 1), (1, 2)], n_vertices=3)
    srv.drain()
    stats = srv.stats
    assert stats["submitted"] == 1 and stats["served"] == 1
    assert stats["batches"] == 1 and stats["failed"] == 0
    # a second server must not see the first one's counts (instance labels)
    srv2 = TopoServe(TopoServeConfig(method="none"))
    assert srv2.stats["submitted"] == 0


# --------------------------------------------------------- perfgate plumbing

def test_telemetry_rows_classify_as_info():
    from repro.perfgate.references import classify_metric

    spec = classify_metric("telemetry", "kernel_calls_pairwise_l1")
    assert spec.direction == "info"
    spec = classify_metric("telemetry", "plan_cache_misses")
    assert spec.direction == "info"


def test_telemetry_delta_tracks_counters():
    from benchmarks.common import telemetry_delta, telemetry_snapshot

    before = telemetry_snapshot()
    obs.counter("kernels.calls").inc(2, kernel="obs_test_probe")
    delta = telemetry_delta(before)
    assert delta["kernel_calls_obs_test_probe"] == 2
    for k in ("plan_cache_hits", "plan_cache_misses",
              "plan_cache_evictions"):
        assert k in delta  # always present, even when zero
