"""ReductionEngine + repack: fixpoint properties, two-phase parity, ladders.

The contracts under test (docs/ARCHITECTURE.md §ReductionEngine):

* pass scheduler — iterating registered passes reaches a fixpoint that is
  idempotent, and the final *diagrams* are pass-order invariant in every
  guaranteed dimension;
* repack — vertex compaction is a pure permutation (round-trips exactly),
  and two-phase execution (``repack="on"``) yields persistence pairs
  bit-identical to single-phase (``"off"``, the oracle) across methods ×
  sublevel/superlevel;
* ladder — first-fit shape-class selection is deterministic and always
  lands (default ladder), and serve/stream surfaces share reduced-size
  persist plans through the process-wide cache.
"""
import networkx as nx
import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import from_edge_lists, topological_signature
from repro.core.api import make_topo_plan, plan_cache_info
from repro.core.persistence_jax import diagrams_to_numpy
from repro.core.reduction import (
    PASS_REGISTRY,
    ReductionEngine,
    ReductionPass,
    apply_passes,
    engine_exact_from_dim,
    get_pass,
    method_for_passes,
    passes_for_method,
    reduce_fixpoint,
    register_pass,
)
from repro.core.repack import (
    ShapeClass,
    compact_batch,
    default_ladder,
    diagram_size,
    measure_counts,
    select_classes,
)

CAPS = dict(edge_cap=96, tri_cap=160)


def _batch(n_pad=24, seed=0, with_f=False):
    graphs = [nx.cycle_graph(6), nx.petersen_graph(), nx.star_graph(9),
              nx.barabasi_albert_graph(18, 2, seed=seed),
              nx.gnp_random_graph(20, 0.2, seed=seed + 1),
              nx.complete_graph(6)]
    edge_lists, nvs = [], []
    for g in graphs:
        nodes = sorted(g.nodes())
        idx = {u: i for i, u in enumerate(nodes)}
        edge_lists.append([(idx[u], idx[v]) for (u, v) in g.edges()])
        nvs.append(len(nodes))
    f_values = None
    if with_f:
        rng = np.random.default_rng(seed)
        f_values = [[float(rng.integers(0, 4)) for _ in range(nv)]
                    for nv in nvs]
    return from_edge_lists(edge_lists, nvs, n_pad=n_pad, f_values=f_values)


def _pairs(d, b, k):
    return diagrams_to_numpy(d, b, max_dim=k)[k]


# ----------------------------------------------------------------- registry

def test_registry_methods_and_contracts():
    assert passes_for_method("both") == ("prunit", "kcore")
    assert passes_for_method("none") == ()
    assert method_for_passes(("prunit", "kcore")) == "both"
    assert method_for_passes(("strong_collapse",)) == "strong_collapse"
    with pytest.raises(ValueError, match="unknown reduction"):
        passes_for_method("bogus")
    with pytest.raises(ValueError, match="unknown reduction pass"):
        get_pass("bogus")
    # exactness contract: coral restricts to >= dim, prunit preserves all
    assert engine_exact_from_dim(("prunit",), 1) == 0
    assert engine_exact_from_dim(("prunit", "kcore"), 1) == 1
    assert engine_exact_from_dim(("kcore",), 0) == 0  # dim-0 kcore: identity


def test_register_pass_extension_point():
    noop = ReductionPass(name="_test_noop",
                         apply_mask=lambda adj, mask, f, dim, sublevel: mask,
                         exact_from_dim=lambda d: 0)
    try:
        register_pass(noop)
        with pytest.raises(ValueError, match="already registered"):
            register_pass(noop)
        g = _batch()
        g2 = apply_passes(g, ("_test_noop",), dim=1)
        assert np.array_equal(np.asarray(g.mask), np.asarray(g2.mask))
    finally:
        PASS_REGISTRY.pop("_test_noop", None)


# ----------------------------------------------------------------- fixpoint

def test_fixpoint_idempotent():
    g = _batch(with_f=True)
    for passes in [("prunit",), ("prunit", "kcore"), ("strong_collapse",)]:
        r1 = reduce_fixpoint(g, passes, dim=1)
        r2 = reduce_fixpoint(r1, passes, dim=1)
        assert np.array_equal(np.asarray(r1.mask), np.asarray(r2.mask)), passes


def test_fixpoint_removes_at_least_single_sweep():
    g = _batch(with_f=True)
    sweep = apply_passes(g, ("prunit", "kcore"), dim=1)
    fix = reduce_fixpoint(g, ("prunit", "kcore"), dim=1)
    # fixpoint mask is a subset of the single-sweep mask (monotone passes)
    assert not np.any(np.asarray(fix.mask) & ~np.asarray(sweep.mask))


def test_pass_order_invariance_of_diagrams():
    # masks may differ between orderings; the guaranteed diagrams may not
    g = _batch(with_f=True)
    d_a = make_topo_plan(dim=1, passes=("prunit", "kcore"), fixpoint=True,
                         **CAPS).execute(g)
    d_b = make_topo_plan(dim=1, passes=("kcore", "prunit"), fixpoint=True,
                         **CAPS).execute(g)
    for b in range(g.batch):
        assert _pairs(d_a, b, 1) == _pairs(d_b, b, 1), b


def test_strong_collapse_exact_all_dims_both_orientations():
    # equal-f twins: satellites sharing a hub and an f value collapse
    g = _batch(with_f=True)
    for sublevel in (True, False):
        d_red = make_topo_plan(dim=1, passes=("strong_collapse",),
                               sublevel=sublevel, fixpoint=True,
                               **CAPS).execute(g)
        d_ref = make_topo_plan(dim=1, passes=(), sublevel=sublevel,
                               **CAPS).execute(g)
        for b in range(g.batch):
            for k in (0, 1):
                assert _pairs(d_red, b, k) == _pairs(d_ref, b, k), (b, k)


# ------------------------------------------------------------------- repack

def test_compact_batch_roundtrip():
    g = _batch(with_f=True)
    gr = reduce_fixpoint(g, ("prunit",), dim=1)
    gc, order = compact_batch(gr)
    m = np.asarray(gc.mask)
    # live vertices are front-packed
    for b in range(g.batch):
        nv = int(m[b].sum())
        assert m[b, :nv].all() and not m[b, nv:].any()
    # the permutation round-trips: scattering back restores the original
    order = np.asarray(order)
    adj, f, mask = (np.asarray(x) for x in (gr.adj, gr.f, gr.mask))
    for b in range(g.batch):
        inv = np.argsort(order[b])
        assert np.array_equal(np.asarray(gc.adj)[b][inv][:, inv], adj[b])
        assert np.array_equal(np.asarray(gc.mask)[b][inv], mask[b])
        assert np.array_equal(np.asarray(gc.f)[b][inv], f[b])


def test_measure_counts():
    g = _batch()
    nv, ne, nt = measure_counts(g)
    assert np.array_equal(np.asarray(nv), np.asarray(g.n_vertices()))
    assert np.array_equal(np.asarray(ne), np.asarray(g.n_edges()))
    # petersen: 0 triangles; K6: 20 triangles
    assert int(np.asarray(nt)[1]) == 0
    assert int(np.asarray(nt)[5]) == 20


def test_default_ladder_and_selection():
    lad = default_ladder(64, 320, 512)
    assert lad[-1] == ShapeClass(64, 320, 512, 0)
    assert [c.n_pad for c in lad] == [8, 16, 32, 64]
    assert all(a < b for a, b in zip(lad, lad[1:]))  # sorted, strict
    idx = select_classes(lad, nv=np.array([3, 9, 64, 30]),
                         ne=np.array([3, 20, 300, 100]),
                         nt=np.array([1, 5, 400, 30]))
    assert [lad[i].n_pad for i in idx] == [8, 16, 64, 32]
    # cap overflow promotes past a rung whose vertex budget fits
    idx2 = select_classes(lad, nv=np.array([8]), ne=np.array([28]),
                          nt=np.array([56]))
    assert lad[idx2[0]].n_pad == 8
    idx3 = select_classes(lad, nv=np.array([8]), ne=np.array([29]),
                          nt=np.array([56]))
    assert lad[idx3[0]].n_pad == 16
    with pytest.raises(ValueError, match="no repack shape class"):
        select_classes((ShapeClass(8, 28, 56),), nv=np.array([20]),
                       ne=np.array([10]), nt=np.array([0]))


@pytest.mark.slow
def test_two_phase_parity_methods_x_orientations():
    g = _batch(with_f=True)
    for method, dims in [("none", (0, 1)), ("prunit", (0, 1)),
                         ("coral", (1,)), ("both", (1,))]:
        for sublevel in (True, False):
            d_off = topological_signature(g, dim=1, method=method,
                                          sublevel=sublevel, repack="off",
                                          **CAPS)
            d_on = topological_signature(g, dim=1, method=method,
                                         sublevel=sublevel, repack="on",
                                         **CAPS)
            # one output shape: rows padded to the single-phase row count
            assert d_on.birth.shape == d_off.birth.shape
            for b in range(g.batch):
                for k in dims:
                    assert _pairs(d_off, b, k) == _pairs(d_on, b, k), \
                        (method, sublevel, b, k)


def test_two_phase_execute_info_report():
    g = _batch()
    plan = make_topo_plan(dim=1, method="both", repack="on", **CAPS)
    d, info = plan.execute_info(g)
    assert info is not None and len(info.class_index) == g.batch
    assert sum(info.rung_histogram().values()) == g.batch
    assert d.birth.shape[-1] == diagram_size(g.n, 1, CAPS["edge_cap"],
                                             CAPS["tri_cap"])
    # single-phase plans report no repack info
    d2, info2 = make_topo_plan(dim=1, method="both", **CAPS).execute_info(g)
    assert info2 is None


def test_custom_ladder_sanitized_per_input_shape():
    # rungs with caps above the plan's caps (non-monotone bucket configs)
    # or wider than the input order are dropped, and a top rung at the
    # input shape is appended — never an opaque scatter crash
    g = _batch(n_pad=24, with_f=True)
    bad = (ShapeClass(8, 4096, 4096), ShapeClass(16, CAPS["edge_cap"],
                                                 CAPS["tri_cap"]),
           ShapeClass(128, 4096, 8192))
    plan = make_topo_plan(dim=1, method="both", repack="on", ladder=bad,
                          **CAPS)
    d_on, info = plan.execute_info(g)
    assert all(c.n_pad <= g.n and c.edge_cap <= CAPS["edge_cap"]
               and c.tri_cap <= CAPS["tri_cap"] for c in info.ladder)
    assert info.ladder[-1] == ShapeClass(g.n, CAPS["edge_cap"],
                                         CAPS["tri_cap"])
    d_off = make_topo_plan(dim=1, method="both", **CAPS).execute(g)
    for b in range(g.batch):
        assert _pairs(d_on, b, 1) == _pairs(d_off, b, 1), b


def test_two_phase_sweep_vs_fixpoint_reduce_executor():
    # repack='on' honors fixpoint=False: the reduce phase runs one sweep,
    # whose surviving mask is a superset of the fixpoint's
    g = _batch(with_f=True)
    p_fix = make_topo_plan(dim=1, method="both", repack="on", **CAPS)
    p_swp = make_topo_plan(dim=1, method="both", repack="on",
                           fixpoint=False, **CAPS)
    assert p_fix is not p_swp
    _, (nv_f, _, _) = p_fix.reduce_executor(g)
    _, (nv_s, _, _) = p_swp.reduce_executor(g)
    assert (np.asarray(nv_f) <= np.asarray(nv_s)).all()
    # and both yield the oracle's pairs in the guaranteed dimension
    d_off = make_topo_plan(dim=1, method="both", **CAPS).execute(g)
    for plan in (p_fix, p_swp):
        d = plan.execute(g)
        for b in range(g.batch):
            assert _pairs(d, b, 1) == _pairs(d_off, b, 1), b


def test_repack_plan_validation():
    with pytest.raises(ValueError, match="repack"):
        make_topo_plan(dim=1, method="both", repack="sideways")

    class _FakeDevices:
        size = 4

    class _FakeMesh:
        devices = _FakeDevices()
        axis_names = ("data",)

    with pytest.raises(ValueError, match="mesh"):
        make_topo_plan(dim=1, method="both", repack="on", mesh=_FakeMesh())


# ------------------------------------------------------------- serve/stream

def test_serve_repack_parity_and_rung_sharing():
    from repro.serve import TopoServe, TopoServeConfig

    srv = TopoServe(TopoServeConfig(method="both", repack="on"))
    graphs = [nx.star_graph(8), nx.star_graph(25), nx.cycle_graph(6),
              nx.gnp_random_graph(30, 0.12, seed=4)]
    futs = [None] * len(graphs)
    for i, gnx in enumerate(graphs):
        nodes = sorted(gnx.nodes())
        idx = {u: j for j, u in enumerate(nodes)}
        futs[i] = srv.submit(
            edges=[(idx[u], idx[v]) for (u, v) in gnx.edges()],
            n_vertices=len(nodes))
    assert srv.drain() == len(graphs)
    assert len({f.bucket for f in futs}) >= 2
    for gnx, f in zip(graphs, futs):
        assert f.repack_class is not None
        assert f.repack_class.n_pad <= f.bucket.n_pad
        nodes = sorted(gnx.nodes())
        idx = {u: j for j, u in enumerate(nodes)}
        direct = topological_signature(
            from_edge_lists([[(idx[u], idx[v]) for (u, v) in gnx.edges()]],
                            [len(nodes)], n_pad=f.bucket.n_pad),
            dim=1, method="both", edge_cap=f.bucket.edge_cap,
            tri_cap=f.bucket.tri_cap)
        got = f.result()
        want = jax.tree.map(lambda x: x[0], direct)
        for k in (1,):
            got_pairs = sorted(zip(
                np.asarray(got.birth)[np.asarray(got.valid)
                                      & (np.asarray(got.dim) == k)].tolist(),
                np.asarray(got.death)[np.asarray(got.valid)
                                      & (np.asarray(got.dim) == k)].tolist()))
            assert got_pairs == _pairs_row(want, k)
    # the two star buckets both land on the small shared rung
    rungs_by_bucket: dict[int, set] = {}
    for (bn, rn) in srv.stats["repack_rungs"]:
        rungs_by_bucket.setdefault(rn, set()).add(bn)
    assert any(len(bs) > 1 for bs in rungs_by_bucket.values())


def _pairs_row(d, k):
    """Sorted (birth, death) pairs of one per-graph Diagrams slice."""
    b = np.asarray(d.birth)
    de = np.asarray(d.death)
    dm = np.asarray(d.dim)
    v = np.asarray(d.valid)
    sel = v & (dm == k)
    return sorted(zip(b[sel].tolist(), de[sel].tolist()))


def test_serve_and_similarity_share_one_ladder():
    from repro.serve.similarity import SimilarityServe
    from repro.serve.topo_serve import TopoServeConfig, repack_ladder_for

    sim = SimilarityServe(repack="on")
    srv_cfg = TopoServeConfig(repack="on")
    assert sim.server._repack_ladder == repack_ladder_for(
        tuple(sorted(srv_cfg.buckets)), srv_cfg.quad_cap)
    # the shared ladder flows end to end: add + query + rung accounting
    sim.add(edges=[(0, 1), (0, 2), (0, 3)], n_vertices=4, gid="star")
    fut = sim.submit(edges=[(0, 1), (0, 2)], n_vertices=3, k=1)
    sim.drain()
    assert fut.result().ids == ("star",)
    assert sim.repack_rungs()  # rung accounting flows through


def test_stream_repack_parity():
    from repro.core.delta import EDGE_DELETE, EDGE_INSERT, delta_from_lists
    from repro.stream import TopoStream, TopoStreamConfig, dim_pairs

    g = from_edge_lists([[(0, 1), (1, 2), (2, 3), (3, 0)]] * 2, [4, 4],
                        n_pad=16)
    cfg = TopoStreamConfig(dim=1, method="both", edge_cap=48, tri_cap=96,
                           repack="on")
    s = TopoStream(g, cfg)
    for ops in ([[(0, 2, EDGE_INSERT)], []],
                [[(0, 1, EDGE_DELETE)], [(1, 3, EDGE_INSERT)]]):
        d = s.apply(delta_from_lists(ops, edge_slots=1))
        ref = topological_signature(s.graph, dim=1, method="both",
                                    edge_cap=48, tri_cap=96)
        for b in range(2):
            assert dim_pairs(d, b, 1) == dim_pairs(ref, b, 1), b
    assert s.stats["recomputes"] > 0
    assert s.last_repack is not None
