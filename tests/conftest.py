"""Shared test fixtures/helpers.

NOTE: no XLA_FLAGS device-count override here — smoke tests and benches must
see the real single CPU device; only launch/dryrun.py forces 512 devices.
"""
from __future__ import annotations

import numpy as np
import networkx as nx
import pytest

from repro.core import from_networkx


def hypothesis_or_stub():
    """(given, settings, st) from hypothesis, or skip-stubs without it.

    hypothesis is an optional ``[dev]`` extra (see pyproject.toml).  Modules
    that are pure property tests use ``pytest.importorskip("hypothesis")``;
    modules mixing property tests with plain tests use this helper so the
    plain tests still collect and run when hypothesis is absent.
    """
    try:
        from hypothesis import given, settings, strategies as st
        return given, settings, st
    except ImportError:
        def given(*_args, **_kwargs):
            def deco(fn):
                def stub():
                    pytest.skip("hypothesis not installed (pip install .[dev])")
                stub.__name__ = fn.__name__
                stub.__doc__ = fn.__doc__
                return stub
            return deco

        def settings(*_args, **_kwargs):
            return lambda fn: fn

        class _StubStrategies:
            def __getattr__(self, _name):
                return lambda *a, **k: None

        return given, settings, _StubStrategies()


def random_graphs(kind: str, count: int, seed: int = 0):
    rng = np.random.default_rng(seed)
    out = []
    for i in range(count):
        s = int(rng.integers(0, 2**31 - 1))
        if kind == "er":
            n = int(rng.integers(6, 20))
            p = float(rng.uniform(0.15, 0.6))
            out.append(nx.gnp_random_graph(n, p, seed=s))
        elif kind == "ba":
            n = int(rng.integers(8, 24))
            m = int(rng.integers(1, 4))
            out.append(nx.barabasi_albert_graph(n, m, seed=s))
        elif kind == "plc":
            n = int(rng.integers(8, 24))
            out.append(nx.powerlaw_cluster_graph(n, 2, 0.5, seed=s))
        elif kind == "complete":
            out.append(nx.complete_graph(int(rng.integers(3, 8))))
        else:
            raise ValueError(kind)
    return out


def graphs_to_batch(graphs, n_pad=None, f_mode="degree", seed=0):
    g = from_networkx(graphs, n_pad=n_pad)
    if f_mode == "random":
        rng = np.random.default_rng(seed)
        import jax.numpy as jnp

        f = rng.integers(0, 7, size=g.f.shape).astype(np.float32)
        f = np.where(np.asarray(g.mask), f, np.inf)
        from repro.core.graph import GraphBatch

        g = GraphBatch(adj=g.adj, mask=g.mask, f=jnp.asarray(f))
    return g


@pytest.fixture(scope="session")
def er_batch():
    gs = random_graphs("er", 6, seed=1)
    return gs, graphs_to_batch(gs, n_pad=24)


@pytest.fixture(scope="session")
def ba_batch():
    gs = random_graphs("ba", 6, seed=2)
    return gs, graphs_to_batch(gs, n_pad=24)
