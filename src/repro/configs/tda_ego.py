"""The paper's own workload as a config: batched ego-net persistence
(CoralTDA + PrunIT + bit-packed GF(2) PH) — §6.2 of the paper at cluster
scale.  Not an LM; consumed by launch/dryrun.py as the technique-
representative cell ("tda_ego" x "ego_pd").
"""
import dataclasses


@dataclasses.dataclass(frozen=True)
class TDAConfig:
    name: str = "tda_ego"
    n_pad: int = 64          # padded vertices per ego-net
    graphs_per_device: int = 64
    max_dim: int = 1
    edge_cap: int = 512
    tri_cap: int = 1024
    sublevel: bool = False   # degree + superlevel (paper Fig 5 setting)


def config() -> TDAConfig:
    return TDAConfig()
