"""zamba2-7b [hybrid]: Mamba2 backbone + shared attention blocks.

81L d_model=3584 32H (GQA kv=32) d_ff=14336 vocab=32000 ssm_state=64
[arXiv:2411.15242; unverified].  Shared attn block applied every 6 mamba
layers (weights shared across applications).  Sub-quadratic (Mamba state is
O(1); only the shared-attn KV grows) -> eligible for long_500k.
Paper technique (CoralTDA/PrunIT): inapplicable to the forward path (not a
graph model) — see DESIGN.md §Arch-applicability.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="zamba2-7b", family="hybrid", n_layers=81, d_model=3584,
        n_heads=32, n_kv_heads=32, d_ff=14336, vocab_size=32000,
        ssm_state=64, ssm_expand=2, ssm_head_dim=64, attn_period=6,
        rope_theta=10000.0, supports_long_context=True,
    )
