"""rwkv6-1.6b [ssm]: Finch — attention-free, data-dependent decay.

24L d_model=2048 d_ff=7168 vocab=65536 [arXiv:2404.05892; unverified].
O(1) decode state -> eligible for long_500k.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-1.6b", family="ssm", rwkv=True, n_layers=24,
        d_model=2048, n_heads=32, n_kv_heads=32, d_ff=7168,
        vocab_size=65536, supports_long_context=True,
    )
