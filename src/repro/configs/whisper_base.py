"""whisper-base [audio]: enc-dec backbone; conv frontend is a STUB
(input_specs provides precomputed frame embeddings, per the assignment).

6L enc + 6L dec, d_model=512 8H (kv=8) d_ff=2048 vocab=51865
[arXiv:2212.04356; unverified].  Deviation: RoPE instead of learned
positional embeddings (keeps cache shapes static across shape cells); noted
in DESIGN.md §8.  Plain (non-gated) GELU MLP as in the original.
long_500k skipped (full-attention decoder).

Vocab padded 51865 -> 51872 (Megatron-style padding to the 16-way TP axis;
the 7 pad ids are never emitted by the tokenizer stub and never appear as
labels, so the loss is unchanged).  Noted in DESIGN.md §8.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="whisper-base", family="encdec", n_layers=6, n_enc_layers=6,
        d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048, vocab_size=51872,
        enc_seq=1500, gated_mlp=False, rope_theta=10000.0,
    )
