"""Architecture registry: --arch <id> -> ModelConfig."""
from __future__ import annotations

import importlib

ARCHS = {
    "zamba2-7b": "zamba2_7b",
    "codeqwen1.5-7b": "codeqwen15_7b",
    "qwen3-1.7b": "qwen3_1p7b",
    "qwen1.5-4b": "qwen15_4b",
    "gemma3-27b": "gemma3_27b",
    "olmoe-1b-7b": "olmoe_1b_7b",
    "phi3.5-moe-42b-a6.6b": "phi35_moe",
    "rwkv6-1.6b": "rwkv6_1p6b",
    "whisper-base": "whisper_base",
    "qwen2-vl-2b": "qwen2_vl_2b",
    "tda_ego": "tda_ego",
}


def get_config(arch: str):
    if arch not in ARCHS:
        raise KeyError(f"unknown arch {arch!r}; known: {sorted(ARCHS)}")
    mod = importlib.import_module(f"repro.configs.{ARCHS[arch]}")
    return mod.config()


def reduced_config(arch: str):
    """Tiny same-family config for CPU smoke tests."""
    import dataclasses

    cfg = get_config(arch)
    if arch == "tda_ego":
        return cfg
    updates = dict(
        n_layers=min(cfg.n_layers, 4), d_model=128, d_ff=256, vocab_size=512,
        n_heads=4, n_kv_heads=max(1, min(cfg.n_kv_heads * 4 // cfg.n_heads, 4)),
        d_head=32 if cfg.d_head else 0, attn_chunk=64, ssm_chunk=32,
    )
    if cfg.family == "moe":
        updates.update(n_experts=8, moe_top_k=2)
    if cfg.family == "hybrid":
        updates.update(n_layers=7, attn_period=3, ssm_state=16, ssm_head_dim=16)
    if cfg.local_global_pattern != (0, 0):
        updates.update(n_layers=5, local_global_pattern=(1, 1), sliding_window=8)
    if cfg.family == "encdec":
        updates.update(n_layers=2, n_enc_layers=2, enc_seq=16)
    if cfg.mrope_sections:
        updates.update(mrope_sections=(4, 6, 6), vision_tokens=4)
    return dataclasses.replace(cfg, **updates)
