"""olmoe-1b-7b [moe]: 64 experts, top-8.

16L d_model=2048 16H (kv=16) d_ff=1024/expert vocab=50304
[arXiv:2409.02060; hf].
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b", family="moe", n_layers=16, d_model=2048,
        n_heads=16, n_kv_heads=16, d_ff=1024, vocab_size=50304,
        n_experts=64, moe_top_k=8, qk_norm=True, rope_theta=10000.0,
    )
