"""qwen2-vl-2b [vlm]: M-RoPE, dynamic resolution (frontend STUB: precomputed
patch embeddings via input_specs, per the assignment).

28L d_model=1536 12H (kv=2) d_ff=8960 vocab=151936 [arXiv:2409.12191; hf].
M-RoPE sections (16, 24, 24) over head_dim/2 = 64.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-2b", family="vlm", n_layers=28, d_model=1536,
        n_heads=12, n_kv_heads=2, d_ff=8960, vocab_size=151936,
        d_head=128, qkv_bias=True, mrope_sections=(16, 24, 24),
        vision_tokens=256, rope_theta=1_000_000.0,
    )
