"""One config module per assigned architecture (+ the paper's own workload)."""
from repro.configs.registry import ARCHS, get_config

__all__ = ["ARCHS", "get_config"]
