"""gemma3-27b [dense]: 5:1 local:global attention, 128k context.

62L d_model=5376 32H (kv=16) d_ff=21504 vocab=262144
[hf:google/gemma-3-1b-pt; unverified].  62 = 10x(5 local + 1 global) + 2
local tail.  Local window 1024.  Global layers are full attention ->
long_500k skipped (pure-quadratic global path), noted in EXPERIMENTS.md.
"""
from repro.models.config import ModelConfig


def config() -> ModelConfig:
    return ModelConfig(
        name="gemma3-27b", family="dense", n_layers=62, d_model=5376,
        n_heads=32, n_kv_heads=16, d_ff=21504, vocab_size=262144,
        d_head=128, qk_norm=True, sliding_window=1024,
        local_global_pattern=(5, 1), rope_theta=1_000_000.0,
    )
