"""StreamServe: stateful TopoStream sessions behind a TopoServe-style API.

TopoServe (topo_serve.py) serves stateless one-shot queries; StreamServe
gives the serving layer *sessions*: a client registers a GraphBatch once,
then keeps submitting :class:`~repro.core.delta.DeltaBatch` updates to its
session id.  ``drain()`` applies each session's queued updates in submission
order through its :class:`~repro.stream.TopoStream` — so most ticks are
answered from cache by the reduction-aware invalidation check — and resolves
the futures with the fresh-or-cached diagrams plus that step's
hit/miss/recompute verdict.

Same sync-first design as TopoServe: ``submit``/``drain`` under one lock,
``serve_forever`` for a dedicated drain thread.  The counter surface
(``stats()``, ``session_stats(sid)``) exposes cumulative hits, coral/prunit
hit split, recomputes and skip rate, per session and aggregated.
"""
from __future__ import annotations

import threading
import time
from collections import deque
from typing import Optional

from repro import obs
from repro.obs import flight as _flight
from repro.obs.context import DeadlineExceeded, resolve_submit
from repro.core.delta import DeltaBatch
from repro.core.graph import GraphBatch
from repro.core.persistence_jax import Diagrams
from repro.serve.futures import ServeFuture
from repro.stream.topo_stream import TopoStream, TopoStreamConfig

# aggregate per-step outcome keys (the ``stats()`` surface)
_AGG_KEYS = ("graph_updates", "hits", "coral_hits", "prunit_hits",
             "recomputes", "anomalies")

# TopoScope instruments, one series per server instance.  Aggregates are
# incremented per applied step in ``_apply_items`` — i.e. UNDER the
# session's apply lock — fixing the pre-TopoScope inconsistency where
# ``stats()`` folded live session dicts and ``_closed_stats`` outside it
# (a drain racing a close could double- or under-count a step).
_C_STEPS = obs.counter(
    "stream.steps", help="per-step verdict outcomes, aggregated per server")
_C_OPENED = obs.counter("stream.sessions_opened")
_C_CLOSED = obs.counter("stream.sessions_closed")
_G_LIVE = obs.gauge("stream.sessions_live",
                    help="currently registered sessions per server")

# TopoWatch request-outcome instruments are SHARED with TopoServe (same
# registry names, bucket="session"), so the serve-wide SLO ratios in
# obs/slo.py — deadline misses / submissions, failures / submissions —
# see every frontend's traffic with one selector.
_C_SUBMITTED = obs.counter("serve.submitted")
_C_FAILED = obs.counter("serve.failed")
_C_DEADLINE = obs.counter("serve.deadline_exceeded")
_C_CANCELLED = obs.counter("serve.cancelled")
_H_LATENCY = obs.histogram("serve.request_latency_seconds")
_G_HEARTBEAT = obs.gauge("serve.heartbeat_ts")
_G_READY = obs.gauge("serve.ready")
_BUCKET = "session"  # stream steps have no padding bucket


class StreamFuture(ServeFuture):
    """Handle for one submitted update step; resolved by a later drain.

    ``result()`` returns the session's maintained Diagrams as of this step;
    ``info`` (available once done) is that step's verdict summary:
    ``{"graph_updates", "hits", "coral_hits", "prunit_hits", "recomputes",
    "anomalies"}`` plus, when the session scores drift
    (``TopoStreamConfig.drift_metric``), ``"drift"`` — the per-graph
    diagram-distance array of this step — and ``"anomaly"`` — its
    thresholded flags.  Thread-safe plumbing lives in ``ServeFuture``.
    """

    __slots__ = ("info", "session_id")

    def __init__(self, session_id: str, request_id: Optional[str] = None,
                 deadline: Optional[float] = None):
        super().__init__(request_id=request_id, deadline=deadline)
        self.info: Optional[dict] = None
        self.session_id = session_id

    def _resolve(self, value: Diagrams, info: dict) -> bool:  # type: ignore[override]
        self.info = info
        return super()._resolve(value)


class _Session:
    __slots__ = ("sid", "stream", "queue", "apply_lock")

    def __init__(self, sid: str, stream: TopoStream):
        self.sid = sid
        self.stream = stream
        self.queue: deque = deque()
        # serializes appliers: TopoStream is stateful, so concurrent drains
        # (serve_forever thread + a manual drain) must not interleave a
        # session's steps
        self.apply_lock = threading.Lock()


class StreamServe:
    """Session manager: one TopoStream per session id, drained like a server.

    >>> server = StreamServe()
    >>> sid = server.create_session(g0)
    >>> fut = server.submit(sid, delta)
    >>> server.drain()
    1
    >>> diagrams, verdict = fut.result(), fut.info
    """

    def __init__(self, config: TopoStreamConfig | None = None):
        self.config = config or TopoStreamConfig()
        self._lock = threading.Lock()
        self._sessions: dict[str, _Session] = {}
        self._next_id = 0
        self._stopped = threading.Event()
        self._obs_instance = obs.next_instance("stream")

    # ----------------------------------------------------------- sessions

    def create_session(self, g: GraphBatch,
                       config: TopoStreamConfig | None = None) -> str:
        """Register a GraphBatch; computes its initial diagrams eagerly."""
        with obs.span("stream.init", frontend="stream"):
            stream = TopoStream(g, config or self.config)
        with self._lock:
            sid = f"s{self._next_id}"
            self._next_id += 1
            self._sessions[sid] = _Session(sid, stream)
        _C_OPENED.inc(instance=self._obs_instance)
        _G_LIVE.inc(instance=self._obs_instance)
        return sid

    def close_session(self, sid: str) -> dict:
        """Drop a session; returns its final stats.  Pending futures fail.

        Takes the session's apply lock so an in-flight drain finishes its
        current items first; queue hand-off happens under the global lock so
        a future is failed by close XOR resolved by a drain, never both.
        """
        with self._lock:
            sess = self._sessions.pop(sid, None)
        if sess is None:
            raise KeyError(f"unknown session {sid!r}")
        with sess.apply_lock:
            with self._lock:
                pending = list(sess.queue)
                sess.queue.clear()
            for (_, fut) in pending:
                fut._fail(RuntimeError(f"session {sid} closed before drain"))
            # aggregates need no folding: _apply_items already counted every
            # applied step into the registry, and those counters outlive the
            # session
            _C_CLOSED.inc(instance=self._obs_instance)
            _G_LIVE.dec(instance=self._obs_instance)
            return dict(sess.stream.stats)

    def diagrams(self, sid: str) -> Diagrams:
        """Current maintained diagrams of a session (no queue interaction)."""
        return self._session(sid).stream.diagrams

    def graph(self, sid: str) -> GraphBatch:
        return self._session(sid).stream.graph

    def _session(self, sid: str) -> _Session:
        with self._lock:
            sess = self._sessions.get(sid)
        if sess is None:
            raise KeyError(f"unknown session {sid!r}")
        return sess

    # ------------------------------------------------------------- ingest

    def submit(self, sid: str, delta: DeltaBatch, *,
               request_id: Optional[str] = None,
               deadline_s: Optional[float] = None) -> StreamFuture:
        """Enqueue one update step for a session (FIFO per session).

        Request id and optional deadline follow the TopoServe contract
        (explicit args > ambient ``obs.request_context()`` > fresh mint);
        expired steps are failed with ``DeadlineExceeded`` by the drain
        sweep, cancelled ones are skipped — a skipped step is NOT applied,
        so later steps of the session still see the pre-step state.
        """
        sess = self._session(sid)
        if delta.edge_u.ndim != 2:
            raise ValueError(
                "submit() takes one update step (leaves shaped (B, slots)); "
                "slice stacked streams with repro.core.delta.delta_step")
        if delta.batch != sess.stream.graph.batch:
            raise ValueError(
                f"delta batch {delta.batch} != session batch "
                f"{sess.stream.graph.batch}")
        rid, deadline = resolve_submit(request_id, deadline_s)
        fut = StreamFuture(sid, request_id=rid, deadline=deadline)
        with self._lock:
            # re-check under the lock: a concurrent close_session may have
            # popped the session after _session() returned it — appending to
            # the dead queue would orphan the future (never failed, never
            # resolved)
            if self._sessions.get(sid) is not sess:
                raise KeyError(f"session {sid!r} closed")
            sess.queue.append((delta, fut))
        _C_SUBMITTED.inc(instance=self._obs_instance, bucket=_BUCKET)
        return fut

    def pending(self) -> int:
        with self._lock:
            return sum(len(s.queue) for s in self._sessions.values())

    # -------------------------------------------------------------- drain

    def drain(self) -> int:
        """Apply every queued update, session by session, in FIFO order.

        Returns the number of update steps applied.  A failing step fails
        its own future and every later future of the same session (their
        base state is gone), then the session queue is cleared.
        """
        if not self.pending():
            return 0  # keep idle poll loops out of the trace
        with obs.span("stream.drain", frontend="stream") as sp:
            applied = 0
            while True:
                with self._lock:
                    # snapshot so one hot session cannot starve the others:
                    # each pass gives every queued session one turn
                    queued = [s for s in self._sessions.values() if s.queue]
                if not queued:
                    sp.set(applied=applied)
                    return applied
                for sess in queued:
                    # take the apply lock BEFORE popping: a concurrent drain
                    # of the same session blocks here, then pops strictly
                    # later items, so per-session FIFO order survives
                    # concurrent drains
                    with sess.apply_lock:
                        with self._lock:
                            items = list(sess.queue)
                            sess.queue.clear()
                        applied += self._apply_items(sess, items)

    def _apply_items(self, sess: _Session, items: list) -> int:
        applied = 0
        inst = self._obs_instance
        for i, (delta, fut) in enumerate(items):
            # TopoWatch sweep: a cancelled/expired step is NOT applied, so
            # the stream state stays exactly as if it was never submitted
            if fut.cancelled():
                _C_CANCELLED.inc(instance=inst, bucket=_BUCKET)
                _flight.record("serve", "cancelled_skip", frontend="stream",
                               session=sess.sid, rid=fut.request_id or "")
                continue
            if fut.expired():
                if fut._fail(DeadlineExceeded(
                        f"stream step {fut.request_id or '?'} expired "
                        f"before drain pickup (session {sess.sid})")):
                    _C_DEADLINE.inc(instance=inst, bucket=_BUCKET)
                    _flight.record("serve", "deadline_exceeded",
                                   frontend="stream", session=sess.sid,
                                   rid=fut.request_id or "")
                    _flight.auto_dump("deadline_exceeded")
                continue
            before = dict(sess.stream.stats)
            try:
                with obs.span("stream.step", session=sess.sid):
                    d = sess.stream.apply(delta)
            except Exception as e:
                n_failed = sum(1 for (_, later) in items[i:]
                               if later._fail(e))
                if n_failed:
                    _C_FAILED.inc(n_failed, instance=inst)
                _flight.record("serve", "step_failed", frontend="stream",
                               session=sess.sid, error=repr(e))
                break
            after = sess.stream.stats
            info = {k: after[k] - before[k] for k in _AGG_KEYS}
            # aggregate registry counters, incremented under the apply lock
            # every caller of this method holds (see drain/close_session)
            for k, v in info.items():
                if v:
                    _C_STEPS.inc(v, instance=inst, key=k)
            if sess.stream.config.drift_metric is not None:
                info["drift"] = sess.stream.last_drift.copy()
                info["anomaly"] = sess.stream.last_anomaly.copy()
            if fut._resolve(d, info):
                _H_LATENCY.observe(fut.latency_s(),
                                   instance=inst, bucket=_BUCKET)
            applied += 1
        return applied

    # --------------------------------------------------------------- loops

    def serve_forever(self, poll_s: float = 1e-3) -> None:
        """Blocking drain loop (run on a dedicated thread); stop() exits.

        Stamps ``serve.heartbeat_ts{frontend=stream}`` each iteration and
        holds ``serve.ready`` high while running (no plan warmup needed:
        sessions compile eagerly at ``create_session``), so ``/healthz`` /
        ``/readyz`` cover this frontend too.
        """
        inst = self._obs_instance
        _flight.record("serve", "loop_start", frontend="stream",
                       instance=inst)
        _G_HEARTBEAT.set(time.time(), frontend="stream", instance=inst)
        _G_READY.set(1, frontend="stream", instance=inst)
        try:
            while not self._stopped.is_set():
                _G_HEARTBEAT.set(time.time(), frontend="stream",
                                 instance=inst)
                try:
                    n = self.drain()
                except BaseException as e:
                    _flight.record("serve", "drain_exception",
                                   frontend="stream", error=repr(e))
                    _flight.auto_dump("drain_exception")
                    raise
                if n == 0:
                    self._stopped.wait(poll_s)
        finally:
            _G_READY.set(0, frontend="stream", instance=inst)
            _flight.record("serve", "loop_stop", frontend="stream",
                           instance=inst)

    def stop(self) -> None:
        self._stopped.set()

    # -------------------------------------------------------------- stats

    def session_stats(self, sid: str) -> dict:
        """One session's cumulative counters plus its skip rate."""
        stream = self._session(sid).stream
        out = dict(stream.stats)
        out["skip_rate"] = stream.skip_rate()
        return out

    def stats(self) -> dict:
        """Aggregate hit/miss/recompute counters over all sessions (live and
        closed) — the serving layer's cache-effectiveness surface.

        A dict-shaped view over the TopoScope registry: steps are counted
        once, at apply time, under the session's apply lock, so this read
        never races a drain or a close (pre-TopoScope it folded per-session
        dicts outside that lock).  Steps applied directly on a session's
        ``TopoStream`` object (bypassing ``submit``/``drain``) are that
        session's business and are not aggregated here.
        """
        inst = self._obs_instance
        with self._lock:
            n_live = len(self._sessions)
        agg = {k: int(_C_STEPS.value(instance=inst, key=k))
               for k in _AGG_KEYS}
        agg["sessions"] = n_live
        agg["sessions_closed"] = int(_C_CLOSED.value(instance=inst))
        agg["skip_rate"] = agg["hits"] / max(agg["graph_updates"], 1)
        return agg
