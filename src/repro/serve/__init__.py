"""Serving substrate: LM prefill/decode steps (serve_step), the TopoServe
batched persistence-diagram scheduler (topo_serve), and the StreamServe
stateful dynamic-graph session layer (stream_serve) — see
docs/ARCHITECTURE.md."""
from repro.serve.stream_serve import StreamFuture, StreamServe
from repro.serve.topo_serve import (
    DEFAULT_BUCKETS,
    Bucket,
    TopoFuture,
    TopoRequest,
    TopoServe,
    TopoServeConfig,
    pack_requests,
)

__all__ = [
    "Bucket",
    "DEFAULT_BUCKETS",
    "StreamFuture",
    "StreamServe",
    "TopoFuture",
    "TopoRequest",
    "TopoServe",
    "TopoServeConfig",
    "pack_requests",
]
