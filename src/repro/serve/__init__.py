"""Serving substrate: LM prefill/decode steps (serve_step) and the TopoServe
batched persistence-diagram scheduler (topo_serve) — see docs/ARCHITECTURE.md."""
from repro.serve.topo_serve import (
    DEFAULT_BUCKETS,
    Bucket,
    TopoFuture,
    TopoRequest,
    TopoServe,
    TopoServeConfig,
    pack_requests,
)

__all__ = [
    "Bucket",
    "DEFAULT_BUCKETS",
    "TopoFuture",
    "TopoRequest",
    "TopoServe",
    "TopoServeConfig",
    "pack_requests",
]
