"""Serving substrate: LM prefill/decode steps (serve_step), the TopoServe
batched persistence-diagram scheduler (topo_serve), the StreamServe
stateful dynamic-graph session layer (stream_serve), and the
SimilarityServe graph-similarity query path (similarity) — see
docs/ARCHITECTURE.md."""
from repro.serve.similarity import (
    SimilarityFuture,
    SimilarityResult,
    SimilarityServe,
)
from repro.serve.stream_serve import StreamFuture, StreamServe
from repro.serve.topo_serve import (
    DEFAULT_BUCKETS,
    Bucket,
    TopoFuture,
    TopoRequest,
    TopoServe,
    TopoServeConfig,
    pack_requests,
)

__all__ = [
    "Bucket",
    "DEFAULT_BUCKETS",
    "SimilarityFuture",
    "SimilarityResult",
    "SimilarityServe",
    "StreamFuture",
    "StreamServe",
    "TopoFuture",
    "TopoRequest",
    "TopoServe",
    "TopoServeConfig",
    "pack_requests",
]
