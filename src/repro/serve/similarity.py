"""SimilarityServe: graph-similarity queries over TopoServe + TopoIndex.

The third serving surface (after stateless TopoServe and session-ful
StreamServe): a client submits a *graph* and gets back the ``k`` nearest
*indexed* graphs with their diagram distances.  The drain is **two-phase**
— the same coarse→exact shape PR 4 gave reduce→repack→persist:

```
submit(edges, n, f, k) ──► TopoServe.submit          (bucketed PD batch path)
drain() ──► TopoServe.drain()                         (diagrams computed)
        ──► stage 1 (retrieve): ONE TopoIndex.query per shape group for
            top k·overfetch candidates — embedding-L1 Gram kernel, itself
            optionally LSH-prefiltered inside the index
        ──► stage 2 (re-rank, ``rerank="exact_w"``): batched auction-LAP
            exact Wasserstein between each query diagram and its
            candidates' stored compacted clouds, one MetricEngine
            ``compare_info`` per shape group
        ──► resolve SimilarityFuture(ids, distances, backends, diagrams)
```

``stage1_backend="exact_w"`` replaces the retrieve funnel entirely: stage 1
scores every query against **every** stored cloud with the exact metric —
recall 1.0 by construction, no overfetch/re-rank — which the
reservoir-collapsed forward/reverse auction plus the **price cache** makes
viable.  Every exact solve (stage-1 exact or stage-2 re-rank) routes
through one ``_exact_pairs`` helper that warm-starts the solver from an
LRU of converged price vectors keyed by ``(query LSH bucket code,
candidate row)`` (``repro.metrics.price_cache``): near-duplicate queries
land in the same hyperplane bucket and inherit each other's equilibrium
prices across drains.

``stats`` reports the stages separately (``stage1_candidates``,
``stage2_pairs``, per-stage wall seconds), plus the auction telemetry
(``auction_rounds``, ``warm_start_hits``/``misses``), and every resolved
distance carries its backend label (``"gram"`` vs ``"exact_w"``) so
clients never mix the coarse and exact distance scales silently.

Indexing goes through the same diagram path (``add`` submits to the inner
server and indexes at drain), so corpus and queries share compiled plans
and the embedding contract of ``TopoIndex`` — a graph served from any
padding bucket lands in the same embedding space.

With ``repack="on"`` (pass it to the constructor, or set it on the
``TopoServeConfig``) queries and corpus adds are no longer persisted at
their *input*-shape bucket caps: the inner server's two-phase plans route
every reduced graph through the one serve-wide persist ladder
(``repro.serve.topo_serve.repack_ladder_for`` — the same helper TopoServe
uses, so there is exactly one bucket-ladder definition), and similarity
queries share reduced-size compiled persist plans with every other serving
surface in the process.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.obs import flight as _flight
from repro.obs.context import DeadlineExceeded, resolve_submit
from repro.index.sharded_index import ShardedIndex
from repro.index.topo_index import TopoIndex, TopoIndexConfig
from repro.metrics.engine import compare_info
from repro.metrics.price_cache import PriceCache
from repro.serve.futures import ServeFuture
from repro.serve.topo_serve import TopoFuture, TopoServe, TopoServeConfig

RERANKS = ("off", "exact_w")
STAGE1_BACKENDS = ("gram", "exact_w")

# TopoScope instruments (one series per server instance); ``stats`` is a
# dict-shaped view over these.  stage1/stage2 wall-seconds are float
# counters — same semantics as the pre-TopoScope accumulators.
_C_EVENTS = obs.counter(
    "similarity.events",
    help="queries resolved / graphs indexed / add failures")
_C_STAGE = obs.counter(
    "similarity.stage_totals",
    help="stage1 candidates fetched, stage2 exact pairs, per-stage seconds")
_H_STAGE_S = obs.histogram(
    "similarity.stage_seconds", help="per-drain stage wall time")

# auction solver telemetry for the exact_w paths (stage-1 exact backend and
# the stage-2 re-rank both route through _exact_pairs); the warm-start
# hit/miss counters live with the cache itself (metrics/price_cache.py)
_C_ROUNDS = obs.counter(
    "auction.rounds",
    help="total bidding rounds spent by serve-side exact_w solves")
_H_ROUNDS = obs.histogram(
    "auction.rounds_per_pair",
    help="mean auction rounds per pair, one observation per exact batch")

# TopoWatch request-outcome instruments shared with the other frontends
# (bucket="query"), plus the liveness/readiness gauges for /healthz//readyz.
_C_DEADLINE = obs.counter("serve.deadline_exceeded")
_C_CANCELLED = obs.counter("serve.cancelled")
_H_LATENCY = obs.histogram("serve.request_latency_seconds")
_G_HEARTBEAT = obs.gauge("serve.heartbeat_ts")
_G_READY = obs.gauge("serve.ready")
_BUCKET = "query"


@dataclasses.dataclass(frozen=True)
class SimilarityResult:
    """kNN answer for one query graph: parallel id/distance lists plus the
    query's own Diagrams slice (so clients can inspect or re-index it).
    ``backends[i]`` names the metric backend that produced ``distances[i]``
    (``"gram"`` embedding-L1, or ``"exact_w"`` after the re-rank stage)."""

    ids: tuple[str, ...]
    distances: tuple[float, ...]
    diagrams: object  # per-graph Diagrams slice (leaves shaped (S,))
    backends: tuple[str, ...] = ()


class SimilarityFuture(ServeFuture):
    """Handle for one similarity query; resolves to a SimilarityResult.

    ``cancel()`` also cancels the inner PD future, so a cancelled query
    skips BOTH phases: the bucketed diagram batch slot and the
    retrieve/re-rank work.
    """

    __slots__ = ("k", "inner")

    def __init__(self, k: int, request_id: Optional[str] = None,
                 deadline: Optional[float] = None,
                 inner: Optional[TopoFuture] = None):
        super().__init__(request_id=request_id, deadline=deadline)
        self.k = k
        self.inner = inner

    def cancel(self) -> bool:
        won = super().cancel()
        if won and self.inner is not None:
            self.inner.cancel()
        return won


def _stack_by_shape(rows):
    """Group per-graph Diagrams rows by leaf shape and stack each group.

    Rows resolved in one drain can come from different padding buckets and
    therefore carry different tensor sizes S; the embedding is S-independent
    but ``jnp.stack`` is not, so batching happens per shape class.  Yields
    ``(original_indices, stacked_batch)``.
    """
    groups: dict[tuple, list[int]] = {}
    for i, r in enumerate(rows):
        groups.setdefault(tuple(r.birth.shape), []).append(i)
    for idxs in groups.values():
        batch = jax.tree.map(lambda *xs: jax.numpy.stack(xs),
                             *[rows[i] for i in idxs])
        yield idxs, batch


class SimilarityServe:
    """Similarity-search front end over a TopoServe and a TopoIndex.

    >>> server = SimilarityServe()
    >>> server.add(edges=[(0, 1), (1, 2), (2, 0)], n_vertices=3, gid="tri")
    >>> fut = server.submit(edges=[(0, 1), (1, 2)], n_vertices=3, k=1)
    >>> server.drain()
    >>> fut.result().ids
    ('tri',)
    """

    def __init__(self, index: TopoIndex | None = None,
                 config: TopoServeConfig | None = None,
                 index_config: TopoIndexConfig | None = None,
                 default_k: int = 5, mesh=None,
                 repack: str | None = None,
                 rerank: str = "off", overfetch: int = 4,
                 stage1_backend: str = "gram",
                 price_cache_size: int = 4096,
                 sharded: bool = False, index_mesh=None):
        if rerank not in RERANKS:
            raise ValueError(f"unknown rerank {rerank!r}; want {RERANKS}")
        if stage1_backend not in STAGE1_BACKENDS:
            raise ValueError(f"unknown stage1_backend {stage1_backend!r}; "
                             f"want {STAGE1_BACKENDS}")
        # sharded=True swaps in the mesh-sharded index flavor; every drain
        # path below only touches the shared TopoIndex query surface
        # (add/query/clouds/query_codes/ids/config), so stage-1 retrieval,
        # the stage-2 exact re-rank (shard-owner cloud gathers), stats and
        # obs counters all ride the sharded index transparently
        if index is not None:
            self.index = (ShardedIndex.from_index(index, mesh=index_mesh)
                          if sharded and not isinstance(index, ShardedIndex)
                          else index)
        elif sharded:
            self.index = ShardedIndex(index_config, mesh=index_mesh)
        else:
            self.index = TopoIndex(index_config)
        if repack is not None:
            config = dataclasses.replace(config or TopoServeConfig(),
                                         repack=repack)
        # the inner TopoServe owns bucket routing AND (repack="on") the
        # measure/repack helper + persist ladder — similarity queries are
        # re-bucketed by their *reduced* shape, not just their input shape
        self.server = TopoServe(config, mesh=mesh)
        self.default_k = int(default_k)
        self.rerank = rerank
        self.stage1_backend = stage1_backend
        self.overfetch = max(int(overfetch), 1)
        self._lock = threading.Lock()
        # serializes drains: the TopoIndex is not internally synchronized, so
        # concurrent index.add/query (embedding store mutation) must not race
        self._drain_lock = threading.Lock()
        self._pending_queries: list[tuple[TopoFuture, SimilarityFuture]] = []
        self._pending_adds: list[tuple[TopoFuture, Optional[str]]] = []
        self._stopped = threading.Event()
        self._obs_instance = obs.next_instance("sim")
        # converged price vectors for exact_w warm starts, keyed by
        # (query LSH bucket code, candidate row); used by _exact_pairs
        self._price_cache = PriceCache(price_cache_size,
                                       instance=self._obs_instance)

    @property
    def stats(self) -> dict:
        """Dict-shaped view over the TopoScope registry (backward compat
        with the pre-TopoScope ad-hoc ``stats`` dict, same keys)."""
        inst = self._obs_instance
        return {
            "queries": int(_C_EVENTS.value(instance=inst, event="query")),
            "indexed": int(_C_EVENTS.value(instance=inst, event="indexed")),
            "add_failures": int(_C_EVENTS.value(instance=inst,
                                                event="add_failure")),
            "stage1_candidates": int(_C_STAGE.value(instance=inst,
                                                    what="candidates",
                                                    stage="1")),
            "stage2_pairs": int(_C_STAGE.value(instance=inst, what="pairs",
                                               stage="2")),
            "stage1_s": float(_C_STAGE.value(instance=inst, what="seconds",
                                             stage="1")),
            "stage2_s": float(_C_STAGE.value(instance=inst, what="seconds",
                                             stage="2")),
            "cancelled": int(_C_CANCELLED.total(instance=inst)),
            "deadline_exceeded": int(_C_DEADLINE.total(instance=inst)),
            "auction_rounds": int(_C_ROUNDS.value(instance=inst)),
            "warm_start_hits": self._price_cache.hits,
            "warm_start_misses": self._price_cache.misses,
        }

    # ------------------------------------------------------------- ingest

    def add(self, edges: Sequence[tuple[int, int]], n_vertices: int,
            f: Sequence[float] | None = None,
            gid: Optional[str] = None) -> None:
        """Enqueue one graph for indexing (takes effect at the next drain)."""
        fut = self.server.submit(edges=edges, n_vertices=n_vertices, f=f)
        with self._lock:
            self._pending_adds.append((fut, gid))

    def submit(self, edges: Sequence[tuple[int, int]], n_vertices: int,
               f: Sequence[float] | None = None,
               k: int | None = None, *,
               request_id: Optional[str] = None,
               deadline_s: Optional[float] = None) -> SimilarityFuture:
        """Enqueue one similarity query; resolved by a later ``drain()``.

        The request id and deadline are minted once here and shared with
        the inner PD future, so an expired query is swept out of the
        bucketed batch by TopoServe's drain (counted there, per bucket)
        and the similarity layer just propagates the ``DeadlineExceeded``.
        """
        rid, deadline = resolve_submit(request_id, deadline_s)
        rem = (None if deadline is None
               else deadline - time.monotonic())
        fut = self.server.submit(edges=edges, n_vertices=n_vertices, f=f,
                                 request_id=rid, deadline_s=rem)
        sim = SimilarityFuture(
            k=int(k) if k is not None else self.default_k,
            request_id=rid, deadline=deadline, inner=fut)
        with self._lock:
            self._pending_queries.append((fut, sim))
        return sim

    def pending(self) -> int:
        with self._lock:
            return len(self._pending_queries) + len(self._pending_adds)

    def repack_rungs(self) -> dict:
        """(bucket n_pad, persist rung n_pad) -> graphs, from the inner
        server (empty unless ``repack="on"``)."""
        return dict(self.server.stats["repack_rungs"])

    # ------------------------------------------------------------- drain

    def drain(self) -> int:
        """Drain the inner server, index pending adds, answer queries.

        Adds are indexed before queries are answered, so a corpus graph and
        a query submitted before the same drain see each other.  Items whose
        inner future is still unresolved (submitted concurrently with this
        drain, after the inner server flushed) stay pending for the next
        drain.  Returns the number of similarity queries resolved.
        """
        with self._drain_lock:
            self.server.drain()
            with self._lock:
                adds, self._pending_adds = self._pending_adds, []
                queries, self._pending_queries = self._pending_queries, []

            done_adds, later_adds = [], []
            for (f, gid) in adds:
                if not f.done():  # raced a concurrent submit: keep for later
                    later_adds.append((f, gid))
                    continue
                try:
                    done_adds.append((f.result(timeout=0), gid))
                except Exception:  # a failed PD batch must not wedge indexing
                    _C_EVENTS.inc(instance=self._obs_instance,
                                  event="add_failure")
            for idxs, batch in _stack_by_shape([r for (r, _) in done_adds]):
                ids = [done_adds[i][1] for i in idxs]
                try:
                    self.index.add(
                        batch, ids=None if all(i is None for i in ids)
                        else [i if i is not None
                              else f"g{len(self.index) + j}"
                              for j, i in enumerate(ids)])
                    _C_EVENTS.inc(len(idxs), instance=self._obs_instance,
                                  event="indexed")
                except Exception:  # e.g. duplicate gid: drop group, continue
                    _C_EVENTS.inc(len(idxs), instance=self._obs_instance,
                                  event="add_failure")

            resolved = 0
            ready: list[tuple[object, SimilarityFuture]] = []
            later_queries = []
            now = time.monotonic()
            for (f, sim) in queries:
                if sim.cancelled():
                    # inner future already cancelled too (linked cancel);
                    # skip the retrieve/re-rank work entirely
                    _C_CANCELLED.inc(instance=self._obs_instance,
                                     bucket=_BUCKET)
                    _flight.record("serve", "cancelled_skip",
                                   frontend="similarity",
                                   rid=sim.request_id or "")
                    continue
                if sim.expired(now) and not f.done():
                    # inner sweep has not seen it yet (e.g. manual drain
                    # raced); fail here rather than hold the query over
                    if sim._fail(DeadlineExceeded(
                            f"similarity query {sim.request_id or '?'} "
                            "expired before drain pickup")):
                        _C_DEADLINE.inc(instance=self._obs_instance,
                                        bucket=_BUCKET)
                        _flight.auto_dump("deadline_exceeded")
                    continue
                if not f.done():
                    later_queries.append((f, sim))
                    continue
                try:
                    ready.append((f.result(timeout=0), sim))
                except Exception as e:  # propagate batch failure, don't wedge
                    sim._fail(e)
            if later_adds or later_queries:
                with self._lock:  # prepend: next drain sees FIFO order
                    self._pending_adds[:0] = later_adds
                    self._pending_queries[:0] = later_queries
            if not ready:
                return 0
            if not len(self.index):
                err = ValueError("similarity query against an empty index "
                                 "(add() graphs before querying)")
                for (_, sim) in ready:
                    sim._fail(err)
                return 0
            for idxs, batch in _stack_by_shape([r for (r, _) in ready]):
                sims = [ready[i][1] for i in idxs]
                try:
                    k_max = max(sim.k for sim in sims)
                    if self.stage1_backend == "exact_w":
                        # exact stage 1: no retrieve funnel, no stage 2 —
                        # every corpus entry is scored exactly already
                        ids, dists, backends = self._stage1_exact(
                            batch, k_max)
                    else:
                        k_fetch = (k_max * self.overfetch
                                   if self.rerank != "off" else k_max)
                        t0 = time.perf_counter()
                        with obs.span("similarity.stage1",
                                      frontend="similarity",
                                      k=k_fetch) as sp1:
                            res = self.index.query(batch, k=k_fetch)
                            n_cand = sum(len(row) for row in res.ids)
                            sp1.set(candidates=n_cand)
                        dt1 = time.perf_counter() - t0
                        inst = self._obs_instance
                        _C_STAGE.inc(dt1, instance=inst, what="seconds",
                                     stage="1")
                        _C_STAGE.inc(n_cand, instance=inst,
                                     what="candidates", stage="1")
                        _H_STAGE_S.observe(dt1, instance=inst, stage="1")
                        ids, dists, backends = (res.ids, res.distances,
                                                res.backends)
                        if self.rerank == "exact_w":
                            with obs.span("similarity.stage2",
                                          frontend="similarity") as sp2:
                                ids, dists, backends = self._rerank_exact(
                                    batch, res)
                                sp2.set(pairs=res.rows.shape[0]
                                        * res.rows.shape[1])
                except Exception as e:  # resolve, never wedge waiting clients
                    for sim in sims:
                        sim._fail(e)
                    continue
                for j, (i, sim) in enumerate(zip(idxs, sims)):
                    kk = min(sim.k, len(ids[j]))
                    if sim._resolve(SimilarityResult(
                        ids=tuple(ids[j][:kk]),
                        distances=tuple(float(x) for x in dists[j][:kk]),
                        diagrams=ready[i][0],
                        backends=tuple(backends[j][:kk]),
                    )):
                        _H_LATENCY.observe(sim.latency_s(),
                                           instance=self._obs_instance,
                                           bucket=_BUCKET)
                        resolved += 1
            if resolved:
                _C_EVENTS.inc(resolved, instance=self._obs_instance,
                              event="query")
            return resolved

    # ------------------------------------------------------------- loops

    def serve_forever(self, poll_s: float = 1e-3) -> None:
        """Blocking drain loop (run on a dedicated thread); stop() exits.

        Warms the inner TopoServe's bucket plans before raising
        ``serve.ready{frontend=similarity}``, and stamps
        ``serve.heartbeat_ts`` each iteration — same liveness/readiness
        contract as the other frontends (obs/http.py).
        """
        inst = self._obs_instance
        _flight.record("serve", "loop_start", frontend="similarity",
                       instance=inst)
        self.server.warmup()
        _G_HEARTBEAT.set(time.time(), frontend="similarity", instance=inst)
        _G_READY.set(1, frontend="similarity", instance=inst)
        try:
            while not self._stopped.is_set():
                _G_HEARTBEAT.set(time.time(), frontend="similarity",
                                 instance=inst)
                try:
                    n = self.drain()
                except BaseException as e:
                    _flight.record("serve", "drain_exception",
                                   frontend="similarity", error=repr(e))
                    _flight.auto_dump("drain_exception")
                    raise
                if n == 0 and not self.pending():
                    self._stopped.wait(poll_s)
        finally:
            _G_READY.set(0, frontend="similarity", instance=inst)
            _flight.record("serve", "loop_stop", frontend="similarity",
                           instance=inst)

    def stop(self) -> None:
        self._stopped.set()

    # -------------------------------------------------------- exact solves

    def _exact_pairs(self, batch, rows):
        """exact_w distances for row-aligned (Q, C) query×candidate pairs.

        The one exact-solve path the stage-1 exact backend and the stage-2
        re-rank share: gathers the candidates' stored compacted clouds,
        warm-starts the collapsed auction from the price cache (keyed by
        query LSH bucket code × candidate row), pads the pair count to the
        next power of two (bounded ladder of compiled batch shapes), and
        stores the converged price vectors back for later drains.  Returns
        the (Q, C) float32 distance matrix.
        """
        q, c = rows.shape
        cfg = self.index.config
        cand = self.index.clouds(rows)        # leaves (Q, C, n_points)
        left = jax.tree.map(
            lambda x: jnp.broadcast_to(x[:, None], (q, c) + x.shape[1:]),
            batch)
        codes = self.index.query_codes(batch)
        prices0, _, _ = self._price_cache.lookup(codes, rows, cfg.n_points)
        qc = q * c
        r = 1 << (qc - 1).bit_length()

        def flat_pad(t):
            def one(x):
                x = x.reshape((qc,) + x.shape[2:])
                if r == qc:
                    return x
                fill = jnp.broadcast_to(x[:1], (r - qc,) + x.shape[1:])
                return jnp.concatenate([x, fill], axis=0)
            return jax.tree.map(one, t)

        w, conv, rounds, prices = compare_info(
            flat_pad(left), flat_pad(cand), metric="exact_w", k=cfg.k,
            cap=cfg.cap, n_points=cfg.n_points,
            prices=flat_pad(jnp.asarray(prices0)))
        rounds = np.asarray(rounds)[:qc]
        inst = self._obs_instance
        _C_ROUNDS.inc(int(rounds.sum()), instance=inst)
        _H_ROUNDS.observe(float(rounds.mean()), instance=inst)
        self._price_cache.store(
            codes, rows, np.asarray(prices)[:qc].reshape(q, c, -1),
            np.asarray(conv)[:qc].reshape(q, c))
        return np.asarray(w)[:qc].reshape(q, c)

    def _stage1_exact(self, batch, k_max):
        """Stage 1 with ``stage1_backend="exact_w"``: score the whole corpus.

        Every query is matched exactly against **every** stored cloud — no
        retrieve funnel, so recall is 1.0 by construction and there is no
        stage 2.  Q·N auction solves per drain, made viable by the
        collapsed solver and the price-cache warm starts; reported under
        ``stage="1"`` so ``stats`` separates it from the gram stage.
        """
        q = batch.birth.shape[0]
        n = len(self.index)
        rows = np.broadcast_to(np.arange(n), (q, n))
        t0 = time.perf_counter()
        with obs.span("similarity.stage1", frontend="similarity",
                      backend="exact_w", k=k_max) as sp1:
            d = self._exact_pairs(batch, rows)
            sp1.set(candidates=q * n)
        dt1 = time.perf_counter() - t0
        inst = self._obs_instance
        _C_STAGE.inc(dt1, instance=inst, what="seconds", stage="1")
        _C_STAGE.inc(q * n, instance=inst, what="candidates", stage="1")
        _H_STAGE_S.observe(dt1, instance=inst, stage="1")
        kk = min(int(k_max), n)
        order = np.argsort(d, axis=-1, kind="stable")[:, :kk]
        ids_all = self.index.ids
        ids = [[ids_all[j] for j in row] for row in order]
        dists = np.take_along_axis(d, order, axis=-1).astype(np.float32)
        backends = [["exact_w"] * kk for _ in range(q)]
        return ids, dists, backends

    # ------------------------------------------------------------- rerank

    def _rerank_exact(self, batch, res):
        """Stage 2: exact re-rank of the stage-1 candidates.

        One batched ``compare_info(metric="exact_w")`` (via
        :meth:`_exact_pairs`, so re-rank solves share the price-cache warm
        starts) between the query diagrams and the candidates' stored
        clouds.  Returns ``(ids, dists, backends)`` reordered by exact
        distance.
        """
        rows = res.rows                             # (Q, C) index rows
        q, c = rows.shape
        t0 = time.perf_counter()
        d = self._exact_pairs(batch, np.asarray(rows))
        order = np.argsort(d, axis=-1, kind="stable")
        dt2 = time.perf_counter() - t0
        inst = self._obs_instance
        _C_STAGE.inc(q * c, instance=inst, what="pairs", stage="2")
        _C_STAGE.inc(dt2, instance=inst, what="seconds", stage="2")
        _H_STAGE_S.observe(dt2, instance=inst, stage="2")
        ids = [[res.ids[i][j] for j in order[i]] for i in range(q)]
        dists = np.take_along_axis(d, order, axis=-1).astype(np.float32)
        backends = [["exact_w"] * c for _ in range(q)]
        return ids, dists, backends
