"""TopoServe: batched persistence-diagram serving on padding buckets.

Turns the batch-at-a-time TDA core into a request-serving path (the
ROADMAP's "serve heavy traffic" direction; docs/ARCHITECTURE.md §TopoServe):

* clients ``submit()`` single graphs (edge list + optional filtering values)
  and get back a ``TopoFuture``;
* the scheduler assigns each request to a **padding bucket** — a fixed
  ``(n_pad, edge_cap, tri_cap)`` shape class — so the number of distinct jit
  signatures is bounded by the bucket ladder, not by the query distribution;
* ``drain()`` packs each bucket's queue into a padded GraphBatch, executes
  the bucket's plan through the process-wide plan cache
  (``repro.core.api.make_topo_plan``), and resolves the futures with
  per-graph Diagrams slices.

The loop is deliberately sync-first (``submit``/``drain`` under one lock) so
it is trivially testable; ``serve_forever`` runs the same drain as a blocking
loop for a dedicated thread, and ``serve_forever_async`` wraps it for an
asyncio event loop.  On a multi-device mesh, bucket batches are padded to a
multiple of the mesh size and sharded over the ("pod", "data") axes via the
plan's shard_map executor (repro/launch/mesh.py::make_serve_mesh).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import deque
from typing import Optional, Sequence

import jax
import numpy as np

from repro import obs
from repro.obs import flight as _flight
from repro.obs.context import DeadlineExceeded, resolve_submit
from repro.core.api import TopoPlan, make_topo_plan
from repro.core.graph import GraphBatch, from_edge_lists
from repro.core.persistence_jax import Diagrams
from repro.core.repack import ShapeClass, default_ladder
from repro.serve.futures import ServeFuture

# TopoScope instruments (always on; one series per server instance via the
# ``instance`` label, so tests and multi-server processes never mix stats).
# ``TopoServe.stats`` is a dict-shaped view over these — the registry is
# the single source of truth.
_C_SUBMITTED = obs.counter("serve.submitted",
                           help="requests accepted per bucket")
_C_SERVED = obs.counter("serve.served", help="futures resolved per bucket")
_C_FAILED = obs.counter("serve.failed", help="futures failed at drain")
_C_BATCHES = obs.counter("serve.batches", help="executed batches per bucket")
_C_PADDED = obs.counter("serve.padded_rows",
                        help="empty pad rows executed (mesh divisibility)")
_C_RUNGS = obs.counter(
    "serve.repack_rungs",
    help="repack='on' graphs per (input bucket, persist rung)")
_H_QWAIT = obs.histogram(
    "serve.queue_wait_seconds",
    help="submit -> drain-pickup wait per request")
_H_OCC = obs.histogram(
    "serve.batch_occupancy", help="executed batch fill vs max_batch",
    buckets=obs.DEFAULT_RATIO_BUCKETS)

# TopoWatch instruments: request outcomes + loop liveness.  The latency
# histogram feeds the per-bucket p50/p99 SLOs (obs/slo.py); the heartbeat
# and ready gauges back /healthz and /readyz (obs/http.py).
_H_LATENCY = obs.histogram(
    "serve.request_latency_seconds",
    help="submit -> resolve wall time per bucket")
_C_DEADLINE = obs.counter(
    "serve.deadline_exceeded",
    help="requests failed by the drain deadline sweep, per bucket")
_C_CANCELLED = obs.counter(
    "serve.cancelled", help="cancelled requests skipped at drain")
_G_HEARTBEAT = obs.gauge(
    "serve.heartbeat_ts",
    help="wall-clock timestamp of the drain loop's last iteration")
_G_READY = obs.gauge(
    "serve.ready", help="1 once serve_forever warmed the bucket plans")


@dataclasses.dataclass(frozen=True, order=True)
class Bucket:
    """One padding bucket == one jit signature class.

    Every graph routed here is padded to ``n_pad`` vertices and persisted
    with this bucket's simplex caps, so all its batches share one compiled
    executable per batch size (and one per (batch,) shape when the server
    pads batches to a fixed multiple).
    """

    n_pad: int
    edge_cap: int
    tri_cap: int


# Default ladder: ego-net-regime graphs (the paper's §6.2 workload).  Caps
# grow with the vertex budget; a graph lands in the first rung that fits
# both its order and its edge count.
DEFAULT_BUCKETS = (
    Bucket(n_pad=16, edge_cap=64, tri_cap=96),
    Bucket(n_pad=32, edge_cap=160, tri_cap=256),
    Bucket(n_pad=64, edge_cap=320, tri_cap=512),
    Bucket(n_pad=128, edge_cap=768, tri_cap=1024),
)


@dataclasses.dataclass(frozen=True)
class TopoServeConfig:
    """Scheduler policy + the pipeline parameters shared by every bucket.

    ``repack="on"`` switches every bucket to the two-phase plan: drain
    becomes reduce → measure → repack → persist, where the persist phase
    runs at each graph's post-reduction :class:`ShapeClass` instead of the
    input bucket's caps.  The persist ladder is shared across buckets (see
    ``repack_ladder_for``), so reduced graphs from different input buckets
    execute the same compiled persist plans.
    """

    buckets: tuple[Bucket, ...] = DEFAULT_BUCKETS
    dim: int = 1
    method: str = "both"
    sublevel: bool = True
    quad_cap: int = 0
    reducer: str = "jnp"
    max_batch: int = 256      # largest executed batch per bucket flush
    pad_batch_to: int = 1     # executed batches padded to a multiple of this
    record_batches: bool = False  # keep (bucket, requests) per executed batch
    repack: str = "off"       # "off" | "on": two-phase reduce→repack→persist


@dataclasses.dataclass(frozen=True)
class TopoRequest:
    """One client graph, host-side (hashable ids only; arrays built at pack)."""

    edges: tuple[tuple[int, int], ...]
    n_vertices: int
    f: Optional[tuple[float, ...]] = None  # None -> degree filtration


class TopoFuture(ServeFuture):
    """Handle for one submitted graph; resolved by a later ``drain()``.

    ``result()`` returns the per-graph Diagrams slice (leaves shaped (S,),
    no batch axis).  Thread-safe plumbing — including ``cancel()`` and the
    request id / deadline carried from ``submit()`` — lives in
    ``ServeFuture``.  With ``repack="on"``, ``repack_class`` carries the
    persist :class:`ShapeClass` this request was re-bucketed into (set at
    drain, before the future resolves).
    """

    __slots__ = ("bucket", "repack_class")

    def __init__(self, bucket: Bucket, request_id: Optional[str] = None,
                 deadline: Optional[float] = None):
        super().__init__(request_id=request_id, deadline=deadline)
        self.bucket = bucket
        self.repack_class: ShapeClass | None = None


def pack_requests(reqs: Sequence[TopoRequest], bucket: Bucket) -> GraphBatch:
    """Pad a bucket's requests into one GraphBatch (shared with benchmarks
    so served-vs-direct parity checks run the exact same packing)."""
    if all(r.f is None for r in reqs):
        f_values = None  # from_edge_lists' vectorized degree-filtration default
    else:
        f_values = [r.f if r.f is not None
                    else _degree_f(r.edges, r.n_vertices) for r in reqs]
    return from_edge_lists(
        [list(r.edges) for r in reqs],
        [r.n_vertices for r in reqs],
        n_pad=bucket.n_pad,
        f_values=f_values,
    )


def _degree_f(edges: Sequence[tuple[int, int]], n_vertices: int) -> tuple[float, ...]:
    # dedupe first: duplicate/bidirectional entries must not inflate degrees
    # (from_edge_lists' adjacency-based default dedupes implicitly, and the
    # two paths must agree or co-batching would change a request's numerics)
    deg = np.zeros(n_vertices, dtype=np.float32)
    for (u, v) in {(min(u, v), max(u, v)) for (u, v) in edges if u != v}:
        deg[u] += 1
        deg[v] += 1
    return tuple(float(x) for x in deg)


def repack_ladder_for(buckets: Sequence[Bucket],
                      quad_cap: int = 0) -> tuple[ShapeClass, ...]:
    """The ONE persist-shape ladder shared by every repack-enabled server.

    Rungs are the serve buckets themselves (so a reduced graph that stays
    large persists at a familiar bucket shape) plus the default pow2
    sub-rungs below the smallest bucket (where most reduced ego-regime
    graphs land).  TopoServe and SimilarityServe both derive their ladders
    here — one definition, one set of persist plan-cache keys, so reduced
    queries from any serving surface share compiled persist pipelines.
    """
    smallest = min(buckets)
    sub = default_ladder(smallest.n_pad, smallest.edge_cap,
                         smallest.tri_cap, quad_cap)[:-1]
    classes = {ShapeClass(n_pad=b.n_pad, edge_cap=b.edge_cap,
                          tri_cap=b.tri_cap, quad_cap=quad_cap)
               for b in buckets}
    classes.update(sub)
    return tuple(sorted(classes))


def _count_triangles(edge_set, n_vertices: int) -> int:
    """Host-side triangle count (trace(A^3)/6) for cap-aware routing."""
    a = np.zeros((n_vertices, n_vertices), dtype=np.int64)
    for (u, v) in edge_set:
        a[u, v] = a[v, u] = 1
    return int(np.trace(a @ a @ a) // 6)


class TopoServe:
    """Bucketed batch scheduler over the plan cache.

    >>> server = TopoServe()
    >>> fut = server.submit(edges=[(0, 1), (1, 2), (2, 0)], n_vertices=3)
    >>> server.drain()
    1
    >>> int(fut.result().betti(0))
    1
    """

    def __init__(self, config: TopoServeConfig | None = None, mesh=None):
        self.config = config or TopoServeConfig()
        if not self.config.buckets:
            raise ValueError("TopoServeConfig.buckets must be non-empty")
        if self.config.repack not in ("off", "on"):
            raise ValueError(
                f"repack must be 'off' or 'on', got {self.config.repack!r}")
        if self.config.repack == "on" and mesh is not None:
            raise ValueError(
                "repack='on' is not supported under a mesh (the repack "
                "phase boundary is host-driven); use repack='off'")
        self.mesh = mesh
        self._buckets = tuple(sorted(self.config.buckets))
        self._repack_ladder = (
            repack_ladder_for(self._buckets, self.config.quad_cap)
            if self.config.repack == "on" else None)
        pad = max(1, self.config.pad_batch_to)
        if mesh is not None:
            # executed batches must DIVIDE the mesh (shard_map contract), so
            # round pad up to the next multiple of the mesh size
            n_dev = int(mesh.devices.size)
            pad = -(-pad // n_dev) * n_dev
        self._pad_batch_to = pad
        self._lock = threading.Lock()
        self._queues: dict[Bucket, deque] = {b: deque() for b in self._buckets}
        self._stopped = threading.Event()
        # (bucket, requests, futures) per executed batch when record_batches
        self.executed_batches: list[tuple] = []
        self._obs_instance = obs.next_instance("topo")
        # bucket -> stable label ("n32"); n_pad collisions disambiguate by
        # caps so per-bucket registry series stay distinct
        self._bucket_label: dict[Bucket, str] = {}
        for b in self._buckets:
            lbl = f"n{b.n_pad}"
            if lbl in self._bucket_label.values():
                lbl = f"n{b.n_pad}e{b.edge_cap}"
            if lbl in self._bucket_label.values():
                lbl = f"n{b.n_pad}e{b.edge_cap}t{b.tri_cap}"
            self._bucket_label[b] = lbl

    @property
    def stats(self) -> dict:
        """Dict-shaped view over the TopoScope registry (backward compat:
        the pre-TopoScope ad-hoc ``stats`` dict, same keys and key types).
        Mutating the returned dict has no effect — counters live in
        ``repro.obs``."""
        inst = self._obs_instance
        per_bucket = {}
        for b in self._buckets:
            lbl = self._bucket_label[b]
            per_bucket[b] = {
                "submitted": int(_C_SUBMITTED.value(instance=inst,
                                                    bucket=lbl)),
                "served": int(_C_SERVED.value(instance=inst, bucket=lbl)),
                "batches": int(_C_BATCHES.value(instance=inst, bucket=lbl)),
            }
        rungs = {}
        for key, v in _C_RUNGS.series().items():
            d = dict(key)
            if d.get("instance") != inst:
                continue
            rungs[(int(d["bucket"][1:]), int(d["rung"][1:]))] = int(v)
        return {
            "submitted": sum(pb["submitted"] for pb in per_bucket.values()),
            "served": sum(pb["served"] for pb in per_bucket.values()),
            "failed": int(_C_FAILED.value(instance=inst)),
            # per-bucket series summed over this instance
            "deadline_exceeded": int(_C_DEADLINE.total(instance=inst)),
            "cancelled": int(_C_CANCELLED.total(instance=inst)),
            "batches": sum(pb["batches"] for pb in per_bucket.values()),
            "padded_rows": int(_C_PADDED.value(instance=inst)),
            # repack="on": {(bucket n_pad, persist rung n_pad): graphs} —
            # rungs keyed by >1 bucket are shared compiled persist plans
            "repack_rungs": rungs,
            "per_bucket": per_bucket,
        }

    # ------------------------------------------------------------- routing

    def bucket_for(self, n_vertices: int, n_edges: int,
                   n_triangles: int = 0) -> Bucket:
        """Deterministic bucket assignment: the smallest rung (buckets are
        totally ordered by (n_pad, edge_cap, tri_cap)) whose capacities hold
        every simplex of the graph — exactness requires caps >= the true
        counts (docs/ARCHITECTURE.md §GraphBatch invariants), so a
        triangle-dense graph is promoted past rungs its edge count fits."""
        for b in self._buckets:
            if (n_vertices <= b.n_pad and n_edges <= b.edge_cap
                    and n_triangles <= b.tri_cap):
                return b
        raise ValueError(
            f"graph with {n_vertices} vertices / {n_edges} edges / "
            f"{n_triangles} triangles exceeds every bucket "
            f"(largest: {self._buckets[-1]})")

    def plan_for(self, bucket: Bucket) -> TopoPlan:
        """The bucket's compiled pipeline, via the process-wide plan cache.

        With ``repack="on"`` every bucket's plan shares the one serve-wide
        persist ladder, so their reduced-size persist plans coincide in the
        plan cache whenever reductions land on the same rung.
        """
        c = self.config
        return make_topo_plan(
            dim=c.dim, method=c.method, sublevel=c.sublevel,
            edge_cap=bucket.edge_cap, tri_cap=bucket.tri_cap,
            quad_cap=c.quad_cap, reducer=c.reducer, mesh=self.mesh,
            repack=c.repack, ladder=self._repack_ladder,
        )

    # ------------------------------------------------------------- ingest

    def submit(self, edges: Sequence[tuple[int, int]], n_vertices: int,
               f: Sequence[float] | None = None, *,
               request_id: Optional[str] = None,
               deadline_s: Optional[float] = None) -> TopoFuture:
        """Enqueue one graph; returns a future resolved by a later drain.

        Malformed requests are rejected HERE (ValueError) so they can never
        poison a batch and fail co-batched clients' futures at drain time.

        Every request gets an id (explicit ``request_id``, the ambient
        ``obs.request_context()`` id, or a fresh mint) and an optional
        deadline: ``deadline_s`` is relative seconds-from-now, clamped to
        any ambient context deadline.  Expired requests are failed with
        :class:`~repro.obs.DeadlineExceeded` by the drain sweep instead of
        executing late for nobody; cancelled futures are skipped the same
        way.
        """
        req = TopoRequest(
            edges=tuple((int(u), int(v)) for (u, v) in edges),
            n_vertices=int(n_vertices),
            f=None if f is None else tuple(float(x) for x in f),
        )
        if req.n_vertices < 1:
            raise ValueError(f"n_vertices must be >= 1, got {req.n_vertices}")
        for (u, v) in req.edges:
            if not (0 <= u < req.n_vertices and 0 <= v < req.n_vertices):
                raise ValueError(
                    f"edge ({u}, {v}) out of range for n_vertices="
                    f"{req.n_vertices}")
        if req.f is not None and len(req.f) != req.n_vertices:
            raise ValueError(
                f"f has {len(req.f)} values for {req.n_vertices} vertices")
        edge_set = {(min(u, v), max(u, v)) for (u, v) in req.edges if u != v}
        bucket = self.bucket_for(req.n_vertices, len(edge_set),
                                 _count_triangles(edge_set, req.n_vertices))
        rid, deadline = resolve_submit(request_id, deadline_s)
        fut = TopoFuture(bucket, request_id=rid, deadline=deadline)
        with self._lock:
            self._queues[bucket].append((req, fut))
        _C_SUBMITTED.inc(instance=self._obs_instance,
                         bucket=self._bucket_label[bucket])
        return fut

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    # ------------------------------------------------------------- drain

    def drain(self) -> int:
        """Execute every queued request, bucket by bucket; returns #served.

        Bucket queues are flushed in submission order, chunked at
        ``max_batch`` and padded (with empty graphs, dropped after execution)
        to a multiple of ``pad_batch_to`` so sharded plans always see a batch
        that divides the mesh.  Buckets are swept round-robin — one chunk per
        bucket per sweep — so sustained traffic into one bucket cannot starve
        requests queued in the others.

        Before each chunk executes, the TopoWatch sweep drops cancelled
        futures and fails expired ones with ``DeadlineExceeded`` — both
        counted per bucket — so the batch only carries requests somebody is
        still waiting for."""
        if not self.pending():
            return 0  # keep idle poll loops out of the trace
        with obs.span("serve.drain", frontend="topo") as sp:
            served = 0
            while True:
                progressed = False
                for b in self._buckets:
                    with self._lock:
                        q = self._queues[b]
                        items = [q.popleft()
                                 for _ in range(min(len(q),
                                                    self.config.max_batch))]
                    if items:
                        progressed = True
                        items = self._sweep(b, items)
                    if items:
                        served += self._execute(b, items)
                if not progressed:
                    sp.set(served=served)
                    return served

    def _sweep(self, bucket: Bucket, items: list) -> list:
        """Drop cancelled requests and fail expired ones (deadline sweep)."""
        inst = self._obs_instance
        lbl = self._bucket_label[bucket]
        now = time.monotonic()
        live = []
        for (req, fut) in items:
            if fut.cancelled():
                _C_CANCELLED.inc(instance=inst, bucket=lbl)
                _flight.record("serve", "cancelled_skip", frontend="topo",
                               bucket=lbl, rid=fut.request_id or "")
                continue
            if fut.expired(now):
                if fut._fail(DeadlineExceeded(
                        f"request {fut.request_id or '?'} expired "
                        f"{now - fut.deadline:.3f}s before drain pickup "
                        f"(bucket {lbl})")):
                    _C_DEADLINE.inc(instance=inst, bucket=lbl)
                    _flight.record("serve", "deadline_exceeded",
                                   frontend="topo", bucket=lbl,
                                   rid=fut.request_id or "",
                                   late_s=round(now - fut.deadline, 4))
                    _flight.auto_dump("deadline_exceeded")
                continue
            live.append((req, fut))
        return live

    def _execute(self, bucket: Bucket, items: list) -> int:
        inst = self._obs_instance
        lbl = self._bucket_label[bucket]
        reqs = tuple(r for (r, _) in items)
        futs = [f for (_, f) in items]
        now = time.perf_counter()
        for f in futs:
            _H_QWAIT.observe(now - f.submitted_at, instance=inst)
        _H_OCC.observe(len(items) / self.config.max_batch,
                       instance=inst, bucket=lbl)
        repack_info = None
        with obs.span("serve.batch", frontend="topo", bucket=lbl,
                      graphs=len(items)):
            try:
                with obs.span("serve.gather", bucket=lbl):
                    g = pack_requests(reqs, bucket)
                    n_pad_rows = (-len(reqs)) % self._pad_batch_to
                    if n_pad_rows:
                        g = _pad_batch(g, n_pad_rows)
                plan = self.plan_for(bucket)
                if self.config.repack == "on":
                    # two-phase drain: reduce → measure → repack → persist;
                    # the report carries each request's persist-rung
                    # assignment (plan.* spans nest here)
                    d, repack_info = plan.execute_info(g)
                else:
                    d = plan.execute(g)
                with obs.span("serve.sync"):
                    jax.block_until_ready(d.birth)
            except Exception as e:  # resolve, don't wedge waiting clients
                n_failed = sum(1 for f in futs if f._fail(e))
                if n_failed:
                    _C_FAILED.inc(n_failed, instance=inst)
                _flight.record("serve", "batch_failed", frontend="topo",
                               bucket=lbl, graphs=len(futs), error=repr(e))
                return 0
            if self.config.record_batches:
                self.executed_batches.append((bucket, reqs, tuple(futs)))
            with obs.span("serve.resolve"):
                for i, f in enumerate(futs):
                    if repack_info is not None:
                        f.repack_class = repack_info.shape_class(i)
                    if f._resolve(jax.tree.map(lambda x: x[i], d)):
                        _H_LATENCY.observe(f.latency_s(),
                                           instance=inst, bucket=lbl)
        _C_SERVED.inc(len(futs), instance=inst, bucket=lbl)
        _C_BATCHES.inc(instance=inst, bucket=lbl)
        _flight.record("serve", "batch", frontend="topo", bucket=lbl,
                       graphs=len(futs))
        if n_pad_rows:
            _C_PADDED.inc(n_pad_rows, instance=inst)
        if repack_info is not None:
            for i in range(len(futs)):
                _C_RUNGS.inc(
                    instance=inst, bucket=f"n{bucket.n_pad}",
                    rung=f"n{repack_info.shape_class(i).n_pad}")
        return len(futs)

    # ------------------------------------------------------------- loops

    def warmup(self) -> None:
        """Build every bucket's plan through the process-wide plan cache.

        Called by ``serve_forever`` before raising ``serve.ready`` so
        ``/readyz`` flipping to 200 means plan construction cost is paid —
        the first live request will not eat it.
        """
        for b in self._buckets:
            self.plan_for(b)

    def _loop_enter(self) -> None:
        inst = self._obs_instance
        _flight.record("serve", "loop_start", frontend="topo", instance=inst)
        self.warmup()
        _G_HEARTBEAT.set(time.time(), frontend="topo", instance=inst)
        _G_READY.set(1, frontend="topo", instance=inst)

    def _loop_exit(self) -> None:
        inst = self._obs_instance
        _G_READY.set(0, frontend="topo", instance=inst)
        _flight.record("serve", "loop_stop", frontend="topo", instance=inst)

    def _drain_guarded(self) -> int:
        """One loop iteration: heartbeat + drain; flight-dump on escape.

        ``drain`` fails co-batched futures on per-batch errors, so anything
        escaping here is a scheduler bug — dump the flight ring before the
        loop dies so the wreckage is on disk even with tracing off.
        """
        _G_HEARTBEAT.set(time.time(), frontend="topo",
                         instance=self._obs_instance)
        try:
            return self.drain()
        except BaseException as e:
            _flight.record("serve", "drain_exception", frontend="topo",
                           error=repr(e))
            _flight.auto_dump("drain_exception")
            raise

    def serve_forever(self, poll_s: float = 1e-3) -> None:
        """Blocking drain loop (run on a dedicated thread); stop() exits it.

        Warms the bucket plans then raises ``serve.ready`` (readiness) and
        stamps ``serve.heartbeat_ts`` every iteration (liveness) — the
        gauges behind ``/readyz`` and ``/healthz``.
        """
        self._loop_enter()
        try:
            while not self._stopped.is_set():
                if self._drain_guarded() == 0:
                    self._stopped.wait(poll_s)
        finally:
            self._loop_exit()

    async def serve_forever_async(self, poll_s: float = 1e-3) -> None:
        """Same loop for an asyncio host.  Each drain (jit dispatch +
        block_until_ready, potentially hundreds of ms per batch) runs on a
        worker thread so request-ingestion / health-check coroutines keep
        interleaving on the event loop."""
        import asyncio

        await asyncio.to_thread(self._loop_enter)
        try:
            while not self._stopped.is_set():
                if await asyncio.to_thread(self._drain_guarded) == 0:
                    await asyncio.sleep(poll_s)
        finally:
            self._loop_exit()

    def stop(self) -> None:
        self._stopped.set()


def _pad_batch(g: GraphBatch, n_rows: int) -> GraphBatch:
    """Append ``n_rows`` empty graphs (all-padding rows) to a batch."""
    import jax.numpy as jnp

    def pad(x, fill):
        pad_shape = (n_rows,) + x.shape[1:]
        return jnp.concatenate([x, jnp.full(pad_shape, fill, x.dtype)], axis=0)

    return GraphBatch(adj=pad(g.adj, False), mask=pad(g.mask, False),
                      f=pad(g.f, jnp.inf))
