"""Shared future plumbing for the serving layers.

``TopoFuture`` (stateless batch serving) and ``StreamFuture`` (stateful
sessions) resolve through the same thread-safe event/value/error mechanics;
this base class keeps that behavior in one place so fixes cannot silently
diverge between the two.
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class ServeFuture:
    """Thread-safe one-shot future resolved by a later ``drain()``.

    ``result()`` blocks until a drain — possibly on another thread — fulfils
    it; async callers can ``await asyncio.to_thread(fut.result)`` or poll
    ``done()``.
    """

    __slots__ = ("_event", "_value", "_error", "submitted_at", "resolved_at")

    def __init__(self):
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None
        self.submitted_at = time.perf_counter()
        self.resolved_at: Optional[float] = None

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"{type(self).__name__} not resolved within timeout "
                "(is a drain loop running?)")
        if self._error is not None:
            raise self._error
        return self._value

    def latency_s(self) -> float:
        """submit->resolve wall time; valid once done()."""
        if self.resolved_at is None:
            raise RuntimeError("future not resolved yet")
        return self.resolved_at - self.submitted_at

    def _resolve(self, value) -> None:
        self._value = value
        self.resolved_at = time.perf_counter()
        self._event.set()

    def _fail(self, err: BaseException) -> None:
        self._error = err
        self.resolved_at = time.perf_counter()
        self._event.set()
