"""Shared future plumbing for the serving layers.

``TopoFuture`` (stateless batch serving) and ``StreamFuture`` (stateful
sessions) resolve through the same thread-safe event/value/error mechanics;
this base class keeps that behavior in one place so fixes cannot silently
diverge between the two.

TopoWatch additions: every future carries the request id and optional
absolute deadline minted by ``submit()`` (see :mod:`repro.obs.context`),
and callers can ``cancel()`` a pending future — the drain skips cancelled
work instead of executing it for nobody.  Without cancellation, a caller
whose ``result(timeout=...)`` raised would leave the request queued and
it would still burn a kernel slot on the next drain (the queued-forever
leak).
"""
from __future__ import annotations

import threading
import time
from typing import Optional


class FutureCancelled(RuntimeError):
    """Raised by ``result()`` on a future the caller cancelled."""


class ServeFuture:
    """Thread-safe one-shot future resolved by a later ``drain()``.

    ``result()`` blocks until a drain — possibly on another thread — fulfils
    it; async callers can ``await asyncio.to_thread(fut.result)`` or poll
    ``done()``.

    Resolution is first-writer-wins under ``_state_lock``: once resolved,
    failed, or cancelled, later transitions are no-ops — so a drain racing
    a ``cancel()`` can never overwrite the caller-visible outcome.
    """

    __slots__ = ("_event", "_value", "_error", "_cancelled", "_state_lock",
                 "submitted_at", "resolved_at", "request_id", "deadline")

    def __init__(self, request_id: Optional[str] = None,
                 deadline: Optional[float] = None):
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None
        self._cancelled = False
        self._state_lock = threading.Lock()
        self.submitted_at = time.perf_counter()
        self.resolved_at: Optional[float] = None
        #: request id minted by submit() (``obs.context``); None for
        #: futures created outside a serving frontend.
        self.request_id = request_id
        #: absolute ``time.monotonic()`` deadline, or None.  Drains sweep
        #: expired futures and fail them with ``DeadlineExceeded``.
        self.deadline = deadline

    def done(self) -> bool:
        return self._event.is_set()

    def cancelled(self) -> bool:
        return self._cancelled

    def cancel(self) -> bool:
        """Cancel a pending request; True if this call won the race.

        The future resolves immediately (``result()`` raises
        :class:`FutureCancelled`) and the next drain discards the queued
        work instead of executing it.  Cancelling an already-resolved
        future is a no-op returning False.
        """
        with self._state_lock:
            if self._event.is_set():
                return False
            self._cancelled = True
            self._error = FutureCancelled(
                f"request {self.request_id or '?'} cancelled by caller")
            self.resolved_at = time.perf_counter()
            self._event.set()
            return True

    def expired(self, now: Optional[float] = None) -> bool:
        """True when a deadline is set and already past."""
        if self.deadline is None:
            return False
        return (time.monotonic() if now is None else now) >= self.deadline

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(
                f"{type(self).__name__} not resolved within timeout "
                "(is a drain loop running?)")
        if self._error is not None:
            raise self._error
        return self._value

    def latency_s(self) -> float:
        """submit->resolve wall time; valid once done()."""
        if self.resolved_at is None:
            raise RuntimeError("future not resolved yet")
        return self.resolved_at - self.submitted_at

    def _resolve(self, value) -> bool:
        with self._state_lock:
            if self._event.is_set():
                return False
            self._value = value
            self.resolved_at = time.perf_counter()
            self._event.set()
            return True

    def _fail(self, err: BaseException) -> bool:
        with self._state_lock:
            if self._event.is_set():
                return False
            self._error = err
            self.resolved_at = time.perf_counter()
            self._event.set()
            return True
