"""Serving: prefill + batched decode built on the model zoo's cache API."""
from __future__ import annotations

from functools import partial
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as tf
from repro.models.config import ModelConfig


def make_prefill_step(cfg: ModelConfig, extra_keys: tuple[str, ...] = ()):
    @jax.jit
    def prefill(params, tokens, caches, extras):
        logits, new_caches = tf.forward(
            params, cfg, tokens, mode="prefill", caches=caches,
            **{k: extras[k] for k in extra_keys},
        )
        return logits[:, -1, :], new_caches

    return prefill


def make_decode_step(cfg: ModelConfig, extra_keys: tuple[str, ...] = (),
                     temperature: float = 0.0):
    @jax.jit
    def decode(params, tokens, caches, pos, extras, rng):
        logits, new_caches = tf.forward(
            params, cfg, tokens, mode="decode", caches=caches, pos=pos,
            **{k: extras[k] for k in extra_keys},
        )
        lg = logits[:, -1, :].astype(jnp.float32)
        if temperature > 0:
            nxt = jax.random.categorical(rng, lg / temperature, axis=-1)
        else:
            nxt = jnp.argmax(lg, axis=-1)
        return nxt.astype(jnp.int32), new_caches

    return decode


def generate(params, cfg: ModelConfig, prompt: jax.Array, max_new: int,
             s_kv: int | None = None, extras: dict | None = None,
             temperature: float = 0.0, rng=None):
    """Greedy/sampled generation loop (prefill + lax.fori decode).

    prompt (B, S0) int32; returns (B, S0 + max_new).
    """
    b, s0 = prompt.shape
    s_kv = s_kv or (s0 + max_new)
    extras = extras or {}
    extra_keys = tuple(extras)
    rng = rng if rng is not None else jax.random.PRNGKey(0)

    caches = tf.init_caches(cfg, b, s_kv)
    prefill = make_prefill_step(cfg, extra_keys)
    decode = make_decode_step(cfg, extra_keys, temperature)

    last_logits, caches = prefill(params, prompt, caches, extras)
    # SSM families keep their recurrent state out of the attention KV cache;
    # replaying the prompt through decode keeps every family exact.
    if cfg.family in ("ssm", "hybrid"):
        for i in range(s0):
            nxt, caches = decode(params, prompt[:, i : i + 1], caches,
                                 jnp.int32(i), extras, rng)
        first = nxt
    else:
        first = jnp.argmax(last_logits.astype(jnp.float32), axis=-1).astype(jnp.int32)

    out = jnp.concatenate([prompt, jnp.zeros((b, max_new), jnp.int32)], axis=1)
    out = out.at[:, s0].set(first)
    tok = first[:, None]
    for t in range(1, max_new):
        rng, sub = jax.random.split(rng)
        tok_next, caches = decode(params, tok, caches, jnp.int32(s0 + t - 1),
                                  extras, sub)
        out = out.at[:, s0 + t].set(tok_next)
        tok = tok_next[:, None]
    return out
