"""Roofline analysis from a compiled dry-run artifact.

Three terms (seconds, per step, per device — the SPMD-partitioned HLO is the
per-device program):

  compute    = HLO_FLOPs / peak_FLOP/s
  memory     = HLO_bytes_accessed / HBM_bw
  collective = Σ effective collective bytes / ICI link bw

cost_analysis() supplies FLOPs/bytes; collective bytes are parsed from the
compiled HLO text (result-type of every all-reduce / all-gather /
reduce-scatter / all-to-all / collective-permute, with the standard ring
traffic model: all-reduce moves ~2x its payload, the others ~1x).
"""
from __future__ import annotations

import re
from collections import defaultdict

from repro.launch.mesh import HBM_BW, ICI_BW_PER_LINK, PEAK_FLOPS_BF16

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLL_RE = re.compile(
    r"=\s+((?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*))\s+"
    r"(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

# ring traffic multipliers (bytes moved per device per payload byte)
_TRAFFIC = {
    "all-reduce": 2.0,
    "all-gather": 1.0,
    "reduce-scatter": 1.0,
    "all-to-all": 1.0,
    "collective-permute": 1.0,
}


def _shape_bytes(type_str: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(type_str):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """{op_kind: {"count": n, "bytes": payload, "traffic": effective}}."""
    out: dict[str, dict[str, float]] = defaultdict(
        lambda: {"count": 0, "bytes": 0.0, "traffic": 0.0}
    )
    for m in _COLL_RE.finditer(hlo_text):
        type_str, kind = m.group(1), m.group(2)
        b = _shape_bytes(type_str)
        out[kind]["count"] += 1
        out[kind]["bytes"] += b
        out[kind]["traffic"] += b * _TRAFFIC[kind]
    return dict(out)


# ops that actually move HBM bytes on a fused TPU pipeline.  Elementwise /
# convert / broadcast chains fuse into their consumers; sub-computation
# `parameter` declarations and tuple plumbing move nothing.  The
# fusion-adjusted memory term counts 2x the output bytes (read + write,
# coarse) of just the movers.
_MOVER = (
    "dot|fusion|scatter|gather|dynamic-slice|dynamic-update-slice|slice|"
    "sort|copy|transpose|concatenate|pad|reduce|reduce-window|"
    "all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute"
)
_OP_RE = re.compile(r"^\s*(?:ROOT )?%?\S+ = (\S+?) ([a-z0-9-]+)[.(]", re.M)
_MOVER_RE = re.compile(f"^({_MOVER})$")


def fusion_adjusted_bytes(hlo_text: str) -> float:
    """Estimated HBM traffic if elementwise chains fuse (TPU behaviour)."""
    total = 0.0
    for m in _OP_RE.finditer(hlo_text):
        shape, op = m.group(1), m.group(2)
        if _MOVER_RE.match(op):
            total += 2.0 * _shape_bytes(shape)
    return total


def roofline_terms(
    flops: float,
    bytes_accessed: float,
    collectives: dict[str, dict[str, float]],
    model_flops_global: float = 0.0,
    chips: int = 256,
) -> dict:
    coll_traffic = sum(v["traffic"] for v in collectives.values())
    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = bytes_accessed / HBM_BW
    collective_s = coll_traffic / ICI_BW_PER_LINK
    terms = {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "hlo_flops_per_device": flops,
        "hlo_bytes_per_device": bytes_accessed,
        "collective_traffic_per_device": coll_traffic,
        "collectives": collectives,
    }
    dom = max(
        ("compute", compute_s), ("memory", memory_s), ("collective", collective_s),
        key=lambda kv: kv[1],
    )
    terms["dominant"] = dom[0]
    terms["bound_s"] = dom[1]
    if model_flops_global:
        terms["model_flops_global"] = model_flops_global
        terms["model_flops_per_device"] = model_flops_global / chips
        terms["useful_flop_ratio"] = (
            model_flops_global / chips / flops if flops else 0.0
        )
        # roofline fraction: useful model FLOP/s achieved at the bound
        terms["roofline_fraction"] = (
            (model_flops_global / chips / PEAK_FLOPS_BF16) / dom[1]
            if dom[1] > 0 else 0.0
        )
    return terms


def model_flops_for(cfg, shape_cfg) -> float:
    """MODEL_FLOPS: 6·N·D train, 2·N_active·D inference (global, per step)."""
    n_active = cfg.active_param_count()
    if shape_cfg.kind == "train":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 6.0 * n_active * tokens
    if shape_cfg.kind == "prefill":
        tokens = shape_cfg.global_batch * shape_cfg.seq_len
        return 2.0 * n_active * tokens
    # decode: one token per sequence
    return 2.0 * n_active * shape_cfg.global_batch
