"""Training driver: mesh setup, sharded state, checkpoint/restart, straggler
accounting.  Runs real steps on whatever devices exist (CPU smoke / TPU pod);
the production-mesh path is exercised by dryrun.py.

  PYTHONPATH=src python -m repro.launch.train --arch qwen3-1.7b --reduced \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/ckpt

Fault tolerance:
  * auto-resume from the newest committed checkpoint in --ckpt-dir;
  * periodic atomic checkpoints (params + optimizer + data-stream state);
  * per-step deadline: steps slower than --deadline-x times the rolling
    median are logged as straggler events (on real multi-host deployments
    this hook triggers re-slicing / hot-spare swap; here it is accounting);
  * elastic restart: the checkpoint layout is mesh-independent, so a restart
    may use a different device count (see tests/test_system.py).
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import get_config, reduced_config
from repro.data.tokens import TokenStream
from repro.launch import sharding as sh
from repro.models import transformer as tf
from repro.models.pjit_utils import set_axis_env
from repro.train import checkpoint as ckpt
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainState, make_train_step


def make_host_mesh():
    """Best-effort (data, model) mesh from the available devices."""
    n = jax.device_count()
    model = 1
    for cand in (4, 2):
        if n % cand == 0 and n >= cand * 2:
            model = cand
            break
    return jax.make_mesh((n // model, model), ("data", "model"))


def train(arch: str, steps: int, batch: int, seq: int, ckpt_dir: str | None,
          reduced: bool = True, ckpt_every: int = 20, lr: float = 3e-4,
          grad_accum: int = 1, deadline_x: float = 3.0, log_every: int = 10,
          seed: int = 0):
    cfg = reduced_config(arch) if reduced else get_config(arch)
    mesh = make_host_mesh()
    set_axis_env(dp=("data",))
    try:
        return _train_inner(cfg, mesh, steps, batch, seq, ckpt_dir, ckpt_every,
                            lr, grad_accum, deadline_x, log_every, seed)
    finally:
        from repro.models.pjit_utils import clear_axis_env
        clear_axis_env()


def _train_inner(cfg, mesh, steps, batch, seq, ckpt_dir, ckpt_every, lr,
                 grad_accum, deadline_x, log_every, seed):

    stream = TokenStream(vocab_size=cfg.vocab_size, batch=batch, seq_len=seq,
                         seed=seed)
    step_fn = make_train_step(cfg, grad_accum=grad_accum, base_lr=lr)

    with mesh:
        params = tf.init_params(cfg, jax.random.PRNGKey(seed))
        state = TrainState(params=params, opt=adamw_init(params))
        pspecs = sh.param_specs(params)
        sshard = sh.to_shardings(
            TrainState(params=pspecs, opt=sh.opt_specs(pspecs)), mesh)
        state = jax.device_put(state, sshard)

        start = 0
        if ckpt_dir and ckpt.latest_steps(ckpt_dir):
            state, start, sstate = ckpt.restore(ckpt_dir, state, shardings=sshard)
            stream, start = TokenStream.resume(stream, sstate)
            print(f"[resume] restored step {start} from {ckpt_dir}")

        jit_step = jax.jit(
            step_fn,
            in_shardings=(sshard, NamedSharding(mesh, sh.batch_spec(batch, mesh))),
            out_shardings=(sshard, NamedSharding(mesh, P())),
            donate_argnums=(0,),
        )
        batch_fn = jax.jit(
            stream.batch_at,
            out_shardings={"tokens": NamedSharding(mesh, sh.batch_spec(batch, mesh))},
        )

        durations: list[float] = []
        stragglers = 0
        history = []
        for step in range(start, steps):
            t0 = time.time()
            data = batch_fn(jnp.int32(step))
            state, metrics = jit_step(state, data)
            metrics = jax.device_get(metrics)
            dt = time.time() - t0
            durations.append(dt)
            med = float(np.median(durations[-50:]))
            if len(durations) > 5 and dt > deadline_x * med:
                stragglers += 1
                print(f"[straggler] step {step}: {dt:.2f}s vs median {med:.2f}s")
            if step % log_every == 0 or step == steps - 1:
                print(f"step {step:5d} loss {float(metrics['loss']):.4f} "
                      f"gnorm {float(metrics['grad_norm']):.3f} "
                      f"lr {float(metrics['lr']):.2e} {dt:.2f}s")
            history.append(float(metrics["loss"]))
            if ckpt_dir and (step + 1) % ckpt_every == 0:
                path = ckpt.save(ckpt_dir, step + 1, state,
                                 stream_state=stream.state(step + 1))
                print(f"[ckpt] wrote {path}")

        if ckpt_dir:
            ckpt.save(ckpt_dir, steps, state, stream_state=stream.state(steps))
    return {"final_loss": history[-1] if history else None,
            "first_loss": history[0] if history else None,
            "stragglers": stragglers, "steps_run": len(history)}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=50)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--grad-accum", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=20)
    ap.add_argument("--full", action="store_true",
                    help="use the full (non-reduced) config")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()
    out = train(args.arch, args.steps, args.batch, args.seq, args.ckpt_dir,
                reduced=not args.full, ckpt_every=args.ckpt_every, lr=args.lr,
                grad_accum=args.grad_accum, seed=args.seed)
    print(json.dumps(out))


if __name__ == "__main__":
    main()
