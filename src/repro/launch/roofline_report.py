"""Assemble EXPERIMENTS.md tables from the dry-run / roofline JSON artifacts.

  PYTHONPATH=src python -m repro.launch.roofline_report \
      --dryrun results/dryrun --roofline results/roofline
"""
from __future__ import annotations

import argparse
import glob
import json
import os


def load_cells(d: str, suffix: str) -> dict:
    out = {}
    for f in sorted(glob.glob(os.path.join(d, f"*__{suffix}.json"))):
        j = json.load(open(f))
        out[(j["arch"], j["shape"])] = j
    return out


def fmt_s(x) -> str:
    if x is None:
        return "-"
    if x >= 0.1:
        return f"{x:.2f}"
    if x >= 1e-3:
        return f"{x*1e3:.1f}m"
    return f"{x*1e6:.0f}u"


def dryrun_table(cells: dict) -> str:
    rows = ["| arch | shape | chips | peak GiB/dev | collectives (count) | compile s |",
            "|---|---|---|---|---|---|"]
    for (a, s), j in sorted(cells.items()):
        if "skipped" in j:
            rows.append(f"| {a} | {s} | - | - | SKIP: {j['skipped'][:60]} | - |")
            continue
        peak = j["memory"].get("peak_bytes") or 0
        colls = j["roofline"]["collectives"]
        cstr = " ".join(f"{k}:{int(v['count'])}" for k, v in sorted(colls.items()))
        rows.append(
            f"| {a} | {s} | {j['chips']} | {peak/2**30:.2f} | {cstr} "
            f"| {j['compile_s']} |")
    return "\n".join(rows)


def roofline_table(cells: dict) -> str:
    rows = ["| arch | shape | compute s | memory s | collective s | dominant "
            "| model GFLOP/dev | useful-flop ratio | roofline frac |",
            "|---|---|---|---|---|---|---|---|---|"]
    for (a, s), j in sorted(cells.items()):
        if "skipped" in j:
            rows.append(f"| {a} | {s} | - | - | - | SKIP | - | - | - |")
            continue
        r = j["roofline"]
        mf = r.get("model_flops_per_device", 0) / 1e9
        rows.append(
            f"| {a} | {s} | {fmt_s(r['compute_s'])} | {fmt_s(r['memory_s'])} "
            f"| {fmt_s(r['collective_s'])} | **{r['dominant']}** "
            f"| {mf:.0f} | {r.get('useful_flop_ratio', 0):.2f} "
            f"| {r.get('roofline_fraction', 0):.3f} |")
    return "\n".join(rows)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--dryrun", default="results/dryrun")
    ap.add_argument("--roofline", default="results/roofline")
    args = ap.parse_args()

    print("## Dry-run (scanned lowering, memory fit + collective schedule)\n")
    print("### single-pod (16x16)\n")
    print(dryrun_table(load_cells(args.dryrun, "1pod")))
    print("\n### multi-pod (2x16x16)\n")
    print(dryrun_table(load_cells(args.dryrun, "2pod")))
    if os.path.isdir(args.roofline):
        print("\n## Roofline (cost-exact xcost lowering, single-pod)\n")
        print(roofline_table(load_cells(args.roofline, "1pod")))


if __name__ == "__main__":
    main()
