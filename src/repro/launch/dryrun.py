"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production meshes, prove memory fit, and extract roofline inputs.

Usage:
  python -m repro.launch.dryrun --arch qwen3-1.7b --shape train_4k [--multipod]
  python -m repro.launch.dryrun --all [--multipod] [--out-dir results/dryrun]

The XLA_FLAGS lines below MUST precede any jax import (device count locks at
first init); only this module sets it — tests/benches see 1 device.
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import dataclasses
import json
import subprocess
import sys
import time
from functools import partial

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding
from jax.sharding import PartitionSpec as P

from repro.configs.registry import ARCHS, get_config
from repro.launch import roofline as rl
from repro.launch import sharding as sh
from repro.launch.mesh import CHIPS_PER_POD, make_production_mesh
from repro.models import transformer as tf
from repro.models.config import SHAPES
from repro.train.optimizer import adamw_init
from repro.train.train_step import TrainState, make_train_step

def _abstract(tree, shardings=None):
    if shardings is None:
        return jax.tree.map(
            lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree
        )
    return jax.tree.map(
        lambda x, s: jax.ShapeDtypeStruct(x.shape, x.dtype, sharding=s),
        tree, shardings,
    )


def input_specs(arch: str, shape: str, mesh, micro: bool = False, cfg=None):
    """ShapeDtypeStruct stand-ins (weak-type-correct, sharded, no allocation)
    for every model input of this cell, plus the step callable.

    micro=True lowers ONE microbatch (grad_accum=1, batch/ga) — used with
    --unroll for cost-exact roofline terms; a full step is exactly
    grad_accum x the microbatch plus the one grad all-reduce + optimizer
    epilogue (which this lowering still contains once).

    cfg overrides the registry config (depth-probe lowerings for --xcost).
    """
    cfg = cfg if cfg is not None else get_config(arch)
    sc = SHAPES[shape]
    if micro and sc.kind == "train" and sc.grad_accum > 1:
        sc = dataclasses.replace(
            sc, global_batch=sc.global_batch // sc.grad_accum, grad_accum=1)
    if shape == "long_500k" and not cfg.supports_long_context:
        return None, f"{arch} is full-attention; long_500k requires sub-quadratic"

    params_shapes = jax.eval_shape(lambda: tf.init_params(cfg, jax.random.PRNGKey(0)))
    pspecs = sh.param_specs(params_shapes)
    pshard = sh.to_shardings(pspecs, mesh)
    params_abs = _abstract(params_shapes, pshard)
    bspec = sh.batch_spec(sc.global_batch, mesh)
    bshard = NamedSharding(mesh, bspec)
    rep = NamedSharding(mesh, P())

    def tok(b, s):
        return jax.ShapeDtypeStruct((b, s), jnp.int32, sharding=bshard)

    extras = {}
    if cfg.family == "encdec":
        extras["frames"] = jax.ShapeDtypeStruct(
            (sc.global_batch, cfg.enc_seq, cfg.d_model), jnp.bfloat16,
            sharding=bshard)
    if cfg.family == "vlm":
        extras["vision"] = jax.ShapeDtypeStruct(
            (sc.global_batch, cfg.vision_tokens, cfg.d_model), jnp.bfloat16,
            sharding=bshard)
        s_pos = 1 if sc.kind == "decode" else sc.seq_len
        extras["mrope_positions"] = jax.ShapeDtypeStruct(
            (sc.global_batch, s_pos, 3), jnp.int32, sharding=bshard)

    if sc.kind == "train":
        opt_shapes = jax.eval_shape(adamw_init, params_shapes)
        ospecs = sh.opt_specs(pspecs)
        oshard = sh.to_shardings(ospecs, mesh)
        state_abs = TrainState(params=params_abs, opt=_abstract(opt_shapes, oshard))
        batch = {"tokens": tok(sc.global_batch, sc.seq_len), **extras}
        step = make_train_step(cfg, grad_accum=sc.grad_accum,
                               extra_keys=tuple(extras))
        return (step, (state_abs, batch)), None

    caches_shapes = jax.eval_shape(
        lambda: tf.init_caches(cfg, sc.global_batch, sc.seq_len)
    )
    cspecs = sh.cache_specs(caches_shapes, cfg, mesh, sc.global_batch)
    cshard = sh.to_shardings(cspecs, mesh)
    caches_abs = _abstract(caches_shapes, cshard)

    if sc.kind == "prefill":
        def prefill_step(params, tokens, caches, extra):
            logits, new_caches = tf.forward(
                params, cfg, tokens, mode="prefill", caches=caches, **extra
            )
            return logits[:, -1, :], new_caches

        return (prefill_step, (params_abs, tok(sc.global_batch, sc.seq_len),
                               caches_abs, extras)), None

    def decode_step(params, tokens, caches, pos, extra):
        logits, new_caches = tf.forward(
            params, cfg, tokens, mode="decode", caches=caches, pos=pos, **extra
        )
        return jnp.argmax(logits[:, -1, :], axis=-1), new_caches

    pos_abs = jax.ShapeDtypeStruct((), jnp.int32, sharding=rep)
    return (decode_step, (params_abs, tok(sc.global_batch, 1), caches_abs,
                          pos_abs, extras)), None


def tda_input_specs(mesh, sharded: bool = True):
    """The paper's own workload: batched ego-net PDs sharded over the mesh.

    sharded=True routes through shard_map (§Perf iteration 5 — zero
    collectives); False keeps the plain-pjit baseline for comparison.
    """
    from repro.configs.tda_ego import config as tda_config
    from repro.core.api import topological_signature, topological_signature_sharded
    from repro.core.graph import GraphBatch

    tcfg = tda_config()
    n_dev = mesh.devices.size
    b = tcfg.graphs_per_device * n_dev
    all_axes = tuple(mesh.axis_names)
    gshard = NamedSharding(mesh, P(all_axes))
    g_abs = GraphBatch(
        adj=jax.ShapeDtypeStruct((b, tcfg.n_pad, tcfg.n_pad), jnp.bool_, sharding=gshard),
        mask=jax.ShapeDtypeStruct((b, tcfg.n_pad), jnp.bool_, sharding=gshard),
        f=jax.ShapeDtypeStruct((b, tcfg.n_pad), jnp.float32, sharding=gshard),
    )

    def tda_step(g):
        if sharded:
            d = topological_signature_sharded(
                g, mesh, dim=tcfg.max_dim, method="both",
                sublevel=tcfg.sublevel, edge_cap=tcfg.edge_cap,
                tri_cap=tcfg.tri_cap,
            )
        else:
            d = topological_signature(
                g, dim=tcfg.max_dim, method="both", sublevel=tcfg.sublevel,
                edge_cap=tcfg.edge_cap, tri_cap=tcfg.tri_cap,
            )
        return d.birth, d.death, d.dim, d.valid

    return tda_step, (g_abs,)


def tda_two_phase_specs(mesh, phase: str):
    """Cost specs for the two-phase ReductionEngine path (core/api.py,
    ``repack="on"``), one cell per phase so the roofline separates them:

    * ``phase="reduce"`` — fixpoint pass iteration + vertex compaction +
      simplex-count measurement at the *input* caps (masked matmul sweeps;
      the cheap phase), shard_mapped with zero collectives;
    * ``phase="persist"`` — the ``passes=()`` persistence pipeline at the
      default repack ladder's middle rung (the shape class the reduced
      ego-regime graphs re-bucket into) — the phase the refactor shrinks.
    """
    from repro.configs.tda_ego import config as tda_config
    from repro.core.api import topological_signature_sharded
    from repro.core.graph import GraphBatch
    from repro.core.reduction import reduce_fixpoint
    from repro.core.repack import compact_batch, default_ladder, measure_counts
    from jax.experimental.shard_map import shard_map

    tcfg = tda_config()
    n_dev = mesh.devices.size
    b = tcfg.graphs_per_device * n_dev
    all_axes = tuple(mesh.axis_names)
    gshard = NamedSharding(mesh, P(all_axes))
    ladder = default_ladder(tcfg.n_pad, tcfg.edge_cap, tcfg.tri_cap)
    mid = ladder[len(ladder) // 2]

    def g_abs(n_pad):
        return GraphBatch(
            adj=jax.ShapeDtypeStruct((b, n_pad, n_pad), jnp.bool_, sharding=gshard),
            mask=jax.ShapeDtypeStruct((b, n_pad), jnp.bool_, sharding=gshard),
            f=jax.ShapeDtypeStruct((b, n_pad), jnp.float32, sharding=gshard),
        )

    if phase == "reduce":
        spec = P(all_axes)

        def per_device(adj, mask, f):
            g = GraphBatch(adj=adj, mask=mask, f=f)
            gr = reduce_fixpoint(g, ("prunit", "kcore"), tcfg.max_dim,
                                 tcfg.sublevel)
            gc, _ = compact_batch(gr)
            nv, ne, nt = measure_counts(gc)
            return gc.adj, gc.mask, gc.f, nv, ne, nt

        sharded = shard_map(
            per_device, mesh=mesh,
            in_specs=(spec, spec, spec),
            out_specs=(spec,) * 6,
            check_rep=False,
        )

        def reduce_step(g):
            return sharded(g.adj, g.mask, g.f)

        return reduce_step, (g_abs(tcfg.n_pad),)

    if phase == "persist":
        def persist_step(g):
            d = topological_signature_sharded(
                g, mesh, dim=tcfg.max_dim, method="none",
                sublevel=tcfg.sublevel, edge_cap=mid.edge_cap,
                tri_cap=mid.tri_cap,
            )
            return d.birth, d.death, d.dim, d.valid

        return persist_step, (g_abs(mid.n_pad),)

    raise ValueError(f"phase must be 'reduce' or 'persist', got {phase!r}")


def _depth_period(cfg) -> int:
    """Layer-count granularity at which the block pattern repeats exactly."""
    if cfg.family == "hybrid":
        return cfg.attn_period
    if cfg.local_global_pattern != (0, 0):
        return sum(cfg.local_global_pattern)
    return 1


def _probe_config(cfg, n_layers: int):
    reps = {"n_layers": n_layers}
    if cfg.family == "encdec":
        # encoder depth scales with decoder depth (whisper: 6 == 6)
        reps["n_enc_layers"] = max(1, round(cfg.n_enc_layers * n_layers
                                            / cfg.n_layers))
    return dataclasses.replace(cfg, **reps)


def _lower_cost(arch, shape, mesh, cfg):
    """(flops, bytes, collectives) of one unrolled micro lowering."""
    spec, skip = input_specs(arch, shape, mesh, micro=True, cfg=cfg)
    if skip:
        return None
    step, args = spec
    with mesh:
        lowered = jax.jit(step).lower(*args)
        compiled = lowered.compile()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    colls = rl.parse_collectives(text)
    return (float(cost.get("flops", 0.0)),
            float(cost.get("bytes accessed", 0.0)), colls,
            rl.fusion_adjusted_bytes(text))


def _extrapolate(c1, c2, l1, l2, L):
    """Linear depth extrapolation of (flops, bytes, collectives)."""
    slope = (L - l1) / (l2 - l1)

    def lin(a, b):
        return a + slope * (b - a)

    flops = lin(c1[0], c2[0])
    bts = lin(c1[1], c2[1])
    kinds = set(c1[2]) | set(c2[2])
    zero = {"count": 0, "bytes": 0.0, "traffic": 0.0}
    colls = {
        k: {f: lin(c1[2].get(k, zero)[f], c2[2].get(k, zero)[f])
            for f in ("count", "bytes", "traffic")}
        for k in kinds
    }
    return flops, bts, colls


def run_cell_xcost(arch: str, shape: str, multi_pod: bool) -> dict:
    """Cost-exact roofline terms via unrolled depth-probe extrapolation.

    XLA counts while/scan bodies once, so the full-depth scanned lowering
    under-reports FLOPs/bytes/collectives by ~n_layers.  Fully unrolling the
    real depth is compile-prohibitive, but cost is linear in depth for a
    homogeneous (periodic) stack: lower unrolled probes at 1 and 2 pattern
    periods and extrapolate to the real depth.  Train cells are lowered as
    one grad-accum microbatch (terms per microbatch; a full step is exactly
    grad_accum x this plus one grad-reduce + optimizer epilogue, already
    present once in the probe).
    """
    from repro.models.pjit_utils import set_axis_env
    from repro.models.unroll import set_unroll

    if arch == "tda_ego":
        # no layer stack; data-dependent while loops handled analytically
        # in EXPERIMENTS.md — the compiled numbers are the once-through
        # lower bound.
        return run_cell(arch, shape, multi_pod, unroll=False)

    set_unroll(True)
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_axis_env(dp=tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    chips = mesh.devices.size
    cfg = get_config(arch)
    sc = SHAPES[shape]
    if sc.kind == "train" and sc.grad_accum > 1:
        sc = dataclasses.replace(
            sc, global_batch=sc.global_batch // sc.grad_accum, grad_accum=1)
    per = _depth_period(cfg)
    l1, l2 = per, 2 * per

    t0 = time.time()
    c1 = _lower_cost(arch, shape, mesh, _probe_config(cfg, l1))
    if c1 is None:
        return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                "skipped": f"{arch}/{shape} skipped (see baseline cell)"}
    c2 = _lower_cost(arch, shape, mesh, _probe_config(cfg, l2))
    t_compile = time.time() - t0

    flops, bts, colls = _extrapolate(c1[:3], c2[:3], l1, l2, cfg.n_layers)
    mf = rl.model_flops_for(cfg, sc)
    terms = rl.roofline_terms(flops, bts, colls, mf, chips)
    # fusion-adjusted memory term (elementwise chains assumed fused, as on
    # a real TPU pipeline) — extrapolated with the same depth slope
    slope = (cfg.n_layers - l1) / (l2 - l1)
    adj = c1[3] + slope * (c2[3] - c1[3])
    terms["memory_adjusted_s"] = adj / rl.HBM_BW
    terms["hlo_bytes_adjusted_per_device"] = adj
    return {
        "arch": arch, "shape": shape, "multi_pod": multi_pod, "chips": chips,
        "method": "xcost-depth-extrapolation",
        "probe_layers": [l1, l2], "true_layers": cfg.n_layers,
        "grad_accum_lowered": sc.grad_accum,
        "global_batch_lowered": sc.global_batch,
        "compile_s": round(t_compile, 1),
        "probe1": {"flops": c1[0], "bytes": c1[1]},
        "probe2": {"flops": c2[0], "bytes": c2[1]},
        "roofline": terms,
    }


def run_cell(arch: str, shape: str, multi_pod: bool, unroll: bool = False,
             micro: bool = False) -> dict:
    from repro.models.pjit_utils import set_axis_env
    from repro.models.unroll import set_unroll

    set_unroll(unroll)  # cost-exact roofline: count scan bodies x trips
    mesh = make_production_mesh(multi_pod=multi_pod)
    set_axis_env(dp=tuple(a for a in ("pod", "data") if a in mesh.axis_names))
    chips = mesh.devices.size
    t0 = time.time()
    if arch == "tda_ego":
        if shape in ("ego_pd_reduce", "ego_pd_persist"):
            step, args = tda_two_phase_specs(
                mesh, phase=shape.removeprefix("ego_pd_"))
        else:
            step, args = tda_input_specs(mesh)
        cfg = None
        sc = None
    else:
        spec, skip = input_specs(arch, shape, mesh, micro=micro)
        if skip:
            return {"arch": arch, "shape": shape, "multi_pod": multi_pod,
                    "skipped": skip}
        step, args = spec
        cfg = get_config(arch)
        sc = SHAPES[shape]
        if micro and sc.kind == "train" and sc.grad_accum > 1:
            sc = dataclasses.replace(
                sc, global_batch=sc.global_batch // sc.grad_accum, grad_accum=1)

    with mesh:
        lowered = jax.jit(step).lower(*args)
        compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    text = compiled.as_text()
    colls = rl.parse_collectives(text)
    flops = float(cost.get("flops", 0.0))
    bytes_acc = float(cost.get("bytes accessed", 0.0))
    mf = rl.model_flops_for(cfg, sc) if cfg is not None else 0.0
    terms = rl.roofline_terms(flops, bytes_acc, colls, mf, chips)

    out = {
        "arch": arch, "shape": shape, "multi_pod": multi_pod, "chips": chips,
        "unrolled_costs": unroll, "microbatch_costs": micro,
        "grad_accum_lowered": getattr(sc, "grad_accum", None),
        "global_batch_lowered": getattr(sc, "global_batch", None),
        "compile_s": round(t_compile, 1),
        "memory": {
            "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
            "output_bytes": getattr(mem, "output_size_in_bytes", None),
            "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
            "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        },
        "roofline": terms,
    }
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--multipod", action="store_true")
    ap.add_argument("--unroll", action="store_true",
                    help="fully unroll structural scans for cost-exact "
                         "roofline terms (slower compiles)")
    ap.add_argument("--micro", action="store_true",
                    help="lower one grad-accum microbatch (use with --unroll)")
    ap.add_argument("--xcost", action="store_true",
                    help="cost-exact roofline via unrolled depth-probe "
                         "extrapolation (cheap; preferred for §Roofline)")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    args = ap.parse_args()

    if args.all:
        os.makedirs(args.out_dir, exist_ok=True)
        cells = [(a, s) for a in ARCHS if a != "tda_ego" for s in SHAPES]
        cells.append(("tda_ego", "ego_pd"))
        # two-phase ReductionEngine cells: reduce vs persist roofline terms
        cells.append(("tda_ego", "ego_pd_reduce"))
        cells.append(("tda_ego", "ego_pd_persist"))
        failures = []
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'2pod' if args.multipod else '1pod'}"
            out_path = os.path.join(args.out_dir, tag + ".json")
            if os.path.exists(out_path):
                print(f"[skip-cached] {tag}")
                continue
            cmd = [sys.executable, "-m", "repro.launch.dryrun",
                   "--arch", arch, "--shape", shape, "--out-dir", args.out_dir]
            if args.multipod:
                cmd.append("--multipod")
            if args.unroll:
                cmd.append("--unroll")
            if args.micro:
                cmd.append("--micro")
            if args.xcost:
                cmd.append("--xcost")
            r = subprocess.run(cmd, capture_output=True, text=True)
            if r.returncode != 0:
                failures.append(tag)
                print(f"[FAIL] {tag}\n{r.stdout[-2000:]}\n{r.stderr[-2000:]}")
            else:
                print(f"[ok] {tag}")
        print(f"done; {len(failures)} failures: {failures}")
        sys.exit(1 if failures else 0)

    if args.xcost:
        out = run_cell_xcost(args.arch, args.shape, args.multipod)
    else:
        out = run_cell(args.arch, args.shape, args.multipod,
                       unroll=args.unroll, micro=args.micro)
    os.makedirs(args.out_dir, exist_ok=True)
    tag = f"{args.arch}__{args.shape}__{'2pod' if args.multipod else '1pod'}"
    with open(os.path.join(args.out_dir, tag + ".json"), "w") as f:
        json.dump(out, f, indent=2, default=str)
    print(json.dumps(
        {k: out[k] for k in out if k != "roofline"} |
        {"dominant": out.get("roofline", {}).get("dominant"),
         "terms_s": {t: out.get("roofline", {}).get(f"{t}_s")
                     for t in ("compute", "memory", "collective")}},
        indent=2, default=str))


if __name__ == "__main__":
    main()
