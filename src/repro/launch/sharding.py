"""Partitioning rules: params (FSDP+TP), optimizer state, inputs, KV caches.

Axis meaning (DESIGN.md §6):
  "pod"   — pure DP across pods (slow links: gradient all-reduce only)
  "data"  — DP for activations, FSDP shard axis for params/optimizer
  "model" — TP: heads / d_ff / experts / vocab; SP fallback for KV seq
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding
from jax.sharding import PartitionSpec as P

# trailing-dims spec per leaf name; extra leading (scan/stack) dims get None.
_RULES: dict[str, tuple] = {
    # embeddings
    "embed": ("model", "data"),
    "unembed": ("data", "model"),
    # attention
    "w_q": ("data", "model"),
    "w_k": ("data", "model"),
    "w_v": ("data", "model"),
    "w_o": ("model", "data"),
    "b_q": ("model",),
    "b_k": ("model",),
    "b_v": ("model",),
    # dense mlp
    "w_gate": ("data", "model"),
    "w_up": ("data", "model"),
    "w_down": ("model", "data"),
    # moe (experts over "model" = EP)
    "moe_gate": ("data", None),
    "moe_wg": ("model", "data", None),
    "moe_wu": ("model", "data", None),
    "moe_wd": ("model", None, "data"),
    # mamba2
    "in_proj": ("data", "model"),
    "conv_w": (None, "model"),
    "A_log": ("model",),
    "D_skip": ("model",),
    "dt_bias": ("model",),
    "ssm_norm": ("model",),
    "out_proj": ("model", "data"),
    # rwkv6
    "w_r": ("data", "model"),
    "w_g": ("data", "model"),
    "w_lora_a": ("data", None),
    "w_lora_b": (None, "data"),
    "u_bonus": ("model", None),
    "cw_k": ("data", "model"),
    "cw_v": ("model", "data"),
    "cw_r": ("data", "model"),
}
_REPLICATED_HINTS = (
    "ln", "norm", "scale", "mu_", "cmu_", "w0", "final", "b_", "q_norm",
    "k_norm", "step",
)


def _leaf_name(path) -> str:
    for entry in reversed(path):
        if hasattr(entry, "key"):
            return str(entry.key)
        if hasattr(entry, "name"):
            return str(entry.name)
    return ""


def spec_for_leaf(path, leaf) -> P:
    name = _leaf_name(path)
    rule = _RULES.get(name)
    if rule is None:
        return P()  # replicated (norm scales, small mixing vectors, scalars)
    extra = leaf.ndim - len(rule)
    if extra < 0:
        return P()
    return P(*((None,) * extra + tuple(rule)))


def param_specs(params) -> Any:
    return jax.tree_util.tree_map_with_path(spec_for_leaf, params)


def opt_specs(params_specs) -> Any:
    """AdamW m/v mirror the param sharding; step is replicated."""
    from repro.train.optimizer import AdamWState

    return AdamWState(step=P(), m=params_specs, v=params_specs)


def batch_spec(global_batch: int, mesh: Mesh) -> P:
    """Shard the batch over ("pod","data") when divisible, else best effort."""
    dp_axes = [a for a in ("pod", "data") if a in mesh.axis_names]
    size = 1
    use = []
    for a in dp_axes:
        if global_batch % (size * mesh.shape[a]) == 0:
            use.append(a)
            size *= mesh.shape[a]
    return P(tuple(use) if use else None)


def cache_specs(caches, cfg, mesh: Mesh, batch: int) -> Any:
    """KV/state cache sharding with head-vs-sequence fallback (DESIGN §6).

    * batch axis over ("pod","data") when divisible; otherwise the sequence
      axis takes "data" (long-context, batch=1).
    * kv-head axis over "model" when divisible; otherwise the sequence axis
      takes "model" (sequence-parallel attention, psum over seq inserted by
      SPMD).
    """
    tp = mesh.shape["model"]
    dp_axes = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    dp = 1
    for a in dp_axes:
        dp *= mesh.shape[a]
    batch_ok = batch % dp == 0 and batch >= dp

    def leaf_spec(path, leaf):
        name = _leaf_name(path)
        b_ax = dp_axes if batch_ok else None
        if name in ("k", "v"):  # (B, S, KV, dh)
            kv = leaf.shape[-2]
            if kv % tp == 0:
                seq_ax = None if batch_ok else "data"
                return P(*_pad(leaf, (b_ax, seq_ax, "model", None)))
            seq_ax = "model" if batch_ok else ("data", "model")
            return P(*_pad(leaf, (b_ax, seq_ax, None, None)))
        if name == "conv":  # (B, W-1, C)
            return P(*_pad(leaf, (b_ax, None, "model")))
        if name == "ssm":  # (B, H, N, P)
            return P(*_pad(leaf, (b_ax, "model", None, None)))
        if name == "tm_state":  # (B, H, P, P)
            return P(*_pad(leaf, (b_ax, "model", None, None)))
        if name in ("tm_xprev", "cm_xprev"):  # (B, D)
            return P(*_pad(leaf, (b_ax, "model")))
        return P()

    def _pad(leaf, trailing):
        extra = leaf.ndim - len(trailing)
        return (None,) * extra + tuple(trailing)

    return jax.tree_util.tree_map_with_path(leaf_spec, caches)


def index_row_spec() -> P:
    """Row-partition spec for ShardedIndex stores (codes / clouds / ids).

    One contiguous block of corpus rows per device of the flattened
    ("row", "col") index mesh — shard ``p`` owns rows
    ``[p·per, (p+1)·per)``, which is also the owner rule the serve-level
    re-rank uses to scatter cloud gathers back to shards.
    """
    return P(("row", "col"), None)


def index_gram_specs() -> tuple[P, P, P]:
    """(corpus, queries, out) specs of the SUMMA distributed Gram.

    Corpus rows shard over "row" and the embedding width over "col";
    query blocks start "row"-sharded and ring-stream via ``ppermute``;
    the (Q, N) output is row-group sharded over "row" and replicated over
    "col" (each column already holds the full-width ``psum``).
    """
    return P("row", "col"), P("row", "col"), P(None, "row")


def to_shardings(spec_tree, mesh: Mesh):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P),
    )
