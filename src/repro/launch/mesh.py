"""Production meshes.  Functions, not module constants: importing this module
never touches jax device state (the dry-run forces 512 host devices *before*
any jax import; tests/benches see the single real device).
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ("data","model"); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh over whatever devices exist (CPU tests)."""
    n = 1
    for s in shape:
        n *= s
    assert n <= len(jax.devices()), (shape, len(jax.devices()))
    return jax.make_mesh(shape, axes)


# Hardware constants (TPU v5e class, per chip) used by the roofline.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s
CHIPS_PER_POD = 256
