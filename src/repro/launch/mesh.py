"""Production meshes.  Functions, not module constants: importing this module
never touches jax device state (the dry-run forces 512 host devices *before*
any jax import; tests/benches see the single real device).
"""
from __future__ import annotations

import jax
import numpy as np


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 = 256 chips per pod ("data","model"); 2 pods when multi_pod."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_serve_mesh(n_devices: int | None = None, *, multi_pod: bool = False):
    """Data-only mesh for TopoServe bucket execution.

    The TDA serve path is embarrassingly parallel over graphs, so it shards
    over ("pod", "data") only — no "model" axis — and TopoServe pads every
    bucket batch to a multiple of the mesh size (see
    repro/serve/topo_serve.py).  Default: every visible device on one axis.
    """
    n = n_devices if n_devices is not None else len(jax.devices())
    if multi_pod:
        assert n % 2 == 0, f"multi_pod serve mesh needs even device count, got {n}"
        return jax.make_mesh((2, n // 2), ("pod", "data"))
    return jax.make_mesh((n,), ("data",))


def make_index_mesh(n_devices: int | None = None, rows: int | None = None):
    """2D ("row", "col") mesh for ShardedIndex retrieval.

    Corpus rows (embeddings, packed LSH codes, stored clouds) shard over
    the *flattened* ("row", "col") axes for the coarse Hamming scan, while
    the SUMMA-style distributed Gram streams query blocks along "row" with
    partial L1 sums reduced over "col" (docs/ARCHITECTURE.md
    §ShardedIndex).  ``rows`` defaults to the largest divisor of the
    device count <= sqrt(n), so 4 devices give the square (2, 2) mesh and
    one device degenerates to (1, 1).  Built from ``jax.devices()[:n]``
    directly so benches can stand up smaller submeshes next to the full
    one.
    """
    n = n_devices if n_devices is not None else len(jax.devices())
    if rows is None:
        rows = 1
        r = int(n ** 0.5)
        while r > 1:
            if n % r == 0:
                rows = r
                break
            r -= 1
    if n < 1 or n % rows:
        raise ValueError(f"rows={rows} does not divide device count {n}")
    devs = np.asarray(jax.devices()[:n]).reshape(rows, n // rows)
    return jax.sharding.Mesh(devs, ("row", "col"))


def make_test_mesh(shape=(1, 1), axes=("data", "model")):
    """Degenerate mesh over whatever devices exist (CPU tests)."""
    n = 1
    for s in shape:
        n *= s
    assert n <= len(jax.devices()), (shape, len(jax.devices()))
    return jax.make_mesh(shape, axes)


# Hardware constants (TPU v5e class, per chip) used by the roofline.
PEAK_FLOPS_BF16 = 197e12  # FLOP/s
HBM_BW = 819e9  # B/s
ICI_BW_PER_LINK = 50e9  # B/s
CHIPS_PER_POD = 256
