"""Topological feature extraction (betti curves, persistence images)."""
