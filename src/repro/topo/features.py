"""Fixed-size ML features from persistence diagrams.

Turns the variable-content Diagrams tensor into dense vectors usable by a
classifier or as auxiliary model inputs: Betti curves, persistence
statistics, persistence images, and landscapes.  Everything is masked
arithmetic over the fixed-size Diagrams layout, so it vmaps/pjit-shards with
the rest of the pipeline.

The train-side entry point is ``signature_features``, which consumes a
``TopoPlan`` from ``repro.core.api.make_topo_plan`` — the same plan->execute
contract the serve and benchmark layers use (docs/ARCHITECTURE.md
§Plan/Execute), so all three share one compiled pipeline per shape class.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.core.persistence_jax import Diagrams


def betti_curve(d: Diagrams, k: int, grid: jax.Array) -> jax.Array:
    """(..., G) number of dim-k classes alive at each grid value."""
    sel = d.valid & (d.dim == k)
    b = d.birth[..., :, None]
    dd = d.death[..., :, None]
    alive = (grid >= b) & (grid < dd) & sel[..., :, None]
    return jnp.sum(alive, axis=-2).astype(jnp.float32)


def persistence_stats(d: Diagrams, k: int, cap: float = 64.0) -> jax.Array:
    """(..., 6) [count, betti, total-pers, max-pers, mean-birth, mean-death]."""
    sel = (d.valid & (d.dim == k)).astype(jnp.float32)
    n = jnp.sum(sel, axis=-1)
    nz = jnp.maximum(n, 1.0)
    death = d.finite_death(cap)
    pers = jnp.where(sel > 0, death - d.birth, 0.0)
    birth = jnp.where(sel > 0, d.birth, 0.0)
    return jnp.stack([
        n,
        jnp.sum(sel * jnp.isinf(d.death), axis=-1),
        jnp.sum(pers, axis=-1),
        jnp.max(pers, axis=-1, initial=0.0),
        jnp.sum(birth, axis=-1) / nz,
        jnp.sum(jnp.where(sel > 0, death, 0.0), axis=-1) / nz,
    ], axis=-1)


def persistence_image(d: Diagrams, k: int, res: int = 8,
                      lo: float = 0.0, hi: float = 32.0,
                      sigma: float = 1.0, cap: float = 64.0) -> jax.Array:
    """(..., res, res) Gaussian-weighted persistence surface on (birth, pers)."""
    sel = (d.valid & (d.dim == k)).astype(jnp.float32)
    death = d.finite_death(cap)
    birth0 = d.finite_birth()
    pers = jnp.clip(death - birth0, 0.0, hi - lo)
    birth = jnp.clip(birth0, lo, hi)
    grid = jnp.linspace(lo, hi, res)
    gx = grid[None, :]  # birth axis
    gy = grid[None, :]  # persistence axis
    wb = jnp.exp(-0.5 * ((birth[..., :, None] - gx) / sigma) ** 2)
    wp = jnp.exp(-0.5 * ((pers[..., :, None] - gy) / sigma) ** 2)
    # weight each point by its persistence (standard PI weighting)
    w = (sel * pers)[..., :, None, None]
    img = jnp.sum(w * wb[..., :, :, None] * wp[..., :, None, :], axis=-3)
    return img


def persistence_landscape(d: Diagrams, k: int, grid: jax.Array,
                          n_levels: int = 3, cap: float = 64.0) -> jax.Array:
    """(..., n_levels, G) landscape functions lambda_1..lambda_n on grid."""
    sel = d.valid & (d.dim == k)
    death = d.finite_death(cap)
    b = d.finite_birth()[..., :, None]
    dd = death[..., :, None]
    tent = jnp.maximum(jnp.minimum(grid - b, dd - grid), 0.0)
    tent = jnp.where(sel[..., :, None], tent, -jnp.inf)
    top = jax.lax.top_k(jnp.swapaxes(tent, -1, -2), n_levels)[0]
    return jnp.maximum(jnp.swapaxes(top, -1, -2), 0.0)


def signature_features(g, plan, res: int = 8, cap: float = 64.0) -> jax.Array:
    """GraphBatch -> topological feature vectors through a shared TopoPlan.

    ``plan`` is a ``repro.core.api.TopoPlan``; the diagram computation reuses
    whatever executable the serve/bench layers already compiled for the same
    (dim, method, caps, reducer) key.  Output matches ``feature_vector`` with
    ``max_dim = plan.dim``.
    """
    return feature_vector(plan.execute(g), max_dim=plan.dim, res=res, cap=cap)


@partial(jax.jit, static_argnames=("max_dim", "res"))
def feature_vector(d: Diagrams, max_dim: int = 1, res: int = 8,
                   cap: float = 64.0) -> jax.Array:
    """Concatenate stats + flattened persistence image per dimension.

    Output: (..., (6 + res*res) * (max_dim+1)) — a drop-in fixed-size
    topological signature for downstream classifiers.
    """
    parts = []
    for k in range(max_dim + 1):
        parts.append(persistence_stats(d, k, cap))
        parts.append(persistence_image(d, k, res=res, cap=cap).reshape(
            d.birth.shape[:-1] + (res * res,)))
    return jnp.concatenate(parts, axis=-1)
