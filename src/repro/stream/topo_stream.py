r"""TopoStream: exact incremental persistence for dynamic graphs.

The paper's two reduction theorems are *locality* statements:

* **Theorem 2 (CoralTDA)** — ``PD_k(G, f) = PD_k(G^{k+1}, f)`` for k >= 1:
  the k-th diagram only sees the (k+1)-core.
* **Theorem 7 (PrunIT)** — deleting a dominated vertex (``N[u] ⊆ N[v]`` with
  ``f(u) >= f(v)``, sublevel) preserves *every* ``PD_k``.

So most single-edge updates to a large network provably cannot change its
diagram — and a stream of updates only needs a cheap graph-level check, not a
fresh boundary-matrix reduction, to know that.  ``TopoStream`` is a stateful
session over a :class:`~repro.core.graph.GraphBatch`: it holds the current
graphs, their cached (dim+1)-core / domination state and the last diagrams;
``apply(delta)`` runs a jit-compiled **invalidation verdict** and only
re-executes the compiled persistence plan (``repro.core.api.make_topo_plan``
— the same plan→execute machinery TopoServe uses) for the graphs whose
diagram could actually have moved, gathered into a power-of-two padded
sub-batch so recompute cost scales with the *miss* count, not the batch.

Invalidation predicates (both exact, proofs in ``invalidation_verdict``):

* **coral hit** — the induced (dim+1)-core subgraph (vertex set, edges and f
  on it) is unchanged ⟹ ``PD_j`` unchanged for all ``j >= dim``.  Guards the
  *target* dimension only (PD_0 may still move), so it is enabled for
  ``exact_dims="target"`` and ``dim >= 1``.
* **prunit hit** — every touched vertex is dominated, before *and* after the
  update, by an untouched witness satisfying the f condition ⟹ ``PD_k``
  unchanged for *all* k.  Always enabled.

``exact_dims="target"`` (default) serves ``PD_dim`` exactly; lower dims may
be stale after coral hits (``all_dims_exact`` tracks this per graph).
``exact_dims="all"`` restricts to the prunit predicate (and to reductions
that are exact in every dimension) so the full diagram tensor stays exact.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.api import REDUCTIONS, TopoPlan, make_topo_plan
from repro.core.delta import DeltaBatch, apply_delta
from repro.core.filtration import complex_caps_ok
from repro.core.graph import GraphBatch
from repro.core.kcore import coreness, kcore_mask
from repro.core.persistence_jax import Diagrams, diagrams_to_numpy
from repro.core.prunit import eligibility_matrix as _prunit_eligibility
from repro.stream.calibration import DriftCalibrator, parse_drift_threshold

# reductions exact in every homology dimension (no coral core restriction)
_ALL_DIM_METHODS = ("prunit", "none")

# process-wide TopoScope instruments (per-session breakdown stays in the
# session's own ``stats`` dict; these aggregate across every session)
_OBS_VERDICTS = obs.counter(
    "stream.verdicts", help="invalidation verdicts per (graph, step) touch")
_OBS_DRIFT = obs.histogram(
    "stream.drift_score", help="drift distances of recomputed graphs",
    buckets=(0.01, 0.03, 0.1, 0.3, 1.0, 3.0, 10.0, 30.0, 100.0))


@dataclasses.dataclass(frozen=True)
class TopoStreamConfig:
    """Pipeline parameters + invalidation policy for one stream session.

    Drift scoring (``drift_metric="sw"`` or any registered MetricEngine
    backend, e.g. ``"sinkhorn"``/``"exact_w"``): each apply step also
    reports, per graph, the backend's distance between the previous and the
    new cached ``PD_drift_dim`` — cache hits are provably distance 0 (the
    theorems certify the diagram did not move), so only recomputed graphs
    pay the embedding/distance cost.  ``last_drift`` / ``last_anomaly``
    expose the scores; a score above ``drift_threshold`` flags an anomaly
    (the Azamir–Bennis–Michel change-detection loop as a serve-time
    by-product).  ``drift_threshold`` is either a constant float or
    ``"auto:q0.99"``-style: an online P² quantile estimator over each
    graph's own drift history (repro/stream/calibration.py) calibrates the
    flagging threshold per stream, with no flags during the first
    ``drift_warmup`` observed recomputes per graph.

    ``repack="on"`` makes the session's plan two-phase (reduce → repack →
    persist, repro/core/api.py): recomputes pay persistence at each graph's
    *post-reduction* shape class, and the session caches the last
    reduce-phase repack assignment in ``last_repack``.
    """

    dim: int = 1
    method: str = "both"
    sublevel: bool = True
    edge_cap: int = 256
    tri_cap: int = 512
    quad_cap: int = 0
    reducer: str = "jnp"
    exact_dims: str = "target"   # "target" (coral+prunit) | "all" (prunit)
    recompute_pad: str = "pow2"  # "pow2" | "full" sub-batch padding policy
    check_caps: bool = True      # verify simplex caps still hold after updates
    repack: str = "off"          # "off" | "on": two-phase persist at reduced size
    drift_metric: str | None = None  # None (off) | any MetricEngine backend
    drift_dim: int | None = None     # diagram dimension scored (default: dim)
    drift_threshold: float | str = 1.0  # constant, or "auto:qX" (P² quantile)
    drift_n_dirs: int = 16           # SW direction-grid resolution
    drift_cap: float = 64.0          # essential-class death cap
    drift_warmup: int = 10           # auto mode: min observations before flags

    def __post_init__(self):
        if self.method not in REDUCTIONS:
            raise ValueError(f"unknown reduction {self.method!r}")
        if self.exact_dims not in ("target", "all"):
            raise ValueError(f"exact_dims must be 'target' or 'all', "
                             f"got {self.exact_dims!r}")
        if self.exact_dims == "all" and self.method not in _ALL_DIM_METHODS:
            raise ValueError(
                f"exact_dims='all' requires a reduction exact in every "
                f"dimension ({_ALL_DIM_METHODS}); {self.method!r} restricts "
                f"to the (dim+1)-core and breaks PD_0..PD_dim-1")
        if self.recompute_pad not in ("pow2", "full"):
            raise ValueError(f"recompute_pad must be 'pow2' or 'full', "
                             f"got {self.recompute_pad!r}")
        if self.repack not in ("off", "on"):
            raise ValueError(f"repack must be 'off' or 'on', "
                             f"got {self.repack!r}")
        parse_drift_threshold(self.drift_threshold)  # raises on bad spec
        if self.drift_warmup < 5:
            raise ValueError(f"drift_warmup must be >= 5 (P² needs 5 "
                             f"observations), got {self.drift_warmup}")
        if self.drift_metric is not None:
            # any registered MetricEngine backend may score drift; resolve
            # through the registry so the config rejects unknown names with
            # the full backend list (import here: metrics ↛ stream)
            from repro.metrics.engine import get_metric
            try:
                get_metric(self.drift_metric)
            except ValueError as e:
                raise ValueError(f"drift_metric: {e}") from None
        if self.drift_dim is not None and not (0 <= self.drift_dim <= self.dim):
            raise ValueError(
                f"drift_dim {self.drift_dim} outside computed dims 0..{self.dim}")
        if (self.drift_metric is not None and self.drift_dim is not None
                and self.drift_dim < self.dim and self.exact_dims != "all"):
            # coral hits leave dims < dim stale, so a later recompute would
            # misattribute the accumulated sub-target movement to one step
            raise ValueError(
                f"drift_dim {self.drift_dim} < dim {self.dim} requires "
                f"exact_dims='all' (with exact_dims='target' the scored "
                f"dimension can go stale on coral hits)")


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class StreamVerdict:
    """Per-graph invalidation outcome of one ``apply`` step.

    touched:    any effective change (adjacency, mask, or f) this step.
    coral_hit:  induced (dim+1)-core unchanged (PD_{>=dim} preserved).
    prunit_hit: all touched vertices dominated before+after by untouched
                witnesses (every PD_k preserved).
    recompute:  touched & not hit — the graphs the plan re-executes on.
    core_mask:  fresh (dim+1)-core mask of the updated graphs.
    elig:       fresh eligibility (domination & f-condition) matrix.
    caps_ok:    simplex caps still hold for the updated graph.
    """

    touched: jax.Array
    coral_hit: jax.Array
    prunit_hit: jax.Array
    recompute: jax.Array
    core_mask: jax.Array
    elig: jax.Array
    caps_ok: jax.Array


def eligibility_matrix(g: GraphBatch, sublevel: bool = True) -> jax.Array:
    """(B, N, N) bool E with E[u, v] = "PrunIT may remove u with witness v".

    GraphBatch-level view of ``repro.core.prunit.eligibility_matrix`` — the
    one definition of Theorem 7's hypothesis (domination + f condition),
    shared with the PrunIT reduction rounds.
    """
    return _prunit_eligibility(g.adj, g.mask, g.f, sublevel)


def _prunit_safe(touched_v: jax.Array, mask: jax.Array,
                 elig: jax.Array) -> jax.Array:
    r"""(B,) bool: every touched live vertex has an untouched witness.

    Soundness (Theorem 7, iterated): let U be the touched set.  Each u in U
    with a witness v ∉ U stays dominated while *other* members of U are
    removed (deleting z ∉ {u, v} preserves ``N[u] ⊆ N[v]``, and f never
    changes under vertex deletion), so removing U∩live one by one is a valid
    PrunIT sequence: ``PD_k(G) = PD_k(G \ U)`` for all k.
    """
    witness_ok = jnp.any(elig & ~touched_v[..., None, :], axis=-1)
    need = touched_v & mask
    return jnp.all(~need | witness_ok, axis=-1)


@partial(jax.jit, static_argnames=("dim", "sublevel", "use_coral",
                                   "check_caps", "edge_cap", "tri_cap",
                                   "quad_cap"))
def invalidation_verdict(
    g_old: GraphBatch,
    g_new: GraphBatch,
    core_old: jax.Array,
    elig_old: jax.Array,
    dim: int,
    sublevel: bool,
    use_coral: bool,
    check_caps: bool = False,
    edge_cap: int = 0,
    tri_cap: int = 0,
    quad_cap: int = 0,
) -> StreamVerdict:
    """The reduction-aware invalidation check (pure JAX, one jitted program).

    The touched set is the exact state diff (so ineffective ops — inserting
    an existing edge, rewriting f with the same value — never invalidate).

    Coral predicate: ``PD_dim(G) = PD_dim(core_{dim+1}(G))`` (Thm 2), so if
    the (dim+1)-core *as an f-labelled induced subgraph* is identical in G
    and G', then ``PD_dim(G) = PD_dim(G')``.  Checking the core of G' from
    scratch (a few masked mat-vec sweeps) is what makes edge *insertions*
    safe too — an inserted edge between outside-core vertices can create new
    core (e.g. closing a path into a cycle), which mask equality detects.

    PrunIT predicate: see ``_prunit_safe``; applying it to both G and G'
    gives ``PD_k(G) = PD_k(G \\ U) = PD_k(G' \\ U) = PD_k(G')`` for all k,
    because every changed edge/f/mask entry is incident to the touched set U,
    hence ``G \\ U = G' \\ U``.
    """
    adj_diff = g_old.adj ^ g_new.adj
    f_diff = g_old.f != g_new.f
    mask_diff = g_old.mask ^ g_new.mask
    # PrunIT's removal set U only needs to COVER the diff (every changed
    # edge incident to U, every changed f/mask entry inside U) — vertices
    # whose own state changed, plus both endpoints of any changed edge not
    # already covered.  The tighter U matters: dropping vertex u also flips
    # its neighbors' adjacency rows, but {u} alone covers those edges, so a
    # plain dominated-vertex removal (the paper's Theorem 7 move) stays U={u}
    # and keeps its untouched witness.
    u0 = f_diff | mask_diff                                      # (B, N)
    covered = u0[..., None, :] | u0[..., :, None]
    touched_v = u0 | jnp.any(adj_diff & ~covered, axis=-1)       # (B, N)
    touched = jnp.any(touched_v, axis=-1) | jnp.any(adj_diff, axis=(-1, -2))

    core_new = kcore_mask(g_new.adj, g_new.mask, dim + 1)
    elig_new = eligibility_matrix(g_new, sublevel)

    if use_coral:
        core_same = jnp.all(core_new == core_old, axis=-1)
        edge_in_core = jnp.any(
            adj_diff & core_new[..., None, :] & core_new[..., :, None],
            axis=(-1, -2))
        f_in_core = jnp.any(f_diff & core_new, axis=-1)
        coral_hit = core_same & ~edge_in_core & ~f_in_core
    else:
        coral_hit = jnp.zeros_like(touched)

    prunit_hit = (_prunit_safe(touched_v, g_old.mask, elig_old)
                  & _prunit_safe(touched_v, g_new.mask, elig_new))

    hit = coral_hit | prunit_hit
    if check_caps:
        caps_ok = jax.vmap(
            lambda a, m: complex_caps_ok(a, m, edge_cap, tri_cap, quad_cap,
                                         max_dim=dim)
        )(g_new.adj, g_new.mask)
    else:
        caps_ok = jnp.ones_like(touched)
    return StreamVerdict(
        touched=touched,
        coral_hit=coral_hit & touched,
        prunit_hit=prunit_hit & touched,
        recompute=touched & ~hit,
        core_mask=core_new,
        elig=elig_new,
        caps_ok=caps_ok,
    )


class TopoStream:
    """Stateful incremental-persistence session over a GraphBatch.

    >>> stream = TopoStream(g0, TopoStreamConfig(dim=1, method="both"))
    >>> d = stream.apply(delta)        # fresh-or-cached Diagrams, exact PD_1
    >>> stream.stats["hits"], stream.stats["recomputes"]

    Each session owns one compiled plan (via the process-wide plan cache);
    recomputes gather only the invalidated graphs into a power-of-two padded
    sub-batch, so the jit-signature count is bounded by ``log2(B)`` and the
    work scales with misses, not with the session size.
    """

    def __init__(self, g: GraphBatch, config: TopoStreamConfig | None = None):
        self.config = config or TopoStreamConfig()
        c = self.config
        self._use_coral = c.exact_dims == "target" and c.dim >= 1
        self._plan: TopoPlan = make_topo_plan(
            dim=c.dim, method=c.method, sublevel=c.sublevel,
            edge_cap=c.edge_cap, tri_cap=c.tri_cap, quad_cap=c.quad_cap,
            reducer=c.reducer, repack=c.repack)
        self._g = g
        # repack="on": the session caches the last reduce-phase repack report
        # so recomputes pay reduced-size persistence and callers can inspect
        # the rung assignments (last_repack)
        self._diagrams, self.last_repack = self._plan.execute_info(g)
        self._core = kcore_mask(g.adj, g.mask, c.dim + 1)
        self._elig = eligibility_matrix(g, c.sublevel)
        self._all_dims_exact = np.full(
            (g.batch,), c.method in _ALL_DIM_METHODS, bool)
        # drift scoring state (zero-cost when drift_metric is None)
        self.last_drift = np.zeros((g.batch,), np.float32)
        self.last_anomaly = np.zeros((g.batch,), bool)
        mode, val = parse_drift_threshold(c.drift_threshold)
        self._drift_calibrator = (
            DriftCalibrator(g.batch, q=val, warmup=c.drift_warmup)
            if mode == "auto" else None)
        self._drift_const = val if mode == "const" else None
        self.stats = {
            "applied": 0,            # apply() calls
            "graph_updates": 0,      # (graph, step) pairs with a real change
            "hits": 0,               # ... answered from cache
            "coral_hits": 0,
            "prunit_hits": 0,        # prunit-only hits (coral takes priority)
            "recomputes": 0,         # ... that re-executed the plan
            "recompute_batches": 0,  # plan executions
            "recomputed_rows": 0,    # padded rows executed (cost proxy)
            "anomalies": 0,          # drift scores above drift_threshold
        }

    # ------------------------------------------------------------ accessors

    @property
    def graph(self) -> GraphBatch:
        """The current (post-update) GraphBatch."""
        return self._g

    @property
    def diagrams(self) -> Diagrams:
        """The maintained diagrams; ``PD_dim`` rows are always exact."""
        return self._diagrams

    @property
    def plan(self) -> TopoPlan:
        return self._plan

    @property
    def all_dims_exact(self) -> np.ndarray:
        """(B,) bool — graphs whose dims < dim rows are also exact."""
        return self._all_dims_exact.copy()

    def coreness(self) -> jax.Array:
        """Full per-vertex core numbers of the current graphs (diagnostic)."""
        return coreness(self._g.adj, self._g.mask)

    def skip_rate(self) -> float:
        """Fraction of graph updates answered from cache so far."""
        return self.stats["hits"] / max(self.stats["graph_updates"], 1)

    def drift_thresholds(self) -> np.ndarray:
        """(B,) per-graph anomaly threshold currently in force.

        Constant mode broadcasts the configured value; auto mode returns
        each graph's online P² quantile estimate (``+inf`` during warmup, so
        an uncalibrated graph never flags).
        """
        if self._drift_calibrator is not None:
            return self._drift_calibrator.thresholds()
        return np.full((self._g.batch,), self._drift_const, np.float32)

    # ---------------------------------------------------------------- apply

    def apply(self, delta: DeltaBatch) -> Diagrams:
        """Apply one update step; returns the (fresh-or-cached) diagrams.

        Raises ValueError if an update pushes a graph past the session's
        simplex caps (``check_caps=False`` disables the guard).
        """
        c = self.config
        with obs.span("stream.verdict", graphs=self._g.batch):
            g_new = apply_delta(self._g, delta)
            verdict = invalidation_verdict(
                self._g, g_new, self._core, self._elig,
                dim=c.dim, sublevel=c.sublevel, use_coral=self._use_coral,
                check_caps=c.check_caps, edge_cap=c.edge_cap,
                tri_cap=c.tri_cap, quad_cap=c.quad_cap)

            touched = np.asarray(verdict.touched)
            coral = np.asarray(verdict.coral_hit)
            prunit = np.asarray(verdict.prunit_hit)
            needs = np.asarray(verdict.recompute)
        if c.check_caps and not np.asarray(verdict.caps_ok).all():
            bad = np.nonzero(~np.asarray(verdict.caps_ok))[0].tolist()
            raise ValueError(
                f"update overflows simplex caps (edge_cap={c.edge_cap}, "
                f"tri_cap={c.tri_cap}) for graphs {bad}; diagrams would be "
                f"truncated — resize the session caps")

        drift = np.zeros((g_new.batch,), np.float32)
        if needs.any():
            idx = np.nonzero(needs)[0]
            old = self._diagrams
            with obs.span("stream.recompute", misses=len(idx)):
                self._diagrams = self._recompute(g_new, idx)
            self.stats["recomputes"] += int(needs.sum())
            self._all_dims_exact[idx] = c.method in _ALL_DIM_METHODS
            if c.drift_metric is not None:
                with obs.span("stream.drift", backend=c.drift_metric):
                    drift[idx] = self._drift_scores(old, self._diagrams, idx)
                for s in drift[idx]:
                    _OBS_DRIFT.observe(float(s), backend=c.drift_metric)

        if c.drift_metric is not None:
            self.last_drift = drift
            self.last_anomaly = drift > self.drift_thresholds()
            self.stats["anomalies"] += int(self.last_anomaly.sum())
            if self._drift_calibrator is not None and needs.any():
                # absorb AFTER flagging: a burst is judged against the
                # pre-burst history, then becomes part of it
                idx = np.nonzero(needs)[0]
                self._drift_calibrator.observe(idx, drift[idx])

        # coral-only hits leave dims < dim stale for that graph
        self._all_dims_exact[coral & ~prunit] = False

        self.stats["applied"] += 1
        self.stats["graph_updates"] += int(touched.sum())
        self.stats["hits"] += int((touched & ~needs).sum())
        self.stats["coral_hits"] += int(coral.sum())
        self.stats["prunit_hits"] += int((prunit & ~coral).sum())
        for verdict_name, n in (("coral_hit", int(coral.sum())),
                                ("prunit_hit", int((prunit & ~coral).sum())),
                                ("recompute", int(needs.sum()))):
            if n:
                _OBS_VERDICTS.inc(n, verdict=verdict_name)

        self._g = g_new
        self._core = verdict.core_mask
        self._elig = verdict.elig
        return self._diagrams

    def _drift_scores(self, old: Diagrams, new: Diagrams,
                      idx: np.ndarray) -> np.ndarray:
        """Drift distances between previous and fresh diagrams of ``idx``.

        Routed through the MetricEngine registry (``compare``) so any
        registered backend — approximate ``sw``/``sinkhorn`` or the exact
        auction-LAP ``exact_w`` — can score drift; per-backend tunables
        (``n_dirs``) are forwarded only where declared.  Hits are skipped
        by construction (their diagram provably did not move, so the score
        is exactly 0); the gather is padded to the next power of two so
        the jitted distance sees the same bounded ladder of shapes as the
        recompute path.
        """
        from repro.metrics.engine import compare, metric_params

        c = self.config
        k = len(idx)
        r = min(old.birth.shape[0], 1 << (k - 1).bit_length()) if k else 0
        idx_p = np.concatenate([idx, np.full(r - k, idx[0], idx.dtype)])
        jidx = jnp.asarray(idx_p)
        rows = lambda d: jax.tree.map(lambda x: x[jidx], d)
        params = {}
        if "n_dirs" in metric_params(c.drift_metric):
            params["n_dirs"] = c.drift_n_dirs
        scores = compare(
            rows(old), rows(new), metric=c.drift_metric,
            k=c.drift_dim if c.drift_dim is not None else c.dim,
            cap=c.drift_cap, **params)
        return np.asarray(scores, np.float32)[:k]

    def _recompute(self, g_new: GraphBatch, idx: np.ndarray) -> Diagrams:
        """Re-execute the plan on the invalidated graphs only.

        The miss set is gathered into a sub-batch padded to the next power
        of two (``recompute_pad="pow2"``) so the plan sees a bounded ladder
        of batch shapes; padding rows repeat the first miss and are dropped
        at scatter time.
        """
        b = g_new.batch
        k = len(idx)
        if self.config.recompute_pad == "full" or k >= b:
            d, rep = self._plan.execute_info(g_new)
            if rep is not None:
                self.last_repack = rep
            self.stats["recompute_batches"] += 1
            self.stats["recomputed_rows"] += b
            if k >= b:
                return d
            jidx = jnp.asarray(idx)
            return jax.tree.map(
                lambda c_, n_: c_.at[jidx].set(n_[jidx]), self._diagrams, d)
        r = min(b, 1 << (k - 1).bit_length())
        idx_p = np.concatenate([idx, np.full(r - k, idx[0], idx.dtype)])
        sub = jax.tree.map(lambda x: x[jnp.asarray(idx_p)], g_new)
        d, rep = self._plan.execute_info(sub)
        if rep is not None:
            self.last_repack = rep  # rung assignment of the gathered misses
        self.stats["recompute_batches"] += 1
        self.stats["recomputed_rows"] += r
        jidx = jnp.asarray(idx)
        return jax.tree.map(
            lambda c_, n_: c_.at[jidx].set(n_[:k]), self._diagrams, d)


def dim_pairs(d: Diagrams, graph_index: int, k: int) -> list[tuple]:
    """Sorted ``(birth, death)`` pairs of ``PD_k`` for one graph.

    The canonical comparison artifact for streamed-vs-scratch parity: cached
    and recomputed diagram *tensors* index rows by filtration position (which
    legitimately shifts when untracked parts of the graph change), but the
    multiset of persistence pairs in every guaranteed dimension must match
    bit-for-bit (benchmarks/stream_bench.py, tests/test_topo_stream.py).
    """
    return diagrams_to_numpy(d, graph_index, max_dim=k)[k]
