"""Online drift-threshold calibration: P² quantile estimation per graph.

``TopoStream`` flags an anomaly when a recompute's drift score exceeds a
threshold.  A constant threshold needs workload-specific tuning (the
ROADMAP's "drift calibration" item); ``drift_threshold="auto:q0.99"``
instead maintains, per graph, a Jain–Chlamtac **P² estimator** of the
q-quantile of that graph's own drift history — O(1) memory (5 markers) and
O(1) update per observation, no sample buffer — and flags scores above the
current estimate.

Only *recomputed* graphs feed the estimator: cache hits score exactly 0 by
theorem (the diagram provably did not move), so including them would only
dilute the distribution of genuine diagram movement.  Scores are compared
against the threshold *before* being absorbed, so a burst is judged against
the pre-burst history; until a graph has ``warmup`` observations its
threshold is ``+inf`` (no flags from an uncalibrated estimator).
"""
from __future__ import annotations

import numpy as np


class P2Quantile:
    """Jain & Chlamtac (1985) P² online quantile estimator (one scalar
    stream).  ``value()`` is ``None`` until 5 observations have been seen."""

    def __init__(self, q: float):
        if not 0.0 < q < 1.0:
            raise ValueError(f"quantile must be in (0, 1), got {q}")
        self.q = float(q)
        self._init: list[float] = []   # first 5 observations
        self._h = np.zeros(5)          # marker heights
        self._n = np.zeros(5)          # marker positions (1-based)
        self._np = np.zeros(5)         # desired positions
        self.count = 0

    def update(self, x: float) -> None:
        x = float(x)
        self.count += 1
        if self.count <= 5:
            self._init.append(x)
            if self.count == 5:
                q = self.q
                self._h = np.sort(np.asarray(self._init, float))
                self._n = np.arange(1.0, 6.0)
                self._np = np.array(
                    [1, 1 + 2 * q, 1 + 4 * q, 3 + 2 * q, 5], float)
            return
        h, n = self._h, self._n
        if x < h[0]:
            h[0] = x
            k = 0
        elif x >= h[4]:
            h[4] = x
            k = 3
        else:
            k = int(np.searchsorted(h, x, side="right")) - 1
            k = min(max(k, 0), 3)
        n[k + 1:] += 1
        self._np += np.array([0, self.q / 2, self.q, (1 + self.q) / 2, 1.0])
        for i in (1, 2, 3):
            d = self._np[i] - n[i]
            if (d >= 1 and n[i + 1] - n[i] > 1) or (d <= -1 and n[i - 1] - n[i] < -1):
                s = 1.0 if d >= 1 else -1.0
                # parabolic (P²) marker adjustment, linear fallback
                hp = h[i] + s / (n[i + 1] - n[i - 1]) * (
                    (n[i] - n[i - 1] + s) * (h[i + 1] - h[i]) / (n[i + 1] - n[i])
                    + (n[i + 1] - n[i] - s) * (h[i] - h[i - 1]) / (n[i] - n[i - 1]))
                if not h[i - 1] < hp < h[i + 1]:
                    j = i + int(s)
                    hp = h[i] + s * (h[j] - h[i]) / (n[j] - n[i])
                h[i] = hp
                n[i] += s

    def value(self) -> float | None:
        if self.count < 5:
            return None
        return float(self._h[2])


class DriftCalibrator:
    """One P² estimator per graph of a TopoStream session.

    ``thresholds()`` returns the per-graph flagging threshold — the current
    quantile estimate, or ``+inf`` while a graph is still inside its warmup
    (fewer than ``warmup`` observed recompute scores).
    """

    def __init__(self, batch: int, q: float, warmup: int = 10):
        if warmup < 5:
            raise ValueError(f"warmup must be >= 5 (P² needs 5 obs), got {warmup}")
        self.q = float(q)
        self.warmup = int(warmup)
        self._est = [P2Quantile(q) for _ in range(batch)]

    def thresholds(self) -> np.ndarray:
        out = np.full(len(self._est), np.inf, np.float32)
        for i, e in enumerate(self._est):
            if e.count >= self.warmup:
                out[i] = e.value()
        return out

    def observe(self, idx, scores) -> None:
        """Absorb the drift scores of the recomputed graphs ``idx``."""
        for i, x in zip(np.asarray(idx).tolist(), np.asarray(scores).tolist()):
            self._est[i].update(x)

    def counts(self) -> np.ndarray:
        return np.asarray([e.count for e in self._est], np.int64)


def parse_drift_threshold(spec) -> tuple[str, float]:
    """Parse ``drift_threshold``: a float (constant mode) or ``"auto:qX"``.

    Returns ``("const", value)`` or ``("auto", quantile)``.
    """
    if isinstance(spec, str):
        if not spec.startswith("auto:q"):
            raise ValueError(
                f"drift_threshold string must look like 'auto:q0.99', "
                f"got {spec!r}")
        q = float(spec[len("auto:q"):])
        if not 0.0 < q < 1.0:
            raise ValueError(f"drift quantile must be in (0, 1), got {q}")
        return "auto", q
    return "const", float(spec)
