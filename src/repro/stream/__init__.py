"""TopoStream: incremental persistence diagrams over dynamic-graph streams
with reduction-aware invalidation (docs/ARCHITECTURE.md §TopoStream)."""
from repro.stream.topo_stream import (
    StreamVerdict,
    TopoStream,
    TopoStreamConfig,
    dim_pairs,
    eligibility_matrix,
    invalidation_verdict,
)

__all__ = [
    "StreamVerdict",
    "TopoStream",
    "TopoStreamConfig",
    "dim_pairs",
    "eligibility_matrix",
    "invalidation_verdict",
]
