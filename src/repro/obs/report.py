"""TopoScope trace report: top-k self-time table with cost-cell labels.

``python -m repro.obs report <trace.json>`` aggregates a Chrome-trace
file produced by :func:`repro.obs.export_chrome_trace` into per-span
self-time (span duration minus enclosed child spans, computed per
thread from the interval nesting), then attaches the same roofline cost
cells PerfGate uses offline (``perfgate/cost_cells.py``) to kernel
spans — so a live trace and a gate regression speak one vocabulary.

Kernel spans carry their shape as a ``B32_N128``-style token string in
``args["shape"]``; the mapping below turns a span name into the
cost-model benchmark prefix ``cost_cells.attribute`` expects.
"""
from __future__ import annotations

import json
from typing import Optional

# span name -> perfgate cost-model benchmark prefix
KERNEL_CELLS = {
    "kernels.pairwise_l1": "kernel_pairwise_gram",
    "kernels.domination": "kernel_domination",
    "kernels.kcore_peel": "kernel_kcore",
    "kernels.common_neighbors": "kernel_common_neighbors",
    "kernels.auction_lap": "kernel_auction_lap",
    "kernels.sinkhorn_lse": "kernel_sinkhorn_lse",
    "kernels.sinkhorn_pair_sum": "kernel_sinkhorn_lse",
    "kernels.gf2_reduce": "kernel_gf2_reduce",
    "kernels.gf2_reduce_batch": "kernel_gf2_reduce",
}


def load_trace(path: str) -> list[dict]:
    """Read ``traceEvents`` from a Chrome-trace JSON file (accepts both
    the object form and a bare event array)."""
    with open(path) as fh:
        doc = json.load(fh)
    events = doc["traceEvents"] if isinstance(doc, dict) else doc
    return [e for e in events if e.get("ph") == "X"]


def self_times(events: list[dict]) -> list[tuple[dict, float]]:
    """[(event, self_us)] — duration minus time covered by child spans.

    Children are recovered from interval nesting per (pid, tid): events
    sorted by (ts, -dur) visit parents before their children, and a span
    whose start is past the top of the open stack closes everything it
    does not nest inside.
    """
    out: list[tuple[dict, float]] = []
    by_thread: dict[tuple, list[dict]] = {}
    for e in events:
        by_thread.setdefault((e.get("pid"), e.get("tid")), []).append(e)
    for evs in by_thread.values():
        evs.sort(key=lambda e: (e["ts"], -e["dur"]))
        stack: list[list] = []  # [event, self_us]
        for e in evs:
            while stack and e["ts"] >= stack[-1][0]["ts"] + stack[-1][0]["dur"]:
                out.append((stack[-1][0], max(stack[-1][1], 0.0)))
                stack.pop()
            if stack:
                stack[-1][1] -= e["dur"]
            stack.append([e, e["dur"]])
        while stack:
            out.append((stack[-1][0], max(stack[-1][1], 0.0)))
            stack.pop()
    return out


def aggregate(events: list[dict]) -> list[dict]:
    """Per (name, shape) rows: calls, total/self time, cost cell."""
    rows: dict[tuple[str, str], dict] = {}
    for e, self_us in self_times(events):
        shape = str(e.get("args", {}).get("shape", ""))
        key = (e["name"], shape)
        row = rows.get(key)
        if row is None:
            row = rows[key] = {
                "span": e["name"], "shape": shape, "calls": 0,
                "total_us": 0.0, "self_us": 0.0,
            }
        row["calls"] += 1
        row["total_us"] += e["dur"]
        row["self_us"] += self_us
    for row in rows.values():
        row["cost_cell"] = cost_cell_for(row["span"], row["shape"])
    return sorted(rows.values(), key=lambda r: -r["self_us"])


def cost_cell_for(span_name: str, shape: str) -> Optional[dict]:
    """Roofline cost cell for a kernel span (None for non-kernel spans)."""
    bench = KERNEL_CELLS.get(span_name)
    if bench is None:
        return None
    # lazy import: keeps repro.obs importable without the perfgate deps
    from repro.perfgate.cost_cells import attribute
    return attribute("obs", bench, shape or "")


def format_report(events: list[dict], top: int = 15) -> str:
    """Human-readable top-k self-time table over a trace."""
    if not events:
        return "(empty trace)"
    rows = aggregate(events)
    wall_us = (max(e["ts"] + e["dur"] for e in events)
               - min(e["ts"] for e in events))
    total_self = sum(r["self_us"] for r in rows) or 1.0
    lines = [
        f"trace: {len(events)} spans, {len(rows)} distinct, "
        f"wall {wall_us / 1e6:.3f}s",
        f"{'span':<28} {'shape':<14} {'calls':>6} {'total_s':>9} "
        f"{'self_s':>9} {'self%':>6}  cost cell",
        "-" * 100,
    ]
    for row in rows[:top]:
        cell = row["cost_cell"]
        cell_s = ""
        if cell is not None:
            cell_s = f"{cell['cell']} [{cell['bound']}]"
        lines.append(
            f"{row['span']:<28} {row['shape']:<14} {row['calls']:>6d} "
            f"{row['total_us'] / 1e6:>9.4f} {row['self_us'] / 1e6:>9.4f} "
            f"{100.0 * row['self_us'] / total_self:>5.1f}%  {cell_s}")
    if len(rows) > top:
        rest = sum(r["self_us"] for r in rows[top:])
        lines.append(f"... {len(rows) - top} more rows "
                     f"({rest / 1e6:.4f}s self)")
    return "\n".join(lines)


def report(path: str, top: int = 15) -> str:
    return format_report(load_trace(path), top=top)
