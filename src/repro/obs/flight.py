"""TopoWatch flight recorder: always-on bounded ring of recent events.

Tracing (:mod:`repro.obs.trace`) is opt-in and unbounded-ish; the flight
recorder is the opposite — **always on**, bounded, and cheap enough to
feed from the serving hot path: each thread appends to its own
``collections.deque(maxlen=...)``, so recording is one lock-free append
(~100 ns) and memory is hard-capped at ``capacity × threads`` events no
matter how long the process runs.

``record(kind, name, **attrs)`` is called from the drain loops (batch
executed / failed, deadline expiries, cancellations), from completed
spans when tracing happens to be on, and from SLO verdict transitions —
so when something goes wrong, the last ~512 events per thread are
already in memory.  ``dump(reason)`` writes them (plus a full metrics
snapshot and the current SLO verdicts) to
``results/obs/FLIGHT_<rev>.json``; ``auto_dump`` is the rate-limited
variant wired to SLO breaches, deadline expiries, and drain exceptions.
"""
from __future__ import annotations

import json
import os
import subprocess
import threading
import time
from collections import deque
from typing import Optional


class _Config:
    __slots__ = ("capacity", "dump_dir", "min_dump_interval_s")

    def __init__(self):
        self.capacity = 512          # events kept per thread
        self.dump_dir = "results/obs"
        self.min_dump_interval_s = 30.0


_CONFIG = _Config()
_LOCK = threading.Lock()
# (thread name, ring) per thread that ever recorded; rings of finished
# threads linger but are bounded, so a thread-churny process stays capped
_RINGS: list[tuple[int, str, deque]] = []
_TLS = threading.local()

_SEQ_LOCK = threading.Lock()
_SEQ = 0          # global sequence for a total event order across threads
_LAST_DUMP = 0.0  # monotonic instant of the last auto_dump
_LAST_DUMP_PATH: Optional[str] = None


def configure(capacity: Optional[int] = None,
              dump_dir: Optional[str] = None,
              min_dump_interval_s: Optional[float] = None) -> None:
    """Tune the ring size / dump location.  ``capacity`` applies to rings
    created after the call (existing per-thread rings keep their bound)."""
    if capacity is not None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        _CONFIG.capacity = int(capacity)
    if dump_dir is not None:
        _CONFIG.dump_dir = dump_dir
    if min_dump_interval_s is not None:
        _CONFIG.min_dump_interval_s = float(min_dump_interval_s)


def _ring() -> deque:
    r = getattr(_TLS, "ring", None)
    if r is None:
        r = _TLS.ring = deque(maxlen=_CONFIG.capacity)
        t = threading.current_thread()
        with _LOCK:
            _RINGS.append((t.ident or 0, t.name, r))
    return r


def record(kind: str, name: str, **attrs) -> None:
    """Append one event to this thread's ring (never blocks on other
    threads; the only lock is first-touch ring registration)."""
    global _SEQ
    with _SEQ_LOCK:
        _SEQ += 1
        seq = _SEQ
    _ring().append({
        "seq": seq,
        "ts": time.time(),
        "kind": kind,
        "name": name,
        "attrs": {k: (v if isinstance(v, (int, float, bool, str)) else str(v))
                  for k, v in attrs.items()},
    })


def events(limit: Optional[int] = None) -> list[dict]:
    """All buffered events merged across threads in recording order
    (most recent last); ``limit`` keeps only the newest N."""
    with _LOCK:
        rings = [(tid, nm, list(r)) for (tid, nm, r) in _RINGS]
    out = []
    for tid, name, evs in rings:
        for e in evs:
            e = dict(e)
            e["thread"] = name
            e["tid"] = tid & 0x7FFFFFFF
            out.append(e)
    out.sort(key=lambda e: e["seq"])
    if limit is not None and limit >= 0:
        out = out[-limit:]
    return out


def clear() -> None:
    """Drop every buffered event (tests); rings stay registered."""
    global _LAST_DUMP, _LAST_DUMP_PATH
    with _LOCK:
        for (_, _, r) in _RINGS:
            r.clear()
    _LAST_DUMP = 0.0
    _LAST_DUMP_PATH = None


_GIT_REV: Optional[str] = None


def _git_rev() -> str:
    """Short revision for the dump filename (cached; "norev" outside a
    checkout).  Deliberately independent of benchmarks/common.py — the
    recorder must work in a bare deployment without the bench package."""
    global _GIT_REV
    if _GIT_REV is None:
        try:
            rev = subprocess.run(
                ["git", "rev-parse", "--short", "HEAD"],
                cwd=os.path.dirname(os.path.abspath(__file__)),
                capture_output=True, text=True, timeout=5,
            ).stdout.strip()
            _GIT_REV = rev or "norev"
        except Exception:
            _GIT_REV = "norev"
    return _GIT_REV


def dump(reason: str, path: Optional[str] = None,
         extra: Optional[dict] = None) -> str:
    """Write the flight buffer + metrics snapshot + SLO verdicts to disk.

    Default path is ``<dump_dir>/FLIGHT_<rev>.json`` — one post-mortem
    per revision, overwritten by later incidents (the newest state is the
    one a responder wants; CI uploads it as an artifact per run).
    """
    global _LAST_DUMP_PATH
    from .export import snapshot  # lazy: flight must import before export

    try:  # lazy + guarded: slo imports flight for its breach callback
        from . import slo as _slo
        slo_block = _slo.verdict_block()
    except Exception:
        slo_block = None
    doc = {
        "schema": 1,
        "reason": reason,
        "ts": time.time(),
        "git_rev": _git_rev(),
        "events": events(),
        "metrics": snapshot(),
        "slo": slo_block,
    }
    if extra:
        doc["extra"] = extra
    if path is None:
        path = os.path.join(_CONFIG.dump_dir, f"FLIGHT_{_git_rev()}.json")
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1)
    _LAST_DUMP_PATH = path
    return path


def auto_dump(reason: str, extra: Optional[dict] = None) -> Optional[str]:
    """Rate-limited :func:`dump` for automatic triggers (SLO breach,
    deadline expiry storm, drain exception).  Returns the path, or None
    when a dump landed less than ``min_dump_interval_s`` ago — an
    incident produces one post-mortem, not one per failing request."""
    global _LAST_DUMP
    now = time.monotonic()
    with _SEQ_LOCK:
        if _LAST_DUMP and now - _LAST_DUMP < _CONFIG.min_dump_interval_s:
            return None
        _LAST_DUMP = now
    record("flight", "auto_dump", reason=reason)
    return dump(reason, extra=extra)


def last_dump_path() -> Optional[str]:
    return _LAST_DUMP_PATH
