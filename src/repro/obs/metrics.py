"""TopoScope metrics registry: process-wide counters, gauges, histograms.

The registry is the *always-on* half of TopoScope (tracing, the opt-in
half, lives in :mod:`repro.obs.trace`): instruments are plain Python
numbers behind per-instrument locks, so recording costs ~a dict lookup
plus a lock — cheap enough that the serving frontends' stats surfaces
(``TopoServe.stats``, ``StreamServe.stats()``, ``SimilarityServe.stats``)
are *views over this registry* rather than ad-hoc dicts, and the bench
runner can stamp kernel call counts into every ``BENCH_<suite>.json``
without flipping any flag.

Label sets (``{"frontend": "topo", "bucket": "n32"}``) key independent
series inside one instrument; values are coerced to ``str``.  There is no
network server anywhere — export is pull-style via
:func:`repro.obs.export.snapshot` / ``export_prometheus(path)``.

Concurrency model: one lock per instrument guards its series dict; the
registry lock only guards instrument creation.  No lock is ever held
while another is taken, so instrument methods cannot deadlock against
registry methods.
"""
from __future__ import annotations

import bisect
import itertools
import threading
from typing import Iterable, Optional

# default duration buckets (seconds): log-spaced from 10 µs to 30 s, the
# span of one kernel dispatch up to a full cold-compile drain
DEFAULT_TIME_BUCKETS = (
    1e-5, 3e-5, 1e-4, 3e-4, 1e-3, 3e-3, 1e-2, 3e-2,
    0.1, 0.3, 1.0, 3.0, 10.0, 30.0,
)
# default buckets for unit-interval ratios (batch occupancy, skip rates)
DEFAULT_RATIO_BUCKETS = (0.1, 0.25, 0.5, 0.75, 0.9, 1.0)

LabelKey = tuple[tuple[str, str], ...]


def _label_key(labels: dict) -> LabelKey:
    return tuple(sorted((str(k), str(v)) for k, v in labels.items()))


class _Instrument:
    """Shared plumbing: name/help, a lock, and a labelset -> state dict."""

    kind = "?"
    __slots__ = ("name", "help", "_lock", "_series")

    def __init__(self, name: str, help: str = ""):
        self.name = name
        self.help = help
        self._lock = threading.Lock()
        self._series: dict = {}

    def clear(self) -> None:
        """Drop every series (tests / registry reset); the instrument stays
        registered so held references keep working."""
        with self._lock:
            self._series.clear()

    def series(self) -> dict:
        """Copy of {label_key: state} under the instrument lock."""
        with self._lock:
            return dict(self._series)

    def labeled(self, label: str) -> dict[str, float]:
        """{value-of-<label>: scalar} across series (counters/gauges)."""
        out: dict[str, float] = {}
        for key, val in self.series().items():
            d = dict(key)
            if label in d:
                out[d[label]] = out.get(d[label], 0.0) + float(val)
        return out


class Counter(_Instrument):
    """Monotone float counter; one series per label set."""

    kind = "counter"
    __slots__ = ()

    def inc(self, amount: float = 1.0, **labels) -> None:
        if amount < 0:
            raise ValueError(f"counter {self.name} cannot decrease "
                             f"(inc {amount})")
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))

    def total(self, **labels) -> float:
        """Sum over every series whose labels are a superset of ``labels``."""
        want = set(_label_key(labels))
        with self._lock:
            return float(sum(v for k, v in self._series.items()
                             if want <= set(k)))


class Gauge(_Instrument):
    """Last-write-wins scalar; ``inc``/``dec`` for up-down counts."""

    kind = "gauge"
    __slots__ = ()

    def set(self, value: float, **labels) -> None:
        with self._lock:
            self._series[_label_key(labels)] = float(value)

    def inc(self, amount: float = 1.0, **labels) -> None:
        key = _label_key(labels)
        with self._lock:
            self._series[key] = self._series.get(key, 0.0) + amount

    def dec(self, amount: float = 1.0, **labels) -> None:
        self.inc(-amount, **labels)

    def value(self, **labels) -> float:
        with self._lock:
            return float(self._series.get(_label_key(labels), 0.0))


class _HistState:
    __slots__ = ("counts", "sum", "count")

    def __init__(self, n_buckets: int):
        self.counts = [0] * (n_buckets + 1)  # +1: overflow (+Inf) bucket
        self.sum = 0.0
        self.count = 0


def bucket_quantile(bounds: tuple[float, ...],
                    counts: Iterable[int], q: float) -> float:
    """Quantile estimate from fixed-bucket counts via linear interpolation.

    ``counts`` has one entry per bound plus the trailing ``+Inf`` overflow
    bucket.  Observations are assumed uniformly distributed inside each
    bucket (the Prometheus ``histogram_quantile`` model); the first
    bucket's lower edge is 0 (or ``bounds[0]`` if that is negative), and a
    quantile landing in the overflow bucket is clamped to the largest
    finite bound — the histogram carries no information beyond it.
    Returns NaN when the histogram is empty.
    """
    counts = list(counts)
    if len(counts) != len(bounds) + 1:
        raise ValueError(
            f"need {len(bounds) + 1} counts (incl. overflow), "
            f"got {len(counts)}")
    total = sum(counts)
    if total <= 0:
        return float("nan")
    q = min(max(float(q), 0.0), 1.0)
    rank = q * total
    acc = 0.0
    lo = min(0.0, bounds[0])
    for b, c in zip(bounds, counts):
        if c and acc + c >= rank:
            return lo + (rank - acc) / c * (b - lo)
        acc += c
        lo = b
    return bounds[-1]


def bucket_count_over(bounds: tuple[float, ...],
                      counts: Iterable[int], threshold: float) -> float:
    """Estimated number of observations strictly above ``threshold``.

    Buckets entirely above the threshold count whole; the bucket
    containing it contributes its linearly interpolated fraction.  This
    is the SLO engine's "bad event" estimator for latency ceilings; the
    threshold should lie within the bucket range — overflow observations
    are not attributed to a threshold beyond the largest finite bound.
    """
    counts = list(counts)
    if len(counts) != len(bounds) + 1:
        raise ValueError(
            f"need {len(bounds) + 1} counts (incl. overflow), "
            f"got {len(counts)}")
    i = bisect.bisect_left(bounds, threshold)
    over = float(sum(counts[i + 1:]))
    if i < len(bounds):
        lo = bounds[i - 1] if i > 0 else min(0.0, bounds[0])
        width = bounds[i] - lo
        if width > 0:
            over += counts[i] * max(0.0, (bounds[i] - threshold) / width)
    return over


class Histogram(_Instrument):
    """Fixed-bucket histogram (Prometheus ``le`` semantics: a value lands
    in the first bucket whose upper bound is >= it; larger values land in
    the implicit ``+Inf`` overflow bucket)."""

    kind = "histogram"
    __slots__ = ("buckets",)

    def __init__(self, name: str, help: str = "",
                 buckets: Iterable[float] = DEFAULT_TIME_BUCKETS):
        super().__init__(name, help)
        bs = tuple(float(b) for b in buckets)
        if not bs or list(bs) != sorted(bs) or len(set(bs)) != len(bs):
            raise ValueError(
                f"histogram {name}: buckets must be non-empty strictly "
                f"ascending upper bounds, got {bs}")
        self.buckets = bs

    def observe(self, value: float, **labels) -> None:
        value = float(value)
        key = _label_key(labels)
        idx = bisect.bisect_left(self.buckets, value)
        with self._lock:
            st = self._series.get(key)
            if st is None:
                st = self._series[key] = _HistState(len(self.buckets))
            st.counts[idx] += 1
            st.sum += value
            st.count += 1

    def quantile(self, q: float, **labels) -> float:
        """Interpolated quantile over every series whose labels are a
        superset of ``labels`` (all series when none given) — see
        :func:`bucket_quantile`.  NaN when nothing matched/observed."""
        want = set(_label_key(labels))
        merged = [0] * (len(self.buckets) + 1)
        for key, st in self.series().items():
            if want <= set(key):
                for i, c in enumerate(st.counts):
                    merged[i] += c
        return bucket_quantile(self.buckets, merged, q)

    def count_over(self, threshold: float, **labels) -> float:
        """Estimated observations above ``threshold`` across matching
        series — see :func:`bucket_count_over`."""
        want = set(_label_key(labels))
        merged = [0] * (len(self.buckets) + 1)
        for key, st in self.series().items():
            if want <= set(key):
                for i, c in enumerate(st.counts):
                    merged[i] += c
        return bucket_count_over(self.buckets, merged, threshold)

    def merged_counts(self, **labels) -> tuple[list[int], float]:
        """(per-bucket counts incl. overflow, total sum) aggregated over
        series whose labels are a superset of ``labels`` — the raw state
        the SLO engine snapshots for windowed quantiles."""
        want = set(_label_key(labels))
        merged = [0] * (len(self.buckets) + 1)
        total_sum = 0.0
        for key, st in self.series().items():
            if want <= set(key):
                for i, c in enumerate(st.counts):
                    merged[i] += c
                total_sum += st.sum
        return merged, total_sum

    def snapshot_series(self) -> dict[LabelKey, dict]:
        """{label_key: {"count", "sum", "buckets": [(le, cumulative), ...]}}
        with cumulative counts (exposition-format semantics) and a final
        ``("+Inf", count)`` entry."""
        out = {}
        for key, st in self.series().items():
            cum, acc = [], 0
            for le, c in zip(self.buckets, st.counts):
                acc += c
                cum.append((le, acc))
            cum.append(("+Inf", st.count))
            out[key] = {"count": st.count, "sum": st.sum, "buckets": cum}
        return out


class MetricsRegistry:
    """Thread-safe name -> instrument map with get-or-create accessors."""

    def __init__(self):
        self._lock = threading.Lock()
        self._instruments: dict[str, _Instrument] = {}

    def _get(self, cls, name: str, help: str, **kwargs):
        with self._lock:
            inst = self._instruments.get(name)
            if inst is None:
                inst = cls(name, help, **kwargs)
                self._instruments[name] = inst
            elif type(inst) is not cls:
                raise TypeError(
                    f"instrument {name!r} already registered as "
                    f"{inst.kind}, not {cls.kind}")
            return inst

    def counter(self, name: str, help: str = "") -> Counter:
        return self._get(Counter, name, help)

    def gauge(self, name: str, help: str = "") -> Gauge:
        return self._get(Gauge, name, help)

    def histogram(self, name: str, help: str = "",
                  buckets: Iterable[float] = DEFAULT_TIME_BUCKETS
                  ) -> Histogram:
        return self._get(Histogram, name, help, buckets=buckets)

    def get(self, name: str) -> Optional[_Instrument]:
        with self._lock:
            return self._instruments.get(name)

    def items(self) -> list[tuple[str, _Instrument]]:
        with self._lock:
            return sorted(self._instruments.items())

    def snapshot(self) -> dict:
        """JSON-ready {name: {"type", "help", "series": [...]}} snapshot.

        Counter/gauge series: ``{"labels": {...}, "value": v}``; histogram
        series additionally carry cumulative ``buckets``/``sum``/``count``.
        """
        out: dict = {}
        for name, inst in self.items():
            if isinstance(inst, Histogram):
                series = [{"labels": dict(k), **st}
                          for k, st in inst.snapshot_series().items()]
            else:
                series = [{"labels": dict(k), "value": v}
                          for k, v in inst.series().items()]
            out[name] = {"type": inst.kind, "help": inst.help,
                         "series": series}
        return out

    def reset(self) -> None:
        """Zero every instrument's series (instruments stay registered, so
        references held by the serving layers keep recording)."""
        for _, inst in self.items():
            inst.clear()


_DEFAULT = MetricsRegistry()


def default_registry() -> MetricsRegistry:
    return _DEFAULT


_INSTANCE_COUNTER = itertools.count()


def next_instance(prefix: str) -> str:
    """Process-unique instance label (``topo-0``, ``stream-1``, ...) so
    multiple frontends share the one registry without mixing series."""
    return f"{prefix}-{next(_INSTANCE_COUNTER)}"
