"""CLI: ``python -m repro.obs report <trace.json> [--top K]``."""
from __future__ import annotations

import argparse
import sys

from .report import report


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="TopoScope trace tooling")
    sub = p.add_subparsers(dest="cmd", required=True)
    rp = sub.add_parser(
        "report",
        help="top-k self-time table with roofline cost cells")
    rp.add_argument("trace", help="Chrome-trace JSON written by "
                                  "repro.obs.export_chrome_trace")
    rp.add_argument("--top", type=int, default=15,
                    help="rows to print (default 15)")
    args = p.parse_args(argv)
    if args.cmd == "report":
        print(report(args.trace, top=args.top))
    return 0


if __name__ == "__main__":
    sys.exit(main())
