"""CLI: trace reports, the live SLO watcher, and one-shot SLO checks.

  python -m repro.obs report <trace.json> [--top K]
  python -m repro.obs watch [--port P] [--interval S] [--duration S]
                            [--demo]
  python -m repro.obs slo check (--url http://host:port | --file slo.json)

``watch`` starts the TopoWatch HTTP exporter in-process, installs the
stock serving SLOs when no engine is installed yet, and prints a verdict
table every interval (``--demo`` additionally spins a small TopoServe
with synthetic traffic so the loop has something to watch).  ``slo
check`` fetches ``/slo`` from a running exporter (or reads a saved
verdict JSON) and exits 1 on any breach — the scriptable alerting hook.
"""
from __future__ import annotations

import argparse
import json
import sys
import time

from .report import report


def _cmd_watch(args) -> int:
    from . import slo as slo_mod
    from .http import start_http_server

    engine = slo_mod.installed()
    if engine is None:
        engine = slo_mod.SLOEngine(slo_mod.default_serve_slos())
        slo_mod.install(engine)
    srv = start_http_server(port=args.port)
    print(f"[watch] exporter at {srv.url} "
          "(/metrics /healthz /readyz /varz /slo /debug/flight)")

    stop_demo = _start_demo() if args.demo else None
    t_end = (time.monotonic() + args.duration
             if args.duration is not None else None)
    try:
        while t_end is None or time.monotonic() < t_end:
            status = engine.tick()
            stamp = time.strftime("%H:%M:%S")
            marks = {"ok": ".", "breach": "!", "no_data": "-"}
            line = " ".join(
                f"{name}={marks.get(v['status'], '?')}"
                for name, v in sorted(status.items()))
            breached = [n for n, v in status.items()
                        if v["status"] == "breach"]
            print(f"[watch {stamp}] {line}"
                  + (f"  BREACH: {breached}" if breached else ""),
                  flush=True)
            time.sleep(args.interval)
    except KeyboardInterrupt:
        pass
    finally:
        if stop_demo is not None:
            stop_demo()
        srv.stop()
    return 0


def _start_demo():
    """Tiny in-process TopoServe + traffic thread for `watch --demo`."""
    import threading

    import numpy as np

    from repro.serve import TopoServe, TopoServeConfig

    # pad_batch_to == max_batch pins one jit shape per bucket, and the
    # synchronous warm round below pays each bucket's compile cost before
    # the watcher ticks — otherwise the demo's latency SLOs breach on
    # compilation, not on anything a real operator should alert on
    server = TopoServe(TopoServeConfig(max_batch=8, pad_batch_to=8))
    for n in (10, 28):  # one graph per bucket the traffic below can hit
        server.submit(edges=[(i, i + 1) for i in range(n - 1)],
                      n_vertices=n)
    server.drain()
    drain = threading.Thread(target=server.serve_forever,
                             name="watch-demo-drain", daemon=True)
    drain.start()
    stop = threading.Event()

    def traffic():
        rng = np.random.default_rng(0)
        while not stop.is_set():
            n = int(rng.integers(5, 30))
            edges = [(int(rng.integers(n)), int(rng.integers(n)))
                     for _ in range(2 * n)]
            edges = [(u, v) for (u, v) in edges if u != v]
            try:
                server.submit(edges=edges, n_vertices=n)
            except ValueError:
                pass  # oversize roll: skip
            stop.wait(0.05)

    gen = threading.Thread(target=traffic, name="watch-demo-traffic",
                           daemon=True)
    gen.start()

    def stop_all():
        stop.set()
        server.stop()
        gen.join(timeout=2)
        drain.join(timeout=2)

    return stop_all


def _cmd_slo_check(args) -> int:
    if args.url:
        from urllib.request import urlopen

        url = args.url.rstrip("/")
        if not url.endswith("/slo"):
            url += "/slo"
        try:
            with urlopen(url, timeout=args.timeout) as resp:
                doc = json.load(resp)
        except Exception as e:
            print(f"[slo check] cannot reach {url}: {e}")
            return 2
    else:
        try:
            with open(args.file) as fh:
                doc = json.load(fh)
        except Exception as e:
            print(f"[slo check] cannot read {args.file}: {e}")
            return 2
    status = doc.get("status", doc)  # accept /slo payloads or bare dicts
    if not status:
        print("[slo check] no SLO engine installed / empty status")
        return 2
    breached = sorted(n for n, v in status.items()
                      if v.get("status") == "breach")
    for name, v in sorted(status.items()):
        print(f"  {v.get('status', '?'):>8}  {name}"
              + (f"  ({v.get('description', '')})"
                 if v.get("description") else ""))
    if breached:
        print(f"[slo check] FAIL: {len(breached)} breached: {breached}")
        return 1
    print(f"[slo check] OK: {len(status)} objectives within budget")
    return 0


def main(argv=None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="TopoScope/TopoWatch tooling")
    sub = p.add_subparsers(dest="cmd", required=True)

    rp = sub.add_parser(
        "report",
        help="top-k self-time table with roofline cost cells")
    rp.add_argument("trace", help="Chrome-trace JSON written by "
                                  "repro.obs.export_chrome_trace")
    rp.add_argument("--top", type=int, default=15,
                    help="rows to print (default 15)")

    wp = sub.add_parser(
        "watch", help="live SLO watcher + TopoWatch HTTP exporter")
    wp.add_argument("--port", type=int, default=9464,
                    help="exporter port (0 = ephemeral; default 9464)")
    wp.add_argument("--interval", type=float, default=2.0,
                    help="seconds between SLO ticks (default 2)")
    wp.add_argument("--duration", type=float, default=None,
                    help="exit after this many seconds (default: run "
                         "until Ctrl-C)")
    wp.add_argument("--demo", action="store_true",
                    help="also run a demo TopoServe with synthetic "
                         "traffic")

    sp = sub.add_parser("slo", help="SLO verdict tooling")
    sp.add_argument("action", choices=["check"],
                    help="check: exit 1 on any breached objective")
    sp.add_argument("--url", default=None,
                    help="base URL (or /slo URL) of a running exporter")
    sp.add_argument("--file", default=None,
                    help="saved /slo JSON payload to check instead")
    sp.add_argument("--timeout", type=float, default=5.0)

    args = p.parse_args(argv)
    if args.cmd == "report":
        print(report(args.trace, top=args.top))
        return 0
    if args.cmd == "watch":
        return _cmd_watch(args)
    if args.cmd == "slo":
        if not args.url and not args.file:
            p.error("slo check needs --url or --file")
        return _cmd_slo_check(args)
    return 0


if __name__ == "__main__":
    sys.exit(main())
