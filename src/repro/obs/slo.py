"""TopoWatch SLO engine: declarative objectives + multi-window burn rates.

An :class:`SLOSpec` declares one objective over the TopoScope registry —
a per-bucket latency ceiling, an error-rate or deadline-miss budget, a
stream skip-rate floor, or a static recall floor read from a committed
bench baseline.  The :class:`SLOEngine` snapshots the relevant counters
on every ``tick()`` and evaluates each spec with **multi-window
burn-rate rules** (the SRE alerting pattern): the budget-consumption
rate is computed over a long and a short window, and the SLO only fires
when *both* exceed the rule's factor — the long window proves the
problem is real, the short window proves it is still happening, so a
transient blip neither fires nor masks an ongoing burn.

Burn rate 1.0 means "consuming exactly the whole error budget at a
sustained rate"; a factor above 1 alerts on faster-than-budget burns.

Verdicts surface four ways, all fed by the same ``tick()``:

- :func:`slo_status` / ``SLOEngine.status()`` — JSON-ready dicts;
- Prometheus gauges ``slo.burn_rate{slo,window}``, ``slo.status{slo}``
  and the counter ``slo.breaches_total{slo}`` (scraped via
  :mod:`repro.obs.http`, stamped into bench telemetry, and gated
  ``abs_upper`` by PerfGate);
- a breach callback (default :func:`repro.obs.flight.auto_dump`) so
  every new breach leaves a flight-recorder post-mortem;
- ``python -m repro.obs watch`` / ``slo check`` CLIs.
"""
from __future__ import annotations

import dataclasses
import json
import threading
import time
from collections import deque
from typing import Callable, Optional, Sequence

from . import flight
from .metrics import (
    Counter,
    Gauge,
    Histogram,
    MetricsRegistry,
    bucket_count_over,
    bucket_quantile,
    default_registry,
)

KINDS = ("latency", "error_rate", "ratio_floor", "value_floor")

_G_BURN = default_registry().gauge(
    "slo.burn_rate", help="per-SLO budget burn rate per rule window")
_G_STATUS = default_registry().gauge(
    "slo.status", help="per-SLO verdict: 0 ok, 1 breach, -1 no_data")
_C_BREACH = default_registry().counter(
    "slo.breaches_total",
    help="ok->breach verdict transitions per SLO (gated abs_upper by "
         "PerfGate: a gate run with any breach fails)")


@dataclasses.dataclass(frozen=True)
class BurnRule:
    """One multi-window burn-rate rule: fire when the burn rate exceeds
    ``factor`` over BOTH the long and the short window."""

    long_s: float
    short_s: float
    factor: float = 1.0

    def __post_init__(self):
        if not (self.short_s > 0 and self.long_s >= self.short_s):
            raise ValueError(
                f"need long_s >= short_s > 0, got {self.long_s}/"
                f"{self.short_s}")


# Default pair: a fast rule for sharp burns and a slow one for sustained
# slow leaks.  Windows are short by production standards because the
# serving stack's unit of traffic is a drain (~ms-seconds), not minutes.
DEFAULT_RULES = (BurnRule(long_s=60.0, short_s=5.0, factor=1.0),
                 BurnRule(long_s=300.0, short_s=30.0, factor=0.5))


@dataclasses.dataclass(frozen=True)
class SLOSpec:
    """One declarative objective over registry instruments.

    Kinds (``budget`` is always the allowed *bad fraction* of events):

    - ``latency`` — ``histogram`` + ``quantile`` + ``ceiling_s``: "the
      q-quantile stays under the ceiling".  Bad events are observations
      above the ceiling (bucket-interpolated); the budget defaults to
      ``1 - quantile`` (a p99 ceiling allows 1% over).
    - ``error_rate`` — ``bad``/``total`` counter names: bad-event
      fraction must stay within ``budget``.
    - ``ratio_floor`` — ``good``/``total`` counter names + ``floor``:
      the good fraction must stay >= ``floor`` (bad = total - good,
      budget = 1 - floor).  Stream skip-rate floors use this.
    - ``value_floor`` — ``value_from`` (``"bench:<suite>:<benchmark>.
      <metric>"`` over a committed ``BENCH_<suite>.json``, or
      ``"gauge:<name>"``) + ``floor``: a static, un-windowed check
      (recall floors from bench telemetry).

    ``labels`` filters instrument series by label subset, e.g.
    ``(("bucket", "n32"),)`` for a per-bucket latency objective.
    """

    name: str
    kind: str
    description: str = ""
    # latency
    histogram: str = ""
    quantile: float = 0.99
    ceiling_s: float = 0.0
    # error_rate / ratio_floor: counter names + per-selector extra labels
    # (merged over ``labels``) — the stream skip-rate good/total pair
    # lives in ONE counter split by a ``key`` label, so each side needs
    # its own filter
    bad: str = ""
    good: str = ""
    total: str = ""
    bad_labels: tuple[tuple[str, str], ...] = ()
    good_labels: tuple[tuple[str, str], ...] = ()
    total_labels: tuple[tuple[str, str], ...] = ()
    # shared
    labels: tuple[tuple[str, str], ...] = ()
    budget: Optional[float] = None
    floor: float = 0.0
    value_from: str = ""
    rules: tuple[BurnRule, ...] = DEFAULT_RULES

    def __post_init__(self):
        if self.kind not in KINDS:
            raise ValueError(f"unknown SLO kind {self.kind!r}; want {KINDS}")
        if self.kind == "latency" and (not self.histogram
                                       or self.ceiling_s <= 0):
            raise ValueError(f"latency SLO {self.name!r} needs histogram "
                             "and ceiling_s > 0")
        if self.kind == "error_rate" and not (self.bad and self.total):
            raise ValueError(f"error_rate SLO {self.name!r} needs bad/total "
                             "counter names")
        if self.kind == "ratio_floor" and not (self.good and self.total):
            raise ValueError(f"ratio_floor SLO {self.name!r} needs "
                             "good/total counter names")
        if self.kind == "value_floor" and not self.value_from:
            raise ValueError(f"value_floor SLO {self.name!r} needs "
                             "value_from")
        if not self.rules and self.kind != "value_floor":
            raise ValueError(f"SLO {self.name!r} needs at least one "
                             "BurnRule")

    @property
    def bad_budget(self) -> float:
        """Allowed bad-event fraction (>0; a zero budget would make burn
        rate undefined — use an abs_upper PerfGate row for hard zeros)."""
        if self.budget is not None:
            b = self.budget
        elif self.kind == "latency":
            b = 1.0 - self.quantile
        elif self.kind == "ratio_floor":
            b = 1.0 - self.floor
        else:
            b = 0.01
        return max(float(b), 1e-9)


# ------------------------------------------------------------------ engine

class SLOEngine:
    """Snapshot ring + evaluator over one metrics registry.

    ``tick()`` is the only mutator: capture a snapshot, evaluate every
    spec's rules against the windowed deltas, update the Prom surfaces,
    count ok->breach transitions, and invoke ``on_breach`` for each new
    breach.  ``clock`` is injectable so tests drive synthetic time.
    """

    def __init__(self, specs: Sequence[SLOSpec],
                 registry: Optional[MetricsRegistry] = None,
                 clock: Callable[[], float] = time.monotonic,
                 on_breach: Optional[Callable[[str, dict],
                                              Optional[str]]] = None,
                 bench_dir: str = "results"):
        names = [s.name for s in specs]
        if len(set(names)) != len(names):
            raise ValueError(f"duplicate SLO names in {names}")
        self.specs = tuple(specs)
        self.registry = registry or default_registry()
        self.clock = clock
        self.on_breach = (on_breach if on_breach is not None
                          else lambda name, v: flight.auto_dump(
                              f"slo_breach:{name}", extra={"verdict": v}))
        self.bench_dir = bench_dir
        self._lock = threading.Lock()
        self._history: deque[tuple[float, dict]] = deque()
        self._last_status: dict[str, dict] = {}
        self._bench_cache: dict[str, Optional[dict]] = {}

    # ------------------------------------------------------------ capture

    def _capture(self) -> dict:
        """{spec.name: state} — cumulative (bad, total) pairs or raw
        histogram bucket counts, per spec, at this instant."""
        snap: dict[str, object] = {}
        for spec in self.specs:
            labels = dict(spec.labels)
            if spec.kind == "latency":
                inst = self.registry.get(spec.histogram)
                if isinstance(inst, Histogram):
                    counts, _ = inst.merged_counts(**labels)
                    snap[spec.name] = (tuple(inst.buckets), tuple(counts))
                else:
                    snap[spec.name] = None
            elif spec.kind in ("error_rate", "ratio_floor"):
                if spec.kind == "error_rate":
                    first_name, first_extra = spec.bad, spec.bad_labels
                else:
                    first_name, first_extra = spec.good, spec.good_labels
                snap[spec.name] = (
                    self._counter_total(first_name,
                                        {**labels, **dict(first_extra)}),
                    self._counter_total(spec.total,
                                        {**labels,
                                         **dict(spec.total_labels)}))
            else:  # value_floor: stateless, evaluated directly
                snap[spec.name] = None
        return snap

    def _counter_total(self, name: str, labels: dict) -> float:
        inst = self.registry.get(name)
        if isinstance(inst, Counter):
            return inst.total(**labels)
        return 0.0

    # ----------------------------------------------------------- evaluate

    def _window_state(self, name: str, now: float, window_s: float):
        """The buffered state closest to (and at least as old as)
        ``now - window_s``; falls back to the oldest snapshot when the
        history is younger than the window."""
        target = now - window_s
        chosen = None
        for (t, snap) in self._history:  # oldest -> newest
            if t <= target:
                chosen = (t, snap.get(name))
            else:
                break
        if chosen is None and self._history:
            t0, snap0 = self._history[0]
            chosen = (t0, snap0.get(name))
        return chosen

    @staticmethod
    def _bad_total(spec: SLOSpec, state) -> tuple[float, float]:
        """Cumulative (bad, total) events from one captured state."""
        if state is None:
            return 0.0, 0.0
        if spec.kind == "latency":
            bounds, counts = state
            total = float(sum(counts))
            return bucket_count_over(bounds, counts, spec.ceiling_s), total
        first, total = state
        if spec.kind == "error_rate":
            return float(first), float(total)
        # ratio_floor: first is the GOOD count
        return float(total) - float(first), float(total)

    def _burn(self, spec: SLOSpec, name: str, now: float,
              window_s: float) -> tuple[Optional[float], float]:
        """(burn rate over the window, total events in window); burn is
        None when the window saw no events."""
        past = self._window_state(name, now, window_s)
        cur = self._history[-1][1].get(name) if self._history else None
        bad1, tot1 = self._bad_total(spec, cur)
        bad0, tot0 = self._bad_total(spec, past[1]) if past else (0.0, 0.0)
        d_bad, d_tot = bad1 - bad0, tot1 - tot0
        if d_tot <= 0:
            return None, 0.0
        return (d_bad / d_tot) / spec.bad_budget, d_tot

    def _eval_value_floor(self, spec: SLOSpec) -> dict:
        value = self._static_value(spec.value_from)
        if value is None:
            return {"status": "no_data", "value": None, "floor": spec.floor}
        return {"status": "breach" if value < spec.floor else "ok",
                "value": value, "floor": spec.floor}

    def _static_value(self, src: str) -> Optional[float]:
        try:
            scheme, rest = src.split(":", 1)
        except ValueError:
            return None
        if scheme == "gauge":
            inst = self.registry.get(rest)
            if isinstance(inst, Gauge):
                series = inst.series()
                return float(next(iter(series.values()))) if series else None
            return None
        if scheme == "bench":
            suite, key = rest.split(":", 1)
            bench, metric = key.rsplit(".", 1)
            payload = self._bench_cache.get(suite)
            if suite not in self._bench_cache:
                try:
                    with open(f"{self.bench_dir}/BENCH_{suite}.json") as fh:
                        payload = json.load(fh)
                except Exception:
                    payload = None
                self._bench_cache[suite] = payload
            if not payload:
                return None
            for row in payload.get("rows", ()):
                if (row.get("benchmark"), row.get("metric")) == (bench,
                                                                 metric):
                    return float(row["value"])
        return None

    def _evaluate(self, now: float) -> dict[str, dict]:
        out: dict[str, dict] = {}
        for spec in self.specs:
            if spec.kind == "value_floor":
                verdict = self._eval_value_floor(spec)
                verdict.update(slo=spec.name, kind=spec.kind,
                               description=spec.description)
                out[spec.name] = verdict
                continue
            rules_out, firing, any_data = [], False, False
            for rule in spec.rules:
                burn_l, n_l = self._burn(spec, spec.name, now, rule.long_s)
                burn_s, n_s = self._burn(spec, spec.name, now, rule.short_s)
                fired = (burn_l is not None and burn_s is not None
                         and burn_l >= rule.factor and burn_s >= rule.factor)
                firing = firing or fired
                any_data = any_data or burn_l is not None
                rules_out.append({
                    "long_s": rule.long_s, "short_s": rule.short_s,
                    "factor": rule.factor, "burn_long": burn_l,
                    "burn_short": burn_s, "events_long": n_l,
                    "fired": fired,
                })
                _G_BURN.set(burn_l if burn_l is not None else -1.0,
                            slo=spec.name, window=f"{rule.long_s:g}s")
            verdict = {
                "slo": spec.name, "kind": spec.kind,
                "description": spec.description,
                "status": ("breach" if firing
                           else "ok" if any_data else "no_data"),
                "budget": spec.bad_budget,
                "rules": rules_out,
            }
            if spec.kind == "latency":
                state = (self._history[-1][1].get(spec.name)
                         if self._history else None)
                if state is not None:
                    bounds, counts = state
                    verdict["quantile"] = spec.quantile
                    verdict["ceiling_s"] = spec.ceiling_s
                    verdict["observed_q_s"] = bucket_quantile(
                        bounds, counts, spec.quantile)
            out[spec.name] = verdict
        return out

    # --------------------------------------------------------------- tick

    def tick(self, now: Optional[float] = None) -> dict[str, dict]:
        """Capture + evaluate; returns {slo name: verdict dict}."""
        if now is None:
            now = self.clock()
        with self._lock:
            self._history.append((now, self._capture()))
            horizon = max((r.long_s for s in self.specs for r in s.rules),
                          default=60.0)
            while (len(self._history) > 2
                   and self._history[1][0] <= now - horizon):
                self._history.popleft()
            status = self._evaluate(now)
            prev = self._last_status
            self._last_status = status
        for name, verdict in status.items():
            st = verdict["status"]
            _G_STATUS.set({"ok": 0, "breach": 1}.get(st, -1), slo=name)
            was = prev.get(name, {}).get("status")
            if st == "breach" and was != "breach":
                _C_BREACH.inc(slo=name)
                flight.record("slo", name, status="breach",
                              slo_kind=verdict["kind"])
                if self.on_breach is not None:
                    try:
                        self.on_breach(name, verdict)
                    except Exception:
                        pass  # a broken dump hook must not kill the loop
            elif st == "ok" and was == "breach":
                flight.record("slo", name, status="recovered")
        return status

    def status(self) -> dict[str, dict]:
        """Last ``tick()`` verdicts (empty before the first tick)."""
        with self._lock:
            return dict(self._last_status)

    def breached(self) -> list[str]:
        return [n for n, v in self.status().items()
                if v.get("status") == "breach"]


# ------------------------------------------------------- default objectives

def default_serve_slos(latency_p99_s: float = 2.0,
                       latency_p50_s: float = 0.5,
                       error_budget: float = 0.01,
                       deadline_budget: float = 0.01,
                       skip_rate_floor: float = 0.5,
                       recall_floor: float = 0.95,
                       buckets: Sequence[str] = ("n16", "n32", "n64",
                                                 "n128"),
                       rules: tuple[BurnRule, ...] = DEFAULT_RULES,
                       ) -> tuple[SLOSpec, ...]:
    """The serving stack's stock objectives (tune per deployment).

    Per-bucket p50/p99 latency ceilings over the request-latency
    histogram, an error-rate and a deadline-miss budget over the serve
    counters, a stream skip-rate floor (the cache-effectiveness
    contract), and a static retrieval-recall floor read from the
    committed metrics bench baseline.
    """
    specs: list[SLOSpec] = []
    for lbl in buckets:
        specs.append(SLOSpec(
            name=f"serve-latency-p99-{lbl}", kind="latency",
            histogram="serve.request_latency_seconds",
            quantile=0.99, ceiling_s=latency_p99_s,
            labels=(("bucket", lbl),), rules=rules,
            description=f"bucket {lbl}: p99 submit->resolve latency "
                        f"<= {latency_p99_s:g}s"))
        specs.append(SLOSpec(
            name=f"serve-latency-p50-{lbl}", kind="latency",
            histogram="serve.request_latency_seconds",
            quantile=0.5, ceiling_s=latency_p50_s,
            labels=(("bucket", lbl),), rules=rules,
            description=f"bucket {lbl}: p50 submit->resolve latency "
                        f"<= {latency_p50_s:g}s"))
    specs += [
        SLOSpec(name="serve-error-rate", kind="error_rate",
                bad="serve.failed", total="serve.submitted",
                budget=error_budget, rules=rules,
                description="failed futures / submitted requests"),
        SLOSpec(name="serve-deadline-miss", kind="error_rate",
                bad="serve.deadline_exceeded", total="serve.submitted",
                budget=deadline_budget, rules=rules,
                description="requests expired in queue / submitted"),
        SLOSpec(name="stream-skip-rate", kind="ratio_floor",
                good="stream.steps", good_labels=(("key", "hits"),),
                total="stream.steps",
                total_labels=(("key", "graph_updates"),),
                floor=skip_rate_floor, rules=rules,
                description="certified update skips / graph updates"),
        SLOSpec(name="rerank-recall", kind="value_floor",
                value_from="bench:metrics:metrics_rerank.recall_at_10",
                floor=recall_floor,
                description="two-stage retrieval recall@10 from the "
                            "committed bench baseline"),
    ]
    return tuple(specs)


# ----------------------------------------------------------- installation

_INSTALLED: Optional[SLOEngine] = None
_INSTALL_LOCK = threading.Lock()


def install(engine: Optional[SLOEngine]) -> Optional[SLOEngine]:
    """Make ``engine`` the process-wide engine surfaced by
    :func:`slo_status`, ``/slo``, and the CLIs; returns the previous one.
    Pass None to uninstall."""
    global _INSTALLED
    with _INSTALL_LOCK:
        prev, _INSTALLED = _INSTALLED, engine
    return prev


def installed() -> Optional[SLOEngine]:
    return _INSTALLED


def slo_status(tick: bool = True) -> dict[str, dict]:
    """Verdicts of the installed engine ({} when none installed);
    ``tick=True`` re-evaluates first so scrapes always see fresh state."""
    eng = _INSTALLED
    if eng is None:
        return {}
    return eng.tick() if tick else eng.status()


def verdict_block() -> dict:
    """JSON block for reports (GATE_report.json, flight dumps): installed
    flag, per-SLO verdicts, and the cumulative breach counter."""
    eng = _INSTALLED
    breaches = _C_BREACH.labeled("slo")
    return {
        "installed": eng is not None,
        "status": eng.status() if eng is not None else {},
        "breaches_total": int(sum(breaches.values())),
        "breaches_by_slo": {k: int(v) for k, v in sorted(breaches.items())},
    }
