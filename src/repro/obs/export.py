"""TopoScope exporters: snapshot dict, JSON-lines append, Prometheus text.

No network server — everything is file/pull based.  ``snapshot()`` gives
a JSON-ready dict of every instrument, ``append_jsonl(path)`` appends one
timestamped snapshot line (suitable for a poor-man's time series), and
``export_prometheus(path)`` / ``prometheus_text()`` render the standard
text exposition format (counters as ``<name>_total``, histograms with
cumulative ``le`` buckets plus ``_sum``/``_count``), ready to be scraped
off disk by a node-exporter textfile collector.
"""
from __future__ import annotations

import json
import os
import re
import time
from typing import Optional

from .metrics import Counter, Gauge, Histogram, MetricsRegistry, \
    default_registry

_NAME_RE = re.compile(r"[^a-zA-Z0-9_:]")
_LABEL_RE = re.compile(r"[^a-zA-Z0-9_]")


def _metric_name(name: str) -> str:
    out = _NAME_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _label_name(name: str) -> str:
    out = _LABEL_RE.sub("_", name)
    if out and out[0].isdigit():
        out = "_" + out
    return out


def _escape(value: str) -> str:
    return value.replace("\\", "\\\\").replace("\"", "\\\"") \
        .replace("\n", "\\n")


def _render_labels(labels: dict, extra: Optional[dict] = None) -> str:
    merged = dict(labels)
    if extra:
        merged.update(extra)
    if not merged:
        return ""
    inner = ",".join(f'{_label_name(k)}="{_escape(str(v))}"'
                     for k, v in sorted(merged.items()))
    return "{" + inner + "}"


def _fmt(v: float) -> str:
    f = float(v)
    if f == int(f) and abs(f) < 1e15:
        return str(int(f))
    return repr(f)


def snapshot(registry: Optional[MetricsRegistry] = None) -> dict:
    """JSON-ready snapshot of every instrument in the registry."""
    reg = registry or default_registry()
    return reg.snapshot()


def append_jsonl(path: str,
                 registry: Optional[MetricsRegistry] = None) -> str:
    """Append one ``{"ts": <unix seconds>, "metrics": snapshot}`` line."""
    line = {"ts": time.time(), "metrics": snapshot(registry)}
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "a") as fh:
        fh.write(json.dumps(line) + "\n")
    return path


def prometheus_text(registry: Optional[MetricsRegistry] = None) -> str:
    """Render the registry in Prometheus text exposition format v0.0.4."""
    reg = registry or default_registry()
    lines: list[str] = []
    for name, inst in reg.items():
        base = _metric_name(name)
        if isinstance(inst, Counter):
            base += "_total"
        if inst.help:
            lines.append(f"# HELP {base} {_escape(inst.help)}")
        lines.append(f"# TYPE {base} {inst.kind}")
        if isinstance(inst, Histogram):
            for key, st in sorted(inst.snapshot_series().items()):
                labels = dict(key)
                for le, cum in st["buckets"]:
                    le_s = "+Inf" if le == "+Inf" else _fmt(le)
                    lines.append(
                        f"{base}_bucket"
                        f"{_render_labels(labels, {'le': le_s})} {cum}")
                lines.append(
                    f"{base}_sum{_render_labels(labels)} {repr(st['sum'])}")
                lines.append(
                    f"{base}_count{_render_labels(labels)} {st['count']}")
        else:
            for key, val in sorted(inst.series().items()):
                lines.append(
                    f"{base}{_render_labels(dict(key))} {_fmt(val)}")
    return "\n".join(lines) + "\n"


def export_prometheus(path: str,
                      registry: Optional[MetricsRegistry] = None) -> str:
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        fh.write(prometheus_text(registry))
    return path
