"""TopoScope tracing: nestable spans -> Chrome-trace (Perfetto) JSON.

Tracing is the *opt-in* half of TopoScope and is off by default: until
``configure(enabled=True)`` is called (or the process starts with
``REPRO_OBS=1``), ``span(...)`` returns a shared stateless no-op context
manager — the disabled path is one module attribute read plus a call,
bounded <1 µs/span by ``tests/test_obs.py`` so serving numbers are
unaffected.

When enabled, each span records a complete ("ph": "X") Chrome-trace
event with microsecond timestamps relative to a process epoch, the
owning thread id, its parent span name, and arbitrary attributes
(``span("serve.batch", bucket="n32")`` or ``sp.set(graphs=7)`` from
inside the block).  Nesting is tracked per thread via a thread-local
span stack.  Every completed span also feeds the ``obs.span_seconds``
duration histogram in the metrics registry, so traces and metrics never
disagree about where time went.

``span(..., jax_profiler=True)`` additionally brackets the block with
``jax.profiler.start_trace/stop_trace`` for XLA-level deep dives; the
profile lands under the configured ``jax_trace_dir``.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Optional

from . import flight as _flight
from .context import current as _current_context
from .metrics import DEFAULT_TIME_BUCKETS, default_registry


class _Config:
    __slots__ = ("enabled", "capacity", "jax_trace_dir")

    def __init__(self):
        self.enabled = os.environ.get("REPRO_OBS", "").strip() not in (
            "", "0", "false", "off")
        self.capacity = 200_000
        self.jax_trace_dir = os.environ.get(
            "REPRO_OBS_JAX_DIR", "results/jax_trace")


_CONFIG = _Config()

# trace buffer: list of Chrome-trace event dicts + overflow accounting
_EVENTS: list[dict] = []
_EVENTS_LOCK = threading.Lock()
_DROPPED = 0

_TLS = threading.local()  # .stack: list of active Span objects
_EPOCH = time.perf_counter()
_PID = os.getpid()

# spans auto-feed this histogram (one series per span name) when enabled
_SPAN_SECONDS = default_registry().histogram(
    "obs.span_seconds", help="TopoScope span durations by span name",
    buckets=DEFAULT_TIME_BUCKETS)


def configure(enabled: Optional[bool] = None,
              capacity: Optional[int] = None,
              jax_trace_dir: Optional[str] = None) -> None:
    """Flip tracing on/off and tune the event buffer.

    Metrics instruments are unaffected — they are always live.  Only
    span recording (and the span->histogram feed) is gated.
    """
    if enabled is not None:
        _CONFIG.enabled = bool(enabled)
    if capacity is not None:
        if capacity < 1:
            raise ValueError("capacity must be >= 1")
        _CONFIG.capacity = int(capacity)
    if jax_trace_dir is not None:
        _CONFIG.jax_trace_dir = jax_trace_dir


def enabled() -> bool:
    return _CONFIG.enabled


def _stack() -> list:
    st = getattr(_TLS, "stack", None)
    if st is None:
        st = _TLS.stack = []
    return st


class _NoopSpan:
    """Singleton returned by span() while tracing is disabled."""

    __slots__ = ()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False

    def set(self, **attrs):
        return self


_NOOP = _NoopSpan()


class Span:
    """Live span; created by :func:`span` only while tracing is enabled."""

    __slots__ = ("name", "attrs", "parent", "_t0", "_jax", "_jax_active")

    def __init__(self, name: str, attrs: dict, jax_profiler: bool):
        self.name = name
        self.attrs = attrs
        self.parent: Optional[str] = None
        self._t0 = 0.0
        self._jax = jax_profiler
        self._jax_active = False

    def set(self, **attrs) -> "Span":
        """Attach attributes from inside the block (end-of-span facts like
        candidate counts)."""
        self.attrs.update(attrs)
        return self

    def __enter__(self) -> "Span":
        st = _stack()
        if st:
            self.parent = st[-1].name
        st.append(self)
        if self._jax:
            try:
                import jax
                os.makedirs(_CONFIG.jax_trace_dir, exist_ok=True)
                jax.profiler.start_trace(_CONFIG.jax_trace_dir)
                self._jax_active = True
            except Exception:
                self._jax_active = False
        self._t0 = time.perf_counter()
        return self

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.perf_counter()
        if self._jax_active:
            try:
                import jax
                jax.profiler.stop_trace()
            except Exception:
                pass
        st = _stack()
        if st and st[-1] is self:
            st.pop()
        dur = t1 - self._t0
        args: dict[str, Any] = {}
        if self.parent is not None:
            args["parent"] = self.parent
        if exc_type is not None:
            args["error"] = exc_type.__name__
        for k, v in self.attrs.items():
            args[k] = v if isinstance(v, (int, float, bool, str)) else str(v)
        event = {
            "name": self.name,
            "cat": self.name.split(".", 1)[0],
            "ph": "X",
            "ts": (self._t0 - _EPOCH) * 1e6,
            "dur": dur * 1e6,
            "pid": _PID,
            "tid": threading.get_ident() & 0x7FFFFFFF,
            "args": args,
        }
        global _DROPPED
        with _EVENTS_LOCK:
            if len(_EVENTS) < _CONFIG.capacity:
                _EVENTS.append(event)
            else:
                _DROPPED += 1
        _SPAN_SECONDS.observe(dur, span=self.name)
        # completed spans also feed the always-on flight recorder ring
        # (the recorder additionally gets explicit drain-level records,
        # so it stays useful with tracing off)
        _flight.record("span", self.name, dur_ms=round(dur * 1e3, 3),
                       **({"error": exc_type.__name__}
                          if exc_type is not None else {}))
        return False


def span(name: str, jax_profiler: bool = False, **attrs):
    """Open a nestable trace span; usable as a context manager.

    Disabled path returns a shared no-op (no allocation beyond the
    kwargs dict at the call site).
    """
    if not _CONFIG.enabled:
        return _NOOP
    ctx = _current_context()
    if ctx is not None and "rid" not in attrs:
        # request-context propagation: every span opened under a
        # request_context() carries the request id
        attrs["rid"] = ctx.request_id
    return Span(name, attrs, jax_profiler)


def current_span() -> Optional[Span]:
    """The innermost active span on this thread (None when outside any
    span or tracing is disabled)."""
    st = getattr(_TLS, "stack", None)
    return st[-1] if st else None


def trace_events() -> list[dict]:
    """Copy of the buffered Chrome-trace events."""
    with _EVENTS_LOCK:
        return list(_EVENTS)


def dropped_events() -> int:
    with _EVENTS_LOCK:
        return _DROPPED


def clear_trace() -> None:
    global _DROPPED
    with _EVENTS_LOCK:
        _EVENTS.clear()
        _DROPPED = 0


def export_chrome_trace(path: str) -> str:
    """Write buffered spans as a Chrome-trace JSON object — loadable in
    Perfetto (https://ui.perfetto.dev) or chrome://tracing."""
    events = sorted(trace_events(), key=lambda e: (e["tid"], e["ts"]))
    doc = {
        "displayTimeUnit": "ms",
        "otherData": {"producer": "repro.obs", "dropped": dropped_events()},
        "traceEvents": events,
    }
    d = os.path.dirname(path)
    if d:
        os.makedirs(d, exist_ok=True)
    with open(path, "w") as fh:
        json.dump(doc, fh)
    return path
