"""TopoWatch request context: per-request ids + deadlines via contextvars.

Every serve frontend's ``submit()`` mints a :class:`RequestContext` (or
adopts the ambient one installed by :func:`request_context`) and stamps
its ``request_id``/``deadline`` onto the returned future; ``span()``
picks the ambient context up automatically so every trace event of a
request carries its ``rid``.  Deadlines are *absolute* ``time.monotonic``
instants — the drain-side sweep compares against one clock regardless of
which thread executes the batch.

The context is asyncio-safe and thread-inheriting-free by construction
(``contextvars``): a drain thread never sees the submitter's context
unless it opts in, so batch-side spans attribute to the batch, not to
whichever request happened to submit last.
"""
from __future__ import annotations

import contextlib
import contextvars
import dataclasses
import itertools
import os
import time
from typing import Iterator, Optional


class DeadlineExceeded(TimeoutError):
    """A request's deadline passed before a drain could execute it.

    Raised *through the future* (``fut.result()``) by the drain-side
    deadline sweep — the request is dropped from its queue, never
    executed, and counted in ``serve.deadline_exceeded`` per bucket.
    """


@dataclasses.dataclass(frozen=True)
class RequestContext:
    """One request's identity + time budget.

    ``deadline`` is an absolute ``time.monotonic()`` instant (None = no
    deadline).  ``attrs`` are free-form key/value pairs propagated into
    spans opened under this context.
    """

    request_id: str
    deadline: Optional[float] = None
    attrs: tuple[tuple[str, str], ...] = ()

    def remaining_s(self) -> Optional[float]:
        """Seconds until the deadline (negative once expired); None when
        the request has no deadline."""
        if self.deadline is None:
            return None
        return self.deadline - time.monotonic()

    def expired(self) -> bool:
        return self.deadline is not None and time.monotonic() >= self.deadline


_CTX: contextvars.ContextVar[Optional[RequestContext]] = \
    contextvars.ContextVar("repro_obs_request_context", default=None)

_RID_COUNTER = itertools.count()
_RID_PREFIX = f"{os.getpid() & 0xFFFF:04x}"


def new_request_id(prefix: str = "r") -> str:
    """Process-unique request id (``r-<pid16>-<seq>``); cheap enough to
    mint on every submit."""
    return f"{prefix}-{_RID_PREFIX}-{next(_RID_COUNTER)}"


def deadline_in(timeout_s: Optional[float]) -> Optional[float]:
    """Relative timeout -> absolute monotonic deadline (None passes through)."""
    if timeout_s is None:
        return None
    return time.monotonic() + float(timeout_s)


def current() -> Optional[RequestContext]:
    """The ambient request context of this thread/task, or None."""
    return _CTX.get()


def current_request_id() -> Optional[str]:
    ctx = _CTX.get()
    return ctx.request_id if ctx is not None else None


@contextlib.contextmanager
def request_context(request_id: Optional[str] = None,
                    deadline_s: Optional[float] = None,
                    **attrs) -> Iterator[RequestContext]:
    """Install an ambient request context for the enclosed block.

    ``submit()`` calls made inside the block adopt this id/deadline
    instead of minting fresh ones, and every ``obs.span`` opened inside
    carries ``rid=<request_id>`` — so one client call threads a single
    identity through submit, drain spans, and the resolved future.

    Nesting: an inner ``request_context()`` without an explicit
    ``deadline_s`` inherits the outer deadline (a sub-operation can never
    outlive its parent's budget); an explicit inner deadline is clamped
    to the outer one.
    """
    outer = _CTX.get()
    if request_id is None:
        request_id = new_request_id()
    deadline = deadline_in(deadline_s)
    if outer is not None and outer.deadline is not None:
        deadline = (outer.deadline if deadline is None
                    else min(deadline, outer.deadline))
    ctx = RequestContext(
        request_id=request_id, deadline=deadline,
        attrs=tuple(sorted((str(k), str(v)) for k, v in attrs.items())))
    token = _CTX.set(ctx)
    try:
        yield ctx
    finally:
        _CTX.reset(token)


def resolve_submit(request_id: Optional[str],
                   deadline_s: Optional[float]
                   ) -> tuple[str, Optional[float]]:
    """The (request_id, absolute deadline) a ``submit()`` should stamp.

    Explicit arguments win; otherwise the ambient :func:`request_context`
    supplies both; otherwise a fresh id with no deadline is minted.  An
    explicit relative ``deadline_s`` is still clamped to an ambient
    deadline when one exists.
    """
    ctx = _CTX.get()
    if request_id is None:
        request_id = ctx.request_id if ctx is not None else new_request_id()
    deadline = deadline_in(deadline_s)
    if ctx is not None and ctx.deadline is not None:
        deadline = (ctx.deadline if deadline is None
                    else min(deadline, ctx.deadline))
    return request_id, deadline
