"""TopoScope: unified tracing, metrics registry, and profiling hooks.

Three layers (see ARCHITECTURE.md §TopoScope):

- **Metrics registry** (:mod:`repro.obs.metrics`) — process-wide
  thread-safe counters/gauges/histograms, always live; the serving
  frontends' ``stats`` surfaces are views over it.
- **Tracing** (:mod:`repro.obs.trace`) — nestable ``span()`` context
  managers producing Perfetto-loadable Chrome-trace JSON; off by
  default, enabled via ``REPRO_OBS=1`` or ``obs.configure(enabled=True)``.
- **Export + report** (:mod:`repro.obs.export`,
  :mod:`repro.obs.report`) — Prometheus text / JSON-lines snapshots and
  the ``python -m repro.obs report`` self-time table with roofline
  cost-cell attribution.

Typical instrumentation site::

    from repro import obs

    _CALLS = obs.counter("kernels.calls")

    def my_kernel(x):
        _CALLS.inc(kernel="my_kernel")
        with obs.span("kernels.my_kernel", shape=f"N{x.shape[0]}"):
            return _impl(x)
"""
from __future__ import annotations

from typing import Iterable, Optional

from .metrics import (
    Counter,
    DEFAULT_RATIO_BUCKETS,
    DEFAULT_TIME_BUCKETS,
    Gauge,
    Histogram,
    MetricsRegistry,
    default_registry,
    next_instance,
)
from .trace import (
    Span,
    clear_trace,
    configure,
    current_span,
    dropped_events,
    enabled,
    export_chrome_trace,
    span,
    trace_events,
)
from .export import (
    append_jsonl,
    export_prometheus,
    prometheus_text,
    snapshot,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span",
    "DEFAULT_TIME_BUCKETS", "DEFAULT_RATIO_BUCKETS",
    "default_registry", "next_instance",
    "counter", "gauge", "histogram", "get_instrument",
    "configure", "enabled", "span", "current_span",
    "trace_events", "clear_trace", "dropped_events",
    "export_chrome_trace", "export_prometheus", "prometheus_text",
    "snapshot", "append_jsonl", "reset",
]


def counter(name: str, help: str = "") -> Counter:
    """Get-or-create a counter in the default registry."""
    return default_registry().counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return default_registry().gauge(name, help)


def histogram(name: str, help: str = "",
              buckets: Iterable[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
    return default_registry().histogram(name, help, buckets=buckets)


def get_instrument(name: str):
    return default_registry().get(name)


def reset() -> None:
    """Zero every metric series and drop buffered trace events.

    Instruments stay registered, so module-level references held by the
    instrumented subsystems keep recording.
    """
    default_registry().reset()
    clear_trace()
