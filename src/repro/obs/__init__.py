"""TopoScope + TopoWatch: tracing, metrics, SLOs, and serving health.

Passive layers (TopoScope, see ARCHITECTURE.md §TopoScope):

- **Metrics registry** (:mod:`repro.obs.metrics`) — process-wide
  thread-safe counters/gauges/histograms, always live; the serving
  frontends' ``stats`` surfaces are views over it.
- **Tracing** (:mod:`repro.obs.trace`) — nestable ``span()`` context
  managers producing Perfetto-loadable Chrome-trace JSON; off by
  default, enabled via ``REPRO_OBS=1`` or ``obs.configure(enabled=True)``.
- **Export + report** (:mod:`repro.obs.export`,
  :mod:`repro.obs.report`) — Prometheus text / JSON-lines snapshots and
  the ``python -m repro.obs report`` self-time table with roofline
  cost-cell attribution.

Active layers (TopoWatch, see ARCHITECTURE.md §TopoWatch):

- **Request context** (:mod:`repro.obs.context`) — contextvars-scoped
  request ids + absolute deadlines, minted by every ``submit()``,
  propagated into spans and futures; drains sweep expired requests with
  :class:`DeadlineExceeded` and skip cancelled ones.
- **SLO engine** (:mod:`repro.obs.slo`) — declarative latency/error/
  skip-rate/recall objectives evaluated by multi-window burn-rate rules
  over registry snapshots; ``python -m repro.obs watch`` / ``slo check``.
- **Scrape endpoints** (:mod:`repro.obs.http`) — dependency-free
  ``/metrics``, ``/healthz``, ``/readyz``, ``/varz``, ``/slo``,
  ``/debug/flight`` HTTP server.
- **Flight recorder** (:mod:`repro.obs.flight`) — always-on bounded
  ring of recent events, auto-dumped to ``results/obs/FLIGHT_<rev>.json``
  on SLO breach / deadline expiry / drain exception.

Typical instrumentation site::

    from repro import obs

    _CALLS = obs.counter("kernels.calls")

    def my_kernel(x):
        _CALLS.inc(kernel="my_kernel")
        with obs.span("kernels.my_kernel", shape=f"N{x.shape[0]}"):
            return _impl(x)
"""
from __future__ import annotations

from typing import Iterable, Optional

from .metrics import (
    Counter,
    DEFAULT_RATIO_BUCKETS,
    DEFAULT_TIME_BUCKETS,
    Gauge,
    Histogram,
    MetricsRegistry,
    bucket_count_over,
    bucket_quantile,
    default_registry,
    next_instance,
)
from .context import (
    DeadlineExceeded,
    RequestContext,
    current_request_id,
    new_request_id,
    request_context,
)
from .trace import (
    Span,
    clear_trace,
    configure,
    current_span,
    dropped_events,
    enabled,
    export_chrome_trace,
    span,
    trace_events,
)
from .export import (
    append_jsonl,
    export_prometheus,
    prometheus_text,
    snapshot,
)
from .http import ObsHTTPServer, start_http_server
from .slo import (
    BurnRule,
    SLOEngine,
    SLOSpec,
    default_serve_slos,
    slo_status,
)

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span",
    "DEFAULT_TIME_BUCKETS", "DEFAULT_RATIO_BUCKETS",
    "bucket_quantile", "bucket_count_over",
    "default_registry", "next_instance",
    "counter", "gauge", "histogram", "get_instrument",
    "configure", "enabled", "span", "current_span",
    "trace_events", "clear_trace", "dropped_events",
    "export_chrome_trace", "export_prometheus", "prometheus_text",
    "snapshot", "append_jsonl", "reset",
    # TopoWatch
    "DeadlineExceeded", "RequestContext", "request_context",
    "new_request_id", "current_request_id",
    "BurnRule", "SLOEngine", "SLOSpec", "default_serve_slos",
    "slo_status",
    "ObsHTTPServer", "start_http_server",
]


def counter(name: str, help: str = "") -> Counter:
    """Get-or-create a counter in the default registry."""
    return default_registry().counter(name, help)


def gauge(name: str, help: str = "") -> Gauge:
    return default_registry().gauge(name, help)


def histogram(name: str, help: str = "",
              buckets: Iterable[float] = DEFAULT_TIME_BUCKETS) -> Histogram:
    return default_registry().histogram(name, help, buckets=buckets)


def get_instrument(name: str):
    return default_registry().get(name)


def reset() -> None:
    """Zero every metric series, drop buffered trace events, and clear
    the flight-recorder ring.

    Instruments stay registered, so module-level references held by the
    instrumented subsystems keep recording.
    """
    from . import flight as _flight

    default_registry().reset()
    clear_trace()
    _flight.clear()
