"""TopoWatch scrape endpoints: a dependency-free ``http.server`` exporter.

One background :class:`ObsHTTPServer` makes the whole TopoScope/TopoWatch
surface scrapeable — no third-party web stack, just the standard
library's ``ThreadingHTTPServer`` so 8 Prometheus scrapers hammering
``/metrics`` during a drain never block each other or the drain:

==================  =====================================================
``/metrics``        Prometheus text exposition (v0.0.4) of the registry
``/healthz``        liveness: 200 while every registered drain-loop
                    heartbeat is fresh, 503 once any goes stale
``/readyz``         readiness: 200 once a frontend reports ready
                    (``serve_forever`` warmed the bucket plans), 503
                    before/after
``/varz``           full JSON registry snapshot (+ timestamp)
``/slo``            verdicts of the installed SLO engine (ticked per
                    scrape, so alerts never read stale burn rates)
``/debug/flight``   the flight recorder's in-memory ring, newest last
==================  =====================================================

Liveness is gauge-based, not handler-based: ``serve_forever`` loops set
``serve.heartbeat_ts{frontend=...}`` each iteration, and ``/healthz``
compares those wall-clock stamps against ``health_max_age_s`` — a wedged
drain (the exact failure the flight recorder exists for) keeps the HTTP
thread perfectly responsive, so only the heartbeat can tell the truth.
"""
from __future__ import annotations

import json
import threading
import time
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer
from typing import Optional
from urllib.parse import urlparse

from . import flight
from . import slo as _slo
from .export import prometheus_text, snapshot
from .metrics import Gauge, MetricsRegistry, default_registry

HEARTBEAT_GAUGE = "serve.heartbeat_ts"
READY_GAUGE = "serve.ready"


def loop_health(registry: Optional[MetricsRegistry] = None,
                max_age_s: float = 5.0) -> dict:
    """Heartbeat freshness of every registered drain loop.

    ``{"status": "ok"|"stale"|"no_loops", "loops": {label: age_s}}`` —
    ``no_loops`` (no ``serve_forever`` running anywhere) still reports
    healthy: the process is alive, there is just nothing to monitor.
    """
    reg = registry or default_registry()
    inst = reg.get(HEARTBEAT_GAUGE)
    now = time.time()
    loops: dict[str, float] = {}
    if isinstance(inst, Gauge):
        for key, ts in inst.series().items():
            d = dict(key)
            lbl = d.get("frontend", "?") + "/" + d.get("instance", "?")
            loops[lbl] = round(now - float(ts), 3)
    if not loops:
        return {"status": "no_loops", "loops": {}}
    stale = {k: v for k, v in loops.items() if v > max_age_s}
    return {"status": "stale" if stale else "ok", "loops": loops,
            "stale": sorted(stale), "max_age_s": max_age_s}


def readiness(registry: Optional[MetricsRegistry] = None) -> dict:
    """Ready once any frontend set its ``serve.ready`` gauge to 1 (done
    by ``serve_forever`` after plan-cache warmup, cleared on stop)."""
    reg = registry or default_registry()
    inst = reg.get(READY_GAUGE)
    ready: list[str] = []
    if isinstance(inst, Gauge):
        for key, v in inst.series().items():
            if float(v) >= 1.0:
                d = dict(key)
                ready.append(d.get("frontend", "?") + "/"
                             + d.get("instance", "?"))
    return {"status": "ready" if ready else "not_ready",
            "ready": sorted(ready)}


class _Handler(BaseHTTPRequestHandler):
    server_version = "TopoWatch/1.0"
    protocol_version = "HTTP/1.1"

    # the owning ObsHTTPServer injects itself here via a subclass attr
    obs_server: "ObsHTTPServer"

    def log_message(self, fmt, *args):  # silence per-request stderr spam
        pass

    def _send(self, code: int, body: bytes,
              content_type: str = "application/json") -> None:
        self.send_response(code)
        self.send_header("Content-Type", content_type)
        self.send_header("Content-Length", str(len(body)))
        self.end_headers()
        self.wfile.write(body)

    def _send_json(self, code: int, doc) -> None:
        self._send(code, (json.dumps(doc, indent=1) + "\n").encode())

    def do_GET(self) -> None:  # noqa: N802 (http.server API)
        srv = self.obs_server
        path = urlparse(self.path).path.rstrip("/") or "/"
        try:
            if path == "/metrics":
                self._send(200, prometheus_text(srv.registry).encode(),
                           "text/plain; version=0.0.4; charset=utf-8")
            elif path == "/healthz":
                h = loop_health(srv.registry, srv.health_max_age_s)
                self._send_json(200 if h["status"] != "stale" else 503, h)
            elif path == "/readyz":
                r = readiness(srv.registry)
                self._send_json(200 if r["status"] == "ready" else 503, r)
            elif path == "/varz":
                self._send_json(200, {"ts": time.time(),
                                      "metrics": snapshot(srv.registry)})
            elif path == "/slo":
                self._send_json(200, {
                    "ts": time.time(),
                    "status": _slo.slo_status(tick=True),
                    "breaches": _slo.verdict_block()["breaches_by_slo"],
                })
            elif path == "/debug/flight":
                self._send_json(200, {
                    "ts": time.time(),
                    "events": flight.events(limit=srv.flight_limit),
                    "last_dump": flight.last_dump_path(),
                })
            elif path == "/":
                self._send_json(200, {"endpoints": [
                    "/metrics", "/healthz", "/readyz", "/varz", "/slo",
                    "/debug/flight"]})
            else:
                self._send_json(404, {"error": f"unknown path {path!r}"})
        except BrokenPipeError:
            pass  # scraper went away mid-response
        except Exception as e:  # an exporter bug must not kill the server
            try:
                self._send_json(500, {"error": repr(e)})
            except Exception:
                pass


class ObsHTTPServer:
    """Background scrape server; ``port=0`` binds an ephemeral port
    (read it back from ``.port`` — tests and same-host scrapers do).

    >>> srv = start_http_server(port=0)
    >>> srv.url  # doctest: +SKIP
    'http://127.0.0.1:49152'
    >>> srv.stop()
    """

    def __init__(self, port: int = 0, host: str = "127.0.0.1",
                 registry: Optional[MetricsRegistry] = None,
                 health_max_age_s: float = 5.0,
                 flight_limit: int = 256):
        self.registry = registry or default_registry()
        self.health_max_age_s = float(health_max_age_s)
        self.flight_limit = int(flight_limit)
        handler = type("_BoundHandler", (_Handler,), {"obs_server": self})
        self._httpd = ThreadingHTTPServer((host, port), handler)
        self._httpd.daemon_threads = True
        self.host = host
        self.port = int(self._httpd.server_address[1])
        self._thread: Optional[threading.Thread] = None

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}"

    def start(self) -> "ObsHTTPServer":
        if self._thread is not None:
            return self
        self._thread = threading.Thread(
            target=self._httpd.serve_forever, name="topowatch-http",
            daemon=True)
        self._thread.start()
        flight.record("http", "exporter_started", url=self.url)
        return self

    def stop(self) -> None:
        if self._thread is None:
            return
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join(timeout=5)
        self._thread = None


def start_http_server(port: int = 0, host: str = "127.0.0.1",
                      registry: Optional[MetricsRegistry] = None,
                      health_max_age_s: float = 5.0) -> ObsHTTPServer:
    """Create + start an exporter; returns the server (``.port``/
    ``.url``/``.stop()``)."""
    return ObsHTTPServer(port=port, host=host, registry=registry,
                         health_max_age_s=health_max_age_s).start()
