"""Data pipelines: synthetic token streams, graph dataset generators
(graphs.py), and temporal-graph update streams (temporal.py)."""
