"""Data pipelines: synthetic token streams + graph dataset generators."""
