"""Ego-network extraction — the paper's §6.2 OGB workload.

Given one large graph, extract the 1-hop ego net of every vertex as a padded
GraphBatch so that per-vertex persistence diagrams (the TRL / node-
classification feature pipeline of [18] in the paper) become a single
vmapped/pjit-sharded program.

The extraction itself is dense linear algebra: the ego membership matrix is
``M = A | I`` (closed neighborhoods); ego ``v``'s induced adjacency is
``A[M[v], :][:, M[v]]``, realized as a gather with a per-ego vertex ranking so
every ego net is compacted into the first ``n_pad`` slots.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.graph import GraphBatch


def ego_batch(adj: jax.Array, f: jax.Array, n_pad: int,
              centers: jax.Array | None = None) -> GraphBatch:
    """Extract 1-hop ego nets.

    adj: (N, N) bool adjacency of the host graph.
    f:   (N,) float filtering values on the host graph (paper Remark 1: kept
         from the host graph, not recomputed per ego net).
    n_pad: per-ego padded order; ego nets larger than n_pad are truncated to
         the n_pad members with smallest f (sublevel-stable truncation).
    centers: (B,) vertex ids; default = all vertices.

    Returns a GraphBatch of B ego nets.
    """
    n = adj.shape[0]
    if centers is None:
        centers = jnp.arange(n)

    member = adj | jnp.eye(n, dtype=bool)  # closed neighborhoods

    def one(c):
        m = member[c]  # (N,) membership of ego c
        # rank members by (not-member, f, id): members first, smallest f first
        key1 = jnp.where(m, 0, 1)
        order = jnp.lexsort((jnp.arange(n), f, key1))
        sel = order[:n_pad]  # (n_pad,) selected host-vertex ids
        sub_mask = m[sel]
        sub_adj = adj[sel][:, sel] & sub_mask[:, None] & sub_mask[None, :]
        sub_f = jnp.where(sub_mask, f[sel], jnp.inf)
        return sub_adj, sub_mask, sub_f

    a, mk, fv = jax.vmap(one)(centers)
    return GraphBatch(adj=a, mask=mk, f=fv)


def ego_sizes(adj: jax.Array) -> jax.Array:
    """(N,) closed-neighborhood sizes (for picking n_pad / truncation stats)."""
    return 1 + jnp.sum(adj, axis=-1).astype(jnp.int32)
