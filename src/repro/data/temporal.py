"""Synthetic temporal-graph generators: (initial GraphBatch, update stream).

Dynamic-network surrogates for the workloads where recomputing persistence
from scratch per tick is the bottleneck (Azamir–Bennis–Michel; Aktas et al.
name temporal networks as the main unserved PH-on-graphs scenario).  Each
generator returns ``(g0, deltas)`` where ``g0`` is a padded GraphBatch and
``deltas`` is a stacked :class:`~repro.core.delta.DeltaBatch` with a leading
time axis — ``delta_step(deltas, t)`` slices step ``t``; feeding the steps to
``TopoStream.apply`` replays the stream.

Same pure-JAX style as repro/data/graphs.py (PRNGKey in, arrays out, all
loops are ``lax.scan`` with static trip counts) so streams can be generated
device-side under jit.

The three families cover the three invalidation regimes of TopoStream:

* ``pa_growth_stream`` — preferential-attachment growth.  With ``m=1`` every
  arrival is a pendant vertex outside the 2-core: Theorem 2 says PD_1 can
  never move, so a monitoring stream skips every recompute.
* ``community_churn_stream`` — edge churn inside planted communities.  Most
  updates land inside the (dim+1)-core: the recompute-bound regime.
* ``ego_decay_stream`` — a dense ego-net whose peripheral edges decay and
  recover.  Satellite updates are provably skippable (coral for pendant
  satellites, PrunIT for hub-dominated ones); occasional core edges force
  real recomputes.  This is the paper's §6.2 regime made temporal.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.delta import (
    EDGE_DELETE,
    EDGE_INSERT,
    EDGE_NOP,
    DeltaBatch,
    delta_step,
)
from repro.core.graph import GraphBatch, canonicalize

__all__ = [
    "pa_growth_stream",
    "community_churn_stream",
    "ego_decay_stream",
    "delta_step",
]


def _stack_delta(edge_u, edge_v, edge_op, f_vertex=None, f_value=None,
                 drop_vertex=None) -> DeltaBatch:
    """Assemble a stacked (T, B, ...) DeltaBatch, filling absent op kinds."""
    t, b = edge_u.shape[0], edge_u.shape[1]
    if f_vertex is None:
        f_vertex = jnp.full((t, b, 0), -1, jnp.int32)
        f_value = jnp.zeros((t, b, 0), jnp.float32)
    if drop_vertex is None:
        drop_vertex = jnp.full((t, b, 0), -1, jnp.int32)
    return DeltaBatch(edge_u=edge_u.astype(jnp.int32),
                      edge_v=edge_v.astype(jnp.int32),
                      edge_op=edge_op.astype(jnp.int32),
                      f_vertex=f_vertex.astype(jnp.int32),
                      f_value=f_value.astype(jnp.float32),
                      drop_vertex=drop_vertex.astype(jnp.int32))


# ---------------------------------------------------------------------------
# preferential-attachment growth
# ---------------------------------------------------------------------------

def pa_growth_stream(key, batch: int, n_pad: int, n0: int, m: int,
                     steps: int) -> tuple[GraphBatch, DeltaBatch]:
    """Growing network: step t activates vertex ``n0 + t`` with ``m`` edges.

    Attachment targets are degree-weighted among existing vertices (the BA
    process of data/graphs.py, re-expressed as an update stream).  The
    filtration is vertex arrival time (``f(v) = v``), the standard temporal
    filtration, so old vertices never change f.  Requires
    ``n0 + steps <= n_pad``.
    """
    if n0 + steps > n_pad:
        raise ValueError(f"n0 + steps = {n0 + steps} exceeds n_pad={n_pad}")
    if n0 < 2:
        raise ValueError("need n0 >= 2 seed vertices")
    idx = jnp.arange(n_pad)
    # seed: complete graph on the first n0 vertices
    seed_adj = ((idx[None, :] < n0) & (idx[:, None] < n0)
                & (idx[None, :] != idx[:, None]))
    adj0 = jnp.broadcast_to(seed_adj, (batch, n_pad, n_pad))
    mask0 = jnp.broadcast_to(idx < n0, (batch, n_pad))
    f0 = jnp.where(mask0, idx.astype(jnp.float32), jnp.inf)
    g0 = canonicalize(adj0, mask0, f0)

    def step(carry, inp):
        deg = carry  # (B, n_pad) float degree of existing vertices
        t, k = inp
        new_id = n0 + t
        w = (deg + 1.0) * (idx[None, :] < new_id)
        logits = jnp.log(jnp.maximum(w, 1e-9))
        tgt = jax.random.categorical(k, logits, axis=-1,
                                     shape=(m, batch)).T  # (B, m)
        hot = jax.nn.one_hot(tgt, n_pad, dtype=bool).any(axis=1)  # (B, n_pad)
        deg = deg + hot.astype(jnp.float32)
        deg = deg.at[:, new_id].add(hot.sum(-1).astype(jnp.float32))
        eu = tgt                                       # targets are < new_id
        ev = jnp.broadcast_to(new_id, (batch, m)).astype(jnp.int32)
        op = jnp.full((batch, m), EDGE_INSERT, jnp.int32)
        fv = jnp.broadcast_to(new_id, (batch, 1)).astype(jnp.int32)
        fx = jnp.broadcast_to(new_id, (batch, 1)).astype(jnp.float32)
        return deg, (eu, ev, op, fv, fx)

    deg0 = jnp.sum(adj0, -1).astype(jnp.float32)
    keys = jax.random.split(key, steps)
    _, (eu, ev, op, fv, fx) = lax.scan(
        step, deg0, (jnp.arange(steps), keys))
    return g0, _stack_delta(eu, ev, op, f_vertex=fv, f_value=fx)


# ---------------------------------------------------------------------------
# community churn
# ---------------------------------------------------------------------------

def community_churn_stream(key, batch: int, n_pad: int, n_vertices,
                           n_comm: int, p_in: float, p_out: float,
                           steps: int, churn: int,
                           in_bias: float = 4.0,
                           churn_schedule=None) -> tuple[GraphBatch, DeltaBatch]:
    """Planted-partition graph whose edges churn: per step and per graph,
    ``churn`` uniform-random existing edges are deleted and ``churn``
    community-biased non-edges are inserted.  f is the community label, so
    churn only moves adjacency.  Most churn lands inside the (dim+1)-core —
    the recompute-bound regime for TopoStream.

    ``churn_schedule`` (optional, shape ``(steps,)`` ints ``<= churn``)
    modulates the per-step churn volume: step ``t`` keeps only the first
    ``churn_schedule[t]`` delete and insert slots (the rest become NOPs).
    A mostly-small schedule with occasional ``churn``-sized spikes is the
    injected-rewiring-burst workload for the TopoStream drift detector
    (benchmarks/metrics_bench.py).
    """
    kc, ke, ks = jax.random.split(key, 3)
    if churn_schedule is None:
        churn_schedule = jnp.full((steps,), churn, jnp.int32)
    else:
        churn_schedule = jnp.asarray(churn_schedule, jnp.int32)
        if churn_schedule.shape != (steps,):
            raise ValueError(
                f"churn_schedule shape {churn_schedule.shape} != ({steps},)")
        try:  # host-side range check; skipped when traced under jit
            sched = np.asarray(churn_schedule)
        except jax.errors.TracerArrayConversionError:
            sched = None
        if sched is not None and ((sched < 0) | (sched > churn)).any():
            raise ValueError(
                f"churn_schedule entries must be in 0..churn={churn} "
                f"(only churn op slots exist per step); got "
                f"[{sched.min()}, {sched.max()}]")
    n_vertices = jnp.broadcast_to(jnp.asarray(n_vertices), (batch,))
    idx = jnp.arange(n_pad)
    mask = idx[None, :] < n_vertices[:, None]
    comm = jax.random.randint(kc, (batch, n_pad), 0, n_comm)
    same = comm[:, :, None] == comm[:, None, :]
    p = jnp.where(same, p_in, p_out)
    u = jax.random.uniform(ke, (batch, n_pad, n_pad))
    upper = jnp.triu(jnp.ones((n_pad, n_pad), bool), 1)
    adj0 = (u < p) & upper
    g0 = canonicalize(adj0, mask, comm.astype(jnp.float32))

    live = mask[:, None, :] & mask[:, :, None]
    ins_w = jnp.where(same, in_bias, 1.0)

    def pick(k, weights):
        """(B, churn) flat upper-tri indices sampled prop. to weights."""
        logits = jnp.log(jnp.maximum(weights, 1e-30)).reshape(batch, -1)
        return jax.random.categorical(k, logits[:, None, :], axis=-1,
                                      shape=(batch, churn))

    def step(carry, inp):
        adj = carry  # (B, n_pad, n_pad) bool, upper-tri view via `upper`
        k, active = inp
        kd, ki = jax.random.split(k)
        cur = adj & upper & live
        flat_del = pick(kd, cur.astype(jnp.float32))
        non = (~adj) & upper & live
        flat_ins = pick(ki, non.astype(jnp.float32) * ins_w)
        du, dv = flat_del // n_pad, flat_del % n_pad
        iu, iv = flat_ins // n_pad, flat_ins % n_pad
        # degenerate graphs (no edges / complete): categorical may return an
        # index with zero weight — mask those ops out.  The schedule gates
        # how many of the ``churn`` slots are live this step.
        bidx = jnp.arange(batch)[:, None]
        slot_on = jnp.arange(churn)[None, :] < active
        del_ok = cur[bidx, du, dv] & slot_on
        ins_ok = non[bidx, iu, iv] & slot_on
        eu = jnp.concatenate([jnp.where(del_ok, du, -1),
                              jnp.where(ins_ok, iu, -1)], axis=-1)
        ev = jnp.concatenate([jnp.where(del_ok, dv, -1),
                              jnp.where(ins_ok, iv, -1)], axis=-1)
        op = jnp.concatenate(
            [jnp.where(del_ok, EDGE_DELETE, EDGE_NOP),
             jnp.where(ins_ok, EDGE_INSERT, EDGE_NOP)], axis=-1)
        sym = lambda mnew: mnew | jnp.swapaxes(mnew, -1, -2)
        dmat = sym(jnp.zeros_like(adj).at[bidx, du, dv].set(del_ok))
        imat = sym(jnp.zeros_like(adj).at[bidx, iu, iv].set(ins_ok))
        return (adj | imat) & ~dmat, (eu, ev, op)

    _, (eu, ev, op) = lax.scan(
        step, g0.adj, (jax.random.split(ks, steps), churn_schedule))
    return g0, _stack_delta(eu, ev, op)


# ---------------------------------------------------------------------------
# ego-net edge decay
# ---------------------------------------------------------------------------

def ego_decay_stream(key, batch: int, n_pad: int, n_core: int,
                     n_double: int, n_pendant: int, steps: int,
                     toggles: int = 1, p_core_edge: float = 0.15,
                     p_er: float = 0.5) -> tuple[GraphBatch, DeltaBatch]:
    """Dense ego net with decaying/recovering peripheral edges.

    Layout per graph (f in parentheses):

    * hub 0 (0.0) — adjacent to every live vertex;
    * hub 1 (0.0) — adjacent to hub 0, the core, and the double satellites;
    * core ``2..n_core-1`` (1.0) — ER(p_er) among themselves;
    * double satellites (2.0) — attached to hubs 0 and 1; toggling their
      hub-1 edge is a **PrunIT hit** (hub 0 dominates both endpoints and is
      never touched), exact in every dimension;
    * pendant satellites (2.0) — attached to hub 0 only; toggling that edge
      is a **coral hit** for dim >= 1 (the satellite never enters the
      2-core) but genuinely changes PD_0.

    Each step toggles ``toggles`` random satellite edges per graph and, with
    probability ``p_core_edge``, one random core–core edge (both endpoints in
    the 2-core ⟹ a real recompute).
    """
    n_live = n_core + n_double + n_pendant
    if n_live > n_pad:
        raise ValueError(f"{n_live} live vertices exceed n_pad={n_pad}")
    if n_core < 4:
        raise ValueError("need n_core >= 4 (2 hubs + >= 2 core vertices)")
    k_er, k_tog = jax.random.split(key)
    idx = jnp.arange(n_pad)
    live = idx < n_live
    corev = (idx >= 2) & (idx < n_core)
    dbl = (idx >= n_core) & (idx < n_core + n_double)

    u = jax.random.uniform(k_er, (batch, n_pad, n_pad))
    er = (u < p_er) & corev[None, :, None] & corev[None, None, :]
    hub0 = (idx == 0)[:, None] & live[None, :]
    hub1_row = corev | dbl | (idx == 0)
    hub1 = (idx == 1)[:, None] & hub1_row[None, :]
    adj0 = er | hub0[None] | hub1[None]
    mask0 = jnp.broadcast_to(live, (batch, n_pad))
    f0 = jnp.where(idx < 2, 0.0, jnp.where(idx < n_core, 1.0, 2.0))
    f0 = jnp.where(live, f0, jnp.inf)
    g0 = canonicalize(adj0, mask0, jnp.broadcast_to(f0, (batch, n_pad)))

    n_sat = n_double + n_pendant
    # toggled satellite edge s: (hub, sat_id) with hub 1 for doubles, 0 for
    # pendants; presence tracked through the scan
    sat_ids = n_core + jnp.arange(n_sat)
    sat_hub = jnp.where(jnp.arange(n_sat) < n_double, 1, 0)
    # core–core candidate pairs (i < j among core vertices)
    ci, cj = jnp.meshgrid(jnp.arange(2, n_core), jnp.arange(2, n_core),
                          indexing="ij")
    cu, cv = ci.reshape(-1), cj.reshape(-1)
    csel = cu < cv
    cu, cv = cu[csel], cv[csel]
    n_cand = cu.shape[0]

    def step(carry, k):
        sat_on, core_on = carry  # (B, n_sat) bool, (B, n_cand) bool
        ks, kc, kg = jax.random.split(k, 3)
        pick = jax.random.randint(ks, (batch, toggles), 0, n_sat)
        hot = jax.nn.one_hot(pick, n_sat, dtype=bool).any(axis=1)  # (B,n_sat)
        present = jnp.take_along_axis(sat_on, pick, axis=-1)
        s_eu = jnp.take(sat_hub, pick)
        s_ev = jnp.take(sat_ids, pick)
        s_op = jnp.where(present, EDGE_DELETE, EDGE_INSERT)
        sat_on = sat_on ^ hot

        gate = jax.random.uniform(kg, (batch,)) < p_core_edge
        cpick = jax.random.randint(kc, (batch, 1), 0, n_cand)
        c_present = jnp.take_along_axis(core_on, cpick, axis=-1)
        c_eu = jnp.where(gate[:, None], jnp.take(cu, cpick), -1)
        c_ev = jnp.where(gate[:, None], jnp.take(cv, cpick), -1)
        c_op = jnp.where(gate[:, None],
                         jnp.where(c_present, EDGE_DELETE, EDGE_INSERT),
                         EDGE_NOP)
        chot = (jax.nn.one_hot(cpick[:, 0], n_cand, dtype=bool)
                & gate[:, None])
        core_on = core_on ^ chot

        eu = jnp.concatenate([s_eu, c_eu], axis=-1)
        ev = jnp.concatenate([s_ev, c_ev], axis=-1)
        op = jnp.concatenate([s_op, c_op], axis=-1)
        return (sat_on, core_on), (eu, ev, op)

    sat_on0 = jnp.ones((batch, n_sat), bool)
    core_on0 = g0.adj[:, cu, cv]
    _, (eu, ev, op) = lax.scan(step, (sat_on0, core_on0),
                               jax.random.split(k_tog, steps))
    return g0, _stack_delta(eu, ev, op)
