"""Synthetic graph dataset generators (offline surrogates for TU/SNAP/OGB).

The paper evaluates on real datasets (its Table 2 / Table 1); this container
has no network access, so each dataset is replaced by a *surrogate generator*
matched on the published statistics — graph count, average order, average
size, and the family's degree structure (community graphs for the "com-*"
SNAP networks, preferential attachment for citation graphs, dense ego nets
for FACEBOOK/TWITTER, geometric-ish clustered graphs for the bio kernels).
Exactness claims (Theorems 2/7) are validated on *any* graph, so the
surrogates only need to reproduce the reduction *regime*, not the datasets
bit-for-bit (DESIGN.md §8).

All generators are pure-JAX (PRNGKey in, GraphBatch out) so a sharded data
pipeline can build batches device-side under pjit.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import GraphBatch, canonicalize


# ---------------------------------------------------------------------------
# primitive random-graph models (batched, padded, jit/vmap friendly)
# ---------------------------------------------------------------------------

def erdos_renyi(key, batch: int, n_pad: int, n_vertices, p) -> GraphBatch:
    """G(n, p). ``n_vertices``/``p`` may be scalars or (batch,) arrays."""
    n_vertices = jnp.broadcast_to(jnp.asarray(n_vertices), (batch,))
    p = jnp.broadcast_to(jnp.asarray(p, jnp.float32), (batch,))
    u = jax.random.uniform(key, (batch, n_pad, n_pad))
    upper = jnp.triu(jnp.ones((n_pad, n_pad), bool), 1)
    adj = (u < p[:, None, None]) & upper
    mask = jnp.arange(n_pad)[None, :] < n_vertices[:, None]
    return canonicalize(adj, mask, jnp.zeros((batch, n_pad)))


def barabasi_albert(key, batch: int, n_pad: int, n_vertices, m: int) -> GraphBatch:
    """Preferential attachment, dense-matrix formulation.

    Vertex t attaches to ``m`` earlier vertices sampled by degree.  The loop
    over vertices is a lax.scan (fixed n_pad trip count); masked out above
    n_vertices.
    """
    n_vertices = jnp.broadcast_to(jnp.asarray(n_vertices), (batch,))

    def attach(adj_deg, inp):
        adj, deg = adj_deg
        t, k = inp
        # sample m targets among vertices < t, proportional to degree + 1
        w = (deg + 1.0) * (jnp.arange(n_pad)[None, :] < t)
        logits = jnp.log(jnp.maximum(w, 1e-9))
        tgt = jax.random.categorical(k, logits, axis=-1, shape=(m, batch)).T
        hot = jax.nn.one_hot(tgt, n_pad, dtype=bool).any(axis=1)  # (B, n_pad)
        hot = hot & (jnp.arange(n_pad)[None, :] < t)
        adj = adj.at[:, t, :].set(adj[:, t, :] | hot)
        adj = adj.at[:, :, t].set(adj[:, :, t] | hot)
        deg = deg + hot.astype(jnp.float32)
        deg = deg.at[:, t].add(hot.sum(-1).astype(jnp.float32))
        return (adj, deg), None

    keys = jax.random.split(key, n_pad)
    adj0 = jnp.zeros((batch, n_pad, n_pad), bool)
    deg0 = jnp.zeros((batch, n_pad), jnp.float32)
    ts = jnp.arange(n_pad)
    (adj, _), _ = jax.lax.scan(attach, (adj0, deg0), (ts, keys))
    mask = jnp.arange(n_pad)[None, :] < n_vertices[:, None]
    return canonicalize(adj, mask, jnp.zeros((batch, n_pad)))


def watts_strogatz(key, batch: int, n_pad: int, n_vertices, k_ring: int,
                   p_rewire: float) -> GraphBatch:
    """Ring lattice + random rewiring (approximated as ring + ER overlay)."""
    n_vertices = jnp.broadcast_to(jnp.asarray(n_vertices), (batch,))
    idx = jnp.arange(n_pad)
    # ring distances modulo the *live* vertex count per graph
    nv = jnp.maximum(n_vertices, 1)[:, None, None]
    d = jnp.abs(idx[None, :, None] - idx[None, None, :])
    d = jnp.minimum(d, nv - d)
    ring = (d >= 1) & (d <= k_ring // 2)
    key_drop, key_add = jax.random.split(key)
    drop = jax.random.uniform(key_drop, (batch, n_pad, n_pad)) < p_rewire
    drop = drop | jnp.swapaxes(drop, -1, -2)
    p_add = p_rewire * k_ring / jnp.maximum(n_vertices[:, None, None], 2)
    add = jax.random.uniform(key_add, (batch, n_pad, n_pad)) < p_add
    adj = (ring & ~drop) | add
    mask = idx[None, :] < n_vertices[:, None]
    return canonicalize(adj, mask, jnp.zeros((batch, n_pad)))


def powerlaw_cluster(key, batch: int, n_pad: int, n_vertices, m: int,
                     p_triangle: float) -> GraphBatch:
    """Holme–Kim style: BA plus triangle-closing steps.

    Triangle closure is approximated by adding, for each attachment edge
    (t, v), an edge from t to a random neighbor of v with prob p_triangle —
    implemented as one extra masked matmul round after BA.
    """
    kb, kt, ku = jax.random.split(key, 3)
    g = barabasi_albert(kb, batch, n_pad, n_vertices, m)
    # candidate triangle edges: two-hop pairs
    a = g.adj.astype(jnp.float32)
    two_hop = (a @ a > 0) & ~g.adj
    u = jax.random.uniform(ku, g.adj.shape)
    extra = two_hop & (u < p_triangle) & g.mask[:, None, :] & g.mask[:, :, None]
    extra = extra & jnp.swapaxes(extra, -1, -2)  # keep symmetric draws only
    return canonicalize(g.adj | extra, g.mask, jnp.zeros_like(g.f))


def community_graph(key, batch: int, n_pad: int, n_vertices, n_comm: int,
                    p_in: float, p_out: float) -> GraphBatch:
    """Planted-partition surrogate for the SNAP "com-*" networks."""
    kc, ke = jax.random.split(key)
    comm = jax.random.randint(kc, (batch, n_pad), 0, n_comm)
    same = comm[:, :, None] == comm[:, None, :]
    p_in = jnp.broadcast_to(jnp.asarray(p_in, jnp.float32), (batch,))
    p_out = jnp.broadcast_to(jnp.asarray(p_out, jnp.float32), (batch,))
    p = jnp.where(same, p_in[:, None, None], p_out[:, None, None])
    u = jax.random.uniform(ke, (batch, n_pad, n_pad))
    upper = jnp.triu(jnp.ones((n_pad, n_pad), bool), 1)
    adj = (u < p) & upper
    n_vertices = jnp.broadcast_to(jnp.asarray(n_vertices), (batch,))
    mask = jnp.arange(n_pad)[None, :] < n_vertices[:, None]
    return canonicalize(adj, mask, jnp.zeros((batch, n_pad)))


def attach_satellites(key, g: GraphBatch, frac: float) -> GraphBatch:
    """Rewire the last ``frac`` of live vertices into degree-1/2 satellites.

    Real scale-free networks have a heavy low-degree tail (roughly half the
    vertices have degree <= 2); ER/BA/planted-partition cores with min degree
    >= m have none, which suppresses the dominated-vertex population the
    paper's Table 1 reductions rely on.  A satellite attached to a single
    hub is dominated by that hub (closed neighborhoods), matching the regime.
    """
    if frac <= 0:
        return g
    b, n = g.batch, g.n
    nv = g.n_vertices()
    n_sat = (nv.astype(jnp.float32) * frac).astype(jnp.int32)
    sat_start = nv - n_sat
    idx = jnp.arange(n)[None, :]
    is_sat = (idx >= sat_start[:, None]) & g.mask
    core = g.mask & ~is_sat

    # drop all satellite edges
    adj = g.adj & core[:, None, :] & core[:, :, None]
    # attach each satellite to a degree-weighted random core vertex
    deg = jnp.sum(adj, -1).astype(jnp.float32)
    logits = jnp.where(core, jnp.log1p(deg), -jnp.inf)
    tgt = jax.random.categorical(key, logits[:, None, :], axis=-1,
                                 shape=(b, n))
    hot = jax.nn.one_hot(tgt, n, dtype=bool) & is_sat[:, :, None]
    adj = adj | hot | jnp.swapaxes(hot, -1, -2)
    return canonicalize(adj, g.mask, g.f)


def with_degree_filtration(g: GraphBatch) -> GraphBatch:
    """Paper's default filtering function: degree on the *original* graph."""
    deg = g.degrees().astype(jnp.float32)
    return GraphBatch(adj=g.adj, mask=g.mask, f=jnp.where(g.mask, deg, jnp.inf))


# ---------------------------------------------------------------------------
# dataset surrogates (paper Table 2 — graph/node classification datasets)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class DatasetSpec:
    name: str
    n_graphs: int      # paper's NumGraphs (sampled down by callers)
    avg_nodes: float   # paper's AvgNumNodes
    avg_edges: float   # paper's AvgNumEdges
    family: str        # generator family
    n_pad: int         # padded order used by the surrogate


def _spec(name, n_graphs, nodes, edges, family, n_pad):
    return DatasetSpec(name, n_graphs, nodes, edges, family, n_pad)


# Orders/sizes from paper appendix Table 2. n_pad covers the mean regime
# (huge-N datasets are subsampled: the TDA batch layout is small-N/huge-B).
TABLE2 = {
    "DD":            _spec("DD", 1178, 284.3, 715.7, "powerlaw", 320),
    "DHFR":          _spec("DHFR", 467, 42.4, 44.5, "ws", 64),
    "ENZYMES":       _spec("ENZYMES", 600, 32.6, 62.1, "ws", 64),
    "FIRSTMM":       _spec("FIRSTMM", 41, 1377.3, 3074.1, "community", 256),
    "NCI1":          _spec("NCI1", 4110, 29.9, 32.3, "ws", 48),
    "OHSU":          _spec("OHSU", 79, 82.0, 199.7, "powerlaw", 128),
    "PROTEINS":      _spec("PROTEINS", 1113, 39.1, 72.8, "ws", 64),
    "REDDIT-BINARY": _spec("REDDIT-BINARY", 2000, 429.6, 497.8, "ba", 480),
    "SYNNEW":        _spec("SYNNEW", 300, 100.0, 196.3, "er", 128),
    "TWITTER":       _spec("TWITTER", 973, 83.5, 1817.0, "dense_ego", 128),
    "FACEBOOK":      _spec("FACEBOOK", 10, 403.9, 8823.4, "dense_ego", 448),
    "CORA":          _spec("CORA", 1, 2708.0, 5429.0, "ba", 512),
    "CITESEER":      _spec("CITESEER", 1, 3264.0, 4536.0, "ba", 512),
}

# SNAP large networks (paper Table 1) — scaled surrogates with matching
# average degree; the reduction-% regime depends on degree structure, not on
# absolute order.  The satellite fraction encodes each network's low-degree
# tail (chosen so PrunIT lands in the paper's reported reduction regime).
TABLE1 = {
    # name: (family, |V|, |E|, satellite_frac)
    "com-youtube":      ("community", 1_134_890, 2_987_624, 0.55),
    "com-amazon":       ("community", 334_863, 925_872, 0.35),
    "com-dblp":         ("community", 317_080, 1_049_866, 0.65),
    "web-Stanford":     ("ba", 281_903, 1_992_636, 0.60),
    "emailEuAll":       ("dense_ego", 265_214, 364_481, 0.90),
    "soc-Epinions1":    ("ba", 75_879, 405_740, 0.50),
    "p2pGnutella31":    ("er", 62_586, 147_892, 0.40),
    "Brightkite_edges": ("community", 58_228, 214_078, 0.45),
    "Email-Enron":      ("community", 36_692, 183_831, 0.70),
    "CA-CondMat":       ("community", 23_133, 93_439, 0.60),
    "oregon1_010526":   ("ba", 11_174, 23_409, 0.55),
}


def _gen_family(family: str, key, batch: int, n_pad: int, nv, avg_deg):
    """Dispatch on the family string with degree matched to ``avg_deg``."""
    if family == "er":
        p = avg_deg / jnp.maximum(nv - 1, 1)
        return erdos_renyi(key, batch, n_pad, nv, p)
    if family == "ba":
        m = max(1, int(round(float(jnp.mean(jnp.asarray(avg_deg))) / 2)))
        return barabasi_albert(key, batch, n_pad, nv, m)
    if family == "ws":
        k_ring = max(2, int(round(float(jnp.mean(jnp.asarray(avg_deg))) / 2)) * 2)
        return watts_strogatz(key, batch, n_pad, nv, k_ring, 0.1)
    if family == "powerlaw":
        m = max(1, int(round(float(jnp.mean(jnp.asarray(avg_deg))) / 2)))
        return powerlaw_cluster(key, batch, n_pad, nv, m, 0.3)
    if family == "community":
        p_in = jnp.minimum(avg_deg * 0.8 / jnp.maximum(nv / 8.0, 1.0), 0.9)
        p_out = avg_deg * 0.2 / jnp.maximum(nv, 2)
        return community_graph(key, batch, n_pad, nv, 8, p_in, p_out)
    if family == "dense_ego":
        # hub-and-dense-core: ER core with a connected-to-everything hub set
        kc, kh = jax.random.split(key)
        p = jnp.minimum(2.0 * avg_deg / jnp.maximum(nv - 1, 1), 0.8)
        g = erdos_renyi(kc, batch, n_pad, nv, p)
        hub = jnp.arange(n_pad)[None, :] < jnp.maximum(nv // 20, 1)[..., None]
        adj = g.adj | (hub[:, :, None] & g.mask[:, None, :])
        return canonicalize(adj, g.mask, jnp.zeros_like(g.f))
    raise ValueError(f"unknown family {family!r}")


def load_dataset(name: str, key, batch: int | None = None,
                 degree_filtration: bool = True) -> GraphBatch:
    """Sample a batch of surrogate graphs for a Table-2 dataset."""
    spec = TABLE2[name]
    b = batch or min(spec.n_graphs, 64)
    kn, kg = jax.random.split(jax.random.fold_in(key, hash(name) % (2**31)))
    # graph orders: lognormal around the dataset mean, clipped to n_pad
    mu = jnp.log(spec.avg_nodes)
    nv = jnp.exp(mu + 0.35 * jax.random.normal(kn, (b,)))
    nv = jnp.clip(nv, 4, spec.n_pad).astype(jnp.int32)
    avg_deg = 2.0 * spec.avg_edges / spec.avg_nodes
    g = _gen_family(spec.family, kg, b, spec.n_pad, nv, avg_deg)
    return with_degree_filtration(g) if degree_filtration else g


def load_large_network(name: str, key, n_pad: int = 2048,
                       degree_filtration: bool = True) -> GraphBatch:
    """One scaled surrogate (order n_pad) of a Table-1 SNAP network."""
    family, n_full, e_full, sat_frac = TABLE1[name]
    kg, ks = jax.random.split(key)
    # core average degree is boosted so that after rewiring the satellite
    # tail the overall mean degree still matches the published 2|E|/|V|
    avg_deg = 2.0 * e_full / n_full / max(1.0 - sat_frac, 0.1)
    nv = jnp.asarray([n_pad], jnp.int32)
    g = _gen_family(family, kg, 1, n_pad, nv, jnp.float32(avg_deg))
    g = attach_satellites(ks, g, sat_frac)
    return with_degree_filtration(g) if degree_filtration else g
