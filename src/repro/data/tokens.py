"""Checkpointable synthetic token stream for LM training.

Deterministic function of (seed, step): restoring a checkpoint at step t and
continuing produces the same batches as an uninterrupted run — the property
the fault-tolerance tests assert.  The stream synthesizes structured (not
uniform) token statistics: a Zipfian unigram mixed with a repeated-motif
process so the model has actual signal to fit in the end-to-end example.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class TokenStream:
    vocab_size: int
    batch: int
    seq_len: int
    seed: int = 0
    zipf_a: float = 1.2
    motif_len: int = 16
    p_motif: float = 0.35

    def _zipf_logits(self) -> jax.Array:
        ranks = jnp.arange(1, self.vocab_size + 1, dtype=jnp.float32)
        return -self.zipf_a * jnp.log(ranks)

    def batch_at(self, step) -> dict:
        """Batch for ``step`` — pure function, jit-able, O(1) state."""
        key = jax.random.fold_in(jax.random.PRNGKey(self.seed), step)
        ku, km, kp, ks = jax.random.split(key, 4)
        logits = self._zipf_logits()
        uni = jax.random.categorical(
            ku, logits, shape=(self.batch, self.seq_len))
        # motif process: each sequence repeats a per-sequence motif with phase
        motif = jax.random.categorical(
            km, logits, shape=(self.batch, self.motif_len))
        phase = jax.random.randint(kp, (self.batch, 1), 0, self.motif_len)
        pos = (jnp.arange(self.seq_len)[None, :] + phase) % self.motif_len
        rep = jnp.take_along_axis(motif, pos, axis=1)
        pick = jax.random.uniform(ks, (self.batch, self.seq_len)) < self.p_motif
        return {"tokens": jnp.where(pick, rep, uni).astype(jnp.int32)}

    def state(self, step: int) -> dict:
        """Serializable pipeline state for the checkpoint manifest."""
        return {"seed": self.seed, "step": int(step)}

    @staticmethod
    def resume(cfg: "TokenStream", state: dict) -> tuple["TokenStream", int]:
        assert state["seed"] == cfg.seed, "stream seed mismatch on restore"
        return cfg, int(state["step"])
