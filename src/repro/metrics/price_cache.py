"""PriceCache: LRU of converged auction price vectors for warm starts.

The collapsed forward/reverse auction (``kernels/auction_lap.py``) returns
its converged object-price vector in max-normalized units (cost / c_scale),
and accepts **any** nonnegative price vector as a warm start — the reverse
phase re-grounds stale prices, so a warm start can only save rounds, never
break optimality (the ε-CS argument is in the kernel module docstring).

This module keys those vectors by ``(query LSH bucket code, candidate
row)``: two queries landing in the same hyperplane bucket of
``TopoIndex._lsh_codes`` are near-duplicates in the embedding metric, so
their reduced-cost matrices against a fixed stored candidate are close and
the converged prices of one start the other near equilibrium.  The serve
layer (``serve/similarity.py``) looks a batch up before every exact_w
drain and stores the converged vectors back after.

Only *converged* price vectors are stored — an unconverged solve's prices
are mid-ladder and would seed later queries with a cold ε-scale.  Misses
return zeros, which is exactly the cold-start the solver uses anyway.
"""
from __future__ import annotations

from collections import OrderedDict

import numpy as np

from repro import obs

_C_HITS = obs.counter(
    "auction.warm_start_hits",
    help="price-cache lookups that returned a stored warm-start vector")
_C_MISSES = obs.counter(
    "auction.warm_start_misses",
    help="price-cache lookups that fell back to a zero cold start")


class PriceCache:
    """LRU ``(bucket code bytes, candidate row) -> (n_points,) f32 prices``.

    ``capacity`` bounds the number of stored vectors (LRU eviction).  The
    cache is not thread-safe on its own; the serve layer calls it under
    its drain lock.  ``instance`` labels the TopoScope hit/miss counters
    so multiple servers in one process report separately.
    """

    def __init__(self, capacity: int = 4096, instance: str = ""):
        if capacity <= 0:
            raise ValueError(f"capacity must be positive, got {capacity}")
        self.capacity = int(capacity)
        self.instance = instance
        self._store: OrderedDict[tuple[bytes, int], np.ndarray] = OrderedDict()

    def __len__(self) -> int:
        return len(self._store)

    def lookup(self, codes: np.ndarray, rows: np.ndarray,
               n_points: int) -> tuple[np.ndarray, int, int]:
        """Warm-start prices for a (Q, C) batch of query×candidate pairs.

        ``codes``: (Q, code_bytes) u8 packed bucket codes (one per query);
        ``rows``: (Q, C) int candidate index rows.  Returns
        ``(prices (Q, C, n_points) f32, hits, misses)`` — missed pairs are
        zero rows (the solver's own cold start).
        """
        codes = np.asarray(codes)
        rows = np.asarray(rows)
        q, c = rows.shape
        out = np.zeros((q, c, n_points), np.float32)
        hits = 0
        for i in range(q):
            key_q = codes[i].tobytes()
            for j in range(c):
                v = self._store.get((key_q, int(rows[i, j])))
                if v is not None and v.shape[0] == n_points:
                    out[i, j] = v
                    self._store.move_to_end((key_q, int(rows[i, j])))
                    hits += 1
        misses = q * c - hits
        if hits:
            _C_HITS.inc(hits, instance=self.instance)
        if misses:
            _C_MISSES.inc(misses, instance=self.instance)
        return out, hits, misses

    def store(self, codes: np.ndarray, rows: np.ndarray,
              prices: np.ndarray, converged: np.ndarray) -> int:
        """Store the converged price vectors of a finished (Q, C) batch.

        ``prices``: (Q, C, n_points) f32 from ``compare_info``;
        ``converged``: (Q, C) bool — unconverged solves are skipped (their
        prices are mid-ε-ladder).  Returns the number of vectors stored.
        """
        codes = np.asarray(codes)
        rows = np.asarray(rows)
        prices = np.asarray(prices, np.float32)
        converged = np.asarray(converged)
        q, c = rows.shape
        stored = 0
        for i in range(q):
            key_q = codes[i].tobytes()
            for j in range(c):
                if not converged[i, j]:
                    continue
                self._store[(key_q, int(rows[i, j]))] = prices[i, j].copy()
                self._store.move_to_end((key_q, int(rows[i, j])))
                stored += 1
        while len(self._store) > self.capacity:
            self._store.popitem(last=False)
        return stored

    @property
    def hits(self) -> int:
        return int(_C_HITS.value(instance=self.instance))

    @property
    def misses(self) -> int:
        return int(_C_MISSES.value(instance=self.instance))
