"""TopoMetric: batched persistence-diagram distances on the Diagrams layout.

Every function here is masked arithmetic over the fixed-size
:class:`~repro.core.persistence_jax.Diagrams` tensor — no host-side point
lists — so distances jit, vmap over leading batch axes, and pjit-shard with
the rest of the pipeline.  Host-side exact references (bottleneck, exact
q-Wasserstein, dense sliced-Wasserstein) live in ``repro.metrics.reference``
and are the parity oracles for everything in this module.

Shared conventions (docs/ARCHITECTURE.md §TopoMetric):

* **Per dimension.**  Every distance takes a homology dimension ``k`` and
  selects ``valid & (dim == k)`` rows; distances across dimensions are the
  caller's composition.
* **Essential classes.**  ``death = +inf`` rows are capped at ``cap`` (the
  same ``Diagrams.finite_points`` convention the feature pipeline uses), so
  ``cap`` must dominate the filtration range.
* **Masking.**  Invalid rows are inert: they contribute zero mass, never
  enter a sort ahead of real points, and two Diagrams that differ only in
  padding have distance exactly 0.

Distances:

* ``sliced_wasserstein`` — the Carrière–Cuturi–Oudot SW distance on a fixed
  grid of ``n_dirs`` directions over the half-circle, with each diagram
  augmented by the *other* diagram's diagonal projections (so both sides of
  every 1-D transport problem carry ``n1 + n2`` points).  Exact for the grid;
  parity vs ``reference.sw_dense`` at rtol 1e-5.
* ``sw_embedding`` — the serving fast path: a *pair-independent* fixed-size
  embedding (top-``n_points`` by persistence, each point plus its own
  diagonal projection, absent slots anchored at the diagonal origin, sorted
  per direction).  Pairwise L1 between embeddings is a metric on diagrams
  and is what ``kernels/pairwise_gram.py`` tiles into N×N Gram matrices for
  ``TopoIndex``; it approximates (but is not equal to) ``sliced_wasserstein``
  because true SW augmentation is pair-dependent.
* ``sinkhorn_w2`` — entropic 2-Wasserstein: squared-Euclidean OT between the
  diagonal-augmented masked point clouds, log-domain Sinkhorn with
  ε-scaling, diagonal↔diagonal transport free.  Within a few percent of
  ``reference.wasserstein_exact(q=2)`` on small diagrams.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.persistence_jax import Diagrams


def direction_grid(n_dirs: int) -> tuple[jax.Array, jax.Array]:
    """(cos φ, sin φ) for ``n_dirs`` directions on the half-circle.

    Midpoint grid φ_m = -π/2 + π (m + ½)/M — the fixed quadrature every SW
    path (batched distance, embedding, dense reference) shares.
    """
    phi = -jnp.pi / 2 + jnp.pi * (jnp.arange(n_dirs) + 0.5) / n_dirs
    return jnp.cos(phi), jnp.sin(phi)


def masked_points(d: Diagrams, k: int, cap: float):
    """Sanitized ``(birth, death, sel)`` of the dim-``k`` sub-diagram.

    birth/death are zeroed outside ``sel`` (= valid & dim == k); death is
    capped at ``cap`` for essential classes (``Diagrams.finite_points``).
    """
    sel = d.valid & (d.dim == k)
    birth, death = d.finite_points(cap)
    return jnp.where(sel, birth, 0.0), jnp.where(sel, death, 0.0), sel


def compact_top_k(d: Diagrams, k: int, n_points: int, cap: float):
    """``masked_points`` compacted to exactly ``n_points`` slots by persistence.

    The one cloud-compaction convention shared by every backend that works
    on fixed-width point sets — ``sinkhorn_w2``, ``sw_embedding`` and the
    auction-LAP ``exact_w`` path (repro/metrics/exact.py) all call this, so
    "top points by persistence, absent slots zeroed with ``keep=False``"
    is defined in one place.  Returns ``(birth, death, keep)`` of width
    ``n_points`` regardless of the diagram tensor size ``S``: diagrams from
    different serve buckets compact into the same shape, which is what lets
    the batched assignment kernels jit once per ``n_points``.

    Diagram tensors carry one row per *potential* birth simplex (S = n +
    edge_cap + tri_cap), but real diagrams occupy a handful of rows; the
    compaction keeps distance working sets proportional to diagram content
    instead of tensor capacity.  Exact whenever the dim-``k`` sub-diagram
    has at most ``n_points`` points; beyond that the lowest-persistence
    points are dropped (documented truncation).
    """
    b, e, sel = masked_points(d, k, cap)
    s = b.shape[-1]
    if s < n_points:  # tiny diagram tensors: pad rows up to the slot count
        pad = [(0, 0)] * (b.ndim - 1) + [(0, n_points - s)]
        b, e = jnp.pad(b, pad), jnp.pad(e, pad)
        return b, e, jnp.pad(sel, pad)
    if s == n_points:
        return b, e, sel
    pers = jnp.where(sel, e - b, -jnp.inf)
    top_pers, top_idx = lax.top_k(pers, n_points)
    keep = jnp.isfinite(top_pers)
    tb = jnp.take_along_axis(b, top_idx, axis=-1)
    te = jnp.take_along_axis(e, top_idx, axis=-1)
    return jnp.where(keep, tb, 0.0), jnp.where(keep, te, 0.0), keep


@partial(jax.jit, static_argnames=("k", "n_dirs"))
def sliced_wasserstein(d1: Diagrams, d2: Diagrams, k: int = 1,
                       n_dirs: int = 32, cap: float = 64.0) -> jax.Array:
    """Sliced-Wasserstein distance between dim-``k`` diagrams (batched).

    Leaves may carry arbitrary leading batch axes (pairs are aligned
    row-wise); returns ``(...,)`` distances.  For each direction θ the two
    projected multisets are ``P1 ∪ Δ(P2)`` and ``P2 ∪ Δ(P1)`` (Δ = orthogonal
    projection onto the diagonal), so both carry ``n1 + n2`` real entries;
    1-D W1 is the L1 distance of the sorted sequences, and the result is the
    direction average.  Padding sorts to an aligned +inf tail on both sides
    and is dropped by rank masking.
    """
    cos, sin = direction_grid(n_dirs)
    b1, e1, sel1 = masked_points(d1, k, cap)
    b2, e2, sel2 = masked_points(d2, k, cap)

    def entries(b, e, sel, ob, oe, osel):
        # (…, M, 2S): own points then the other diagram's diagonal projections
        pt = b[..., None, :] * cos[:, None] + e[..., None, :] * sin[:, None]
        mid = (ob + oe) / 2.0
        dg = mid[..., None, :] * (cos + sin)[:, None]
        pt = jnp.where(sel[..., None, :], pt, jnp.inf)
        dg = jnp.where(osel[..., None, :], dg, jnp.inf)
        return jnp.sort(jnp.concatenate([pt, dg], axis=-1), axis=-1)

    v1 = entries(b1, e1, sel1, b2, e2, sel2)
    v2 = entries(b2, e2, sel2, b1, e1, sel1)
    cnt = (jnp.sum(sel1, axis=-1) + jnp.sum(sel2, axis=-1))[..., None, None]
    rank = jnp.arange(v1.shape[-1])
    diff = jnp.where(rank < cnt, jnp.abs(v1 - v2), 0.0)  # inf-inf tail dropped
    return jnp.sum(diff, axis=(-1, -2)) / n_dirs


@partial(jax.jit, static_argnames=("k", "n_points", "n_dirs"))
def sw_embedding(d: Diagrams, k: int = 1, n_points: int = 16,
                 n_dirs: int = 16, cap: float = 64.0) -> jax.Array:
    """Pair-independent sliced projection embedding: ``(..., n_dirs·2·n_points)``.

    The top ``n_points`` rows by persistence are kept (so the embedding width
    is independent of the diagram tensor size ``S`` — diagrams from different
    serve buckets embed into the same space).  Per direction, each kept point
    contributes its projection and its own diagonal projection; absent slots
    anchor at the diagonal origin (projection 0), which makes a cardinality
    mismatch cost the transport of the extra points to the origin.  Entries
    are sorted per direction and scaled by ``1/n_dirs`` so that the pairwise
    **L1 distance between embeddings** (``kernels/pairwise_gram.py``) is the
    direction-averaged 1-D W1 of the anchored multisets — the ``TopoIndex``
    metric.
    """
    tb, te, keep = compact_top_k(d, k, n_points, cap)
    cos, sin = direction_grid(n_dirs)
    pt = tb[..., None, :] * cos[:, None] + te[..., None, :] * sin[:, None]
    dg = ((tb + te) / 2.0)[..., None, :] * (cos + sin)[:, None]
    pt = jnp.where(keep[..., None, :], pt, 0.0)
    dg = jnp.where(keep[..., None, :], dg, 0.0)
    emb = jnp.sort(jnp.concatenate([pt, dg], axis=-1), axis=-1) / n_dirs
    return emb.reshape(emb.shape[:-2] + (n_dirs * 2 * n_points,))


def _diag_free_cost(x, y, xd, yd):
    """Squared-Euclidean cost with diagonal↔diagonal transport free.

    ``xd``/``yd`` flag the diagonal-image slots of each cloud; moving mass
    along the diagonal costs nothing (the quotient-metric convention every
    exact diagram-Wasserstein formulation uses).
    """
    c = jnp.sum((x[..., :, None, :] - y[..., None, :, :]) ** 2, axis=-1)
    return jnp.where(xd[:, None] & yd[None, :], 0.0, c)


def _lse(z, axis):
    """Log-sum-exp ``m + log Σ exp(z − m)`` with all-masked rows → −inf.

    This accumulation contract — block max, shifted-exp sum, final
    ``m + log s`` — is what the blocked Pallas kernel
    (kernels/sinkhorn_lse.py) reproduces tile-by-tile: at tile-fitting
    sizes the two paths run the identical algebra in the identical order
    and agree to float32 roundoff (≤ ~1 ulp per update; XLA fusion keeps
    strict bit equality out of reach), asserted in tests and
    ``metrics_bench``.
    """
    m = jnp.max(z, axis=axis, keepdims=True)
    s = jnp.sum(jnp.exp(z - m), axis=axis)
    m = jnp.squeeze(m, axis=axis)
    return jnp.where(jnp.isfinite(m), m + jnp.log(s), -jnp.inf)


class _DenseSinkhornOps:
    """Sinkhorn update primitives on a materialized (…, M, N) cost matrix.

    O(M·N) memory per pair — fine for compacted clouds (``n_points``), the
    ceiling ``_BlockedSinkhornOps`` lifts for full diagram tensors.
    """

    def __init__(self, c):
        self.c = c

    def lse_cols(self, dual, logw, e_t):
        """(…, M): LSE over the y side, row i gets LSE_j(logw_j + (dual_j − c_ij)/ε)."""
        z = logw[..., None, :] + (dual[..., None, :] - self.c) / e_t[..., None]
        return _lse(z, -1)

    def lse_rows(self, dual, logw, e_t):
        """(…, N): LSE over the x side (transposed reduction of the same c)."""
        z = logw[..., :, None] + (dual[..., :, None] - self.c) / e_t[..., None]
        return _lse(z, -2)

    def plan_cost(self, f, g, log_a, log_b, e_t):
        """⟨P, C⟩ of the final potentials (masked pairs only)."""
        log_p = (log_a[..., :, None] + log_b[..., None, :]
                 + (f[..., :, None] + g[..., None, :] - self.c)
                 / e_t[..., None])
        pair = (jnp.isfinite(log_a)[..., :, None]
                & jnp.isfinite(log_b)[..., None, :])
        return jnp.sum(jnp.where(pair, jnp.exp(log_p) * self.c, 0.0),
                       axis=(-1, -2))

    def masked_cost_sum(self, log_a, log_b):
        """Σ of cost over valid pairs (the ε scale statistic)."""
        pair = (jnp.isfinite(log_a)[..., :, None]
                & jnp.isfinite(log_b)[..., None, :])
        return jnp.sum(jnp.where(pair, self.c, 0.0), axis=(-1, -2))


class _BlockedSinkhornOps:
    """Sinkhorn update primitives with the cost computed on the fly in VMEM
    tiles (kernels/sinkhorn_lse.py) — no (M, N) cost matrix ever exists.

    Clouds are passed as coordinate planes ``(B, 8, M)``; every reduction is
    a Pallas call with grid ``(B, M/tile, N/tile)`` and an online-LSE (or
    running-sum) accumulator in VMEM scratch, so the working set per pair is
    O(tile²) regardless of the diagram tensor size ``S``.
    """

    def __init__(self, x, y, xd, yd, tile):
        from repro.kernels import ops as kops

        self._kops = kops
        self.xp = _cloud_planes(x, xd)
        self.yp = _cloud_planes(y, yd)
        self.tile = tile

    def lse_cols(self, dual, logw, e_t):
        return self._kops.sinkhorn_lse(self.xp, self.yp, dual, logw, e_t,
                                       tile=self.tile)

    def lse_rows(self, dual, logw, e_t):
        return self._kops.sinkhorn_lse(self.yp, self.xp, dual, logw, e_t,
                                       tile=self.tile)

    def plan_cost(self, f, g, log_a, log_b, e_t):
        return self._kops.sinkhorn_pair_sum(self.xp, self.yp, f, g,
                                            log_a, log_b, e_t, mode="plan",
                                            tile=self.tile)

    def masked_cost_sum(self, log_a, log_b):
        one = jnp.ones(log_a.shape[:-1] + (1,), jnp.float32)
        zf = jnp.zeros_like(log_a)
        zg = jnp.zeros_like(log_b)
        return self._kops.sinkhorn_pair_sum(self.xp, self.yp, zf, zg,
                                            log_a, log_b, one, mode="cost",
                                            tile=self.tile)


def _cloud_planes(pts, dflag):
    """(…, M, 2) cloud + (M,) diagonal flags → (…, 8, M) coordinate planes.

    Plane 0/1 = birth/death coordinate, plane 2 = diagonal-slot flag,
    planes 3..7 zero (pads the sublane axis to the f32 tile height so the
    kernel's x/y blocks are natively tileable).
    """
    b, d = pts[..., 0], pts[..., 1]
    f = jnp.broadcast_to(dflag.astype(jnp.float32), b.shape)
    z = jnp.zeros_like(b)
    return jnp.stack([b, d, f, z, z, z, z, z], axis=-2)


def _entropic_plan_cost(pair_ops, xv, yv, scale, eps, n_iters, n_scales):
    """⟨P, C⟩ of log-domain Sinkhorn under ε-scaling (masked uniform mass).

    ``pair_ops`` supplies the two LSE reductions and the final plan cost
    (dense materialized cost, or blocked cost-on-the-fly Pallas tiles);
    ``scale`` is the per-pair cost scale ε is relative to; ``n_scales``
    stages anneal geometrically from ``eps·2^(n_scales-1)`` down to ``eps``,
    warm-starting the potentials, ``n_iters`` iterations each.
    """
    nx = jnp.maximum(jnp.sum(xv, axis=-1).astype(jnp.float32), 1.0)[..., None]
    ny = jnp.maximum(jnp.sum(yv, axis=-1).astype(jnp.float32), 1.0)[..., None]
    log_a = jnp.where(xv, -jnp.log(nx), -jnp.inf)
    log_b = jnp.where(yv, -jnp.log(ny), -jnp.inf)
    eps_ladder = eps * (2.0 ** jnp.arange(n_scales - 1, -1, -1))

    def stage(carry, eps_t):
        f, g = carry
        e_t = eps_t * scale

        def it(_, fg):
            f, g = fg
            f = -e_t * pair_ops.lse_cols(g, log_b, e_t)
            f = jnp.where(xv, f, 0.0)
            g = -e_t * pair_ops.lse_rows(f, log_a, e_t)
            g = jnp.where(yv, g, 0.0)
            return f, g

        f, g = lax.fori_loop(0, n_iters, it, (f, g))
        return (f, g), None

    (f, g), _ = lax.scan(stage, (jnp.zeros_like(log_a), jnp.zeros_like(log_b)),
                         eps_ladder)
    return pair_ops.plan_cost(f, g, log_a, log_b, eps * scale)


@partial(jax.jit, static_argnames=("k", "n_iters", "n_scales", "n_points",
                                   "impl", "tile"))
def sinkhorn_w2(d1: Diagrams, d2: Diagrams, k: int = 1, cap: float = 64.0,
                eps: float = 1e-2, n_iters: int = 50,
                n_scales: int = 6, n_points: int | None = 32,
                impl: str = "dense", tile: int = 128) -> jax.Array:
    """Debiased entropic 2-Wasserstein between dim-``k`` diagrams (batched).

    Squared-Euclidean OT between the diagonal-augmented clouds
    ``X = P1 ∪ Δ(P2)`` and ``Y = P2 ∪ Δ(P1)`` (uniform mass ``1/(n1+n2)``
    per real point; diagonal↔diagonal transport is free, which is what lets
    unmatched points pay exactly their distance-to-diagonal).  Each OT value
    comes from log-domain Sinkhorn under ε-scaling, and the estimate is the
    **Sinkhorn divergence** ``OT(μ,ν) − ½OT(μ,μ) − ½OT(ν,ν)`` — the
    self-terms cancel the entropic blur, so self-distance is exactly 0 and
    random pairs land within a few percent of
    ``reference.wasserstein_exact(q=2)``.  Returns the unnormalized value
    square-rooted: ``sqrt(divergence · (n1+n2))``.

    ``n_points`` compacts each cloud to the top points by persistence
    (``compact_top_k``) so the Sinkhorn working set is O(n_points²), not
    O(S²) — exact for diagrams with at most ``n_points`` dim-``k`` points;
    pass ``None`` to run on the full tensor.

    ``impl`` selects the update implementation: ``"dense"`` materializes the
    (2S)² cost matrices; ``"blocked"`` streams the cost tile-by-tile through
    the Pallas online-LSE kernel (kernels/sinkhorn_lse.py) so memory stays
    O(tile²) per pair — the full-tensor (``n_points=None``) regime for dense
    diagrams.  The two are bit-consistent whenever the cloud fits one tile.
    """
    if n_points is not None:
        b1, e1, sel1 = compact_top_k(d1, k, n_points, cap)
        b2, e2, sel2 = compact_top_k(d2, k, n_points, cap)
    else:
        b1, e1, sel1 = masked_points(d1, k, cap)
        b2, e2, sel2 = masked_points(d2, k, cap)
    mid1, mid2 = (b1 + e1) / 2.0, (b2 + e2) / 2.0

    # clouds: (…, 2S, 2); first S slots are points, last S diagonal images
    x = jnp.concatenate([jnp.stack([b1, e1], -1), jnp.stack([mid2, mid2], -1)],
                        axis=-2)
    y = jnp.concatenate([jnp.stack([b2, e2], -1), jnp.stack([mid1, mid1], -1)],
                        axis=-2)
    xv = jnp.concatenate([sel1, sel2], axis=-1)
    yv = jnp.concatenate([sel2, sel1], axis=-1)
    s1, s2 = sel1.shape[-1], sel2.shape[-1]
    xd = jnp.arange(s1 + s2) >= s1  # diagonal-image slots of each cloud
    yd = jnp.arange(s1 + s2) >= s2

    # the update skeleton is shared; only the cost realization differs
    lead = x.shape[:-2]
    if impl == "dense":
        ops_xy = _DenseSinkhornOps(_diag_free_cost(x, y, xd, yd))
        ops_xx = _DenseSinkhornOps(_diag_free_cost(x, x, xd, xd))
        ops_yy = _DenseSinkhornOps(_diag_free_cost(y, y, yd, yd))
    elif impl == "blocked":
        # the kernel grid carries one leading batch axis; flatten to (B, …)
        fl = lambda a: a.reshape((-1,) + a.shape[len(lead):])
        x, y, xv, yv = fl(x), fl(y), fl(xv), fl(yv)
        sel1, sel2 = fl(sel1), fl(sel2)
        ops_xy = _BlockedSinkhornOps(x, y, xd, yd, tile)
        ops_xx = _BlockedSinkhornOps(x, x, xd, xd, tile)
        ops_yy = _BlockedSinkhornOps(y, y, yd, yd, tile)
    else:
        raise ValueError(f"unknown sinkhorn impl {impl!r}; "
                         "want 'dense' or 'blocked'")

    n = (jnp.sum(sel1, axis=-1) + jnp.sum(sel2, axis=-1)).astype(jnp.float32)
    nz = jnp.maximum(n, 1.0)
    log0 = lambda v: jnp.where(v, 0.0, -jnp.inf)

    # ε relative to the mean inter-cloud cost so one setting spans filtrations
    scale = ops_xy.masked_cost_sum(log0(xv), log0(yv)) / (nz ** 2)
    scale = jnp.maximum(scale, 1e-6)[..., None]

    ot = partial(_entropic_plan_cost, scale=scale, eps=eps,
                 n_iters=n_iters, n_scales=n_scales)
    div = (ot(ops_xy, xv, yv)
           - 0.5 * ot(ops_xx, xv, xv)
           - 0.5 * ot(ops_yy, yv, yv))
    w2sq = div * n  # undo the uniform 1/(n1+n2) mass normalization
    out = jnp.where(n > 0, jnp.sqrt(jnp.maximum(w2sq, 0.0)), 0.0)
    return out.reshape(lead) if impl == "blocked" else out
