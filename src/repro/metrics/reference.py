"""Host-side exact diagram-distance oracles (NumPy / pure Python).

Parity targets for ``repro.metrics.distances`` — small diagrams only (the
assignment solvers are O(n³)).  Diagrams are plain point lists
``[(birth, death), ...]`` with ``death`` possibly ``inf``; ``cap_points``
applies the same essential-class capping convention the batched code uses
(``Diagrams.finite_points``), and ``diagrams_to_numpy`` is the bridge from
the fixed-size tensor layout.

* ``sw_dense`` — the sliced-Wasserstein distance on the identical direction
  grid as ``distances.sliced_wasserstein`` (midpoint quadrature over the
  half-circle, diagonal augmentation by the other diagram's projections),
  computed in float64 on dense point lists.  Rtol-1e-5 oracle.
* ``wasserstein_exact`` — exact q-Wasserstein with Euclidean ground metric
  via min-cost perfect matching on the standard diagonal-augmented
  (n1+n2)² cost matrix (each side padded with diagonal reservoir slots,
  reservoir↔reservoir free).  Uses ``scipy.optimize.linear_sum_assignment``
  when available, else the built-in Hungarian solver (they are
  cross-checked in tests).
* ``bottleneck_exact`` — exact bottleneck distance (L∞ ground metric):
  binary search over the candidate cost set with an augmenting-path
  bipartite feasibility matching.
"""
from __future__ import annotations

import numpy as np


def cap_points(pts, cap: float) -> list[tuple[float, float]]:
    """Apply the essential-class convention: death = min(death, cap)."""
    return [(float(b), float(min(d, cap))) for (b, d) in pts]


# ---------------------------------------------------------------------------
# dense sliced-Wasserstein (same quadrature as the batched implementation)
# ---------------------------------------------------------------------------

def sw_dense(pts1, pts2, n_dirs: int = 32) -> float:
    """Sliced-Wasserstein on the fixed direction grid, dense float64."""
    p1 = np.asarray(pts1, np.float64).reshape(-1, 2)
    p2 = np.asarray(pts2, np.float64).reshape(-1, 2)
    phi = -np.pi / 2 + np.pi * (np.arange(n_dirs) + 0.5) / n_dirs
    theta = np.stack([np.cos(phi), np.sin(phi)], axis=-1)  # (M, 2)
    diag = lambda p: np.repeat((p[:, :1] + p[:, 1:]) / 2.0, 2, axis=1)
    total = 0.0
    for t in theta:
        v1 = np.sort(np.concatenate([p1 @ t, diag(p2) @ t]))
        v2 = np.sort(np.concatenate([p2 @ t, diag(p1) @ t]))
        total += float(np.abs(v1 - v2).sum())
    return total / n_dirs


# ---------------------------------------------------------------------------
# exact q-Wasserstein (min-cost perfect matching, Euclidean ground metric)
# ---------------------------------------------------------------------------

def _assignment_cost(cost: np.ndarray) -> float:
    try:
        from scipy.optimize import linear_sum_assignment

        r, c = linear_sum_assignment(cost)
        return float(cost[r, c].sum())
    except ImportError:  # pragma: no cover - exercised via hungarian_cost
        return hungarian_cost(cost)


def hungarian_cost(cost: np.ndarray) -> float:
    """Min-cost perfect matching total, dependency-free (O(n³))."""
    n = cost.shape[0]
    if n == 0:
        return 0.0
    inf = float("inf")
    u = [0.0] * (n + 1)
    v = [0.0] * (n + 1)
    p = [0] * (n + 1)
    way = [0] * (n + 1)
    for i in range(1, n + 1):
        p[0] = i
        j0 = 0
        minv = [inf] * (n + 1)
        used = [False] * (n + 1)
        while True:
            used[j0] = True
            i0, delta, j1 = p[j0], inf, 0
            for j in range(1, n + 1):
                if not used[j]:
                    cur = cost[i0 - 1][j - 1] - u[i0] - v[j]
                    if cur < minv[j]:
                        minv[j] = cur
                        way[j] = j0
                    if minv[j] < delta:
                        delta, j1 = minv[j], j
            for j in range(n + 1):
                if used[j]:
                    u[p[j]] += delta
                    v[j] -= delta
                else:
                    minv[j] -= delta
            j0 = j1
            if p[j0] == 0:
                break
        while j0:
            j1 = way[j0]
            p[j0] = p[j1]
            j0 = j1
    return float(sum(cost[p[j] - 1][j - 1] for j in range(1, n + 1)))


def _augmented_cost(pts1, pts2, q: float, ground: str) -> np.ndarray:
    """(n1+n2)² diagonal-augmented cost matrix, entries already **^q.

    Rows: points of D1 then n2 diagonal reservoir slots; columns: points of
    D2 then n1 reservoir slots.  Point↔reservoir costs the point's distance
    to the diagonal; reservoir↔reservoir is free.
    """
    p1 = np.asarray(pts1, np.float64).reshape(-1, 2)
    p2 = np.asarray(pts2, np.float64).reshape(-1, 2)
    n1, n2 = len(p1), len(p2)
    m = n1 + n2
    c = np.zeros((m, m))
    if ground == "l2":
        dist = lambda a, b: np.hypot(a[0] - b[0], a[1] - b[1])
        diag = lambda a: (a[1] - a[0]) / np.sqrt(2.0)
    elif ground == "linf":
        dist = lambda a, b: max(abs(a[0] - b[0]), abs(a[1] - b[1]))
        diag = lambda a: (a[1] - a[0]) / 2.0
    else:
        raise ValueError(f"unknown ground metric {ground!r}")
    for i in range(n1):
        for j in range(n2):
            c[i, j] = dist(p1[i], p2[j]) ** q
        c[i, n2:] = diag(p1[i]) ** q
    for j in range(n2):
        c[n1:, j] = diag(p2[j]) ** q
    return c


def wasserstein_exact(pts1, pts2, q: float = 2.0, ground: str = "l2") -> float:
    """Exact q-Wasserstein diagram distance, ``(min matching Σ cost^q)^(1/q)``."""
    if len(pts1) == 0 and len(pts2) == 0:
        return 0.0
    total = _assignment_cost(_augmented_cost(pts1, pts2, q, ground))
    return float(max(total, 0.0) ** (1.0 / q))


# ---------------------------------------------------------------------------
# exact bottleneck (binary search + bipartite feasibility matching)
# ---------------------------------------------------------------------------

def _feasible(c: np.ndarray, t: float) -> bool:
    """Perfect matching using only edges of cost <= t (augmenting paths)."""
    m = c.shape[0]
    adj = c <= t + 1e-12
    match = np.full(m, -1, dtype=np.int64)

    def augment(i, seen):
        for j in range(m):
            if adj[i, j] and not seen[j]:
                seen[j] = True
                if match[j] < 0 or augment(match[j], seen):
                    match[j] = i
                    return True
        return False

    for i in range(m):
        if not augment(i, np.zeros(m, dtype=bool)):
            return False
    return True


def bottleneck_exact(pts1, pts2) -> float:
    """Exact bottleneck distance (L∞ ground metric, diagonal matching)."""
    if len(pts1) == 0 and len(pts2) == 0:
        return 0.0
    c = _augmented_cost(pts1, pts2, q=1.0, ground="linf")
    cand = np.unique(c)
    lo, hi = 0, len(cand) - 1
    while lo < hi:
        mid = (lo + hi) // 2
        if _feasible(c, float(cand[mid])):
            hi = mid
        else:
            lo = mid + 1
    return float(cand[lo])
