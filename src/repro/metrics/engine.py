"""MetricEngine: the one registry of diagram-distance backends.

PR 4 unified the *reduction* layer behind a pass registry
(``core/reduction.py::register_pass``); this module does the same for the
*distance* layer.  Every backend is a masked batched function over the
fixed-size :class:`~repro.core.persistence_jax.Diagrams` layout with the
common signature ``fn(d1, d2, *, k, cap, **params) -> (…,) distances``
(pairs aligned row-wise over arbitrary leading batch axes), plus a
**contract record**: is it exact, what error bound it guarantees, and what
its cost class is.  Serving code picks backends by *contract* — the
two-stage similarity drain pairs a cheap approximate stage with an exact
re-rank stage by asking the registry, not by importing distance functions
directly.

Built-in backends (``repro.metrics.distances`` / ``repro.metrics.exact``):

========================  ======  =========================================
name                      exact   notes
========================  ======  =========================================
``sw``                    no      Carrière sliced-Wasserstein on the fixed
                                  ``n_dirs`` half-circle grid (exact for
                                  the quadrature; rtol 1e-5 vs dense ref)
``sinkhorn``              no      debiased entropic W2 (≤ ~5% of exact W2;
                                  ``impl="blocked"`` streams the cost
                                  through Pallas tiles, no O(S²) matrix)
``exact_w``               yes     auction-LAP exact q-Wasserstein (0
                                  mismatches vs Hungarian; exact up to the
                                  documented top-``n_points`` compaction)
``bottleneck_approx``     no      high-q L∞ Wasserstein sandwich,
                                  ``W∞ ≤ value ≤ (2·n_points)^{1/q}·W∞``
========================  ======  =========================================

Entry points: ``compare`` (row-aligned pairs), ``pairwise`` (full Q×N cross
product) — everything downstream (serve re-rank, stream drift scoring,
benchmarks) routes through these two.
"""
from __future__ import annotations

import dataclasses
import inspect
from typing import Any, Callable, Mapping

import jax
import jax.numpy as jnp

from repro import obs
from repro.core.persistence_jax import Diagrams
from repro.metrics import exact as _exact
from repro.metrics.distances import sinkhorn_w2, sliced_wasserstein

# entry="pairwise" calls fan out into per-block compare() calls, so
# compare counts include pairwise-induced invocations; split by the
# entry label to separate them
_CALLS = obs.counter(
    "metrics.calls", help="MetricEngine entry-point invocations per backend")


@dataclasses.dataclass(frozen=True)
class MetricBackend:
    """One registered diagram-distance backend.

    ``fn(d1, d2, *, k, cap, **params)`` must accept row-aligned Diagrams
    with arbitrary leading batch axes and return ``(…,)`` distances; it
    must be masking-invariant (padding rows never contribute).

    The contract record is what serving layers select on:

    * ``exact`` — the value is the true metric (up to documented,
      parameter-controlled truncation), not an approximation;
    * ``error_bound`` — human-readable guarantee of an approximate backend
      (or the truncation caveat of an exact one);
    * ``cost_class`` — asymptotic cost per pair, in terms of the working
      width (``n_points`` / tensor size S).

    ``info_fn`` (optional) is the diagnostics-carrying variant of ``fn``:
    same ``(d1, d2, *, k, cap, **params)`` calling convention, but it
    returns ``(distances, converged, rounds, prices)`` — what serving
    layers that warm-start the solver (the SimilarityServe price cache)
    call through :func:`compare_info` instead of ``compare``.
    """

    name: str
    fn: Callable[..., jax.Array]
    exact: bool
    error_bound: str
    cost_class: str
    description: str = ""
    defaults: Mapping[str, Any] = dataclasses.field(default_factory=dict)
    params: tuple[str, ...] = ()
    info_fn: Callable[..., tuple] | None = None
    info_params: tuple[str, ...] = ()


METRIC_REGISTRY: dict[str, MetricBackend] = {}


def _fn_params(fn: Callable) -> tuple[str, ...]:
    """Tunable keyword parameters of a backend fn (beyond d1/d2/k/cap)."""
    sig = inspect.signature(fn)
    return tuple(p for p in sig.parameters
                 if p not in ("d1", "d2", "k", "cap"))


def register_metric(backend: MetricBackend,
                    overwrite: bool = False) -> MetricBackend:
    """Register a distance backend under ``backend.name`` (extension point).

    Fills ``params`` from the fn signature when not provided, so
    ``compare``/``pairwise`` can reject unknown parameters up front instead
    of failing inside a jit trace.
    """
    if not overwrite and backend.name in METRIC_REGISTRY:
        raise ValueError(f"metric backend {backend.name!r} already registered")
    if not backend.params:
        backend = dataclasses.replace(backend, params=_fn_params(backend.fn))
    if backend.info_fn is not None and not backend.info_params:
        backend = dataclasses.replace(
            backend, info_params=_fn_params(backend.info_fn))
    bad = set(backend.defaults) - set(backend.params)
    if bad:
        raise ValueError(
            f"defaults {sorted(bad)} not accepted by backend "
            f"{backend.name!r} (params: {backend.params})")
    METRIC_REGISTRY[backend.name] = backend
    return backend


def get_metric(name: str) -> MetricBackend:
    try:
        return METRIC_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown metric backend {name!r}; registered: "
            f"{sorted(METRIC_REGISTRY)}") from None


def metric_params(name: str) -> tuple[str, ...]:
    """The tunable parameter names a backend accepts (config validation)."""
    return get_metric(name).params


def compare(d1: Diagrams, d2: Diagrams, metric: str = "sw", k: int = 1,
            cap: float = 64.0, **params) -> jax.Array:
    """Row-aligned batched distances between two Diagrams under ``metric``.

    The single entry point every consumer (serve re-rank, stream drift,
    benchmarks) uses; ``params`` override the backend defaults and are
    validated against the backend's declared parameter set.
    """
    be = get_metric(metric)
    bad = set(params) - set(be.params)
    if bad:
        raise ValueError(
            f"metric {metric!r} does not accept {sorted(bad)}; "
            f"accepted: {sorted(be.params)}")
    kwargs = dict(be.defaults)
    kwargs.update(params)
    _CALLS.inc(backend=metric, entry="compare")
    with obs.span("metrics.compare", backend=metric):
        return be.fn(d1, d2, k=k, cap=cap, **kwargs)


def compare_info(d1: Diagrams, d2: Diagrams, metric: str = "exact_w",
                 k: int = 1, cap: float = 64.0, **params) -> tuple:
    """``compare`` with solver diagnostics: ``(w, converged, rounds, prices)``.

    Routes through the backend's ``info_fn`` — the entry point for callers
    that feed solver state back in (the serve-level price cache passes
    ``prices=`` warm starts and stores the returned converged vectors).
    Only backends registering an ``info_fn`` support it (``exact_w``).
    """
    be = get_metric(metric)
    if be.info_fn is None:
        raise ValueError(
            f"metric {metric!r} has no diagnostics entry point (info_fn); "
            "use compare()")
    bad = set(params) - set(be.info_params)
    if bad:
        raise ValueError(
            f"metric {metric!r} info_fn does not accept {sorted(bad)}; "
            f"accepted: {sorted(be.info_params)}")
    kwargs = {p: v for p, v in be.defaults.items() if p in be.info_params}
    kwargs.update(params)
    _CALLS.inc(backend=metric, entry="compare_info")
    with obs.span("metrics.compare_info", backend=metric):
        return be.info_fn(d1, d2, k=k, cap=cap, **kwargs)


def pairwise(d1: Diagrams, d2: Diagrams | None = None, metric: str = "sw",
             k: int = 1, cap: float = 64.0, block_rows: int | None = None,
             **params) -> jax.Array:
    """(Q, N) cross-product distance matrix under ``metric``.

    ``d1`` carries Q leading rows, ``d2`` N rows (``None`` → ``d1`` vs
    itself).  Rows are broadcast pairwise and evaluated through the same
    backend fn as ``compare`` — for the true pair-*dependent* metrics this
    is the honest N² evaluation (the embedding Gram of
    ``kernels/pairwise_gram.py`` is the cheap pair-independent coarse
    surface, served by ``TopoIndex``).  ``block_rows`` chunks the query
    axis to bound the Q·N working set of expensive backends.
    """
    if d2 is None:
        d2 = d1
    n = d2.birth.shape[0]
    _CALLS.inc(backend=metric, entry="pairwise")

    def tile_pair(da: Diagrams):
        q = da.birth.shape[0]
        left = jax.tree.map(
            lambda x: jnp.broadcast_to(x[:, None], (q, n) + x.shape[1:]), da)
        right = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None, :], (q, n) + x.shape[1:]), d2)
        return compare(left, right, metric=metric, k=k, cap=cap, **params)

    with obs.span("metrics.pairwise", backend=metric,
                  shape=f"Q{d1.birth.shape[0]}_N{n}"):
        if block_rows is None:
            return tile_pair(d1)
        q_total = d1.birth.shape[0]
        blocks = []
        for s in range(0, q_total, block_rows):
            blocks.append(tile_pair(
                jax.tree.map(lambda x: x[s:s + block_rows], d1)))
        return jnp.concatenate(blocks, axis=0)


# ---------------------------------------------------------------------------
# built-in backends (distances.py re-registered + the auction/exact layer)
# ---------------------------------------------------------------------------

register_metric(MetricBackend(
    name="sw",
    fn=sliced_wasserstein,
    exact=False,
    error_bound="exact on the n_dirs half-circle quadrature "
                "(rtol 1e-5 vs the dense host reference)",
    cost_class="O(n_dirs · S log S) per pair",
    description="Carrière sliced-Wasserstein, pair-dependent diagonal "
                "augmentation",
))
register_metric(MetricBackend(
    name="sinkhorn",
    fn=sinkhorn_w2,
    exact=False,
    error_bound="debiased entropic W2, ≤ ~5% of exact W2 at the default "
                "ε ladder (self-distance exactly 0)",
    cost_class="O(P² · iters) dense, O(tile² · iters) blocked "
               "(P = n_points or full 2S)",
    description="log-domain ε-scaled Sinkhorn divergence; impl='blocked' "
                "streams the cost through Pallas VMEM tiles",
))
register_metric(MetricBackend(
    name="exact_w",
    fn=_exact.exact_w,
    info_fn=_exact.exact_w_full,
    exact=True,
    error_bound="exact min-cost matching (0 mismatches vs the Hungarian "
                "oracle; exact up to top-n_points compaction)",
    cost_class="O(P² · rounds) per pair; P = n_points collapsed "
               "(collapse='on'), 2·n_points expanded",
    description="batched auction-LAP q-Wasserstein: reservoir-collapsed "
                "forward/reverse auction (warm-startable prices via "
                "compare_info) or the legacy expanded matrix "
                "(collapse='off')",
))
register_metric(MetricBackend(
    name="bottleneck_approx",
    fn=_exact.bottleneck_approx,
    exact=False,
    error_bound="within max_cost · 2^-n_iters of exact W∞ on the "
                "compacted clouds (≈1e-7 relative at the default), plus "
                "the top-n_points compaction",
    cost_class="O(n_iters · P² · rounds) per pair, P = 2·n_points",
    description="threshold bisection with batched 0/1 auction feasibility "
                "solves; reference.bottleneck_exact is the exact oracle",
))
