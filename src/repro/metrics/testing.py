"""Synthetic Diagrams generators shared by tests and benchmarks.

The parity sweeps (tests/test_metrics.py, benchmarks/metrics_bench.py) need
random small diagrams in the exact tensor conventions of
:class:`~repro.core.persistence_jax.Diagrams` — NaN birth/death sentinels on
invalid rows, ``dim = -1`` padding, points scattered into arbitrary rows.
One definition here keeps the sentinel convention from silently diverging
between the two sweeps.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.persistence_jax import Diagrams


def random_diagram(rng: np.random.Generator, s: int = 12,
                   n: int | None = None, essential: int = 0, k: int = 1,
                   scatter: bool = True) -> Diagrams:
    """A random dim-``k`` Diagrams tensor of size ``s`` with ``n`` points.

    ``essential`` of the points get ``death = +inf``; ``scatter`` places
    points in random rows (exercising padding invariance) instead of the
    leading slots.  ``n`` defaults to uniform 0..8.
    """
    n = int(rng.integers(0, 9)) if n is None else n
    b = np.full(s, np.nan, np.float32)
    d = np.full(s, np.nan, np.float32)
    dim = np.full(s, -1, np.int32)
    val = np.zeros(s, bool)
    bs = rng.uniform(0, 8, n).astype(np.float32)
    ds = bs + rng.uniform(0.2, 6, n).astype(np.float32)
    ds[:essential] = np.inf
    idx = rng.permutation(s)[:n] if scatter else np.arange(n)
    b[idx], d[idx], dim[idx], val[idx] = bs, ds, k, True
    return Diagrams(birth=jnp.asarray(b), death=jnp.asarray(d),
                    dim=jnp.asarray(dim), valid=jnp.asarray(val))


def seed_diagram_arrays(rng: np.random.Generator, n_seeds: int, s: int):
    """Seed diagrams as plain arrays ``(birth, death, dim, valid)``.

    The raw material for :func:`noisy_copies` — kept as numpy arrays so
    corpora of noisy copies can be built vectorized.
    """
    sb = np.full((n_seeds, s), np.nan, np.float32)
    sd = np.full((n_seeds, s), np.nan, np.float32)
    dims = np.full((n_seeds, s), -1, np.int32)
    val = np.zeros((n_seeds, s), bool)
    for j in range(n_seeds):
        dg = random_diagram(rng, s=s, n=int(rng.integers(3, 8)))
        sb[j], sd[j] = np.asarray(dg.birth), np.asarray(dg.death)
        dims[j], val[j] = np.asarray(dg.dim), np.asarray(dg.valid)
    return sb, sd, dims, val


def noisy_copies(seeds, rng: np.random.Generator, n: int,
                 sigma_lo: float, sigma_hi: float) -> Diagrams:
    """(n,) Diagrams batch of noisy seed copies (retrieval corpora).

    Cycles through the seeds with per-copy noise graded uniformly in
    ``[sigma_lo, sigma_hi]`` — neighbor ranks become continuous (no ties),
    which is what the retrieve→re-rank recall sweeps need.  Deaths are
    clamped to ``birth + 1e-3`` so persistence stays positive.
    """
    sb, sd, dims, val = seeds
    n_seeds, s = sb.shape
    rep = np.arange(n) % n_seeds
    sigma = (sigma_lo + (sigma_hi - sigma_lo)
             * rng.random(n)).astype(np.float32)[:, None]
    b = sb[rep] + rng.normal(0, 1, (n, s)).astype(np.float32) * sigma
    d = sd[rep] + rng.normal(0, 1, (n, s)).astype(np.float32) * sigma
    d = np.maximum(d, b + 1e-3)
    return Diagrams(birth=jnp.asarray(b), death=jnp.asarray(d),
                    dim=jnp.asarray(dims[rep]), valid=jnp.asarray(val[rep]))


def diagram_points(d: Diagrams, k: int = 1, cap: float = 64.0):
    """Host-side ``[(birth, death)]`` extraction with the ``cap`` convention
    (the bridge from the tensor layout to the reference oracles)."""
    from repro.metrics.reference import cap_points

    b, dd = np.asarray(d.birth), np.asarray(d.death)
    sel = np.asarray(d.valid) & (np.asarray(d.dim) == k)
    return cap_points(list(zip(b[sel], dd[sel])), cap)
