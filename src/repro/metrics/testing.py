"""Synthetic Diagrams generators shared by tests and benchmarks.

The parity sweeps (tests/test_metrics.py, benchmarks/metrics_bench.py) need
random small diagrams in the exact tensor conventions of
:class:`~repro.core.persistence_jax.Diagrams` — NaN birth/death sentinels on
invalid rows, ``dim = -1`` padding, points scattered into arbitrary rows.
One definition here keeps the sentinel convention from silently diverging
between the two sweeps.
"""
from __future__ import annotations

import numpy as np

import jax.numpy as jnp

from repro.core.persistence_jax import Diagrams


def random_diagram(rng: np.random.Generator, s: int = 12,
                   n: int | None = None, essential: int = 0, k: int = 1,
                   scatter: bool = True) -> Diagrams:
    """A random dim-``k`` Diagrams tensor of size ``s`` with ``n`` points.

    ``essential`` of the points get ``death = +inf``; ``scatter`` places
    points in random rows (exercising padding invariance) instead of the
    leading slots.  ``n`` defaults to uniform 0..8.
    """
    n = int(rng.integers(0, 9)) if n is None else n
    b = np.full(s, np.nan, np.float32)
    d = np.full(s, np.nan, np.float32)
    dim = np.full(s, -1, np.int32)
    val = np.zeros(s, bool)
    bs = rng.uniform(0, 8, n).astype(np.float32)
    ds = bs + rng.uniform(0.2, 6, n).astype(np.float32)
    ds[:essential] = np.inf
    idx = rng.permutation(s)[:n] if scatter else np.arange(n)
    b[idx], d[idx], dim[idx], val[idx] = bs, ds, k, True
    return Diagrams(birth=jnp.asarray(b), death=jnp.asarray(d),
                    dim=jnp.asarray(dim), valid=jnp.asarray(val))


def diagram_points(d: Diagrams, k: int = 1, cap: float = 64.0):
    """Host-side ``[(birth, death)]`` extraction with the ``cap`` convention
    (the bridge from the tensor layout to the reference oracles)."""
    from repro.metrics.reference import cap_points

    b, dd = np.asarray(d.birth), np.asarray(d.death)
    sel = np.asarray(d.valid) & (np.asarray(d.dim) == k)
    return cap_points(list(zip(b[sel], dd[sel])), cap)
