"""Exact q-Wasserstein on accelerator: auction-LAP over compacted clouds.

``repro.metrics.reference.wasserstein_exact`` solves the standard
diagonal-augmented assignment problem host-side (scipy / Hungarian, one
small pair at a time).  This module is the batched accelerator-resident
formulation of the *same* problem: both diagrams are compacted to the
shared fixed-width top-persistence cloud (``distances.compact_top_k``), the
(2·n_points)² augmented cost matrix is built with masked arithmetic, and
the matching is solved by the batched Pallas auction kernel
(``kernels/auction_lap.py``) — jit/vmap-able over arbitrary leading pair
axes, which is what makes exact distances servable (the re-rank stage of
``serve/similarity.py``).

Augmented-matrix convention (identical to the host reference): rows are
the points of D1 followed by diagonal "reservoir" slots, columns the
points of D2 followed by reservoirs; point↔reservoir costs the point's
distance to the diagonal (**q), reservoir↔reservoir is free.  Invalid
compacted slots behave exactly like reservoir slots, so the fixed-width
problem has the same optimal total as the reference's (n1+n2)² one — the
extra slots only add free reservoir↔reservoir matches.

Exactness: ``exact_w`` is exact up to (a) the documented top-``n_points``
persistence truncation (exact whenever each diagram has ≤ ``n_points``
dim-``k`` points) and (b) the auction's ``M·ε_final``-suboptimality bound,
which in float32 practice resolves to the true optimum (0 mismatches vs
the Hungarian oracle across the test/bench sweeps).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.persistence_jax import Diagrams
from repro.kernels import ops
from repro.metrics.distances import compact_top_k

GROUNDS = ("l2", "linf")


def augmented_cost(b1, e1, keep1, b2, e2, keep2, q: float = 2.0,
                   ground: str = "l2"):
    """Batched (…, 2K, 2K) diagonal-augmented assignment costs, entries **q.

    ``(b, e, keep)`` per side are fixed-width compacted clouds
    (``compact_top_k``).  Invalid slots act as extra diagonal reservoirs
    (zero cost against other reservoirs / invalid slots), preserving the
    host reference's optimum.
    """
    if ground not in GROUNDS:
        raise ValueError(f"unknown ground metric {ground!r}; want {GROUNDS}")
    k = b1.shape[-1]
    db = b1[..., :, None] - b2[..., None, :]
    de = e1[..., :, None] - e2[..., None, :]
    if ground == "l2":
        dsq = db * db + de * de
        pp = dsq if q == 2.0 else dsq ** (q / 2.0)
        diag1 = ((e1 - b1) / jnp.sqrt(2.0)) ** q
        diag2 = ((e2 - b2) / jnp.sqrt(2.0)) ** q
    else:
        pp = jnp.maximum(jnp.abs(db), jnp.abs(de)) ** q
        diag1 = ((e1 - b1) / 2.0) ** q
        diag2 = ((e2 - b2) / 2.0) ** q

    pad_tail = [(0, 0)] * (b1.ndim - 1) + [(0, k)]
    rp = jnp.pad(keep1, pad_tail)            # (…, 2K) row is a real point
    cp = jnp.pad(keep2, pad_tail)
    d1 = jnp.pad(jnp.where(keep1, diag1, 0.0), pad_tail)
    d2 = jnp.pad(jnp.where(keep2, diag2, 0.0), pad_tail)
    pp_full = jnp.pad(pp, [(0, 0)] * (pp.ndim - 2) + [(0, k), (0, k)])
    cost = jnp.where(
        rp[..., :, None] & cp[..., None, :], pp_full,
        jnp.where(rp[..., :, None], d1[..., :, None],
                  jnp.where(cp[..., None, :], d2[..., None, :], 0.0)))
    return cost


@partial(jax.jit, static_argnames=("k", "q", "ground", "n_points",
                                   "n_scales"))
def exact_w_info(d1: Diagrams, d2: Diagrams, k: int = 1, q: float = 2.0,
                 ground: str = "l2", cap: float = 64.0, n_points: int = 16,
                 n_scales: int = 10):
    """``exact_w`` plus per-pair solver diagnostics.

    Returns ``(w, converged, rounds)`` with ``w`` the q-Wasserstein
    distances, ``converged`` whether the reported matching came from one of
    the two finest ε rungs (the tight-suboptimality guarantee — see
    ``kernels/auction_lap.py::auction_solve``), and ``rounds`` the total
    bidding rounds (the ε-scaling convergence surface the tests probe).
    """
    b1, e1, k1 = compact_top_k(d1, k, n_points, cap)
    b2, e2, k2 = compact_top_k(d2, k, n_points, cap)
    cost = augmented_cost(b1, e1, k1, b2, e2, k2, q=q, ground=ground)
    lead = cost.shape[:-2]
    flat = cost.reshape((-1,) + cost.shape[-2:])
    _, total, conv, rounds = ops.auction_lap(flat, n_scales=n_scales)
    w = jnp.maximum(total, 0.0) ** (1.0 / q)
    return w.reshape(lead), conv.reshape(lead), rounds.reshape(lead)


def exact_w(d1: Diagrams, d2: Diagrams, k: int = 1, q: float = 2.0,
            ground: str = "l2", cap: float = 64.0, n_points: int = 16,
            n_scales: int = 10) -> jax.Array:
    """Exact q-Wasserstein between dim-``k`` diagrams (batched, auction-LAP).

    The accelerator-resident equivalent of
    ``reference.wasserstein_exact(q, ground)`` — exact up to the documented
    top-``n_points`` compaction.  Leaves may carry arbitrary leading batch
    axes (pairs aligned row-wise); returns ``(…,)`` distances.
    """
    w, _, _ = exact_w_info(d1, d2, k=k, q=q, ground=ground, cap=cap,
                           n_points=n_points, n_scales=n_scales)
    return w


@partial(jax.jit, static_argnames=("k", "n_points", "n_iters"))
def bottleneck_approx(d1: Diagrams, d2: Diagrams, k: int = 1,
                      cap: float = 64.0, n_points: int = 16,
                      n_iters: int = 24) -> jax.Array:
    """Bottleneck distance via batched threshold search (auction feasibility).

    The bottleneck distance is the smallest ``t`` admitting a perfect
    matching that uses only L∞ costs ≤ ``t`` — the same binary search
    ``reference.bottleneck_exact`` runs host-side, except the feasibility
    oracle here is the batched auction kernel on a 0/1 cost matrix
    (``c ≤ t`` → 0, else 1): a zero-total assignment exists iff ``t`` is
    feasible, and 0/1 auctions converge in a handful of rounds.  ``n_iters``
    midpoint bisections bound the answer within ``max_cost · 2^-n_iters``
    of the exact bottleneck on the compacted clouds (≈1e-7 relative at the
    default), so the only structural approximation left is the documented
    top-``n_points`` compaction — the registry records both.
    """
    b1, e1, k1 = compact_top_k(d1, k, n_points, cap)
    b2, e2, k2 = compact_top_k(d2, k, n_points, cap)
    c1 = augmented_cost(b1, e1, k1, b2, e2, k2, q=1.0, ground="linf")
    lead = c1.shape[:-2]
    flat = c1.reshape((-1,) + c1.shape[-2:])
    hi = jnp.max(flat, axis=(-1, -2))
    lo = jnp.zeros_like(hi)
    # the 0/1 feasibility read (total < 0.5) is only sound if the auction's
    # M·ε_final suboptimality stays below ½ a unit cost — deepen the ε
    # ladder with the matrix size (M = 2·n_points) so it always does
    m = 2 * n_points
    n_scales = max(4, int(np.ceil(np.log(4.0 * m) / np.log(5.0))) + 1)

    def bisect(_, bounds):
        lo, hi = bounds
        t = (lo + hi) / 2.0
        cost01 = jnp.where(flat <= t[:, None, None], 0.0, 1.0)
        _, total, conv, _ = ops.auction_lap(cost01, n_scales=n_scales)
        # an unconverged solve is untrusted: treat as infeasible, which can
        # only push the (upper-bound) answer up, never below W∞
        feasible = (total < 0.5) & conv
        return jnp.where(feasible, lo, t), jnp.where(feasible, t, hi)

    lo, hi = jax.lax.fori_loop(0, n_iters, bisect, (lo, hi))
    return hi.reshape(lead)
