"""Exact q-Wasserstein on accelerator: auction-LAP over compacted clouds.

``repro.metrics.reference.wasserstein_exact`` solves the standard
diagonal-augmented assignment problem host-side (scipy / Hungarian, one
small pair at a time).  This module is the batched accelerator-resident
formulation of the *same* problem: both diagrams are compacted to the
shared fixed-width top-persistence cloud (``distances.compact_top_k``) and
the matching is solved by a batched Pallas auction kernel
(``kernels/auction_lap.py``) — jit/vmap-able over arbitrary leading pair
axes, which is what makes exact distances servable (the re-rank stage of
``serve/similarity.py``).

Two equivalent formulations, selected by ``collapse``:

* ``"off"`` — the legacy *expanded* path: rows are the points of D1
  followed by diagonal "reservoir" slots, columns the points of D2
  followed by reservoirs; point↔reservoir costs the point's distance to
  the diagonal (**q), reservoir↔reservoir is free.  Invalid compacted
  slots behave exactly like reservoir slots, so the fixed-width problem
  has the same optimal total as the reference's (n1+n2)² one.  The M
  identical reservoir rows/columns tie-fight, costing ~1.3k bidding
  rounds per pair.
* ``"on"`` (default) — the *collapsed* path: the identical reservoir
  rows/columns are detected by construction and folded into one
  multi-unit pseudo-slot, leaving the K×K *reduced* cost
  ``cbar[i, j] = pp[i, j] − diag1[i] − diag2[j]`` plus the constant
  ``base = Σ diag1 + Σ diag2``; ``W_q^q = base + min partial matching of
  cbar``, solved by the combined forward/reverse auction
  (``auction_solve_collapsed``) in ~30 rounds instead of ~1.3k — and it
  accepts/returns *price vectors* for LSH-bucket warm starts across
  near-duplicate pairs.

Exactness: ``exact_w`` is exact up to (a) the documented top-``n_points``
persistence truncation (exact whenever each diagram has ≤ ``n_points``
dim-``k`` points) and (b) the auction's ``M·ε_final``-suboptimality bound,
which in float32 practice resolves to the true optimum (0 mismatches vs
the Hungarian oracle across the test/bench sweeps, both formulations).
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.persistence_jax import Diagrams
from repro.kernels import ops, tuning
from repro.metrics.distances import compact_top_k

GROUNDS = ("l2", "linf")
COLLAPSE_MODES = ("on", "off")


def cloud_costs(b1, e1, keep1, b2, e2, keep2, q: float = 2.0,
                ground: str = "l2"):
    """The three cost surfaces of the augmented problem, entries **q.

    Returns ``(pp, diag1, diag2)``: point↔point costs (…, K, K) and each
    side's point↔diagonal costs (…, K) (zeroed at invalid slots).  Both
    the expanded matrix (``augmented_cost``) and the collapsed reduced
    matrix (``collapsed_cost``) are assembled from these — one definition
    of the ground metric, two solver layouts.
    """
    if ground not in GROUNDS:
        raise ValueError(f"unknown ground metric {ground!r}; want {GROUNDS}")
    db = b1[..., :, None] - b2[..., None, :]
    de = e1[..., :, None] - e2[..., None, :]
    if ground == "l2":
        dsq = db * db + de * de
        pp = dsq if q == 2.0 else dsq ** (q / 2.0)
        diag1 = ((e1 - b1) / jnp.sqrt(2.0)) ** q
        diag2 = ((e2 - b2) / jnp.sqrt(2.0)) ** q
    else:
        pp = jnp.maximum(jnp.abs(db), jnp.abs(de)) ** q
        diag1 = ((e1 - b1) / 2.0) ** q
        diag2 = ((e2 - b2) / 2.0) ** q
    diag1 = jnp.where(keep1, diag1, 0.0)
    diag2 = jnp.where(keep2, diag2, 0.0)
    return pp, diag1, diag2


def augmented_cost(b1, e1, keep1, b2, e2, keep2, q: float = 2.0,
                   ground: str = "l2"):
    """Batched (…, 2K, 2K) diagonal-augmented assignment costs, entries **q.

    ``(b, e, keep)`` per side are fixed-width compacted clouds
    (``compact_top_k``).  Invalid slots act as extra diagonal reservoirs
    (zero cost against other reservoirs / invalid slots), preserving the
    host reference's optimum.  This is the ``collapse="off"`` layout; the
    reservoir rows/columns it pads in are all identical — which is exactly
    what ``collapsed_cost`` exploits.
    """
    k = b1.shape[-1]
    pp, diag1, diag2 = cloud_costs(b1, e1, keep1, b2, e2, keep2, q=q,
                                   ground=ground)
    pad_tail = [(0, 0)] * (b1.ndim - 1) + [(0, k)]
    rp = jnp.pad(keep1, pad_tail)            # (…, 2K) row is a real point
    cp = jnp.pad(keep2, pad_tail)
    d1 = jnp.pad(diag1, pad_tail)
    d2 = jnp.pad(diag2, pad_tail)
    pp_full = jnp.pad(pp, [(0, 0)] * (pp.ndim - 2) + [(0, k), (0, k)])
    cost = jnp.where(
        rp[..., :, None] & cp[..., None, :], pp_full,
        jnp.where(rp[..., :, None], d1[..., :, None],
                  jnp.where(cp[..., None, :], d2[..., None, :], 0.0)))
    return cost


def collapsed_cost(b1, e1, keep1, b2, e2, keep2, q: float = 2.0,
                   ground: str = "l2"):
    """Reservoir-collapsed reduced costs: ``(cbar (…, K, K), base (…,))``.

    Every reservoir row/column of the expanded matrix is identical, so
    the whole reservoir block collapses into the constant
    ``base = Σ diag1 + Σ diag2`` (everything goes to the diagonal) plus
    the reduced cost ``cbar[i, j] = pp[i, j] − diag1[i] − diag2[j]`` of
    *choosing* to match (i, j) instead:
    ``W_q^q = base + min over partial matchings Σ cbar`` — a K×K
    multi-unit (transportation) auction instead of a (2K)² one.
    """
    pp, diag1, diag2 = cloud_costs(b1, e1, keep1, b2, e2, keep2, q=q,
                                   ground=ground)
    cbar = pp - diag1[..., :, None] - diag2[..., None, :]
    base = jnp.sum(diag1, axis=-1) + jnp.sum(diag2, axis=-1)
    return cbar, base


def _resolve_collapse(collapse: str | None) -> str:
    mode = collapse
    if mode is None:
        mode = tuning.resolve_tiles("auction_collapsed")["collapse"]
    if mode not in COLLAPSE_MODES:
        raise ValueError(
            f"unknown collapse mode {mode!r}; want {COLLAPSE_MODES}")
    return mode


@partial(jax.jit, static_argnames=("k", "q", "ground", "n_points",
                                   "n_scales"))
def _expanded_info(d1: Diagrams, d2: Diagrams, k: int, q: float,
                   ground: str, cap: float, n_points: int, n_scales: int):
    b1, e1, k1 = compact_top_k(d1, k, n_points, cap)
    b2, e2, k2 = compact_top_k(d2, k, n_points, cap)
    cost = augmented_cost(b1, e1, k1, b2, e2, k2, q=q, ground=ground)
    lead = cost.shape[:-2]
    flat = cost.reshape((-1,) + cost.shape[-2:])
    _, total, conv, rounds = ops.auction_lap(flat, n_scales=n_scales)
    w = jnp.maximum(total, 0.0) ** (1.0 / q)
    return w.reshape(lead), conv.reshape(lead), rounds.reshape(lead)


@partial(jax.jit, static_argnames=("k", "q", "ground", "n_points",
                                   "n_scales"))
def _collapsed_info(d1: Diagrams, d2: Diagrams, prices, k: int, q: float,
                    ground: str, cap: float, n_points: int, n_scales: int):
    b1, e1, k1 = compact_top_k(d1, k, n_points, cap)
    b2, e2, k2 = compact_top_k(d2, k, n_points, cap)
    cbar, base = collapsed_cost(b1, e1, k1, b2, e2, k2, q=q, ground=ground)
    lead = cbar.shape[:-2]
    flat = cbar.reshape((-1, n_points, n_points))
    k1f = jnp.broadcast_to(k1, lead + (n_points,)).reshape(-1, n_points)
    k2f = jnp.broadcast_to(k2, lead + (n_points,)).reshape(-1, n_points)
    pf = jnp.broadcast_to(prices, lead + (n_points,)).reshape(-1, n_points)
    _, red, conv, rounds, price = ops.auction_lap_collapsed(
        flat, k1f, k2f, pf, n_scales=n_scales)
    total = base.reshape(-1) + red
    w = jnp.maximum(total, 0.0) ** (1.0 / q)
    return (w.reshape(lead), conv.reshape(lead), rounds.reshape(lead),
            price.reshape(lead + (n_points,)))


def exact_w_full(d1: Diagrams, d2: Diagrams, k: int = 1, q: float = 2.0,
                 ground: str = "l2", cap: float = 64.0, n_points: int = 16,
                 n_scales: int = 10, collapse: str | None = None,
                 prices: jax.Array | None = None):
    """``exact_w`` plus solver diagnostics *and* warm-startable prices.

    Returns ``(w, converged, rounds, prices_out)``.  ``collapse`` picks
    the solver layout (``None`` → the pinned/tuned default, normally
    ``"on"``).  On the collapsed path, ``prices`` is an optional
    ``lead + (n_points,)`` warm-start price array in the solver's
    max-normalized units and ``prices_out`` is the converged price vector
    per pair — cache it keyed by the query's LSH bucket and feed it back
    for near-duplicate pairs (any nonnegative vector is *safe*; a good
    one is *fast*).  The expanded path ignores ``prices`` and returns
    zeros (its price vector lives on the 2K-wide matrix and is not cached).
    """
    mode = _resolve_collapse(collapse)
    lead = jnp.broadcast_shapes(d1.birth.shape[:-1], d2.birth.shape[:-1])
    if mode == "off":
        w, conv, rounds = _expanded_info(d1, d2, k, q, ground, cap,
                                         n_points, n_scales)
        return w, conv, rounds, jnp.zeros(lead + (n_points,), jnp.float32)
    if prices is None:
        prices = jnp.zeros(lead + (n_points,), jnp.float32)
    return _collapsed_info(d1, d2, prices, k, q, ground, cap, n_points,
                           n_scales)


def exact_w_info(d1: Diagrams, d2: Diagrams, k: int = 1, q: float = 2.0,
                 ground: str = "l2", cap: float = 64.0, n_points: int = 16,
                 n_scales: int = 10, collapse: str | None = None):
    """``exact_w`` plus per-pair solver diagnostics.

    Returns ``(w, converged, rounds)`` with ``w`` the q-Wasserstein
    distances, ``converged`` whether the reported matching came from one of
    the two finest ε rungs (the tight-suboptimality guarantee — see
    ``kernels/auction_lap.py``), and ``rounds`` the total bidding rounds
    (the ε-scaling convergence surface the tests and PerfGate probe).
    """
    w, conv, rounds, _ = exact_w_full(d1, d2, k=k, q=q, ground=ground,
                                      cap=cap, n_points=n_points,
                                      n_scales=n_scales, collapse=collapse)
    return w, conv, rounds


def exact_w(d1: Diagrams, d2: Diagrams, k: int = 1, q: float = 2.0,
            ground: str = "l2", cap: float = 64.0, n_points: int = 16,
            n_scales: int = 10, collapse: str | None = None) -> jax.Array:
    """Exact q-Wasserstein between dim-``k`` diagrams (batched, auction-LAP).

    The accelerator-resident equivalent of
    ``reference.wasserstein_exact(q, ground)`` — exact up to the documented
    top-``n_points`` compaction.  Leaves may carry arbitrary leading batch
    axes (pairs aligned row-wise); returns ``(…,)`` distances.
    """
    w, _, _ = exact_w_info(d1, d2, k=k, q=q, ground=ground, cap=cap,
                           n_points=n_points, n_scales=n_scales,
                           collapse=collapse)
    return w


@partial(jax.jit, static_argnames=("k", "n_points", "n_iters"))
def bottleneck_approx(d1: Diagrams, d2: Diagrams, k: int = 1,
                      cap: float = 64.0, n_points: int = 16,
                      n_iters: int = 24) -> jax.Array:
    """Bottleneck distance via batched threshold search (auction feasibility).

    The bottleneck distance is the smallest ``t`` admitting a perfect
    matching that uses only L∞ costs ≤ ``t`` — the same binary search
    ``reference.bottleneck_exact`` runs host-side.  The feasibility oracle
    here is the *collapsed* 0/1 problem: thresholding each cost surface
    gives per-slot diagonal violations ``out1 = diag1 > t`` /
    ``out2 = diag2 > t`` and pair violations ``pp > t``, and ``t`` is
    feasible iff ``Σ out1 + Σ out2 + min matching of
    (pp>t) − out1 − out2`` is 0 — the same collapsed solve ``exact_w``
    uses, so each probe pays ~tens of bidding rounds instead of
    re-fighting the full reservoir tie blowup ~``n_iters`` times.
    ``n_iters`` midpoint bisections bound the answer within
    ``max_cost · 2^-n_iters`` of the exact bottleneck on the compacted
    clouds (≈1e-7 relative at the default), so the only structural
    approximation left is the documented top-``n_points`` compaction —
    the registry records both.
    """
    b1, e1, k1 = compact_top_k(d1, k, n_points, cap)
    b2, e2, k2 = compact_top_k(d2, k, n_points, cap)
    pp, diag1, diag2 = cloud_costs(b1, e1, k1, b2, e2, k2, q=1.0,
                                   ground="linf")
    lead = pp.shape[:-2]
    kk = n_points
    ppf = jnp.broadcast_to(pp, lead + (kk, kk)).reshape(-1, kk, kk)
    d1f = jnp.broadcast_to(diag1, lead + (kk,)).reshape(-1, kk)
    d2f = jnp.broadcast_to(diag2, lead + (kk,)).reshape(-1, kk)
    k1f = jnp.broadcast_to(k1, lead + (kk,)).reshape(-1, kk)
    k2f = jnp.broadcast_to(k2, lead + (kk,)).reshape(-1, kk)
    validf = k1f[:, :, None] & k2f[:, None, :]
    hi = jnp.maximum(
        jnp.max(jnp.where(validf, ppf, 0.0), axis=(-1, -2)),
        jnp.maximum(jnp.max(d1f, axis=-1), jnp.max(d2f, axis=-1)))
    lo = jnp.zeros_like(hi)
    # the 0/1 feasibility read (< 0.5 violations) is only sound if the
    # auction's K·ε_final suboptimality stays below ½ a unit cost —
    # deepen the ε ladder with the collapsed matrix size accordingly
    n_scales = max(4, int(np.ceil(np.log(4.0 * kk) / np.log(5.0))) + 1)

    def bisect(_, bounds):
        lo, hi = bounds
        t = (lo + hi) / 2.0
        out1 = jnp.where(k1f & (d1f > t[:, None]), 1.0, 0.0)
        out2 = jnp.where(k2f & (d2f > t[:, None]), 1.0, 0.0)
        c01 = jnp.where(ppf > t[:, None, None], 1.0, 0.0)
        cbar01 = c01 - out1[:, :, None] - out2[:, None, :]
        base01 = jnp.sum(out1, axis=-1) + jnp.sum(out2, axis=-1)
        _, red, conv, _, _ = ops.auction_lap_collapsed(
            cbar01, k1f, k2f, None, n_scales=n_scales)
        # an unconverged solve is untrusted: treat as infeasible, which can
        # only push the (upper-bound) answer up, never below W∞
        feasible = (base01 + red < 0.5) & conv
        return jnp.where(feasible, lo, t), jnp.where(feasible, t, hi)

    lo, hi = jax.lax.fori_loop(0, n_iters, bisect, (lo, hi))
    return hi.reshape(lead)
