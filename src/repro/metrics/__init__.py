"""MetricEngine: batched persistence-diagram distances behind one backend
registry (docs/ARCHITECTURE.md §MetricEngine).  The batched functions
operate directly on the fixed-size ``Diagrams`` layout; ``engine`` holds
the registry + ``compare``/``pairwise`` entry points every consumer routes
through; ``reference`` holds the small-diagram host oracles everything is
parity-tested against."""
from repro.metrics.distances import (
    compact_top_k,
    direction_grid,
    masked_points,
    sinkhorn_w2,
    sliced_wasserstein,
    sw_embedding,
)
from repro.metrics.engine import (
    METRIC_REGISTRY,
    MetricBackend,
    compare,
    get_metric,
    metric_params,
    pairwise,
    register_metric,
)
from repro.metrics.exact import bottleneck_approx, exact_w, exact_w_info

__all__ = [
    "METRIC_REGISTRY",
    "MetricBackend",
    "bottleneck_approx",
    "compact_top_k",
    "compare",
    "direction_grid",
    "exact_w",
    "exact_w_info",
    "get_metric",
    "masked_points",
    "metric_params",
    "pairwise",
    "register_metric",
    "sinkhorn_w2",
    "sliced_wasserstein",
    "sw_embedding",
]
