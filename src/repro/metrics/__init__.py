"""TopoMetric: batched persistence-diagram distances + host-side exact
references (docs/ARCHITECTURE.md §TopoMetric).  The batched functions
operate directly on the fixed-size ``Diagrams`` layout; ``reference`` holds
the small-diagram oracles they are parity-tested against."""
from repro.metrics.distances import (
    direction_grid,
    masked_points,
    sinkhorn_w2,
    sliced_wasserstein,
    sw_embedding,
)

__all__ = [
    "direction_grid",
    "masked_points",
    "sinkhorn_w2",
    "sliced_wasserstein",
    "sw_embedding",
]
