"""Fault-tolerant sharded checkpointing.

Layout (one directory per step):

  ckpt_dir/
    step_000123/            # committed atomically by directory rename
      manifest.json         # mesh metadata, tree structure, stream state
      shard_00000.msgpack.zst ... one file per host (here: per save_shards)

Design points (DESIGN.md §6 fault tolerance):
  * atomic commit — write into ``step_XXXX.tmp``, fsync, rename; a crashed
    save never produces a half-readable checkpoint, restore picks the newest
    committed step.
  * elastic resharding — arrays are stored *unsharded* per leaf but split
    across shard files by leaf (round-robin by size), so restore can
    device_put onto a mesh of any shape/size (tested: save on 1 device,
    restore onto 8, and vice versa).
  * data-pipeline state travels in the manifest: restore resumes the stream
    at the exact step.
  * zstd-compressed msgpack; bf16/f32 arrays pass through raw bytes.
"""
from __future__ import annotations

import json
import os
import shutil
from typing import Any

import jax
import jax.numpy as jnp
import msgpack
import numpy as np

try:
    import zstandard as zstd
except ImportError:  # optional dep: fall back to stdlib zlib (codec is
    zstd = None      # recorded in the manifest, so restore stays compatible)
import zlib


def _compressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise ImportError("checkpoint was saved with zstd; install "
                              "zstandard to restore it")
        return zstd.ZstdCompressor(level=3).compress
    return lambda b: zlib.compress(b, 6)


def _decompressor(codec: str):
    if codec == "zstd":
        if zstd is None:
            raise ImportError("checkpoint was saved with zstd; install "
                              "zstandard to restore it")
        return zstd.ZstdDecompressor().decompress
    return zlib.decompress


_DEFAULT_CODEC = "zstd" if zstd is not None else "zlib"


def _path_str(path) -> str:
    return jax.tree_util.keystr(path)


def _encode_array(x: np.ndarray) -> dict:
    return {
        "dtype": str(x.dtype),
        "shape": list(x.shape),
        "data": x.tobytes(),
    }


def _decode_array(d: dict) -> np.ndarray:
    return np.frombuffer(d["data"], dtype=np.dtype(d["dtype"])).reshape(d["shape"])


def save(ckpt_dir: str, step: int, state: Any, *, stream_state: dict | None = None,
         save_shards: int = 4, keep: int = 3) -> str:
    """Write one committed checkpoint; prune to the newest ``keep``."""
    leaves, treedef = jax.tree_util.tree_flatten_with_path(state)
    names = [_path_str(p) for p, _ in leaves]
    arrays = [np.asarray(jax.device_get(v)) for _, v in leaves]

    # round-robin leaves into shard files by running byte count
    shard_of: list[int] = []
    sizes = [0] * save_shards
    for a in arrays:
        tgt = int(np.argmin(sizes))
        shard_of.append(tgt)
        sizes[tgt] += a.nbytes

    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = final + ".tmp"
    if os.path.exists(tmp):
        shutil.rmtree(tmp)
    os.makedirs(tmp, exist_ok=True)

    compress = _compressor(_DEFAULT_CODEC)
    for s in range(save_shards):
        payload = {
            names[i]: _encode_array(arrays[i])
            for i in range(len(arrays)) if shard_of[i] == s
        }
        blob = compress(msgpack.packb(payload, use_bin_type=True))
        with open(os.path.join(tmp, f"shard_{s:05d}.msgpack.zst"), "wb") as f:
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())

    manifest = {
        "step": step,
        "codec": _DEFAULT_CODEC,
        "n_shards": save_shards,
        "leaf_names": names,
        "leaf_shard": shard_of,
        "stream_state": stream_state or {},
        "jax_device_count_at_save": jax.device_count(),
    }
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)  # atomic commit

    _prune(ckpt_dir, keep)
    return final


def _prune(ckpt_dir: str, keep: int) -> None:
    steps = sorted(latest_steps(ckpt_dir))
    for s in steps[:-keep]:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"), ignore_errors=True)


def latest_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        if name.startswith("step_") and not name.endswith(".tmp"):
            if os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
                out.append(int(name[len("step_"):]))
    return sorted(out)


def restore(ckpt_dir: str, like: Any, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int, dict]:
    """Load the newest (or given) step into the structure of ``like``.

    ``shardings``: optional matching pytree of NamedSharding for elastic
    resharding onto the *current* mesh (may differ from the saving mesh).
    Returns (state, step, stream_state).
    """
    steps = latest_steps(ckpt_dir)
    if not steps:
        raise FileNotFoundError(f"no committed checkpoints under {ckpt_dir}")
    step = step if step is not None else steps[-1]
    d = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)

    # pre-codec manifests (no "codec" key) were always zstd
    decompress = _decompressor(manifest.get("codec", "zstd"))
    by_name: dict[str, np.ndarray] = {}
    for s in range(manifest["n_shards"]):
        with open(os.path.join(d, f"shard_{s:05d}.msgpack.zst"), "rb") as f:
            payload = msgpack.unpackb(decompress(f.read()), raw=False)
        for k, v in payload.items():
            by_name[k] = _decode_array(v)

    leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "device_set"))
        if shardings is not None else [None] * len(leaves)
    )
    out = []
    for (path, ref), shd in zip(leaves, shard_leaves):
        name = _path_str(path)
        if name not in by_name:
            raise KeyError(f"checkpoint missing leaf {name}")
        a = by_name[name]
        want = jnp.asarray(ref).dtype if not hasattr(ref, "dtype") else ref.dtype
        arr = a.astype(want) if str(want) != str(a.dtype) else a
        out.append(jax.device_put(arr, shd) if shd is not None else jnp.asarray(arr))
    state = jax.tree_util.tree_unflatten(
        jax.tree_util.tree_structure(like), out)
    return state, step, manifest.get("stream_state", {})
