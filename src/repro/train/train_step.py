"""Training step: grad accumulation (scan over microbatches), AdamW update,
optional cross-pod int8 gradient compression (shard_map variant).

The step is a single pjit-able function; all parallelism (DP over
pod×data, FSDP + TP over data/model) comes from the in/out shardings set by
the launch layer — nothing here is mesh-specific.
"""
from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import transformer as tf
from repro.models.config import ModelConfig
from repro.train.optimizer import AdamWState, adamw_update, compressed_psum


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class TrainState:
    params: Any
    opt: AdamWState


def make_train_step(cfg: ModelConfig, grad_accum: int = 1, base_lr: float = 3e-4,
                    extra_keys: tuple[str, ...] = ()):
    """Returns train_step(state, batch) -> (state, metrics).

    batch: {"tokens": (GB, S)} (+ "frames"/"vision"/"mrope_positions").
    GB must be divisible by grad_accum; microbatches are scanned and gradients
    accumulated in f32 (one grad all-reduce at the end, inserted by SPMD).
    """

    def loss_for(params, mb):
        kwargs = {k: mb[k] for k in extra_keys}
        return tf.loss_fn(params, cfg, mb["tokens"], **kwargs)

    def train_step(state: TrainState, batch):
        gb = batch["tokens"].shape[0]
        mb_size = gb // grad_accum

        def reshape(x):
            return x.reshape((grad_accum, mb_size) + x.shape[1:])

        from repro.models.pjit_utils import constrain

        micro = jax.tree.map(reshape, batch)
        micro = jax.tree.map(
            lambda x: constrain(x, None, "dp", *((None,) * (x.ndim - 2))), micro
        )
        grad_fn = jax.value_and_grad(loss_for)

        def accum(carry, mb):
            g_acc, l_acc = carry
            loss, g = grad_fn(state.params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / grad_accum, g_acc, g
            )
            return (g_acc, l_acc + loss / grad_accum), None

        g0 = jax.tree.map(
            lambda p: jnp.zeros(p.shape, jnp.float32), state.params
        )
        from repro.models.unroll import scan_unroll
        (grads, loss), _ = lax.scan(accum, (g0, jnp.float32(0.0)), micro,
                                    unroll=scan_unroll())

        gnorm = jnp.sqrt(
            sum(jnp.sum(jnp.square(g)) for g in jax.tree.leaves(grads))
        )
        clip = jnp.minimum(1.0, 1.0 / jnp.maximum(gnorm, 1e-6))
        grads = jax.tree.map(lambda g: g * clip, grads)

        new_params, new_opt, lr = adamw_update(
            grads, state.opt, state.params, base_lr=base_lr
        )
        metrics = {"loss": loss, "grad_norm": gnorm, "lr": lr}
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step


def make_compressed_pod_step(cfg: ModelConfig, mesh, base_lr: float = 3e-4):
    """Cross-pod data parallelism with int8+error-feedback grad exchange.

    Inside each pod, gradients flow through normal SPMD sharding; across the
    slow pod links, the exchange is quantized with error feedback.  State
    carries the per-pod residuals.  Implemented with shard_map over "pod".
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    def loss_for(params, tokens):
        return tf.loss_fn(params, cfg, tokens)

    def step(state: TrainState, error, tokens):
        def per_pod(params, opt_step, err, toks):
            loss, g = jax.value_and_grad(loss_for)(params, toks)
            flat_g, tdef = jax.tree.flatten(g)
            flat_e = tdef.flatten_up_to(err)
            out = [compressed_psum(gi, "pod", ei) for gi, ei in zip(flat_g, flat_e)]
            n_pods = jax.lax.psum(jnp.ones((), jnp.float32), "pod")
            g_sync = tdef.unflatten([o[0] / n_pods for o in out])
            new_err = tdef.unflatten([o[1] for o in out])
            loss = jax.lax.pmean(loss, "pod")
            return g_sync, new_err, loss

        grads, new_error, loss = shard_map(
            per_pod, mesh=mesh,
            in_specs=(P(), P(), P(), P("pod")),
            out_specs=(P(), P(), P()),
            check_rep=False,
        )(state.params, state.opt.step, error, tokens)
        new_params, new_opt, lr = adamw_update(
            grads, state.opt, state.params, base_lr=base_lr
        )
        return TrainState(params=new_params, opt=new_opt), new_error, {
            "loss": loss, "lr": lr,
        }

    return step
