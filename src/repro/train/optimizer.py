"""AdamW in pure JAX (f32 state) + int8 gradient compression with error
feedback (for the slow cross-pod link; see DESIGN.md §6).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class AdamWState:
    step: jax.Array
    m: Any
    v: Any


def adamw_init(params) -> AdamWState:
    zeros = jax.tree.map(lambda p: jnp.zeros_like(p, dtype=jnp.float32), params)
    return AdamWState(step=jnp.zeros((), jnp.int32), m=zeros,
                      v=jax.tree.map(jnp.copy, zeros))


def lr_schedule(step, base_lr=3e-4, warmup=100, total=10_000):
    warm = base_lr * (step + 1) / warmup
    prog = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = base_lr * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(step < warmup, warm, cos).astype(jnp.float32)


def adamw_update(
    grads, state: AdamWState, params, base_lr=3e-4, b1=0.9, b2=0.95,
    eps=1e-8, weight_decay=0.1, warmup=100, total=10_000,
):
    step = state.step + 1
    lr = lr_schedule(step, base_lr, warmup, total)
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32)
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * g * g
        mh = m / bc1
        vh = v / bc2
        new_p = p - lr * (mh / (jnp.sqrt(vh) + eps) + weight_decay * p)
        return new_p.astype(p.dtype), m, v

    flat_g, treedef = jax.tree.flatten(grads)
    flat_m = treedef.flatten_up_to(state.m)
    flat_v = treedef.flatten_up_to(state.v)
    flat_p = treedef.flatten_up_to(params)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    return new_p, AdamWState(step=step, m=new_m, v=new_v), lr


# ------------------------------------------------ gradient compression ----
def quantize_int8(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Symmetric per-tensor int8 quantization: returns (q, scale)."""
    amax = jnp.maximum(jnp.max(jnp.abs(g)), 1e-12)
    scale = (amax / 127.0).astype(jnp.float32)
    q = jnp.clip(jnp.round(g / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compressed_psum(g: jax.Array, axis_name: str, error: jax.Array):
    """int8 all-reduce with error feedback (call inside shard_map).

    Returns (summed_grad, new_error).  The quantization residual is carried
    into the next step so the compression is unbiased over time.
    """
    g_fb = g.astype(jnp.float32) + error
    q, scale = quantize_int8(g_fb)
    deq = dequantize_int8(q, scale)
    new_error = g_fb - deq
    # Numerics of the compressed exchange: each participant contributes its
    # int8-fidelity payload.  (On real hardware the collective itself moves
    # int8 + one f32 scale — 4x less cross-pod traffic; XLA exposes no int8
    # all-reduce so the simulation psums the dequantized values, which is
    # bit-identical to sum_i deq_i.)
    return jax.lax.psum(deq, axis_name), new_error
