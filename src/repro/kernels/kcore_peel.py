"""Pallas TPU kernel: one k-core Jacobi peel sweep (CoralTDA inner loop).

``deg[u] = Σ_w A[u, w]·alive[w];  alive'[u] = alive[u] ∧ (deg[u] ≥ k)``

Fused masked mat-vec + threshold: the degree accumulator stays in VMEM
scratch across the W tiles, the threshold is applied in the epilogue, so one
sweep is a single HBM pass over A (the sweep is memory-bound; the fixed point
driver in repro/core/kcore.py calls this until no change).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(adj_ref, alive_w_ref, alive_u_ref, k_ref, out_ref, acc_ref, *, n_w: int):
    iw = pl.program_id(2)

    @pl.when(iw == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    adj = adj_ref[0]  # (TU, TW) f32
    alive = alive_w_ref[0]  # (TW,) f32
    acc_ref[...] += lax.dot_general(
        adj, alive[:, None], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )[:, 0]

    @pl.when(iw == n_w - 1)
    def _epilogue():
        k = k_ref[0]
        out_ref[0] = (alive_u_ref[0] > 0) & (acc_ref[...] >= k)


@functools.partial(jax.jit, static_argnames=("tile_u", "tile_w", "interpret"))
def kcore_peel_pallas(
    adj: jax.Array,
    alive: jax.Array,
    k: jax.Array | int,
    tile_u: int = 128,
    tile_w: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """One peel sweep.  adj (B,N,N) bool, alive (B,N) bool, k scalar."""
    b, n, _ = adj.shape
    t = max(tile_u, tile_w)
    npad = -(-n // t) * t
    pad = npad - n
    adj_p = jnp.pad(adj, ((0, 0), (0, pad), (0, pad))).astype(jnp.float32)
    alive_p = jnp.pad(alive, ((0, 0), (0, pad)))
    alive_f = alive_p.astype(jnp.float32)
    k_arr = jnp.broadcast_to(jnp.asarray(k, jnp.float32), (1,))

    grid = (b, npad // tile_u, npad // tile_w)
    out = pl.pallas_call(
        functools.partial(_kernel, n_w=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_u, tile_w), lambda b_, u, w: (b_, u, w),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_w), lambda b_, u, w: (b_, w),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_u), lambda b_, u, w: (b_, u),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1,), lambda b_, u, w: (0,), memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, tile_u), lambda b_, u, w: (b_, u),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, npad), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((tile_u,), jnp.float32)],
        interpret=interpret,
        name="kcore_peel_sweep",
    )(adj_p, alive_f, alive_p, k_arr)
    return out[:, :n]
