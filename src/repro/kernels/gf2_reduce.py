"""Pallas TPU kernel: bit-packed GF(2) boundary-matrix reduction in VMEM.

The persistence pairing itself (the O(S^3)-worst-case stage the paper's
reductions shrink).  Columns are packed 32 simplices per uint32 word; the
whole packed matrix for one complex lives in VMEM (a 2048-simplex complex is
2048×64 u32 = 512 KiB), so the data-dependent pivot-chase never touches HBM.
Grid is a single program per complex; batching is an outer vmap at the ops
layer.

The kernel is fully caps-polymorphic: every dimension (columns S, packed
words W, owner rows) is read from the ref shapes, so one definition serves
any persist shape class — the two-phase repack path (repro/core/repack.py)
relies on this to compile the same kernel at each ladder rung's *reduced*
caps instead of the input caps, and the bounded rung ladder is what keeps
the number of compiled kernel variants small.

Matches repro.core.persistence_jax.reduce_packed bit-for-bit.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

WORD = 32


def _low_of(col: jax.Array) -> jax.Array:
    """col: (1, W) u32 -> highest set bit index or -1."""
    w = col.shape[-1]
    nz = col != 0
    iota = lax.broadcasted_iota(jnp.int32, (1, w), 1)
    widx = jnp.max(jnp.where(nz, iota, -1))
    word = jnp.max(jnp.where(iota == widx, col, jnp.uint32(0)))
    bit = 31 - lax.clz(word).astype(jnp.int32)
    return jnp.where(widx >= 0, widx * WORD + bit, -1)


def _reduce_columns(s, get_col, put_col, get_owner, put_owner,
                    put_positive):
    """The column reduction loop, parameterized over ref accessors.

    One definition serves both the flat single-matrix kernel (refs
    ``(S, W)``) and the grid-batched kernel (refs ``(1, S, W)``, one
    complex per grid step): the accessors close over the refs and hide
    the leading-axis indexing difference.
    """

    def col_body(j, _):
        def w_cond(cs):
            _, done, _ = cs
            return ~done

        def w_body(cs):
            col, _, _ = cs
            l = _low_of(col)

            def no_bits(col):
                return col, jnp.array(True), jnp.int32(-1)

            def has_bits(col):
                p = get_owner(l)

                def claim(col):
                    return col, jnp.array(True), l

                def xor(col):
                    return col ^ get_col(p), jnp.array(False), jnp.int32(-1)

                return lax.cond(p < 0, claim, xor, col)

            return lax.cond(l < 0, no_bits, has_bits, col)

        col, _, claimed = lax.while_loop(
            w_cond, w_body, (get_col(j), jnp.array(False), jnp.int32(-1))
        )
        put_col(j, col)

        @pl.when(claimed >= 0)
        def _claim():
            put_owner(claimed, j)

        put_positive(j, claimed < 0)
        return 0

    lax.fori_loop(0, s, col_body, 0)


def _kernel(b_ref, bm_ref, owner_ref, positive_ref):
    s, w = b_ref.shape
    r = owner_ref.shape[0]  # rows may differ from columns (block reduction)
    bm_ref[...] = b_ref[...]
    owner_ref[...] = jnp.full((r,), -1, jnp.int32)
    positive_ref[...] = jnp.zeros((s,), jnp.bool_)
    _reduce_columns(
        s,
        get_col=lambda j: pl.load(bm_ref, (pl.dslice(j, 1), slice(None))),
        put_col=lambda j, col: pl.store(
            bm_ref, (pl.dslice(j, 1), slice(None)), col),
        get_owner=lambda l: pl.load(owner_ref, (pl.dslice(l, 1),))[0],
        put_owner=lambda l, j: pl.store(
            owner_ref, (pl.dslice(l, 1),), jnp.full((1,), j, jnp.int32)),
        put_positive=lambda j, pos: pl.store(
            positive_ref, (pl.dslice(j, 1),),
            jnp.full((1,), pos, jnp.bool_)),
    )


def _batch_kernel(b_ref, bm_ref, owner_ref, positive_ref):
    _, s, w = b_ref.shape
    r = owner_ref.shape[-1]
    bm_ref[...] = b_ref[...]
    owner_ref[...] = jnp.full((1, r), -1, jnp.int32)
    positive_ref[...] = jnp.zeros((1, s), jnp.bool_)
    z = pl.dslice(0, 1)
    _reduce_columns(
        s,
        get_col=lambda j: pl.load(
            bm_ref, (z, pl.dslice(j, 1), slice(None)))[0],
        put_col=lambda j, col: pl.store(
            bm_ref, (z, pl.dslice(j, 1), slice(None)), col[None]),
        get_owner=lambda l: pl.load(owner_ref, (z, pl.dslice(l, 1)))[0, 0],
        put_owner=lambda l, j: pl.store(
            owner_ref, (z, pl.dslice(l, 1)), jnp.full((1, 1), j, jnp.int32)),
        put_positive=lambda j, pos: pl.store(
            positive_ref, (z, pl.dslice(j, 1)),
            jnp.full((1, 1), pos, jnp.bool_)),
    )


@functools.partial(jax.jit, static_argnames=("interpret", "n_rows"))
def gf2_reduce_pallas(b: jax.Array, interpret: bool = True,
                      n_rows: int | None = None):
    """Reduce one packed boundary matrix.  b: (S, W) uint32.

    Returns (reduced_matrix, owner, positive) — owner[i] = killing column of
    row (simplex) i or -1; positive[j] = column j reduced to zero.  n_rows
    sizes the owner vector for rectangular per-dimension blocks (defaults to
    the square case n_rows = S).
    """
    s, w = b.shape
    r = s if n_rows is None else n_rows
    bm, owner, positive = pl.pallas_call(
        _kernel,
        in_specs=[pl.BlockSpec(memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
            pl.BlockSpec(memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((s, w), jnp.uint32),
            jax.ShapeDtypeStruct((r,), jnp.int32),
            jax.ShapeDtypeStruct((s,), jnp.bool_),
        ],
        interpret=interpret,
        name="gf2_boundary_reduce",
    )(b)
    return bm, owner, positive


@functools.partial(jax.jit, static_argnames=("interpret", "n_rows"))
def gf2_reduce_batch_pallas(b: jax.Array, interpret: bool = True,
                            n_rows: int | None = None):
    """Grid-batched reduction of (B, S, W) packed matrices.

    One grid step per complex (block ``(1, S, W)`` resident in VMEM) —
    the alternative to vmapping :func:`gf2_reduce_pallas` over the batch
    (which batches every column op across complexes instead).  Which
    wins is device-dependent; ``python -m repro.perfgate tune`` times
    both and pins the winner as the ``gf2_reduce.batch_mode`` tile
    (``repro.kernels.ops.gf2_reduce_batch`` consults it).
    """
    bsz, s, w = b.shape
    r = s if n_rows is None else n_rows
    bm, owner, positive = pl.pallas_call(
        _batch_kernel,
        grid=(bsz,),
        in_specs=[pl.BlockSpec((1, s, w), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec((1, s, w), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, r), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, s), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, s, w), jnp.uint32),
            jax.ShapeDtypeStruct((bsz, r), jnp.int32),
            jax.ShapeDtypeStruct((bsz, s), jnp.bool_),
        ],
        interpret=interpret,
        name="gf2_boundary_reduce_batch",
    )(b)
    return bm, owner, positive
