"""jit'd public wrappers for the Pallas kernels.

On TPU the kernels compile natively; everywhere else they run in
``interpret=True`` mode (the kernel body executed with real JAX ops on CPU),
which is how correctness is validated in this container (see tests/).

Tile shapes resolve through :mod:`repro.kernels.tuning`: a winner pinned
by ``python -m repro.perfgate tune`` in ``results/TUNED_tiles.json`` (and
matching the current device string) overrides the hardcoded defaults;
explicit keyword arguments override both.  Absent or foreign-device files
silently fall back to the hardcoded tiles.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.kernels import tuning
from repro.kernels.auction_lap import (
    auction_lap_collapsed_pallas,
    auction_lap_pallas,
)
from repro.kernels.common_neighbors import common_neighbors_pallas
from repro.kernels.domination import domination_pallas
from repro.kernels.gf2_reduce import (
    gf2_reduce_batch_pallas,
    gf2_reduce_pallas,
)
from repro.kernels.hamming import hamming_scan_pallas, pack_codes_u32
from repro.kernels.kcore_peel import kcore_peel_pallas
from repro.kernels.pairwise_gram import pairwise_l1_pallas
from repro.kernels.sinkhorn_lse import (
    sinkhorn_lse_pallas,
    sinkhorn_pair_sum_pallas,
)


def _interpret() -> bool:
    return jax.default_backend() != "tpu"


# Per-kernel wrapper invocation counts (always on).  Under jit these
# wrappers run at *trace* time, so for jitted call sites the counter
# counts compilations, not executions; the eager call sites
# (metrics/engine.py, TopoIndex) count 1:1.
_KCALLS = obs.counter(
    "kernels.calls",
    help="Pallas kernel wrapper invocations (trace-time under jit)")


def domination(adj: jax.Array, mask: jax.Array,
               tile: int | None = None) -> jax.Array:
    """(B, N, N) dom[u, v] = "v dominates u" (closed neighborhoods)."""
    t = tuning.resolve_tiles("domination", tile=tile)["tile"]
    _KCALLS.inc(kernel="domination")
    with obs.span("kernels.domination",
                  shape=f"B{adj.shape[0]}_N{adj.shape[1]}"):
        return domination_pallas(
            adj, mask, tile_u=t, tile_v=t, tile_w=t, interpret=_interpret()
        )


def kcore_peel(adj: jax.Array, alive: jax.Array, k, tile: int = 128) -> jax.Array:
    """One k-core peel sweep over a (B, N, N) batch."""
    _KCALLS.inc(kernel="kcore_peel")
    with obs.span("kernels.kcore_peel",
                  shape=f"B{adj.shape[0]}_N{adj.shape[1]}"):
        return kcore_peel_pallas(
            adj, alive, k, tile_u=tile, tile_w=tile, interpret=_interpret()
        )


def common_neighbors(adj: jax.Array, tile: int = 128) -> jax.Array:
    """(B, N, N) i32 common-neighbor counts restricted to edges."""
    _KCALLS.inc(kernel="common_neighbors")
    with obs.span("kernels.common_neighbors",
                  shape=f"B{adj.shape[0]}_N{adj.shape[1]}"):
        return common_neighbors_pallas(adj, tile=tile,
                                       interpret=_interpret())


def gf2_reduce(b: jax.Array, n_rows: int | None = None):
    """Reduce one (S, W) packed boundary matrix -> (owner, positive).

    n_rows sizes the owner vector for rectangular per-dimension blocks
    (defaults to the square case).
    """
    _KCALLS.inc(kernel="gf2_reduce")
    with obs.span("kernels.gf2_reduce", shape=f"S{b.shape[0]}"):
        _, owner, positive = gf2_reduce_pallas(
            b, interpret=_interpret(), n_rows=n_rows)
    return owner, positive


def gf2_reduce_batch(b: jax.Array, n_rows: int | None = None,
                     batch_mode: str | None = None):
    """Reduce a (B, S, W) packed batch -> (owner (B, R), positive (B, S)).

    ``batch_mode="vmap"`` batches the column ops across complexes (one
    vectorized program); ``"grid"`` gives each complex its own grid step
    (the native TPU shape).  Defaults to the winner pinned in
    ``results/TUNED_tiles.json`` for this device, else ``"vmap"``.
    """
    mode = tuning.resolve_tiles("gf2_reduce",
                                batch_mode=batch_mode)["batch_mode"]
    _KCALLS.inc(kernel="gf2_reduce_batch")
    if mode == "grid":
        with obs.span("kernels.gf2_reduce_batch",
                      shape=f"B{b.shape[0]}_S{b.shape[1]}"):
            _, owner, positive = gf2_reduce_batch_pallas(
                b, interpret=_interpret(), n_rows=n_rows)
        return owner, positive
    if mode != "vmap":
        raise ValueError(f"unknown gf2 batch_mode {mode!r}")
    owner, positive = jax.vmap(
        lambda bb: gf2_reduce(bb, n_rows=n_rows))(b)
    return owner, positive


def pairwise_l1(x: jax.Array, y: jax.Array, tile_m: int | None = None,
                tile_n: int | None = None,
                tile_d: int | None = None) -> jax.Array:
    """(M, D) × (N, D) → (M, N) pairwise-L1 Gram over SW embeddings."""
    t = tuning.resolve_tiles("pairwise_gram", tile_m=tile_m, tile_n=tile_n,
                             tile_d=tile_d)
    _KCALLS.inc(kernel="pairwise_l1")
    with obs.span("kernels.pairwise_l1",
                  shape=f"G{max(x.shape[0], y.shape[0])}_D{x.shape[1]}"):
        return pairwise_l1_pallas(
            x, y, tile_m=t["tile_m"], tile_n=t["tile_n"], tile_d=t["tile_d"],
            interpret=_interpret())


def hamming_scan(codes_q, codes_db, mask_q=None,
                 tile_q: int | None = None,
                 tile_n: int | None = None) -> jax.Array:
    """(Q, N) int32 masked Hamming distances over packed LSH codes.

    Accepts codes either as uint8 packed bytes (the TopoIndex storage
    layout — repacked to uint32 words host-side via
    :func:`repro.kernels.hamming.pack_codes_u32`) or as ready uint32
    words.  ``mask_q`` (same packing as ``codes_q``) clears query bits
    from the distance — the multi-probe LSH trick; ``None`` means plain
    Hamming.
    """
    def as_words(a):
        a = np.asarray(a) if not isinstance(a, jax.Array) else a
        if a.dtype == jnp.uint32:
            return a
        return pack_codes_u32(np.asarray(a))

    cq = as_words(codes_q)
    cd = as_words(codes_db)
    mq = (jnp.full(np.shape(cq), 0xFFFFFFFF, jnp.uint32)
          if mask_q is None else as_words(mask_q))
    t = tuning.resolve_tiles("hamming", tile_q=tile_q, tile_n=tile_n)
    _KCALLS.inc(kernel="hamming_scan")
    with obs.span("kernels.hamming_scan",
                  shape=f"Q{cq.shape[0]}_N{cd.shape[0]}_W{cq.shape[1]}"):
        return hamming_scan_pallas(
            jnp.asarray(cq), jnp.asarray(mq), jnp.asarray(cd),
            tile_q=t["tile_q"], tile_n=t["tile_n"],
            interpret=_interpret())


def auction_lap(cost: jax.Array, n_scales: int = 10,
                max_rounds: int | None = None,
                tile_b: int | None = None):
    """Batched ε-scaled auction assignment: (B, M, M) → matchings + totals.

    Returns ``(assign, total, converged, rounds)`` — see
    ``kernels/auction_lap.py`` for the termination/optimality contract.
    ``tile_b`` pairs share one grid step (pinned winner by default).
    """
    tb = tuning.resolve_tiles("auction_lap", tile_b=tile_b)["tile_b"]
    _KCALLS.inc(kernel="auction_lap")
    with obs.span("kernels.auction_lap",
                  shape=f"B{cost.shape[0]}_M{cost.shape[1]}"):
        return auction_lap_pallas(cost, n_scales=n_scales,
                                  max_rounds=max_rounds, tile_b=tb,
                                  interpret=_interpret())


def auction_lap_collapsed(cbar: jax.Array, keep1: jax.Array,
                          keep2: jax.Array, price0: jax.Array | None = None,
                          n_scales: int = 10,
                          max_rounds: int | None = None,
                          tile_b: int | None = None,
                          rev_every: int | None = None):
    """Batched collapsed forward/reverse auction: (B, K, K) reduced costs.

    Returns ``(p2o, total, converged, rounds, price)`` — see
    ``kernels/auction_lap.py::auction_solve_collapsed`` for the contract.
    ``price0`` warm-starts the object prices (max-normalized units; any
    nonnegative vector is safe).  ``tile_b`` and ``rev_every`` (the
    forward/reverse phase ratio) resolve through the ``auction_collapsed``
    tuning entry — both are autotuner sweep axes.
    """
    cfg = tuning.resolve_tiles("auction_collapsed", tile_b=tile_b,
                               rev_every=rev_every)
    if price0 is None:
        price0 = jnp.zeros(cbar.shape[:-1], jnp.float32)
    _KCALLS.inc(kernel="auction_lap_collapsed")
    with obs.span("kernels.auction_lap_collapsed",
                  shape=f"B{cbar.shape[0]}_K{cbar.shape[1]}"):
        return auction_lap_collapsed_pallas(
            cbar, keep1, keep2, price0, n_scales=n_scales,
            max_rounds=max_rounds, tile_b=cfg["tile_b"],
            rev_every=int(cfg["rev_every"]), interpret=_interpret())


def sinkhorn_lse(xp: jax.Array, yp: jax.Array, dual: jax.Array,
                 logw: jax.Array, e_t: jax.Array,
                 tile: int | None = None) -> jax.Array:
    """Blocked online-LSE Sinkhorn half-update (cost built on the fly)."""
    t = tuning.resolve_tiles("sinkhorn_lse", tile=tile)["tile"]
    _KCALLS.inc(kernel="sinkhorn_lse")
    with obs.span("kernels.sinkhorn_lse",
                  shape=f"B{xp.shape[0]}_M{xp.shape[-1]}"):
        return sinkhorn_lse_pallas(xp, yp, dual, logw, e_t, tile_m=t,
                                   tile_n=t, interpret=_interpret())


def sinkhorn_pair_sum(xp: jax.Array, yp: jax.Array, f: jax.Array,
                      g: jax.Array, log_a: jax.Array, log_b: jax.Array,
                      e_t: jax.Array, mode: str = "plan",
                      tile: int | None = None) -> jax.Array:
    """Blocked masked pair reduction: ⟨P, C⟩ (``"plan"``) or Σc (``"cost"``)."""
    t = tuning.resolve_tiles("sinkhorn_lse", tile=tile)["tile"]
    _KCALLS.inc(kernel="sinkhorn_pair_sum")
    with obs.span("kernels.sinkhorn_pair_sum",
                  shape=f"B{xp.shape[0]}_M{xp.shape[-1]}"):
        return sinkhorn_pair_sum_pallas(xp, yp, f, g, log_a, log_b, e_t,
                                        mode=mode, tile_m=t, tile_n=t,
                                        interpret=_interpret())


def clustering_coefficients(adj: jax.Array, mask: jax.Array, tile: int = 128) -> jax.Array:
    """(B, N) local clustering coefficients via the common-neighbors kernel."""
    adj = adj & mask[:, None, :] & mask[:, :, None]
    cn = common_neighbors(adj, tile=tile)
    tri2 = jnp.sum(cn, axis=-1)  # 2 * triangles through u ... per row
    deg = jnp.sum(adj, axis=-1).astype(jnp.float32)
    denom = deg * (deg - 1.0)
    cc = jnp.where(denom > 0, tri2.astype(jnp.float32) / denom, 0.0)
    return jnp.where(mask, cc, 0.0)
