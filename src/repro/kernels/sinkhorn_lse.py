"""Pallas TPU kernels: blocked log-sum-exp Sinkhorn updates, cost on the fly.

The dense Sinkhorn path (``repro.metrics.distances._DenseSinkhornOps``)
materializes the (M, N) squared-Euclidean cost between the two
diagonal-augmented diagram clouds — an O(S²) allocation per pair that caps
how dense a diagram the entropic distance can handle (the reason
``sinkhorn_w2`` compacts clouds to ``n_points``).  These kernels lift that
ceiling: the cost block ``c_ij = (xb_i − yb_j)² + (xd_i − yd_j)²`` (zeroed
on diagonal↔diagonal slot pairs) is rebuilt inside VMEM for each
``(tile_m, tile_n)`` tile from the coordinate planes, in the style of
``pairwise_gram.py``, so per-pair memory is O(tile²) however large the
diagram tensor is.

Two reductions cover everything one Sinkhorn iteration needs:

* ``sinkhorn_lse_pallas`` — per x-row online log-sum-exp over the y side:
  ``out_i = LSE_j(logw_j + (dual_j − c_ij)/ε)`` with the classic running
  (max, shifted-sum) merge across column tiles.  Both potential updates use
  it (the g-update swaps the x/y operands; the cost is symmetric).
* ``sinkhorn_pair_sum_pallas`` — masked scalar reduction over all pairs:
  ``mode="plan"`` accumulates ``⟨P, C⟩ = Σ exp(log plan)·c`` and
  ``mode="cost"`` accumulates ``Σ c`` (the ε scale statistic).  Pair
  validity is carried by the −inf slots of the log-weight planes.

Consistency contract: for a single column tile the online merge
degenerates to exactly ``m + log Σ exp(z − m)`` — the same expression, in
the same op order, that ``distances._lse`` computes — so at tile-fitting
sizes the blocked and dense paths run identical accumulation algebra and
agree to float32 roundoff (≤ ~1 ulp per update; XLA fusion decisions keep
strict bit equality out of reach).  Tests and ``metrics_bench`` assert
this tolerance, and that blocked runs at full-tensor sizes whose dense
cost matrix would blow the previous ``n_points²`` working-set ceiling.

Cloud planes are ``(B, 8, M)`` f32: plane 0/1 birth/death coordinate,
plane 2 the diagonal-slot flag, planes 3..7 zero (sublane padding to the
native f32 tile height).  Grid is ``(B, M/tile_m, N/tile_n)`` with the
column axis innermost; accumulators live in VMEM scratch.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _safe_exp(t: jax.Array) -> jax.Array:
    """exp with −inf−(−inf)=NaN exponents treated as exp(−inf)=0.

    Finite exponents pass through untouched (``where`` returns them
    verbatim), preserving the single-tile bitwise contract.
    """
    return jnp.exp(jnp.where(jnp.isnan(t), -jnp.inf, t))


def _cost_block(x, y):
    """(TM, TN) squared-Euclidean cost from two coordinate-plane blocks,
    diagonal↔diagonal pairs free."""
    xb, xd, xf = x[0], x[1], x[2]
    yb, yd, yf = y[0], y[1], y[2]
    c = (xb[:, None] - yb[None, :]) ** 2 + (xd[:, None] - yd[None, :]) ** 2
    return jnp.where((xf[:, None] > 0) & (yf[None, :] > 0), 0.0, c)


def _lse_kernel(xp_ref, yp_ref, dual_ref, logw_ref, e_ref, out_ref,
                m_ref, s_ref, *, n_j: int):
    j = pl.program_id(2)

    @pl.when(j == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, -jnp.inf)
        s_ref[...] = jnp.zeros_like(s_ref)

    c = _cost_block(xp_ref[0], yp_ref[0])
    e = e_ref[0, 0]
    # identical op order to the dense path: logw + (dual − c)/ε
    z = logw_ref[...] + (dual_ref[...] - c) / e
    m_blk = jnp.max(z, axis=-1)                                   # (TM,)
    s_blk = jnp.sum(_safe_exp(z - m_blk[:, None]), axis=-1)
    m_old, s_old = m_ref[0], s_ref[0]
    m_new = jnp.maximum(m_old, m_blk)
    s_new = (s_old * _safe_exp(m_old - m_new)
             + s_blk * _safe_exp(m_blk - m_new))
    m_ref[...] = m_new[None]
    s_ref[...] = s_new[None]

    @pl.when(j == n_j - 1)
    def _fin():
        out_ref[...] = jnp.where(jnp.isfinite(m_new),
                                 m_new + jnp.log(s_new), -jnp.inf)[None]


@functools.partial(jax.jit,
                   static_argnames=("tile_m", "tile_n", "interpret"))
def sinkhorn_lse_pallas(xp: jax.Array, yp: jax.Array, dual: jax.Array,
                        logw: jax.Array, e_t: jax.Array,
                        tile_m: int = 128, tile_n: int = 128,
                        interpret: bool = True) -> jax.Array:
    """(B, M) online-LSE: ``out[b, i] = LSE_j(logw[b,j] + (dual[b,j] − c_ij)/ε_b)``.

    ``xp``/``yp``: (B, 8, M)/(B, 8, N) coordinate planes; ``dual``/``logw``:
    (B, N); ``e_t``: (B, 1) per-pair ε.  Padding slots must carry
    ``logw = −inf`` (they then contribute exp(−inf) = 0).
    """
    b, _, m = xp.shape
    _, _, n = yp.shape
    mp = -(-m // tile_m) * tile_m
    np_ = -(-n // tile_n) * tile_n
    xpp = jnp.pad(xp, ((0, 0), (0, 0), (0, mp - m)))
    ypp = jnp.pad(yp, ((0, 0), (0, 0), (0, np_ - n)))
    dualp = jnp.pad(dual, ((0, 0), (0, np_ - n)))
    logwp = jnp.pad(logw, ((0, 0), (0, np_ - n)),
                    constant_values=-jnp.inf)

    grid = (b, mp // tile_m, np_ // tile_n)
    out = pl.pallas_call(
        functools.partial(_lse_kernel, n_j=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8, tile_m), lambda b, i, j: (b, 0, i),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 8, tile_n), lambda b, i, j: (b, 0, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_n), lambda b, i, j: (b, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_n), lambda b, i, j: (b, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, tile_m), lambda b, i, j: (b, i),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, mp), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, tile_m), jnp.float32),
                        pltpu.VMEM((1, tile_m), jnp.float32)],
        interpret=interpret,
        name="sinkhorn_lse_blocked",
    )(xpp.astype(jnp.float32), ypp.astype(jnp.float32),
      dualp.astype(jnp.float32), logwp.astype(jnp.float32),
      e_t.astype(jnp.float32))
    return out[:, :m]


def _pair_sum_kernel(xp_ref, yp_ref, f_ref, g_ref, la_ref, lb_ref, e_ref,
                     out_ref, acc_ref, *, n_i: int, n_j: int, plan: bool):
    i, j = pl.program_id(1), pl.program_id(2)

    @pl.when((i == 0) & (j == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    c = _cost_block(xp_ref[0], yp_ref[0])
    la_col = la_ref[...].T                                         # (TM, 1)
    lb_row = lb_ref[...]                                           # (1, TN)
    pair = jnp.isfinite(la_col) & jnp.isfinite(lb_row)
    if plan:
        e = e_ref[0, 0]
        z = la_col + lb_row + (f_ref[...].T + g_ref[...] - c) / e
        add = jnp.where(pair, jnp.exp(z) * c, 0.0)
    else:
        add = jnp.where(pair, c, 0.0)
    acc_ref[0, 0] += jnp.sum(add, axis=(0, 1))

    @pl.when((i == n_i - 1) & (j == n_j - 1))
    def _fin():
        out_ref[...] = acc_ref[...]


@functools.partial(jax.jit,
                   static_argnames=("mode", "tile_m", "tile_n", "interpret"))
def sinkhorn_pair_sum_pallas(xp: jax.Array, yp: jax.Array, f: jax.Array,
                             g: jax.Array, log_a: jax.Array,
                             log_b: jax.Array, e_t: jax.Array,
                             mode: str = "plan", tile_m: int = 128,
                             tile_n: int = 128,
                             interpret: bool = True) -> jax.Array:
    """(B,) masked pair reduction over the on-the-fly cost.

    ``mode="plan"``: Σ over valid pairs of ``exp(log_a + log_b +
    (f + g − c)/ε)·c`` (the transport cost ⟨P, C⟩).  ``mode="cost"``:
    Σ over valid pairs of ``c`` (the ε scale statistic; ``f``/``g``/``e_t``
    ignored).  Validity = finiteness of the log weights.
    """
    if mode not in ("plan", "cost"):
        raise ValueError(f"unknown pair-sum mode {mode!r}")
    b, _, m = xp.shape
    _, _, n = yp.shape
    mp = -(-m // tile_m) * tile_m
    np_ = -(-n // tile_n) * tile_n
    xpp = jnp.pad(xp, ((0, 0), (0, 0), (0, mp - m)))
    ypp = jnp.pad(yp, ((0, 0), (0, 0), (0, np_ - n)))
    fp = jnp.pad(f, ((0, 0), (0, mp - m)))
    gp = jnp.pad(g, ((0, 0), (0, np_ - n)))
    lap = jnp.pad(log_a, ((0, 0), (0, mp - m)), constant_values=-jnp.inf)
    lbp = jnp.pad(log_b, ((0, 0), (0, np_ - n)), constant_values=-jnp.inf)

    grid = (b, mp // tile_m, np_ // tile_n)
    out = pl.pallas_call(
        functools.partial(_pair_sum_kernel, n_i=grid[1], n_j=grid[2],
                          plan=(mode == "plan")),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 8, tile_m), lambda b, i, j: (b, 0, i),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 8, tile_n), lambda b, i, j: (b, 0, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_m), lambda b, i, j: (b, i),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_n), lambda b, i, j: (b, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_m), lambda b, i, j: (b, i),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_n), lambda b, i, j: (b, j),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, 1), lambda b, i, j: (b, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, 1), lambda b, i, j: (b, 0),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, 1), jnp.float32),
        scratch_shapes=[pltpu.VMEM((1, 1), jnp.float32)],
        interpret=interpret,
        name=f"sinkhorn_pair_sum_{mode}",
    )(xpp.astype(jnp.float32), ypp.astype(jnp.float32),
      fp.astype(jnp.float32), gp.astype(jnp.float32),
      lap.astype(jnp.float32), lbp.astype(jnp.float32),
      e_t.astype(jnp.float32))
    return out[:, 0]
