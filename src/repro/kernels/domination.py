"""Pallas TPU kernel: dominated-vertex (violation-count) matrix.

Computes ``dom[u, v] = (|N[u] \\ N[v]| == 0) ∧ u≠v ∧ live(u) ∧ live(v)`` as a
tiled MXU matmul ``viol = Nc @ NotNc^T`` with the comparison fused into the
epilogue — the TPU-native form of the paper's Remark 9 / Algorithm 2 inner
loops (DESIGN.md §3).

Grid: (B, N/TU, N/TV, N/TW), W innermost; a (TU, TV) f32 accumulator lives in
VMEM scratch; all operand tiles are staged HBM→VMEM by BlockSpecs.  Tile
defaults are MXU-aligned (128).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(nc_ref, notc_ref, mask_u_ref, mask_v_ref, out_ref, acc_ref, *, n_w: int):
    iu = pl.program_id(1)
    iv = pl.program_id(2)
    iw = pl.program_id(3)

    @pl.when(iw == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    nc = nc_ref[0]  # (TU, TW) f32
    notc = notc_ref[0]  # (TV, TW) f32
    acc_ref[...] += lax.dot_general(
        nc, notc, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    )

    @pl.when(iw == n_w - 1)
    def _epilogue():
        tu, tv = acc_ref.shape
        gu = iu * tu + lax.broadcasted_iota(jnp.int32, (tu, tv), 0)
        gv = iv * tv + lax.broadcasted_iota(jnp.int32, (tu, tv), 1)
        live = (mask_u_ref[0][:, None] > 0) & (mask_v_ref[0][None, :] > 0)
        dom = (acc_ref[...] == 0.0) & (gu != gv) & live
        out_ref[0] = dom


@functools.partial(jax.jit, static_argnames=("tile_u", "tile_v", "tile_w", "interpret"))
def domination_pallas(
    adj: jax.Array,
    mask: jax.Array,
    tile_u: int = 128,
    tile_v: int = 128,
    tile_w: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """dom[b, u, v] = "v dominates u".  adj (B,N,N) bool, mask (B,N) bool."""
    b, n, _ = adj.shape
    n_pad = max(tile_u, tile_v, tile_w)
    npad = -(-n // n_pad) * n_pad
    pad = npad - n
    adj_p = jnp.pad(adj, ((0, 0), (0, pad), (0, pad)))
    mask_p = jnp.pad(mask, ((0, 0), (0, pad)))

    eye = jnp.eye(npad, dtype=bool)
    live = mask_p[:, None, :] & mask_p[:, :, None]
    nc = ((adj_p | eye) & live & mask_p[:, :, None]).astype(jnp.float32)
    notc = (1.0 - nc) * mask_p[:, None, :].astype(jnp.float32)
    maskf = mask_p.astype(jnp.float32)

    grid = (b, npad // tile_u, npad // tile_v, npad // tile_w)
    out = pl.pallas_call(
        functools.partial(_kernel, n_w=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile_u, tile_w), lambda b_, u, v, w: (b_, u, w),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_v, tile_w), lambda b_, u, v, w: (b_, v, w),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_u), lambda b_, u, v, w: (b_, u),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile_v), lambda b_, u, v, w: (b_, v),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, tile_u, tile_v), lambda b_, u, v, w: (b_, u, v),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, npad, npad), jnp.bool_),
        scratch_shapes=[pltpu.VMEM((tile_u, tile_v), jnp.float32)],
        interpret=interpret,
        name="domination_viol_matmul",
    )(nc, notc, maskf, maskf)
    return out[:, :n, :n]
