"""Pure-jnp oracles for every Pallas kernel in this package.

Each function is the semantic ground truth the kernels are asserted against
(tests sweep shapes/dtypes with assert_allclose / exact equality).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def domination_ref(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """dom[u, v] = "v dominates u" with closed neighborhoods, u != v.

    adj: (N, N) bool symmetric, mask: (N,) bool.  (vmap for batches.)
    """
    n = adj.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    live = mask[None, :] & mask[:, None]
    nc = (adj | eye) & live & mask[:, None]
    nc_f = nc.astype(jnp.float32)
    not_ncv = (~nc).astype(jnp.float32) * mask[None, :].astype(jnp.float32)
    viol = nc_f @ not_ncv.T
    return (viol == 0) & ~eye & live


def kcore_peel_ref(adj: jax.Array, alive: jax.Array, k: jax.Array | int) -> jax.Array:
    """One Jacobi peel sweep: alive & (deg_within_alive >= k)."""
    deg = jnp.einsum(
        "uw,w->u", adj.astype(jnp.float32), alive.astype(jnp.float32)
    )
    return alive & (deg >= jnp.asarray(k, jnp.float32))


def common_neighbors_ref(adj: jax.Array) -> jax.Array:
    """cn[u, v] = |N(u) ∩ N(v)| restricted to edges: (A @ A) ⊙ A. (N,N) i32."""
    a = adj.astype(jnp.float32)
    return ((a @ a) * a).astype(jnp.int32)


def pairwise_l1_ref(x: jax.Array, y: jax.Array) -> jax.Array:
    """gram[i, j] = Σ_d |x[i, d] − y[j, d]|.  (M, D) × (N, D) → (M, N) f32.

    Materializes the full (M, N, D) broadcast — fine as an oracle; the
    Pallas kernel tiles the same reduction through VMEM.
    """
    x = x.astype(jnp.float32)
    y = y.astype(jnp.float32)
    return jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)


def hamming_scan_ref(codes_q: jax.Array, mask_q: jax.Array,
                     codes_db: jax.Array) -> jax.Array:
    """dist[i, j] = popcount((q[i] ^ c[j]) & mask[i]).

    (Q, W) × (N, W) packed uint32 codes → (Q, N) int32.  Materializes the
    full (Q, N, W) broadcast — fine as an oracle; the Pallas kernel tiles
    the same reduction through VMEM.
    """
    x = jnp.bitwise_xor(codes_q[:, None, :], codes_db[None, :, :])
    x = x & mask_q[:, None, :]
    return jnp.sum(jax.lax.population_count(x).astype(jnp.int32), axis=-1)


def auction_lap_ref(cost: jax.Array, **kw):
    """ε-scaled Jacobi auction on one (M, M) cost matrix (pure jnp).

    Delegates to :func:`repro.kernels.auction_lap.auction_solve` — the same
    algorithm the Pallas kernel runs per grid step, so kernel-vs-ref parity
    is semantic.  *Optimality* is asserted separately against the host-side
    Hungarian/scipy oracle (``repro.metrics.reference``).
    """
    from repro.kernels.auction_lap import auction_solve

    return auction_solve(cost, **kw)


def auction_lap_collapsed_ref(cbar: jax.Array, keep1: jax.Array,
                              keep2: jax.Array, price0=None, **kw):
    """Collapsed forward/reverse auction on one (K, K) reduced-cost problem.

    Delegates to
    :func:`repro.kernels.auction_lap.auction_solve_collapsed` — the same
    combined forward/reverse solver the collapsed Pallas kernel vmaps per
    grid step, so kernel-vs-ref parity is semantic.  *Optimality* is
    asserted separately against the expanded-matrix Hungarian oracle
    (``repro.metrics.reference``) via
    ``auction_lap.expand_collapsed_assignment``.
    """
    from repro.kernels.auction_lap import auction_solve_collapsed

    return auction_solve_collapsed(cbar, keep1, keep2, price0, **kw)


def sinkhorn_lse_ref(xp: jax.Array, yp: jax.Array, dual: jax.Array,
                     logw: jax.Array, e_t: jax.Array) -> jax.Array:
    """Dense reference for the blocked LSE kernel (materializes (M, N)).

    ``xp``/``yp`` are the (B, 8, M) coordinate planes of
    ``repro.metrics.distances._cloud_planes``; returns (B, M) rows
    ``LSE_j(logw_j + (dual_j − c_ij)/ε)`` with diagonal↔diagonal cost 0.
    """
    xb, xd, xf = xp[:, 0], xp[:, 1], xp[:, 2]
    yb, yd, yf = yp[:, 0], yp[:, 1], yp[:, 2]
    c = ((xb[:, :, None] - yb[:, None, :]) ** 2
         + (xd[:, :, None] - yd[:, None, :]) ** 2)
    c = jnp.where((xf[:, :, None] > 0) & (yf[:, None, :] > 0), 0.0, c)
    z = logw[:, None, :] + (dual[:, None, :] - c) / e_t[:, :, None]
    m = jnp.max(z, axis=-1)
    s = jnp.sum(jnp.exp(z - m[..., None]), axis=-1)
    return jnp.where(jnp.isfinite(m), m + jnp.log(s), -jnp.inf)


def gf2_reduce_ref(b: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Bit-packed GF(2) boundary reduction (delegates to the core module)."""
    from repro.core.persistence_jax import reduce_packed

    return reduce_packed(b)
