"""Pallas TPU kernel: batched auction algorithm for the assignment problem.

Exact q-Wasserstein between persistence diagrams is a min-cost perfect
matching on the diagonal-augmented cost matrix — historically a host-side
O(n³) Hungarian solve (``repro.metrics.reference``), which caps exact
distances at "small diagrams, one pair at a time".  The auction algorithm
(Bertsekas; the synchronous/Jacobi variant of Bertsekas–Castañón) is the
accelerator-friendly formulation: every free person bids simultaneously
(two row-max reductions + one object-side argmax aggregation per round —
pure VPU work on an (M, M) value matrix), objects go to the highest
bidder, and ε-scaling anneals the bid increment so late rounds only refine
an almost-optimal price vector.

One grid step solves one pair's matrix, held in VMEM for the whole
data-dependent bidding loop (the ``gf2_reduce`` pattern); batching over
pairs is the leading grid axis.  The kernel body and the pure-jnp oracle
(``repro.kernels.ref.auction_lap_ref``) share ``auction_solve`` below, so
kernel-vs-reference parity is semantic, not coincidental.

ε-scaling + termination contract
--------------------------------
Costs are normalized by their per-pair max, so prices live in O(1) float32
territory; the ladder anneals ``eps0 → eps0·factor^-(n_scales-1)``
(default 0.25 → ~1.3e-7) and each assignment found at scale ε is within
``M·ε·max|cost|`` of optimal total cost.  The final scale's increments sit
just above f32 price resolution — in practice the assignment is *exactly*
optimal for non-degenerate inputs (asserted against the Hungarian oracle
in tests and ``metrics_bench``), and ties (e.g. the all-zero
reservoir↔reservoir block of diagram matrices) only ever differ in which
of several equal-cost matchings is returned.  A per-scale round cap plus a
deterministic index-order completion of any still-free rows guarantee the
kernel always returns a perfect matching; ``converged`` reports whether
the reported matching came from one of the two finest ε rungs (the tight
suboptimality guarantee).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_EPS0 = 0.25
DEFAULT_EPS_FACTOR = 5.0
DEFAULT_N_SCALES = 10


def default_max_rounds(m: int) -> int:
    """Per-scale bidding-round cap — the one definition the kernel wrapper
    and the jnp oracle share, so their fallback behavior is identical."""
    return 64 + 32 * m


def bid_round(neg_cost, price, p2o, o2p, eps):
    """One synchronous (Jacobi) auction round.

    ``neg_cost``: (M, M) benefit = −cost; ``price``: (M,) object prices;
    ``p2o``/``o2p``: person→object / object→person assignment (−1 = free).
    Every free person bids best-value + ε over its second-best; each object
    receiving bids goes to the highest bidder (ties → lowest person index),
    evicting any previous owner.
    """
    m = neg_cost.shape[-1]
    idx = jnp.arange(m)
    free = p2o < 0
    v = neg_cost - price[None, :]
    j_star = jnp.argmax(v, axis=-1)
    v1 = jnp.max(v, axis=-1)
    v2 = jnp.max(jnp.where(idx[None, :] == j_star[:, None], -jnp.inf, v),
                 axis=-1)
    v2 = jnp.where(jnp.isfinite(v2), v2, v1)  # M == 1 degenerate case
    # price[j*] + (v1 − v2) + ε == a[i, j*] − v2 + ε
    bid = (jnp.take_along_axis(neg_cost, j_star[:, None], axis=-1)[:, 0]
           - v2 + eps)
    bids = jnp.where(free[:, None] & (j_star[:, None] == idx[None, :]),
                     bid[:, None], -jnp.inf)          # (person, object)
    best = jnp.max(bids, axis=0)
    winner = jnp.argmax(bids, axis=0)
    has = best > -jnp.inf
    price = jnp.where(has, best, price)
    # owners of re-auctioned objects are evicted ...
    lost = jnp.any(has[None, :] & (o2p[None, :] == idx[:, None]), axis=-1)
    p2o = jnp.where(lost, -1, p2o)
    o2p = jnp.where(has, winner, o2p)
    # ... and each winning bidder picks up its (single) object
    won = jnp.max(jnp.where(has[None, :] & (winner[None, :] == idx[:, None]),
                            idx[None, :], -1), axis=-1)
    p2o = jnp.where(won >= 0, won, p2o)
    return price, p2o, o2p


def auction_solve(cost, eps0: float = DEFAULT_EPS0,
                  eps_factor: float = DEFAULT_EPS_FACTOR,
                  n_scales: int = DEFAULT_N_SCALES,
                  max_rounds: int | None = None):
    """Solve one (M, M) assignment problem by ε-scaled Jacobi auction.

    Returns ``(assign, total, converged, rounds)``: ``assign[i]`` = column
    matched to row i (always a permutation), ``total`` = Σ cost[i,
    assign[i]] of the found matching (computed from the *unnormalized*
    costs, full precision), ``rounds`` = total bidding rounds across all
    scales.  The reported assignment is the finest fully-converged scale's;
    ``converged`` is True only when that scale is one of the **two finest**
    ε rungs (suboptimality ≤ M·ε_factor·ε_final·max|cost| — the f32 stall
    on the last rung keeps the guarantee, a coarse-only convergence does
    not and reports False).
    """
    m = cost.shape[-1]
    if max_rounds is None:
        max_rounds = default_max_rounds(m)
    cost = cost.astype(jnp.float32)
    c_scale = jnp.maximum(jnp.max(jnp.abs(cost)), 1e-30)
    a = -(cost / c_scale)
    idx = jnp.arange(m)
    eps_ladder = eps0 * eps_factor ** -jnp.arange(n_scales, dtype=jnp.float32)

    def run_scale(carry, eps):
        price, p2o, o2p, rounds = carry
        # partial reset (ε-CS): keep assignments still within eps of each
        # person's best value at the new scale — the warm start that makes
        # late scales cheap refinements instead of full re-auctions
        v = a - price[None, :]
        best = jnp.max(v, axis=-1)
        mine = jnp.take_along_axis(v, jnp.clip(p2o, 0)[:, None], axis=-1)[:, 0]
        keep = (p2o >= 0) & (mine >= best - eps)
        p2o = jnp.where(keep, p2o, -1)
        o2p = jnp.max(jnp.where(keep[:, None] & (p2o[:, None] == idx[None, :]),
                                idx[:, None], -1), axis=0)

        def cond(s):
            _, p2o, _, it, stalled = s
            return jnp.any(p2o < 0) & (it < max_rounds) & ~stalled

        def body(s):
            price, p2o, o2p, it, _ = s
            price2, p2o2, o2p2 = bid_round(a, price, p2o, o2p, eps)
            # every win must raise a price by >= eps; an unchanged price
            # vector means the increments fell below f32 resolution and no
            # further round can make progress (livelock) — bail out and let
            # the last converged scale's assignment stand
            stalled = jnp.all(price2 == price)
            return price2, p2o2, o2p2, it + 1, stalled

        price, p2o, o2p, it, _ = lax.while_loop(
            cond, body, (price, p2o, o2p, jnp.int32(0), jnp.bool_(False)))
        return (price, p2o, o2p, rounds + it), (p2o, jnp.all(p2o >= 0))

    free = jnp.full((m,), -1, jnp.int32)
    (price, _, _, rounds), (p2o_s, conv_s) = lax.scan(
        run_scale, (jnp.zeros((m,), jnp.float32), free, free, jnp.int32(0)),
        eps_ladder)
    # use the finest-ε scale that fully converged (stalled/capped scales
    # carry partial assignments); the optimality flag demands that scale be
    # one of the two finest rungs — see the docstring
    any_conv = jnp.any(conv_s)
    converged = jnp.any(conv_s[-2:])
    last = n_scales - 1 - jnp.argmax(conv_s[::-1])
    p2o = jnp.where(any_conv, jnp.take(p2o_s, last, axis=0), p2o_s[-1])
    # deterministic completion of any still-free rows (nothing converged):
    # k-th free person ↔ k-th free object, so a permutation always returns
    owned = jnp.any((p2o[:, None] == idx[None, :]) & (p2o >= 0)[:, None],
                    axis=0)
    free_p, free_o = p2o < 0, ~owned
    rank_p = jnp.cumsum(free_p) - 1
    rank_o = jnp.cumsum(free_o) - 1
    match = (free_p[:, None] & free_o[None, :]
             & (rank_p[:, None] == rank_o[None, :]))
    fill = jnp.max(jnp.where(match, idx[None, :], -1), axis=-1)
    assign = jnp.where(free_p, fill, p2o)
    total = jnp.sum(jnp.take_along_axis(cost, assign[:, None], axis=-1))
    return assign, total, converged, rounds


def _kernel(cost_ref, assign_ref, total_ref, conv_ref, rounds_ref, *,
            eps0, eps_factor, n_scales, max_rounds):
    assign, total, converged, rounds = jax.vmap(functools.partial(
        auction_solve, eps0=eps0, eps_factor=eps_factor, n_scales=n_scales,
        max_rounds=max_rounds))(cost_ref[...])
    assign_ref[...] = assign.astype(jnp.int32)
    total_ref[...] = total[:, None]
    conv_ref[...] = converged[:, None]
    rounds_ref[...] = rounds[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "eps0", "eps_factor", "n_scales", "max_rounds", "tile_b", "interpret"))
def auction_lap_pallas(cost: jax.Array, eps0: float = DEFAULT_EPS0,
                       eps_factor: float = DEFAULT_EPS_FACTOR,
                       n_scales: int = DEFAULT_N_SCALES,
                       max_rounds: int | None = None,
                       tile_b: int = 1,
                       interpret: bool = True):
    """Batched assignment solve: (B, M, M) costs → matchings + totals.

    Returns ``(assign (B, M) i32, total (B,) f32, converged (B,) bool,
    rounds (B,) i32)``.  ``tile_b`` pairs are solved per grid step (their
    cost matrices co-resident in VMEM for the entire data-dependent
    bidding loop; the batch is zero-padded to a ``tile_b`` multiple —
    an all-zero cost matrix converges in a handful of rounds).  The
    autotuner (``python -m repro.perfgate tune``) sweeps ``tile_b``; the
    ops wrapper loads the pinned winner per device.
    """
    b, m, m2 = cost.shape
    if m != m2:
        raise ValueError(f"cost must be square per pair, got {(m, m2)}")
    if max_rounds is None:
        max_rounds = default_max_rounds(m)
    bp = -(-b // tile_b) * tile_b
    costp = jnp.pad(cost.astype(jnp.float32),
                    ((0, bp - b), (0, 0), (0, 0)))
    assign, total, conv, rounds = pl.pallas_call(
        functools.partial(_kernel, eps0=eps0, eps_factor=eps_factor,
                          n_scales=n_scales, max_rounds=max_rounds),
        grid=(bp // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, m, m), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec((tile_b, m), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.bool_),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
        name="auction_lap",
    )(costp)
    return assign[:b], total[:b, 0], conv[:b, 0], rounds[:b, 0]
