"""Pallas TPU kernel: batched auction algorithm for the assignment problem.

Exact q-Wasserstein between persistence diagrams is a min-cost perfect
matching on the diagonal-augmented cost matrix — historically a host-side
O(n³) Hungarian solve (``repro.metrics.reference``), which caps exact
distances at "small diagrams, one pair at a time".  The auction algorithm
(Bertsekas; the synchronous/Jacobi variant of Bertsekas–Castañón) is the
accelerator-friendly formulation: every free person bids simultaneously
(two row-max reductions + one object-side argmax aggregation per round —
pure VPU work on an (M, M) value matrix), objects go to the highest
bidder, and ε-scaling anneals the bid increment so late rounds only refine
an almost-optimal price vector.

One grid step solves one pair's matrix, held in VMEM for the whole
data-dependent bidding loop (the ``gf2_reduce`` pattern); batching over
pairs is the leading grid axis.  The kernel body and the pure-jnp oracle
(``repro.kernels.ref.auction_lap_ref``) share ``auction_solve`` below, so
kernel-vs-reference parity is semantic, not coincidental.

ε-scaling + termination contract
--------------------------------
Costs are normalized by their per-pair max, so prices live in O(1) float32
territory; the ladder anneals ``eps0 → eps0·factor^-(n_scales-1)``
(default 0.25 → ~1.3e-7) and each assignment found at scale ε is within
``M·ε·max|cost|`` of optimal total cost.  The final scale's increments sit
just above f32 price resolution — in practice the assignment is *exactly*
optimal for non-degenerate inputs (asserted against the Hungarian oracle
in tests and ``metrics_bench``), and ties (e.g. the all-zero
reservoir↔reservoir block of diagram matrices) only ever differ in which
of several equal-cost matchings is returned.  A per-scale round cap plus a
deterministic index-order completion of any still-free rows guarantee the
kernel always returns a perfect matching; ``converged`` reports whether
the reported matching came from one of the two finest ε rungs (the tight
suboptimality guarantee).

Collapsed (reservoir-free) formulation
--------------------------------------
The diagram matrices this kernel exists for are *degenerate*: half the
rows/columns are identical diagonal reservoirs, and the M-way ties make
the reservoir block fight over equal-cost slots for hundreds of rounds.
``auction_solve_collapsed`` solves the same optimum on the K×K *reduced*
costs ``cbar[i, j] = cost(i→j) − cost(i→Δ) − cost(Δ→j)`` plus ONE
pseudo-object ``OUT`` (price fixed at 0, unlimited capacity — the whole
reservoir block collapsed into a single multi-unit slot, the
transportation-auction variant), so no reservoir tie ever reaches the
bidding loop.  Because the collapsed problem is *asymmetric* (persons may
stay OUT, objects may stay unmatched), the per-scale loop is a **combined
forward/reverse auction**: forward rounds have free persons bid (OUT is
always a zero-value fallback), reverse rounds have unmatched objects with
stale positive prices bid for persons through the profit vector ``pi`` —
the classic repair for prices stranded above the λ = 0 floor by scale
resets or warm starts, without which ε-scaling loses its optimality
guarantee on asymmetric problems.  Warm starts enter as ``price0``
(max-normalized units, what the solver also returns): any nonnegative
price vector is safe — the reverse phase re-grounds stale prices — which
is what makes the serve-level LSH-bucket price cache sound.  A warm lane
(any nonzero ``price0``) additionally skips the annealing ladder and runs
straight at the finest ε — coarse scales would only inflate the
already-equilibrated prices and then pay reverse rounds undoing it —
which is where the measured warm-repeat round reduction comes from.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_EPS0 = 0.25
DEFAULT_EPS_FACTOR = 5.0
DEFAULT_N_SCALES = 10
DEFAULT_REV_EVERY = 8

# collapsed-assignment code for "person matched to the collapsed diagonal
# reservoir" (the OUT pseudo-object); -1 keeps meaning "free"
OUT = -2


def default_max_rounds(m: int) -> int:
    """Per-scale bidding-round cap — the one definition the kernel wrapper
    and the jnp oracle share, so their fallback behavior is identical."""
    return 64 + 32 * m


def bid_round(neg_cost, price, p2o, o2p, eps):
    """One synchronous (Jacobi) auction round.

    ``neg_cost``: (M, M) benefit = −cost; ``price``: (M,) object prices;
    ``p2o``/``o2p``: person→object / object→person assignment (−1 = free).
    Every free person bids best-value + ε over its second-best; each object
    receiving bids goes to the highest bidder (ties → lowest person index),
    evicting any previous owner.
    """
    m = neg_cost.shape[-1]
    idx = jnp.arange(m)
    free = p2o < 0
    v = neg_cost - price[None, :]
    j_star = jnp.argmax(v, axis=-1)
    v1 = jnp.max(v, axis=-1)
    v2 = jnp.max(jnp.where(idx[None, :] == j_star[:, None], -jnp.inf, v),
                 axis=-1)
    v2 = jnp.where(jnp.isfinite(v2), v2, v1)  # M == 1 degenerate case
    # price[j*] + (v1 − v2) + ε == a[i, j*] − v2 + ε
    bid = (jnp.take_along_axis(neg_cost, j_star[:, None], axis=-1)[:, 0]
           - v2 + eps)
    bids = jnp.where(free[:, None] & (j_star[:, None] == idx[None, :]),
                     bid[:, None], -jnp.inf)          # (person, object)
    best = jnp.max(bids, axis=0)
    winner = jnp.argmax(bids, axis=0)
    has = best > -jnp.inf
    price = jnp.where(has, best, price)
    # owners of re-auctioned objects are evicted ...
    lost = jnp.any(has[None, :] & (o2p[None, :] == idx[:, None]), axis=-1)
    p2o = jnp.where(lost, -1, p2o)
    o2p = jnp.where(has, winner, o2p)
    # ... and each winning bidder picks up its (single) object
    won = jnp.max(jnp.where(has[None, :] & (winner[None, :] == idx[:, None]),
                            idx[None, :], -1), axis=-1)
    p2o = jnp.where(won >= 0, won, p2o)
    return price, p2o, o2p


def auction_solve(cost, eps0: float = DEFAULT_EPS0,
                  eps_factor: float = DEFAULT_EPS_FACTOR,
                  n_scales: int = DEFAULT_N_SCALES,
                  max_rounds: int | None = None):
    """Solve one (M, M) assignment problem by ε-scaled Jacobi auction.

    Returns ``(assign, total, converged, rounds)``: ``assign[i]`` = column
    matched to row i (always a permutation), ``total`` = Σ cost[i,
    assign[i]] of the found matching (computed from the *unnormalized*
    costs, full precision), ``rounds`` = total bidding rounds across all
    scales.  The reported assignment is the finest fully-converged scale's;
    ``converged`` is True only when that scale is one of the **two finest**
    ε rungs (suboptimality ≤ M·ε_factor·ε_final·max|cost| — the f32 stall
    on the last rung keeps the guarantee, a coarse-only convergence does
    not and reports False).
    """
    m = cost.shape[-1]
    if max_rounds is None:
        max_rounds = default_max_rounds(m)
    cost = cost.astype(jnp.float32)
    c_scale = jnp.maximum(jnp.max(jnp.abs(cost)), 1e-30)
    a = -(cost / c_scale)
    idx = jnp.arange(m)
    eps_ladder = eps0 * eps_factor ** -jnp.arange(n_scales, dtype=jnp.float32)

    def run_scale(carry, eps):
        price, p2o, o2p, rounds = carry
        # partial reset (ε-CS): keep assignments still within eps of each
        # person's best value at the new scale — the warm start that makes
        # late scales cheap refinements instead of full re-auctions
        v = a - price[None, :]
        best = jnp.max(v, axis=-1)
        mine = jnp.take_along_axis(v, jnp.clip(p2o, 0)[:, None], axis=-1)[:, 0]
        keep = (p2o >= 0) & (mine >= best - eps)
        p2o = jnp.where(keep, p2o, -1)
        o2p = jnp.max(jnp.where(keep[:, None] & (p2o[:, None] == idx[None, :]),
                                idx[:, None], -1), axis=0)

        def cond(s):
            _, p2o, _, it, stalled = s
            return jnp.any(p2o < 0) & (it < max_rounds) & ~stalled

        def body(s):
            price, p2o, o2p, it, _ = s
            price2, p2o2, o2p2 = bid_round(a, price, p2o, o2p, eps)
            # every win must raise a price by >= eps; an unchanged price
            # vector means the increments fell below f32 resolution and no
            # further round can make progress (livelock) — bail out and let
            # the last converged scale's assignment stand
            stalled = jnp.all(price2 == price)
            return price2, p2o2, o2p2, it + 1, stalled

        price, p2o, o2p, it, _ = lax.while_loop(
            cond, body, (price, p2o, o2p, jnp.int32(0), jnp.bool_(False)))
        return (price, p2o, o2p, rounds + it), (p2o, jnp.all(p2o >= 0))

    free = jnp.full((m,), -1, jnp.int32)
    (price, _, _, rounds), (p2o_s, conv_s) = lax.scan(
        run_scale, (jnp.zeros((m,), jnp.float32), free, free, jnp.int32(0)),
        eps_ladder)
    # use the finest-ε scale that fully converged (stalled/capped scales
    # carry partial assignments); the optimality flag demands that scale be
    # one of the two finest rungs — see the docstring
    any_conv = jnp.any(conv_s)
    converged = jnp.any(conv_s[-2:])
    last = n_scales - 1 - jnp.argmax(conv_s[::-1])
    p2o = jnp.where(any_conv, jnp.take(p2o_s, last, axis=0), p2o_s[-1])
    # deterministic completion of any still-free rows (nothing converged):
    # k-th free person ↔ k-th free object, so a permutation always returns
    owned = jnp.any((p2o[:, None] == idx[None, :]) & (p2o >= 0)[:, None],
                    axis=0)
    free_p, free_o = p2o < 0, ~owned
    rank_p = jnp.cumsum(free_p) - 1
    rank_o = jnp.cumsum(free_o) - 1
    match = (free_p[:, None] & free_o[None, :]
             & (rank_p[:, None] == rank_o[None, :]))
    fill = jnp.max(jnp.where(match, idx[None, :], -1), axis=-1)
    assign = jnp.where(free_p, fill, p2o)
    total = jnp.sum(jnp.take_along_axis(cost, assign[:, None], axis=-1))
    return assign, total, converged, rounds


# --------------------------------------------------------------------------
# collapsed (reservoir-free) forward/reverse auction
# --------------------------------------------------------------------------

def collapsed_bid_round(a, price, pi, p2o, o2p, eps):
    """One synchronous *forward* round of the collapsed auction.

    ``a``: (K, K) benefit = −reduced-cost, ``-inf`` at invalid pairs;
    ``price``: (K,) real-object prices; ``pi``: (K,) person profits;
    ``p2o`` ∈ {OUT, −1=free, j}; ``o2p`` ∈ {−1=unowned, i}.  Every free
    person's option set is its real objects *plus* OUT (value 0, price
    pinned at 0, unlimited capacity): persons whose best real value is
    ≤ 0 take OUT immediately — the collapsed reservoir absorbs any number
    of takers in one round, which is exactly the tie blowup the expanded
    matrix pays ~M rounds for — and the rest bid best-over-second-best + ε
    with OUT folded into the second-best.
    """
    k = a.shape[-1]
    idx = jnp.arange(k)
    free = p2o == -1
    v = a - price[None, :]
    j_star = jnp.argmax(v, axis=-1)
    v1 = jnp.max(v, axis=-1)
    v2 = jnp.max(jnp.where(idx[None, :] == j_star[:, None], -jnp.inf, v),
                 axis=-1)
    v2o = jnp.maximum(v2, 0.0)         # second-best option including OUT
    take_out = free & (v1 <= 0.0)      # OUT is (weakly) the best option
    bid_ok = free & (v1 > 0.0)
    aj = jnp.take_along_axis(a, j_star[:, None], axis=-1)[:, 0]
    bid = aj - v2o + eps
    bids = jnp.where(bid_ok[:, None] & (j_star[:, None] == idx[None, :]),
                     bid[:, None], -jnp.inf)          # (person, object)
    best = jnp.max(bids, axis=0)
    winner = jnp.argmax(bids, axis=0)
    has = best > -jnp.inf
    price = jnp.where(has, best, price)
    lost = jnp.any(has[None, :] & (o2p[None, :] == idx[:, None]), axis=-1)
    p2o = jnp.where(lost, -1, p2o)
    o2p = jnp.where(has, winner, o2p)
    won = jnp.max(jnp.where(has[None, :] & (winner[None, :] == idx[:, None]),
                            idx[None, :], -1), axis=-1)
    p2o = jnp.where(won >= 0, won, p2o)
    # winners' profits: value of the second-best option they forwent, −ε —
    # the ε-CS-consistent dual update the reverse rounds price against
    pi = jnp.where(won >= 0, v2o - eps, pi)
    pi = jnp.where(take_out, 0.0, pi)
    p2o = jnp.where(take_out, OUT, p2o)
    return price, pi, p2o, o2p


def collapsed_reverse_round(a, price, pi, p2o, o2p, keep2, eps):
    """One synchronous *reverse* round: stale unmatched objects bid.

    Bidders are real objects that are unowned yet priced above the λ = 0
    floor (stranded there by a scale-boundary reset or a warm-start price
    vector).  Each computes its best person through the profit vector
    (``β1 = max_i a[i,j] − pi[i]``): below ``λ + ε`` it *drops out*
    (price := 0, the state the termination test accepts); otherwise it
    undercuts to ``max(λ, β2 − ε)`` and offers that person a raised
    profit.  A person receiving several offers accepts the best one
    (Jacobi conflict resolution — losers keep their old price and retry),
    and the accepted person's previous object is released with its price
    intact, to be repaired by a later reverse round.
    """
    k = a.shape[-1]
    idx = jnp.arange(k)
    bidder = keep2 & (o2p < 0) & (price > 0.0)
    w = a - pi[:, None]                # (person, object)
    i_star = jnp.argmax(w, axis=0)
    b1 = jnp.max(w, axis=0)
    b2 = jnp.max(jnp.where(idx[:, None] == i_star[None, :], -jnp.inf, w),
                 axis=0)
    drop = bidder & (b1 < eps)
    active = bidder & (b1 >= eps)
    p_new = jnp.maximum(0.0, b2 - eps)
    offer = jnp.take_along_axis(a, i_star[None, :], axis=0)[0, :] - p_new
    offers = jnp.where(active[None, :] & (i_star[None, :] == idx[:, None]),
                       offer[None, :], -jnp.inf)      # (person, object)
    best_off = jnp.max(offers, axis=1)
    j_win = jnp.argmax(offers, axis=1)
    got = best_off > -jnp.inf
    # accepted persons release their old object (an owned object is never
    # a bidder, so freed/taken are disjoint and update order is immaterial)
    freed = jnp.any(got[:, None] & (p2o[:, None] == idx[None, :]), axis=0)
    won_obj = got[:, None] & (j_win[:, None] == idx[None, :])
    taken = jnp.any(won_obj, axis=0)
    new_owner = jnp.max(jnp.where(won_obj, idx[:, None], -1), axis=0)
    o2p = jnp.where(freed, -1, o2p)
    o2p = jnp.where(taken, new_owner, o2p)
    price = jnp.where(taken, p_new, jnp.where(drop, 0.0, price))
    p2o = jnp.where(got, j_win, p2o)
    pi = jnp.where(got, best_off, pi)
    return price, pi, p2o, o2p


def auction_solve_collapsed(cbar, keep1, keep2, price0=None,
                            eps0: float = DEFAULT_EPS0,
                            eps_factor: float = DEFAULT_EPS_FACTOR,
                            n_scales: int = DEFAULT_N_SCALES,
                            max_rounds: int | None = None,
                            rev_every: int = DEFAULT_REV_EVERY):
    """ε-scaled combined forward/reverse auction on one collapsed problem.

    ``cbar`` is the (K, K) *reduced* cost (matching pair (i, j) instead of
    sending both to the diagonal), ``keep1``/``keep2`` the valid-slot
    masks, ``price0`` an optional warm-start price vector in the solver's
    max-normalized units (any nonnegative vector is safe, and a nonzero
    one skips the ε ladder — see the module docstring).  Returns
    ``(p2o, total, converged, rounds, price)``:
    ``p2o[i]`` ∈ {OUT, −1, j} with ``total = Σ cbar[i, p2o[i]]`` over the
    matched pairs (add the caller's diagonal base cost to recover the
    expanded-matrix optimum), ``converged`` as in :func:`auction_solve`
    (one of the two finest ε rungs fully terminated: no free person, no
    unmatched object priced above 0), ``price`` the final normalized
    prices (feed them back as ``price0`` to warm-start a near-duplicate
    pair).  ``rev_every`` > 0 additionally forces a reverse round every
    that many rounds even while free persons remain (the fwd/rev phase
    ratio the autotuner sweeps); reverse rounds always run once forward
    bidding has no free persons left.
    """
    k = cbar.shape[-1]
    if max_rounds is None:
        max_rounds = default_max_rounds(k)
    rev_every = int(rev_every)
    cbar = cbar.astype(jnp.float32)
    valid = keep1[:, None] & keep2[None, :]
    c_scale = jnp.maximum(
        jnp.max(jnp.where(valid, jnp.abs(cbar), 0.0)), 1e-30)
    a = jnp.where(valid, -(cbar / c_scale), -jnp.inf)
    idx = jnp.arange(k)
    eps_ladder = eps0 * eps_factor ** -jnp.arange(n_scales, dtype=jnp.float32)
    if price0 is None:
        price = jnp.zeros((k,), jnp.float32)
    else:
        price = jnp.where(keep2, jnp.maximum(price0.astype(jnp.float32), 0.0),
                          0.0)
    # warm start (any nonzero price) skips the annealing ladder: coarse
    # scales would inflate the already-equilibrated prices and then pay
    # reverse rounds to re-ground them, so a warm lane runs every scan
    # iteration at the finest ε instead (auction from arbitrary nonneg
    # prices + empty assignment preserves ε-CS, so the ε_final optimality
    # certificate is unchanged; the ladder is purely a cold-start speedup)
    warm = jnp.any(price > 0.0)
    eps_ladder = jnp.where(warm, eps_ladder[-1], eps_ladder)
    # initial profits must over-claim nothing: best attainable value now
    pi = jnp.maximum(jnp.max(a - price[None, :], axis=-1), 0.0)
    # invalid persons sit at OUT for good (cbar row is -inf, never bid)
    p2o = jnp.where(keep1, -1, OUT).astype(jnp.int32)
    o2p = jnp.full((k,), -1, jnp.int32)

    def run_scale(carry, eps):
        price, pi, p2o, o2p, rounds = carry
        # ε-CS partial reset: persons keep their slot (real object or OUT)
        # only while it is still within eps of their best option at the
        # new, finer scale; freed persons re-bid, and the objects they
        # abandon keep their stale prices for the reverse rounds to repair
        v = a - price[None, :]
        best = jnp.maximum(jnp.max(v, axis=-1), 0.0)
        mine = jnp.where(
            p2o >= 0,
            jnp.take_along_axis(v, jnp.clip(p2o, 0)[:, None], axis=-1)[:, 0],
            0.0)                                     # OUT is worth exactly 0
        keep = (p2o != -1) & (mine >= best - eps)
        keep = keep | ~keep1
        p2o = jnp.where(keep, p2o, -1)
        o2p = jnp.max(jnp.where((p2o[:, None] == idx[None, :]),
                                idx[:, None], -1), axis=0)

        def cond(s):
            price, pi, p2o, o2p, prev, it, stalled = s
            free_any = jnp.any(p2o == -1)
            stale_any = jnp.any(keep2 & (o2p < 0) & (price > 0.0))
            return (free_any | stale_any) & (it < max_rounds) & ~stalled

        def body(s):
            price, pi, p2o, o2p, prev, it, _ = s
            free_any = jnp.any(p2o == -1)
            stale_any = jnp.any(keep2 & (o2p < 0) & (price > 0.0))
            if rev_every > 0:
                periodic = (it % rev_every) == (rev_every - 1)
            else:
                periodic = jnp.bool_(False)
            do_rev = stale_any & (~free_any | periodic)
            price2, pi2, p2o2, o2p2 = lax.cond(
                do_rev,
                lambda args: collapsed_reverse_round(*args[:-1], keep2,
                                                     args[-1]),
                lambda args: collapsed_bid_round(*args),
                (a, price, pi, p2o, o2p, eps))
            # two livelock exits, both leaving the last converged scale's
            # assignment to stand: an unchanged state means the ≥ε
            # increments fell below f32 resolution, and a state equal to
            # the one *two* rounds back means a forced fwd/rev interleave
            # (rev_every) is ping-ponging a contested object ±ε per phase
            # — neither can ever make further progress
            p_price, p_pi, p_p2o = prev
            same1 = (jnp.all(price2 == price) & jnp.all(pi2 == pi)
                     & jnp.all(p2o2 == p2o))
            same2 = (jnp.all(price2 == p_price) & jnp.all(pi2 == p_pi)
                     & jnp.all(p2o2 == p_p2o))
            return (price2, pi2, p2o2, o2p2, (price, pi, p2o), it + 1,
                    same1 | same2)

        prev0 = (jnp.full_like(price, -1.0), jnp.full_like(pi, -1.0),
                 jnp.full_like(p2o, -3))
        price, pi, p2o, o2p, _, it, _ = lax.while_loop(
            cond, body,
            (price, pi, p2o, o2p, prev0, jnp.int32(0), jnp.bool_(False)))
        conv = (~jnp.any(p2o == -1)
                & ~jnp.any(keep2 & (o2p < 0) & (price > 0.0)))
        return (price, pi, p2o, o2p, rounds + it), (p2o, conv)

    (price, _, _, _, rounds), (p2o_s, conv_s) = lax.scan(
        run_scale, (price, pi, p2o, o2p, jnp.int32(0)), eps_ladder)
    any_conv = jnp.any(conv_s)
    converged = jnp.any(conv_s[-2:])
    last = n_scales - 1 - jnp.argmax(conv_s[::-1])
    p2o = jnp.where(any_conv, jnp.take(p2o_s, last, axis=0), p2o_s[-1])
    # a still-free person (nothing converged) is reported at OUT: the
    # matching stays feasible — every person holds at most one distinct
    # object throughout — just not certified optimal (converged=False)
    matched = p2o >= 0
    total = jnp.sum(jnp.where(
        matched,
        jnp.take_along_axis(cbar, jnp.clip(p2o, 0)[:, None], axis=-1)[:, 0],
        0.0))
    return p2o.astype(jnp.int32), total, converged, rounds, price


def expand_collapsed_assignment(p2o, keep1, keep2):
    """(K,) collapsed assignment → (2K,) expanded-matrix row assignment.

    Rows 0..K−1 are the real D1 slots, rows K..2K−1 the reservoirs (the
    ``metrics/exact.py::augmented_cost`` convention).  A person at OUT (or
    free, or invalid) pairs with its own reservoir column K+i; a real
    column nobody owns pairs with its own reservoir row K+j; leftover
    reservoir rows/columns pair off in index order (all zero-cost).  The
    result evaluates the *expanded* cost matrix to exactly
    ``base + Σ cbar[i, p2o[i]]`` — the bit-for-bit equivalence the
    degenerate-input tests assert.
    """
    k = p2o.shape[-1]
    idx = jnp.arange(k)
    matched = p2o >= 0
    top = jnp.where(matched, p2o, k + idx)
    owned = jnp.any(matched[:, None] & (p2o[:, None] == idx[None, :]), axis=0)
    # reservoir row K+j takes column j when unowned; owned columns leave
    # their reservoir rows to pair with the reservoir columns K+i of
    # matched persons (rank pairing, #owned == #matched)
    rank_r = jnp.cumsum(owned) - 1
    rank_c = jnp.cumsum(matched) - 1
    pair = (owned[:, None] & matched[None, :]
            & (rank_r[:, None] == rank_c[None, :]))
    fill = jnp.max(jnp.where(pair, k + idx[None, :], -1), axis=-1)
    bottom = jnp.where(owned, fill, idx)
    return jnp.concatenate([top, bottom]).astype(jnp.int32)


def _kernel(cost_ref, assign_ref, total_ref, conv_ref, rounds_ref, *,
            eps0, eps_factor, n_scales, max_rounds):
    assign, total, converged, rounds = jax.vmap(functools.partial(
        auction_solve, eps0=eps0, eps_factor=eps_factor, n_scales=n_scales,
        max_rounds=max_rounds))(cost_ref[...])
    assign_ref[...] = assign.astype(jnp.int32)
    total_ref[...] = total[:, None]
    conv_ref[...] = converged[:, None]
    rounds_ref[...] = rounds[:, None].astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=(
    "eps0", "eps_factor", "n_scales", "max_rounds", "tile_b", "interpret"))
def auction_lap_pallas(cost: jax.Array, eps0: float = DEFAULT_EPS0,
                       eps_factor: float = DEFAULT_EPS_FACTOR,
                       n_scales: int = DEFAULT_N_SCALES,
                       max_rounds: int | None = None,
                       tile_b: int = 1,
                       interpret: bool = True):
    """Batched assignment solve: (B, M, M) costs → matchings + totals.

    Returns ``(assign (B, M) i32, total (B,) f32, converged (B,) bool,
    rounds (B,) i32)``.  ``tile_b`` pairs are solved per grid step (their
    cost matrices co-resident in VMEM for the entire data-dependent
    bidding loop; the batch is zero-padded to a ``tile_b`` multiple —
    an all-zero cost matrix converges in a handful of rounds).  The
    autotuner (``python -m repro.perfgate tune``) sweeps ``tile_b``; the
    ops wrapper loads the pinned winner per device.
    """
    b, m, m2 = cost.shape
    if m != m2:
        raise ValueError(f"cost must be square per pair, got {(m, m2)}")
    if max_rounds is None:
        max_rounds = default_max_rounds(m)
    bp = -(-b // tile_b) * tile_b
    costp = jnp.pad(cost.astype(jnp.float32),
                    ((0, bp - b), (0, 0), (0, 0)))
    assign, total, conv, rounds = pl.pallas_call(
        functools.partial(_kernel, eps0=eps0, eps_factor=eps_factor,
                          n_scales=n_scales, max_rounds=max_rounds),
        grid=(bp // tile_b,),
        in_specs=[pl.BlockSpec((tile_b, m, m), lambda i: (i, 0, 0),
                               memory_space=pltpu.VMEM)],
        out_specs=[
            pl.BlockSpec((tile_b, m), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bp, m), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.bool_),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
        ],
        interpret=interpret,
        name="auction_lap",
    )(costp)
    return assign[:b], total[:b, 0], conv[:b, 0], rounds[:b, 0]


def _collapsed_kernel(cbar_ref, keep1_ref, keep2_ref, price0_ref,
                      p2o_ref, total_ref, conv_ref, rounds_ref, price_ref, *,
                      eps0, eps_factor, n_scales, max_rounds, rev_every):
    p2o, total, conv, rounds, price = jax.vmap(functools.partial(
        auction_solve_collapsed, eps0=eps0, eps_factor=eps_factor,
        n_scales=n_scales, max_rounds=max_rounds, rev_every=rev_every,
    ))(cbar_ref[...], keep1_ref[...], keep2_ref[...], price0_ref[...])
    p2o_ref[...] = p2o.astype(jnp.int32)
    total_ref[...] = total[:, None]
    conv_ref[...] = conv[:, None]
    rounds_ref[...] = rounds[:, None].astype(jnp.int32)
    price_ref[...] = price


@functools.partial(jax.jit, static_argnames=(
    "eps0", "eps_factor", "n_scales", "max_rounds", "rev_every", "tile_b",
    "interpret"))
def auction_lap_collapsed_pallas(cbar: jax.Array, keep1: jax.Array,
                                 keep2: jax.Array, price0: jax.Array,
                                 eps0: float = DEFAULT_EPS0,
                                 eps_factor: float = DEFAULT_EPS_FACTOR,
                                 n_scales: int = DEFAULT_N_SCALES,
                                 max_rounds: int | None = None,
                                 rev_every: int = DEFAULT_REV_EVERY,
                                 tile_b: int = 1,
                                 interpret: bool = True):
    """Batched collapsed forward/reverse auction: (B, K, K) reduced costs.

    Returns ``(p2o (B, K) i32, total (B,) f32, converged (B,) bool,
    rounds (B,) i32, price (B, K) f32)`` — see
    :func:`auction_solve_collapsed` for the contract.  ``tile_b`` pairs
    co-reside in VMEM per grid step exactly like ``auction_lap_pallas``;
    batch padding uses all-invalid slots, which terminate in zero rounds
    (every padded person starts at OUT).
    """
    b, k, k2 = cbar.shape
    if k != k2:
        raise ValueError(f"cbar must be square per pair, got {(k, k2)}")
    if keep1.shape != (b, k) or keep2.shape != (b, k):
        raise ValueError(
            f"keep masks must be {(b, k)}, got {keep1.shape}/{keep2.shape}")
    if price0.shape != (b, k):
        raise ValueError(f"price0 must be {(b, k)}, got {price0.shape}")
    if max_rounds is None:
        max_rounds = default_max_rounds(k)
    bp = -(-b // tile_b) * tile_b
    pad_b = ((0, bp - b),)
    cbarp = jnp.pad(cbar.astype(jnp.float32), pad_b + ((0, 0), (0, 0)))
    keep1p = jnp.pad(keep1.astype(jnp.bool_), pad_b + ((0, 0),))
    keep2p = jnp.pad(keep2.astype(jnp.bool_), pad_b + ((0, 0),))
    price0p = jnp.pad(price0.astype(jnp.float32), pad_b + ((0, 0),))
    row_spec = pl.BlockSpec((tile_b, k), lambda i: (i, 0),
                            memory_space=pltpu.VMEM)
    one_spec = pl.BlockSpec((tile_b, 1), lambda i: (i, 0),
                            memory_space=pltpu.VMEM)
    p2o, total, conv, rounds, price = pl.pallas_call(
        functools.partial(_collapsed_kernel, eps0=eps0,
                          eps_factor=eps_factor, n_scales=n_scales,
                          max_rounds=max_rounds, rev_every=rev_every),
        grid=(bp // tile_b,),
        in_specs=[
            pl.BlockSpec((tile_b, k, k), lambda i: (i, 0, 0),
                         memory_space=pltpu.VMEM),
            row_spec, row_spec, row_spec,
        ],
        out_specs=[row_spec, one_spec, one_spec, one_spec, row_spec],
        out_shape=[
            jax.ShapeDtypeStruct((bp, k), jnp.int32),
            jax.ShapeDtypeStruct((bp, 1), jnp.float32),
            jax.ShapeDtypeStruct((bp, 1), jnp.bool_),
            jax.ShapeDtypeStruct((bp, 1), jnp.int32),
            jax.ShapeDtypeStruct((bp, k), jnp.float32),
        ],
        interpret=interpret,
        name="auction_lap_collapsed",
    )(cbarp, keep1p, keep2p, price0p)
    return (p2o[:b], total[:b, 0], conv[:b, 0], rounds[:b, 0], price[:b])
