"""Pallas TPU kernel: tiled pairwise-L1 Gram matrix over SW embeddings.

``gram[i, j] = Σ_d |x[i, d] − y[j, d]|`` — with ``x``/``y`` the pre-sorted
sliced-Wasserstein projection embeddings of ``repro.metrics.sw_embedding``
this *is* the diagram distance matrix TopoIndex ranks against (the sorting
already solved each direction's 1-D transport; what is left is a masked L1).

L1 cannot ride the MXU, so the kernel is a VPU reduction: grid
``(M/TM, N/TN, D/TD)`` with the feature axis innermost, a ``(TM, TN)`` f32
accumulator in VMEM scratch, and each step materializing one
``(TM, TN, TD)`` broadcast-difference block in registers/VMEM — tile
defaults ``(8, 128, 128)`` keep that block at 512 KB and the output tile at
the native f32 (8, 128) layout.  Rows are zero-padded to tile multiples and
sliced off afterwards (|0 − 0| contributes nothing, so feature padding is
free; row padding only computes throwaway rows).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(x_ref, y_ref, out_ref, acc_ref, *, n_d: int):
    i_d = pl.program_id(2)

    @pl.when(i_d == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (TM, TD)
    y = y_ref[...]  # (TN, TD)
    acc_ref[...] += jnp.sum(jnp.abs(x[:, None, :] - y[None, :, :]), axis=-1)

    @pl.when(i_d == n_d - 1)
    def _epilogue():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit, static_argnames=("tile_m", "tile_n", "tile_d", "interpret"))
def pairwise_l1_pallas(
    x: jax.Array,
    y: jax.Array,
    tile_m: int = 8,
    tile_n: int = 128,
    tile_d: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """(M, D) × (N, D) → (M, N) f32 pairwise-L1 distance (Gram) matrix."""
    m, d = x.shape
    n, d2 = y.shape
    if d != d2:
        raise ValueError(f"embedding widths differ: {d} vs {d2}")
    mp = -(-m // tile_m) * tile_m
    np_ = -(-n // tile_n) * tile_n
    dp = -(-d // tile_d) * tile_d
    xp = jnp.pad(x.astype(jnp.float32), ((0, mp - m), (0, dp - d)))
    yp = jnp.pad(y.astype(jnp.float32), ((0, np_ - n), (0, dp - d)))

    grid = (mp // tile_m, np_ // tile_n, dp // tile_d)
    out = pl.pallas_call(
        functools.partial(_kernel, n_d=grid[2]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_m, tile_d), lambda i, j, k: (i, k),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_n, tile_d), lambda i, j, k: (j, k),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((tile_m, tile_n), lambda i, j, k: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((mp, np_), jnp.float32),
        scratch_shapes=[pltpu.VMEM((tile_m, tile_n), jnp.float32)],
        interpret=interpret,
        name="pairwise_l1_gram",
    )(xp, yp)
    return out[:m, :n]
