"""Pallas TPU kernels for the paper's compute hot spots (+ jnp oracles)."""
from repro.kernels import ops, ref

__all__ = ["ops", "ref"]
