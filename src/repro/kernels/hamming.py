"""Pallas kernel: masked Hamming distances over packed LSH codes.

``dist[i, j] = popcount((q[i] ^ c[j]) & mask[i])`` — the coarse stage of
TopoIndex (``repro/index/topo_index.py``) run on-device: query and corpus
hyperplane codes arrive bit-packed into uint32 words (``W = ceil(bits/32)``
per row), each grid step XORs one ``(TQ, W)`` query block against one
``(TN, W)`` corpus block and reduces ``lax.population_count`` over the
word axis into a native int32 ``(TQ, TN)`` output tile.

The per-query ``mask`` is the multi-probe LSH trick from the index layer:
clearing the ``t`` lowest-margin bits of a query's code from the distance
is exactly ``min`` over all ``2^t`` flip-probe codes, so ``probes``
costs one masked scan instead of ``2^t`` scans (pass an all-ones mask for
plain single-probe Hamming).

Word padding is free (packed codes zero-fill bits past ``lsh_bits`` on
both sides, and ``x ^ 0 & 0`` contributes nothing); row padding computes
throwaway rows that are sliced off, like the pairwise Gram kernel.  The
word axis rides *inside* a block (it is a handful of uint32 lanes), so the
grid is 2-D ``(Q/TQ, N/TN)`` with no reduction carry between steps.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def pack_codes_u32(codes_u8: np.ndarray) -> np.ndarray:
    """(B, n_bytes) uint8 packed codes → (B, W) uint32 words (host side).

    Pads the byte axis to a multiple of 4 with zeros before the view, so
    any ``lsh_bits`` multiple of 8 maps onto whole words; both sides of a
    scan must come through here so the (platform-endian) byte→word layout
    cancels out of every XOR.
    """
    codes_u8 = np.ascontiguousarray(codes_u8, dtype=np.uint8)
    b, nbytes = codes_u8.shape
    pad = (-nbytes) % 4
    if pad:
        codes_u8 = np.concatenate(
            [codes_u8, np.zeros((b, pad), np.uint8)], axis=1)
    return codes_u8.view(np.uint32)


def _kernel(q_ref, m_ref, c_ref, out_ref):
    q = q_ref[...]      # (TQ, W) uint32
    m = m_ref[...]      # (TQ, W) uint32
    c = c_ref[...]      # (TN, W) uint32
    x = jnp.bitwise_xor(q[:, None, :], c[None, :, :]) & m[:, None, :]
    out_ref[...] = jnp.sum(
        jax.lax.population_count(x).astype(jnp.int32), axis=-1)


@functools.partial(
    jax.jit, static_argnames=("tile_q", "tile_n", "interpret"))
def hamming_scan_pallas(
    codes_q: jax.Array,
    mask_q: jax.Array,
    codes_db: jax.Array,
    tile_q: int = 8,
    tile_n: int = 128,
    interpret: bool = True,
) -> jax.Array:
    """(Q, W) × (N, W) packed uint32 codes → (Q, N) int32 masked Hamming."""
    q, w = codes_q.shape
    n, w2 = codes_db.shape
    if w != w2:
        raise ValueError(f"code word counts differ: {w} vs {w2}")
    if mask_q.shape != codes_q.shape:
        raise ValueError(
            f"mask shape {mask_q.shape} != query shape {codes_q.shape}")
    qp = -(-q // tile_q) * tile_q
    np_ = -(-n // tile_n) * tile_n
    cq = jnp.pad(codes_q.astype(jnp.uint32), ((0, qp - q), (0, 0)))
    mq = jnp.pad(mask_q.astype(jnp.uint32), ((0, qp - q), (0, 0)))
    cd = jnp.pad(codes_db.astype(jnp.uint32), ((0, np_ - n), (0, 0)))

    grid = (qp // tile_q, np_ // tile_n)
    out = pl.pallas_call(
        _kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile_q, w), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_q, w), lambda i, j: (i, 0),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((tile_n, w), lambda i, j: (j, 0),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((tile_q, tile_n), lambda i, j: (i, j),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((qp, np_), jnp.int32),
        interpret=interpret,
        name="hamming_scan",
    )(cq, mq, cd)
    return out[:q, :n]
