"""Pinned Pallas tile shapes: load ``results/TUNED_tiles.json`` winners.

``python -m repro.perfgate tune`` sweeps each kernel's grid/block space
and persists the argmin configs here; the ops layer
(:mod:`repro.kernels.ops`) resolves every tile parameter through
:func:`resolve_tiles` so a pinned winner is used automatically, with the
hardcoded defaults below as the fallback whenever the file is absent,
unparseable, from a different device, or from an older schema.  Explicit
keyword arguments always win over pinned values.

The file is keyed by a device string (``"<backend>:<device_kind>"``) —
tiles tuned on a TPU must never be silently applied on CPU interpret
runs and vice versa.
"""
from __future__ import annotations

import json
import os
import threading

import jax

TILES_ENV = "TOPOPIPE_TUNED_TILES"
TILES_SCHEMA = 1

# the hardcoded fallbacks — one entry per tunable kernel, and the full
# set of tunable parameter names each kernel accepts (unknown keys in a
# pinned config are dropped, so a stale file can never inject kwargs)
DEFAULT_TILES: dict[str, dict] = {
    "pairwise_gram": {"tile_m": 8, "tile_n": 128, "tile_d": 128},
    "sinkhorn_lse": {"tile": 128},
    "auction_lap": {"tile_b": 1},
    # the reservoir-collapsed forward/reverse auction: collapse toggles the
    # exact_w formulation ("on" = K×K reduced problem + OUT pseudo-slot,
    # "off" = legacy (2K)² expanded matrix), rev_every the forward/reverse
    # phase ratio (0 = reverse only once forward bidding has drained)
    "auction_collapsed": {"tile_b": 1, "rev_every": 8, "collapse": "on"},
    "gf2_reduce": {"batch_mode": "vmap"},
    "domination": {"tile": 128},
    # packed-code Hamming scan (TopoIndex coarse stage / ShardedIndex
    # per-shard scan): the word axis rides inside a block, so only the
    # (query, corpus) tile shape is sweepable
    "hamming": {"tile_q": 8, "tile_n": 128},
}

_lock = threading.Lock()
_cache: dict[str, dict | None] = {}


def device_string() -> str:
    """``"<backend>:<device_kind>"`` of the default device."""
    dev = jax.devices()[0]
    return f"{jax.default_backend()}:{dev.device_kind}"


def tiles_path() -> str:
    """``$TOPOPIPE_TUNED_TILES`` or ``<repo-root>/results/TUNED_tiles.json``."""
    env = os.environ.get(TILES_ENV)
    if env:
        return env
    root = os.path.dirname(os.path.dirname(os.path.dirname(
        os.path.dirname(os.path.abspath(__file__)))))
    return os.path.join(root, "results", "TUNED_tiles.json")


def _load(path: str) -> dict | None:
    try:
        with open(path) as f:
            payload = json.load(f)
    except (OSError, ValueError):
        return None
    if not isinstance(payload, dict):
        return None
    if payload.get("version") != TILES_SCHEMA:
        return None
    return payload


def load_tuned(path: str | None = None) -> dict | None:
    """The parsed tile file (cached per path), or None when unusable."""
    path = path or tiles_path()
    with _lock:
        if path not in _cache:
            _cache[path] = _load(path)
        return _cache[path]


def reload_tuned() -> None:
    """Drop the cache (tests, and after ``perfgate tune`` writes)."""
    with _lock:
        _cache.clear()


def tuned_tiles(kernel: str, path: str | None = None) -> dict:
    """Pinned config for ``kernel`` on *this* device, or ``{}``.

    Empty when the file is absent/bad, records a different device string,
    or has no entry for the kernel.  Keys not in the kernel's declared
    tunable set are dropped.
    """
    payload = load_tuned(path)
    if payload is None or payload.get("device") != device_string():
        return {}
    entry = payload.get("kernels", {}).get(kernel)
    if not isinstance(entry, dict):
        return {}
    tiles = entry.get("tiles", {})
    known = DEFAULT_TILES.get(kernel, {})
    return {k: v for k, v in tiles.items() if k in known}


def resolve_tiles(kernel: str, **overrides) -> dict:
    """defaults < pinned winners < explicit non-None keyword overrides."""
    out = dict(DEFAULT_TILES.get(kernel, {}))
    out.update(tuned_tiles(kernel))
    for k, v in overrides.items():
        if v is not None:
            out[k] = v
    return out


def save_tuned(winners: dict[str, dict], path: str | None = None,
               meta: dict | None = None) -> str:
    """Persist sweep winners: ``{kernel: {"tiles": {...}, ...}}``."""
    path = path or tiles_path()
    payload = {
        "version": TILES_SCHEMA,
        "device": device_string(),
        "kernels": winners,
    }
    payload.update(meta or {})
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    with open(path, "w") as f:
        json.dump(payload, f, indent=2, sort_keys=True)
        f.write("\n")
    reload_tuned()
    return path
