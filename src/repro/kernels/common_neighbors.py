"""Pallas TPU kernel: common-neighbor counts on edges, ``(A @ A) ⊙ A``.

Backs the clustering-coefficient stage of the paper's §D.2 conjecture
(Figs 2/10) and triangle/2-simplex counting.  Standard tiled MXU matmul with
the elementwise edge-restriction fused into the epilogue (saves one full
(N, N) HBM round trip vs computing A@A then masking).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(a_uw_ref, a_wv_ref, a_uv_ref, out_ref, acc_ref, *, n_w: int):
    iw = pl.program_id(3)

    @pl.when(iw == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += lax.dot_general(
        a_uw_ref[0], a_wv_ref[0], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )

    @pl.when(iw == n_w - 1)
    def _epilogue():
        out_ref[0] = (acc_ref[...] * a_uv_ref[0]).astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("tile", "interpret"))
def common_neighbors_pallas(
    adj: jax.Array, tile: int = 128, interpret: bool = True
) -> jax.Array:
    """cn[b, u, v] = |N(u) ∩ N(v)| on edges.  adj (B,N,N) bool -> (B,N,N) i32."""
    b, n, _ = adj.shape
    npad = -(-n // tile) * tile
    pad = npad - n
    a = jnp.pad(adj, ((0, 0), (0, pad), (0, pad))).astype(jnp.float32)

    grid = (b, npad // tile, npad // tile, npad // tile)
    out = pl.pallas_call(
        functools.partial(_kernel, n_w=grid[3]),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, tile, tile), lambda b_, u, v, w: (b_, u, w),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile, tile), lambda b_, u, v, w: (b_, w, v),
                         memory_space=pltpu.VMEM),
            pl.BlockSpec((1, tile, tile), lambda b_, u, v, w: (b_, u, v),
                         memory_space=pltpu.VMEM),
        ],
        out_specs=pl.BlockSpec((1, tile, tile), lambda b_, u, v, w: (b_, u, v),
                               memory_space=pltpu.VMEM),
        out_shape=jax.ShapeDtypeStruct((b, npad, npad), jnp.int32),
        scratch_shapes=[pltpu.VMEM((tile, tile), jnp.float32)],
        interpret=interpret,
        name="common_neighbors_fused",
    )(a, a, a)
    return out[:, :n, :n]
