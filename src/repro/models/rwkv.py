"""RWKV6 ("Finch") block: time-mix with data-dependent per-channel decay +
channel-mix.  Chunked linear-attention form for training/prefill (short
chunks keep the factored decay exponentials inside f32 range); O(1) state
decode.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import Params, _init, dense, rmsnorm

RWKV_CHUNK = 16
LORA_RANK = 64
W_CLIP = (-8.0, 1.0)  # clamp on log-log decay keeps exp(|w|*chunk) finite


def rwkv_heads(cfg) -> int:
    return cfg.d_model // 64


def rwkv_init(key, cfg) -> Params:
    d = cfg.d_model
    h = rwkv_heads(cfg)
    pdim = d // h
    ks = jax.random.split(key, 10)
    return {
        # time-mix
        "mu_r": jnp.zeros((d,), jnp.float32),
        "mu_k": jnp.zeros((d,), jnp.float32),
        "mu_v": jnp.zeros((d,), jnp.float32),
        "mu_g": jnp.zeros((d,), jnp.float32),
        "mu_w": jnp.zeros((d,), jnp.float32),
        "w_r": _init(ks[0], (d, d)),
        "w_k": _init(ks[1], (d, d)),
        "w_v": _init(ks[2], (d, d)),
        "w_g": _init(ks[3], (d, d)),
        "w_o": _init(ks[4], (d, d)),
        "w0": jnp.full((d,), -1.0, jnp.float32),
        "w_lora_a": _init(ks[5], (d, LORA_RANK)),
        "w_lora_b": _init(ks[6], (LORA_RANK, d)),
        "u_bonus": jnp.zeros((h, pdim), jnp.float32),
        "tm_norm": jnp.zeros((d,), jnp.float32),
        # channel-mix
        "cmu_r": jnp.zeros((d,), jnp.float32),
        "cmu_k": jnp.zeros((d,), jnp.float32),
        "cw_k": _init(ks[7], (d, cfg.d_ff)),
        "cw_v": _init(ks[8], (cfg.d_ff, d)),
        "cw_r": _init(ks[9], (d, d)),
    }


def _mix(x, xprev, mu):
    return x + (xprev - x) * mu.astype(x.dtype)


def _decay_log(p, xw):
    """w_log (B,S,D) in (-exp(1), -exp(-8)): negative per-channel log decay."""
    lora = jnp.tanh(dense(xw, p["w_lora_a"])) @ p["w_lora_b"].astype(xw.dtype)
    raw = p["w0"].astype(xw.dtype) + lora
    return -jnp.exp(jnp.clip(raw.astype(jnp.float32), *W_CLIP))


def time_mix_train(p: Params, x: jax.Array, cfg) -> jax.Array:
    b, s, d = x.shape
    h = rwkv_heads(cfg)
    pdim = d // h
    lc = min(RWKV_CHUNK, s)
    assert s % lc == 0
    g = s // lc

    xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    r = dense(_mix(x, xprev, p["mu_r"]), p["w_r"]).reshape(b, s, h, pdim)
    k = dense(_mix(x, xprev, p["mu_k"]), p["w_k"]).reshape(b, s, h, pdim)
    v = dense(_mix(x, xprev, p["mu_v"]), p["w_v"]).reshape(b, s, h, pdim)
    gate = jax.nn.silu(dense(_mix(x, xprev, p["mu_g"]), p["w_g"]))
    wlog = _decay_log(p, _mix(x, xprev, p["mu_w"])).reshape(b, s, h, pdim)

    rf = r.reshape(b, g, lc, h, pdim).astype(jnp.float32)
    kf = k.reshape(b, g, lc, h, pdim).astype(jnp.float32)
    vf = v.reshape(b, g, lc, h, pdim).astype(jnp.float32)
    wl = wlog.reshape(b, g, lc, h, pdim)
    cum = jnp.cumsum(wl, axis=2)  # inclusive, decreasing

    # factored decays (chunk short enough that exp stays finite)
    r_dec = rf * jnp.exp(cum - wl)  # exp(cum_{l-1})
    k_dec = kf * jnp.exp(-cum)

    att = jnp.einsum("bglhp,bgshp->bghls", r_dec, k_dec)
    tri = jnp.tril(jnp.ones((lc, lc), bool), k=-1)  # strictly lower: s < l
    att = jnp.where(tri[None, None, None], att, 0.0)
    y = jnp.einsum("bghls,bgshp->bglhp", att, vf)
    # current-token bonus
    bonus = jnp.einsum("bglhp,bglhp->bglh", rf, p["u_bonus"][None, None] * kf)
    y = y + bonus[..., None] * vf

    # inter-chunk: carry (B,H,P,P) state
    last = cum[:, :, -1:, :, :]
    k_tail = kf * jnp.exp(last - cum)  # decay from s to chunk end
    states = jnp.einsum("bgshp,bgshq->bghpq", k_tail, vf)  # key-dim x value-dim
    chunk_decay = jnp.exp(last[:, :, 0])  # (B,G,H,P)

    def step(hprev, inp):
        st, dcy = inp
        return dcy[..., None] * hprev + st, hprev

    h0 = jnp.zeros((b, h, pdim, pdim), jnp.float32)
    # NOTE: this inter-chunk recurrence stays SCANNED even under the
    # cost-exact dry-run unroll (repro.models.unroll): its body is a tiny
    # elementwise state update, so the counted-once error is negligible,
    # while unrolling 128 copies explodes compile memory at 32k sequence.
    _, h_prevs = lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,G,H,P,P)
    y = y + jnp.einsum("bglhp,bghpq->bglhq", r_dec, h_prevs)

    y = y.reshape(b, s, d).astype(x.dtype)
    y = rmsnorm(y, p["tm_norm"], cfg.rms_eps) * gate
    return dense(y, p["w_o"])


def channel_mix(p: Params, x: jax.Array, cfg,
                xprev: jax.Array | None = None) -> jax.Array:
    if xprev is None:
        xprev = jnp.pad(x, ((0, 0), (1, 0), (0, 0)))[:, :-1]
    kx = _mix(x, xprev, p["cmu_k"])
    rx = _mix(x, xprev, p["cmu_r"])
    k = jnp.square(jax.nn.relu(dense(kx, p["cw_k"])))
    return jax.nn.sigmoid(dense(rx, p["cw_r"])) * dense(k, p["cw_v"])


def rwkv_cache_init(cfg, batch: int) -> Params:
    d = cfg.d_model
    h = rwkv_heads(cfg)
    pdim = d // h
    return {
        "tm_state": jnp.zeros((batch, h, pdim, pdim), jnp.float32),
        "tm_xprev": jnp.zeros((batch, d), jnp.float32),
        "cm_xprev": jnp.zeros((batch, d), jnp.float32),
    }


def time_mix_decode(p: Params, x: jax.Array, cache: Params, cfg):
    """x (B,1,D); O(1) recurrent update."""
    b, _, d = x.shape
    h = rwkv_heads(cfg)
    pdim = d // h
    xprev = cache["tm_xprev"][:, None, :].astype(x.dtype)
    r = dense(_mix(x, xprev, p["mu_r"]), p["w_r"]).reshape(b, h, pdim)
    k = dense(_mix(x, xprev, p["mu_k"]), p["w_k"]).reshape(b, h, pdim)
    v = dense(_mix(x, xprev, p["mu_v"]), p["w_v"]).reshape(b, h, pdim)
    gate = jax.nn.silu(dense(_mix(x, xprev, p["mu_g"]), p["w_g"]))
    wlog = _decay_log(p, _mix(x, xprev, p["mu_w"])).reshape(b, h, pdim)

    rf, kf, vf = (t.astype(jnp.float32) for t in (r, k, v))
    kv = jnp.einsum("bhp,bhq->bhpq", kf, vf)
    wkv = cache["tm_state"] + p["u_bonus"][None, :, :, None] * kv
    y = jnp.einsum("bhp,bhpq->bhq", rf, wkv)
    new_state = jnp.exp(wlog)[..., None] * cache["tm_state"] + kv

    y = y.reshape(b, 1, d).astype(x.dtype)
    y = rmsnorm(y, p["tm_norm"], cfg.rms_eps) * gate
    out = dense(y, p["w_o"])
    return out, new_state


def rwkv_block_decode(p: Params, x: jax.Array, cache: Params, cfg,
                      norm1, norm2):
    """Full block (time-mix + channel-mix) decode step."""
    xn = rmsnorm(x, norm1, cfg.rms_eps)
    att, new_tm = time_mix_decode(p, xn, cache, cfg)
    x = x + att
    xn2 = rmsnorm(x, norm2, cfg.rms_eps)
    cm_prev = cache["cm_xprev"][:, None, :].astype(x.dtype)
    x = x + channel_mix(p, xn2, cfg, xprev=cm_prev)
    new_cache = {
        "tm_state": new_tm,
        "tm_xprev": xn[:, 0].astype(jnp.float32),
        "cm_xprev": xn2[:, 0].astype(jnp.float32),
    }
    return x, new_cache
