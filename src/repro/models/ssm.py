"""Mamba2 (SSD) block: chunked matmul-form scan for training/prefill, O(1)
recurrent state for decode.  Used by zamba2 (hybrid family).

Chunked SSD follows the Mamba2 paper: within a chunk the state update is
expressed as masked matmuls (MXU-friendly); across chunks a short lax.scan
carries the (H, N, P) state.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.layers import Params, _init, dense, rmsnorm


def d_inner(cfg) -> int:
    return cfg.ssm_expand * cfg.d_model


def n_ssm_heads(cfg) -> int:
    return d_inner(cfg) // cfg.ssm_head_dim


def mamba_init(key, cfg) -> Params:
    d = cfg.d_model
    din = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    conv_ch = din + 2 * n
    ks = jax.random.split(key, 4)
    return {
        "in_proj": _init(ks[0], (d, 2 * din + 2 * n + h)),
        "conv_w": _init(ks[1], (cfg.ssm_conv_width, conv_ch), scale=0.2),
        "A_log": jnp.zeros((h,), jnp.float32),  # a = -exp(A_log) = -1
        "D_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.zeros((h,), jnp.float32),
        "ssm_norm": jnp.zeros((din,), jnp.float32),
        "out_proj": _init(ks[2], (din, d)),
    }


def _causal_conv(xbc: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv, width W.  xbc (B,S,C), w (W,C)."""
    width = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (width - 1, 0), (0, 0)))
    out = jnp.zeros_like(xbc)
    for i in range(width):
        out = out + pad[:, i : i + xbc.shape[1], :] * w[i].astype(xbc.dtype)
    return out


def _split_proj(cfg, proj):
    din = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    z, xs, bmat, cmat, dt = jnp.split(
        proj, [din, 2 * din, 2 * din + n, 2 * din + 2 * n], axis=-1
    )
    return z, xs, bmat, cmat, dt


def mamba_train(p: Params, x: jax.Array, cfg) -> jax.Array:
    b, s, d = x.shape
    din = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    pdim = cfg.ssm_head_dim
    lc = min(cfg.ssm_chunk, s)
    assert s % lc == 0, (s, lc)
    g = s // lc

    proj = dense(x, p["in_proj"])
    z, xs, bm, cm, dt = _split_proj(cfg, proj)
    xbc = _causal_conv(jnp.concatenate([xs, bm, cm], -1), p["conv_w"])
    xbc = jax.nn.silu(xbc)
    xs, bm, cm = jnp.split(xbc, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,S,H)
    a = -jnp.exp(p["A_log"])  # (H,)
    xs = xs.reshape(b, g, lc, h, pdim)
    bm = bm.reshape(b, g, lc, n).astype(jnp.float32)
    cm = cm.reshape(b, g, lc, n).astype(jnp.float32)
    dt = dt.reshape(b, g, lc, h)

    da = dt * a  # (B,G,Lc,H) negative
    cum = jnp.cumsum(da, axis=2)  # inclusive
    xf = xs.astype(jnp.float32)

    # ---- intra-chunk ----
    cb = jnp.einsum("bgln,bgsn->bgls", cm, bm)
    decay = jnp.exp(cum[:, :, :, None, :] - cum[:, :, None, :, :])  # (B,G,L,S,H)
    tri = jnp.tril(jnp.ones((lc, lc), bool))
    att = jnp.where(tri[None, None, :, :, None], decay, 0.0)
    att = att * cb[..., None] * dt[:, :, None, :, :]
    y_intra = jnp.einsum("bglsh,bgshp->bglhp", att, xf)

    # ---- chunk states ----
    last = cum[:, :, -1:, :]  # (B,G,1,H)
    sdecay = jnp.exp(last - cum) * dt  # (B,G,Lc,H)
    states = jnp.einsum("bgsh,bgsn,bgshp->bghnp", sdecay, bm, xf)

    # ---- inter-chunk scan ----
    chunk_decay = jnp.exp(last[:, :, 0, :])  # (B,G,H)

    def step(hprev, inp):
        st, dcy = inp
        return dcy[:, :, None, None] * hprev + st, hprev

    h0 = jnp.zeros((b, h, n, pdim), jnp.float32)
    # NOTE: this inter-chunk recurrence stays SCANNED even under the
    # cost-exact dry-run unroll (repro.models.unroll): its body is a tiny
    # elementwise state update, so the counted-once error is negligible,
    # while unrolling 128 copies explodes compile memory at 32k sequence.
    _, h_prevs = lax.scan(
        step, h0, (jnp.moveaxis(states, 1, 0), jnp.moveaxis(chunk_decay, 1, 0))
    )
    h_prevs = jnp.moveaxis(h_prevs, 0, 1)  # (B,G,H,N,P): state before chunk g
    y_inter = jnp.einsum(
        "bgln,bghnp,bglh->bglhp", cm, h_prevs, jnp.exp(cum)
    )

    y = y_intra + y_inter + xf * p["D_skip"][None, None, None, :, None]
    y = y.reshape(b, s, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z), p["ssm_norm"], cfg.rms_eps)
    return dense(y, p["out_proj"])


def mamba_cache_init(cfg, batch: int, dtype=jnp.float32) -> Params:
    din = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    conv_ch = din + 2 * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_ch), dtype),
        "ssm": jnp.zeros((batch, h, n, cfg.ssm_head_dim), jnp.float32),
    }


def mamba_decode(p: Params, x: jax.Array, cache: Params, cfg) -> tuple[jax.Array, Params]:
    """x (B,1,D) -> (y, new_cache); O(1) per token."""
    b = x.shape[0]
    din = d_inner(cfg)
    n = cfg.ssm_state
    h = n_ssm_heads(cfg)
    pdim = cfg.ssm_head_dim

    proj = dense(x, p["in_proj"])[:, 0]  # (B, ...)
    z, xs, bm, cm, dt = _split_proj(cfg, proj)
    xbc_new = jnp.concatenate([xs, bm, cm], -1)  # (B, C)
    window = jnp.concatenate([cache["conv"], xbc_new[:, None, :]], axis=1)
    w = p["conv_w"].astype(x.dtype)
    xbc = jnp.einsum("bwc,wc->bc", window, w)
    xbc = jax.nn.silu(xbc)
    xs, bm, cm = jnp.split(xbc, [din, din + n], axis=-1)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (B,H)
    a = -jnp.exp(p["A_log"])
    decay = jnp.exp(dt * a)  # (B,H)
    xh = xs.reshape(b, h, pdim).astype(jnp.float32)
    ssm = cache["ssm"] * decay[:, :, None, None] + jnp.einsum(
        "bh,bn,bhp->bhnp", dt, bm.astype(jnp.float32), xh
    )
    y = jnp.einsum("bn,bhnp->bhp", cm.astype(jnp.float32), ssm)
    y = y + xh * p["D_skip"][None, :, None]
    y = y.reshape(b, 1, din).astype(x.dtype)
    y = rmsnorm(y * jax.nn.silu(z[:, None, :]), p["ssm_norm"], cfg.rms_eps)
    return dense(y, p["out_proj"]), {"conv": window[:, 1:], "ssm": ssm}
