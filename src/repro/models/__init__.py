"""Assigned-architecture model zoo (dense/MoE/hybrid/SSM/enc-dec/VLM)."""
