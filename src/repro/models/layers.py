"""Shared NN layers: norms, RoPE/M-RoPE, GQA attention (train + decode),
gated MLP, capacity-based MoE.

Conventions:
* params are plain nested dicts of f32 arrays; compute is bf16 (norms and
  softmax accumulate in f32).
* every apply function is shape-polymorphic over batch and works under scan
  (no python-side state).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

Params = dict
COMPUTE_DTYPE = jnp.bfloat16


def _init(key, shape, scale=0.02):
    return (scale * jax.random.normal(key, shape)).astype(jnp.float32)


def rmsnorm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(x.dtype)


def dense(x: jax.Array, w: jax.Array, b: jax.Array | None = None) -> jax.Array:
    y = jnp.einsum("...d,df->...f", x, w.astype(x.dtype))
    if b is not None:
        y = y + b.astype(x.dtype)
    return y


# ---------------------------------------------------------------- RoPE ----
def rope_tables(positions: jax.Array, dim: int, theta: float) -> tuple[jax.Array, jax.Array]:
    """positions (...,) int -> cos/sin (..., dim//2) f32."""
    half = dim // 2
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    ang = positions.astype(jnp.float32)[..., None] * freq
    return jnp.cos(ang), jnp.sin(ang)


def mrope_tables(positions: jax.Array, dim: int, theta: float,
                 sections: tuple[int, ...]) -> tuple[jax.Array, jax.Array]:
    """M-RoPE (Qwen2-VL): positions (..., 3) [t, h, w]; per-frequency section
    selects which position stream drives the angle."""
    half = dim // 2
    assert sum(sections) == half, (sections, half)
    freq = 1.0 / (theta ** (jnp.arange(half, dtype=jnp.float32) / half))
    sec_id = jnp.concatenate(
        [jnp.full((s,), i, jnp.int32) for i, s in enumerate(sections)]
    )
    pos_sel = jnp.take_along_axis(
        positions.astype(jnp.float32),
        jnp.broadcast_to(sec_id, positions.shape[:-1] + (half,)).astype(jnp.int32),
        axis=-1,
    )
    ang = pos_sel * freq
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: jax.Array, cos: jax.Array, sin: jax.Array) -> jax.Array:
    """x (..., H, dh); cos/sin broadcastable to (..., 1, dh//2)."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    c = cos[..., None, :].astype(x.dtype)
    s = sin[..., None, :].astype(x.dtype)
    return jnp.concatenate([x1 * c - x2 * s, x2 * c + x1 * s], axis=-1)


# ----------------------------------------------------------- attention ----
def attn_init(key, cfg) -> Params:
    d, dh = cfg.d_model, cfg.head_dim
    h, kv = cfg.q_heads, cfg.kv_heads
    ks = jax.random.split(key, 6)
    p = {
        "w_q": _init(ks[0], (d, h * dh)),
        "w_k": _init(ks[1], (d, kv * dh)),
        "w_v": _init(ks[2], (d, kv * dh)),
        "w_o": _init(ks[3], (h * dh, d)),
    }
    if cfg.qkv_bias:
        p["b_q"] = jnp.zeros((h * dh,), jnp.float32)
        p["b_k"] = jnp.zeros((kv * dh,), jnp.float32)
        p["b_v"] = jnp.zeros((kv * dh,), jnp.float32)
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((dh,), jnp.float32)
        p["k_norm"] = jnp.zeros((dh,), jnp.float32)
    return p


def _qkv(p: Params, x: jax.Array, cfg, cos, sin):
    b = x.shape[0]
    s = x.shape[1]
    h, kv, dh = cfg.q_heads, cfg.kv_heads, cfg.head_dim
    q = dense(x, p["w_q"], p.get("b_q")).reshape(b, s, h, dh)
    k = dense(x, p["w_k"], p.get("b_k")).reshape(b, s, kv, dh)
    v = dense(x, p["w_v"], p.get("b_v")).reshape(b, s, kv, dh)
    if cfg.qk_norm:
        q = rmsnorm(q, p["q_norm"], cfg.rms_eps)
        k = rmsnorm(k, p["k_norm"], cfg.rms_eps)
    if cos is not None:
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    return q, k, v


def _sdpa(q, k, v, mask, dh):
    """q (B,Sq,H,dh), k/v (B,Sk,KV,dh), mask (B|1, Sq, Sk) bool keep.

    §Perf gemma iteration 1 (REFUTED, reverted): a manual bf16-probs
    softmax was hypothesized to halve the (Sq, Sk) score traffic; measured
    +3% bytes instead — XLA's fused softmax already avoids the extra
    materializations the manual version introduced.  See EXPERIMENTS.md.
    """
    bq, sq, h, _ = q.shape
    kv = k.shape[2]
    rep = h // kv
    qg = q.reshape(bq, sq, kv, rep, dh)
    scores = jnp.einsum("bqkrd,bskd->bkrqs", qg, k).astype(jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(dh))
    scores = jnp.where(mask[:, None, None, :, :], scores, -1e30)
    probs = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
    out = jnp.einsum("bkrqs,bskd->bqkrd", probs, v)
    return out.reshape(bq, sq, h * dh)


def attn_train(p: Params, x: jax.Array, cfg, cos, sin,
               window: int = 0, causal: bool = True) -> jax.Array:
    """Full-sequence attention, query-chunked when the sequence is long."""
    b, s, _ = x.shape
    dh = cfg.head_dim
    q, k, v = _qkv(p, x, cfg, cos, sin)
    pos = jnp.arange(s)

    chunk = cfg.attn_chunk
    if s <= 2 * chunk or s % chunk != 0:
        mask = jnp.ones((1, s, s), bool)
        if causal:
            mask = mask & (pos[None, :, None] >= pos[None, None, :])
        if window:
            mask = mask & (pos[None, :, None] - pos[None, None, :] < window)
        out = _sdpa(q, k, v, mask, dh)
    elif window and window <= chunk and causal:
        # §Perf gemma iteration 2 — banded sliding-window attention: a
        # causal query chunk only sees keys in [c*chunk - window + 1,
        # c*chunk + chunk), so slice a (window + chunk) K/V band instead of
        # computing (chunk, S) scores and masking.  Score traffic and flops
        # per local layer drop by S / (window + chunk) (2x for gemma3's
        # S=4096, window=chunk=1024).  Front-pad K/V so the band never
        # clamps; padded keys carry kpos < 0 and are masked out.
        n_chunks = s // chunk
        band = window + chunk
        kp = jnp.pad(k, ((0, 0), (window, 0), (0, 0), (0, 0)))
        vp = jnp.pad(v, ((0, 0), (window, 0), (0, 0), (0, 0)))

        def one_chunk(c):
            q0 = c * chunk
            qpos = q0 + jnp.arange(chunk)
            kpos = q0 - window + jnp.arange(band)
            qc = lax.dynamic_slice_in_dim(q, q0, chunk, axis=1)
            kc = lax.dynamic_slice_in_dim(kp, q0, band, axis=1)
            vc = lax.dynamic_slice_in_dim(vp, q0, band, axis=1)
            mask = ((qpos[None, :, None] >= kpos[None, None, :])
                    & (qpos[None, :, None] - kpos[None, None, :] < window)
                    & (kpos[None, None, :] >= 0))
            return _sdpa(qc, kc, vc, mask, dh)

        out = lax.map(one_chunk, jnp.arange(n_chunks))
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, -1)
    else:
        n_chunks = s // chunk

        def one_chunk(c):
            qpos = c * chunk + jnp.arange(chunk)
            qc = lax.dynamic_slice_in_dim(q, c * chunk, chunk, axis=1)
            mask = jnp.ones((1, chunk, s), bool)
            if causal:
                mask = mask & (qpos[None, :, None] >= pos[None, None, :])
            if window:
                mask = mask & (qpos[None, :, None] - pos[None, None, :] < window)
            return _sdpa(qc, k, v, mask, dh)

        out = lax.map(one_chunk, jnp.arange(n_chunks))  # (C, B, chunk, H*dh)
        out = jnp.moveaxis(out, 0, 1).reshape(b, s, -1)
    return dense(out, p["w_o"])


def attn_decode(p: Params, x: jax.Array, cache: Params, pos: jax.Array, cfg,
                cos, sin, window: int = 0) -> tuple[jax.Array, Params]:
    """One-token decode against a fixed-capacity KV cache.

    x (B,1,D); cache {k,v}: (B,Skv,KV,dh); pos scalar i32 (current index).
    """
    b = x.shape[0]
    dh = cfg.head_dim
    q, k_new, v_new = _qkv(p, x, cfg, cos, sin)
    ck = lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), pos, axis=1
    )
    cv = lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), pos, axis=1
    )
    s_kv = ck.shape[1]
    idx = jnp.arange(s_kv)
    keep = idx <= pos
    if window:
        keep = keep & (idx > pos - window)
    mask = jnp.broadcast_to(keep[None, None, :], (1, 1, s_kv))
    out = _sdpa(q, ck, cv, mask, dh)
    return dense(out, p["w_o"]), {"k": ck, "v": cv}


def attn_cache_init(cfg, batch: int, s_kv: int, dtype=COMPUTE_DTYPE) -> Params:
    kv, dh = cfg.kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, s_kv, kv, dh), dtype),
        "v": jnp.zeros((batch, s_kv, kv, dh), dtype),
    }


# ----------------------------------------------------------------- MLP ----
def mlp_init(key, cfg) -> Params:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 3)
    if cfg.gated_mlp:
        return {
            "w_gate": _init(ks[0], (d, f)),
            "w_up": _init(ks[1], (d, f)),
            "w_down": _init(ks[2], (f, d)),
        }
    return {"w_up": _init(ks[0], (d, f)), "w_down": _init(ks[1], (f, d))}


def mlp_apply(p: Params, x: jax.Array, cfg) -> jax.Array:
    if "w_gate" in p:
        h = jax.nn.silu(dense(x, p["w_gate"])) * dense(x, p["w_up"])
    else:
        h = jax.nn.gelu(dense(x, p["w_up"]))
    return dense(h, p["w_down"])


# ----------------------------------------------------------------- MoE ----
def moe_init(key, cfg) -> Params:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.n_experts
    ks = jax.random.split(key, 4)
    return {
        "moe_gate": _init(ks[0], (d, e)),
        "moe_wg": _init(ks[1], (e, d, f)),
        "moe_wu": _init(ks[2], (e, d, f)),
        "moe_wd": _init(ks[3], (e, f, d)),
    }


def moe_apply(p: Params, x: jax.Array, cfg) -> jax.Array:
    """Capacity-based top-k MoE with one-hot dispatch einsums (EP-shardable).

    Routing is *block-local* (``cfg.moe_group`` tokens per group, the
    GSPMD-MoE / Switch "group size"): capacity applies within each group, so
    the dispatch/combine tensors are (G, TB, E, CB) with CB proportional to
    TB — dispatch flops are linear in total tokens instead of quadratic, and
    when the group boundary aligns with the data shard the whole MoE layer
    partitions with NO cross-data collectives (the group axis is batch-like).
    §Perf olmoe iterations 1-2; ``moe_group=0`` recovers the naive
    one-global-group baseline.
    """
    b, s, d = x.shape
    t = b * s
    e, k = cfg.n_experts, cfg.moe_top_k
    grp = getattr(cfg, "moe_group", 0)
    tb = grp if grp and t % grp == 0 else t
    g = t // tb
    cap = max(1, int(cfg.capacity_factor * tb * k / e))
    xt = x.reshape(g, tb, d)

    logits = dense(xt, p["moe_gate"]).astype(jnp.float32)  # (G, TB, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = lax.top_k(probs, k)  # (G, TB, K)
    top_p = top_p / jnp.maximum(jnp.sum(top_p, -1, keepdims=True), 1e-9)

    # position of each (token, rank) within its expert queue (per group)
    onehot = jax.nn.one_hot(top_e, e, dtype=jnp.float32)  # (G, TB, K, E)
    flat = onehot.reshape(g, tb * k, e)
    pos_in_e = (jnp.cumsum(flat, axis=1) - flat).reshape(g, tb, k, e)
    pos = jnp.sum(pos_in_e * onehot, axis=-1)  # (G, TB, K)
    keep = pos < cap
    gate = jnp.where(keep, top_p, 0.0)  # (G, TB, K)

    cap_oh = jax.nn.one_hot(pos.astype(jnp.int32), cap, dtype=jnp.float32)
    dispatch = jnp.einsum("gtke,gtkc->gtec", onehot * keep[..., None], cap_oh)
    combine = jnp.einsum("gtke,gtkc,gtk->gtec", onehot, cap_oh, gate)

    xe = jnp.einsum("gtec,gtd->gecd", dispatch.astype(x.dtype), xt)
    hg = jax.nn.silu(jnp.einsum("gecd,edf->gecf", xe, p["moe_wg"].astype(x.dtype)))
    hu = jnp.einsum("gecd,edf->gecf", xe, p["moe_wu"].astype(x.dtype))
    ye = jnp.einsum("gecf,efd->gecd", hg * hu, p["moe_wd"].astype(x.dtype))
    y = jnp.einsum("gtec,gecd->gtd", combine.astype(x.dtype), ye)
    return y.reshape(b, s, d)
