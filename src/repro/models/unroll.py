"""Scan-unroll switch for cost-exact dry-runs.

XLA's ``compiled.cost_analysis()`` counts a ``while`` body ONCE, not
multiplied by its trip count, so a layer-scanned model under-reports FLOPs /
bytes / collective traffic by ~n_layers x grad_accum.  The dry-run flips this
flag to fully unroll every structural scan (layers, grad-accum microbatches,
SSM chunk recurrences) so the roofline terms are exact.  Training/serving
keep the scanned form (compile cost = one body per block kind).
"""
from __future__ import annotations

_FLAG = {"unroll": False}


def set_unroll(value: bool) -> None:
    _FLAG["unroll"] = bool(value)


def scan_unroll() -> bool | int:
    """Value for the ``unroll=`` argument of ``lax.scan``."""
    return True if _FLAG["unroll"] else 1
