"""Model zoo orchestrator: segments of scanned blocks covering all six
assigned families (dense GQA, MoE, Mamba2-hybrid, RWKV6, enc-dec, VLM).

A model is a list of *segments*; each segment is a homogeneous stack of
blocks executed under ``lax.scan`` with parameters stacked on the leading
axis (compile cost = one block body per distinct kind, not per layer).
Heterogeneous repeat patterns (gemma3's 5-local:1-global, zamba2's shared
attention every N mamba layers) are composite "period" kinds whose body
unrolls the pattern once.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.unroll import scan_unroll
from repro.models import rwkv as rw
from repro.models import ssm
from repro.models.config import ModelConfig
from repro.models.layers import (
    COMPUTE_DTYPE,
    Params,
    _init,
    attn_cache_init,
    attn_decode,
    attn_init,
    attn_train,
    dense,
    mlp_apply,
    mlp_init,
    moe_apply,
    moe_init,
    mrope_tables,
    rmsnorm,
    rope_tables,
)


@dataclasses.dataclass(frozen=True)
class Ctx:
    cfg: ModelConfig
    mode: str  # "train" | "prefill" | "decode"
    cos: Any
    sin: Any
    pos: Any = None  # decode: scalar i32 current position
    enc_out: Any = None  # encdec: (B, S_enc, D)
    shared: Any = None  # zamba: shared attention block params

    @property
    def decoding(self) -> bool:
        return self.mode == "decode"


# ------------------------------------------------------------- segments ----
def segments(cfg: ModelConfig) -> list[tuple[str, int]]:
    """[(block_kind, count)] executed in order (decoder side for encdec)."""
    if cfg.family == "moe":
        return [("moe_block", cfg.n_layers)]
    if cfg.family == "ssm" and cfg.rwkv:
        return [("rwkv_block", cfg.n_layers)]
    if cfg.family == "hybrid":
        period = cfg.attn_period
        n_per = cfg.n_layers // period
        tail = cfg.n_layers % period
        segs = [("zamba_period", n_per)]
        if tail:
            segs.append(("mamba_block", tail))
        return segs
    if cfg.local_global_pattern != (0, 0):
        nl, ng = cfg.local_global_pattern
        per = nl + ng
        n_per = cfg.n_layers // per
        tail = cfg.n_layers % per
        segs = [("lg_period", n_per)]
        if tail:
            segs.append(("local_block", tail))
        return segs
    if cfg.family == "encdec":
        return [("dec_block", cfg.n_layers)]
    return [("attn_block", cfg.n_layers)]


# ----------------------------------------------------------- block init ----
def _attn_block_init(key, cfg, use_moe=False) -> Params:
    k1, k2 = jax.random.split(key)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "attn": attn_init(k1, cfg),
        "ln2": jnp.zeros((d,), jnp.float32),
        "mlp": moe_init(k2, cfg) if use_moe else mlp_init(k2, cfg),
    }


def _mamba_block_init(key, cfg) -> Params:
    return {
        "ln": jnp.zeros((cfg.d_model,), jnp.float32),
        "mamba": ssm.mamba_init(key, cfg),
    }


def _rwkv_block_init(key, cfg) -> Params:
    return {
        "ln1": jnp.zeros((cfg.d_model,), jnp.float32),
        "ln2": jnp.zeros((cfg.d_model,), jnp.float32),
        "mix": rw.rwkv_init(key, cfg),
    }


def _dec_block_init(key, cfg) -> Params:
    k1, k2, k3 = jax.random.split(key, 3)
    d = cfg.d_model
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "self_attn": attn_init(k1, cfg),
        "ln2": jnp.zeros((d,), jnp.float32),
        "cross_attn": attn_init(k2, cfg),
        "ln3": jnp.zeros((d,), jnp.float32),
        "mlp": mlp_init(k3, cfg),
    }


def block_init(kind: str, key, cfg) -> Params:
    if kind == "attn_block" or kind == "local_block":
        return _attn_block_init(key, cfg)
    if kind == "moe_block":
        return _attn_block_init(key, cfg, use_moe=True)
    if kind == "mamba_block":
        return _mamba_block_init(key, cfg)
    if kind == "rwkv_block":
        return _rwkv_block_init(key, cfg)
    if kind == "dec_block" or kind == "enc_block":
        return _dec_block_init(key, cfg) if kind == "dec_block" else _attn_block_init(key, cfg)
    if kind == "zamba_period":
        keys = jax.random.split(key, cfg.attn_period)
        return {"mambas": jax.vmap(lambda k: _mamba_block_init(k, cfg))(keys)}
    if kind == "lg_period":
        nl, ng = cfg.local_global_pattern
        keys = jax.random.split(key, nl + 1)
        return {
            "locals": jax.vmap(lambda k: _attn_block_init(k, cfg))(keys[:nl]),
            "global": _attn_block_init(keys[nl], cfg),
        }
    raise ValueError(kind)


# ---------------------------------------------------------- block cache ----
def block_cache_init(kind: str, cfg, batch: int, s_kv: int):
    if kind in ("attn_block", "moe_block", "local_block"):
        return attn_cache_init(cfg, batch, s_kv)
    if kind == "mamba_block":
        return ssm.mamba_cache_init(cfg, batch)
    if kind == "rwkv_block":
        return rw.rwkv_cache_init(cfg, batch)
    if kind == "dec_block":
        return {"self": attn_cache_init(cfg, batch, s_kv)}
    if kind == "zamba_period":
        m = jax.tree.map(
            lambda x: jnp.stack([x] * cfg.attn_period),
            ssm.mamba_cache_init(cfg, batch),
        )
        return {"mambas": m, "attn": attn_cache_init(cfg, batch, s_kv)}
    if kind == "lg_period":
        nl, _ = cfg.local_global_pattern
        window_kv = min(s_kv, cfg.sliding_window) if cfg.sliding_window else s_kv
        loc = jax.tree.map(
            lambda x: jnp.stack([x] * nl), attn_cache_init(cfg, batch, s_kv)
        )
        return {"locals": loc, "global": attn_cache_init(cfg, batch, s_kv)}
    raise ValueError(kind)


# ---------------------------------------------------------- block apply ----
def _apply_attn_mlp(p, x, ctx, cache, window=0, causal=True):
    cfg = ctx.cfg
    xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
    if ctx.decoding:
        att, new_cache = attn_decode(
            p["attn"], xn, cache, ctx.pos, cfg, ctx.cos, ctx.sin, window=window
        )
    else:
        att = attn_train(p["attn"], xn, cfg, ctx.cos, ctx.sin, window=window,
                         causal=causal)
        new_cache = None
        if ctx.mode == "prefill" and cache is not None:
            # write the full-sequence K/V into the cache prefix
            q, k, v = None, None, None  # recomputed below at low cost
            new_cache = _prefill_kv(p["attn"], xn, cfg, ctx, cache, window)
    x = x + att
    xn2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
    if "moe_gate" in p["mlp"]:
        x = x + moe_apply(p["mlp"], xn2, cfg)
    else:
        x = x + mlp_apply(p["mlp"], xn2, cfg)
    return x, new_cache


def _prefill_kv(ap, xn, cfg, ctx, cache, window):
    from repro.models.layers import _qkv

    _, k, v = _qkv(ap, xn, cfg, ctx.cos, ctx.sin)
    s = k.shape[1]
    ck = lax.dynamic_update_slice_in_dim(
        cache["k"], k.astype(cache["k"].dtype), 0, axis=1
    )
    cv = lax.dynamic_update_slice_in_dim(
        cache["v"], v.astype(cache["v"].dtype), 0, axis=1
    )
    return {"k": ck, "v": cv}


def _apply_mamba(p, x, ctx, cache):
    cfg = ctx.cfg
    xn = rmsnorm(x, p["ln"], cfg.rms_eps)
    if ctx.decoding:
        y, new_cache = ssm.mamba_decode(p["mamba"], xn, cache, cfg)
    else:
        y = ssm.mamba_train(p["mamba"], xn, cfg)
        new_cache = cache  # prefill state return handled at serving layer
    return x + y, new_cache


def _apply_rwkv(p, x, ctx, cache):
    cfg = ctx.cfg
    if ctx.decoding:
        return rw.rwkv_block_decode(p["mix"], x, cache, cfg, p["ln1"], p["ln2"])
    xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
    x = x + rw.time_mix_train(p["mix"], xn, cfg)
    xn2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
    x = x + rw.channel_mix(p["mix"], xn2, cfg)
    return x, cache


def _apply_cross(p_attn, x, ctx):
    """Cross-attention over ctx.enc_out (no rope, not causal)."""
    cfg = ctx.cfg
    from repro.models.layers import _qkv, _sdpa

    b, sq = x.shape[0], x.shape[1]
    dh = cfg.head_dim
    h, kv = cfg.q_heads, cfg.kv_heads
    q = dense(x, p_attn["w_q"], p_attn.get("b_q")).reshape(b, sq, h, dh)
    enc = ctx.enc_out.astype(x.dtype)
    sk = enc.shape[1]
    k = dense(enc, p_attn["w_k"], p_attn.get("b_k")).reshape(b, sk, kv, dh)
    v = dense(enc, p_attn["w_v"], p_attn.get("b_v")).reshape(b, sk, kv, dh)
    mask = jnp.ones((1, sq, sk), bool)
    out = _sdpa(q, k, v, mask, dh)
    return dense(out, p_attn["w_o"])


def _apply_dec_block(p, x, ctx, cache):
    cfg = ctx.cfg
    xn = rmsnorm(x, p["ln1"], cfg.rms_eps)
    if ctx.decoding:
        att, new_self = attn_decode(
            p["self_attn"], xn, cache["self"], ctx.pos, cfg, ctx.cos, ctx.sin
        )
        new_cache = {"self": new_self}
    else:
        att = attn_train(p["self_attn"], xn, cfg, ctx.cos, ctx.sin)
        new_cache = None
        if ctx.mode == "prefill" and cache is not None:
            new_cache = {"self": _prefill_kv(p["self_attn"], xn, cfg, ctx, cache["self"], 0)}
    x = x + att
    xn2 = rmsnorm(x, p["ln2"], cfg.rms_eps)
    x = x + _apply_cross(p["cross_attn"], xn2, ctx)
    xn3 = rmsnorm(x, p["ln3"], cfg.rms_eps)
    x = x + mlp_apply(p["mlp"], xn3, cfg)
    return x, new_cache


def apply_block(kind: str, p: Params, x, ctx: Ctx, cache):
    cfg = ctx.cfg
    if kind == "attn_block" or kind == "moe_block":
        return _apply_attn_mlp(p, x, ctx, cache)
    if kind == "local_block":
        return _apply_attn_mlp(p, x, ctx, cache, window=cfg.sliding_window)
    if kind == "enc_block":
        return _apply_attn_mlp(p, x, ctx, cache, causal=False)
    if kind == "mamba_block":
        return _apply_mamba(p, x, ctx, cache)
    if kind == "rwkv_block":
        return _apply_rwkv(p, x, ctx, cache)
    if kind == "dec_block":
        return _apply_dec_block(p, x, ctx, cache)
    if kind == "zamba_period":
        def body(xc, inp):
            pp, cc = inp
            xo, nc = _apply_mamba(pp, xc, ctx, cc)
            return xo, nc

        mcache = cache["mambas"] if cache is not None else None
        x, new_m = _scan(body, x, p["mambas"], mcache)
        acache = cache["attn"] if cache is not None else None
        x, new_a = _apply_attn_mlp(ctx.shared, x, ctx, acache)
        newc = None if cache is None and ctx.mode == "train" else {
            "mambas": new_m, "attn": new_a,
        }
        return x, newc
    if kind == "lg_period":
        def body(xc, inp):
            pp, cc = inp
            return _apply_attn_mlp(pp, xc, ctx, cc, window=cfg.sliding_window)

        lcache = cache["locals"] if cache is not None else None
        x, new_l = _scan(body, x, p["locals"], lcache)
        gcache = cache["global"] if cache is not None else None
        x, new_g = _apply_attn_mlp(p["global"], x, ctx, gcache)
        newc = None if cache is None and ctx.mode == "train" else {
            "locals": new_l, "global": new_g,
        }
        return x, newc
    raise ValueError(kind)


def _scan(body, x, stacked_params, stacked_cache, remat: bool = False):
    if remat:
        body = jax.checkpoint(body)
    if stacked_cache is None:
        x, _ = lax.scan(lambda xc, pp: body(xc, (pp, None)), x, stacked_params,
                        unroll=scan_unroll())
        return x, None
    return lax.scan(body, x, (stacked_params, stacked_cache),
                    unroll=scan_unroll())


# ------------------------------------------------------------- the model ---
def init_params(cfg: ModelConfig, key) -> Params:
    keys = jax.random.split(key, 8)
    d = cfg.d_model
    params: Params = {
        "embed": _init(keys[0], (cfg.vocab_size, d)),
        "final_norm": jnp.zeros((d,), jnp.float32),
        "segments": {},
    }
    for i, (kind, count) in enumerate(segments(cfg)):
        ks = jax.random.split(keys[1 + (i % 6)], count)
        params["segments"][f"seg{i}_{kind}"] = jax.vmap(
            lambda k: block_init(kind, k, cfg)
        )(ks)
    if cfg.family == "hybrid":
        params["shared_attn"] = _attn_block_init(keys[7], cfg)
    if cfg.family == "encdec":
        enc_keys = jax.random.split(keys[6], cfg.n_enc_layers)
        params["enc_segments"] = jax.vmap(
            lambda k: block_init("enc_block", k, cfg)
        )(enc_keys)
        params["enc_norm"] = jnp.zeros((d,), jnp.float32)
    if not cfg.tie_embeddings:
        params["unembed"] = _init(keys[5], (d, cfg.vocab_size))
    return params


def _rope_for(cfg: ModelConfig, positions, mrope_positions=None):
    dh = cfg.head_dim
    if cfg.mrope_sections and mrope_positions is not None:
        return mrope_tables(mrope_positions, dh, cfg.rope_theta, cfg.mrope_sections)
    return rope_tables(positions, dh, cfg.rope_theta)


def _encode(params, frames, cfg) -> jax.Array:
    """Whisper-style encoder over stubbed conv-frontend frames (B, T, D)."""
    x = frames.astype(COMPUTE_DTYPE)
    pos = jnp.arange(x.shape[1])
    cos, sin = rope_tables(pos, cfg.head_dim, cfg.rope_theta)
    ctx = Ctx(cfg=cfg, mode="train", cos=cos, sin=sin)

    def body(xc, pp):
        xo, _ = _apply_attn_mlp(pp, xc, ctx, None, causal=False)
        return xo, None

    x, _ = lax.scan(body, x, params["enc_segments"], unroll=scan_unroll())
    return rmsnorm(x, params["enc_norm"], cfg.rms_eps)


def forward(
    params: Params,
    cfg: ModelConfig,
    tokens: jax.Array,
    mode: str = "train",
    caches: Params | None = None,
    pos: jax.Array | None = None,
    frames: jax.Array | None = None,
    vision: jax.Array | None = None,
    mrope_positions: jax.Array | None = None,
):
    """Returns (logits, new_caches).

    train/prefill: tokens (B, S).  decode: tokens (B, 1) with ``caches`` and
    scalar ``pos``.  ``frames``: encdec encoder input (stub frontend).
    ``vision``: (B, n_vis, D) stub patch embeddings overriding the first
    n_vis positions (VLM).  ``mrope_positions``: (B, S|1, 3).
    """
    from repro.models.pjit_utils import constrain

    b, s = tokens.shape
    d = cfg.d_model
    x = jnp.take(params["embed"], tokens, axis=0).astype(COMPUTE_DTYPE)
    x = x * jnp.asarray(jnp.sqrt(d), COMPUTE_DTYPE)
    x = constrain(x, "dp", None, None)
    if vision is not None and cfg.vision_tokens:
        nv = vision.shape[1]
        if mode != "decode":
            sel = (jnp.arange(s) < nv)[None, :, None]
            vis_pad = jnp.zeros_like(x).at[:, :nv, :].set(
                vision[:, : min(nv, s)].astype(COMPUTE_DTYPE)
            )
            x = jnp.where(sel, vis_pad, x)

    if mode == "decode":
        positions = jnp.asarray(pos)[None]
    else:
        positions = jnp.arange(s)
    cos, sin = _rope_for(cfg, positions, mrope_positions)

    enc_out = None
    if cfg.family == "encdec":
        enc_out = _encode(params, frames, cfg)

    ctx = Ctx(
        cfg=cfg, mode=mode, cos=cos, sin=sin, pos=pos, enc_out=enc_out,
        shared=params.get("shared_attn"),
    )

    new_caches = {}
    for name, seg_params in params["segments"].items():
        kind = name.split("_", 1)[1]
        seg_cache = None if caches is None else caches[name]

        def body(xc, inp):
            pp, cc = inp
            return apply_block(kind, pp, xc, ctx, cc)

        x, nc = _scan(body, x, seg_params, seg_cache, remat=(mode == "train"))
        new_caches[name] = nc

    x = rmsnorm(x, params["final_norm"], cfg.rms_eps)
    x = constrain(x, "dp", None, None)
    if cfg.tie_embeddings:
        logits = jnp.einsum("bsd,vd->bsv", x, params["embed"].astype(x.dtype))
    else:
        logits = dense(x, params["unembed"])
    logits = constrain(logits, "dp", None, "tp")
    return logits, (new_caches if caches is not None or mode == "prefill" else None)


def init_caches(cfg: ModelConfig, batch: int, s_kv: int) -> Params:
    out = {}
    for i, (kind, count) in enumerate(segments(cfg)):
        one = block_cache_init(kind, cfg, batch, s_kv)
        out[f"seg{i}_{kind}"] = jax.tree.map(
            lambda x: jnp.broadcast_to(x[None], (count,) + x.shape).copy(), one
        )
    return out


def loss_fn(params: Params, cfg: ModelConfig, tokens: jax.Array,
            frames=None, vision=None, mrope_positions=None) -> jax.Array:
    """Causal LM loss (next-token CE), SPMD-friendly over a vocab-sharded
    logits tensor: lse via sharded reductions, target logit via a one-hot
    einsum (no gather along the sharded vocab axis)."""
    logits, _ = forward(
        params, cfg, tokens, mode="train", frames=frames, vision=vision,
        mrope_positions=mrope_positions,
    )
    lg = logits[:, :-1].astype(jnp.float32)
    targets = tokens[:, 1:]
    mx = jax.lax.stop_gradient(jnp.max(lg, axis=-1, keepdims=True))
    lse = jnp.log(jnp.sum(jnp.exp(lg - mx), axis=-1)) + mx[..., 0]
    oh = jax.nn.one_hot(targets, cfg.vocab_size, dtype=lg.dtype)
    tgt = jnp.einsum("bsv,bsv->bs", lg, oh)
    return jnp.mean(lse - tgt)
