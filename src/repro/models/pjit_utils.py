"""Sharding-constraint plumbing for model code.

Model code is mesh-agnostic; the launch layer activates an "axis environment"
(`set_axis_env`) and the model sprinkles `constrain(x, ...)` hints that become
`with_sharding_constraint` when active and no-ops otherwise (CPU tests).
"""
from __future__ import annotations

from typing import Sequence

import jax
from jax.sharding import PartitionSpec as P

_ACTIVE: dict | None = None


def set_axis_env(dp: Sequence[str] = ("data",), tp: str = "model") -> None:
    global _ACTIVE
    _ACTIVE = {"dp": tuple(dp), "tp": tp}


def clear_axis_env() -> None:
    global _ACTIVE
    _ACTIVE = None


def axis_env() -> dict | None:
    return _ACTIVE


def constrain(x: jax.Array, *dims: str | None) -> jax.Array:
    """dims use logical names: "dp" (batch), "tp" (tensor), None.

    Example: constrain(x, "dp", None, None) for (B, S, D) activations.
    """
    if _ACTIVE is None:
        return x
    spec = tuple(
        _ACTIVE["dp"] if d == "dp" else (_ACTIVE["tp"] if d == "tp" else None)
        for d in dims
    )
    return jax.lax.with_sharding_constraint(x, P(*spec))
