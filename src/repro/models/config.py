"""Model configuration for the assigned architecture zoo."""
from __future__ import annotations

import dataclasses
from typing import Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | hybrid | ssm | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    d_head: int = 0  # 0 -> d_model // n_heads

    # attention flavor
    qk_norm: bool = False
    qkv_bias: bool = False
    rope_theta: float = 1_000_000.0
    sliding_window: int = 0  # 0 = full attention
    local_global_pattern: Tuple[int, int] = (0, 0)  # (n_local, n_global) per period

    # MoE
    n_experts: int = 0
    moe_top_k: int = 0
    capacity_factor: float = 1.25

    # Mamba2 (hybrid / ssm families)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_conv_width: int = 4
    attn_period: int = 0  # hybrid: one shared attn block per `attn_period` ssm layers

    # RWKV6
    rwkv: bool = False

    # encoder-decoder (whisper)
    n_enc_layers: int = 0
    enc_seq: int = 0  # stubbed conv-frontend frames
    gated_mlp: bool = True

    # VLM (qwen2-vl)
    mrope_sections: Tuple[int, ...] = ()
    vision_tokens: int = 0

    rms_eps: float = 1e-6
    tie_embeddings: bool = True
    # long_500k eligibility (sub-quadratic sequence mixing)
    supports_long_context: bool = False

    # compute knobs (not architecture): chunk sizes etc.
    attn_chunk: int = 1024  # query-chunked attention threshold block
    ssm_chunk: int = 256
    # MoE dispatch group size (tokens per routing block).  0 = one global
    # group (the naive formulation: (T,E,C) dispatch tensors with C ∝ T —
    # quadratic flops and a cross-data psum).  Block-local capacity is the
    # standard GSPMD-MoE/Switch "group_size"; §Perf olmoe iteration 1.
    moe_group: int = 4096

    @property
    def head_dim(self) -> int:
        return self.d_head or (self.d_model // self.n_heads)

    @property
    def q_heads(self) -> int:
        return self.n_heads

    @property
    def kv_heads(self) -> int:
        return self.n_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (for roofline MODEL_FLOPS)."""
        d, dh = self.d_model, self.head_dim
        emb = self.vocab_size * d * (1 if self.tie_embeddings else 2)
        attn = d * dh * (self.q_heads + 2 * self.kv_heads) + self.q_heads * dh * d
        if self.gated_mlp:
            mlp = 3 * d * self.d_ff
        else:
            mlp = 2 * d * self.d_ff
        if self.family == "moe":
            mlp_layer = self.n_experts * mlp + d * self.n_experts
            per_layer = attn + mlp_layer
            total = self.n_layers * per_layer
        elif self.family in ("ssm",) and self.rwkv:
            # rwkv6: r/k/v/g/o (D,D) + w lora + channel-mix (D,3.5D-ish)
            tm = 5 * d * d + 2 * d * 64
            cm = 2 * d * self.d_ff
            total = self.n_layers * (tm + cm)
        elif self.family == "hybrid":
            d_in = self.ssm_expand * d
            m_layer = d * (2 * d_in + 2 * self.ssm_state + d_in // self.ssm_head_dim) + d_in * d
            n_attn = self.n_layers // max(self.attn_period, 1)
            total = self.n_layers * m_layer + (attn + mlp)  # attn block shared
            total += 0 * n_attn
        elif self.family == "encdec":
            enc = self.n_enc_layers * (attn + mlp)
            dec = self.n_layers * (2 * attn + mlp)
            total = enc + dec
        else:
            total = self.n_layers * (attn + mlp)
        return int(total + emb + d)

    def active_param_count(self) -> int:
        """Active params per token (MoE: top_k experts only)."""
        if self.family != "moe":
            return self.param_count()
        d = self.d_model
        dh = self.head_dim
        attn = d * dh * (self.q_heads + 2 * self.kv_heads) + self.q_heads * dh * d
        mlp = (3 if self.gated_mlp else 2) * d * self.d_ff * self.moe_top_k
        emb = self.vocab_size * d
        return int(self.n_layers * (attn + mlp + d * self.n_experts) + emb + d)


@dataclasses.dataclass(frozen=True)
class ShapeConfig:
    """One (input-shape) cell of the assignment."""

    name: str  # train_4k | prefill_32k | decode_32k | long_500k
    kind: str  # train | prefill | decode
    seq_len: int
    global_batch: int
    grad_accum: int = 1  # train only


SHAPES = {
    "train_4k": ShapeConfig("train_4k", "train", 4096, 256, grad_accum=8),
    "prefill_32k": ShapeConfig("prefill_32k", "prefill", 32768, 32),
    "decode_32k": ShapeConfig("decode_32k", "decode", 32768, 128),
    "long_500k": ShapeConfig("long_500k", "decode", 524288, 1),
}
