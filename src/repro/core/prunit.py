r"""PrunIT: dominated-vertex pruning that preserves every persistence diagram.

Paper Theorem 7: if ``u`` is dominated by ``v`` (closed neighborhoods,
``N[u] ⊆ N[v]``) and ``f(u) >= f(v)`` (sublevel filtration; reversed for
superlevel), then ``PD_k(G, f) = PD_k(G - {u}, f)`` for all k >= 0.

Domination as linear algebra (DESIGN.md §3, paper Remark 9 rewritten for the
MXU): with ``Nc = A ∨ I`` the closed-neighborhood matrix,

    viol[u, v] = Σ_w Nc[u, w] · (1 − Nc[v, w]) = |N[u] \\ N[v]|

so ``viol[u, v] == 0  ⟺  v dominates u``.  ``viol`` is one (B, N, N) matmul.
Note u != v and viol==0 forces A[u,v]=1 (u ∈ N[u] ⊆ N[v]), so dominated
vertices are always adjacent to a dominator.

Batch-removal safety.  The paper removes one dominated vertex at a time.  We
remove a whole independent-of-conflicts batch per round:

    remove u  ⟺  ∃v:  elig(u→v)  ∧  ( ¬elig(v→u)  ∨  v < u )

where ``elig(u→v) = dom(u by v) ∧ f(u) >= f(v) ∧ u != v``.  Soundness: give
every removed u a witness v from the rule.  (i) Domination (and the f
condition) is preserved by deleting any *other* vertex z ∉ {u, v}: N[u]\{z} ⊆
N[v]\{z}.  (ii) Witness chains u → v → w … cannot cycle: elig is transitive on
its dom component (⊆ is transitive) and f is non-increasing along a chain; a
cycle forces all dominations mutual with equal f, and then the index tiebreak
(v < u) makes the witness edge strictly index-decreasing.  So chains end at a
survivor, and deleting each round's batch in reverse chain order is a valid
sequential PrunIT execution.  Hence the batch removal is exact.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import GraphBatch


def domination_matrix(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """(B, N, N) bool D with D[u, v] = "v dominates u" (closed nbhd, u != v).

    Pure-jnp reference path; the Pallas kernel in repro/kernels/domination.py
    computes the same thing tiled in VMEM.
    """
    n = adj.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    live = mask[..., None, :] & mask[..., :, None]
    nc = (adj | eye) & live & mask[..., :, None]  # closed nbhd rows of live u
    nc_f = nc.astype(jnp.float32)
    # viol[u, v] = sum_w nc[u, w] * (1 - nc[v, w]); only count live w.
    not_ncv = (~nc).astype(jnp.float32) * mask[..., None, :].astype(jnp.float32)
    viol = jnp.einsum("buw,bvw->buv", nc_f, not_ncv)
    dom = (viol == 0) & ~eye & live
    return dom


def eligibility_matrix(adj: jax.Array, mask: jax.Array, f: jax.Array,
                       sublevel: bool = True,
                       dom_fn=domination_matrix,
                       equal_only: bool = False) -> jax.Array:
    """(B, N, N) bool E with E[u, v] = "PrunIT may remove u with witness v".

    Theorem 7's full hypothesis: domination (``dom_fn``) plus the filtration
    condition ``f(u) >= f(v)`` (reversed for superlevel).  Shared by the
    PrunIT reduction rounds below and TopoStream's invalidation predicate
    (repro/stream/topo_stream.py) so the eligibility condition lives in
    exactly one place.

    ``equal_only=True`` tightens the filtration condition to ``f(u) == f(v)``
    — the orientation-free special case (it satisfies Theorem 7 for sublevel
    AND superlevel simultaneously), which is the graph-level strong-collapse
    pass of the ReductionEngine (repro/core/reduction.py).
    """
    dom = dom_fn(adj, mask)  # dom[u, v]: v dominates u
    if equal_only:
        f_ok = f[..., :, None] == f[..., None, :]
    elif sublevel:
        f_ok = f[..., :, None] >= f[..., None, :]  # f(u) >= f(v)
    else:
        f_ok = f[..., :, None] <= f[..., None, :]
    return dom & f_ok


def prune_round_mask(
    adj: jax.Array,
    mask: jax.Array,
    f: jax.Array,
    sublevel: bool = True,
    dom_fn=domination_matrix,
    equal_only: bool = False,
) -> jax.Array:
    """One parallel PrunIT round: the mask of vertices that survive."""
    elig = eligibility_matrix(adj, mask, f, sublevel, dom_fn,
                              equal_only=equal_only)  # elig[u, v]
    elig_t = jnp.swapaxes(elig, -1, -2)  # elig[v, u]
    n = adj.shape[-1]
    idx = jnp.arange(n)
    v_lt_u = idx[None, :] < idx[:, None]  # [u, v]: v < u
    removable_by = elig & (~elig_t | v_lt_u)
    removed = jnp.any(removable_by, axis=-1)
    return mask & ~removed


@partial(jax.jit, static_argnames=("sublevel", "max_rounds", "equal_only"))
def prunit_mask(
    adj: jax.Array,
    mask: jax.Array,
    f: jax.Array,
    sublevel: bool = True,
    max_rounds: int | None = None,
    equal_only: bool = False,
) -> jax.Array:
    """Iterate parallel prune rounds to a fixed point; returns surviving mask."""

    def cond(state):
        m, changed, r = state
        ok = changed
        if max_rounds is not None:
            ok = ok & (r < max_rounds)
        return ok

    def body(state):
        m, _, r = state
        adj_m = adj & m[..., None, :] & m[..., :, None]
        new = prune_round_mask(adj_m, m, jnp.where(m, f, jnp.inf), sublevel,
                               equal_only=equal_only)
        return new, jnp.any(new != m), r + 1

    m, _, _ = lax.while_loop(cond, body, (mask, jnp.array(True), jnp.array(0)))
    return m


def prunit(g: GraphBatch, sublevel: bool = True, max_rounds: int | None = None) -> GraphBatch:
    """PrunIT-reduce every graph in the batch (exact for all PD_k)."""
    return g.with_mask(prunit_mask(g.adj, g.mask, g.f, sublevel, max_rounds))


def prunit_then_coral(g: GraphBatch, dim: int, sublevel: bool = True) -> GraphBatch:
    """Combined reduction of §5.1: PD_k(G) = PD_k((G')^{k+1})."""
    from repro.core.kcore import coral_reduce

    return coral_reduce(prunit(g, sublevel=sublevel), dim)
