"""JAX bit-packed GF(2) persistence (boundary-matrix reduction).

The boundary matrix of the filtered clique complex is packed 32
simplices/`uint32` word so a column XOR is a short vector op, and the standard
reduction (pivot-chase with `low`) runs under `lax.fori_loop`/`while_loop`.
Everything vmaps over a GraphBatch and pjit-shards over the data axis — this
is the paper's workload (millions of small ego-net PDs) as one SPMD program.

A Pallas kernel with the identical algorithm living entirely in VMEM is in
repro/kernels/gf2_reduce.py; this module is its jnp reference and the default
CPU path.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.filtration import FilteredComplex, build_filtered_complex
from repro.core.graph import GraphBatch

WORD = 32


def pack_boundary(fc: FilteredComplex) -> jax.Array:
    """(S, W) uint32 packed boundary columns in sorted filtration order."""
    s = fc.size
    w = (s + WORD - 1) // WORD
    rows = jnp.repeat(jnp.arange(s), fc.face_pos.shape[1])
    fp = fc.face_pos.reshape(-1)
    ok = fp >= 0
    word = jnp.where(ok, fp // WORD, 0)
    bit = jnp.where(ok, fp % WORD, 0)
    contrib = jnp.where(ok, (jnp.uint32(1) << bit.astype(jnp.uint32)), jnp.uint32(0))
    b = jnp.zeros((s, w), jnp.uint32)
    # distinct faces -> distinct bits, so add == or
    return b.at[rows, word].add(contrib)


def _low(col: jax.Array) -> jax.Array:
    """Index of the highest set bit of a packed column, or -1."""
    w = col.shape[0]
    nz = col != 0
    any_bit = jnp.any(nz)
    # last nonzero word
    widx = (w - 1) - jnp.argmax(nz[::-1])
    word = col[widx]
    bit = 31 - lax.clz(word).astype(jnp.int32)
    return jnp.where(any_bit, widx.astype(jnp.int32) * WORD + bit, -1)


def reduce_packed(b: jax.Array, n_rows: int | None = None) -> tuple[jax.Array, jax.Array]:
    """Run the standard reduction. Returns (pivot_owner, positive).

    pivot_owner: (n_rows,) i32, pivot_owner[i] = j if column j kills row
    (simplex) i, else -1.  positive: (S,) bool, column reduced to zero (a
    birth).  In the flat square case rows == columns (n_rows = S); the
    per-dimension block path passes rectangular blocks.
    """
    s = b.shape[0]
    n_rows = s if n_rows is None else n_rows

    def col_body(j, state):
        bm, owner, positive = state

        def w_cond(cs):
            col, done, _ = cs
            return ~done

        def w_body(cs):
            col, _, _ = cs
            l = _low(col)

            def no_bits(_):
                return col, jnp.array(True), jnp.int32(-1)

            def has_bits(_):
                p = owner[l]

                def claim(_):
                    return col, jnp.array(True), l

                def xor(_):
                    return col ^ bm[p], jnp.array(False), jnp.int32(-1)

                return lax.cond(p < 0, claim, xor, None)

            return lax.cond(l < 0, no_bits, has_bits, None)

        col0 = bm[j]
        col, _, claimed = lax.while_loop(
            w_cond, w_body, (col0, jnp.array(False), jnp.int32(-1))
        )
        bm = bm.at[j].set(col)
        owner = lax.cond(
            claimed >= 0, lambda o: o.at[claimed].set(j), lambda o: o, owner
        )
        positive = positive.at[j].set(claimed < 0)
        return bm, owner, positive

    owner0 = jnp.full((n_rows,), -1, jnp.int32)
    pos0 = jnp.zeros((s,), bool)
    _, owner, positive = lax.fori_loop(0, s, col_body, (b, owner0, pos0))
    return owner, positive


def _block_caps(fc: FilteredComplex, n: int, edge_cap: int, tri_cap: int,
                quad_cap: int) -> list[int]:
    caps = [n, edge_cap]
    if tri_cap:
        caps.append(tri_cap)
    if quad_cap:
        caps.append(quad_cap)
    return caps


def pack_boundary_blocks(fc: FilteredComplex, caps: list[int]):
    """Per-dimension packed boundary blocks (§Perf iteration 3).

    A dim-d column only has dim-(d-1) rows, so reducing each dimension as its
    own (cap_d, ceil(cap_{d-1}/32)) block shrinks the packed state ~4x vs
    one (S, S/32) matrix and keeps every pivot chase inside its block (the
    standard per-dimension PH reduction, here in bit-packed form).

    Returns (blocks, ranks, pos_of_rank):
      blocks[d]: (caps[d], W_{d-1}) u32 for d >= 1, in within-dim filtration
                 (rank) order;
      ranks: (S,) i32 within-dim rank of each sorted position;
      pos_of_rank[d]: (caps[d],) i32 sorted position of each rank (-1 pad).
    """
    sel = [fc.dims == d for d in range(len(caps))]
    ranks = jnp.zeros(fc.size, jnp.int32)
    pos_of_rank = []
    for d, s_d in enumerate(sel):
        r_d = jnp.cumsum(s_d.astype(jnp.int32)) - 1
        ranks = jnp.where(s_d, r_d, ranks)
        por = jnp.full((caps[d],), -1, jnp.int32)
        pos = jnp.arange(fc.size, dtype=jnp.int32)
        por = por.at[jnp.where(s_d, r_d, caps[d])].set(
            jnp.where(s_d, pos, -1), mode="drop")
        pos_of_rank.append(por)

    blocks = []
    for d in range(1, len(caps)):
        w = (caps[d - 1] + WORD - 1) // WORD
        por = pos_of_rank[d]
        valid_col = por >= 0
        fp = fc.face_pos[jnp.clip(por, 0), : d + 1]  # (cap_d, d+1) positions
        ok = (fp >= 0) & valid_col[:, None]
        r = jnp.where(ok, ranks[jnp.clip(fp, 0)], 0)
        word = jnp.where(ok, r // WORD, 0)
        bit = (r % WORD).astype(jnp.uint32)
        contrib = jnp.where(ok, jnp.uint32(1) << bit, jnp.uint32(0))
        b = jnp.zeros((caps[d], w), jnp.uint32)
        rows = jnp.repeat(jnp.arange(caps[d]), d + 1)
        b = b.at[rows, word.reshape(-1)].add(contrib.reshape(-1))
        blocks.append(b)
    return blocks, ranks, pos_of_rank


def reduce_packed_blocks(fc: FilteredComplex, caps: list[int],
                         inner=reduce_packed):
    """Per-dimension block reduction; returns global (owner, positive)."""
    blocks, ranks, pos_of_rank = pack_boundary_blocks(fc, caps)
    owner = jnp.full((fc.size,), -1, jnp.int32)
    positive = sel0 = (fc.dims == 0)  # vertices: always births
    for d in range(1, len(caps)):
        own_d, pos_d = inner(blocks[d - 1], caps[d - 1])  # rows: dim d-1 ranks
        # rows killed by a dim-d column
        killed = own_d >= 0
        row_pos = pos_of_rank[d - 1]
        col_pos = pos_of_rank[d][jnp.clip(own_d, 0)]
        owner = owner.at[jnp.where(killed, row_pos, fc.size)].set(
            jnp.where(killed, col_pos, -1), mode="drop")
        # columns reduced to zero are births of dim d
        cpos = pos_of_rank[d]
        cvalid = cpos >= 0
        positive = positive.at[jnp.where(cvalid, cpos, fc.size)].set(
            jnp.where(cvalid, pos_d, False), mode="drop")
    return owner, positive


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class Diagrams:
    """Fixed-size persistence diagram tensor (per graph; vmap for batches).

    Each *birth simplex position* i contributes one row:
      birth/death: (S,) f32 (death = +inf for essential classes),
      dim:  (S,) i32 homology dimension,
      valid:(S,) bool (paired-with-persistence or essential, dim <= max_dim).
    """

    birth: jax.Array
    death: jax.Array
    dim: jax.Array
    valid: jax.Array

    def count(self, k: int) -> jax.Array:
        return jnp.sum(self.valid & (self.dim == k), axis=-1)

    def betti(self, k: int) -> jax.Array:
        return jnp.sum(
            self.valid & (self.dim == k) & jnp.isinf(self.death), axis=-1
        )

    # The one masking convention for downstream arithmetic (features,
    # metrics): invalid rows carry NaN birth/death sentinels and essential
    # classes carry +inf death, so both must be sanitized before any masked
    # sum/sort touches the tensors.
    def finite_birth(self) -> jax.Array:
        """(..., S) birth with invalid-row NaN sentinels replaced by 0."""
        return jnp.where(self.valid, jnp.nan_to_num(self.birth), 0.0)

    def finite_death(self, cap: float) -> jax.Array:
        """(..., S) death with NaN -> 0 and +inf (essential) capped at ``cap``."""
        death = jnp.nan_to_num(self.death, nan=0.0, posinf=cap)
        return jnp.where(self.valid, death, 0.0)

    def finite_points(self, cap: float) -> tuple[jax.Array, jax.Array]:
        """Sanitized ``(birth, death)`` pair; the masked-arithmetic layout
        shared by ``repro.topo.features`` and ``repro.metrics``."""
        return self.finite_birth(), self.finite_death(cap)


def pairs_to_diagrams(
    fc: FilteredComplex, owner: jax.Array, positive: jax.Array, max_dim: int,
    sublevel: bool = True,
) -> Diagrams:
    s = fc.size
    killed = owner >= 0
    death_val = jnp.where(killed, fc.values[jnp.clip(owner, 0)], jnp.inf)
    birth_val = fc.values
    essential = positive & ~killed & fc.valid
    is_birth = (killed | essential) & fc.valid
    nonzero_pers = ~killed | (death_val != birth_val)
    valid = is_birth & nonzero_pers & (fc.dims <= max_dim) & (fc.dims >= 0)
    sign = 1.0 if sublevel else -1.0
    birth = jnp.where(valid, sign * birth_val, jnp.nan)
    death = jnp.where(
        valid, jnp.where(jnp.isinf(death_val), jnp.inf, sign * death_val), jnp.nan
    )
    return Diagrams(birth=birth, death=death, dim=jnp.where(valid, fc.dims, -1), valid=valid)


@partial(
    jax.jit,
    static_argnames=("max_dim", "edge_cap", "tri_cap", "quad_cap", "sublevel", "reducer"),
)
def persistence_diagrams_batched(
    g: GraphBatch,
    max_dim: int = 1,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    sublevel: bool = True,
    reducer: str = "jnp",
) -> Diagrams:
    """Exact PDs of every graph in the batch (vmapped bit-packed reduction).

    reducer: "jnp" (this module) or "pallas" (VMEM kernel, interpret on CPU).
    """

    def one(adj, mask, f):
        fc = build_filtered_complex(
            adj, mask, f, max_dim, edge_cap, tri_cap, quad_cap, sublevel
        )
        n = adj.shape[-1]
        if reducer in ("jnp", "pallas"):
            # per-dimension block reduction (§Perf iteration 3, default)
            if reducer == "pallas":
                from repro.kernels import ops as kops

                inner = kops.gf2_reduce
            else:
                inner = reduce_packed
            caps = _block_caps(fc, n, edge_cap, tri_cap, quad_cap)
            owner, positive = reduce_packed_blocks(fc, caps, inner=inner)
        else:  # "jnp-flat" / "pallas-flat": one (S, S/32) matrix
            b = pack_boundary(fc)
            if reducer == "pallas-flat":
                from repro.kernels import ops as kops

                owner, positive = kops.gf2_reduce(b)
            else:
                owner, positive = reduce_packed(b)
        return pairs_to_diagrams(fc, owner, positive, max_dim, sublevel)

    return jax.vmap(one)(g.adj, g.mask, g.f)


def diagrams_bitwise_equal(a: Diagrams, b: Diagrams) -> bool:
    """Bit-identical Diagrams comparison (NaN == NaN on invalid rows).

    The serving layer's parity contract (benchmarks/serve_bench.py,
    tests/test_topo_serve.py): scheduling must never change numerics.
    """
    import numpy as np

    return (
        np.array_equal(np.asarray(a.birth), np.asarray(b.birth), equal_nan=True)
        and np.array_equal(np.asarray(a.death), np.asarray(b.death), equal_nan=True)
        and np.array_equal(np.asarray(a.dim), np.asarray(b.dim))
        and np.array_equal(np.asarray(a.valid), np.asarray(b.valid))
    )


def diagrams_to_numpy(d: Diagrams, batch_index: int, max_dim: int):
    """Extract a {dim: [(birth, death)]} dict matching persistence_ref."""
    import numpy as np

    out = {}
    b = np.asarray(d.birth[batch_index])
    dd = np.asarray(d.death[batch_index])
    dim = np.asarray(d.dim[batch_index])
    val = np.asarray(d.valid[batch_index])
    for k in range(max_dim + 1):
        sel = val & (dim == k)
        out[k] = sorted(zip(b[sel].tolist(), dd[sel].tolist()))
    return out
