"""High-level TopoPipe API: reduce -> filter -> persist, batched & shardable.

This is the paper's contribution packaged as a composable JAX module: feed a
GraphBatch, choose a reduction (coral / prunit / both / none), get exact
persistence diagrams.  All functions are jit/vmap/pjit friendly; the launch
layer shards batches over the ("pod", "data") mesh axes.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp

from repro.core.graph import GraphBatch
from repro.core.kcore import coral_reduce, kcore
from repro.core.persistence_jax import Diagrams, persistence_diagrams_batched
from repro.core.prunit import prunit


REDUCTIONS = ("none", "coral", "prunit", "both")


def reduce_graphs(g: GraphBatch, dim: int, method: str = "both",
                  sublevel: bool = True) -> GraphBatch:
    """Apply the paper's reduction(s) for computing PD_dim."""
    if method not in REDUCTIONS:
        raise ValueError(f"unknown reduction {method!r}; want one of {REDUCTIONS}")
    if method in ("prunit", "both"):
        g = prunit(g, sublevel=sublevel)
    if method in ("coral", "both"):
        g = coral_reduce(g, dim)
    return g


@partial(jax.jit, static_argnames=("dim", "method", "sublevel", "edge_cap",
                                   "tri_cap", "quad_cap", "reducer"))
def topological_signature(
    g: GraphBatch,
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
) -> Diagrams:
    """End-to-end: reduce with the paper's algorithms, then exact PDs.

    The returned Diagrams cover dimensions 0..dim.  (Coral reduction is only
    exact for dimensions >= dim's core level, so when ``method`` includes
    coral, read out only dimension ``dim`` — or use method="prunit" for all
    dims at once.)
    """
    gr = reduce_graphs(g, dim, method, sublevel)
    return persistence_diagrams_batched(
        gr, max_dim=dim, edge_cap=edge_cap, tri_cap=tri_cap, quad_cap=quad_cap,
        sublevel=sublevel, reducer=reducer,
    )


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ReductionStats:
    """Per-graph reduction accounting (the paper's evaluation metric)."""

    v_before: jax.Array
    v_after: jax.Array
    e_before: jax.Array
    e_after: jax.Array

    def v_reduction_pct(self) -> jax.Array:
        v0 = jnp.maximum(self.v_before, 1)
        return 100.0 * (self.v_before - self.v_after) / v0

    def e_reduction_pct(self) -> jax.Array:
        e0 = jnp.maximum(self.e_before, 1)
        return 100.0 * (self.e_before - self.e_after) / e0


def topological_signature_sharded(
    g: GraphBatch,
    mesh,
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
) -> Diagrams:
    """``topological_signature`` under shard_map over every mesh axis.

    The workload is embarrassingly parallel over graphs, but under plain pjit
    GSPMD cannot partition the vmapped scatter/gather/top-k ops inside the
    pipeline and inserts batch all-gathers (measured: 0.6-3 GB/device on a
    256-chip mesh).  shard_map pins the whole pipeline per-device, so the
    collective term is exactly zero (§Perf iteration 5).  The global batch
    must divide the mesh size.
    """
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    axes = tuple(mesh.axis_names)
    spec = P(axes)

    def per_device(adj, mask, f):
        gb = GraphBatch(adj=adj, mask=mask, f=f)
        return topological_signature(
            gb, dim=dim, method=method, sublevel=sublevel,
            edge_cap=edge_cap, tri_cap=tri_cap, quad_cap=quad_cap,
            reducer=reducer,
        )

    return shard_map(
        per_device, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=Diagrams(birth=spec, death=spec, dim=spec, valid=spec),
        check_rep=False,
    )(g.adj, g.mask, g.f)


@partial(jax.jit, static_argnames=("dim", "method", "sublevel"))
def reduction_stats(g: GraphBatch, dim: int, method: str = "both",
                    sublevel: bool = True) -> ReductionStats:
    gr = reduce_graphs(g, dim, method, sublevel)
    return ReductionStats(
        v_before=g.n_vertices(), v_after=gr.n_vertices(),
        e_before=g.n_edges(), e_after=gr.n_edges(),
    )
