"""High-level TopoPipe API: reduce -> repack -> persist, batched & shardable.

This is the paper's contribution packaged as a composable JAX module: feed a
GraphBatch, choose a reduction (a legacy method name or an explicit pass
tuple from the :mod:`repro.core.reduction` registry), get exact persistence
diagrams.  All functions are jit/vmap/pjit friendly; the launch layer shards
batches over the ("pod", "data") mesh axes.

Compilation is organised as an explicit **plan -> execute** split (see
docs/ARCHITECTURE.md §Plan/Execute): ``make_topo_plan(...)`` returns a
``TopoPlan`` — one compiled pipeline per distinct ``TopoPlanKey``, held in a
process-wide LRU cache — and ``topological_signature`` is a thin wrapper
over it.  The serve layer (repro/serve/topo_serve.py), the feature pipeline
(repro/topo/features.py) and the benchmarks all go through this one path, so
a given pipeline shape is compiled exactly once per process.

Plans execute in one of two modes (docs/ARCHITECTURE.md §ReductionEngine):

* ``repack="off"`` (default) — the historical single-phase path: one jitted
  (or shard_mapped) reduce→persist body compiled at the *input* caps.  This
  is the parity oracle for everything below.
* ``repack="on"`` — two-phase: a jitted **reduce plan** (fixpoint pass
  iteration + vertex compaction + simplex-count measurement) runs at input
  caps; the host then re-buckets every reduced graph into the smallest
  :class:`~repro.core.repack.ShapeClass` of a bounded ladder and executes a
  **persist plan** (``passes=()``) per rung — so the expensive GF(2) stage
  compiles and runs at *reduced* size.  Persist plans live in the same plan
  cache, keyed only by their rung, and are therefore shared by every caller
  (serve buckets, stream sessions) whose reductions land on the same rung.
"""
from __future__ import annotations

import dataclasses
import threading
import time
from collections import OrderedDict
from functools import partial
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core.graph import GraphBatch
from repro.core.persistence_jax import Diagrams, persistence_diagrams_batched
from repro.core.reduction import (
    apply_passes,
    engine_exact_from_dim,
    method_for_passes,
    passes_for_method,
    run_reduction,
    validate_passes,
)
from repro.core.repack import (
    RepackReport,
    ShapeClass,
    compact_batch,
    default_ladder,
    diagram_size,
    measure_counts,
    select_classes,
    slice_to,
)


REDUCTIONS = ("none", "coral", "prunit", "both")
REPACK_MODES = ("off", "on")


def reduce_graphs(g: GraphBatch, dim: int, method: str = "both",
                  sublevel: bool = True) -> GraphBatch:
    """Apply the paper's reduction(s) for computing PD_dim (one sweep).

    Thin wrapper over the pass engine: ``method`` maps to a pass tuple
    (``"both"`` → ``("prunit", "kcore")``) applied once in order — the
    single-phase reduction every ``repack="off"`` plan compiles.
    """
    if method not in REDUCTIONS:
        raise ValueError(f"unknown reduction {method!r}; want one of {REDUCTIONS}")
    return apply_passes(g, passes_for_method(method), dim, sublevel)


@dataclasses.dataclass(frozen=True)
class TopoPlanKey:
    """Hashable identity of one compiled TDA pipeline (the plan-cache key).

    Two calls that agree on every field share one ``TopoPlan`` and therefore
    one jit cache; anything not in this key (batch size, padded order) is a
    jit shape specialization *inside* the plan, not a new plan.

    ``passes`` replaces the former ``method`` string (legacy names still
    accepted at ``make_topo_plan``); ``repack`` selects single- vs two-phase
    execution, ``fixpoint`` whether the pass list iterates to its joint
    fixpoint or runs one sweep, and ``ladder`` optionally pins the persist
    shape classes (``None`` derives the default ladder from the input shape
    at execute time — see repro/core/repack.py).
    """

    dim: int
    passes: tuple[str, ...]
    sublevel: bool
    edge_cap: int
    tri_cap: int
    quad_cap: int
    reducer: str
    mesh: Any = None  # jax.sharding.Mesh (hashable) or None for single-host
    repack: str = "off"
    fixpoint: bool = False
    ladder: Optional[tuple[ShapeClass, ...]] = None

    def caps(self) -> tuple[int, int, int]:
        return (self.edge_cap, self.tri_cap, self.quad_cap)

    @property
    def method(self) -> str:
        return method_for_passes(self.passes)


@dataclasses.dataclass(frozen=True)
class TopoPlan:
    """A compiled reduce->persist pipeline plus its static metadata.

    ``execute`` (alias ``__call__``) maps a GraphBatch to Diagrams.  With
    ``repack="off"`` that is a single jitted (or shard_mapped, when the plan
    carries a mesh) program; with ``repack="on"`` it is the two-phase driver
    — ``reduce_plan`` (jitted) → host repack → per-rung ``persist_plan``
    execution — and ``execute_info`` additionally returns the
    :class:`~repro.core.repack.RepackReport` of rung assignments.  The plan
    object is safe to hold across requests — re-executing with the same
    (B, N) shape never recompiles.
    """

    key: TopoPlanKey
    executor: Optional[Callable[[GraphBatch], Diagrams]] = None
    reduce_executor: Optional[Callable] = None
    _ladders: dict = dataclasses.field(
        default_factory=dict, compare=False, repr=False)

    def execute(self, g: GraphBatch) -> Diagrams:
        if self.key.repack == "on":
            return self.execute_info(g)[0]
        # dispatch is async: this span covers trace/dispatch, not device
        # time — callers that block (serve) wrap the sync in serve.sync
        with obs.span("plan.execute", graphs=g.batch, n=g.n):
            return self.executor(g)

    def __call__(self, g: GraphBatch) -> Diagrams:
        return self.execute(g)

    @property
    def dim(self) -> int:
        return self.key.dim

    @property
    def method(self) -> str:
        return self.key.method

    @property
    def passes(self) -> tuple[str, ...]:
        return self.key.passes

    @property
    def sublevel(self) -> bool:
        return self.key.sublevel

    def exact_from_dim(self) -> int:
        """Lowest homology dimension this plan's reduction preserves."""
        return engine_exact_from_dim(self.key.passes, self.key.dim)

    # --------------------------------------------------------- two-phase

    @property
    def reduce_plan(self) -> Optional[Callable]:
        """Phase 1 (``repack="on"``): jitted fixpoint-reduce + compact +
        measure; ``reduce_plan(g) -> (compacted GraphBatch, (nv, ne, nt))``.
        """
        return self.reduce_executor

    def persist_plan(self, sc: ShapeClass) -> "TopoPlan":
        """Phase 2: the compiled no-reduction persist pipeline of one rung.

        Keyed only by ``(dim, (), sublevel, rung caps, reducer)`` in the
        process-wide cache — every caller whose reduced graphs land on this
        rung shares the same compiled executable.
        """
        return make_topo_plan(
            dim=self.key.dim, passes=(), sublevel=self.key.sublevel,
            edge_cap=sc.edge_cap, tri_cap=sc.tri_cap, quad_cap=sc.quad_cap,
            reducer=self.key.reducer)

    def ladder_for(self, n: int) -> tuple[ShapeClass, ...]:
        """The persist ladder used for input padded order ``n``.

        Custom ladders (``key.ladder``) are sanitized per input shape: rungs
        wider than the input order or with caps above the plan's caps are
        dropped — they can never be *needed* (every graph fits the input
        shape, and a wider rung would emit more diagram rows than the
        single-phase row count the output is padded to) — and a top rung at
        exactly the input shape is appended so first-fit always lands.  This
        keeps one ladder shareable across serve buckets whose plans differ
        in caps (non-monotone bucket configs included).
        """
        k = self.key
        lad = self._ladders.get(n)
        if lad is not None:
            return lad
        if k.ladder is not None:
            top = ShapeClass(n_pad=n, edge_cap=k.edge_cap,
                             tri_cap=k.tri_cap, quad_cap=k.quad_cap)
            # tetrahedra are never measured (the one count that does not
            # pay for itself), so when quads are live (dim >= 2) a rung
            # must carry the plan's quad_cap verbatim — smaller would
            # silently truncate, larger would overflow the row budget
            quads_live = k.dim >= 2 and k.quad_cap
            fits = {c for c in k.ladder
                    if (c.n_pad <= n and c.edge_cap <= k.edge_cap
                        and c.tri_cap <= k.tri_cap
                        and (c.quad_cap == k.quad_cap if quads_live
                             else c.quad_cap <= k.quad_cap))}
            fits.add(top)
            lad = tuple(sorted(fits))
        else:
            lad = default_ladder(
                n, k.edge_cap, k.tri_cap if k.dim >= 1 else 0,
                k.quad_cap if k.dim >= 2 else 0)
        return self._ladders.setdefault(n, lad)

    def execute_info(self, g: GraphBatch
                     ) -> tuple[Diagrams, Optional[RepackReport]]:
        """Execute, also returning the repack report (``None`` when off).

        Two-phase driver: reduce/compact/measure under one jitted program,
        fetch the per-graph counts to the host (the one phase-boundary
        sync), group graphs by first-fit shape class, run each group —
        padded to a power-of-two batch so jit signatures stay bounded —
        through its rung's persist plan, and scatter the rows back into an
        input-order Diagrams tensor padded to the single-phase row count
        (rows past a rung's capacity are invalid padding, so downstream
        masked arithmetic and canonical-pair extraction see one shape).
        """
        if self.key.repack != "on":
            return self.executor(g), None
        k = self.key
        with obs.span("plan.reduce", graphs=g.batch, n=g.n):
            gc, counts = self.reduce_executor(g)
        with obs.span("plan.measure"):  # the one phase-boundary host sync
            nv, ne, nt = (np.asarray(c) for c in counts)
        with obs.span("plan.repack"):
            ladder = self.ladder_for(g.n)
            cls_idx = select_classes(ladder, nv, ne, nt)
        s_full = diagram_size(g.n, k.dim, k.edge_cap, k.tri_cap, k.quad_cap)
        out = _invalid_diagrams(g.batch, s_full)
        for ci in sorted(set(cls_idx.tolist())):
            sc = ladder[ci]
            idx = np.nonzero(cls_idx == ci)[0]
            n_g = len(idx)
            r = 1 << (n_g - 1).bit_length()  # pow2-padded group batch
            with obs.span("plan.persist", rung=f"n{sc.n_pad}", graphs=n_g):
                idx_p = np.concatenate(
                    [idx, np.full(r - n_g, idx[0], idx.dtype)])
                jidx = jnp.asarray(idx_p)
                sub = slice_to(jax.tree.map(lambda x: x[jidx], gc), sc.n_pad)
                d = self.persist_plan(sc).execute(sub)
                d = _pad_diagram_rows(d, s_full)
                jdst = jnp.asarray(idx)
                out = jax.tree.map(
                    lambda o, n_: o.at[jdst].set(n_[:n_g]), out, d)
        report = RepackReport(ladder=ladder, class_index=cls_idx,
                              n_vertices=nv, n_edges=ne, n_triangles=nt)
        return out, report


def _invalid_diagrams(b: int, s: int) -> Diagrams:
    """An all-invalid Diagrams tensor matching pairs_to_diagrams sentinels."""
    return Diagrams(
        birth=jnp.full((b, s), jnp.nan, jnp.float32),
        death=jnp.full((b, s), jnp.nan, jnp.float32),
        dim=jnp.full((b, s), -1, jnp.int32),
        valid=jnp.zeros((b, s), bool),
    )


def _pad_diagram_rows(d: Diagrams, s: int) -> Diagrams:
    """Pad a (B, S_r) Diagrams to (B, s) with invalid sentinel rows."""
    pad = s - d.birth.shape[-1]
    if pad <= 0:
        return d
    cfg = ((0, 0), (0, pad))
    return Diagrams(
        birth=jnp.pad(d.birth, cfg, constant_values=jnp.nan),
        death=jnp.pad(d.death, cfg, constant_values=jnp.nan),
        dim=jnp.pad(d.dim, cfg, constant_values=-1),
        valid=jnp.pad(d.valid, cfg, constant_values=False),
    )


def _pipeline(g: GraphBatch, key: TopoPlanKey) -> Diagrams:
    """The one reduce->persist body every single-phase execution compiles."""
    gr = run_reduction(g, key.passes, key.dim, key.sublevel, key.fixpoint)
    return persistence_diagrams_batched(
        gr, max_dim=key.dim, edge_cap=key.edge_cap, tri_cap=key.tri_cap,
        quad_cap=key.quad_cap, sublevel=key.sublevel, reducer=key.reducer,
    )


def _build_executor(key: TopoPlanKey) -> Callable[[GraphBatch], Diagrams]:
    if key.mesh is None:
        return jax.jit(partial(_pipeline, key=key))

    # shard_map pins the whole pipeline per-device (zero collectives — under
    # plain pjit GSPMD cannot partition the vmapped scatter/gather/top-k ops
    # and inserts 0.6-3 GB/device batch all-gathers on a 256-chip mesh,
    # §Perf iteration 5).  The global batch must divide the mesh size; the
    # serve layer pads bucket batches to guarantee this.
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = key.mesh
    spec = P(tuple(mesh.axis_names))

    def per_device(adj, mask, f):
        return _pipeline(GraphBatch(adj=adj, mask=mask, f=f), key)

    sharded = shard_map(
        per_device, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=Diagrams(birth=spec, death=spec, dim=spec, valid=spec),
        check_rep=False,
    )

    def executor(g: GraphBatch) -> Diagrams:
        return sharded(g.adj, g.mask, g.f)

    return executor


def _build_reduce_executor(key: TopoPlanKey) -> Callable:
    """Phase 1 of a two-phase plan: reduce + compact + measure.

    Honors ``key.fixpoint`` like the single-phase body: the default for
    ``repack="on"`` is fixpoint iteration, but ``fixpoint=False`` keeps the
    one-sweep reduction (useful for benchmarking sweep vs fixpoint through
    the identical two-phase machinery).
    """
    count_tris = key.dim >= 1 and key.tri_cap > 0

    def reduce_phase(g: GraphBatch):
        gr = run_reduction(g, key.passes, key.dim, key.sublevel, key.fixpoint)
        gc, _ = compact_batch(gr)
        return gc, measure_counts(gc, count_triangles=count_tris)

    return jax.jit(reduce_phase)


_PLAN_CACHE: "OrderedDict[TopoPlanKey, TopoPlan]" = OrderedDict()
_PLAN_CACHE_MAXSIZE = 64
_PLAN_CACHE_LOCK = threading.Lock()
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}

# TopoScope mirrors of the cache counters (always on; reset by
# clear_plan_cache alongside _PLAN_CACHE_STATS so the two never drift)
_OBS_PC_EVENTS = obs.counter(
    "plancache.events", help="TopoPlan cache hits/misses/evictions")
_OBS_PC_BUILD = obs.histogram(
    "plancache.build_seconds", help="TopoPlan executor build time (host-side "
    "trace/compile setup on a cache miss)")


def make_topo_plan(
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
    mesh=None,
    passes: Optional[tuple] = None,
    repack: str = "off",
    fixpoint: Optional[bool] = None,
    ladder: Optional[tuple] = None,
) -> TopoPlan:
    """Plan step of the plan->execute split: build or fetch a compiled pipeline.

    Returns the process-wide ``TopoPlan`` for this key (LRU-cached, thread
    safe).  Callers that execute many batches — TopoServe buckets, training
    epochs, benchmark sweeps — should hold the plan and call it directly.

    ``passes`` (a tuple of registry names, see repro/core/reduction.py)
    overrides the legacy ``method`` string.  ``repack="on"`` selects
    two-phase execution (reduce → repack → persist at reduced shape
    classes); ``fixpoint`` defaults to True exactly then, so the reduce
    phase extracts everything the theorems allow before sizing the persist
    phase.  ``ladder`` pins the persist shape classes (e.g. a serve bucket
    ladder); ``None`` derives the default pow2 ladder from the input shape.
    """
    if passes is None:
        if method not in REDUCTIONS:
            raise ValueError(
                f"unknown reduction {method!r}; want one of {REDUCTIONS}")
        passes = passes_for_method(method)
    else:
        passes = validate_passes(passes)
    if repack not in REPACK_MODES:
        raise ValueError(f"repack must be one of {REPACK_MODES}, got {repack!r}")
    if repack == "on" and mesh is not None:
        raise ValueError(
            "repack='on' is host-driven at the phase boundary and is not "
            "supported under a mesh; shard the single-phase plan instead "
            "(repack='off') or drive per-host two-phase plans")
    if fixpoint is None:
        fixpoint = repack == "on"
    key = TopoPlanKey(dim=dim, passes=passes, sublevel=bool(sublevel),
                      edge_cap=int(edge_cap), tri_cap=int(tri_cap),
                      quad_cap=int(quad_cap), reducer=reducer, mesh=mesh,
                      repack=repack, fixpoint=bool(fixpoint),
                      ladder=None if ladder is None else tuple(ladder))
    with _PLAN_CACHE_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _PLAN_CACHE.move_to_end(key)
            _PLAN_CACHE_STATS["hits"] += 1
            _OBS_PC_EVENTS.inc(event="hit")
            return plan
        _PLAN_CACHE_STATS["misses"] += 1
        _OBS_PC_EVENTS.inc(event="miss")
        t0 = time.perf_counter()
        with obs.span("plan.build", repack=repack):
            if repack == "on":
                plan = TopoPlan(key=key,
                                reduce_executor=_build_reduce_executor(key))
            else:
                plan = TopoPlan(key=key, executor=_build_executor(key))
        _OBS_PC_BUILD.observe(time.perf_counter() - t0)
        _PLAN_CACHE[key] = plan
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAXSIZE:
            _PLAN_CACHE.popitem(last=False)
            _PLAN_CACHE_STATS["evictions"] += 1
            _OBS_PC_EVENTS.inc(event="eviction")
    return plan


def plan_cache_info() -> dict:
    """Snapshot of the plan cache: hits/misses/evictions/currsize/maxsize."""
    with _PLAN_CACHE_LOCK:
        return dict(_PLAN_CACHE_STATS, currsize=len(_PLAN_CACHE),
                    maxsize=_PLAN_CACHE_MAXSIZE)


def clear_plan_cache() -> None:
    """Drop every cached plan and reset the counters (tests/benchmarks)."""
    with _PLAN_CACHE_LOCK:
        _PLAN_CACHE.clear()
        for k in _PLAN_CACHE_STATS:
            _PLAN_CACHE_STATS[k] = 0
        _OBS_PC_EVENTS.clear()
        _OBS_PC_BUILD.clear()


def topological_signature(
    g: GraphBatch,
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
    repack: str = "off",
) -> Diagrams:
    """End-to-end: reduce with the paper's algorithms, then exact PDs.

    Thin wrapper over ``make_topo_plan(...).execute(g)`` — one-shot callers
    and the serve/train/bench layers all share the same compiled pipelines.

    The returned Diagrams cover dimensions 0..dim.  (Coral reduction is only
    exact for dimensions >= dim's core level, so when ``method`` includes
    coral, read out only dimension ``dim`` — or use method="prunit" for all
    dims at once.)  ``repack="on"`` selects the two-phase path; the valid
    persistence pairs are identical, row positions are not (compare
    canonically, e.g. via ``diagrams_to_numpy``).
    """
    plan = make_topo_plan(dim=dim, method=method, sublevel=sublevel,
                          edge_cap=edge_cap, tri_cap=tri_cap,
                          quad_cap=quad_cap, reducer=reducer, repack=repack)
    return plan.execute(g)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ReductionStats:
    """Per-graph reduction accounting (the paper's evaluation metric)."""

    v_before: jax.Array
    v_after: jax.Array
    e_before: jax.Array
    e_after: jax.Array

    def v_reduction_pct(self) -> jax.Array:
        v0 = jnp.maximum(self.v_before, 1)
        return 100.0 * (self.v_before - self.v_after) / v0

    def e_reduction_pct(self) -> jax.Array:
        e0 = jnp.maximum(self.e_before, 1)
        return 100.0 * (self.e_before - self.e_after) / e0


def topological_signature_sharded(
    g: GraphBatch,
    mesh,
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
) -> Diagrams:
    """``topological_signature`` under shard_map over every mesh axis.

    Thin wrapper over ``make_topo_plan(..., mesh=mesh)``; see _build_executor
    for why shard_map beats plain pjit here.  The global batch must divide
    the mesh size.
    """
    plan = make_topo_plan(dim=dim, method=method, sublevel=sublevel,
                          edge_cap=edge_cap, tri_cap=tri_cap,
                          quad_cap=quad_cap, reducer=reducer, mesh=mesh)
    return plan.execute(g)


@partial(jax.jit, static_argnames=("dim", "method", "sublevel"))
def reduction_stats(g: GraphBatch, dim: int, method: str = "both",
                    sublevel: bool = True) -> ReductionStats:
    gr = reduce_graphs(g, dim, method, sublevel)
    return ReductionStats(
        v_before=g.n_vertices(), v_after=gr.n_vertices(),
        e_before=g.n_edges(), e_after=gr.n_edges(),
    )
