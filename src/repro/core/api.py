"""High-level TopoPipe API: reduce -> filter -> persist, batched & shardable.

This is the paper's contribution packaged as a composable JAX module: feed a
GraphBatch, choose a reduction (coral / prunit / both / none), get exact
persistence diagrams.  All functions are jit/vmap/pjit friendly; the launch
layer shards batches over the ("pod", "data") mesh axes.

Compilation is organised as an explicit **plan -> execute** split (see
docs/ARCHITECTURE.md §Plan/Execute): ``make_topo_plan(...)`` returns a
``TopoPlan`` — one compiled pipeline per distinct
``(dim, method, sublevel, caps, reducer, mesh)`` key, held in a process-wide
LRU cache — and ``topological_signature`` is a thin wrapper over it.  The
serve layer (repro/serve/topo_serve.py), the feature pipeline
(repro/topo/features.py) and the benchmarks all go through this one path, so
a given pipeline shape is compiled exactly once per process.
"""
from __future__ import annotations

import dataclasses
import threading
from collections import OrderedDict
from functools import partial
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.core.graph import GraphBatch
from repro.core.kcore import coral_reduce, kcore
from repro.core.persistence_jax import Diagrams, persistence_diagrams_batched
from repro.core.prunit import prunit


REDUCTIONS = ("none", "coral", "prunit", "both")


def reduce_graphs(g: GraphBatch, dim: int, method: str = "both",
                  sublevel: bool = True) -> GraphBatch:
    """Apply the paper's reduction(s) for computing PD_dim."""
    if method not in REDUCTIONS:
        raise ValueError(f"unknown reduction {method!r}; want one of {REDUCTIONS}")
    if method in ("prunit", "both"):
        g = prunit(g, sublevel=sublevel)
    if method in ("coral", "both"):
        g = coral_reduce(g, dim)
    return g


@dataclasses.dataclass(frozen=True)
class TopoPlanKey:
    """Hashable identity of one compiled TDA pipeline (the plan-cache key).

    Two calls that agree on every field share one ``TopoPlan`` and therefore
    one jit cache; anything not in this key (batch size, padded order) is a
    jit shape specialization *inside* the plan, not a new plan.
    """

    dim: int
    method: str
    sublevel: bool
    edge_cap: int
    tri_cap: int
    quad_cap: int
    reducer: str
    mesh: Any = None  # jax.sharding.Mesh (hashable) or None for single-host

    def caps(self) -> tuple[int, int, int]:
        return (self.edge_cap, self.tri_cap, self.quad_cap)


@dataclasses.dataclass(frozen=True)
class TopoPlan:
    """A compiled reduce->persist pipeline plus its static metadata.

    ``execute`` (alias ``__call__``) maps a GraphBatch to Diagrams through a
    single jitted (or shard_mapped, when the plan carries a mesh) program.
    The plan object is safe to hold across requests — re-executing with the
    same (B, N) shape never recompiles.
    """

    key: TopoPlanKey
    executor: Callable[[GraphBatch], Diagrams]

    def execute(self, g: GraphBatch) -> Diagrams:
        return self.executor(g)

    def __call__(self, g: GraphBatch) -> Diagrams:
        return self.executor(g)

    @property
    def dim(self) -> int:
        return self.key.dim

    @property
    def method(self) -> str:
        return self.key.method

    @property
    def sublevel(self) -> bool:
        return self.key.sublevel


def _pipeline(g: GraphBatch, key: TopoPlanKey) -> Diagrams:
    """The one reduce->persist body every execution path compiles."""
    gr = reduce_graphs(g, key.dim, key.method, key.sublevel)
    return persistence_diagrams_batched(
        gr, max_dim=key.dim, edge_cap=key.edge_cap, tri_cap=key.tri_cap,
        quad_cap=key.quad_cap, sublevel=key.sublevel, reducer=key.reducer,
    )


def _build_executor(key: TopoPlanKey) -> Callable[[GraphBatch], Diagrams]:
    if key.mesh is None:
        return jax.jit(partial(_pipeline, key=key))

    # shard_map pins the whole pipeline per-device (zero collectives — under
    # plain pjit GSPMD cannot partition the vmapped scatter/gather/top-k ops
    # and inserts 0.6-3 GB/device batch all-gathers on a 256-chip mesh,
    # §Perf iteration 5).  The global batch must divide the mesh size; the
    # serve layer pads bucket batches to guarantee this.
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    mesh = key.mesh
    spec = P(tuple(mesh.axis_names))

    def per_device(adj, mask, f):
        return _pipeline(GraphBatch(adj=adj, mask=mask, f=f), key)

    sharded = shard_map(
        per_device, mesh=mesh,
        in_specs=(spec, spec, spec),
        out_specs=Diagrams(birth=spec, death=spec, dim=spec, valid=spec),
        check_rep=False,
    )

    def executor(g: GraphBatch) -> Diagrams:
        return sharded(g.adj, g.mask, g.f)

    return executor


_PLAN_CACHE: "OrderedDict[TopoPlanKey, TopoPlan]" = OrderedDict()
_PLAN_CACHE_MAXSIZE = 64
_PLAN_CACHE_LOCK = threading.Lock()
_PLAN_CACHE_STATS = {"hits": 0, "misses": 0, "evictions": 0}


def make_topo_plan(
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
    mesh=None,
) -> TopoPlan:
    """Plan step of the plan->execute split: build or fetch a compiled pipeline.

    Returns the process-wide ``TopoPlan`` for this key (LRU-cached, thread
    safe).  Callers that execute many batches — TopoServe buckets, training
    epochs, benchmark sweeps — should hold the plan and call it directly.
    """
    if method not in REDUCTIONS:
        raise ValueError(f"unknown reduction {method!r}; want one of {REDUCTIONS}")
    key = TopoPlanKey(dim=dim, method=method, sublevel=bool(sublevel),
                      edge_cap=int(edge_cap), tri_cap=int(tri_cap),
                      quad_cap=int(quad_cap), reducer=reducer, mesh=mesh)
    with _PLAN_CACHE_LOCK:
        plan = _PLAN_CACHE.get(key)
        if plan is not None:
            _PLAN_CACHE.move_to_end(key)
            _PLAN_CACHE_STATS["hits"] += 1
            return plan
        _PLAN_CACHE_STATS["misses"] += 1
        plan = TopoPlan(key=key, executor=_build_executor(key))
        _PLAN_CACHE[key] = plan
        while len(_PLAN_CACHE) > _PLAN_CACHE_MAXSIZE:
            _PLAN_CACHE.popitem(last=False)
            _PLAN_CACHE_STATS["evictions"] += 1
    return plan


def plan_cache_info() -> dict:
    """Snapshot of the plan cache: hits/misses/evictions/currsize/maxsize."""
    with _PLAN_CACHE_LOCK:
        return dict(_PLAN_CACHE_STATS, currsize=len(_PLAN_CACHE),
                    maxsize=_PLAN_CACHE_MAXSIZE)


def clear_plan_cache() -> None:
    """Drop every cached plan and reset the counters (tests/benchmarks)."""
    with _PLAN_CACHE_LOCK:
        _PLAN_CACHE.clear()
        for k in _PLAN_CACHE_STATS:
            _PLAN_CACHE_STATS[k] = 0


def topological_signature(
    g: GraphBatch,
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
) -> Diagrams:
    """End-to-end: reduce with the paper's algorithms, then exact PDs.

    Thin wrapper over ``make_topo_plan(...).execute(g)`` — one-shot callers
    and the serve/train/bench layers all share the same compiled pipelines.

    The returned Diagrams cover dimensions 0..dim.  (Coral reduction is only
    exact for dimensions >= dim's core level, so when ``method`` includes
    coral, read out only dimension ``dim`` — or use method="prunit" for all
    dims at once.)
    """
    plan = make_topo_plan(dim=dim, method=method, sublevel=sublevel,
                          edge_cap=edge_cap, tri_cap=tri_cap,
                          quad_cap=quad_cap, reducer=reducer)
    return plan.execute(g)


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class ReductionStats:
    """Per-graph reduction accounting (the paper's evaluation metric)."""

    v_before: jax.Array
    v_after: jax.Array
    e_before: jax.Array
    e_after: jax.Array

    def v_reduction_pct(self) -> jax.Array:
        v0 = jnp.maximum(self.v_before, 1)
        return 100.0 * (self.v_before - self.v_after) / v0

    def e_reduction_pct(self) -> jax.Array:
        e0 = jnp.maximum(self.e_before, 1)
        return 100.0 * (self.e_before - self.e_after) / e0


def topological_signature_sharded(
    g: GraphBatch,
    mesh,
    dim: int = 1,
    method: str = "both",
    sublevel: bool = True,
    edge_cap: int = 256,
    tri_cap: int = 512,
    quad_cap: int = 0,
    reducer: str = "jnp",
) -> Diagrams:
    """``topological_signature`` under shard_map over every mesh axis.

    Thin wrapper over ``make_topo_plan(..., mesh=mesh)``; see _build_executor
    for why shard_map beats plain pjit here.  The global batch must divide
    the mesh size.
    """
    plan = make_topo_plan(dim=dim, method=method, sublevel=sublevel,
                          edge_cap=edge_cap, tri_cap=tri_cap,
                          quad_cap=quad_cap, reducer=reducer, mesh=mesh)
    return plan.execute(g)


@partial(jax.jit, static_argnames=("dim", "method", "sublevel"))
def reduction_stats(g: GraphBatch, dim: int, method: str = "both",
                    sublevel: bool = True) -> ReductionStats:
    gr = reduce_graphs(g, dim, method, sublevel)
    return ReductionStats(
        v_before=g.n_vertices(), v_after=gr.n_vertices(),
        e_before=g.n_edges(), e_after=gr.n_edges(),
    )
