"""Exact persistent-homology oracle (NumPy/pure Python).

Standard boundary-matrix column reduction over GF(2) on the sublevel clique
(flag) filtration of a vertex-filtered graph.  This is the ground truth used
to validate the paper's theorems (CoralTDA / PrunIT exactness), the JAX
bit-packed implementation, and the Pallas kernels.

Conventions
-----------
* Filtering function f on vertices; a simplex enters at max f over vertices.
* Simplices ordered by (value, dim, lexicographic vertex tuple) — a valid
  filtration order (faces precede cofaces: a face has <= value and < dim).
* Diagrams are multisets of (birth, death) with death = +inf for essential
  classes; zero-persistence pairs (birth == death) are dropped, matching the
  usual convention (they are invisible in any diagram distance).
"""
from __future__ import annotations

import itertools
from collections import defaultdict

import numpy as np


def enumerate_cliques(adj: np.ndarray, mask: np.ndarray, max_size: int):
    """All cliques of size 1..max_size as sorted vertex tuples.

    Simple pivot-free Bron–Kerbosch-style expansion; fine for the small-N
    batched regime the oracle serves.
    """
    n = adj.shape[0]
    verts = [int(v) for v in range(n) if mask[v]]
    nbrs = {v: set(int(w) for w in np.nonzero(adj[v])[0] if mask[w]) for v in verts}
    out = [(v,) for v in verts]
    frontier = [(v,) for v in verts]
    for size in range(2, max_size + 1):
        nxt = []
        for c in frontier:
            last = c[-1]
            # extend with a common neighbor greater than last (canonical order)
            cand = set(w for w in nbrs[last] if w > last)
            for v in c[:-1]:
                cand &= nbrs[v]
            for w in sorted(cand):
                nxt.append(c + (w,))
        out.extend(nxt)
        frontier = nxt
        if not frontier:
            break
    return out


def sublevel_order(cliques, f, sublevel: bool = True):
    """Sort simplices into filtration order; returns (simplices, values)."""
    if sublevel:
        val = lambda c: max(float(f[v]) for v in c)
    else:
        val = lambda c: -min(float(f[v]) for v in c)
    order = sorted(cliques, key=lambda c: (val(c), len(c), c))
    values = [val(c) for c in order]
    return order, values


def reduce_boundary(simplices):
    """GF(2) column reduction.  Returns (pairs, essential) as simplex indices.

    pairs: list of (birth_idx, death_idx); essential: list of birth_idx.
    """
    index = {s: i for i, s in enumerate(simplices)}
    cols = []
    for s in simplices:
        if len(s) == 1:
            cols.append(frozenset())
            continue
        faces = [s[:j] + s[j + 1 :] for j in range(len(s))]
        cols.append(frozenset(index[fc] for fc in faces))
    cols = [set(c) for c in cols]
    pivot_of = {}
    pairs = []
    positive = set()
    for j in range(len(cols)):
        col = cols[j]
        while col:
            low = max(col)
            p = pivot_of.get(low)
            if p is None:
                pivot_of[low] = j
                pairs.append((low, j))
                break
            col ^= cols[p]
        else:
            positive.add(j)
    paired_births = {b for b, _ in pairs}
    essential = [j for j in positive if j not in paired_births]
    return pairs, essential


def persistence_diagrams(
    adj: np.ndarray,
    f: np.ndarray,
    mask: np.ndarray | None = None,
    max_dim: int = 1,
    sublevel: bool = True,
    keep_zero: bool = False,
):
    """Exact PD_0..PD_max_dim of the sublevel clique filtration.

    Returns dict: dim -> sorted list of (birth, death) (death may be inf).
    Needs cliques up to size max_dim + 2 (deaths of max_dim classes).
    """
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    if mask is None:
        mask = np.ones(n, dtype=bool)
    mask = np.asarray(mask, dtype=bool)
    f = np.asarray(f, dtype=np.float64)

    cliques = enumerate_cliques(adj, mask, max_dim + 2)
    simplices, values = sublevel_order(cliques, f, sublevel)
    pairs, essential = reduce_boundary(simplices)

    sign = 1.0 if sublevel else -1.0
    dgms = defaultdict(list)
    for b, d in pairs:
        dim = len(simplices[b]) - 1
        if dim > max_dim:
            continue
        birth, death = sign * values[b], sign * values[d]
        if keep_zero or birth != death:
            dgms[dim].append((birth, death))
    for b in essential:
        dim = len(simplices[b]) - 1
        if dim > max_dim:
            continue
        dgms[dim].append((sign * values[b], np.inf))
    return {k: sorted(v) for k, v in sorted(dgms.items())}


def diagrams_equal(d1, d2, max_dim: int | None = None, atol: float = 1e-9) -> bool:
    """Multiset equality of persistence diagrams up to max_dim."""
    dims = set(d1) | set(d2)
    if max_dim is not None:
        dims = {k for k in dims if k <= max_dim}
    for k in dims:
        a = sorted(d1.get(k, []))
        b = sorted(d2.get(k, []))
        if len(a) != len(b):
            return False
        for (b1, e1), (b2, e2) in zip(a, b):
            if abs(b1 - b2) > atol:
                return False
            if np.isinf(e1) != np.isinf(e2):
                return False
            if not np.isinf(e1) and abs(e1 - e2) > atol:
                return False
    return True


def betti_numbers(adj, f=None, mask=None, max_dim: int = 1):
    """Betti numbers of the full clique complex (count of essential classes)."""
    n = np.asarray(adj).shape[0]
    if f is None:
        f = np.zeros(n)
    dg = persistence_diagrams(adj, f, mask, max_dim=max_dim, keep_zero=False)
    return {
        k: sum(1 for (_, d) in dg.get(k, []) if np.isinf(d)) for k in range(max_dim + 1)
    }


def power_filtration_diagrams(adj, mask=None, max_dim: int = 1, keep_zero: bool = False):
    """PDs of the power filtration (paper Thm 10 setting).

    The power filtration G^1 ⊂ G^2 ⊂ … is the Vietoris–Rips filtration of the
    hop metric: a simplex enters at the max pairwise graph distance of its
    vertices (vertices enter at 0).  Only sensible for small connected graphs
    (the final complex is complete).
    """
    from repro.core.filtration import graph_power_distances

    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    if mask is None:
        mask = np.ones(n, dtype=bool)
    mask = np.asarray(mask, bool)
    dist = graph_power_distances(adj, mask)
    verts = [int(v) for v in range(n) if mask[v]]
    cliques = []
    for size in range(1, max_dim + 3):
        cliques.extend(itertools.combinations(verts, size))

    def val(c):
        if len(c) == 1:
            return 0.0
        return max(float(dist[u, v]) for u, v in itertools.combinations(c, 2))

    finite = [c for c in cliques if np.isfinite(val(c))]
    order = sorted(finite, key=lambda c: (val(c), len(c), c))
    values = [val(c) for c in order]
    pairs, essential = reduce_boundary(order)
    dgms = defaultdict(list)
    for b, d in pairs:
        dim = len(order[b]) - 1
        if dim > max_dim:
            continue
        if keep_zero or values[b] != values[d]:
            dgms[dim].append((values[b], values[d]))
    for b in essential:
        dim = len(order[b]) - 1
        if dim <= max_dim:
            dgms[dim].append((values[b], np.inf))
    return {k: sorted(v) for k, v in sorted(dgms.items())}


def simplex_count(adj, mask=None, max_dim: int = 2) -> int:
    """Number of simplices of dim <= max_dim in the clique complex."""
    adj = np.asarray(adj, dtype=bool)
    n = adj.shape[0]
    if mask is None:
        mask = np.ones(n, dtype=bool)
    return len(enumerate_cliques(adj, np.asarray(mask, bool), max_dim + 1))
