r"""ReductionEngine: composable reduction passes iterated to a fixpoint.

The paper contributes two *lossless* graph reductions (CoralTDA's (k+1)-core,
Theorem 2; PrunIT's dominated-vertex removal, Theorem 7).  Both are
*closure operators on the vertex mask* — monotone (they only remove
vertices), idempotent at their own fixed point, and exactness-preserving for
a declared range of homology dimensions.  That makes them **composable**:
any sequence of exact passes is exact, and iterating a pass list until the
mask stops changing (the joint fixpoint) is still exact while removing
strictly more than any single sweep — PrunIT can expose new sub-degree
vertices to the core peel, and the peel can expose new dominated vertices to
PrunIT (Choi et al. 2023 iterate exactly this way; the paper's own
experiments iterate PrunIT rounds).

This module is the one registry of such passes plus the scheduler that
iterates them.  ``repro.core.api`` builds every compiled pipeline on top of
it: single-phase plans apply one sweep (``apply_passes``, bit-compatible
with the historical ``reduce_graphs``), two-phase ``repack="on"`` plans run
``reduce_fixpoint`` as their reduce phase so the boundary-matrix stage
compiles at the *reduced* graph's shape class (see repro/core/repack.py).

Exactness contract
------------------
Each pass declares ``exact_from_dim(target_dim)`` — the lowest homology
dimension it provably preserves when the pipeline targets ``PD_target_dim``:

* ``prunit``          → 0   (Theorem 7: every ``PD_k`` preserved)
* ``strong_collapse`` → 0   (equal-``f`` domination collapse: the
  orientation-free special case of Theorem 7 — ``f(u) == f(v)`` satisfies
  the filtration condition for sublevel *and* superlevel, so the same
  reduced graph serves both orientations.  This is the *graph-level*,
  filtration-compatible restriction of Boissonnat–Pritam strong collapse;
  the per-threshold baseline of paper Remark 13 lives in
  repro/core/strong_collapse.py and is not a registered pass because
  collapsing without the f condition does not preserve diagrams.)
* ``kcore``           → target_dim   (Theorem 2: ``PD_j`` preserved only for
  ``j >= target_dim``; dimensions below go stale)

A pipeline's contract is the *maximum* over its passes
(``engine_exact_from_dim``) — the most restrictive pass wins.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import GraphBatch
from repro.core.kcore import kcore_mask
from repro.core.prunit import prunit_mask


@dataclasses.dataclass(frozen=True)
class ReductionPass:
    """One composable reduction pass.

    apply_mask(adj, mask, f, dim, sublevel) -> new mask.  The scheduler
    guarantees ``adj``/``f`` are already restricted to ``mask`` (re-masked
    between passes), and requires the pass to be **mask-monotone**
    (``new ⊆ mask``) so the fixpoint iteration terminates.

    exact_from_dim(target_dim) -> the lowest homology dimension this pass
    preserves when the pipeline computes ``PD_target_dim`` (see module
    docstring).
    """

    name: str
    apply_mask: Callable[..., jax.Array]
    exact_from_dim: Callable[[int], int]
    description: str = ""


def _kcore_apply(adj, mask, f, dim, sublevel):
    # dim is static at trace time; for dim 0 the 1-core would drop isolated
    # vertices that DO carry PD_0 classes, so the pass is the identity there
    if dim < 1:
        return mask
    return kcore_mask(adj, mask, dim + 1)


def _prunit_apply(adj, mask, f, dim, sublevel):
    return prunit_mask(adj, mask, f, sublevel)


def _strong_collapse_apply(adj, mask, f, dim, sublevel):
    return prunit_mask(adj, mask, f, sublevel, equal_only=True)


PASS_REGISTRY: dict[str, ReductionPass] = {}


def register_pass(p: ReductionPass, overwrite: bool = False) -> ReductionPass:
    """Register a reduction pass under ``p.name`` (extension point)."""
    if not overwrite and p.name in PASS_REGISTRY:
        raise ValueError(f"reduction pass {p.name!r} already registered")
    PASS_REGISTRY[p.name] = p
    return p


def get_pass(name: str) -> ReductionPass:
    try:
        return PASS_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown reduction pass {name!r}; registered: "
            f"{sorted(PASS_REGISTRY)}") from None


register_pass(ReductionPass(
    name="kcore",
    apply_mask=_kcore_apply,
    exact_from_dim=lambda d: d if d >= 1 else 0,
    description="CoralTDA (dim+1)-core (Thm 2; exact for PD_j, j >= dim)",
))
register_pass(ReductionPass(
    name="prunit",
    apply_mask=_prunit_apply,
    exact_from_dim=lambda d: 0,
    description="PrunIT dominated-vertex pruning (Thm 7; exact for all PD_k)",
))
register_pass(ReductionPass(
    name="strong_collapse",
    apply_mask=_strong_collapse_apply,
    exact_from_dim=lambda d: 0,
    description="equal-f domination collapse (orientation-free Thm 7 case)",
))


# method string -> pass tuple; the historical REDUCTIONS surface of api.py
METHOD_PASSES: dict[str, tuple[str, ...]] = {
    "none": (),
    "coral": ("kcore",),
    "prunit": ("prunit",),
    "both": ("prunit", "kcore"),
}


def passes_for_method(method: str) -> tuple[str, ...]:
    """Map a legacy reduction method name to its pass tuple."""
    try:
        return METHOD_PASSES[method]
    except KeyError:
        raise ValueError(
            f"unknown reduction {method!r}; want one of "
            f"{tuple(METHOD_PASSES)}") from None


def method_for_passes(passes: tuple[str, ...]) -> str:
    """Inverse of ``passes_for_method`` where one exists, else '+'.join."""
    for m, p in METHOD_PASSES.items():
        if p == tuple(passes):
            return m
    return "+".join(passes)


def validate_passes(passes) -> tuple[str, ...]:
    passes = tuple(passes)
    for name in passes:
        get_pass(name)  # raises on unknown
    return passes


def engine_exact_from_dim(passes: tuple[str, ...], dim: int) -> int:
    """Lowest homology dimension the whole pass pipeline preserves."""
    return max((get_pass(p).exact_from_dim(dim) for p in passes), default=0)


def _sweep_mask(adj, mask, f, passes, dim, sublevel):
    """One in-order application of every pass, re-masking between passes."""
    for name in passes:
        p = get_pass(name)
        adj_m = adj & mask[..., None, :] & mask[..., :, None]
        f_m = jnp.where(mask, f, jnp.inf)
        mask = p.apply_mask(adj_m, mask, f_m, dim, sublevel) & mask
    return mask


def apply_passes(g: GraphBatch, passes, dim: int,
                 sublevel: bool = True) -> GraphBatch:
    """One sweep through ``passes`` (the historical single-phase reduction).

    ``apply_passes(g, ("prunit", "kcore"), dim)`` is bit-compatible with the
    pre-engine ``reduce_graphs(g, dim, "both")`` — it is the parity oracle
    for everything the fixpoint scheduler and the repack path produce.
    """
    passes = validate_passes(passes)
    if not passes:
        return g
    return g.with_mask(_sweep_mask(g.adj, g.mask, g.f, passes, dim, sublevel))


def reduce_fixpoint(g: GraphBatch, passes, dim: int, sublevel: bool = True,
                    max_sweeps: int | None = None) -> GraphBatch:
    """Iterate the pass list to its joint fixpoint (mask unchanged).

    Termination: every registered pass is mask-monotone, so the live-vertex
    count strictly decreases on every sweep that changes anything — at most
    N sweeps.  Exactness: each sweep is a composition of exact reductions
    applied to the previous sweep's (exact) output, so by induction the
    fixpoint preserves ``PD_j`` for every ``j >= engine_exact_from_dim``.
    """
    passes = validate_passes(passes)
    if not passes:
        return g

    def cond(state):
        _, changed, i = state
        ok = changed
        if max_sweeps is not None:
            ok = ok & (i < max_sweeps)
        return ok

    def body(state):
        m, _, i = state
        new = _sweep_mask(g.adj, m, g.f, passes, dim, sublevel)
        return new, jnp.any(new != m), i + 1

    m, _, _ = lax.while_loop(
        cond, body, (g.mask, jnp.array(True), jnp.array(0)))
    return g.with_mask(m)


def run_reduction(g: GraphBatch, passes, dim: int, sublevel: bool,
                  fixpoint: bool, max_sweeps: int | None = None) -> GraphBatch:
    """The one sweep-vs-fixpoint dispatch every execution path shares
    (single-phase plan bodies, two-phase reduce executors, the engine)."""
    if fixpoint:
        return reduce_fixpoint(g, passes, dim, sublevel, max_sweeps)
    return apply_passes(g, passes, dim, sublevel)


class ReductionEngine:
    """Convenience wrapper: a configured pass pipeline as a callable.

    >>> engine = ReductionEngine(("prunit", "kcore"), dim=1)
    >>> g_red = engine(g)                 # fixpoint-reduced batch
    >>> engine.exact_from_dim()           # 1: PD_j exact for j >= 1
    """

    def __init__(self, passes=("prunit", "kcore"), dim: int = 1,
                 sublevel: bool = True, fixpoint: bool = True,
                 max_sweeps: int | None = None):
        self.passes = validate_passes(passes)
        self.dim = int(dim)
        self.sublevel = bool(sublevel)
        self.fixpoint = bool(fixpoint)
        self.max_sweeps = max_sweeps

    def __call__(self, g: GraphBatch) -> GraphBatch:
        return run_reduction(g, self.passes, self.dim, self.sublevel,
                             self.fixpoint, self.max_sweeps)

    def exact_from_dim(self) -> int:
        return engine_exact_from_dim(self.passes, self.dim)

    def __repr__(self) -> str:
        mode = "fixpoint" if self.fixpoint else "sweep"
        return (f"ReductionEngine({'|'.join(self.passes) or 'identity'}, "
                f"dim={self.dim}, {mode})")
