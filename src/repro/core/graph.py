"""Batched, padded graph representation used throughout the TDA core.

All TDA algorithms in this framework operate on dense adjacency matrices with
an explicit node mask.  This is deliberate (see DESIGN.md §3): on TPU the
paper's pointer-chasing graph algorithms are re-derived as masked linear
algebra, and a dense (B, N, N) layout feeds the MXU directly.  Real-world
inputs (ego networks, TU-style graph datasets) are small-N / huge-B, which is
exactly the regime where padding overhead is bounded and batching wins.

The padded-batch invariants (mask sentinels, +inf filtration padding, cap
semantics) every layer relies on are spelled out in docs/ARCHITECTURE.md
§GraphBatch invariants.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class GraphBatch:
    """A batch of padded undirected graphs.

    adj:  (B, N, N) bool — symmetric, zero diagonal, zero outside mask.
    mask: (B, N)    bool — True for real vertices.
    f:    (B, N)    float32 — vertex filtering function values (padding = +inf
          so padded vertices never enter a sublevel filtration).
    """

    adj: jax.Array
    mask: jax.Array
    f: jax.Array

    @property
    def batch(self) -> int:
        return self.adj.shape[0]

    @property
    def n(self) -> int:
        return self.adj.shape[1]

    def degrees(self) -> jax.Array:
        """(B, N) int32 degree of each live vertex (0 for padding)."""
        a = self.adj & self.mask[:, None, :] & self.mask[:, :, None]
        return jnp.sum(a, axis=-1).astype(jnp.int32)

    def n_vertices(self) -> jax.Array:
        return jnp.sum(self.mask, axis=-1).astype(jnp.int32)

    def n_edges(self) -> jax.Array:
        a = self.adj & self.mask[:, None, :] & self.mask[:, :, None]
        return (jnp.sum(a, axis=(-1, -2)) // 2).astype(jnp.int32)

    def with_mask(self, new_mask: jax.Array) -> "GraphBatch":
        """Restrict the batch to ``new_mask`` (an induced-subgraph view).

        The adjacency matrix is re-masked; filtering values are kept for the
        surviving vertices (paper Remark 1: f is *not* recomputed on the
        reduced graph).
        """
        new_mask = new_mask & self.mask
        adj = self.adj & new_mask[:, None, :] & new_mask[:, :, None]
        f = jnp.where(new_mask, self.f, jnp.inf)
        return GraphBatch(adj=adj, mask=new_mask, f=f)


def canonicalize(adj: jax.Array, mask: jax.Array, f: jax.Array) -> GraphBatch:
    """Symmetrize, clear the diagonal, zero out padding, inf-pad f."""
    adj = adj.astype(bool)
    mask = mask.astype(bool)
    adj = adj | jnp.swapaxes(adj, -1, -2)
    n = adj.shape[-1]
    eye = jnp.eye(n, dtype=bool)
    adj = adj & ~eye
    adj = adj & mask[..., None, :] & mask[..., :, None]
    f = jnp.where(mask, f.astype(jnp.float32), jnp.inf)
    return GraphBatch(adj=adj, mask=mask, f=f)


def from_edge_lists(
    edge_lists: Sequence[Sequence[tuple[int, int]]],
    n_vertices: Sequence[int],
    n_pad: int | None = None,
    f_values: Sequence[Sequence[float]] | None = None,
) -> GraphBatch:
    """Build a GraphBatch from python edge lists (host-side helper)."""
    b = len(edge_lists)
    n = n_pad or max(int(v) for v in n_vertices)
    adj = np.zeros((b, n, n), dtype=bool)
    mask = np.zeros((b, n), dtype=bool)
    f = np.full((b, n), np.inf, dtype=np.float32)
    for i, (edges, nv) in enumerate(zip(edge_lists, n_vertices)):
        mask[i, :nv] = True
        for (u, v) in edges:
            if u != v:
                adj[i, u, v] = adj[i, v, u] = True
        if f_values is not None:
            f[i, :nv] = np.asarray(f_values[i], dtype=np.float32)[:nv]
    if f_values is None:
        # Default filtering function: vertex degree (the paper's choice).
        deg = adj.sum(-1).astype(np.float32)
        f = np.where(mask, deg, np.inf)
    return GraphBatch(adj=jnp.asarray(adj), mask=jnp.asarray(mask), f=jnp.asarray(f))


def from_networkx(graphs, n_pad: int | None = None, f_attr: str | None = None) -> GraphBatch:
    """Build a GraphBatch from a list of networkx graphs.

    Vertices are relabelled 0..n-1 in sorted order.  ``f_attr`` selects a node
    attribute as the filtering function; default is the degree function.
    """
    edge_lists, nvs, fvals = [], [], []
    for g in graphs:
        nodes = sorted(g.nodes())
        idx = {u: i for i, u in enumerate(nodes)}
        edge_lists.append([(idx[u], idx[v]) for (u, v) in g.edges()])
        nvs.append(len(nodes))
        if f_attr is not None:
            fvals.append([float(g.nodes[u][f_attr]) for u in nodes])
    return from_edge_lists(
        edge_lists, nvs, n_pad=n_pad, f_values=fvals if f_attr else None
    )


def degree_filtration(g: GraphBatch) -> GraphBatch:
    """Replace f with the degree function computed on the *current* graph."""
    deg = g.degrees().astype(jnp.float32)
    return GraphBatch(adj=g.adj, mask=g.mask, f=jnp.where(g.mask, deg, jnp.inf))
