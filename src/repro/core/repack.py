"""Repack step of the two-phase plan: compact, measure, re-bucket.

The reductions shrink graphs by up to ~95% (paper Figs 4-6), but a fused
reduce→persist pipeline compiles the boundary-matrix stage at the *input*
graph's padded caps — the expensive stage never sees the smaller graph.  The
repack step sits between an explicit reduce phase and persist phase
(repro/core/api.py, ``repack="on"``):

1. ``compact_batch`` — permute every graph's surviving vertices to the front
   of the padded axis (a jitted gather via the rank-by-mask permutation), so
   a reduced graph occupies a contiguous ``n' x n'`` prefix;
2. ``measure_counts`` — per-graph vertex / edge / triangle counts of the
   reduced graphs (cheap masked linear algebra, one batched einsum);
3. ``select_classes`` — first-fit each graph into the smallest
   :class:`ShapeClass` of a bounded ladder whose caps hold its counts, so
   ``pack_boundary``/``reduce_packed`` (and the Pallas ``gf2_reduce`` path,
   which is fully caps-polymorphic — it reads its (S, W) shape from the
   refs) compile and run at *reduced* size.

The ladder is what keeps jit signatures bounded: persist plans exist only at
ladder rungs, never at per-graph exact sizes, and the rungs are shared
process-wide through the plan cache — two serve buckets whose reduced graphs
land on the same rung execute the same compiled persist pipeline.
"""
from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphBatch


@dataclasses.dataclass(frozen=True, order=True)
class ShapeClass:
    """One persist-phase shape rung: padded order + simplex caps.

    The persist analogue of the serve layer's ``Bucket`` — a jit signature
    class.  Total order (n_pad, edge_cap, tri_cap, quad_cap) gives the
    deterministic first-fit used by ``select_classes``.
    """

    n_pad: int
    edge_cap: int
    tri_cap: int
    quad_cap: int = 0


def diagram_size(n: int, dim: int, edge_cap: int, tri_cap: int,
                 quad_cap: int = 0) -> int:
    """Rows of the Diagrams tensor a plan with these caps emits.

    Mirrors ``build_filtered_complex``: triangles only enter for dim >= 1,
    tetrahedra only for dim >= 2.
    """
    s = n + edge_cap
    if dim >= 1 and tri_cap:
        s += tri_cap
    if dim >= 2 and quad_cap:
        s += quad_cap
    return s


def compact_batch(g: GraphBatch) -> tuple[GraphBatch, jax.Array]:
    """Permute surviving vertices to the front of the padded axis.

    Returns ``(compacted, order)`` where ``order[b, i]`` is the original
    index of compacted vertex ``i`` (live vertices first, original order
    preserved — the stable rank-by-mask permutation).  Pure gather, jit/vmap
    friendly; row ``i < n_vertices[b]`` of the result is always live, so a
    graph whose counts fit a :class:`ShapeClass` can be *sliced* to it.

    Diagram invariance: persistence pairs are a multiset invariant of the
    filtration ``(G, f)`` — relabelling vertices permutes simplex slots but
    never the (birth, death) value multiset — so persisting the compacted
    graph yields the same pairs as the uncompacted one (the repack
    round-trip property, tests/test_reduction_engine.py).
    """
    order = jnp.argsort(~g.mask, axis=-1, stable=True).astype(jnp.int32)
    mask_c = jnp.take_along_axis(g.mask, order, axis=-1)
    f_c = jnp.where(mask_c, jnp.take_along_axis(g.f, order, axis=-1), jnp.inf)
    adj_r = jnp.take_along_axis(g.adj, order[:, :, None], axis=1)
    adj_c = jnp.take_along_axis(adj_r, order[:, None, :], axis=2)
    adj_c = adj_c & mask_c[:, None, :] & mask_c[:, :, None]
    return GraphBatch(adj=adj_c, mask=mask_c, f=f_c), order


def measure_counts(g: GraphBatch, count_triangles: bool = True
                   ) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Per-graph (n_vertices, n_edges, n_triangles) of a (reduced) batch.

    Triangle counts via trace(A^3)/6 as one batched f32 einsum (exact below
    2^24, far above any cap this system pads to).
    """
    nv = g.n_vertices()
    ne = g.n_edges()
    if count_triangles:
        a = (g.adj & g.mask[:, None, :] & g.mask[:, :, None]).astype(jnp.float32)
        nt = (jnp.einsum("bij,bjk,bki->b", a, a, a) / 6.0).astype(jnp.int32)
    else:
        nt = jnp.zeros_like(nv)
    return nv, ne, nt


def _ceil_pow2(x: int) -> int:
    return 1 << max(0, int(x) - 1).bit_length()


def default_ladder(n: int, edge_cap: int, tri_cap: int, quad_cap: int = 0,
                   min_n: int = 8) -> tuple[ShapeClass, ...]:
    """The default repack ladder for an input shape ``(n, caps)``.

    Power-of-two vertex rungs from ``min_n`` up to ``n``; each rung's caps
    are the input caps scaled by the vertex fraction (rounded up to a power
    of two) and clamped by both the input caps and the complete-graph counts
    at that order.  The top rung is exactly the input shape, so a fitting
    rung always exists (reduction only removes simplices).  ``quad_cap`` is
    carried unscaled: 4-clique counting is the one measurement that does not
    pay for itself, and caps only need to stay >= the true counts.
    """
    n = int(n)
    rungs = []
    m = min_n
    while m < n:
        rungs.append(m)
        m *= 2
    classes = []
    for m in rungs:
        frac = m / n
        e = min(edge_cap, m * (m - 1) // 2,
                _ceil_pow2(max(m, int(edge_cap * frac))))
        if tri_cap:
            t = min(tri_cap, m * (m - 1) * (m - 2) // 6,
                    _ceil_pow2(max(m, int(tri_cap * frac))))
        else:
            t = 0
        classes.append(ShapeClass(n_pad=m, edge_cap=e, tri_cap=t,
                                  quad_cap=quad_cap))
    classes.append(ShapeClass(n_pad=n, edge_cap=edge_cap, tri_cap=tri_cap,
                              quad_cap=quad_cap))
    return tuple(classes)


def select_classes(ladder: tuple[ShapeClass, ...], nv, ne, nt) -> np.ndarray:
    """First-fit rung index per graph (host-side, vectorized).

    A graph lands on the first rung holding all its measured counts —
    deterministic, like TopoServe's bucket routing.  Raises if some graph
    fits no rung (impossible for ``default_ladder``; a custom ladder must
    keep a top rung at least as large as the input shape).
    """
    nv = np.asarray(nv)
    ne = np.asarray(ne)
    nt = np.asarray(nt)
    out = np.full(nv.shape, -1, np.int64)
    for i, c in enumerate(ladder):
        # nt is 0 when triangles were not measured (dim-0 plans), so the
        # gate is inert there; when they WERE measured, a zero-tri rung
        # must reject triangle-bearing graphs like any other overflow
        fit = ((out < 0) & (nv <= c.n_pad) & (ne <= c.edge_cap)
               & (nt <= c.tri_cap))
        out[fit] = i
    if (out < 0).any():
        bad = np.nonzero(out < 0)[0].tolist()
        raise ValueError(
            f"graphs {bad} fit no repack shape class (ladder top rung "
            f"{ladder[-1]}); custom ladders must cover the input shape")
    return out


def slice_to(g: GraphBatch, n_pad: int) -> GraphBatch:
    """Slice a *compacted* batch down to its first ``n_pad`` vertex slots."""
    return GraphBatch(adj=g.adj[:, :n_pad, :n_pad],
                      mask=g.mask[:, :n_pad], f=g.f[:, :n_pad])


@dataclasses.dataclass(frozen=True)
class RepackReport:
    """Host-side account of one two-phase execution's repack decisions."""

    ladder: tuple[ShapeClass, ...]
    class_index: np.ndarray   # (B,) rung index into ladder
    n_vertices: np.ndarray    # (B,) post-reduction counts
    n_edges: np.ndarray
    n_triangles: np.ndarray

    def shape_class(self, i: int) -> ShapeClass:
        return self.ladder[int(self.class_index[i])]

    def rung_histogram(self) -> dict[int, int]:
        """{rung n_pad: graph count} over the batch."""
        out: dict[int, int] = {}
        for ci in self.class_index.tolist():
            n = self.ladder[ci].n_pad
            out[n] = out.get(n, 0) + 1
        return out
