"""CoralTDA: k-core reduction for exact higher persistence diagrams.

Paper Theorem 2: for an (unweighted) graph G with vertex filtering function f
and sublevel clique-complex filtration, ``PD_j(G, f) = PD_j(G^{k+1}, f)`` for
every ``j >= k >= 1``.  So the k-th persistence diagram only needs the
(k+1)-core.

TPU adaptation (DESIGN.md §3): instead of Batagelj–Zaversnik's sequential
bucket peeling we iterate a Jacobi fixed point

    deg  = A @ alive          (masked mat-vec, MXU)
    alive <- alive ∧ (deg >= k)

under ``lax.while_loop`` until nothing changes.  Each sweep removes *all*
currently sub-degree vertices at once; the fixed point is exactly the k-core
(the k-core is the maximal subgraph with min-degree >= k, and the sweep
operator is monotone, so the fixed point from `alive = mask` is that maximal
subgraph).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import GraphBatch


def kcore_mask(adj: jax.Array, mask: jax.Array, k: jax.Array | int) -> jax.Array:
    """Return the (B, N) bool mask of the k-core of each graph in the batch.

    adj: (B, N, N) bool; mask: (B, N) bool; k: scalar int (traced ok).
    """
    k = jnp.asarray(k, jnp.int32)
    adj_i = adj.astype(jnp.int32)

    def sweep(alive):
        deg = jnp.einsum("bij,bj->bi", adj_i, alive.astype(jnp.int32))
        return alive & (deg >= k)

    def cond(state):
        alive, changed = state
        return changed

    def body(state):
        alive, _ = state
        new = sweep(alive)
        return new, jnp.any(new != alive)

    alive0 = mask
    alive, _ = lax.while_loop(cond, body, (alive0, jnp.array(True)))
    return alive


def kcore(g: GraphBatch, k: int) -> GraphBatch:
    """The k-core of every graph in the batch (as a masked view)."""
    return g.with_mask(kcore_mask(g.adj, g.mask, k))


def coral_reduce(g: GraphBatch, dim: int) -> GraphBatch:
    """CoralTDA reduction for computing ``PD_dim``: the (dim+1)-core.

    Valid for dim >= 1 (Theorem 2).  For dim == 0 the 1-core would drop
    isolated vertices, which *do* carry PD_0 classes, so we return the graph
    unchanged.
    """
    if dim < 1:
        return g
    return kcore(g, dim + 1)


def coreness(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """(B, N) int32 core number of every vertex (0 for padding).

    Computed by running the k-core fixed point for k = 1..N and accumulating.
    O(N) sweeps worst case; used by benchmarks/analysis, not the hot path.
    """
    n = adj.shape[-1]

    def body(k, state):
        core = state
        alive = kcore_mask(adj, mask, k)
        return jnp.where(alive, k, core)

    core0 = jnp.zeros(mask.shape, jnp.int32)
    return lax.fori_loop(1, n + 1, body, core0)


def degeneracy(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """(B,) int32 degeneracy (max k with non-empty k-core) of each graph."""
    return jnp.max(coreness(adj, mask), axis=-1)
