r"""Batched graph-update representation for dynamic-network streams.

A ``DeltaBatch`` carries, for every graph in a :class:`~repro.core.graph.
GraphBatch`, a fixed number of *slots* of three update kinds:

* **edge ops** — insert / delete an undirected edge ``{u, v}``;
* **f ops** — overwrite the vertex filtration value ``f(w)``;
* **vertex drops** — deactivate a vertex (mask off; incident edges die).

Slots are static-capacity (padded with ``-1`` / ``EDGE_NOP``) so a whole
update stream is one stacked pytree and ``apply_delta`` is a single jitted
scatter program — the same dense-masked-linear-algebra philosophy as the rest
of the core (DESIGN.md §3).  Temporal generators (repro/data/temporal.py)
emit DeltaBatches with a leading time axis; ``delta_step`` slices one step.

Semantics (all enforced by ``apply_delta``; ``canonicalize_delta`` restores
the slot-level invariants from raw arrays):

* edges are undirected — ops are canonicalized to ``u < v`` and applied
  symmetrically; self loops and out-of-range endpoints are dropped;
* a delete beats an insert of the same edge within one DeltaBatch;
* inserting an edge **activates** both endpoints (grows the graph into
  padding slots); a newly activated vertex with no f op gets ``f = 0``;
* vertex drops beat everything touching the dropped vertex;
* f is explicit stream state (paper Remark 1: the filtration is *not*
  recomputed on the updated graph) — degree-filtration users must ship f ops
  alongside their edge ops.
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.graph import GraphBatch, canonicalize

EDGE_NOP = 0
EDGE_INSERT = 1
EDGE_DELETE = 2


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class DeltaBatch:
    """One batched update step (or a stacked (T, ...) stream of steps).

    edge_u/edge_v: (B, E) int32 endpoints, ``-1`` for unused slots.
    edge_op:       (B, E) int32 in {EDGE_NOP, EDGE_INSERT, EDGE_DELETE}.
    f_vertex:      (B, F) int32 vertex ids (``-1`` unused).
    f_value:       (B, F) float32 new filtration values.
    drop_vertex:   (B, D) int32 vertex ids to deactivate (``-1`` unused).
    """

    edge_u: jax.Array
    edge_v: jax.Array
    edge_op: jax.Array
    f_vertex: jax.Array
    f_value: jax.Array
    drop_vertex: jax.Array

    @property
    def batch(self) -> int:
        return self.edge_u.shape[-2]

    @property
    def edge_slots(self) -> int:
        return self.edge_u.shape[-1]

    @property
    def f_slots(self) -> int:
        return self.f_vertex.shape[-1]

    @property
    def drop_slots(self) -> int:
        return self.drop_vertex.shape[-1]

    @property
    def steps(self) -> int:
        """Leading time axis length for stacked streams (1 for a single step)."""
        return self.edge_u.shape[0] if self.edge_u.ndim == 3 else 1


def delta_step(d: DeltaBatch, t: int) -> DeltaBatch:
    """Slice step ``t`` out of a stacked (T, B, ...) DeltaBatch stream."""
    return jax.tree.map(lambda x: x[t], d)


def empty_delta(batch: int, edge_slots: int = 0, f_slots: int = 0,
                drop_slots: int = 0) -> DeltaBatch:
    """An all-padding DeltaBatch (useful as a scan carry / test fixture)."""
    return DeltaBatch(
        edge_u=jnp.full((batch, edge_slots), -1, jnp.int32),
        edge_v=jnp.full((batch, edge_slots), -1, jnp.int32),
        edge_op=jnp.full((batch, edge_slots), EDGE_NOP, jnp.int32),
        f_vertex=jnp.full((batch, f_slots), -1, jnp.int32),
        f_value=jnp.zeros((batch, f_slots), jnp.float32),
        drop_vertex=jnp.full((batch, drop_slots), -1, jnp.int32),
    )


def canonicalize_delta(d: DeltaBatch, n: int) -> DeltaBatch:
    """Restore slot invariants: u < v, no self loops, in-range ids, -1 pads."""
    u = jnp.minimum(d.edge_u, d.edge_v)
    v = jnp.maximum(d.edge_u, d.edge_v)
    ok = ((u >= 0) & (v < n) & (u != v)
          & (d.edge_op != EDGE_NOP))
    op = jnp.where(ok, d.edge_op, EDGE_NOP)
    u = jnp.where(ok, u, -1)
    v = jnp.where(ok, v, -1)
    f_ok = (d.f_vertex >= 0) & (d.f_vertex < n)
    fv = jnp.where(f_ok, d.f_vertex, -1)
    dr_ok = (d.drop_vertex >= 0) & (d.drop_vertex < n)
    dr = jnp.where(dr_ok, d.drop_vertex, -1)
    return DeltaBatch(edge_u=u.astype(jnp.int32), edge_v=v.astype(jnp.int32),
                      edge_op=op.astype(jnp.int32),
                      f_vertex=fv.astype(jnp.int32),
                      f_value=d.f_value.astype(jnp.float32),
                      drop_vertex=dr.astype(jnp.int32))


def _valid_pairs(n: int, u: jax.Array, v: jax.Array) -> jax.Array:
    """Slots holding a well-formed undirected edge: in range, no self loop."""
    return (u >= 0) & (u < n) & (v >= 0) & (v < n) & (u != v)


def _scatter_pairs(b: int, n: int, u: jax.Array, v: jax.Array,
                   on: jax.Array) -> jax.Array:
    """(B, N, N) bool with True at (u, v) and (v, u) for slots where ``on``."""
    # sentinel-out invalid slots so mode="drop" discards them (negative ids
    # would otherwise wrap under NumPy indexing semantics)
    uu = jnp.where(on, u, n)
    vv = jnp.where(on, v, n)
    bidx = jnp.arange(b)[:, None]
    m = jnp.zeros((b, n, n), bool)
    m = m.at[bidx, uu, vv].set(True, mode="drop")
    return m | jnp.swapaxes(m, -1, -2)


def _scatter_vertices(b: int, n: int, ids: jax.Array,
                      on: jax.Array) -> jax.Array:
    """(B, N) bool with True at the listed vertex ids where ``on``."""
    valid = on & (ids >= 0) & (ids < n)
    vv = jnp.where(valid, ids, n)
    bidx = jnp.arange(b)[:, None]
    return jnp.zeros((b, n), bool).at[bidx, vv].set(True, mode="drop")


@jax.jit
def apply_delta(g: GraphBatch, d: DeltaBatch) -> GraphBatch:
    """Apply one DeltaBatch step to a GraphBatch (pure, jitted).

    Update order (ties documented in the module docstring): edge inserts,
    edge deletes (delete wins), endpoint activation, f ops, vertex drops
    (drop wins), then ``canonicalize`` restores every GraphBatch invariant
    (symmetry, empty diagonal, mask-sentinel adjacency, +inf f padding).
    """
    b, n = g.batch, g.n
    # malformed edge ops (self loops, out-of-range endpoints) are dropped as
    # a PAIR — they must neither touch adjacency nor activate an endpoint
    ok = _valid_pairs(n, d.edge_u, d.edge_v)
    is_ins = ok & (d.edge_op == EDGE_INSERT)
    is_del = ok & (d.edge_op == EDGE_DELETE)
    ins = _scatter_pairs(b, n, d.edge_u, d.edge_v, is_ins)
    dele = _scatter_pairs(b, n, d.edge_u, d.edge_v, is_del)
    adj = (g.adj | ins) & ~dele

    act = (_scatter_vertices(b, n, d.edge_u, is_ins)
           | _scatter_vertices(b, n, d.edge_v, is_ins))
    drop = _scatter_vertices(b, n, d.drop_vertex,
                             jnp.ones_like(d.drop_vertex, bool))
    mask = (g.mask | act) & ~drop

    f = g.f
    if d.f_vertex.shape[-1]:
        f_on = (d.f_vertex >= 0) & (d.f_vertex < n)
        fv = jnp.where(f_on, d.f_vertex, n)
        bidx = jnp.arange(b)[:, None]
        # duplicate f ops on one vertex: highest slot index wins (matches
        # delta_from_lists' last-wins dedupe).  A plain .at[].set with
        # duplicate indices is nondeterministic in JAX; scatter-max of the
        # slot index followed by a gather is deterministic.
        slots = jnp.arange(d.f_vertex.shape[-1], dtype=jnp.int32)
        win = jnp.full((b, n + 1), -1, jnp.int32).at[bidx, fv].max(
            jnp.where(f_on, slots[None, :], -1))[:, :n]
        val = jnp.take_along_axis(d.f_value, jnp.clip(win, 0), axis=-1)
        f = jnp.where(win >= 0, val, f)
    # newly activated vertices default to f = 0 unless an f op set them
    newly = mask & ~g.mask
    f = jnp.where(newly & jnp.isinf(f), 0.0, f)
    return canonicalize(adj, mask, f)


def delta_from_lists(
    edge_ops: Sequence[Sequence[tuple[int, int, int]]],
    f_ops: Sequence[Sequence[tuple[int, float]]] | None = None,
    drops: Sequence[Sequence[int]] | None = None,
    edge_slots: int | None = None,
    f_slots: int | None = None,
    drop_slots: int | None = None,
) -> DeltaBatch:
    """Build a single-step DeltaBatch from python lists (host-side helper).

    edge_ops[i] is a list of ``(u, v, op)`` with op in {EDGE_INSERT,
    EDGE_DELETE} (or the strings "insert"/"delete"); duplicate ops on the
    same canonical edge keep the *last* occurrence (last-wins dedupe).
    """
    b = len(edge_ops)
    f_ops = f_ops if f_ops is not None else [[] for _ in range(b)]
    drops = drops if drops is not None else [[] for _ in range(b)]
    ops_named = {"insert": EDGE_INSERT, "delete": EDGE_DELETE}

    deduped: list[list[tuple[int, int, int]]] = []
    for ops in edge_ops:
        seen: dict[tuple[int, int], int] = {}
        for (u, v, op) in ops:
            op = ops_named.get(op, op)
            if u == v:
                continue
            seen[(min(u, v), max(u, v))] = int(op)
        deduped.append([(u, v, op) for (u, v), op in seen.items()])
    f_deduped = [list(dict(fo).items()) for fo in f_ops]

    e_cap = edge_slots if edge_slots is not None else max(
        [len(x) for x in deduped] + [0])
    f_cap = f_slots if f_slots is not None else max(
        [len(x) for x in f_deduped] + [0])
    d_cap = drop_slots if drop_slots is not None else max(
        [len(x) for x in drops] + [0])

    eu = np.full((b, e_cap), -1, np.int32)
    ev = np.full((b, e_cap), -1, np.int32)
    eo = np.full((b, e_cap), EDGE_NOP, np.int32)
    fv = np.full((b, f_cap), -1, np.int32)
    fx = np.zeros((b, f_cap), np.float32)
    dr = np.full((b, d_cap), -1, np.int32)
    for i in range(b):
        for j, (u, v, op) in enumerate(deduped[i][:e_cap]):
            eu[i, j], ev[i, j], eo[i, j] = u, v, op
        for j, (w, val) in enumerate(f_deduped[i][:f_cap]):
            fv[i, j], fx[i, j] = w, val
        for j, w in enumerate(list(drops[i])[:d_cap]):
            dr[i, j] = w
    return DeltaBatch(edge_u=jnp.asarray(eu), edge_v=jnp.asarray(ev),
                      edge_op=jnp.asarray(eo), f_vertex=jnp.asarray(fv),
                      f_value=jnp.asarray(fx), drop_vertex=jnp.asarray(dr))
