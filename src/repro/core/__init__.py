"""TopoPipe core: CoralTDA + PrunIT exact reductions and persistence."""
from repro.core.api import (
    ReductionStats,
    TopoPlan,
    TopoPlanKey,
    clear_plan_cache,
    make_topo_plan,
    plan_cache_info,
    reduce_graphs,
    reduction_stats,
    topological_signature,
)
from repro.core.graph import GraphBatch, canonicalize, degree_filtration, from_edge_lists, from_networkx
from repro.core.kcore import coral_reduce, coreness, degeneracy, kcore, kcore_mask
from repro.core.persistence_jax import Diagrams, persistence_diagrams_batched
from repro.core.prunit import domination_matrix, prunit, prunit_mask, prunit_then_coral

__all__ = [
    "Diagrams",
    "GraphBatch",
    "ReductionStats",
    "TopoPlan",
    "TopoPlanKey",
    "canonicalize",
    "clear_plan_cache",
    "make_topo_plan",
    "plan_cache_info",
    "coral_reduce",
    "coreness",
    "degeneracy",
    "degree_filtration",
    "domination_matrix",
    "from_edge_lists",
    "from_networkx",
    "kcore",
    "kcore_mask",
    "persistence_diagrams_batched",
    "prunit",
    "prunit_mask",
    "prunit_then_coral",
    "reduce_graphs",
    "reduction_stats",
    "topological_signature",
]
