"""Filtration construction utilities (sublevel / superlevel / power).

The JAX persistence engine (persistence_jax.py) consumes a *filtered clique
complex* built here: a static-capacity table of simplices (vertices, edges,
triangles, tetrahedra) with entry values and face indices, all as dense JAX
arrays so the whole pipeline vmaps over a GraphBatch and pjit-shards over the
data axis.
"""
from __future__ import annotations

import dataclasses
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np


@jax.tree_util.register_dataclass
@dataclasses.dataclass(frozen=True)
class FilteredComplex:
    """Static-capacity filtered clique complex for one graph.

    All arrays are in *sorted filtration order* (value asc, dim asc).
      values:  (S,) f32, +inf for padding slots.
      dims:    (S,) i32, simplex dimension (-1 padding).
      valid:   (S,) bool.
      face_pos:(S, 4) i32 sorted positions of boundary faces (-1 unused).
    """

    values: jax.Array
    dims: jax.Array
    valid: jax.Array
    face_pos: jax.Array

    @property
    def size(self) -> int:
        return self.values.shape[0]


def _first_k_indices(flat_mask: jax.Array, cap: int) -> tuple[jax.Array, jax.Array]:
    """Indices of the first ``cap`` set bits (ascending) and their validity.

    Stable argsort on the boolean key (set bits first, index ascending).
    Note: ``lax.top_k`` would be cheaper single-device, but GSPMD cannot
    partition TopK and all-gathers the whole batch on a pod mesh (measured:
    3 GB/device of batch all-gathers on 256 chips) — Sort partitions cleanly
    on the batch axis, so argsort wins at scale (§Perf iteration 4).
    """
    order = jnp.argsort(~flat_mask, stable=True)[:cap]
    valid = flat_mask[order]
    if order.shape[0] < cap:  # cap exceeds the universe: pad with invalid slots
        pad = cap - order.shape[0]
        order = jnp.pad(order, (0, pad))
        valid = jnp.pad(valid, (0, pad))
    return order.astype(jnp.int32), valid


def build_filtered_complex(
    adj: jax.Array,
    mask: jax.Array,
    f: jax.Array,
    max_dim: int,
    edge_cap: int,
    tri_cap: int,
    quad_cap: int = 0,
    sublevel: bool = True,
) -> FilteredComplex:
    """Build the sorted filtered clique complex of one graph (vmap over batch).

    Simplices up to dimension max_dim + 1 are required (deaths of max_dim
    classes): max_dim=0 -> edges, max_dim=1 -> triangles, max_dim=2 -> tetra.
    Capacities are static; real counts beyond a cap raise at the caller's
    discretion via the returned validity information (see ops.check_caps).
    """
    n = adj.shape[-1]
    fv = jnp.where(mask, f, jnp.inf)
    if not sublevel:
        fv = jnp.where(mask, -f, jnp.inf)
    adjm = adj & mask[None, :] & mask[:, None]

    iu = jnp.arange(n)
    # --- edges (dim 1) ---
    upper = adjm & (iu[:, None] < iu[None, :])
    e_flat, e_valid = _first_k_indices(upper.reshape(-1), edge_cap)
    eu, ev = e_flat // n, e_flat % n
    e_val = jnp.where(e_valid, jnp.maximum(fv[eu], fv[ev]), jnp.inf)
    # edge slot lookup (u, v) -> edge index
    edge_id = jnp.full((n, n), -1, jnp.int32)
    edge_id = edge_id.at[eu, ev].set(
        jnp.where(e_valid, jnp.arange(edge_cap, dtype=jnp.int32), -1),
        mode="drop",
    )

    slots_values = [fv, e_val]
    slots_dims = [jnp.zeros(n, jnp.int32), jnp.ones(edge_cap, jnp.int32)]
    slots_valid = [mask, e_valid]

    t_cap = tri_cap if max_dim >= 1 else 0
    if t_cap:
        # §Perf iteration 2: enumerate triangles per *selected edge* instead
        # of over the full (N,N,N) tensor.  A triangle {i<j<k} is found
        # exactly once, on its (i,j) edge with third vertex k>j, so the
        # candidate universe shrinks from N^3 to edge_cap x N (8x fewer keys
        # through the top_k selection at N=64, and it scales with the graph's
        # true edge count, not its padded order).
        # §Perf iteration 4: the row selections adjm[eu]/adjm[ev] are
        # expressed as one-hot matmuls — GSPMD cannot partition the vmapped
        # gather and falls back to all-gathering the whole batch (3 GB/dev on
        # the 256-chip mesh); the einsum partitions cleanly and runs on the
        # MXU.
        hot_u = jax.nn.one_hot(eu, n, dtype=jnp.bfloat16)   # (E, N)
        hot_v = jax.nn.one_hot(ev, n, dtype=jnp.bfloat16)
        adj_f = adjm.astype(jnp.bfloat16)
        rows_u = jnp.einsum("en,nw->ew", hot_u, adj_f) > 0.5
        rows_v = jnp.einsum("en,nw->ew", hot_v, adj_f) > 0.5
        third = (rows_u & rows_v                       # common neighbors
                 & (iu[None, :] > ev[:, None])          # k > j
                 & e_valid[:, None])                    # live edges only
        t_flat, t_valid = _first_k_indices(third.reshape(-1), t_cap)
        te = t_flat // n                                # edge slot
        ti = eu[te]
        tj = ev[te]
        tk = t_flat % n
        t_val = jnp.where(
            t_valid, jnp.maximum(jnp.maximum(fv[ti], fv[tj]), fv[tk]), jnp.inf
        )
        # tri_id (an (N,N,N) i32 scatter) is only needed to look up the faces
        # of tetrahedra — skip it entirely when quads are disabled
        # (§Perf iteration 1: saves the N^3 i32 materialization + scatter).
        q_cap_active = quad_cap if max_dim >= 2 else 0
        if q_cap_active:
            tri_id = jnp.full((n, n, n), -1, jnp.int32)
            tri_id = tri_id.at[ti, tj, tk].set(
                jnp.where(t_valid, jnp.arange(t_cap, dtype=jnp.int32), -1),
                mode="drop",
            )
        slots_values.append(t_val)
        slots_dims.append(jnp.full(t_cap, 2, jnp.int32))
        slots_valid.append(t_valid)

    q_cap = quad_cap if max_dim >= 2 else 0
    if q_cap:
        # §Perf iteration 2 (same idea as triangles): enumerate tetrahedra
        # per selected triangle — candidate universe tri_cap x N instead of
        # the N^4 tensor.  {i<j<k<l} found exactly once on its {i,j,k} face.
        fourth = (adjm[ti] & adjm[tj] & adjm[tk]
                  & (iu[None, :] > tk[:, None])
                  & t_valid[:, None])
        q_flat, q_valid = _first_k_indices(fourth.reshape(-1), q_cap)
        qt = q_flat // n
        qi = ti[qt]
        qj = tj[qt]
        qk = tk[qt]
        ql = q_flat % n
        q_val = jnp.where(
            q_valid,
            jnp.maximum(jnp.maximum(fv[qi], fv[qj]), jnp.maximum(fv[qk], fv[ql])),
            jnp.inf,
        )
        slots_values.append(q_val)
        slots_dims.append(jnp.full(q_cap, 3, jnp.int32))
        slots_valid.append(q_valid)

    values = jnp.concatenate(slots_values)
    dims = jnp.concatenate(slots_dims)
    valid = jnp.concatenate(slots_valid)
    s_total = values.shape[0]

    # --- filtration order: (value, dim, slot) lexicographic ---
    perm = jnp.lexsort((jnp.arange(s_total), dims, values))
    pos_of_slot = jnp.zeros(s_total, jnp.int32).at[perm].set(
        jnp.arange(s_total, dtype=jnp.int32)
    )

    # --- face slots per unsorted slot ---
    face_slot = jnp.full((s_total, 4), -1, jnp.int32)
    # edges -> vertex slots
    e_rows = n + jnp.arange(edge_cap)
    face_slot = face_slot.at[e_rows, 0].set(jnp.where(e_valid, eu.astype(jnp.int32), -1))
    face_slot = face_slot.at[e_rows, 1].set(jnp.where(e_valid, ev.astype(jnp.int32), -1))
    if t_cap:
        t_rows = n + edge_cap + jnp.arange(t_cap)
        f0 = edge_id[ti, tj]
        f1 = edge_id[ti, tk]
        f2 = edge_id[tj, tk]
        for c, fid in enumerate((f0, f1, f2)):
            face_slot = face_slot.at[t_rows, c].set(
                jnp.where(t_valid & (fid >= 0), n + fid, -1)
            )
    if q_cap:
        q_rows = n + edge_cap + t_cap + jnp.arange(q_cap)
        g0 = tri_id[qi, qj, qk]
        g1 = tri_id[qi, qj, ql]
        g2 = tri_id[qi, qk, ql]
        g3 = tri_id[qj, qk, ql]
        for c, gid in enumerate((g0, g1, g2, g3)):
            face_slot = face_slot.at[q_rows, c].set(
                jnp.where(q_valid & (gid >= 0), n + edge_cap + gid, -1)
            )

    # --- reorder everything into sorted position space ---
    values_s = values[perm]
    dims_s = jnp.where(valid[perm], dims[perm], -1)
    valid_s = valid[perm]
    fs = face_slot[perm]
    face_pos = jnp.where(fs >= 0, pos_of_slot[jnp.clip(fs, 0)], -1)
    return FilteredComplex(values=values_s, dims=dims_s, valid=valid_s, face_pos=face_pos)


def complex_caps_ok(adj: jax.Array, mask: jax.Array, edge_cap: int, tri_cap: int,
                    quad_cap: int = 0, max_dim: int = 1) -> jax.Array:
    """True if the static capacities hold all simplices of this graph."""
    n = adj.shape[-1]
    iu = jnp.arange(n)
    adjm = adj & mask[None, :] & mask[:, None]
    n_e = jnp.sum(adjm) // 2
    ok = n_e <= edge_cap
    if max_dim >= 1:
        a_f = adjm.astype(jnp.float32)
        tri_total = jnp.einsum("ij,jk,ki->", a_f, a_f, a_f) / 6.0
        ok = ok & (tri_total <= tri_cap)
    if max_dim >= 2 and quad_cap:
        tri = (
            adjm[:, :, None] & adjm[:, None, :] & adjm[None, :, :]
            & (iu[:, None, None] < iu[None, :, None])
            & (iu[None, :, None] < iu[None, None, :])
        )
        quad = (
            tri[:, :, :, None]
            & adjm[:, None, None, :] & adjm[None, :, None, :] & adjm[None, None, :, :]
            & (iu[None, None, :, None] < iu[None, None, None, :])
        )
        ok = ok & (jnp.sum(quad) <= quad_cap)
    return ok


def graph_power_distances(adj: np.ndarray, mask: np.ndarray) -> np.ndarray:
    """All-pairs shortest-path hop distances (NumPy; inf if disconnected)."""
    adj = np.asarray(adj, bool)
    mask = np.asarray(mask, bool)
    n = adj.shape[0]
    dist = np.full((n, n), np.inf)
    reach = adj & mask[None, :] & mask[:, None]
    np.fill_diagonal(dist, 0.0)
    dist[reach & np.isinf(dist)] = 1.0
    cur = reach.copy()
    for step in range(2, n + 1):
        cur = (cur @ reach) & mask[None, :] & mask[:, None]
        newly = cur & np.isinf(dist)
        if not newly.any():
            break
        dist[newly] = float(step)
    dist[~mask, :] = np.inf
    dist[:, ~mask] = np.inf
    np.fill_diagonal(dist, 0.0)
    return dist
