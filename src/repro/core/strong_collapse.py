"""Strong Collapse baseline (Boissonnat–Pritam), paper Remark 13 / Table 3.

Strong collapse removes dominated vertices of each *flag complex in the
filtration sequence* separately — it must run once per threshold, whereas
PrunIT runs once on the graph.  We implement it with the same dense
domination machinery (no f-condition: within a fixed complex any dominated
vertex may be collapsed) so the comparison is apples-to-apples on identical
compute primitives.
"""
from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.graph import GraphBatch
from repro.core.prunit import domination_matrix


def collapse_mask(adj: jax.Array, mask: jax.Array) -> jax.Array:
    """Fully strong-collapse one (batch of) fixed graph(s): surviving mask."""

    def cond(state):
        m, changed = state
        return changed

    def body(state):
        m, _ = state
        adj_m = adj & m[..., None, :] & m[..., :, None]
        dom = domination_matrix(adj_m, m)  # dom[u, v]: v dominates u
        dom_t = jnp.swapaxes(dom, -1, -2)
        n = adj.shape[-1]
        idx = jnp.arange(n)
        v_lt_u = idx[None, :] < idx[:, None]
        removable_by = dom & (~dom_t | v_lt_u)
        new = m & ~jnp.any(removable_by, axis=-1)
        return new, jnp.any(new != m)

    m, _ = lax.while_loop(cond, body, (mask, jnp.array(True)))
    return m


@partial(jax.jit, static_argnames=("n_steps", "sublevel"))
def strong_collapse_filtration_masks(
    g: GraphBatch, thresholds: jax.Array, n_steps: int, sublevel: bool = True
) -> tuple[jax.Array, jax.Array]:
    """Collapse every sublevel subcomplex G_i separately.

    Returns (sub_masks, collapsed_masks), each (n_steps, B, N).  The work is
    n_steps domination fixed points — the cost PrunIT avoids by pruning once.
    """

    def per_step(alpha):
        if sublevel:
            sub = g.mask & (g.f <= alpha)
        else:
            sub = g.mask & (g.f >= alpha)
        adj_i = g.adj & sub[..., None, :] & sub[..., :, None]
        return sub, collapse_mask(adj_i, sub)

    return jax.vmap(per_step)(thresholds)
