"""CLI: ``python -m repro.perfgate {check,tune}``.

``check`` runs benchmark suites and gates fresh numbers against the
committed ``results/BENCH_*.json`` baselines (exit 1 on any regression
past its band, or on a suite crash).  ``tune`` sweeps the Pallas tile
spaces and pins per-device winners to ``results/TUNED_tiles.json``.
"""
from __future__ import annotations

import argparse
import sys


def _csv(s: str) -> list[str]:
    return [t for t in s.replace(",", " ").split() if t]


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.perfgate",
        description=__doc__.strip().splitlines()[0])
    sub = p.add_subparsers(dest="cmd", required=True)

    c = sub.add_parser("check", help="gate fresh benchmark runs against "
                                     "committed BENCH_*.json references")
    c.add_argument("--only", type=_csv, default=None, metavar="SUITE,...",
                   help="subset of benchmark suites (default: all)")
    c.add_argument("--quick", action="store_true",
                   help="CI-sized workloads; size-dependent rows demote to "
                        "info unless the baseline is also quick")
    c.add_argument("--band-scale", type=float, default=1.0, metavar="F",
                   help="multiply every relative tolerance band "
                        "(abs_upper correctness rows never loosen)")
    c.add_argument("--results", default="results", metavar="DIR",
                   help="directory holding BENCH_*.json baselines")
    c.add_argument("--out", default=None, metavar="PATH",
                   help="gate report path (default: RESULTS/GATE_report.json)")

    t = sub.add_parser("tune", help="sweep Pallas tile spaces, pin winners "
                                    "to results/TUNED_tiles.json")
    t.add_argument("--only", type=_csv, default=None, metavar="KERNEL,...",
                   help="subset of tunable kernels (default: all)")
    t.add_argument("--quick", action="store_true",
                   help="smaller sweep workloads (CI)")
    t.add_argument("--repeats", type=int, default=2, metavar="N",
                   help="timed repetitions per candidate (best-of)")
    t.add_argument("--out", default=None, metavar="PATH",
                   help="tile file path (default: results/TUNED_tiles.json)")
    t.add_argument("--dry-run", action="store_true",
                   help="sweep and report, but do not write the tile file")

    args = p.parse_args(argv)
    if args.cmd == "check":
        from repro.perfgate.gate import check

        report = check(only=args.only, quick=args.quick,
                       band_scale=args.band_scale, results_dir=args.results,
                       out=args.out)
        return 0 if report["ok"] else 1

    from repro.perfgate.autotune import tune

    tune(only=args.only, quick=args.quick, repeats=args.repeats,
         path=args.out, save=not args.dry_run)
    return 0


if __name__ == "__main__":
    sys.exit(main())
